//! Figure 6 reproduction: associative-array multiplication `A @ B` —
//! sorted intersection of `A.col ∩ B.row`, SpGEMM, condense (paper
//! §II.C.3). The paper sweeps n ≤ 17 (vs 18 elsewhere) because of the
//! op's cost; the full sweep here honors that cap.
//!
//! Usage: `cargo bench --bench fig6_matmul -- [--full] ...`

mod fig_common;

use d4m::bench::BenchParams;
use fig_common::{run_figure, BinaryOp, OpKind};

fn main() {
    let params = BenchParams::from_env(17, 11);
    run_figure(
        "fig6",
        "array multiplication A @ B (paper Fig. 6)",
        OpKind::Binary(BinaryOp::Matmul),
        &params,
    );
}
