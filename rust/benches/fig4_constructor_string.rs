//! Figure 4 reproduction: `Assoc` constructor runtime, string values
//! (≈8 random length-8 strings per row; the constructor additionally
//! builds the sorted unique value pool and stores 1-based indices).
//!
//! Usage: `cargo bench --bench fig4_constructor_string -- [--full] ...`

mod fig_common;

use d4m::bench::BenchParams;
use fig_common::{run_figure, OpKind};

fn main() {
    let params = BenchParams::from_env(18, 12);
    run_figure(
        "fig4",
        "Assoc constructor, string values (paper Fig. 4)",
        OpKind::Construct { string_vals: true },
        &params,
    );
}
