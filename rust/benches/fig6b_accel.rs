//! Beyond-paper bench: dense-block PJRT kernel vs host SpGEMM for `@`,
//! swept over operand density — locates the crossover that justifies
//! the `should_accelerate` dispatch threshold (DESIGN.md §5).
//!
//! Skips (exit 0) when artifacts are missing so `cargo bench` works
//! before `make artifacts`.
//!
//! Usage: `cargo bench --bench fig6b_accel -- [--repeats R] [--out DIR]`

use d4m::assoc::{Assoc, ValsInput};
use d4m::bench::FigureHarness;
use d4m::runtime::{accel_matmul, Runtime};
use d4m::semiring::PlusTimes;
use d4m::util::{time_op, Args, SplitMix64};

fn random_assoc(seed: u64, keys: u64, density: f64) -> Assoc {
    let mut r = SplitMix64::new(seed);
    let triples = ((keys * keys) as f64 * density) as usize;
    let rows: Vec<String> = (0..triples).map(|_| format!("k{:05}", r.below(keys))).collect();
    let cols: Vec<String> = (0..triples).map(|_| format!("k{:05}", r.below(keys))).collect();
    let vals: Vec<f64> = (0..triples).map(|_| r.range_i64(1, 9) as f64).collect();
    Assoc::from_triples(&rows, &cols, ValsInput::Num(vals))
}

fn main() {
    let args = Args::from_env();
    let repeats = args.usize_or("repeats", 3);
    let out_dir = args.str_or("out", "results");
    // Pin the host-SpGEMM baseline to the serial code path (like every
    // figure bench) so the host-vs-PJRT crossover stays comparable;
    // --threads N opts into parallel measurement.
    d4m::util::Parallelism::with_threads(args.usize_or("threads", 1)).set_default();
    let rt = match Runtime::load_default() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("fig6b_accel: skipping ({e}); run `make artifacts`");
            return;
        }
    };
    let mut harness = FigureHarness::new(
        "fig6b",
        "host SpGEMM vs PJRT dense-block matmul across density (beyond-paper)",
    );
    // Encode density as the n column (permille) for CSV compatibility.
    for (i, density) in [0.002, 0.01, 0.05, 0.1, 0.2].into_iter().enumerate() {
        let a = random_assoc(100 + i as u64, 512, density);
        let b = random_assoc(200 + i as u64, 512, density);
        let permille = (density * 1000.0) as usize;

        let mut nnz = 0usize;
        let t_host = time_op(1, repeats, |_| {
            let c = a.matmul_with(&b, &PlusTimes);
            nnz = c.nnz();
            c
        });
        harness.record(permille, "host-spgemm", t_host, nnz);

        // Warm the kernel cache before timing (first call compiles).
        let _ = accel_matmul(&rt, &a, &b, &PlusTimes).unwrap();
        let mut nnz2 = 0usize;
        let t_pjrt = time_op(0, repeats, |_| {
            let (c, _) = accel_matmul(&rt, &a, &b, &PlusTimes).unwrap();
            nnz2 = c.nnz();
            c
        });
        assert_eq!(nnz, nnz2, "PJRT and host results must agree");
        harness.record(permille, "pjrt-dense", t_pjrt, nnz2);
    }
    harness.write_csv(&out_dir).expect("write CSV");
}
