//! Figure 7 reproduction: element-wise multiplication `A * B` —
//! sorted-intersection key alignment + sparse element-wise multiply
//! (paper §II.C.2). The paper sweeps only n ≤ 13 here "because of the
//! large running times relative to n" of the MATLAB/Julia engines —
//! the figure where implementation strategies diverge most.
//!
//! Usage: `cargo bench --bench fig7_elemmul -- [--full] ...`

mod fig_common;

use d4m::bench::BenchParams;
use fig_common::{run_figure, BinaryOp, OpKind};

fn main() {
    let params = BenchParams::from_env(13, 11);
    run_figure(
        "fig7",
        "element-wise multiplication A * B (paper Fig. 7)",
        OpKind::Binary(BinaryOp::Elemmul),
        &params,
    );
}
