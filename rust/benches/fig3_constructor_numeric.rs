//! Figure 3 reproduction: `Assoc` constructor runtime, numeric values.
//!
//! Paper workload (§III.A): arrays of dimension ≈ 2ⁿ×2ⁿ with 8·2ⁿ
//! triples, keys = uniform ints in [0, 2ⁿ] cast to strings, values =
//! uniform ints (numeric). Series: one per engine (paper: Python /
//! MATLAB / Julia; here: d4m-rs / hashmap / btree — see DESIGN.md §3).
//!
//! Usage: `cargo bench --bench fig3_constructor_numeric -- [--full]
//! [--min-n A] [--max-n B] [--repeats R] [--out DIR]`

mod fig_common;

use d4m::bench::BenchParams;
use fig_common::{run_figure, OpKind};

fn main() {
    let params = BenchParams::from_env(18, 12);
    run_figure(
        "fig3",
        "Assoc constructor, numeric values (paper Fig. 3)",
        OpKind::Construct { string_vals: false },
        &params,
    );
}
