//! Shared driver for the Figure 3–7 reproduction benches: sweep n,
//! run one operation on all three engines, record into the harness.

use d4m::baselines::{btree::BTreeEngine, hashmap::HashMapEngine, D4mEngine, Engine};
use d4m::bench::{BenchParams, FigureHarness, Workload};
use d4m::util::time_op;

/// Which operand set a figure op needs.
///
/// (Each bench binary uses one variant; the cross-binary "unused
/// variant" lint is silenced since the module is shared source.)
#[allow(dead_code)]
pub enum OpKind {
    /// Figs 3–4: construct from the raw key/value lists.
    Construct { string_vals: bool },
    /// Figs 5–7: binary op on `A = Assoc(rows, cols, 1)`,
    /// `B = Assoc(rows2, cols2, 1)`.
    Binary(BinaryOp),
}

/// The binary operations of Figures 5–7.
#[derive(Clone, Copy)]
#[allow(dead_code)]
pub enum BinaryOp {
    /// Fig 5 — `A + B`.
    Add,
    /// Fig 6 — `A @ B`.
    Matmul,
    /// Fig 7 — `A * B`.
    Elemmul,
}

/// Run one figure: sweep `params.ns()`, measure every engine, write CSV.
pub fn run_figure(id: &str, title: &str, kind: OpKind, params: &BenchParams) {
    params.apply_parallelism(); // honor --threads for the d4m engine
    let mut harness = FigureHarness::new(id, title);
    for n in params.ns() {
        let w = Workload::generate(n, 0xD4A7_2022 + n as u64);
        measure_engine(&D4mEngine, &mut harness, &w, &kind, params);
        measure_engine(&HashMapEngine, &mut harness, &w, &kind, params);
        measure_engine(&BTreeEngine, &mut harness, &w, &kind, params);
    }
    harness.write_csv(&params.out_dir).expect("write CSV");
}

fn measure_engine<E: Engine>(
    engine: &E,
    harness: &mut FigureHarness,
    w: &Workload,
    kind: &OpKind,
    params: &BenchParams,
) {
    match kind {
        OpKind::Construct { string_vals } => {
            let mut out_nnz = 0usize;
            let t = time_op(1, params.repeats, |_| {
                let a = if *string_vals {
                    engine.construct_string(&w.rows, &w.cols, &w.str_vals)
                } else {
                    engine.construct_numeric(&w.rows, &w.cols, &w.num_vals)
                };
                out_nnz = engine.nnz(&a);
                a
            });
            harness.record(w.n, engine.name(), t, out_nnz);
        }
        OpKind::Binary(op) => {
            let ones = w.ones();
            let a = engine.construct_numeric(&w.rows, &w.cols, &ones);
            let b = engine.construct_numeric(&w.rows2, &w.cols2, &ones);
            let mut out_nnz = 0usize;
            let op = *op;
            let t = time_op(1, params.repeats, |_| {
                let c = match op {
                    BinaryOp::Add => engine.add(&a, &b),
                    BinaryOp::Matmul => engine.matmul(&a, &b),
                    BinaryOp::Elemmul => engine.elemmul(&a, &b),
                };
                out_nnz = engine.nnz(&c);
                c
            });
            harness.record(w.n, engine.name(), t, out_nnz);
        }
    }
}
