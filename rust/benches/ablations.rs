//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! * **condense strategy** — the paper's indptr-mask trick
//!   (`csr_rows[:-1] < csr_rows[1:]`) vs rebuilding from triples.
//! * **constructor sort strategy** — the packed-u64 pair sort used by
//!   the COO builder vs sorting (row, col) tuples of strings.
//! * **CSR-resident vs COO-resident adj** — what D4M.py pays per `@`
//!   for keeping COO and converting inside each operation (the
//!   deviation documented in `assoc`'s module docs).
//! * **thread scaling** — fig6 matmul and fig3 constructor swept over
//!   worker counts (`threads = 1` is the exact serial code path),
//!   ending with a serial-vs-parallel speedup line so BENCH captures
//!   the scaling trajectory over time.
//! * **accumulator policy** — the adaptive SpGEMM engine vs the PR 1
//!   dense-scratch kernel (`AccumulatorPolicy::Dense`) on a
//!   hypersparse (1 nnz/row) workload, the regime the D4M papers show
//!   associative-array products live in.
//! * **masked TableMult** — the sink-filtered multiply
//!   (`graphulo::table_mult_masked`, masked SpGEMM under the hood) vs
//!   computing the full product and filtering afterwards. The kept
//!   cells are bit-identical by contract; with a ~10%-density sink mask
//!   the masked path must be **≥ 1.5× faster** (asserted — this is the
//!   PR's acceptance number, enforced on every CI bench smoke).
//! * **streaming vs materializing scan** — a column-windowed filtered
//!   scan consumed off the iterator stack vs materializing the full
//!   `Vec<Triple>` and filtering client-side.
//! * **dictionary-encoded key space (PR 4)** — the end-to-end
//!   scan→constructor and TableMult pipelines on the dict-encoded path
//!   (intern to `u32` ids, shared-bytes cells) vs the PR 3 string path
//!   (per-cell `Key` materialization + digest sort; per-cell string
//!   binary search in TableMult ingest). Outputs are bit-identical by
//!   contract; the combined pipeline must be **≥ 1.3× faster**
//!   (asserted — the PR 4 acceptance number). A counting global
//!   allocator additionally witnesses the filter pushdown: a highly
//!   selective streamed scan must allocate *nothing per rejected cell*
//!   (asserted against the allocation counter).
//!
//! * **BFS frontier strategy (PR 5)** — one stacked multi-range scan
//!   per hop (`graphulo::bfs` handing the frontier to the stack as a
//!   coalesced `ScanSpec::ranges` set) vs the frozen pre-PR 5 baseline
//!   issuing one absolute seek per frontier node. Frontiers are
//!   identical by contract; at a 1 000-node frontier the one-scan path
//!   must be **≥ 1.4× faster** (asserted — the PR 5 acceptance
//!   number, enforced on every CI bench smoke).
//!
//! * **Durable tier (PR 6)** — WAL-backed ingest, checkpoint recovery,
//!   and run-backed scans. Recovering a checkpointed table directory
//!   (immutable sorted runs + an empty log) must be **≥ 5× faster**
//!   than re-ingesting the workload through the durable write path,
//!   and a full scan served from runs must stay **within 1.1×** of the
//!   all-in-memory scan (speedup ≥ 0.91×, bit-identical output) —
//!   both asserted, the PR 6 acceptance numbers.
//!
//! * **Fault-injectable storage (PR 7)** — durable ingest through the
//!   deterministic retry/backoff layer (`RetryPolicy::default()`) vs
//!   the raw single-attempt PR 6 path (`RetryPolicy::none()`). With
//!   healthy storage the layer must cost **within 1.05×** (asserted —
//!   the PR 7 acceptance number). A third leg runs the same ingest
//!   through a `FaultyIo` injecting a transient fault every 16th
//!   storage operation: the retry layer heals every one, and a clean
//!   recovery is bit-identical to the all-in-memory image.
//!
//! * **Snapshot-pinned scans (PR 8)** — two legs, both asserted at
//!   **≥ 1.3×** (the PR 8 acceptance numbers). *Scan under writers*:
//!   a full scan through the pinned-snapshot path
//!   (`Table::scan_spec_par`, zero lock acquisitions after open) vs
//!   the frozen lock-per-block baseline
//!   (`Table::scan_spec_locked_par`) while writer threads overwrite
//!   the table. *Range-chunk fan-out*: a 4-thread scan of a
//!   single-tablet table, where per-tablet grouping degenerates to a
//!   serial walk but weighted range chunking still splits the work.
//!   Outputs are bit-identical by contract (asserted quiescently).
//!
//! * **Block-granular run I/O (PR 9)** — a settled multi-run durable
//!   table scanned fully resident vs through the shared LRU block cache
//!   capped at `--block-cap-pct`% of the run bytes (the beyond-RAM cold
//!   leg; bit-identical, with `peak_live_bytes` asserted within
//!   `capacity + one block per run cursor`), a warm-cache leg (the
//!   0.91× acceptance floor lives in `scripts/summarize_results.py`),
//!   and `major_compact` streamed block-by-block under the same cap vs
//!   the resident compactor (same memory bound asserted). `--block-only
//!   1` runs just this section — the CI low-memory smoke leg.
//! * **Cost-based planner (PR 10)** — planner-chosen plans vs the
//!   frozen pre-planner heuristics on parity shapes (masked TableMult
//!   and BFS, ≥ 0.95× asserted — within the 1.05× band), an
//!   adversarial ingest shape where the cost rule must beat the frozen
//!   `8×` row-restriction heuristic ≥ 1.2× (asserted), and the
//!   symbolic-exact SpGEMM output bound on column skew (allocation
//!   witness asserted). Every leg asserts bit-identical output.
//!
//! Besides the CSV, the run writes the machine-readable perf
//! trajectories `BENCH_PR2.json` (thread sweep + accumulator policies,
//! schema-compatible with the PR 2 capture), `BENCH_PR3.json`
//! (accumulator-policy row counters as extras, masked-vs-unmasked
//! TableMult, streaming-vs-materializing scans), `BENCH_PR4.json`
//! (string-vs-dict constructor + TableMult, allocation counters),
//! `BENCH_PR5.json` (per-seek vs one-scan BFS frontiers),
//! `BENCH_PR6.json` (durable ingest, checkpoint recovery, run-backed
//! scans), `BENCH_PR7.json` (retry-layer overhead and the
//! fault-healing showcase), `BENCH_PR8.json` (snapshot scans under
//! writers, range-chunk fan-out), `BENCH_PR9.json` (block-cache
//! cold/warm scans and bounded-memory compaction) and
//! `BENCH_PR10.json` (planner parity, adversarial ingest, symbolic
//! bound) for `scripts/summarize_results.py` and the CI artifacts.
//!
//! Usage: `cargo bench --bench ablations -- [--n N] [--repeats R]
//! [--threads-n N] [--hyper-scale S] [--mask-scale S]
//! [--stream-scale S] [--dict-scale S] [--bfs-scale S]
//! [--wal-scale S]` (`--threads-n`
//! sets the scale of the thread sweep; default 10, the acceptance
//! workload. `--hyper-scale` sets the hypersparse matmul to 2^S rows;
//! default 14. `--mask-scale` / `--stream-scale` / `--dict-scale` size
//! the masked-TableMult, scan, and dictionary sections to 2^S triples;
//! defaults 12, 13 and 13. `--bfs-scale` sizes the BFS graph to 2^S
//! nodes (degree 4); default 13 — the seed frontier stays pinned at
//! 1 000 nodes, the acceptance shape. `--wal-scale` sizes the durable
//! tier section to 2^S triples; default 13. `--chunk-scale` sizes the
//! snapshot-scan section to 2^S cells; default 14. `--block-scale`
//! sizes the block-cache section to 2^S cells, default 14, with
//! `--block-cap-pct` setting the cold-leg cache budget as a percentage
//! of the run bytes, default 25; `--block-only 1` runs only that
//! section. `--plan-scale` sizes the planner section to 2^S triples;
//! default 12).

use d4m::assoc::{keys_from, Aggregator, Assoc, Key, KeyEncoding, ValsInput};
use d4m::bench::{BenchRecord, FigureHarness, Workload};
use d4m::graphulo;
use d4m::plan::Choices;
use d4m::semiring::{PlusTimes, Semiring};
use d4m::sparse::{
    spgemm, spgemm_par, spgemm_with_modes_par, spgemm_with_policy_par, AccumulatorPolicy,
    CooMatrix, CsrMatrix, SymbolicBound,
};
use d4m::store::{
    format_num, BatchWriter, BlockCache, CellFilter, CompactionSpec, DurableOptions, FaultKind,
    FaultPlan, FaultyIo, FsyncPolicy, KeyMatch, ScanIter, ScanRange, ScanSpec, Table, TableConfig,
    TableStore, Triple, WriterConfig,
};
use d4m::util::{time_op, Args, Parallelism, RetryPolicy, SplitMix64};
use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::BTreeSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Allocation-counting wrapper around the system allocator. The
/// filter-pushdown acceptance ("zero per-rejected-cell allocation")
/// can only be witnessed by a real allocator hook; the counter costs
/// one relaxed atomic per allocation and applies equally to every
/// section, so relative numbers stay fair.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// The PR 3 scan→assoc path, verbatim: materialize the scan, build one
/// `Key` per cell, digest-sort every cell's keys. **Frozen snapshot**
/// — `tests/dict_equivalence.rs` carries its twin
/// (`triples_to_assoc_string_path`); change both together or not at
/// all.
fn scan_to_assoc_string_path(table: &Table) -> Assoc {
    let triples = table.scan_par(ScanRange::all(), Parallelism::serial());
    let rows: Vec<Key> = triples.iter().map(|t| Key::str(t.row.as_str())).collect();
    let cols: Vec<Key> = triples.iter().map(|t| Key::str(t.col.as_str())).collect();
    let numeric: Option<Vec<f64>> = triples.iter().map(|t| t.val.parse::<f64>().ok()).collect();
    let vals = match numeric {
        Some(nums) => ValsInput::Num(nums),
        None => ValsInput::Str(triples.iter().map(|t| t.val.to_string()).collect()),
    };
    Assoc::try_new_with(
        rows,
        cols,
        vals,
        Aggregator::Last,
        Parallelism::serial(),
        KeyEncoding::Sort,
    )
    .expect("scan triples are consistent")
}

/// The PR 3 TableMult ingest, verbatim: owned strings, sorted distinct
/// column list, one string binary search per cell — then the same
/// SpGEMM and the same write-back, so the delta is pure encoding cost.
/// **Frozen snapshot** — `tests/dict_equivalence.rs` carries its twin
/// (`table_mult_string_baseline`); change both together or not at all.
fn table_mult_string_path(a: &Table, b: &Table, out: &Arc<Table>, s: &dyn Semiring) -> usize {
    struct Side {
        rows: Vec<String>,
        row_of: Vec<u32>,
        cols: Vec<String>,
        vals: Vec<f64>,
    }
    let ingest = |t: &Table| {
        let mut side =
            Side { rows: Vec::new(), row_of: Vec::new(), cols: Vec::new(), vals: Vec::new() };
        for tr in t.scan_par(ScanRange::all(), Parallelism::serial()) {
            if side.rows.last().map(String::as_str) != Some(tr.row.as_str()) {
                side.rows.push(tr.row.to_string());
            }
            side.row_of.push((side.rows.len() - 1) as u32);
            side.cols.push(tr.col.to_string());
            side.vals.push(tr.val.parse().unwrap_or(0.0));
        }
        side
    };
    let (sa, sb) = (ingest(a), ingest(b));
    if sa.rows.is_empty() && sb.rows.is_empty() {
        return 0;
    }
    let mut merged: Vec<String> = sa.rows.iter().chain(&sb.rows).cloned().collect();
    merged.sort_unstable();
    merged.dedup();
    let to_csr = |side: &Side| {
        let mut distinct: Vec<String> = side.cols.clone();
        distinct.sort_unstable();
        distinct.dedup();
        let rows: Vec<usize> = side
            .row_of
            .iter()
            .map(|&own| merged.binary_search(&side.rows[own as usize]).expect("row merged"))
            .collect();
        let cols: Vec<usize> = side
            .cols
            .iter()
            .map(|c| distinct.binary_search(c).expect("col distinct"))
            .collect();
        let m = CooMatrix::from_triples_aggregate(
            merged.len(),
            distinct.len(),
            &rows,
            &cols,
            &side.vals,
            0.0,
            |x, _| x,
        )
        .expect("scan triples unique per cell")
        .into_csr();
        (m, distinct)
    };
    let (ma, cols_a) = to_csr(&sa);
    let (mb, cols_b) = to_csr(&sb);
    let at = ma.transpose();
    let c = spgemm_par(&at, &mb, s, Parallelism::serial()).expect("shared row dimension");
    let mut w = BatchWriter::new(Arc::clone(out), WriterConfig::default());
    let mut cells = 0usize;
    for (i, c1) in cols_a.iter().enumerate() {
        let (cj, cv) = c.row(i);
        for (j, v) in cj.iter().zip(cv) {
            if *v != s.zero() {
                w.put(Triple::new(c1.as_str(), cols_b[*j as usize].as_str(), format_num(*v)));
                cells += 1;
            }
        }
    }
    w.flush().expect("bench flush");
    cells
}

/// The pre-PR 5 BFS, verbatim: one streaming scanner, one absolute
/// seek + row read per frontier node per hop, small per-probe batch
/// hint. **Frozen snapshot** — the baseline the one-scan-per-hop BFS
/// is measured against; its hop-0 behavior (seeds pushed unprobed)
/// only matches `graphulo::bfs` when every seed has an adjacency row,
/// which the benchmark workload guarantees.
fn bfs_per_seek(adj: &Table, seeds: &[String], hops: usize) -> Vec<BTreeSet<String>> {
    const BFS_BATCH: usize = 16;
    let mut frontiers: Vec<BTreeSet<String>> = Vec::with_capacity(hops + 1);
    let mut visited: BTreeSet<String> = seeds.iter().cloned().collect();
    frontiers.push(visited.clone());
    let mut frontier: BTreeSet<String> = visited.clone();
    let mut stream = adj.scan_stream(ScanSpec::all().batched(BFS_BATCH));
    for _ in 0..hops {
        let mut next = BTreeSet::new();
        for node in &frontier {
            stream.seek(node, "");
            while let Some(t) = stream.next_triple() {
                if t.row != *node {
                    break;
                }
                if !visited.contains(t.col.as_str()) {
                    next.insert(t.col.to_string());
                }
            }
        }
        visited.extend(next.iter().cloned());
        frontiers.push(next.clone());
        if next.is_empty() {
            break;
        }
        frontier = next;
    }
    frontiers
}

/// Block-granular run I/O through the shared LRU cache (PR 9). Builds a
/// settled multi-run table with small data blocks, then measures:
///
/// * `block-resident-scan` — the fully resident baseline (speedup 1.0).
/// * `block-cold-scan` — the same scan with the cache capped at
///   `--block-cap-pct`% of the run bytes (default 25%): the beyond-RAM
///   regime. Bit-identity to the resident scan is asserted, and
///   [`CacheStats::peak_live_bytes`] is asserted to stay within
///   `capacity + one block per run cursor` — the bounded-memory claim.
/// * `block-warm-scan` — a second scan through an unbounded cache:
///   once blocks are resident the paged path must track the resident
///   one (the 0.91× floor is enforced by `scripts/summarize_results.py`).
/// * `block-compact` — `major_compact` streamed block-by-block under
///   the capped cache vs the resident compactor, with the same
///   peak-memory bound asserted and post-compaction bit-identity.
///
/// Standalone via `--block-only 1` (the CI low-memory smoke leg).
fn bench_blocks(args: &Args, repeats: usize) -> Vec<BenchRecord> {
    let scale = args.usize_or("block-scale", 14);
    let cap_pct = args.usize_or("block-cap-pct", 25).max(1);
    let block_triples = 256usize;
    let block_bytes = block_triples * 12;
    let bn = 1usize << scale;
    let row = |i: usize| format!("r{:06}", i / 24);
    let col = |i: usize| format!("c{:02}", i % 24);
    let popts = || DurableOptions { block_triples, ..DurableOptions::default() };

    let base = std::env::temp_dir().join(format!("d4m-ablations-blocks-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    std::fs::create_dir_all(&base).expect("bench dir");
    let dir = base.join("main");
    {
        let t = Table::durable_with(
            "blockbench",
            TableConfig::default(),
            &dir,
            FsyncPolicy::Never,
            popts(),
        )
        .expect("durable table");
        for wave in 0..4usize {
            let batch: Vec<Triple> = (wave * (bn / 4)..(wave + 1) * (bn / 4))
                .map(|i| Triple::new(row(i), col(i), format!("{i}")))
                .collect();
            for chunk in batch.chunks(512) {
                t.write_batch(chunk.to_vec()).expect("block ingest");
            }
            t.minor_compact().expect("block minor compact");
        }
        t.sync().expect("block sync");
    }
    // Settle: the replayed WAL suffix is frozen (with the same small
    // blocks) and the log truncated, so every leg below recovers the
    // identical on-disk image without writing new runs.
    drop(
        Table::recover_with("blockbench", TableConfig::default(), &dir, FsyncPolicy::Never, popts())
            .expect("settle recover"),
    );
    let copy_into = |dst: &std::path::Path| {
        std::fs::create_dir_all(dst).expect("copy dir");
        for entry in std::fs::read_dir(&dir).expect("read dir") {
            let entry = entry.expect("dir entry");
            if entry.file_type().expect("file type").is_file() {
                std::fs::copy(entry.path(), dst.join(entry.file_name())).expect("copy file");
            }
        }
    };
    let dir_rc = base.join("compact-resident");
    let dir_pc = base.join("compact-paged");
    copy_into(&dir_rc);
    copy_into(&dir_pc);
    let run_sizes: Vec<u64> = std::fs::read_dir(&dir)
        .expect("read dir")
        .filter_map(|e| e.ok())
        .filter(|e| e.path().extension().is_some_and(|x| x == "run"))
        .filter_map(|e| e.metadata().ok().map(|m| m.len()))
        .collect();
    let run_files = run_sizes.len();
    let run_bytes: u64 = run_sizes.iter().sum();
    assert!(run_files >= 2, "block bench needs a multi-run table, got {run_files}");
    let capacity = (run_bytes as usize) * cap_pct / 100;
    // Memory bound for capped legs: the cache budget plus one block per
    // run cursor (every run can have one pinned block per pass), plus a
    // little slack for blocks in flight between load and first pin.
    let peak_bound = (capacity + (run_files + 4) * block_bytes) as u64;

    // Resident baseline.
    let (expect, t_res) = {
        let t =
            Table::recover_with("blockbench", TableConfig::default(), &dir, FsyncPolicy::Never, popts())
                .expect("resident recover");
        let expect = t.scan_par(ScanRange::all(), Parallelism::serial());
        let t_res =
            time_op(1, repeats, |_| t.scan_par(ScanRange::all(), Parallelism::serial()).len());
        (expect, t_res)
    };
    assert_eq!(expect.len(), bn, "block bench table lost cells");

    // Cold beyond-RAM scans: capped cache, sequential full scans churn
    // the whole budget every pass.
    let cold_cache = BlockCache::new(capacity);
    let (t_cold, cold_stats) = {
        let t = Table::recover_with(
            "blockbench",
            TableConfig::default(),
            &dir,
            FsyncPolicy::Never,
            DurableOptions { cache: Some(Arc::clone(&cold_cache)), ..popts() },
        )
        .expect("capped recover");
        assert_eq!(
            expect,
            t.scan_par(ScanRange::all(), Parallelism::serial()),
            "capped paged scan must be bit-identical to the resident scan"
        );
        cold_cache.reset_peak();
        let t_cold =
            time_op(0, repeats, |_| t.scan_par(ScanRange::all(), Parallelism::serial()).len());
        (t_cold, cold_cache.stats())
    };
    assert!(cold_stats.misses > 0, "capped scans must fault blocks");
    assert!(
        cold_stats.peak_live_bytes <= peak_bound,
        "cold scan peak {} bytes exceeds capacity + per-cursor bound {peak_bound}",
        cold_stats.peak_live_bytes,
    );
    let cold_speedup =
        if t_cold.mean_s() > 0.0 { t_res.mean_s() / t_cold.mean_s() } else { 0.0 };

    // Warm cache: unbounded budget, first scan faults everything in,
    // the timed scans are pure cache hits.
    let warm_cache = BlockCache::new(usize::MAX);
    let (t_warm, warm_stats) = {
        let t = Table::recover_with(
            "blockbench",
            TableConfig::default(),
            &dir,
            FsyncPolicy::Never,
            DurableOptions { cache: Some(Arc::clone(&warm_cache)), ..popts() },
        )
        .expect("warm recover");
        assert_eq!(
            expect,
            t.scan_par(ScanRange::all(), Parallelism::serial()),
            "warm paged scan must be bit-identical to the resident scan"
        );
        let t_warm =
            time_op(1, repeats, |_| t.scan_par(ScanRange::all(), Parallelism::serial()).len());
        (t_warm, warm_cache.stats())
    };
    assert!(warm_stats.hits > 0, "warm scans must hit the cache");
    let warm_speedup =
        if t_warm.mean_s() > 0.0 { t_res.mean_s() / t_warm.mean_s() } else { 0.0 };
    // Soft in-binary sanity; the real 0.91x acceptance floor lives in
    // scripts/summarize_results.py where it gates CI.
    assert!(
        warm_speedup >= 0.5,
        "warm-cache scan at {warm_speedup:.2}x of resident is implausibly slow"
    );

    // Bounded-memory streaming compaction vs the resident compactor,
    // each on its own copy of the settled image.
    let t_comp_res = {
        let t = Table::recover_with(
            "blockbench",
            TableConfig::default(),
            &dir_rc,
            FsyncPolicy::Never,
            popts(),
        )
        .expect("compact-resident recover");
        time_op(0, 1, |_| t.major_compact(&CompactionSpec::default()).expect("resident compact"))
    };
    let comp_cache = BlockCache::new(capacity);
    let (t_comp, comp_stats) = {
        let t = Table::recover_with(
            "blockbench",
            TableConfig::default(),
            &dir_pc,
            FsyncPolicy::Never,
            DurableOptions { cache: Some(Arc::clone(&comp_cache)), ..popts() },
        )
        .expect("compact-paged recover");
        comp_cache.reset_peak();
        let t_comp =
            time_op(0, 1, |_| t.major_compact(&CompactionSpec::default()).expect("streamed compact"));
        let stats = comp_cache.stats();
        assert_eq!(
            expect,
            t.scan_par(ScanRange::all(), Parallelism::serial()),
            "post-compaction scan must be bit-identical"
        );
        (t_comp, stats)
    };
    assert!(
        comp_stats.peak_live_bytes <= peak_bound,
        "streamed compaction peak {} bytes exceeds capacity + per-cursor bound {peak_bound}",
        comp_stats.peak_live_bytes,
    );
    let comp_speedup =
        if t_comp.mean_s() > 0.0 { t_comp_res.mean_s() / t_comp.mean_s() } else { 0.0 };

    println!(
        "[ablations] block cache 2^{scale} cells ({run_files} runs, {run_bytes} run bytes, \
         cap {capacity} = {cap_pct}%): resident={:.6}s cold={:.6}s ({cold_speedup:.2}x, \
         {} misses, {} evictions, peak {} <= {peak_bound}) warm={:.6}s ({warm_speedup:.2}x); \
         major compact resident={:.6}s streamed={:.6}s ({comp_speedup:.2}x, peak {})",
        t_res.mean_s(),
        t_cold.mean_s(),
        cold_stats.misses,
        cold_stats.evictions,
        cold_stats.peak_live_bytes,
        t_warm.mean_s(),
        t_comp_res.mean_s(),
        t_comp.mean_s(),
        comp_stats.peak_live_bytes,
    );
    let _ = std::fs::remove_dir_all(&base);

    vec![
        BenchRecord::new("block-resident-scan", scale, 1, t_res.mean_s() * 1e9, 1.0)
            .with_extra("cells", expect.len() as f64)
            .with_extra("run_bytes", run_bytes as f64)
            .with_extra("runs", run_files as f64),
        BenchRecord::new("block-cold-scan", scale, 1, t_cold.mean_s() * 1e9, cold_speedup)
            .with_extra("cells", expect.len() as f64)
            .with_extra("capacity_bytes", capacity as f64)
            .with_extra("cache_misses", cold_stats.misses as f64)
            .with_extra("cache_evictions", cold_stats.evictions as f64)
            .with_extra("peak_live_bytes", cold_stats.peak_live_bytes as f64),
        BenchRecord::new("block-warm-scan", scale, 1, t_warm.mean_s() * 1e9, warm_speedup)
            .with_extra("cells", expect.len() as f64)
            .with_extra("capacity_bytes", usize::MAX as f64)
            .with_extra("cache_hits", warm_stats.hits as f64)
            .with_extra("cache_misses", warm_stats.misses as f64),
        BenchRecord::new("block-compact", scale, 1, t_comp.mean_s() * 1e9, comp_speedup)
            .with_extra("capacity_bytes", capacity as f64)
            .with_extra("peak_live_bytes", comp_stats.peak_live_bytes as f64)
            .with_extra("runs", run_files as f64),
    ]
}

/// Cost-based query planner (PR 10). Three shapes:
///
/// * **parity** (masked TableMult and BFS on the shapes the frozen
///   heuristics were tuned for) — the planner must stay within 1.05×
///   of [`Choices::frozen`] (speedup ≥ 0.95, asserted) and its output
///   must be bit-identical;
/// * **adversarial ingest** — an operand sized into the gap where the
///   frozen `8·rows ≤ len` rule refuses to restrict but the cost rule,
///   which can *estimate* the range-set cells, restricts and must win
///   **≥ 1.2×** (asserted);
/// * **symbolic-exact bound** — a column-skewed SpGEMM where the loose
///   `min(flops, ncols)` bound overallocates; `Auto` must upgrade to
///   the exact two-pass bound (allocation witness asserted,
///   bit-identical output).
///
/// Returns the `BENCH_PR10.json` records.
fn bench_planner(args: &Args, repeats: usize) -> Vec<BenchRecord> {
    let pscale = args.usize_or("plan-scale", 12);
    let pn = 1usize << pscale;
    let par = Parallelism::current();
    let threads = par.threads;
    let store = TableStore::new(TableConfig::default());
    let mut records = Vec::new();

    // --- parity: masked TableMult, planner vs frozen plan ------------
    // The PR 5 hit-table shape (most rows survive the mask, so the
    // frozen full-scan ingest was already right); the planner must
    // reach the same physical plan family and the same bits.
    {
        let mut rng = SplitMix64::new(0x91A_77E5);
        let rows: Vec<String> =
            (0..pn).map(|i| format!("r{:05}", i % (pn / 16).max(1))).collect();
        let cols: Vec<String> = (0..pn).map(|_| format!("c{:03}", rng.below(1000))).collect();
        store.ingest_assoc("phits", &Assoc::from_triples(&rows, &cols, 1.0));
    }
    let phits = store.table("phits").expect("ingested above");
    let keep = KeyMatch::Prefix("c0".into());
    let out_frozen = store.create_table("pm_frozen");
    let mut pm_cells = 0usize;
    let t_pm_frozen = time_op(1, repeats, |_| {
        pm_cells = graphulo::table_mult_masked_planned(
            &phits,
            &phits,
            &out_frozen,
            &PlusTimes,
            &keep,
            par,
            &Choices::frozen(),
        );
        pm_cells
    });
    let out_plan = store.create_table("pm_plan");
    let t_pm_plan = time_op(1, repeats, |_| {
        graphulo::table_mult_masked_planned(
            &phits,
            &phits,
            &out_plan,
            &PlusTimes,
            &keep,
            par,
            &Choices::planner(),
        )
    });
    assert_eq!(
        out_plan.scan(ScanRange::all()),
        out_frozen.scan(ScanRange::all()),
        "planner-chosen masked mult must be bit-identical to the frozen plan"
    );
    let pm_speedup = if t_pm_plan.mean_s() > 0.0 {
        t_pm_frozen.mean_s() / t_pm_plan.mean_s()
    } else {
        0.0
    };
    println!(
        "[ablations] planner masked mult 2^{pscale}: frozen={:.6}s planner={:.6}s \
         parity={pm_speedup:.2}x ({pm_cells} cells)",
        t_pm_frozen.mean_s(),
        t_pm_plan.mean_s(),
    );
    assert!(
        pm_speedup >= 0.95,
        "planner masked mult at {pm_speedup:.2}x of frozen is outside the 1.05x parity band"
    );
    records.push(
        BenchRecord::new("tablemult-frozen-plan", pscale, threads, t_pm_frozen.mean_s() * 1e9, 1.0)
            .with_extra("out_cells", pm_cells as f64),
    );
    records.push(
        BenchRecord::new("plan-masked-mult", pscale, threads, t_pm_plan.mean_s() * 1e9, pm_speedup)
            .with_extra("out_cells", pm_cells as f64),
    );

    // --- parity: BFS, planner row-set lowering vs frozen range sets --
    let bfs_graph = store.create_table("pgraph");
    {
        let mut rng = SplitMix64::new(0xB0F5_11AB);
        let mut w = BatchWriter::new(Arc::clone(&bfs_graph), WriterConfig::default());
        for i in 0..pn {
            for _ in 0..4 {
                w.put(Triple::new(
                    format!("n{i:06}"),
                    format!("n{:06}", rng.below_usize(pn)),
                    "1",
                ));
            }
        }
        w.flush().expect("bench flush");
    }
    let frontier_n = 1000usize.min(pn);
    let seeds: Vec<String> =
        (0..frontier_n).map(|i| format!("n{:06}", i * (pn / frontier_n))).collect();
    let mut frozen_frontiers = Vec::new();
    let t_bfs_frozen = time_op(1, repeats, |_| {
        frozen_frontiers = graphulo::bfs_planned(&bfs_graph, &seeds, 2, par, &Choices::frozen());
        frozen_frontiers.len()
    });
    let mut plan_frontiers = Vec::new();
    let t_bfs_plan = time_op(1, repeats, |_| {
        plan_frontiers = graphulo::bfs_planned(&bfs_graph, &seeds, 2, par, &Choices::planner());
        plan_frontiers.len()
    });
    assert_eq!(
        frozen_frontiers, plan_frontiers,
        "planner BFS must reach exactly the frozen-plan frontiers"
    );
    let reached: usize = plan_frontiers.iter().map(BTreeSet::len).sum();
    let bfs_parity = if t_bfs_plan.mean_s() > 0.0 {
        t_bfs_frozen.mean_s() / t_bfs_plan.mean_s()
    } else {
        0.0
    };
    println!(
        "[ablations] planner bfs 2^{pscale} nodes, {frontier_n}-seed frontier: frozen={:.6}s \
         planner={:.6}s parity={bfs_parity:.2}x ({reached} reached)",
        t_bfs_frozen.mean_s(),
        t_bfs_plan.mean_s(),
    );
    assert!(
        bfs_parity >= 0.95,
        "planner BFS at {bfs_parity:.2}x of frozen is outside the 1.05x parity band"
    );
    records.push(
        BenchRecord::new("bfs-frozen-plan", pscale, threads, t_bfs_frozen.mean_s() * 1e9, 1.0)
            .with_extra("frontier_nodes", frontier_n as f64)
            .with_extra("reached_nodes", reached as f64),
    );
    records.push(
        BenchRecord::new("plan-bfs", pscale, threads, t_bfs_plan.mean_s() * 1e9, bfs_parity)
            .with_extra("frontier_nodes", frontier_n as f64)
            .with_extra("reached_nodes", reached as f64),
    );

    // --- adversarial ingest: thin survivors + a fat off-mask band ----
    // A holds one cell per survivor row plus 6·S cells in fat rows the
    // mask never selects — 7·S cells total, sized into the gap where
    // the frozen heuristic refuses to restrict (8·S > 7·S ⇒ full scan,
    // copying every fat cell) but the cost rule estimates S cells +
    // 4·S seek-equivalents < 7·S and restricts.
    let surv = (pn / 2).max(64);
    let fat_rows = ((6 * surv) / 512).max(1);
    let adv_a = store.create_table("adv_a");
    let adv_b = store.create_table("adv_b");
    {
        let mut w = BatchWriter::new(Arc::clone(&adv_a), WriterConfig::default());
        for i in 0..surv {
            w.put(Triple::new(format!("s{i:06}"), "x", "1"));
        }
        for i in 0..fat_rows {
            for j in 0..512 {
                w.put(Triple::new(format!("zfat{i:04}"), format!("f{j:03}"), "1"));
            }
        }
        w.flush().expect("bench flush");
        let mut w = BatchWriter::new(Arc::clone(&adv_b), WriterConfig::default());
        for i in 0..surv {
            w.put(Triple::new(format!("s{i:06}"), "y", "1"));
        }
        w.flush().expect("bench flush");
    }
    let adv_keep = KeyMatch::Equals("y".into());
    let adv_frozen_out = store.create_table("adv_frozen");
    let mut adv_cells = 0usize;
    let t_adv_frozen = time_op(1, repeats, |_| {
        adv_cells = graphulo::table_mult_masked_planned(
            &adv_a,
            &adv_b,
            &adv_frozen_out,
            &PlusTimes,
            &adv_keep,
            par,
            &Choices::frozen(),
        );
        adv_cells
    });
    let adv_plan_out = store.create_table("adv_plan");
    let t_adv_plan = time_op(1, repeats, |_| {
        graphulo::table_mult_masked_planned(
            &adv_a,
            &adv_b,
            &adv_plan_out,
            &PlusTimes,
            &adv_keep,
            par,
            &Choices::planner(),
        )
    });
    assert_eq!(
        adv_plan_out.scan(ScanRange::all()),
        adv_frozen_out.scan(ScanRange::all()),
        "planner adversarial mult must be bit-identical to the frozen plan"
    );
    let adv_speedup = if t_adv_plan.mean_s() > 0.0 {
        t_adv_frozen.mean_s() / t_adv_plan.mean_s()
    } else {
        0.0
    };
    println!(
        "[ablations] planner adversarial ingest ({surv} survivors, {} operand cells): \
         frozen={:.6}s planner={:.6}s speedup={adv_speedup:.2}x",
        adv_a.len(),
        t_adv_frozen.mean_s(),
        t_adv_plan.mean_s(),
    );
    assert!(
        adv_speedup >= 1.2,
        "planner adversarial-ingest speedup {adv_speedup:.2}x below the 1.2x acceptance threshold"
    );
    records.push(
        BenchRecord::new(
            "adversarial-frozen-plan",
            pscale,
            threads,
            t_adv_frozen.mean_s() * 1e9,
            1.0,
        )
        .with_extra("operand_cells", adv_a.len() as f64)
        .with_extra("survivor_rows", surv as f64),
    );
    records.push(
        BenchRecord::new(
            "plan-adversarial-ingest",
            pscale,
            threads,
            t_adv_plan.mean_s() * 1e9,
            adv_speedup,
        )
        .with_extra("operand_cells", adv_a.len() as f64)
        .with_extra("survivor_rows", surv as f64)
        .with_extra("out_cells", adv_cells as f64),
    );

    // --- symbolic-exact output bound on column skew ------------------
    // Every B row lands its 64 nnz inside a 128-column hot set, so the
    // loose per-row bound min(flops, ncols) ≈ 1024 while the true
    // distinct-column count is ≤ 128. `Auto` must detect the skew
    // (Σ bound > 2× input nnz), upgrade to the exact two-pass bound,
    // and allocate a fraction of the loose arrays — same bits.
    let em = (pn / 16).max(64);
    let hot = 128usize;
    let mut rng = SplitMix64::new(0xE8AC_7B0D);
    let (mut ar, mut ac) = (Vec::new(), Vec::new());
    for i in 0..em {
        for _ in 0..32 {
            ar.push(i);
            ac.push(rng.below_usize(em));
        }
    }
    let a_ones = vec![1.0; ar.len()];
    let skew_a = CooMatrix::from_triples_aggregate(em, em, &ar, &ac, &a_ones, 0.0, |x, _| x)
        .expect("skew A")
        .to_csr();
    let (mut br, mut bc) = (Vec::new(), Vec::new());
    for i in 0..em {
        for _ in 0..64 {
            br.push(i);
            bc.push(rng.below_usize(hot));
        }
    }
    let b_ones = vec![1.0; br.len()];
    let skew_b = CooMatrix::from_triples_aggregate(em, 1024, &br, &bc, &b_ones, 0.0, |x, _| x)
        .expect("skew B")
        .to_csr();
    let run_bound = |bound: SymbolicBound| {
        spgemm_with_modes_par(
            &skew_a,
            &skew_b,
            &PlusTimes,
            par,
            AccumulatorPolicy::default(),
            bound,
        )
        .expect("shared dimension")
    };
    let (c_loose, st_loose) = run_bound(SymbolicBound::MinFlopsCols);
    let (c_auto, st_auto) = run_bound(SymbolicBound::Auto);
    let fp = |c: &CsrMatrix| {
        let bits: Vec<u64> = c.values().iter().map(|v| v.to_bits()).collect();
        (c.indptr().to_vec(), c.indices().to_vec(), bits)
    };
    assert_eq!(fp(&c_loose), fp(&c_auto), "exact bound must not change the output bits");
    assert!(
        st_auto.alloc_bound < st_loose.alloc_bound,
        "auto bound {} must allocate under the loose bound {} on column skew",
        st_auto.alloc_bound,
        st_loose.alloc_bound,
    );
    let t_loose = time_op(1, repeats, |_| run_bound(SymbolicBound::MinFlopsCols).1.out_nnz);
    let t_auto = time_op(1, repeats, |_| run_bound(SymbolicBound::Auto).1.out_nnz);
    let bound_speedup =
        if t_auto.mean_s() > 0.0 { t_loose.mean_s() / t_auto.mean_s() } else { 0.0 };
    println!(
        "[ablations] symbolic bound on skew ({em} rows): loose={:.6}s auto/exact={:.6}s \
         ({bound_speedup:.2}x, alloc bound {} -> {})",
        t_loose.mean_s(),
        t_auto.mean_s(),
        st_loose.alloc_bound,
        st_auto.alloc_bound,
    );
    records.push(
        BenchRecord::new("spgemm-loose-bound", pscale, threads, t_loose.mean_s() * 1e9, 1.0)
            .with_extra("alloc_bound", st_loose.alloc_bound as f64)
            .with_extra("out_nnz", st_loose.out_nnz as f64),
    );
    records.push(
        BenchRecord::new("plan-exact-bound", pscale, threads, t_auto.mean_s() * 1e9, bound_speedup)
            .with_extra("alloc_bound", st_auto.alloc_bound as f64)
            .with_extra("out_nnz", st_auto.out_nnz as f64),
    );
    records
}

fn main() {
    let args = Args::from_env();
    let n = args.usize_or("n", 12);
    let repeats = args.usize_or("repeats", 5);
    let out_dir = args.str_or("out", "results");
    // Non-sweep sections measure the serial baselines unless --threads
    // overrides; the thread-scaling section below passes Parallelism
    // explicitly and is unaffected.
    Parallelism::with_threads(args.usize_or("threads", 1)).set_default();

    // Low-memory CI leg: only the PR 9 block-cache section, so the
    // process's own footprint stays a fair proxy for the bounded-memory
    // claim.
    if args.flag("block-only") {
        let records9 = bench_blocks(&args, repeats);
        d4m::bench::write_bench_json(&out_dir, "BENCH_PR9.json", &records9).expect("write JSON");
        return;
    }
    let w = Workload::generate(n, 77);
    let ones = w.ones();
    let a = Assoc::from_triples(&w.rows, &w.cols, ValsInput::Num(ones.clone()));
    let b = Assoc::from_triples(&w.rows2, &w.cols2, ValsInput::Num(ones.clone()));

    let mut h = FigureHarness::new("ablations", "design-choice ablations (DESIGN.md §5)");

    // --- condense: indptr mask vs triple rebuild ------------------------
    // Build an un-condensed product (matmul output before cleanup).
    let k = {
        use d4m::sorted::sorted_intersect;
        sorted_intersect(a.col_keys(), b.row_keys())
    };
    let all_rows: Vec<usize> = (0..a.row_keys().len()).collect();
    let all_cols: Vec<usize> = (0..b.col_keys().len()).collect();
    let ga = a.adj().gather(&all_rows, &k.map_left);
    let gb = b.adj().gather(&k.map_right, &all_cols);
    let c_pre = spgemm(&ga, &gb, &PlusTimes).unwrap();

    let t = time_op(1, repeats, |_| {
        // Paper's strategy: boolean masks from indptr, then select.
        let rm = c_pre.nonempty_rows();
        let cm = c_pre.nonempty_cols();
        c_pre.select(&rm, &cm)
    });
    h.record(n, "condense-mask", t, c_pre.nnz());

    let t = time_op(1, repeats, |_| {
        // Naive strategy: extract triples, re-sort, rebuild.
        let coo = c_pre.to_coo();
        let rows: Vec<usize> = coo.row_indices().iter().map(|&r| r as usize).collect();
        let cols: Vec<usize> = coo.col_indices().iter().map(|&c| c as usize).collect();
        let (m, nn) = c_pre.shape();
        CooMatrix::from_triples_aggregate(m, nn, &rows, &cols, coo.values(), 0.0, |x, _| x)
            .unwrap()
            .to_csr()
    });
    h.record(n, "condense-rebuild", t, c_pre.nnz());

    // --- constructor sort: packed u64 rank pairs vs string tuples -------
    let t = time_op(1, repeats, |_| {
        Assoc::from_triples(&w.rows, &w.cols, ValsInput::Num(w.num_vals.clone()))
    });
    h.record(n, "ctor-packed-u64", t, a.nnz());

    let t = time_op(1, repeats, |_| {
        // Strategy D4M.py can't use (no rank packing): sort owned
        // (row, col, val) string tuples directly.
        let mut triples: Vec<(String, String, f64)> = (0..w.rows.len())
            .map(|i| (w.rows[i].clone(), w.cols[i].clone(), w.num_vals[i]))
            .collect();
        triples.sort_unstable_by(|x, y| (&x.0, &x.1).cmp(&(&y.0, &y.1)));
        triples.dedup_by(|x, y| {
            if x.0 == y.0 && x.1 == y.1 {
                y.2 = y.2.min(x.2);
                true
            } else {
                false
            }
        });
        triples
    });
    h.record(n, "ctor-string-sort", t, a.nnz());

    // --- adj residency: CSR-resident (ours) vs COO + per-op convert -----
    let t = time_op(1, repeats, |_| a.matmul(&b));
    h.record(n, "matmul-csr-resident", t, 0);

    let coo_a = a.adj().to_coo();
    let coo_b = b.adj().to_coo();
    let t = time_op(1, repeats, |_| {
        // D4M.py's layout: COO at rest, convert inside the op.
        let ca = coo_a.to_csr();
        let cb = coo_b.to_csr();
        let ga = ca.gather(&all_rows, &k.map_left);
        let gb = cb.gather(&k.map_right, &all_cols);
        let c = spgemm(&ga, &gb, &PlusTimes).unwrap();
        c.to_coo() // and back to the resident format
    });
    h.record(n, "matmul-coo-convert", t, 0);

    // --- thread scaling: fig6 matmul + fig3 constructor -----------------
    // `threads = 1` runs the exact serial code path; other counts are
    // bit-identical (enforced by tests/parallel_equivalence.rs), so any
    // delta here is pure scheduling cost / speedup.
    let tn = args.usize_or("threads-n", 10);
    let wt = Workload::generate(tn, 77);
    let tones = vec![1.0; wt.rows.len()];
    let ta = Assoc::from_triples(&wt.rows, &wt.cols, ValsInput::Num(tones.clone()));
    let tb = Assoc::from_triples(&wt.rows2, &wt.cols2, ValsInput::Num(tones));
    let sweep = [1usize, 2, 4, 8];
    let mut matmul_means = Vec::with_capacity(sweep.len());
    let mut ctor_means = Vec::with_capacity(sweep.len());
    for &threads in &sweep {
        let par = Parallelism::with_threads(threads);
        let mut nnz = 0usize;
        let t = time_op(1, repeats, |_| {
            let c = ta.matmul_par(&tb, par);
            nnz = c.nnz();
            c
        });
        matmul_means.push(t.mean_s());
        h.record(tn, &format!("matmul-t{threads}"), t, nnz);

        let mut cnnz = 0usize;
        let t = time_op(1, repeats, |_| {
            let c = Assoc::try_new_par(
                keys_from(&wt.rows),
                keys_from(&wt.cols),
                ValsInput::Num(wt.num_vals.clone()),
                Aggregator::Min,
                par,
            )
            .unwrap();
            cnnz = c.nnz();
            c
        });
        ctor_means.push(t.mean_s());
        h.record(tn, &format!("ctor-t{threads}"), t, cnnz);
    }
    // Serial-vs-parallel speedup line (parsed by the BENCH capture).
    let speedup = |means: &[f64], i: usize| {
        if means[i] > 0.0 {
            means[0] / means[i]
        } else {
            0.0
        }
    };
    println!(
        "[ablations] threads-sweep n={tn} matmul serial={:.6}s t2={:.2}x t4={:.2}x t8={:.2}x \
         | ctor serial={:.6}s t2={:.2}x t4={:.2}x t8={:.2}x",
        matmul_means[0],
        speedup(&matmul_means, 1),
        speedup(&matmul_means, 2),
        speedup(&matmul_means, 3),
        ctor_means[0],
        speedup(&ctor_means, 1),
        speedup(&ctor_means, 2),
        speedup(&ctor_means, 3),
    );
    let mut records: Vec<BenchRecord> = Vec::new();
    for (i, &threads) in sweep.iter().enumerate() {
        records.push(BenchRecord::new(
            "matmul",
            tn,
            threads,
            matmul_means[i] * 1e9,
            speedup(&matmul_means, i),
        ));
        records.push(BenchRecord::new(
            "constructor",
            tn,
            threads,
            ctor_means[i] * 1e9,
            speedup(&ctor_means, i),
        ));
    }

    // --- accumulator policy: adaptive engine vs PR-1 dense scratch ------
    // Hypersparse workload (1 nnz per row, the associative-array regime):
    // the dense kernel pays an O(ncols) scratch row and scattered
    // accumulator traffic per chunk; the adaptive engine's copy/sort/hash
    // rows never touch O(ncols) state. Outputs are bit-identical (also
    // enforced by tests/parallel_equivalence.rs), so the delta is pure
    // accumulator cost.
    let hscale = args.usize_or("hyper-scale", 14);
    let hn = 1usize << hscale;
    let mut rng = SplitMix64::new(0xAB1A7E5);
    let hrows: Vec<usize> = (0..hn).collect();
    let hcols: Vec<usize> = (0..hn).map(|_| rng.below_usize(hn)).collect();
    let hvals: Vec<f64> = (0..hn).map(|i| (i % 9 + 1) as f64).collect();
    let ha = CooMatrix::from_triples_aggregate(hn, hn, &hrows, &hcols, &hvals, 0.0, |x, _| x)
        .expect("hypersparse triples")
        .to_csr();
    let mut records3: Vec<BenchRecord> = Vec::new();
    for &threads in &[1usize, 4] {
        let par = Parallelism::with_threads(threads);
        let policies = [
            ("hyper-dense", AccumulatorPolicy::Dense),
            ("hyper-adaptive", AccumulatorPolicy::Adaptive),
        ];
        let mut means = Vec::with_capacity(policies.len());
        let mut stats_of = Vec::with_capacity(policies.len());
        for &(label, policy) in &policies {
            let mut nnz = 0usize;
            let mut last_stats = None;
            let t = time_op(1, repeats, |_| {
                let (c, st) = spgemm_with_policy_par(&ha, &ha, &PlusTimes, par, policy)
                    .expect("square shapes");
                nnz = c.nnz();
                last_stats = Some(st);
                c
            });
            means.push(t.mean_s());
            stats_of.push(last_stats.expect("at least one repeat"));
            h.record(hscale, &format!("{label}-t{threads}"), t, nnz);
        }
        let hyper_speedup = if means[1] > 0.0 { means[0] / means[1] } else { 0.0 };
        println!(
            "[ablations] hypersparse 2^{hscale} t{threads}: dense={:.6}s adaptive={:.6}s \
             adaptive-speedup={hyper_speedup:.2}x",
            means[0], means[1],
        );
        records.push(BenchRecord::new(
            "hypersparse-matmul-dense",
            hscale,
            threads,
            means[0] * 1e9,
            1.0,
        ));
        records.push(BenchRecord::new(
            "hypersparse-matmul-adaptive",
            hscale,
            threads,
            means[1] * 1e9,
            hyper_speedup,
        ));
        // PR 3 trajectory: the same points, with the per-row
        // accumulator-policy counters threaded through as extras.
        for (i, op) in
            ["hypersparse-matmul-dense", "hypersparse-matmul-adaptive"].iter().enumerate()
        {
            let st = &stats_of[i];
            let sp = if i == 0 { 1.0 } else { hyper_speedup };
            records3.push(
                BenchRecord::new(op, hscale, threads, means[i] * 1e9, sp)
                    .with_extra("mults", st.mults as f64)
                    .with_extra("out_nnz", st.out_nnz as f64)
                    .with_extra("rows_copy", st.rows_copy as f64)
                    .with_extra("rows_sort", st.rows_sort as f64)
                    .with_extra("rows_hash", st.rows_hash as f64)
                    .with_extra("rows_dense", st.rows_dense as f64),
            );
        }
    }

    // --- masked TableMult: sink-filter pushdown vs unmasked-then-filter -
    // A bipartite hit table (2^mask-scale triples over 1000 columns);
    // the sink mask keeps the "c0*" prefix — 100 of 1000 columns, a 10%-
    // density mask. The masked multiply must be bit-identical to
    // full-multiply-then-filter and ≥ 1.5× faster (the PR acceptance
    // number, asserted below so the CI bench smoke enforces it).
    let mscale = args.usize_or("mask-scale", 12);
    let mn = 1usize << mscale;
    // These sections run at the process default installed above, so the
    // records carry the *actual* worker count, not a hardcoded 1.
    let bench_threads = Parallelism::current().threads;
    let store = TableStore::new(TableConfig::default());
    {
        let mut rng = SplitMix64::new(0x5EED_3A5C);
        let rows: Vec<String> = (0..mn).map(|i| format!("r{:04}", i % (mn / 16).max(1))).collect();
        let cols: Vec<String> = (0..mn).map(|_| format!("c{:03}", rng.below(1000))).collect();
        let hits = Assoc::from_triples(&rows, &cols, 1.0);
        store.ingest_assoc("hits", &hits);
    }
    let hits = store.table("hits").expect("ingested above");
    let keep = KeyMatch::Glob("c0*".into());
    let out_m = store.create_table("ata_masked");
    let mut masked_cells = 0usize;
    let t_masked = time_op(1, repeats, |_| {
        masked_cells = graphulo::table_mult_masked(&hits, &hits, &out_m, &PlusTimes, &keep);
        masked_cells
    });
    h.record(mscale, "tablemult-masked", t_masked.clone(), masked_cells);
    let out_f = store.create_table("ata_full");
    let mut full_cells = 0usize;
    let t_full = time_op(1, repeats, |_| {
        full_cells = graphulo::table_mult(&hits, &hits, &out_f, &PlusTimes);
        // The client-side alternative: stream the full product back
        // through a filtered scan to obtain the kept cells. (No second
        // table write — the baseline pays only what unmasked-then-filter
        // inherently costs: full compute, full sink write, one filtered
        // read.)
        let spec = ScanSpec::all().filtered(CellFilter::col(KeyMatch::Glob("c0*".into())));
        let mut kept = 0usize;
        for tr in out_f.scan_stream(spec) {
            kept += tr.val.len();
        }
        kept
    });
    h.record(mscale, "tablemult-unmasked-filter", t_full.clone(), full_cells);
    let masked: Vec<Triple> = out_m.scan(ScanRange::all());
    let filter_spec = ScanSpec::all().filtered(CellFilter::col(KeyMatch::Glob("c0*".into())));
    let filtered: Vec<Triple> = out_f.scan_stream(filter_spec).collect();
    assert_eq!(masked, filtered, "masked TableMult must be bit-identical to unmasked-then-filter");
    let mask_speedup = if t_masked.mean_s() > 0.0 {
        t_full.mean_s() / t_masked.mean_s()
    } else {
        0.0
    };
    println!(
        "[ablations] masked tablemult 2^{mscale}: unmasked+filter={:.6}s masked={:.6}s \
         speedup={mask_speedup:.2}x (kept {}/{} cells)",
        t_full.mean_s(),
        t_masked.mean_s(),
        masked.len(),
        full_cells,
    );
    assert!(
        mask_speedup >= 1.5,
        "masked TableMult speedup {mask_speedup:.2}x below the 1.5x acceptance threshold"
    );
    let (full_ns, masked_ns) = (t_full.mean_s() * 1e9, t_masked.mean_s() * 1e9);
    records3.push(
        BenchRecord::new("tablemult-unmasked-filter", mscale, bench_threads, full_ns, 1.0)
            .with_extra("out_cells", full_cells as f64),
    );
    records3.push(
        BenchRecord::new("tablemult-masked", mscale, bench_threads, masked_ns, mask_speedup)
            .with_extra("out_cells", masked.len() as f64),
    );

    // --- streaming vs materializing scan ---------------------------------
    // A column-windowed scan (~10% of columns in range) consumed off the
    // stack vs materializing the whole table and filtering client-side.
    // The stack's tablet cursor seeks past out-of-window cells, so the
    // streaming path never even constructs the dropped triples.
    let sscale = args.usize_or("stream-scale", 13);
    let sn = 1usize << sscale;
    {
        let mut rng = SplitMix64::new(0x5CAB_5CAB);
        let rows: Vec<String> = (0..sn).map(|i| format!("r{:05}", i % (sn / 8).max(1))).collect();
        let cols: Vec<String> = (0..sn).map(|_| format!("c{:03}", rng.below(1000))).collect();
        let logs = Assoc::from_triples(&rows, &cols, 1.0);
        store.ingest_assoc("logs", &logs);
    }
    let logs = store.table("logs").expect("ingested above");
    let window = ScanRange::all().with_cols("c000", "c100");
    let mut stream_cells = 0usize;
    let t_stream = time_op(1, repeats, |_| {
        let mut count = 0usize;
        let mut bytes = 0usize;
        for tr in logs.scan_stream(ScanSpec::over(window.clone())) {
            count += 1;
            bytes += tr.val.len();
        }
        stream_cells = count;
        bytes
    });
    h.record(sscale, "scan-streaming", t_stream.clone(), stream_cells);
    let mut mat_cells = 0usize;
    let t_mat = time_op(1, repeats, |_| {
        // Materialize everything, then filter client-side.
        let all = logs.scan(ScanRange::all());
        let mut count = 0usize;
        let mut bytes = 0usize;
        for tr in &all {
            if tr.col.as_str() >= "c000" && tr.col.as_str() < "c100" {
                count += 1;
                bytes += tr.val.len();
            }
        }
        mat_cells = count;
        bytes
    });
    h.record(sscale, "scan-materialize", t_mat.clone(), mat_cells);
    assert_eq!(stream_cells, mat_cells, "scan paths must agree on the window");
    let scan_speedup = if t_stream.mean_s() > 0.0 {
        t_mat.mean_s() / t_stream.mean_s()
    } else {
        0.0
    };
    println!(
        "[ablations] windowed scan 2^{sscale}: materialize+filter={:.6}s streaming={:.6}s \
         speedup={scan_speedup:.2}x ({stream_cells} cells kept)",
        t_mat.mean_s(),
        t_stream.mean_s(),
    );
    let (mat_ns, stream_ns) = (t_mat.mean_s() * 1e9, t_stream.mean_s() * 1e9);
    records3.push(
        BenchRecord::new("scan-materialize", sscale, bench_threads, mat_ns, 1.0)
            .with_extra("kept_cells", mat_cells as f64),
    );
    records3.push(
        BenchRecord::new("scan-streaming", sscale, bench_threads, stream_ns, scan_speedup)
            .with_extra("kept_cells", stream_cells as f64),
    );

    // --- dictionary-encoded key space: string vs dict pipelines ---------
    // One workload, two full pipelines, serial both sides:
    //   string: scan → Vec<Triple> → per-cell Key + digest sort (ctor);
    //           per-cell string binary-search ingest (TableMult).
    //   dict:   streamed scan → StrDict intern → id sort (ctor);
    //           dict-encoded ingest + shared-bytes cells (TableMult).
    // Outputs are bit-identical (asserted); the combined end-to-end
    // speedup is the PR 4 acceptance number (≥ 1.3×, asserted).
    // Workload shape: heavily duplicated keys (degree-4 rows over a
    // 30-key column space), so the product's write-back — identical in
    // both paths — stays small and the measured delta is the encoding
    // cost itself.
    let dscale = args.usize_or("dict-scale", 13);
    let dn = 1usize << dscale;
    let mut records4: Vec<BenchRecord> = Vec::new();
    {
        let mut rng = SplitMix64::new(0xD1C7_5EED);
        let rows: Vec<String> =
            (0..dn).map(|i| format!("r{:05}", i % (dn / 4).max(1))).collect();
        let cols: Vec<String> = (0..dn).map(|_| format!("c{:02}", rng.below(30))).collect();
        let edges = Assoc::from_triples(&rows, &cols, 1.0);
        store.ingest_assoc("dictbench", &edges);
    }
    let dtab = store.table("dictbench").expect("ingested above");
    let mut ctor_nnz = 0usize;
    let t_ctor_str = time_op(1, repeats, |_| {
        let a = scan_to_assoc_string_path(&dtab);
        ctor_nnz = a.nnz();
        a
    });
    h.record(dscale, "ctor-string", t_ctor_str.clone(), ctor_nnz);
    let t_ctor_dict = time_op(1, repeats, |_| {
        let a = dtab.scan_to_assoc_par(ScanRange::all(), Parallelism::serial());
        ctor_nnz = a.nnz();
        a
    });
    h.record(dscale, "ctor-dict", t_ctor_dict.clone(), ctor_nnz);
    assert_eq!(
        scan_to_assoc_string_path(&dtab),
        dtab.scan_to_assoc_par(ScanRange::all(), Parallelism::serial()),
        "dict-encoded scan→assoc must be bit-identical to the string path"
    );
    let out_ts = store.create_table("dict_tm_string");
    let mut tm_cells = 0usize;
    let t_tm_str = time_op(1, repeats, |_| {
        tm_cells = table_mult_string_path(&dtab, &dtab, &out_ts, &PlusTimes);
        tm_cells
    });
    h.record(dscale, "tablemult-string", t_tm_str.clone(), tm_cells);
    let out_td = store.create_table("dict_tm_dict");
    let t_tm_dict = time_op(1, repeats, |_| {
        tm_cells = graphulo::table_mult_par(
            &dtab,
            &dtab,
            &out_td,
            &PlusTimes,
            Parallelism::serial(),
        );
        tm_cells
    });
    h.record(dscale, "tablemult-dict", t_tm_dict.clone(), tm_cells);
    assert_eq!(
        out_ts.scan(ScanRange::all()),
        out_td.scan(ScanRange::all()),
        "dict-encoded TableMult must be bit-identical to the string path"
    );
    let e2e_str = t_ctor_str.mean_s() + t_tm_str.mean_s();
    let e2e_dict = t_ctor_dict.mean_s() + t_tm_dict.mean_s();
    let dict_speedup = if e2e_dict > 0.0 { e2e_str / e2e_dict } else { 0.0 };
    println!(
        "[ablations] dict encoding 2^{dscale}: ctor string={:.6}s dict={:.6}s | tablemult \
         string={:.6}s dict={:.6}s | e2e speedup={dict_speedup:.2}x",
        t_ctor_str.mean_s(),
        t_ctor_dict.mean_s(),
        t_tm_str.mean_s(),
        t_tm_dict.mean_s(),
    );
    assert!(
        dict_speedup >= 1.3,
        "dict-encoded ctor+TableMult speedup {dict_speedup:.2}x below the 1.3x acceptance \
         threshold"
    );
    records4.push(
        BenchRecord::new("ctor-string", dscale, 1, t_ctor_str.mean_s() * 1e9, 1.0)
            .with_extra("out_nnz", ctor_nnz as f64),
    );
    records4.push(
        BenchRecord::new(
            "ctor-dict",
            dscale,
            1,
            t_ctor_dict.mean_s() * 1e9,
            if t_ctor_dict.mean_s() > 0.0 {
                t_ctor_str.mean_s() / t_ctor_dict.mean_s()
            } else {
                0.0
            },
        )
        .with_extra("out_nnz", ctor_nnz as f64),
    );
    records4.push(
        BenchRecord::new("tablemult-string", dscale, 1, t_tm_str.mean_s() * 1e9, 1.0)
            .with_extra("out_cells", tm_cells as f64),
    );
    records4.push(
        BenchRecord::new(
            "tablemult-dict",
            dscale,
            1,
            t_tm_dict.mean_s() * 1e9,
            if t_tm_dict.mean_s() > 0.0 {
                t_tm_str.mean_s() / t_tm_dict.mean_s()
            } else {
                0.0
            },
        )
        .with_extra("out_cells", tm_cells as f64),
    );
    records4.push(BenchRecord::new("e2e-dict", dscale, 1, e2e_dict * 1e9, dict_speedup));

    // --- filter pushdown: zero allocation per rejected cell -------------
    // A streamed scan over the 1000-column `logs` table whose filter
    // keeps ~1% of cells: filters run beneath the tablet block copy
    // against the stored bytes, so the ~99% rejected cells must not
    // allocate at all. The counting allocator witnesses it: total
    // allocations during the scan stay far below the rejected-cell
    // count (the old path allocated ≥ 3 strings per scanned cell
    // before the client-side filter ran).
    let push_spec =
        ScanSpec::all().filtered(CellFilter::col(KeyMatch::Prefix("c04".into())));
    let total_cells = logs.len();
    let mut kept = 0usize;
    // Warm-up pass sizes the stream buffers outside the counted window.
    for _ in logs.scan_stream(push_spec.clone()) {
        kept += 1;
    }
    assert!(kept > 0, "pushdown workload must keep some cells");
    let before = alloc_count();
    let mut kept_counted = 0usize;
    for t in logs.scan_stream(push_spec.clone()) {
        kept_counted += t.val.len();
    }
    let scan_allocs = alloc_count() - before;
    let rejected = total_cells - kept;
    println!(
        "[ablations] filter pushdown 2^{sscale}: {kept}/{total_cells} cells kept, \
         {scan_allocs} allocations for {rejected} rejected cells ({kept_counted} bytes kept)"
    );
    assert!(
        (scan_allocs as usize) < rejected / 4,
        "filtered scan allocated {scan_allocs} times for {rejected} rejected cells — \
         pushdown must not allocate per rejected cell"
    );
    let t_push = time_op(1, repeats, |_| {
        let mut bytes = 0usize;
        for t in logs.scan_stream(push_spec.clone()) {
            bytes += t.val.len();
        }
        bytes
    });
    h.record(sscale, "scan-pushdown", t_push.clone(), kept);
    records4.push(
        BenchRecord::new("scan-filter-pushdown", sscale, 1, t_push.mean_s() * 1e9, 1.0)
            .with_extra("kept_cells", kept as f64)
            .with_extra("rejected_cells", rejected as f64)
            .with_extra("scan_allocs", scan_allocs as f64)
            .with_extra(
                "allocs_per_rejected",
                if rejected > 0 { scan_allocs as f64 / rejected as f64 } else { 0.0 },
            ),
    );

    // --- BFS frontier: one stacked multi-range scan vs per-node seeks ---
    // A degree-4 random digraph (2^bfs-scale nodes; every node has
    // out-edges, so hop 0 matches the frozen baseline bit-for-bit) and
    // a 1 000-node seed frontier. The per-seek baseline pays one
    // absolute seek — two lock acquisitions, a tablet locate, a B-tree
    // descent, a fresh opening block — per frontier node per hop; the
    // PR 5 path hands the whole frontier to the stack as one sorted,
    // coalesced range set and the tablet walk hops the gaps beneath the
    // block copy. Frontiers are identical by contract; the one-scan
    // path must be **≥ 1.4× faster** (the PR 5 acceptance number,
    // asserted below so the CI bench smoke enforces it).
    let bscale = args.usize_or("bfs-scale", 13);
    let bn = 1usize << bscale;
    let frontier_n = 1000usize.min(bn);
    let bfs_table = Arc::new(Table::new(
        "bfsgraph",
        TableConfig { split_threshold: 64 << 10, write_latency_us: 0 },
    ));
    {
        let mut rng = SplitMix64::new(0xBF5_F805);
        let mut w = BatchWriter::new(Arc::clone(&bfs_table), WriterConfig::default());
        for i in 0..bn {
            for _ in 0..4 {
                w.put(Triple::new(
                    format!("n{i:06}"),
                    format!("n{:06}", rng.below_usize(bn)),
                    "1",
                ));
            }
        }
        w.flush().expect("bench flush");
    }
    let seeds: Vec<String> =
        (0..frontier_n).map(|i| format!("n{:06}", i * (bn / frontier_n))).collect();
    let bfs_hops = 2usize;
    let mut seek_frontiers = Vec::new();
    let t_seek = time_op(1, repeats, |_| {
        seek_frontiers = bfs_per_seek(&bfs_table, &seeds, bfs_hops);
        seek_frontiers.len()
    });
    let mut scan_frontiers = Vec::new();
    let t_scan = time_op(1, repeats, |_| {
        scan_frontiers = graphulo::bfs(&bfs_table, &seeds, bfs_hops);
        scan_frontiers.len()
    });
    assert_eq!(
        seek_frontiers, scan_frontiers,
        "one-scan BFS must reach exactly the per-seek frontiers"
    );
    let reached: usize = scan_frontiers.iter().map(BTreeSet::len).sum();
    h.record(bscale, "bfs-per-seek", t_seek.clone(), reached);
    h.record(bscale, "bfs-one-scan", t_scan.clone(), reached);
    let bfs_speedup =
        if t_scan.mean_s() > 0.0 { t_seek.mean_s() / t_scan.mean_s() } else { 0.0 };
    println!(
        "[ablations] bfs 2^{bscale} nodes, {frontier_n}-seed frontier, {bfs_hops} hops: \
         per-seek={:.6}s one-scan={:.6}s speedup={bfs_speedup:.2}x ({reached} nodes reached, \
         {} tablets)",
        t_seek.mean_s(),
        t_scan.mean_s(),
        bfs_table.tablet_count(),
    );
    assert!(
        bfs_speedup >= 1.4,
        "one-scan BFS speedup {bfs_speedup:.2}x below the 1.4x acceptance threshold"
    );
    let records5: Vec<BenchRecord> = vec![
        BenchRecord::new("bfs-per-seek", bscale, 1, t_seek.mean_s() * 1e9, 1.0)
            .with_extra("frontier_nodes", frontier_n as f64)
            .with_extra("hops", bfs_hops as f64)
            .with_extra("reached_nodes", reached as f64)
            .with_extra("edge_cells", bfs_table.len() as f64),
        BenchRecord::new("bfs-one-scan", bscale, 1, t_scan.mean_s() * 1e9, bfs_speedup)
            .with_extra("frontier_nodes", frontier_n as f64)
            .with_extra("hops", bfs_hops as f64)
            .with_extra("reached_nodes", reached as f64)
            .with_extra("edge_cells", bfs_table.len() as f64),
    ];

    // --- durable tier: WAL ingest, checkpoint recovery, run-backed
    // scans (PR 6). Two acceptance numbers, both asserted:
    //   * recovering a checkpointed directory (sorted runs + an empty
    //     log) must be >= 5x faster than re-ingesting the same workload
    //     through the durable write path — the point of minor
    //     compaction is that a restart loads packed runs instead of
    //     replaying history one memtable insert at a time;
    //   * a full scan of the recovered, run-backed table must stay
    //     within 1.1x of the all-in-memory scan (speedup >= 0.91x) —
    //     tiering must not tax readers.
    let wscale = args.usize_or("wal-scale", 13);
    let wn = 1usize << wscale;
    let wal_dir = std::env::temp_dir().join(format!("d4m-ablations-wal-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&wal_dir);
    let wal_triples: Vec<Triple> = {
        let mut rng = SplitMix64::new(0x3A1_5EED);
        (0..wn)
            .map(|i| {
                Triple::new(
                    format!("r{:06}", i % (wn / 8).max(1)),
                    format!("c{:02}", rng.below(24)),
                    format!("{}", rng.below(100)),
                )
            })
            .collect()
    };
    // Durable ingest: every repeat starts a fresh table (Table::durable
    // truncates the log), so each measures the full WAL-then-memtable
    // write path over the whole workload.
    let mut ingest_cells = 0usize;
    let t_wal_ingest = time_op(1, repeats, |_| {
        let t = Table::durable("walbench", TableConfig::default(), &wal_dir, FsyncPolicy::Never)
            .expect("durable table");
        ingest_cells = 0;
        for chunk in wal_triples.chunks(64) {
            ingest_cells += t.write_batch(chunk.to_vec()).expect("wal ingest");
        }
        t.sync().expect("wal sync");
        ingest_cells
    });
    // Checkpoint the last ingest (freeze the memtable into runs), then
    // recover once untimed: that first recovery replays the log and
    // starts a fresh one, leaving the steady state a restart actually
    // sees — runs plus an empty log.
    {
        let t = Table::durable("walbench", TableConfig::default(), &wal_dir, FsyncPolicy::Never)
            .expect("durable table");
        for chunk in wal_triples.chunks(64) {
            t.write_batch(chunk.to_vec()).expect("wal ingest");
        }
        t.minor_compact().expect("minor compact");
    }
    let warm = Table::recover("walbench", TableConfig::default(), &wal_dir, FsyncPolicy::Never)
        .expect("warm recover");
    let wal_runs = warm.run_count();
    drop(warm);
    let t_wal_recover = time_op(1, repeats, |_| {
        let t = Table::recover("walbench", TableConfig::default(), &wal_dir, FsyncPolicy::Never)
            .expect("recover");
        t.run_count()
    });
    // Reader-side cost of the tiering: the recovered table serves every
    // cell out of runs; the flat table holds the same cells in its
    // memtable. Outputs are bit-identical by contract.
    let tiered = Table::recover("walbench", TableConfig::default(), &wal_dir, FsyncPolicy::Never)
        .expect("recover");
    let mem_table = Table::new("walmem", TableConfig::default());
    for chunk in wal_triples.chunks(64) {
        mem_table.write_batch(chunk.to_vec()).expect("ingest");
    }
    let mem_cells = mem_table.scan_par(ScanRange::all(), Parallelism::serial());
    assert_eq!(
        mem_cells,
        tiered.scan_par(ScanRange::all(), Parallelism::serial()),
        "run-backed scan must be bit-identical to the in-memory scan"
    );
    let wal_cells = mem_cells.len();
    let mut scanned = 0usize;
    let t_scan_mem = time_op(1, repeats, |_| {
        scanned = mem_table.scan_par(ScanRange::all(), Parallelism::serial()).len();
        scanned
    });
    let t_scan_run = time_op(1, repeats, |_| {
        scanned = tiered.scan_par(ScanRange::all(), Parallelism::serial()).len();
        scanned
    });
    let _ = std::fs::remove_dir_all(&wal_dir);
    h.record(wscale, "wal-ingest", t_wal_ingest.clone(), ingest_cells);
    h.record(wscale, "wal-recover", t_wal_recover.clone(), wal_cells);
    h.record(wscale, "scan-in-memory", t_scan_mem.clone(), wal_cells);
    h.record(wscale, "run-backed-scan", t_scan_run.clone(), wal_cells);
    let recover_speedup = if t_wal_recover.mean_s() > 0.0 {
        t_wal_ingest.mean_s() / t_wal_recover.mean_s()
    } else {
        0.0
    };
    let runscan_speedup =
        if t_scan_run.mean_s() > 0.0 { t_scan_mem.mean_s() / t_scan_run.mean_s() } else { 0.0 };
    println!(
        "[ablations] durable 2^{wscale} triples ({wal_cells} cells, {wal_runs} runs): \
         ingest={:.6}s recover={:.6}s ({recover_speedup:.2}x) scan mem={:.6}s \
         run-backed={:.6}s ({runscan_speedup:.2}x)",
        t_wal_ingest.mean_s(),
        t_wal_recover.mean_s(),
        t_scan_mem.mean_s(),
        t_scan_run.mean_s(),
    );
    assert!(
        recover_speedup >= 5.0,
        "checkpoint recovery speedup {recover_speedup:.2}x below the 5x acceptance threshold"
    );
    assert!(
        runscan_speedup >= 0.91,
        "run-backed scan at {runscan_speedup:.2}x of in-memory is outside the 1.1x budget"
    );
    let records6: Vec<BenchRecord> = vec![
        BenchRecord::new("wal-ingest", wscale, 1, t_wal_ingest.mean_s() * 1e9, 1.0)
            .with_extra("cells", ingest_cells as f64),
        BenchRecord::new("wal-recover", wscale, 1, t_wal_recover.mean_s() * 1e9, recover_speedup)
            .with_extra("cells", wal_cells as f64)
            .with_extra("runs", wal_runs as f64),
        BenchRecord::new("scan-in-memory", wscale, 1, t_scan_mem.mean_s() * 1e9, 1.0)
            .with_extra("cells", wal_cells as f64),
        BenchRecord::new("run-backed-scan", wscale, 1, t_scan_run.mean_s() * 1e9, runscan_speedup)
            .with_extra("cells", wal_cells as f64)
            .with_extra("runs", wal_runs as f64),
    ];

    // --- fault-injectable storage (PR 7): the retry/backoff layer must
    // be free when storage is healthy. Durable ingest under the default
    // RetryPolicy must stay within 1.05x of the single-attempt PR 6
    // path (RetryPolicy::none()) — asserted, the PR 7 acceptance
    // number. A third leg shows the layer earning its keep: a FaultyIo
    // injects a transient fault into every 16th storage operation, the
    // retry layer heals all of them (every batch acked), and a clean
    // recovery is bit-identical to the in-memory image.
    let fault_dir =
        std::env::temp_dir().join(format!("d4m-ablations-fault-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&fault_dir);
    let ingest_with = |opts: DurableOptions| {
        let t = Table::durable_with(
            "faultbench",
            TableConfig::default(),
            &fault_dir,
            FsyncPolicy::Never,
            opts,
        )
        .expect("durable table");
        let mut cells = 0usize;
        for chunk in wal_triples.chunks(64) {
            cells += t.write_batch(chunk.to_vec()).expect("fault-layer ingest");
        }
        t.sync().expect("fault-layer sync");
        cells
    };
    let t_noretry = time_op(1, repeats, |_| {
        ingest_with(DurableOptions { retry: RetryPolicy::none(), ..DurableOptions::default() })
    });
    let t_retry = time_op(1, repeats, |_| ingest_with(DurableOptions::default()));
    let retry_overhead =
        if t_noretry.min_s() > 0.0 { t_retry.min_s() / t_noretry.min_s() } else { 1.0 };
    let faulty = FaultyIo::new(FaultPlan::new().fail_every(16, FaultKind::Transient));
    let t_faulty = time_op(0, 1, |_| {
        ingest_with(DurableOptions {
            io: faulty.clone(),
            retry: RetryPolicy::immediate(3),
            fallback_to_memory: false,
            ..DurableOptions::default()
        })
    });
    let injected = faulty.injected();
    assert!(injected > 0, "the fault plan never fired");
    let healed =
        Table::recover("faultbench", TableConfig::default(), &fault_dir, FsyncPolicy::Never)
            .expect("recover after faulty ingest");
    assert_eq!(
        mem_cells,
        healed.scan_par(ScanRange::all(), Parallelism::serial()),
        "retry-healed ingest must recover bit-identical to the in-memory image"
    );
    drop(healed);
    let _ = std::fs::remove_dir_all(&fault_dir);
    h.record(wscale, "wal-ingest-noretry", t_noretry.clone(), wal_cells);
    h.record(wscale, "wal-ingest-retry", t_retry.clone(), wal_cells);
    println!(
        "[ablations] fault layer 2^{wscale} triples: ingest noretry={:.6}s retry={:.6}s \
         ({retry_overhead:.3}x overhead) faulty={:.6}s ({injected} transient faults healed)",
        t_noretry.min_s(),
        t_retry.min_s(),
        t_faulty.min_s(),
    );
    assert!(
        retry_overhead <= 1.05,
        "retry layer overhead {retry_overhead:.3}x exceeds the 1.05x acceptance budget"
    );
    let records7: Vec<BenchRecord> = vec![
        BenchRecord::new("wal-ingest-noretry", wscale, 1, t_noretry.min_s() * 1e9, 1.0)
            .with_extra("cells", wal_cells as f64),
        BenchRecord::new(
            "wal-ingest-retry",
            wscale,
            1,
            t_retry.min_s() * 1e9,
            if t_retry.min_s() > 0.0 { t_noretry.min_s() / t_retry.min_s() } else { 0.0 },
        )
        .with_extra("cells", wal_cells as f64)
        .with_extra("overhead_ratio", retry_overhead),
        BenchRecord::new(
            "wal-ingest-faulty",
            wscale,
            1,
            t_faulty.min_s() * 1e9,
            if t_faulty.min_s() > 0.0 { t_noretry.min_s() / t_faulty.min_s() } else { 0.0 },
        )
        .with_extra("cells", wal_cells as f64)
        .with_extra("injected_faults", injected as f64),
    ];

    // --- snapshot-pinned scans + range-chunk fan-out (PR 8). Two legs,
    // both asserted at >= 1.3x:
    //   * scan under writers — writer threads continuously overwrite
    //     the table while one scanner collects it. The lock-per-block
    //     baseline (`scan_spec_locked_par`, the frozen pre-PR 8 path)
    //     queues behind the writers' tablet locks at every block; the
    //     pinned-snapshot path locks once at open and walks free.
    //   * range-chunk fan-out — a single-tablet table at 4 threads.
    //     Per-tablet grouping degenerates to one serial walk; weighted
    //     range chunking splits the same tablet into balanced chunks.
    // Outputs are bit-identical by contract, asserted while quiescent.
    let cscale = args.usize_or("chunk-scale", 14);
    let cn = 1usize << cscale;
    let chunk_writers = 3usize;
    // Unique (row, col) per index: 24 columns per row.
    let chunk_row = |i: usize| format!("r{:05}", i / 24);
    let chunk_col = |i: usize| format!("c{:02}", i % 24);
    // ~4-8 tablets at ~14 bytes/cell, at every scale.
    let contended = Table::new(
        "chunkbench",
        TableConfig { split_threshold: (cn * 2).max(1024), write_latency_us: 0 },
    );
    {
        let batch: Vec<Triple> =
            (0..cn).map(|i| Triple::new(chunk_row(i), chunk_col(i), format!("{i}"))).collect();
        for chunk in batch.chunks(256) {
            contended.write_batch(chunk.to_vec()).expect("chunk ingest");
        }
    }
    let chunk_tablets = contended.tablet_count();
    let chunk_spec = ScanSpec::all().batched(64);
    let (t_scan_locked, t_scan_pinned) = std::thread::scope(|scope| {
        let stop = &std::sync::atomic::AtomicBool::new(false);
        let table = &contended;
        let row = &chunk_row;
        let col = &chunk_col;
        for w in 0..chunk_writers {
            scope.spawn(move || {
                let mut rng = SplitMix64::new(0xC8A0 + w as u64);
                while !stop.load(Ordering::Relaxed) {
                    let batch: Vec<Triple> = (0..256)
                        .map(|_| {
                            let i = rng.below_usize(cn);
                            Triple::new(row(i), col(i), "w")
                        })
                        .collect();
                    table.write_batch(batch).expect("overwrite");
                }
            });
        }
        let t_locked = time_op(1, repeats, |_| {
            table.scan_spec_locked_par(&chunk_spec, Parallelism::serial()).len()
        });
        let t_pinned = time_op(1, repeats, |_| {
            table.scan_spec_par(&chunk_spec, Parallelism::serial()).len()
        });
        stop.store(true, Ordering::Relaxed);
        (t_locked, t_pinned)
    });
    // Quiescent bit-identity: with the writers stopped, both paths must
    // serve the exact same cells.
    let chunk_expect = contended.scan_spec_locked_par(&chunk_spec, Parallelism::serial());
    assert_eq!(
        chunk_expect,
        contended.scan_spec_par(&chunk_spec, Parallelism::serial()),
        "pinned scan must be bit-identical to the locked scan"
    );
    let writer_speedup = if t_scan_pinned.mean_s() > 0.0 {
        t_scan_locked.mean_s() / t_scan_pinned.mean_s()
    } else {
        0.0
    };
    // Range-chunk fan-out: one tablet (default 4 MiB threshold never
    // splits at these scales), layered memtable-over-run so the chunk
    // walk merges like real scans do.
    let fanout = Table::new("fanoutbench", TableConfig::default());
    {
        let batch: Vec<Triple> =
            (0..cn).map(|i| Triple::new(chunk_row(i), chunk_col(i), format!("{i}"))).collect();
        let mid = batch.len() / 2;
        for chunk in batch[..mid].chunks(256) {
            fanout.write_batch(chunk.to_vec()).expect("fanout ingest");
        }
        fanout.minor_compact().expect("fanout compact");
        for chunk in batch[mid..].chunks(256) {
            fanout.write_batch(chunk.to_vec()).expect("fanout ingest");
        }
    }
    assert_eq!(fanout.tablet_count(), 1, "fan-out leg needs a single tablet");
    let fanout_spec = ScanSpec::all();
    let fanout_expect = fanout.scan_spec_locked_par(&fanout_spec, Parallelism::serial());
    assert_eq!(
        fanout_expect,
        fanout.scan_spec_par(&fanout_spec, Parallelism::with_threads(4)),
        "chunked scan must be bit-identical to the serial scan"
    );
    let t_fanout_groups = time_op(1, repeats, |_| {
        fanout.scan_spec_locked_par(&fanout_spec, Parallelism::with_threads(4)).len()
    });
    let t_fanout_chunks = time_op(1, repeats, |_| {
        fanout.scan_spec_par(&fanout_spec, Parallelism::with_threads(4)).len()
    });
    let fanout_speedup = if t_fanout_chunks.mean_s() > 0.0 {
        t_fanout_groups.mean_s() / t_fanout_chunks.mean_s()
    } else {
        0.0
    };
    h.record(cscale, "scan-locked-under-writers", t_scan_locked.clone(), chunk_expect.len());
    h.record(cscale, "scan-under-writers", t_scan_pinned.clone(), chunk_expect.len());
    h.record(cscale, "scan-tablet-groups", t_fanout_groups.clone(), fanout_expect.len());
    h.record(cscale, "range-chunk-fanout", t_fanout_chunks.clone(), fanout_expect.len());
    println!(
        "[ablations] snapshot scans 2^{cscale} cells ({chunk_tablets} tablets, \
         {chunk_writers} writers): locked={:.6}s pinned={:.6}s ({writer_speedup:.2}x); \
         fan-out @4 threads: tablet-groups={:.6}s range-chunks={:.6}s ({fanout_speedup:.2}x)",
        t_scan_locked.mean_s(),
        t_scan_pinned.mean_s(),
        t_fanout_groups.mean_s(),
        t_fanout_chunks.mean_s(),
    );
    assert!(
        writer_speedup >= 1.3,
        "pinned scan under writers at {writer_speedup:.2}x is below the 1.3x acceptance threshold"
    );
    assert!(
        fanout_speedup >= 1.3,
        "range-chunk fan-out at {fanout_speedup:.2}x is below the 1.3x acceptance threshold"
    );
    let records8: Vec<BenchRecord> = vec![
        BenchRecord::new(
            "scan-locked-under-writers",
            cscale,
            1,
            t_scan_locked.mean_s() * 1e9,
            1.0,
        )
        .with_extra("cells", chunk_expect.len() as f64)
        .with_extra("writers", chunk_writers as f64)
        .with_extra("tablets", chunk_tablets as f64),
        BenchRecord::new(
            "scan-under-writers",
            cscale,
            1,
            t_scan_pinned.mean_s() * 1e9,
            writer_speedup,
        )
        .with_extra("cells", chunk_expect.len() as f64)
        .with_extra("writers", chunk_writers as f64)
        .with_extra("tablets", chunk_tablets as f64),
        BenchRecord::new("scan-tablet-groups", cscale, 4, t_fanout_groups.mean_s() * 1e9, 1.0)
            .with_extra("cells", fanout_expect.len() as f64)
            .with_extra("tablets", 1.0),
        BenchRecord::new(
            "range-chunk-fanout",
            cscale,
            4,
            t_fanout_chunks.mean_s() * 1e9,
            fanout_speedup,
        )
        .with_extra("cells", fanout_expect.len() as f64)
        .with_extra("tablets", 1.0),
    ];

    // --- block-granular run I/O + shared LRU block cache (PR 9) -----
    let records9 = bench_blocks(&args, repeats);

    // --- cost-based query planner vs frozen heuristics (PR 10) ------
    let records10 = bench_planner(&args, repeats);

    h.write_csv(&out_dir).expect("write CSV");
    d4m::bench::write_bench_json(&out_dir, "BENCH_PR2.json", &records).expect("write JSON");
    d4m::bench::write_bench_json(&out_dir, "BENCH_PR3.json", &records3).expect("write JSON");
    d4m::bench::write_bench_json(&out_dir, "BENCH_PR4.json", &records4).expect("write JSON");
    d4m::bench::write_bench_json(&out_dir, "BENCH_PR5.json", &records5).expect("write JSON");
    d4m::bench::write_bench_json(&out_dir, "BENCH_PR6.json", &records6).expect("write JSON");
    d4m::bench::write_bench_json(&out_dir, "BENCH_PR7.json", &records7).expect("write JSON");
    d4m::bench::write_bench_json(&out_dir, "BENCH_PR8.json", &records8).expect("write JSON");
    d4m::bench::write_bench_json(&out_dir, "BENCH_PR9.json", &records9).expect("write JSON");
    d4m::bench::write_bench_json(&out_dir, "BENCH_PR10.json", &records10).expect("write JSON");
}
