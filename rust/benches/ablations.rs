//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! * **condense strategy** — the paper's indptr-mask trick
//!   (`csr_rows[:-1] < csr_rows[1:]`) vs rebuilding from triples.
//! * **constructor sort strategy** — the packed-u64 pair sort used by
//!   the COO builder vs sorting (row, col) tuples of strings.
//! * **CSR-resident vs COO-resident adj** — what D4M.py pays per `@`
//!   for keeping COO and converting inside each operation (the
//!   deviation documented in `assoc`'s module docs).
//! * **thread scaling** — fig6 matmul and fig3 constructor swept over
//!   worker counts (`threads = 1` is the exact serial code path),
//!   ending with a serial-vs-parallel speedup line so BENCH captures
//!   the scaling trajectory over time.
//! * **accumulator policy** — the adaptive SpGEMM engine vs the PR 1
//!   dense-scratch kernel (`AccumulatorPolicy::Dense`) on a
//!   hypersparse (1 nnz/row) workload, the regime the D4M papers show
//!   associative-array products live in.
//!
//! Besides the CSV, the run writes the machine-readable perf
//! trajectory `BENCH_PR2.json` (op, scale, threads, ns/op, speedup)
//! for `scripts/summarize_results.py` and the CI artifact.
//!
//! Usage: `cargo bench --bench ablations -- [--n N] [--repeats R]
//! [--threads-n N] [--hyper-scale S]` (`--threads-n` sets the scale of
//! the thread sweep; default 10, the acceptance workload.
//! `--hyper-scale` sets the hypersparse matmul to 2^S rows; default
//! 14).

use d4m::assoc::{keys_from, Aggregator, Assoc, ValsInput};
use d4m::bench::{BenchRecord, FigureHarness, Workload};
use d4m::semiring::PlusTimes;
use d4m::sparse::{spgemm, spgemm_with_policy_par, AccumulatorPolicy, CooMatrix};
use d4m::util::{time_op, Args, Parallelism, SplitMix64};

fn main() {
    let args = Args::from_env();
    let n = args.usize_or("n", 12);
    let repeats = args.usize_or("repeats", 5);
    let out_dir = args.str_or("out", "results");
    // Non-sweep sections measure the serial baselines unless --threads
    // overrides; the thread-scaling section below passes Parallelism
    // explicitly and is unaffected.
    Parallelism::with_threads(args.usize_or("threads", 1)).set_default();
    let w = Workload::generate(n, 77);
    let ones = w.ones();
    let a = Assoc::from_triples(&w.rows, &w.cols, ValsInput::Num(ones.clone()));
    let b = Assoc::from_triples(&w.rows2, &w.cols2, ValsInput::Num(ones.clone()));

    let mut h = FigureHarness::new("ablations", "design-choice ablations (DESIGN.md §5)");

    // --- condense: indptr mask vs triple rebuild ------------------------
    // Build an un-condensed product (matmul output before cleanup).
    let k = {
        use d4m::sorted::sorted_intersect;
        sorted_intersect(a.col_keys(), b.row_keys())
    };
    let all_rows: Vec<usize> = (0..a.row_keys().len()).collect();
    let all_cols: Vec<usize> = (0..b.col_keys().len()).collect();
    let ga = a.adj().gather(&all_rows, &k.map_left);
    let gb = b.adj().gather(&k.map_right, &all_cols);
    let c_pre = spgemm(&ga, &gb, &PlusTimes).unwrap();

    let t = time_op(1, repeats, |_| {
        // Paper's strategy: boolean masks from indptr, then select.
        let rm = c_pre.nonempty_rows();
        let cm = c_pre.nonempty_cols();
        c_pre.select(&rm, &cm)
    });
    h.record(n, "condense-mask", t, c_pre.nnz());

    let t = time_op(1, repeats, |_| {
        // Naive strategy: extract triples, re-sort, rebuild.
        let coo = c_pre.to_coo();
        let rows: Vec<usize> = coo.row_indices().iter().map(|&r| r as usize).collect();
        let cols: Vec<usize> = coo.col_indices().iter().map(|&c| c as usize).collect();
        let (m, nn) = c_pre.shape();
        CooMatrix::from_triples_aggregate(m, nn, &rows, &cols, coo.values(), 0.0, |x, _| x)
            .unwrap()
            .to_csr()
    });
    h.record(n, "condense-rebuild", t, c_pre.nnz());

    // --- constructor sort: packed u64 rank pairs vs string tuples -------
    let t = time_op(1, repeats, |_| {
        Assoc::from_triples(&w.rows, &w.cols, ValsInput::Num(w.num_vals.clone()))
    });
    h.record(n, "ctor-packed-u64", t, a.nnz());

    let t = time_op(1, repeats, |_| {
        // Strategy D4M.py can't use (no rank packing): sort owned
        // (row, col, val) string tuples directly.
        let mut triples: Vec<(String, String, f64)> = (0..w.rows.len())
            .map(|i| (w.rows[i].clone(), w.cols[i].clone(), w.num_vals[i]))
            .collect();
        triples.sort_unstable_by(|x, y| (&x.0, &x.1).cmp(&(&y.0, &y.1)));
        triples.dedup_by(|x, y| {
            if x.0 == y.0 && x.1 == y.1 {
                y.2 = y.2.min(x.2);
                true
            } else {
                false
            }
        });
        triples
    });
    h.record(n, "ctor-string-sort", t, a.nnz());

    // --- adj residency: CSR-resident (ours) vs COO + per-op convert -----
    let t = time_op(1, repeats, |_| a.matmul(&b));
    h.record(n, "matmul-csr-resident", t, 0);

    let coo_a = a.adj().to_coo();
    let coo_b = b.adj().to_coo();
    let t = time_op(1, repeats, |_| {
        // D4M.py's layout: COO at rest, convert inside the op.
        let ca = coo_a.to_csr();
        let cb = coo_b.to_csr();
        let ga = ca.gather(&all_rows, &k.map_left);
        let gb = cb.gather(&k.map_right, &all_cols);
        let c = spgemm(&ga, &gb, &PlusTimes).unwrap();
        c.to_coo() // and back to the resident format
    });
    h.record(n, "matmul-coo-convert", t, 0);

    // --- thread scaling: fig6 matmul + fig3 constructor -----------------
    // `threads = 1` runs the exact serial code path; other counts are
    // bit-identical (enforced by tests/parallel_equivalence.rs), so any
    // delta here is pure scheduling cost / speedup.
    let tn = args.usize_or("threads-n", 10);
    let wt = Workload::generate(tn, 77);
    let tones = vec![1.0; wt.rows.len()];
    let ta = Assoc::from_triples(&wt.rows, &wt.cols, ValsInput::Num(tones.clone()));
    let tb = Assoc::from_triples(&wt.rows2, &wt.cols2, ValsInput::Num(tones));
    let sweep = [1usize, 2, 4, 8];
    let mut matmul_means = Vec::with_capacity(sweep.len());
    let mut ctor_means = Vec::with_capacity(sweep.len());
    for &threads in &sweep {
        let par = Parallelism::with_threads(threads);
        let mut nnz = 0usize;
        let t = time_op(1, repeats, |_| {
            let c = ta.matmul_par(&tb, par);
            nnz = c.nnz();
            c
        });
        matmul_means.push(t.mean_s());
        h.record(tn, &format!("matmul-t{threads}"), t, nnz);

        let mut cnnz = 0usize;
        let t = time_op(1, repeats, |_| {
            let c = Assoc::try_new_par(
                keys_from(&wt.rows),
                keys_from(&wt.cols),
                ValsInput::Num(wt.num_vals.clone()),
                Aggregator::Min,
                par,
            )
            .unwrap();
            cnnz = c.nnz();
            c
        });
        ctor_means.push(t.mean_s());
        h.record(tn, &format!("ctor-t{threads}"), t, cnnz);
    }
    // Serial-vs-parallel speedup line (parsed by the BENCH capture).
    let speedup = |means: &[f64], i: usize| {
        if means[i] > 0.0 {
            means[0] / means[i]
        } else {
            0.0
        }
    };
    println!(
        "[ablations] threads-sweep n={tn} matmul serial={:.6}s t2={:.2}x t4={:.2}x t8={:.2}x \
         | ctor serial={:.6}s t2={:.2}x t4={:.2}x t8={:.2}x",
        matmul_means[0],
        speedup(&matmul_means, 1),
        speedup(&matmul_means, 2),
        speedup(&matmul_means, 3),
        ctor_means[0],
        speedup(&ctor_means, 1),
        speedup(&ctor_means, 2),
        speedup(&ctor_means, 3),
    );
    let mut records: Vec<BenchRecord> = Vec::new();
    for (i, &threads) in sweep.iter().enumerate() {
        records.push(BenchRecord {
            op: "matmul".into(),
            scale: tn,
            threads,
            ns_per_op: matmul_means[i] * 1e9,
            speedup: speedup(&matmul_means, i),
        });
        records.push(BenchRecord {
            op: "constructor".into(),
            scale: tn,
            threads,
            ns_per_op: ctor_means[i] * 1e9,
            speedup: speedup(&ctor_means, i),
        });
    }

    // --- accumulator policy: adaptive engine vs PR-1 dense scratch ------
    // Hypersparse workload (1 nnz per row, the associative-array regime):
    // the dense kernel pays an O(ncols) scratch row and scattered
    // accumulator traffic per chunk; the adaptive engine's copy/sort/hash
    // rows never touch O(ncols) state. Outputs are bit-identical (also
    // enforced by tests/parallel_equivalence.rs), so the delta is pure
    // accumulator cost.
    let hscale = args.usize_or("hyper-scale", 14);
    let hn = 1usize << hscale;
    let mut rng = SplitMix64::new(0xAB1A7E5);
    let hrows: Vec<usize> = (0..hn).collect();
    let hcols: Vec<usize> = (0..hn).map(|_| rng.below_usize(hn)).collect();
    let hvals: Vec<f64> = (0..hn).map(|i| (i % 9 + 1) as f64).collect();
    let ha = CooMatrix::from_triples_aggregate(hn, hn, &hrows, &hcols, &hvals, 0.0, |x, _| x)
        .expect("hypersparse triples")
        .to_csr();
    for &threads in &[1usize, 4] {
        let par = Parallelism::with_threads(threads);
        let policies = [
            ("hyper-dense", AccumulatorPolicy::Dense),
            ("hyper-adaptive", AccumulatorPolicy::Adaptive),
        ];
        let mut means = Vec::with_capacity(policies.len());
        for &(label, policy) in &policies {
            let mut nnz = 0usize;
            let t = time_op(1, repeats, |_| {
                let (c, _) = spgemm_with_policy_par(&ha, &ha, &PlusTimes, par, policy)
                    .expect("square shapes");
                nnz = c.nnz();
                c
            });
            means.push(t.mean_s());
            h.record(hscale, &format!("{label}-t{threads}"), t, nnz);
        }
        let hyper_speedup = if means[1] > 0.0 { means[0] / means[1] } else { 0.0 };
        println!(
            "[ablations] hypersparse 2^{hscale} t{threads}: dense={:.6}s adaptive={:.6}s \
             adaptive-speedup={hyper_speedup:.2}x",
            means[0], means[1],
        );
        records.push(BenchRecord {
            op: "hypersparse-matmul-dense".into(),
            scale: hscale,
            threads,
            ns_per_op: means[0] * 1e9,
            speedup: 1.0,
        });
        records.push(BenchRecord {
            op: "hypersparse-matmul-adaptive".into(),
            scale: hscale,
            threads,
            ns_per_op: means[1] * 1e9,
            speedup: hyper_speedup,
        });
    }

    h.write_csv(&out_dir).expect("write CSV");
    d4m::bench::write_bench_json(&out_dir, "BENCH_PR2.json", &records).expect("write JSON");
}
