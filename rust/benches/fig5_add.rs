//! Figure 5 reproduction: element-wise addition `A + B` where
//! `A = Assoc(rows, cols, 1)` and `B = Assoc(rows2, cols2, 1)` —
//! sorted-union key alignment + sparse add + condense (paper §II.C.1).
//!
//! Usage: `cargo bench --bench fig5_add -- [--full] ...`

mod fig_common;

use d4m::bench::BenchParams;
use fig_common::{run_figure, BinaryOp, OpKind};

fn main() {
    let params = BenchParams::from_env(18, 12);
    run_figure(
        "fig5",
        "element-wise addition A + B (paper Fig. 5)",
        OpKind::Binary(BinaryOp::Add),
        &params,
    );
}
