//! Store-ingest throughput bench — the scaled-down echo of the D4M
//! lineage's "100,000,000 database inserts per second" Accumulo result
//! (paper ref [13]): triples/second into the tablet store, swept over
//! batch size, worker count, and shard policy.
//!
//! Usage: `cargo bench --bench store_ingest -- [--triples N] [--out DIR]`

use d4m::bench::FigureHarness;
use d4m::pipeline::{IngestPipeline, PipelineConfig, ShardPolicy};
use d4m::store::{Table, TableConfig, Triple, WriterConfig};
use d4m::util::{time_op, Args, SplitMix64};
use std::sync::Arc;

fn gen_triples(n: usize, seed: u64) -> Vec<Triple> {
    let mut r = SplitMix64::new(seed);
    (0..n)
        .map(|i| {
            Triple::new(
                format!("r{:010}", r.next_u64() % (n as u64)),
                format!("c{}", i % 32),
                "1",
            )
        })
        .collect()
}

fn run(table_cfg: TableConfig, pipe_cfg: PipelineConfig, triples: &[Triple]) -> (f64, usize) {
    let table = Arc::new(Table::new("ingest", table_cfg));
    let mut p = IngestPipeline::start(Arc::clone(&table), pipe_cfg);
    p.submit_all(triples.iter().cloned());
    let report = p.finish();
    assert_eq!(report.written, triples.len());
    (report.rate(), report.stalls)
}

fn main() {
    let args = Args::from_env();
    let n = args.usize_or("triples", 400_000);
    let repeats = args.usize_or("repeats", 3);
    let out_dir = args.str_or("out", "results");
    let triples = gen_triples(n, 9);
    let mut h = FigureHarness::new("store_ingest", "pipeline ingest throughput (triples/s ×1000)");

    // Sweep batch size (the BatchWriter lever).
    for (i, batch_bytes) in [4usize << 10, 64 << 10, 1 << 20].into_iter().enumerate() {
        let mut rate = 0.0;
        let t = time_op(0, repeats, |_| {
            let (r, _) = run(
                TableConfig { split_threshold: 8 << 20, write_latency_us: 0 },
                PipelineConfig {
                    workers: 4,
                    writer: WriterConfig { batch_bytes, ..Default::default() },
                    ..Default::default()
                },
                &triples,
            );
            rate = r;
        });
        h.record(i, &format!("batch-{}k", batch_bytes >> 10), t, (rate / 1e3) as usize);
    }

    // Sweep worker count.
    for workers in [1usize, 2, 4, 8] {
        let mut rate = 0.0;
        let t = time_op(0, repeats, |_| {
            let (r, _) = run(
                TableConfig { split_threshold: 8 << 20, write_latency_us: 0 },
                PipelineConfig { workers, ..Default::default() },
                &triples,
            );
            rate = r;
        });
        h.record(workers, &format!("workers-{workers}"), t, (rate / 1e3) as usize);
    }

    // Hash vs range sharding (range pre-split at even boundaries).
    let splits: Vec<String> = (1..4).map(|i| format!("r{:010}", i * (n as u64) / 4)).collect();
    for (name, policy) in [
        ("hash", ShardPolicy::Hash),
        ("range", ShardPolicy::Range { splits: splits.clone() }),
    ] {
        let mut rate = 0.0;
        let t = time_op(0, repeats, |_| {
            let (r, _) = run(
                TableConfig { split_threshold: 8 << 20, write_latency_us: 0 },
                PipelineConfig { workers: 4, policy: policy.clone(), ..Default::default() },
                &triples,
            );
            rate = r;
        });
        h.record(4, &format!("policy-{name}"), t, (rate / 1e3) as usize);
    }

    h.write_csv(&out_dir).expect("write CSV");
}
