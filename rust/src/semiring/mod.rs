//! Semirings — the value algebras of associative arrays (paper §I.A).
//!
//! A semiring `(V, ⊕, ⊗, 0, 1)` supplies the "addition" and
//! "multiplication" that associative-array element-wise ops and `@`
//! contract with. D4M's two implicit algebras are the **plus-times**
//! algebra over numbers and the **(concat, min) string algebra**; this
//! module additionally provides the tropical algebras (max-plus,
//! min-plus) and max-min (fuzzy) algebra the paper lists, plus a
//! user-defined escape hatch ([`FnSemiring`]) anticipating the paper's
//! future-work item of user-selected semirings.
//!
//! All numeric semirings operate on `f64` (D4M's numeric value type).
//! The string algebra lives with the string value pool in
//! [`crate::assoc`], because its "values" are interned indices.

use std::fmt::Debug;

mod laws;
pub use laws::check_semiring_laws;

/// A semiring over `f64` values.
///
/// Implementations must satisfy the semiring axioms (associativity of
/// both ops, commutativity of `add`, identities, annihilation,
/// distributivity); [`check_semiring_laws`] verifies them on sample
/// points and is exercised by the test suite for every instance.
pub trait Semiring: Send + Sync + 'static {
    /// Additive identity ("zero"; the unstored value).
    fn zero(&self) -> f64;
    /// Multiplicative identity.
    fn one(&self) -> f64;
    /// `a ⊕ b`.
    fn add(&self, a: f64, b: f64) -> f64;
    /// `a ⊗ b`.
    fn mul(&self, a: f64, b: f64) -> f64;
    /// Whether `a` is (exactly) the additive identity.
    fn is_zero(&self, a: f64) -> bool {
        a == self.zero()
    }
    /// Stable name used by artifact lookup and bench output.
    fn name(&self) -> &'static str;
}

/// The standard arithmetic algebra `(ℝ, +, ×, 0, 1)`.
#[derive(Debug, Clone, Copy, Default)]
pub struct PlusTimes;

impl Semiring for PlusTimes {
    fn zero(&self) -> f64 {
        0.0
    }
    fn one(&self) -> f64 {
        1.0
    }
    fn add(&self, a: f64, b: f64) -> f64 {
        a + b
    }
    fn mul(&self, a: f64, b: f64) -> f64 {
        a * b
    }
    fn name(&self) -> &'static str {
        "plus_times"
    }
}

/// The tropical max-plus algebra `(ℝ ∪ {−∞}, max, +, −∞, 0)`.
///
/// `A ⊕.⊗ B` under max-plus computes longest paths / best-score
/// contractions — a classic GraphBLAS workhorse.
#[derive(Debug, Clone, Copy, Default)]
pub struct MaxPlus;

impl Semiring for MaxPlus {
    fn zero(&self) -> f64 {
        f64::NEG_INFINITY
    }
    fn one(&self) -> f64 {
        0.0
    }
    fn add(&self, a: f64, b: f64) -> f64 {
        a.max(b)
    }
    fn mul(&self, a: f64, b: f64) -> f64 {
        // −∞ must annihilate: −∞ + x = −∞ (holds for IEEE unless x = +∞,
        // which the key spaces never produce).
        a + b
    }
    fn name(&self) -> &'static str {
        "max_plus"
    }
}

/// The tropical min-plus algebra `(ℝ ∪ {+∞}, min, +, +∞, 0)` — shortest
/// paths.
#[derive(Debug, Clone, Copy, Default)]
pub struct MinPlus;

impl Semiring for MinPlus {
    fn zero(&self) -> f64 {
        f64::INFINITY
    }
    fn one(&self) -> f64 {
        0.0
    }
    fn add(&self, a: f64, b: f64) -> f64 {
        a.min(b)
    }
    fn mul(&self, a: f64, b: f64) -> f64 {
        a + b
    }
    fn name(&self) -> &'static str {
        "min_plus"
    }
}

/// The max-min (fuzzy/bottleneck) algebra
/// `(ℝ ∪ {±∞}, max, min, −∞, +∞)` — widest-path / bottleneck capacity.
#[derive(Debug, Clone, Copy, Default)]
pub struct MaxMin;

impl Semiring for MaxMin {
    fn zero(&self) -> f64 {
        f64::NEG_INFINITY
    }
    fn one(&self) -> f64 {
        f64::INFINITY
    }
    fn add(&self, a: f64, b: f64) -> f64 {
        a.max(b)
    }
    fn mul(&self, a: f64, b: f64) -> f64 {
        a.min(b)
    }
    fn name(&self) -> &'static str {
        "max_min"
    }
}

/// A user-defined semiring from closures (paper §IV future work:
/// "user-selected or user-defined semiring operations").
///
/// The caller is responsible for the closures actually satisfying the
/// semiring axioms; [`check_semiring_laws`] can be used to sanity-check.
pub struct FnSemiring {
    zero: f64,
    one: f64,
    add: fn(f64, f64) -> f64,
    mul: fn(f64, f64) -> f64,
    name: &'static str,
}

impl FnSemiring {
    /// Build a semiring from function pointers and identity constants.
    pub fn new(
        name: &'static str,
        zero: f64,
        one: f64,
        add: fn(f64, f64) -> f64,
        mul: fn(f64, f64) -> f64,
    ) -> Self {
        FnSemiring { zero, one, add, mul, name }
    }
}

impl Semiring for FnSemiring {
    fn zero(&self) -> f64 {
        self.zero
    }
    fn one(&self) -> f64 {
        self.one
    }
    fn add(&self, a: f64, b: f64) -> f64 {
        (self.add)(a, b)
    }
    fn mul(&self, a: f64, b: f64) -> f64 {
        (self.mul)(a, b)
    }
    fn name(&self) -> &'static str {
        self.name
    }
}

/// Look up a built-in semiring by name (CLI / artifact manifest).
pub fn by_name(name: &str) -> Option<Box<dyn Semiring>> {
    match name {
        "plus_times" => Some(Box::new(PlusTimes)),
        "max_plus" => Some(Box::new(MaxPlus)),
        "min_plus" => Some(Box::new(MinPlus)),
        "max_min" => Some(Box::new(MaxMin)),
        _ => None,
    }
}

/// All built-in numeric semirings (for law tests and bench sweeps).
pub fn builtin() -> Vec<Box<dyn Semiring>> {
    vec![
        Box::new(PlusTimes),
        Box::new(MaxPlus),
        Box::new(MinPlus),
        Box::new(MaxMin),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identities() {
        assert_eq!(PlusTimes.add(3.0, PlusTimes.zero()), 3.0);
        assert_eq!(PlusTimes.mul(3.0, PlusTimes.one()), 3.0);
        assert_eq!(MaxPlus.add(3.0, MaxPlus.zero()), 3.0);
        assert_eq!(MaxPlus.mul(3.0, MaxPlus.one()), 3.0);
        assert_eq!(MinPlus.add(3.0, MinPlus.zero()), 3.0);
        assert_eq!(MinPlus.mul(3.0, MinPlus.one()), 3.0);
        assert_eq!(MaxMin.add(3.0, MaxMin.zero()), 3.0);
        assert_eq!(MaxMin.mul(3.0, MaxMin.one()), 3.0);
    }

    #[test]
    fn annihilation() {
        for s in builtin() {
            let z = s.zero();
            for v in [-2.0, 0.0, 1.0, 5.5] {
                assert_eq!(s.mul(v, z), z, "{} right-annihilate", s.name());
                assert_eq!(s.mul(z, v), z, "{} left-annihilate", s.name());
            }
        }
    }

    #[test]
    fn by_name_roundtrip() {
        for s in builtin() {
            let found = by_name(s.name()).expect("by_name");
            assert_eq!(found.name(), s.name());
            assert_eq!(found.zero(), s.zero());
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn fn_semiring_works() {
        // xor-and over {0,1} as floats (boolean ring fragment).
        fn bxor(a: f64, b: f64) -> f64 {
            if (a != 0.0) ^ (b != 0.0) {
                1.0
            } else {
                0.0
            }
        }
        fn band(a: f64, b: f64) -> f64 {
            if a != 0.0 && b != 0.0 {
                1.0
            } else {
                0.0
            }
        }
        let s = FnSemiring::new("xor_and", 0.0, 1.0, bxor, band);
        assert_eq!(s.add(1.0, 1.0), 0.0);
        assert_eq!(s.mul(1.0, 1.0), 1.0);
        assert_eq!(s.name(), "xor_and");
    }
}
