//! Executable semiring axioms (paper §I.A's axiom list), checked over
//! sample points. Floating-point caveat: `+`/`×` over arbitrary floats
//! are not exactly associative/distributive, so law checks use small
//! integer-valued samples where IEEE arithmetic is exact; max/min-based
//! algebras are exact everywhere.

use super::Semiring;

/// Assert the semiring axioms on a grid of sample values.
///
/// Panics with a descriptive message on the first violated law.
/// `samples` should be exactly representable values for which `add`/`mul`
/// are exact (small integers are safe for every built-in algebra).
pub fn check_semiring_laws(s: &dyn Semiring, samples: &[f64]) {
    let zero = s.zero();
    let one = s.one();
    let mut pts: Vec<f64> = samples.to_vec();
    pts.push(zero);
    pts.push(one);

    for &u in &pts {
        // Identities.
        assert_eq!(s.add(u, zero), u, "{}: u ⊕ 0 = u failed for u={u}", s.name());
        assert_eq!(s.add(zero, u), u, "{}: 0 ⊕ u = u failed for u={u}", s.name());
        assert_eq!(s.mul(u, one), u, "{}: u ⊗ 1 = u failed for u={u}", s.name());
        assert_eq!(s.mul(one, u), u, "{}: 1 ⊗ u = u failed for u={u}", s.name());
        // Annihilation.
        assert_eq!(s.mul(u, zero), zero, "{}: u ⊗ 0 = 0 failed for u={u}", s.name());
        assert_eq!(s.mul(zero, u), zero, "{}: 0 ⊗ u = 0 failed for u={u}", s.name());
    }
    for &u in &pts {
        for &v in &pts {
            // Commutativity of ⊕.
            assert_eq!(
                s.add(u, v),
                s.add(v, u),
                "{}: ⊕ not commutative at ({u}, {v})",
                s.name()
            );
            for &w in &pts {
                // Associativity.
                assert_eq!(
                    s.add(u, s.add(v, w)),
                    s.add(s.add(u, v), w),
                    "{}: ⊕ not associative at ({u}, {v}, {w})",
                    s.name()
                );
                assert_eq!(
                    s.mul(u, s.mul(v, w)),
                    s.mul(s.mul(u, v), w),
                    "{}: ⊗ not associative at ({u}, {v}, {w})",
                    s.name()
                );
                // Distributivity (both sides).
                assert_eq!(
                    s.mul(u, s.add(v, w)),
                    s.add(s.mul(u, v), s.mul(u, w)),
                    "{}: left distributivity failed at ({u}, {v}, {w})",
                    s.name()
                );
                assert_eq!(
                    s.mul(s.add(v, w), u),
                    s.add(s.mul(v, u), s.mul(w, u)),
                    "{}: right distributivity failed at ({u}, {v}, {w})",
                    s.name()
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::semiring::{builtin, FnSemiring};

    const SAMPLES: [f64; 7] = [-3.0, -1.0, 0.0, 1.0, 2.0, 5.0, 16.0];

    #[test]
    fn all_builtin_semirings_satisfy_laws() {
        for s in builtin() {
            check_semiring_laws(s.as_ref(), &SAMPLES);
        }
    }

    #[test]
    #[should_panic(expected = "⊗ 0 = 0")]
    fn broken_semiring_is_caught() {
        // "max-times" over all reals is NOT a semiring: negative values
        // break annihilation (−3 × −∞ = +∞ ≠ −∞) and distributivity.
        fn fmax(a: f64, b: f64) -> f64 {
            a.max(b)
        }
        fn fmul(a: f64, b: f64) -> f64 {
            a * b
        }
        let bad = FnSemiring::new("max_times", f64::NEG_INFINITY, 1.0, fmax, fmul);
        check_semiring_laws(&bad, &SAMPLES);
    }
}
