//! From-scratch sparse linear-algebra substrate.
//!
//! The paper leaves "as much of the work as possible to a dedicated
//! sparse linear algebra library" — SciPy.sparse for D4M.py, MATLAB's
//! built-in sparse for D4M-MATLAB, `SparseArrays` for D4M.jl. This repo
//! has no such dependency, so this module *is* that library:
//!
//! * [`CooMatrix`] — COOrdinate (triple) format: the `A.adj` storage
//!   format of D4M.py, and the ingest format for construction.
//! * [`CsrMatrix`] — Compressed Sparse Row: the compute format for
//!   addition, element-wise multiplication and SpGEMM; also supplies the
//!   `indptr`-based nonempty-row test used by `condense` (paper §II.C.1).
//! * [`CscMatrix`] — Compressed Sparse Column: transpose-view used for
//!   the nonempty-column test and column slicing.
//!
//! All value storage is `f64` (D4M's numeric value type; string arrays
//! store 1-based value-pool indices as `f64`, exactly like D4M.py storing
//! `k + 1` in a SciPy COO matrix). Algebraic operations are parameterized
//! by a [`crate::semiring::Semiring`] so `+`, `*`, `@` work over
//! plus-times, max-plus, min-plus, max-min or user algebras.
//!
//! Entries whose value equals the semiring zero are *never stored*;
//! every constructor and operation prunes them ("zeros are unstored",
//! paper §I.B).

mod coo;
mod csc;
mod csr;
mod dense;
mod spgemm;

pub use coo::CooMatrix;
pub use csc::CscMatrix;
pub use csr::CsrMatrix;
pub use dense::DenseBlock;
pub use spgemm::{
    spgemm, spgemm_masked, spgemm_masked_par, spgemm_masked_with_modes_par,
    spgemm_masked_with_stats_par, spgemm_par, spgemm_row_masked, spgemm_row_masked_par,
    spgemm_row_masked_with_modes_par, spgemm_row_masked_with_stats_par, spgemm_with_modes_par,
    spgemm_with_policy_par, spgemm_with_stats, spgemm_with_stats_par, AccumulatorPolicy,
    SpGemmStats, SymbolicBound,
};

/// Errors from sparse-matrix constructors and shape checks.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SparseError {
    /// Triple arrays have mismatched lengths.
    LengthMismatch { rows: usize, cols: usize, vals: usize },
    /// An index is out of the declared shape.
    IndexOutOfBounds { axis: &'static str, index: usize, extent: usize },
    /// Operand shapes are incompatible for the requested operation.
    ShapeMismatch { left: (usize, usize), right: (usize, usize), op: &'static str },
    /// A mask's length disagrees with the masked axis's extent
    /// (`axis` is `"column"` for output-column masks over `B`, `"row"`
    /// for output-row masks over `A`).
    MaskLengthMismatch { mask: usize, extent: usize, axis: &'static str },
}

impl std::fmt::Display for SparseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SparseError::LengthMismatch { rows, cols, vals } => write!(
                f,
                "triple arrays have mismatched lengths: rows={rows} cols={cols} vals={vals}"
            ),
            SparseError::IndexOutOfBounds { axis, index, extent } => {
                write!(f, "{axis} index {index} out of bounds for extent {extent}")
            }
            SparseError::ShapeMismatch { left, right, op } => write!(
                f,
                "shape mismatch for {op}: {}x{} vs {}x{}",
                left.0, left.1, right.0, right.1
            ),
            SparseError::MaskLengthMismatch { mask, extent, axis } => {
                write!(f, "{axis} mask length {mask} does not match {axis} extent {extent}")
            }
        }
    }
}

impl std::error::Error for SparseError {}
