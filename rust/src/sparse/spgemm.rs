//! Sparse general matrix-matrix multiply (SpGEMM) over a semiring.
//!
//! Gustavson's row-wise algorithm with a dense accumulator: for each row
//! `i` of `A`, accumulate `⊕_k A[i,k] ⊗ B[k,:]` into a dense scratch row,
//! tracking which columns were touched so the scratch can be reset in
//! O(touched) rather than O(ncols). This is the general path of `A @ B`
//! (paper §II.C.3); the dense-block PJRT kernel in [`crate::runtime`] is
//! the accelerated alternative for dense operands.
//!
//! **Parallelism.** Rows of `C` are independent in Gustavson's
//! formulation, so [`spgemm_par`] partitions `A`'s rows into contiguous
//! chunks (balanced by `A`'s nnz), runs the identical per-row kernel in
//! each pool worker with its own dense accumulator, and stitches the
//! chunk outputs back in row order. The output is bit-identical to the
//! serial path for every thread count: chunk boundaries depend only on
//! the input and `threads`, and within a row the ⊕-accumulation order
//! is unchanged.

use super::{CsrMatrix, SparseError};
use crate::semiring::Semiring;
use crate::util::parallel::{parallel_map_ranges, Parallelism};
use std::ops::Range;

/// Instrumentation from one SpGEMM call (used by the perf harness).
#[derive(Debug, Clone, Default)]
pub struct SpGemmStats {
    /// Number of `⊗` (multiply) operations performed.
    pub mults: u64,
    /// Stored entries in the output.
    pub out_nnz: usize,
}

/// `C = A ⊗.⊕ B` over semiring `s`, at the process-default parallelism.
/// Shapes must contract: `(m × k) @ (k × n) → (m × n)`.
pub fn spgemm(a: &CsrMatrix, b: &CsrMatrix, s: &dyn Semiring) -> Result<CsrMatrix, SparseError> {
    spgemm_par(a, b, s, Parallelism::current())
}

/// [`spgemm`] with an explicit thread configuration. `threads == 1` is
/// the exact serial code path; any other count produces a bit-identical
/// result (see the module docs).
pub fn spgemm_par(
    a: &CsrMatrix,
    b: &CsrMatrix,
    s: &dyn Semiring,
    par: Parallelism,
) -> Result<CsrMatrix, SparseError> {
    spgemm_with_stats_par(a, b, s, par).map(|(c, _)| c)
}

/// [`spgemm`] with operation counts, at the process-default parallelism.
pub fn spgemm_with_stats(
    a: &CsrMatrix,
    b: &CsrMatrix,
    s: &dyn Semiring,
) -> Result<(CsrMatrix, SpGemmStats), SparseError> {
    spgemm_with_stats_par(a, b, s, Parallelism::current())
}

/// Rows below this count are not worth a fan-out (pool dispatch costs
/// more than the row work saved).
const PAR_MIN_ROWS: usize = 64;

/// [`spgemm_par`] with operation counts.
pub fn spgemm_with_stats_par(
    a: &CsrMatrix,
    b: &CsrMatrix,
    s: &dyn Semiring,
    par: Parallelism,
) -> Result<(CsrMatrix, SpGemmStats), SparseError> {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    if ka != kb {
        return Err(SparseError::ShapeMismatch { left: a.shape(), right: b.shape(), op: "spgemm" });
    }
    let parts: Vec<RowChunk> = if par.is_serial() || m < PAR_MIN_ROWS {
        vec![gustavson_rows(a, b, s, 0..m)]
    } else {
        // Chunk boundaries balanced by A's nnz (a pure function of the
        // input and `threads`, so the stitched output is deterministic).
        let ranges = par.chunk_ranges_weighted(a.indptr());
        parallel_map_ranges(ranges, |rows| gustavson_rows(a, b, s, rows))
    };

    // Stitch chunk outputs in row order.
    let total: usize = parts.iter().map(|p| p.indices.len()).sum();
    let mut indptr = Vec::with_capacity(m + 1);
    indptr.push(0usize);
    let mut indices: Vec<u32> = Vec::with_capacity(total);
    let mut data: Vec<f64> = Vec::with_capacity(total);
    let mut stats = SpGemmStats::default();
    for part in parts {
        let base = indices.len();
        indptr.extend(part.rel_indptr.into_iter().map(|e| base + e));
        indices.extend_from_slice(&part.indices);
        data.extend_from_slice(&part.data);
        stats.mults += part.mults;
    }
    stats.out_nnz = data.len();
    Ok((CsrMatrix::from_parts(m, n, indptr, indices, data), stats))
}

/// Output of [`gustavson_rows`] for one contiguous row range.
struct RowChunk {
    /// `rel_indptr[j]` = entries emitted after finishing the range's
    /// `j`-th row (no leading 0; offset by the stitch base).
    rel_indptr: Vec<usize>,
    indices: Vec<u32>,
    data: Vec<f64>,
    mults: u64,
}

/// The Gustavson kernel over a contiguous row range of `A` — the one
/// and only SpGEMM inner loop; the serial path runs it over `0..m`.
fn gustavson_rows(a: &CsrMatrix, b: &CsrMatrix, s: &dyn Semiring, rows: Range<usize>) -> RowChunk {
    let n = b.shape().1;
    let zero = s.zero();
    let mut mults = 0u64;

    // Dense accumulator row + touched-column list. `occupied` marks which
    // accumulator slots are live so nonstandard zeros (e.g. min-plus +inf)
    // need no sentinel trickery.
    let mut acc = vec![zero; n];
    let mut occupied = vec![false; n];
    let mut touched: Vec<u32> = Vec::new();

    let mut rel_indptr = Vec::with_capacity(rows.len());
    // (Measured: pre-reserving the output vectors gives <1% here — the
    // dense-accumulator inner loop dominates — so no size estimate.)
    let mut indices: Vec<u32> = Vec::new();
    let mut data: Vec<f64> = Vec::new();

    for i in rows {
        let (acols, avals) = a.row(i);
        for (kk, av) in acols.iter().zip(avals) {
            let (bcols, bvals) = b.row(*kk as usize);
            mults += bcols.len() as u64;
            for (c, bv) in bcols.iter().zip(bvals) {
                let prod = s.mul(*av, *bv);
                let ci = *c as usize;
                if occupied[ci] {
                    acc[ci] = s.add(acc[ci], prod);
                } else {
                    occupied[ci] = true;
                    acc[ci] = prod;
                    touched.push(*c);
                }
            }
        }
        // Emit the row in sorted column order and reset the scratch.
        touched.sort_unstable();
        for &c in &touched {
            let ci = c as usize;
            if acc[ci] != zero {
                indices.push(c);
                data.push(acc[ci]);
            }
            occupied[ci] = false;
            acc[ci] = zero;
        }
        touched.clear();
        rel_indptr.push(indices.len());
    }
    RowChunk { rel_indptr, indices, data, mults }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::semiring::{MaxMin, MaxPlus, MinPlus, PlusTimes, Semiring};
    use crate::sparse::CooMatrix;
    use crate::util::prop::check;
    use crate::util::SplitMix64;

    fn from_triples(m: usize, n: usize, t: &[(usize, usize, f64)]) -> CsrMatrix {
        let rows: Vec<usize> = t.iter().map(|x| x.0).collect();
        let cols: Vec<usize> = t.iter().map(|x| x.1).collect();
        let vals: Vec<f64> = t.iter().map(|x| x.2).collect();
        CooMatrix::from_triples_aggregate(m, n, &rows, &cols, &vals, 0.0, |a, b| a + b)
            .unwrap()
            .to_csr()
    }

    /// O(m·k·n) reference matmul over a semiring, via dense views.
    fn dense_matmul(a: &CsrMatrix, b: &CsrMatrix, s: &dyn Semiring) -> Vec<f64> {
        let (m, k) = a.shape();
        let (_, n) = b.shape();
        let mut out = vec![s.zero(); m * n];
        for i in 0..m {
            for kk in 0..k {
                let av = match a.get(i, kk) {
                    Some(v) => v,
                    None => continue,
                };
                for j in 0..n {
                    if let Some(bv) = b.get(kk, j) {
                        out[i * n + j] = s.add(out[i * n + j], s.mul(av, bv));
                    }
                }
            }
        }
        out
    }

    #[test]
    fn small_plus_times() {
        // [[1,2],[3,4]] @ [[1,1],[1,1]] = [[3,3],[7,7]]
        let a = from_triples(2, 2, &[(0, 0, 1.0), (0, 1, 2.0), (1, 0, 3.0), (1, 1, 4.0)]);
        let b = from_triples(2, 2, &[(0, 0, 1.0), (0, 1, 1.0), (1, 0, 1.0), (1, 1, 1.0)]);
        let c = spgemm(&a, &b, &PlusTimes).unwrap();
        assert_eq!(c.get(0, 0), Some(3.0));
        assert_eq!(c.get(0, 1), Some(3.0));
        assert_eq!(c.get(1, 0), Some(7.0));
        assert_eq!(c.get(1, 1), Some(7.0));
    }

    #[test]
    fn shape_mismatch_rejected() {
        let a = CsrMatrix::zeros(2, 3);
        let b = CsrMatrix::zeros(2, 2);
        assert!(spgemm(&a, &b, &PlusTimes).is_err());
    }

    #[test]
    fn rectangular_shapes() {
        let a = from_triples(2, 3, &[(0, 2, 2.0), (1, 0, 1.0)]);
        let b = from_triples(3, 4, &[(2, 3, 5.0), (0, 1, 7.0)]);
        let c = spgemm(&a, &b, &PlusTimes).unwrap();
        assert_eq!(c.shape(), (2, 4));
        assert_eq!(c.get(0, 3), Some(10.0));
        assert_eq!(c.get(1, 1), Some(7.0));
        assert_eq!(c.nnz(), 2);
    }

    #[test]
    fn min_plus_shortest_path_step() {
        // Path graph 0 -> 1 -> 2 with weights 2 and 3; A² under min-plus
        // gives the 2-hop distance 0 -> 2 = 5.
        let a = from_triples(3, 3, &[(0, 1, 2.0), (1, 2, 3.0)]);
        let c = spgemm(&a, &a, &MinPlus).unwrap();
        assert_eq!(c.get(0, 2), Some(5.0));
        assert_eq!(c.nnz(), 1);
    }

    #[test]
    fn max_min_bottleneck() {
        let a = from_triples(2, 2, &[(0, 0, 5.0), (0, 1, 2.0)]);
        let b = from_triples(2, 2, &[(0, 1, 3.0), (1, 1, 9.0)]);
        // C[0,1] = max(min(5,3), min(2,9)) = max(3, 2) = 3
        let c = spgemm(&a, &b, &MaxMin).unwrap();
        assert_eq!(c.get(0, 1), Some(3.0));
    }

    #[test]
    fn stats_count_mults() {
        let a = from_triples(2, 2, &[(0, 0, 1.0), (0, 1, 1.0)]);
        let b = from_triples(2, 2, &[(0, 0, 1.0), (0, 1, 1.0), (1, 0, 1.0), (1, 1, 1.0)]);
        let (_, stats) = spgemm_with_stats(&a, &b, &PlusTimes).unwrap();
        assert_eq!(stats.mults, 4); // row 0 of A hits both rows of B (2 nnz each)
    }

    #[test]
    fn empty_operands() {
        let a = CsrMatrix::zeros(3, 4);
        let b = CsrMatrix::zeros(4, 2);
        let c = spgemm(&a, &b, &PlusTimes).unwrap();
        assert_eq!(c.shape(), (3, 2));
        assert_eq!(c.nnz(), 0);
    }

    #[test]
    fn prop_matches_dense_reference_all_semirings() {
        check("spgemm == dense reference", 120, |g| {
            let m = 6;
            let k = 5;
            let n = 7;
            let mk_mat = |r: &mut SplitMix64, rows: usize, cols: usize| {
                let nnz = r.below_usize(rows * cols);
                let mut t = Vec::new();
                for _ in 0..nnz {
                    t.push((r.below_usize(rows), r.below_usize(cols), r.range_i64(1, 9) as f64));
                }
                from_triples(rows, cols, &t)
            };
            let a = mk_mat(g.rng(), m, k);
            let b = mk_mat(g.rng(), k, n);
            let semirings: Vec<Box<dyn Semiring>> = vec![
                Box::new(PlusTimes),
                Box::new(MaxPlus),
                Box::new(MinPlus),
                Box::new(MaxMin),
            ];
            for s in &semirings {
                let c = spgemm(&a, &b, s.as_ref()).unwrap();
                let expect = dense_matmul(&a, &b, s.as_ref());
                for i in 0..m {
                    for j in 0..n {
                        let got = c.get(i, j).unwrap_or(s.zero());
                        assert_eq!(
                            got,
                            expect[i * n + j],
                            "{} at ({i},{j})",
                            s.name()
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn prop_parallel_matches_serial_bitwise() {
        // The determinism contract: any thread count, same bytes. Runs
        // above PAR_MIN_ROWS so the fan-out actually happens.
        check("spgemm_par == spgemm serial", 20, |g| {
            let m = 200;
            let k = 64;
            let n = 96;
            let mk_mat = |r: &mut SplitMix64, rows: usize, cols: usize, nnz: usize| {
                let mut t = Vec::new();
                for _ in 0..nnz {
                    t.push((r.below_usize(rows), r.below_usize(cols), r.range_i64(1, 9) as f64));
                }
                from_triples(rows, cols, &t)
            };
            let a = mk_mat(g.rng(), m, k, 800);
            let b = mk_mat(g.rng(), k, n, 500);
            for s in [&PlusTimes as &dyn Semiring, &MaxPlus, &MinPlus, &MaxMin] {
                let (serial, st1) =
                    spgemm_with_stats_par(&a, &b, s, Parallelism::serial()).unwrap();
                for threads in [2, 4, 7] {
                    let (par, st2) =
                        spgemm_with_stats_par(&a, &b, s, Parallelism::with_threads(threads))
                            .unwrap();
                    assert_eq!(serial, par, "{} at {threads} threads", s.name());
                    assert_eq!(st1.mults, st2.mults);
                    assert_eq!(st1.out_nnz, st2.out_nnz);
                }
            }
        });
    }

    #[test]
    fn prop_associativity_on_binary_matrices() {
        // (A@B)@C == A@(B@C) for 0/1 matrices under plus-times (exact in f64).
        check("spgemm associative", 60, |g| {
            let n = 5;
            let mk = |r: &mut SplitMix64| {
                let mut t = Vec::new();
                for i in 0..n {
                    for j in 0..n {
                        if r.chance(0.3) {
                            t.push((i, j, 1.0));
                        }
                    }
                }
                from_triples(n, n, &t)
            };
            let a = mk(g.rng());
            let b = mk(g.rng());
            let c = mk(g.rng());
            let left = spgemm(&spgemm(&a, &b, &PlusTimes).unwrap(), &c, &PlusTimes).unwrap();
            let right = spgemm(&a, &spgemm(&b, &c, &PlusTimes).unwrap(), &PlusTimes).unwrap();
            assert_eq!(left, right);
        });
    }
}
