//! Sparse general matrix-matrix multiply (SpGEMM) over a semiring —
//! the adaptive two-phase engine behind `A @ B` (paper §II.C.3).
//!
//! **Phase 1 (symbolic).** One O(nnz(A)) pass computes, per output row,
//! the flop count `f(i) = Σ_{k ∈ A[i,:]} nnz(B[k,:])` — simultaneously
//! an exact ⊗ count, an upper bound `min(f, ncols)` on the row's output
//! size, and the work weight used to balance parallel chunks. The
//! numeric phase allocates each chunk's output from the summed bound up
//! front, so output vectors never grow mid-kernel.
//!
//! **Phase 2 (numeric).** Gustavson's row-wise algorithm, with the
//! accumulator chosen **per row** from the symbolic density estimate
//! (associative-array workloads are hypersparse — Julia D4M
//! arXiv:1608.04041, D4M 3.0 arXiv:1702.03253 — so a one-size dense
//! scratch row wastes O(ncols) memory traffic on most rows):
//!
//! * **copy** — rows with a single stored `A` entry (the hypersparse
//!   common case) are a scaled copy of one `B` row: no accumulator at
//!   all.
//! * **sort** — rows with at most [`SORT_MAX_FLOPS`] products collect
//!   `(col, product)` pairs and combine them with one small sort.
//! * **hash** — sparse rows (`f · 8 < ncols`) scatter into an
//!   open-addressing table sized from the symbolic bound (load ≤ ½, so
//!   probes terminate and the table never rehashes mid-row).
//! * **dense** — dense-ish rows keep PR 1's dense scratch row +
//!   touched list (reset in O(touched)); the scratch is allocated
//!   lazily, so hypersparse inputs never pay the O(ncols) footprint.
//!
//! All scratch is reused across rows within a worker. The policy can be
//! forced via [`AccumulatorPolicy`] ([`spgemm_with_policy_par`]) — the
//! ablation benches pin [`AccumulatorPolicy::Dense`] to measure against
//! the PR 1 kernel, and the equivalence suite cross-checks every
//! policy.
//!
//! **Masked multiply.** [`spgemm_masked_par`] computes only the output
//! columns a caller's mask keeps — the Graphulo `TableMult`-with-sink-
//! filter pattern, where a multiply writing into a filtered table
//! should never compute the cells the sink drops. Output column `j`
//! depends only on column `j` of `B`, so the mask is applied to `B`'s
//! stored structure in a single O(nnz(B)) pass before the two phases
//! run: the symbolic pass then counts zero flops for excluded columns,
//! the per-chunk allocation bounds shrink to the masked output, and the
//! numeric inner loops never see an excluded entry. (Testing the bitmap
//! inside the inner loops instead would pay one branch per *flop* —
//! once per `A`-row touching the entry — rather than once per stored
//! `B` entry.) Because each surviving column's ⊗/⊕ order is untouched,
//! the masked product is **bit-identical** to computing the full
//! product and dropping the masked-out columns, at ~`mask density` of
//! the flops and allocation; `tests/parallel_equivalence.rs` enforces
//! this across semirings, thread counts, and policies.
//!
//! [`spgemm_row_masked_par`] is the row twin: output row `i` depends
//! only on row `i` of `A`, so restricting output rows is one O(nnz(A))
//! pass that empties the masked-out rows of `A` before the phases run —
//! excluded rows then cost zero flops and zero allocation (their
//! symbolic flop count is zero, so the numeric phase skips them
//! outright), and surviving rows are computed by the byte-identical
//! code path. Both masks compose in [`crate::graphulo`]: the column
//! mask serves sink-filtered output *columns*, the row mask
//! sink-filtered output *rows*.
//!
//! **Determinism.** Within a row, every accumulator combines the
//! products of a given output column in identical ⊗-traversal order
//! (the order `A[i,:]` walks `B`'s rows), and rows are emitted in
//! sorted column order — so all policies, and every thread count, are
//! **bit-identical** to the serial dense path. Chunk boundaries depend
//! only on the input and `threads` (flop-weighted), and chunk outputs
//! are stitched in row order; `tests/parallel_equivalence.rs` enforces
//! the contract across policies, thread counts, semirings, and
//! adversarial (hypersparse / power-law / empty-band) shapes.

use super::{CsrMatrix, SparseError};
use crate::semiring::Semiring;
use crate::util::parallel::{parallel_map_ranges, Parallelism};
use std::ops::Range;

/// Instrumentation from one SpGEMM call (used by the perf harness).
#[derive(Debug, Clone, Default)]
pub struct SpGemmStats {
    /// Number of `⊗` (multiply) operations performed.
    pub mults: u64,
    /// Stored entries in the output.
    pub out_nnz: usize,
    /// Rows handled by the single-entry copy path.
    pub rows_copy: usize,
    /// Rows handled by the sort accumulator.
    pub rows_sort: usize,
    /// Rows handled by the hash accumulator.
    pub rows_hash: usize,
    /// Rows handled by the dense scratch row.
    pub rows_dense: usize,
    /// Total output entries *allocated* from the symbolic bound (summed
    /// over chunks). Under [`SymbolicBound::MinFlopsCols`] this is
    /// `Σ min(flops, ncols)`; under [`SymbolicBound::Exact`] it is the
    /// true distinct-column count — the allocation-savings witness for
    /// extreme-skew rows.
    pub alloc_bound: usize,
}

/// Accumulator selection for the numeric phase. [`Adaptive`] picks per
/// row from the symbolic flop/density estimate; the forced variants pin
/// one accumulator for every row (benchmarks and the equivalence suite
/// — all variants produce bit-identical output).
///
/// [`Adaptive`]: AccumulatorPolicy::Adaptive
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AccumulatorPolicy {
    /// Per-row selection (copy / sort / hash / dense) — the default.
    #[default]
    Adaptive,
    /// Dense scratch row for every row (the PR 1 kernel).
    Dense,
    /// Sort accumulator for every row.
    Sort,
    /// Hash accumulator for every row.
    Hash,
}

/// How the symbolic phase bounds each row's output size (the numeric
/// phase allocates its chunk buffers from this bound and never grows
/// them). Purely an allocation decision: every variant produces
/// bit-identical output, so the planner may select freely.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SymbolicBound {
    /// `min(flops, ncols)` per row — one pass over `A`, O(nnz(A)). The
    /// default; loose on extreme-skew rows where many products collide
    /// on few distinct columns.
    #[default]
    MinFlopsCols,
    /// Exact distinct-column count per row — a second symbolic pass
    /// over the products (O(flops) with a generation-stamped mark
    /// table), paying compute to allocate exactly. Worth it when the
    /// loose bound would overallocate badly (power-law column skew).
    Exact,
    /// Run the cheap pass, then upgrade to [`SymbolicBound::Exact`]
    /// when the loose bound exceeds twice the input size — the
    /// overallocation regime where the exact pass pays for itself.
    Auto,
}

/// Rows whose flop count is at most this use the sort accumulator under
/// [`AccumulatorPolicy::Adaptive`] (a handful of products combine
/// faster in a small sorted list than through any table).
pub const SORT_MAX_FLOPS: usize = 32;

/// Under [`AccumulatorPolicy::Adaptive`], rows with
/// `flops * HASH_DENSITY_FACTOR < ncols` (and more than
/// [`SORT_MAX_FLOPS`] flops) use the hash accumulator; denser rows use
/// the dense scratch.
pub const HASH_DENSITY_FACTOR: usize = 8;

/// `C = A ⊗.⊕ B` over semiring `s`, at the process-default parallelism.
/// Shapes must contract: `(m × k) @ (k × n) → (m × n)`.
pub fn spgemm(a: &CsrMatrix, b: &CsrMatrix, s: &dyn Semiring) -> Result<CsrMatrix, SparseError> {
    spgemm_par(a, b, s, Parallelism::current())
}

/// [`spgemm`] with an explicit thread configuration. `threads == 1` is
/// the exact serial code path; any other count produces a bit-identical
/// result (see the module docs).
pub fn spgemm_par(
    a: &CsrMatrix,
    b: &CsrMatrix,
    s: &dyn Semiring,
    par: Parallelism,
) -> Result<CsrMatrix, SparseError> {
    spgemm_with_stats_par(a, b, s, par).map(|(c, _)| c)
}

/// [`spgemm`] with operation counts, at the process-default parallelism.
pub fn spgemm_with_stats(
    a: &CsrMatrix,
    b: &CsrMatrix,
    s: &dyn Semiring,
) -> Result<(CsrMatrix, SpGemmStats), SparseError> {
    spgemm_with_stats_par(a, b, s, Parallelism::current())
}

/// [`spgemm_par`] with operation counts (adaptive accumulator policy).
pub fn spgemm_with_stats_par(
    a: &CsrMatrix,
    b: &CsrMatrix,
    s: &dyn Semiring,
    par: Parallelism,
) -> Result<(CsrMatrix, SpGemmStats), SparseError> {
    spgemm_with_policy_par(a, b, s, par, AccumulatorPolicy::Adaptive)
}

/// Column-masked SpGEMM at the process-default parallelism: compute
/// only the output columns with `mask[j] == true`. See the module docs
/// for the contract (bit-identical to multiply-then-drop, ~mask-density
/// flops).
pub fn spgemm_masked(
    a: &CsrMatrix,
    b: &CsrMatrix,
    s: &dyn Semiring,
    mask: &[bool],
) -> Result<CsrMatrix, SparseError> {
    spgemm_masked_par(a, b, s, Parallelism::current(), mask)
}

/// [`spgemm_masked`] with an explicit thread configuration.
pub fn spgemm_masked_par(
    a: &CsrMatrix,
    b: &CsrMatrix,
    s: &dyn Semiring,
    par: Parallelism,
    mask: &[bool],
) -> Result<CsrMatrix, SparseError> {
    spgemm_masked_with_stats_par(a, b, s, par, mask).map(|(c, _)| c)
}

/// [`spgemm_masked_par`] with operation counts. `stats.mults` counts
/// only the surviving (mask-true) flops — the work-saved witness the
/// benches record. `mask.len()` must equal `B`'s column count.
pub fn spgemm_masked_with_stats_par(
    a: &CsrMatrix,
    b: &CsrMatrix,
    s: &dyn Semiring,
    par: Parallelism,
    mask: &[bool],
) -> Result<(CsrMatrix, SpGemmStats), SparseError> {
    spgemm_masked_with_modes_par(a, b, s, par, mask, Default::default(), Default::default())
}

/// [`spgemm_masked_with_stats_par`] with explicit physical knobs
/// ([`AccumulatorPolicy`] + [`SymbolicBound`]) — the planner-facing
/// masked entry point. Bit-identical across every knob combination.
pub fn spgemm_masked_with_modes_par(
    a: &CsrMatrix,
    b: &CsrMatrix,
    s: &dyn Semiring,
    par: Parallelism,
    mask: &[bool],
    policy: AccumulatorPolicy,
    bound: SymbolicBound,
) -> Result<(CsrMatrix, SpGemmStats), SparseError> {
    let n = b.shape().1;
    if mask.len() != n {
        return Err(SparseError::MaskLengthMismatch { mask: mask.len(), extent: n, axis: "column" });
    }
    if mask.iter().all(|&keep| keep) {
        // Degenerate mask: nothing to restrict, skip the copy.
        return spgemm_with_modes_par(a, b, s, par, policy, bound);
    }
    let bm = restrict_cols(b, mask);
    spgemm_with_modes_par(a, &bm, s, par, policy, bound)
}

/// Row-masked SpGEMM at the process-default parallelism: compute only
/// the output rows with `mask[i] == true` — the twin of
/// [`spgemm_masked`] for sink filters over the *row* key space. See the
/// module docs for the contract (bit-identical to multiply-then-drop
/// rows, zero flops for excluded rows).
pub fn spgemm_row_masked(
    a: &CsrMatrix,
    b: &CsrMatrix,
    s: &dyn Semiring,
    mask: &[bool],
) -> Result<CsrMatrix, SparseError> {
    spgemm_row_masked_par(a, b, s, Parallelism::current(), mask)
}

/// [`spgemm_row_masked`] with an explicit thread configuration.
pub fn spgemm_row_masked_par(
    a: &CsrMatrix,
    b: &CsrMatrix,
    s: &dyn Semiring,
    par: Parallelism,
    mask: &[bool],
) -> Result<CsrMatrix, SparseError> {
    spgemm_row_masked_with_stats_par(a, b, s, par, mask).map(|(c, _)| c)
}

/// [`spgemm_row_masked_par`] with operation counts. `stats.mults`
/// counts only the surviving (mask-true) rows' flops. `mask.len()` must
/// equal `A`'s row count.
pub fn spgemm_row_masked_with_stats_par(
    a: &CsrMatrix,
    b: &CsrMatrix,
    s: &dyn Semiring,
    par: Parallelism,
    mask: &[bool],
) -> Result<(CsrMatrix, SpGemmStats), SparseError> {
    spgemm_row_masked_with_modes_par(a, b, s, par, mask, Default::default(), Default::default())
}

/// [`spgemm_row_masked_with_stats_par`] with explicit physical knobs
/// ([`AccumulatorPolicy`] + [`SymbolicBound`]) — the planner-facing
/// row-masked entry point. Bit-identical across every knob combination.
pub fn spgemm_row_masked_with_modes_par(
    a: &CsrMatrix,
    b: &CsrMatrix,
    s: &dyn Semiring,
    par: Parallelism,
    mask: &[bool],
    policy: AccumulatorPolicy,
    bound: SymbolicBound,
) -> Result<(CsrMatrix, SpGemmStats), SparseError> {
    let m = a.shape().0;
    if mask.len() != m {
        return Err(SparseError::MaskLengthMismatch { mask: mask.len(), extent: m, axis: "row" });
    }
    if mask.iter().all(|&keep| keep) {
        return spgemm_with_modes_par(a, b, s, par, policy, bound);
    }
    let am = restrict_rows(a, mask);
    spgemm_with_modes_par(&am, b, s, par, policy, bound)
}

/// `A` restricted to mask-true rows: same shape, masked-out rows
/// emptied (their `indptr` span collapses). One pass, O(nnz(A)); the
/// symbolic phase then assigns excluded rows zero flops, so they cost
/// nothing downstream.
fn restrict_rows(a: &CsrMatrix, mask: &[bool]) -> CsrMatrix {
    let (m, n) = a.shape();
    let (aptr, aidx, aval) = (a.indptr(), a.indices(), a.values());
    let keep: usize = (0..m).filter(|&r| mask[r]).map(|r| aptr[r + 1] - aptr[r]).sum();
    let mut indptr = Vec::with_capacity(m + 1);
    indptr.push(0usize);
    let mut indices: Vec<u32> = Vec::with_capacity(keep);
    let mut data: Vec<f64> = Vec::with_capacity(keep);
    for r in 0..m {
        if mask[r] {
            indices.extend_from_slice(&aidx[aptr[r]..aptr[r + 1]]);
            data.extend_from_slice(&aval[aptr[r]..aptr[r + 1]]);
        }
        indptr.push(indices.len());
    }
    CsrMatrix::from_parts(m, n, indptr, indices, data)
}

/// `B` restricted to mask-true columns: same shape, same column
/// indices, excluded entries dropped. One counting pass sizes the
/// output exactly; O(nnz(B)) total.
fn restrict_cols(b: &CsrMatrix, mask: &[bool]) -> CsrMatrix {
    let (k, n) = b.shape();
    let (bptr, bidx, bval) = (b.indptr(), b.indices(), b.values());
    let keep = bidx.iter().filter(|&&c| mask[c as usize]).count();
    let mut indptr = Vec::with_capacity(k + 1);
    indptr.push(0usize);
    let mut indices: Vec<u32> = Vec::with_capacity(keep);
    let mut data: Vec<f64> = Vec::with_capacity(keep);
    for r in 0..k {
        for p in bptr[r]..bptr[r + 1] {
            let c = bidx[p];
            if mask[c as usize] {
                indices.push(c);
                data.push(bval[p]);
            }
        }
        indptr.push(indices.len());
    }
    CsrMatrix::from_parts(k, n, indptr, indices, data)
}

/// Rows below this count are not worth a fan-out (pool dispatch costs
/// more than the row work saved).
const PAR_MIN_ROWS: usize = 64;

/// [`spgemm_par`] with an explicit [`AccumulatorPolicy`] (and the
/// default [`SymbolicBound`]). Every policy yields bit-identical
/// output; the forced variants exist for benchmarking and
/// cross-checking.
pub fn spgemm_with_policy_par(
    a: &CsrMatrix,
    b: &CsrMatrix,
    s: &dyn Semiring,
    par: Parallelism,
    policy: AccumulatorPolicy,
) -> Result<(CsrMatrix, SpGemmStats), SparseError> {
    spgemm_with_modes_par(a, b, s, par, policy, SymbolicBound::default())
}

/// The full engine entry point: [`spgemm_par`] with an explicit
/// [`AccumulatorPolicy`] *and* [`SymbolicBound`] — the two physical
/// knobs the query planner selects. Every combination yields
/// bit-identical output: the accumulator changes only the combine
/// order bookkeeping (see the module docs) and the bound changes only
/// allocation sizes.
pub fn spgemm_with_modes_par(
    a: &CsrMatrix,
    b: &CsrMatrix,
    s: &dyn Semiring,
    par: Parallelism,
    policy: AccumulatorPolicy,
    bound: SymbolicBound,
) -> Result<(CsrMatrix, SpGemmStats), SparseError> {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    if ka != kb {
        return Err(SparseError::ShapeMismatch { left: a.shape(), right: b.shape(), op: "spgemm" });
    }

    // Symbolic phase: per-row flop counts and output-size bounds.
    let (cum_flops, cum_bound) = symbolic(a, b);
    let exact = match bound {
        SymbolicBound::MinFlopsCols => false,
        SymbolicBound::Exact => true,
        // Upgrade when the loose bound would allocate more than twice
        // the input size — the skew regime where a second O(flops)
        // pass is cheaper than the wasted allocation + zero-fill.
        SymbolicBound::Auto => cum_bound[m] > 2 * (a.nnz() + b.nnz()),
    };
    let cum_bound = if exact { symbolic_exact(a, b) } else { cum_bound };

    let parts: Vec<RowChunk> = if par.is_serial() || m < PAR_MIN_ROWS {
        vec![numeric_rows(a, b, s, 0..m, &cum_flops, &cum_bound, policy)]
    } else {
        // Chunk boundaries balanced by the symbolic flop counts (a pure
        // function of the input and `threads`, so the stitched output
        // is deterministic).
        let ranges = par.chunk_ranges_weighted(&cum_flops);
        parallel_map_ranges(ranges, |rows| {
            numeric_rows(a, b, s, rows, &cum_flops, &cum_bound, policy)
        })
    };

    // Stitch chunk outputs in row order.
    let total: usize = parts.iter().map(|p| p.indices.len()).sum();
    let mut indptr = Vec::with_capacity(m + 1);
    indptr.push(0usize);
    let mut indices: Vec<u32> = Vec::with_capacity(total);
    let mut data: Vec<f64> = Vec::with_capacity(total);
    let mut stats = SpGemmStats::default();
    for part in parts {
        let base = indices.len();
        indptr.extend(part.rel_indptr.into_iter().map(|e| base + e));
        indices.extend_from_slice(&part.indices);
        data.extend_from_slice(&part.data);
        stats.mults += part.stats.mults;
        stats.rows_copy += part.stats.rows_copy;
        stats.rows_sort += part.stats.rows_sort;
        stats.rows_hash += part.stats.rows_hash;
        stats.rows_dense += part.stats.rows_dense;
        stats.alloc_bound += part.stats.alloc_bound;
    }
    stats.out_nnz = data.len();
    Ok((CsrMatrix::from_parts(m, n, indptr, indices, data), stats))
}

/// Symbolic pass: `cum_flops[i]` = total products of rows `0..i`, and
/// `cum_bound[i]` = total output-size upper bound `Σ min(f, ncols)` of
/// rows `0..i` — both cumulative so chunk weights and chunk allocation
/// sizes are O(1) range differences.
fn symbolic(a: &CsrMatrix, b: &CsrMatrix) -> (Vec<usize>, Vec<usize>) {
    let m = a.shape().0;
    let n = b.shape().1;
    let bptr = b.indptr();
    let mut cum_flops = Vec::with_capacity(m + 1);
    let mut cum_bound = Vec::with_capacity(m + 1);
    cum_flops.push(0usize);
    cum_bound.push(0usize);
    let (mut tf, mut tb) = (0usize, 0usize);
    for r in 0..m {
        let (acols, _) = a.row(r);
        let f: usize = acols.iter().map(|&k| bptr[k as usize + 1] - bptr[k as usize]).sum();
        tf += f;
        tb += f.min(n);
        cum_flops.push(tf);
        cum_bound.push(tb);
    }
    (cum_flops, cum_bound)
}

/// Exact symbolic pass ([`SymbolicBound::Exact`]): `cum[i]` = total
/// *distinct* output columns of rows `0..i`. O(flops) via a
/// generation-stamped mark table — each row stamps the columns it
/// touches with its own row index, so the table never needs clearing.
/// Row indices never reach `u32::MAX` (extents are capped there), so
/// the initial sentinel can't collide with a stamp.
fn symbolic_exact(a: &CsrMatrix, b: &CsrMatrix) -> Vec<usize> {
    let m = a.shape().0;
    let n = b.shape().1;
    let (bptr, bidx) = (b.indptr(), b.indices());
    let mut mark: Vec<u32> = vec![u32::MAX; n];
    let mut cum = Vec::with_capacity(m + 1);
    cum.push(0usize);
    let mut total = 0usize;
    for r in 0..m {
        let (acols, _) = a.row(r);
        let stamp = r as u32;
        for &k in acols {
            for &c in &bidx[bptr[k as usize]..bptr[k as usize + 1]] {
                if mark[c as usize] != stamp {
                    mark[c as usize] = stamp;
                    total += 1;
                }
            }
        }
        cum.push(total);
    }
    cum
}

/// Output of [`numeric_rows`] for one contiguous row range.
struct RowChunk {
    /// `rel_indptr[j]` = entries emitted after finishing the range's
    /// `j`-th row (no leading 0; offset by the stitch base).
    rel_indptr: Vec<usize>,
    indices: Vec<u32>,
    data: Vec<f64>,
    stats: SpGemmStats,
}

/// Which accumulator a row runs on.
#[derive(Clone, Copy, PartialEq, Eq)]
enum RowKind {
    Copy,
    Sort,
    Hash,
    Dense,
}

/// Per-worker scratch, reused across rows within a chunk. Everything is
/// allocated lazily (and the dense scratch only on the first dense
/// row), so hypersparse chunks never touch O(ncols) memory.
struct Scratch {
    // Dense accumulator row + touched-column list. `occupied` marks
    // which slots are live so nonstandard zeros (e.g. min-plus +inf)
    // need no sentinel trickery.
    acc: Vec<f64>,
    occupied: Vec<bool>,
    touched: Vec<u32>,
    // Open-addressing hash accumulator: `hkeys[slot] == u32::MAX` means
    // empty (valid: column indices never exceed `u32::MAX - 1` because
    // extents are capped at `u32::MAX`). `hslots` records used slots in
    // insertion order for O(touched) clearing.
    hkeys: Vec<u32>,
    hvals: Vec<f64>,
    hslots: Vec<u32>,
    hemit: Vec<(u32, u32)>,
    // Sort accumulator: `(col << 32 | seq, product)` — the sequence
    // number makes the unstable sort order-preserving per column.
    items: Vec<(u64, f64)>,
}

impl Scratch {
    fn new() -> Scratch {
        Scratch {
            acc: Vec::new(),
            occupied: Vec::new(),
            touched: Vec::new(),
            hkeys: Vec::new(),
            hvals: Vec::new(),
            hslots: Vec::new(),
            hemit: Vec::new(),
            items: Vec::new(),
        }
    }

    /// Grow the dense scratch to `n` columns (first dense row only).
    fn ensure_dense(&mut self, n: usize, zero: f64) {
        if self.acc.len() < n {
            self.acc = vec![zero; n];
            self.occupied = vec![false; n];
        }
    }

    /// Size the hash table for a row with at most `bound` distinct
    /// columns, keeping load ≤ ½ so probe chains terminate without
    /// rehashing. Growing only happens between rows, when the table is
    /// empty.
    fn ensure_hash(&mut self, bound: usize) {
        let want = (2 * bound.max(1)).next_power_of_two();
        if self.hkeys.len() < want {
            self.hkeys = vec![u32::MAX; want];
            self.hvals = vec![0.0; want];
        }
    }
}

/// The numeric phase over a contiguous row range of `A` — the serial
/// path runs it over `0..m`. Output vectors are allocated once from the
/// symbolic bound and never grow (debug-asserted).
fn numeric_rows(
    a: &CsrMatrix,
    b: &CsrMatrix,
    s: &dyn Semiring,
    rows: Range<usize>,
    cum_flops: &[usize],
    cum_bound: &[usize],
    policy: AccumulatorPolicy,
) -> RowChunk {
    let n = b.shape().1;
    let zero = s.zero();
    let mut stats = SpGemmStats::default();
    let mut scratch = Scratch::new();

    let cap = cum_bound[rows.end] - cum_bound[rows.start];
    stats.alloc_bound = cap;
    let mut rel_indptr = Vec::with_capacity(rows.len());
    let mut indices: Vec<u32> = Vec::with_capacity(cap);
    let mut data: Vec<f64> = Vec::with_capacity(cap);

    for i in rows {
        let flops = cum_flops[i + 1] - cum_flops[i];
        if flops == 0 {
            rel_indptr.push(indices.len());
            continue;
        }
        let (acols, avals) = a.row(i);
        let kind = match policy {
            AccumulatorPolicy::Dense => RowKind::Dense,
            AccumulatorPolicy::Sort => RowKind::Sort,
            AccumulatorPolicy::Hash => RowKind::Hash,
            AccumulatorPolicy::Adaptive => {
                if acols.len() == 1 {
                    RowKind::Copy
                } else if flops <= SORT_MAX_FLOPS {
                    RowKind::Sort
                } else if flops.saturating_mul(HASH_DENSITY_FACTOR) < n {
                    RowKind::Hash
                } else {
                    RowKind::Dense
                }
            }
        };
        stats.mults += flops as u64;
        match kind {
            RowKind::Copy => {
                stats.rows_copy += 1;
                // One stored A entry: the row is a scaled copy of one B
                // row, already in sorted column order.
                let av = avals[0];
                let (bcols, bvals) = b.row(acols[0] as usize);
                for (c, bv) in bcols.iter().zip(bvals) {
                    let prod = s.mul(av, *bv);
                    if prod != zero {
                        indices.push(*c);
                        data.push(prod);
                    }
                }
            }
            RowKind::Sort => {
                stats.rows_sort += 1;
                scratch.items.clear();
                let mut seq = 0u32;
                for (kk, av) in acols.iter().zip(avals) {
                    let (bcols, bvals) = b.row(*kk as usize);
                    for (c, bv) in bcols.iter().zip(bvals) {
                        scratch.items.push((((*c as u64) << 32) | seq as u64, s.mul(*av, *bv)));
                        seq = seq.wrapping_add(1);
                    }
                }
                // The seq suffix makes keys unique, so the unstable sort
                // preserves ⊗-traversal order within each column.
                scratch.items.sort_unstable_by_key(|e| e.0);
                let mut p = 0usize;
                while p < scratch.items.len() {
                    let col = (scratch.items[p].0 >> 32) as u32;
                    let mut acc = scratch.items[p].1;
                    p += 1;
                    while p < scratch.items.len() && (scratch.items[p].0 >> 32) as u32 == col {
                        acc = s.add(acc, scratch.items[p].1);
                        p += 1;
                    }
                    if acc != zero {
                        indices.push(col);
                        data.push(acc);
                    }
                }
            }
            RowKind::Hash => {
                stats.rows_hash += 1;
                scratch.ensure_hash(flops.min(n));
                let mask = scratch.hkeys.len() - 1;
                for (kk, av) in acols.iter().zip(avals) {
                    let (bcols, bvals) = b.row(*kk as usize);
                    for (c, bv) in bcols.iter().zip(bvals) {
                        let prod = s.mul(*av, *bv);
                        let mut slot =
                            ((*c as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize & mask;
                        loop {
                            let key = scratch.hkeys[slot];
                            if key == *c {
                                scratch.hvals[slot] = s.add(scratch.hvals[slot], prod);
                                break;
                            }
                            if key == u32::MAX {
                                scratch.hkeys[slot] = *c;
                                scratch.hvals[slot] = prod;
                                scratch.hslots.push(slot as u32);
                                break;
                            }
                            slot = (slot + 1) & mask;
                        }
                    }
                }
                // Emit in sorted column order and clear the used slots.
                scratch.hemit.clear();
                for &slot in &scratch.hslots {
                    scratch.hemit.push((scratch.hkeys[slot as usize], slot));
                }
                scratch.hemit.sort_unstable();
                for &(c, slot) in &scratch.hemit {
                    let v = scratch.hvals[slot as usize];
                    if v != zero {
                        indices.push(c);
                        data.push(v);
                    }
                    scratch.hkeys[slot as usize] = u32::MAX;
                }
                scratch.hslots.clear();
            }
            RowKind::Dense => {
                stats.rows_dense += 1;
                scratch.ensure_dense(n, zero);
                for (kk, av) in acols.iter().zip(avals) {
                    let (bcols, bvals) = b.row(*kk as usize);
                    for (c, bv) in bcols.iter().zip(bvals) {
                        let prod = s.mul(*av, *bv);
                        let ci = *c as usize;
                        if scratch.occupied[ci] {
                            scratch.acc[ci] = s.add(scratch.acc[ci], prod);
                        } else {
                            scratch.occupied[ci] = true;
                            scratch.acc[ci] = prod;
                            scratch.touched.push(*c);
                        }
                    }
                }
                // Emit in sorted column order and reset the scratch.
                scratch.touched.sort_unstable();
                for &c in &scratch.touched {
                    let ci = c as usize;
                    if scratch.acc[ci] != zero {
                        indices.push(c);
                        data.push(scratch.acc[ci]);
                    }
                    scratch.occupied[ci] = false;
                    scratch.acc[ci] = zero;
                }
                scratch.touched.clear();
            }
        }
        rel_indptr.push(indices.len());
    }
    debug_assert!(indices.len() <= cap, "symbolic output bound violated");
    RowChunk { rel_indptr, indices, data, stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::semiring::{MaxMin, MaxPlus, MinPlus, PlusTimes, Semiring};
    use crate::sparse::CooMatrix;
    use crate::util::prop::check;
    use crate::util::SplitMix64;

    fn from_triples(m: usize, n: usize, t: &[(usize, usize, f64)]) -> CsrMatrix {
        let rows: Vec<usize> = t.iter().map(|x| x.0).collect();
        let cols: Vec<usize> = t.iter().map(|x| x.1).collect();
        let vals: Vec<f64> = t.iter().map(|x| x.2).collect();
        CooMatrix::from_triples_aggregate(m, n, &rows, &cols, &vals, 0.0, |a, b| a + b)
            .unwrap()
            .to_csr()
    }

    /// Structural + raw-bit equality (catches `-0.0` vs `0.0` drift
    /// that `f64` equality would hide).
    fn assert_bits_equal(x: &CsrMatrix, y: &CsrMatrix, ctx: &str) {
        assert_eq!(x.shape(), y.shape(), "{ctx}: shape");
        assert_eq!(x.indptr(), y.indptr(), "{ctx}: indptr");
        assert_eq!(x.indices(), y.indices(), "{ctx}: indices");
        let xb: Vec<u64> = x.values().iter().map(|v| v.to_bits()).collect();
        let yb: Vec<u64> = y.values().iter().map(|v| v.to_bits()).collect();
        assert_eq!(xb, yb, "{ctx}: value bits");
    }

    /// O(m·k·n) reference matmul over a semiring, via dense views.
    fn dense_matmul(a: &CsrMatrix, b: &CsrMatrix, s: &dyn Semiring) -> Vec<f64> {
        let (m, k) = a.shape();
        let (_, n) = b.shape();
        let mut out = vec![s.zero(); m * n];
        for i in 0..m {
            for kk in 0..k {
                let av = match a.get(i, kk) {
                    Some(v) => v,
                    None => continue,
                };
                for j in 0..n {
                    if let Some(bv) = b.get(kk, j) {
                        out[i * n + j] = s.add(out[i * n + j], s.mul(av, bv));
                    }
                }
            }
        }
        out
    }

    #[test]
    fn small_plus_times() {
        // [[1,2],[3,4]] @ [[1,1],[1,1]] = [[3,3],[7,7]]
        let a = from_triples(2, 2, &[(0, 0, 1.0), (0, 1, 2.0), (1, 0, 3.0), (1, 1, 4.0)]);
        let b = from_triples(2, 2, &[(0, 0, 1.0), (0, 1, 1.0), (1, 0, 1.0), (1, 1, 1.0)]);
        let c = spgemm(&a, &b, &PlusTimes).unwrap();
        assert_eq!(c.get(0, 0), Some(3.0));
        assert_eq!(c.get(0, 1), Some(3.0));
        assert_eq!(c.get(1, 0), Some(7.0));
        assert_eq!(c.get(1, 1), Some(7.0));
    }

    #[test]
    fn shape_mismatch_rejected() {
        let a = CsrMatrix::zeros(2, 3);
        let b = CsrMatrix::zeros(2, 2);
        assert!(spgemm(&a, &b, &PlusTimes).is_err());
    }

    #[test]
    fn rectangular_shapes() {
        let a = from_triples(2, 3, &[(0, 2, 2.0), (1, 0, 1.0)]);
        let b = from_triples(3, 4, &[(2, 3, 5.0), (0, 1, 7.0)]);
        let c = spgemm(&a, &b, &PlusTimes).unwrap();
        assert_eq!(c.shape(), (2, 4));
        assert_eq!(c.get(0, 3), Some(10.0));
        assert_eq!(c.get(1, 1), Some(7.0));
        assert_eq!(c.nnz(), 2);
    }

    #[test]
    fn min_plus_shortest_path_step() {
        // Path graph 0 -> 1 -> 2 with weights 2 and 3; A² under min-plus
        // gives the 2-hop distance 0 -> 2 = 5.
        let a = from_triples(3, 3, &[(0, 1, 2.0), (1, 2, 3.0)]);
        let c = spgemm(&a, &a, &MinPlus).unwrap();
        assert_eq!(c.get(0, 2), Some(5.0));
        assert_eq!(c.nnz(), 1);
    }

    #[test]
    fn max_min_bottleneck() {
        let a = from_triples(2, 2, &[(0, 0, 5.0), (0, 1, 2.0)]);
        let b = from_triples(2, 2, &[(0, 1, 3.0), (1, 1, 9.0)]);
        // C[0,1] = max(min(5,3), min(2,9)) = max(3, 2) = 3
        let c = spgemm(&a, &b, &MaxMin).unwrap();
        assert_eq!(c.get(0, 1), Some(3.0));
    }

    #[test]
    fn stats_count_mults() {
        let a = from_triples(2, 2, &[(0, 0, 1.0), (0, 1, 1.0)]);
        let b = from_triples(2, 2, &[(0, 0, 1.0), (0, 1, 1.0), (1, 0, 1.0), (1, 1, 1.0)]);
        let (_, stats) = spgemm_with_stats(&a, &b, &PlusTimes).unwrap();
        assert_eq!(stats.mults, 4); // row 0 of A hits both rows of B (2 nnz each)
    }

    #[test]
    fn empty_operands() {
        let a = CsrMatrix::zeros(3, 4);
        let b = CsrMatrix::zeros(4, 2);
        let c = spgemm(&a, &b, &PlusTimes).unwrap();
        assert_eq!(c.shape(), (3, 2));
        assert_eq!(c.nnz(), 0);
    }

    #[test]
    fn adaptive_policy_counters() {
        // 1000 output columns. Row 0: one A entry → copy. Row 1: two
        // small B rows (4 flops ≤ SORT_MAX_FLOPS) → sort. Row 2: 40
        // flops, 40·8 < 1000 → hash. Row 3: 200 flops, 200·8 ≥ 1000 →
        // dense. Row 4: no entries → skipped entirely.
        let n = 1000usize;
        let mut bt: Vec<(usize, usize, f64)> = Vec::new();
        for j in 0..2 {
            bt.push((0, j, 1.0)); // B row 0: 2 entries
            bt.push((1, j + 2, 1.0)); // B row 1: 2 entries
        }
        for j in 0..40 {
            bt.push((2, j * 3, 1.0)); // B row 2: 40 entries
        }
        for j in 0..100 {
            bt.push((3, j * 5, 1.0)); // B row 3: 100 entries
            bt.push((4, j * 7, 1.0)); // B row 4: 100 entries
        }
        let b = from_triples(5, n, &bt);
        let a = from_triples(
            5,
            5,
            &[
                (0, 3, 2.0), // copy: single entry
                (1, 0, 1.0),
                (1, 1, 1.0), // sort: 2 + 2 = 4 flops
                (2, 2, 1.0),
                (2, 0, 1.0), // hash: 40 + 2 = 42 flops? 42·8 = 336 < 1000
                (3, 3, 1.0),
                (3, 4, 1.0), // dense: 100 + 100 = 200 flops, 1600 ≥ 1000
            ],
        );
        let (_, stats) = spgemm_with_stats(&a, &b, &PlusTimes).unwrap();
        assert_eq!(stats.rows_copy, 1);
        assert_eq!(stats.rows_sort, 1);
        assert_eq!(stats.rows_hash, 1);
        assert_eq!(stats.rows_dense, 1);
        assert_eq!(stats.mults, 100 + 4 + 42 + 200);
    }

    #[test]
    fn forced_policies_bit_identical_small() {
        let a = from_triples(3, 4, &[(0, 0, 2.0), (0, 3, 1.0), (1, 2, 5.0), (2, 1, -1.0)]);
        let b = from_triples(4, 3, &[(0, 0, 1.0), (1, 2, 4.0), (2, 1, 3.0), (3, 0, -2.0)]);
        let (base, _) = spgemm_with_policy_par(
            &a,
            &b,
            &PlusTimes,
            Parallelism::serial(),
            AccumulatorPolicy::Adaptive,
        )
        .unwrap();
        for policy in
            [AccumulatorPolicy::Dense, AccumulatorPolicy::Sort, AccumulatorPolicy::Hash]
        {
            let (c, _) =
                spgemm_with_policy_par(&a, &b, &PlusTimes, Parallelism::serial(), policy).unwrap();
            assert_bits_equal(&base, &c, &format!("{policy:?}"));
        }
    }

    #[test]
    fn symbolic_exact_bound_tighter_on_skew() {
        // Extreme column skew: two fat B rows share the same 50
        // columns, and every A row hits both — per row the flop count
        // is 100 but only 50 distinct output columns exist, so the
        // min(flops, ncols) bound allocates 2x. The exact pass must
        // halve the allocation without changing a single output bit.
        let n = 1000usize;
        let m = 80usize;
        let mut bt = Vec::new();
        for j in 0..50 {
            bt.push((0, j * 3, 1.0));
            bt.push((1, j * 3, 1.0));
        }
        let b = from_triples(2, n, &bt);
        let mut at = Vec::new();
        for i in 0..m {
            at.push((i, 0, 1.0));
            at.push((i, 1, 1.0));
        }
        let a = from_triples(m, 2, &at);
        let run = |bound: SymbolicBound, threads: usize| {
            spgemm_with_modes_par(
                &a,
                &b,
                &PlusTimes,
                Parallelism::with_threads(threads),
                AccumulatorPolicy::Adaptive,
                bound,
            )
            .unwrap()
        };
        let (base, loose) = run(SymbolicBound::MinFlopsCols, 1);
        let (exact_c, exact) = run(SymbolicBound::Exact, 1);
        let (auto_c, auto) = run(SymbolicBound::Auto, 1);
        assert_bits_equal(&base, &exact_c, "exact bound");
        assert_bits_equal(&base, &auto_c, "auto bound");
        assert_eq!(loose.alloc_bound, m * 100, "loose bound = flops");
        assert_eq!(exact.alloc_bound, m * 50, "exact bound = distinct columns");
        // Auto must detect the skew (bound >> input nnz) and upgrade.
        assert_eq!(auto.alloc_bound, exact.alloc_bound, "auto upgrades on skew");
        assert_eq!(exact.alloc_bound, exact.out_nnz, "all products survive here");
        // Bit-identity holds across the fan-out too (m > PAR_MIN_ROWS).
        for bound in [SymbolicBound::MinFlopsCols, SymbolicBound::Exact, SymbolicBound::Auto] {
            for threads in [2usize, 4, 7] {
                let (c, _) = run(bound, threads);
                assert_bits_equal(&base, &c, &format!("{bound:?} at {threads} threads"));
            }
        }
    }

    #[test]
    fn symbolic_auto_stays_loose_on_small_bounds() {
        // Total bound 4 vs input nnz 8: well under the 2x threshold,
        // so Auto keeps the one-pass bound (no wasted exact pass).
        let a = from_triples(3, 4, &[(0, 0, 2.0), (0, 3, 1.0), (1, 2, 5.0), (2, 1, -1.0)]);
        let b = from_triples(4, 3, &[(0, 0, 1.0), (1, 2, 4.0), (2, 1, 3.0), (3, 0, -2.0)]);
        let run = |bound: SymbolicBound| {
            spgemm_with_modes_par(
                &a,
                &b,
                &PlusTimes,
                Parallelism::serial(),
                AccumulatorPolicy::Adaptive,
                bound,
            )
            .unwrap()
            .1
        };
        let auto = run(SymbolicBound::Auto).alloc_bound;
        let loose = run(SymbolicBound::MinFlopsCols).alloc_bound;
        assert_eq!(auto, loose);
    }

    #[test]
    fn prop_matches_dense_reference_all_semirings() {
        check("spgemm == dense reference", 120, |g| {
            let m = 6;
            let k = 5;
            let n = 7;
            let mk_mat = |r: &mut SplitMix64, rows: usize, cols: usize| {
                let nnz = r.below_usize(rows * cols);
                let mut t = Vec::new();
                for _ in 0..nnz {
                    t.push((r.below_usize(rows), r.below_usize(cols), r.range_i64(1, 9) as f64));
                }
                from_triples(rows, cols, &t)
            };
            let a = mk_mat(g.rng(), m, k);
            let b = mk_mat(g.rng(), k, n);
            let semirings: Vec<Box<dyn Semiring>> = vec![
                Box::new(PlusTimes),
                Box::new(MaxPlus),
                Box::new(MinPlus),
                Box::new(MaxMin),
            ];
            for s in &semirings {
                let c = spgemm(&a, &b, s.as_ref()).unwrap();
                let expect = dense_matmul(&a, &b, s.as_ref());
                for i in 0..m {
                    for j in 0..n {
                        let got = c.get(i, j).unwrap_or(s.zero());
                        assert_eq!(
                            got,
                            expect[i * n + j],
                            "{} at ({i},{j})",
                            s.name()
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn prop_parallel_matches_serial_bitwise() {
        // The determinism contract: any thread count, same bytes. Runs
        // above PAR_MIN_ROWS so the fan-out actually happens.
        check("spgemm_par == spgemm serial", 20, |g| {
            let m = 200;
            let k = 64;
            let n = 96;
            let mk_mat = |r: &mut SplitMix64, rows: usize, cols: usize, nnz: usize| {
                let mut t = Vec::new();
                for _ in 0..nnz {
                    t.push((r.below_usize(rows), r.below_usize(cols), r.range_i64(1, 9) as f64));
                }
                from_triples(rows, cols, &t)
            };
            let a = mk_mat(g.rng(), m, k, 800);
            let b = mk_mat(g.rng(), k, n, 500);
            for s in [&PlusTimes as &dyn Semiring, &MaxPlus, &MinPlus, &MaxMin] {
                let (serial, st1) =
                    spgemm_with_stats_par(&a, &b, s, Parallelism::serial()).unwrap();
                for threads in [2, 4, 7] {
                    let (par, st2) =
                        spgemm_with_stats_par(&a, &b, s, Parallelism::with_threads(threads))
                            .unwrap();
                    assert_eq!(serial, par, "{} at {threads} threads", s.name());
                    assert_eq!(st1.mults, st2.mults);
                    assert_eq!(st1.out_nnz, st2.out_nnz);
                }
            }
        });
    }

    #[test]
    fn prop_all_policies_match_all_threads() {
        // The accumulator contract: every forced policy, at every
        // thread count, is bit-identical to the serial adaptive run.
        check("accumulator policies bit-identical", 12, |g| {
            let m = 120;
            let k = 50;
            let n = 80;
            let mk_mat = |r: &mut SplitMix64, rows: usize, cols: usize, nnz: usize| {
                let mut t = Vec::new();
                for _ in 0..nnz {
                    t.push((r.below_usize(rows), r.below_usize(cols), r.range_i64(1, 9) as f64));
                }
                from_triples(rows, cols, &t)
            };
            let a = mk_mat(g.rng(), m, k, 400);
            let b = mk_mat(g.rng(), k, n, 300);
            for s in [&PlusTimes as &dyn Semiring, &MaxPlus, &MinPlus, &MaxMin] {
                let (base, _) = spgemm_with_policy_par(
                    &a,
                    &b,
                    s,
                    Parallelism::serial(),
                    AccumulatorPolicy::Adaptive,
                )
                .unwrap();
                for policy in [
                    AccumulatorPolicy::Adaptive,
                    AccumulatorPolicy::Dense,
                    AccumulatorPolicy::Sort,
                    AccumulatorPolicy::Hash,
                ] {
                    for threads in [1usize, 3, 7] {
                        let (c, _) = spgemm_with_policy_par(
                            &a,
                            &b,
                            s,
                            Parallelism::with_threads(threads),
                            policy,
                        )
                        .unwrap();
                        assert_bits_equal(
                            &base,
                            &c,
                            &format!("{} {policy:?} t={threads}", s.name()),
                        );
                    }
                }
            }
        });
    }

    /// Expected masked result: the full product with mask-false columns
    /// dropped (raw arrays, so the comparison is bit-exact).
    fn drop_cols_arrays(c: &CsrMatrix, mask: &[bool]) -> (Vec<usize>, Vec<u32>, Vec<u64>) {
        let mut indptr = vec![0usize];
        let mut idx: Vec<u32> = Vec::new();
        let mut bits: Vec<u64> = Vec::new();
        for r in 0..c.shape().0 {
            let (ci, cv) = c.row(r);
            for (col, v) in ci.iter().zip(cv) {
                if mask[*col as usize] {
                    idx.push(*col);
                    bits.push(v.to_bits());
                }
            }
            indptr.push(idx.len());
        }
        (indptr, idx, bits)
    }

    #[test]
    fn masked_rejects_bad_mask_length() {
        let a = CsrMatrix::zeros(2, 3);
        let b = CsrMatrix::zeros(3, 4);
        let err = spgemm_masked(&a, &b, &PlusTimes, &[true; 3]).unwrap_err();
        assert!(matches!(
            err,
            SparseError::MaskLengthMismatch { mask: 3, extent: 4, axis: "column" }
        ));
        let err = spgemm_row_masked(&a, &b, &PlusTimes, &[true; 3]).unwrap_err();
        assert!(matches!(err, SparseError::MaskLengthMismatch { mask: 3, extent: 2, axis: "row" }));
    }

    /// Expected row-masked result: the full product with mask-false
    /// rows dropped (raw arrays, bit-exact comparison).
    fn drop_rows_arrays(c: &CsrMatrix, mask: &[bool]) -> (Vec<usize>, Vec<u32>, Vec<u64>) {
        let mut indptr = vec![0usize];
        let mut idx: Vec<u32> = Vec::new();
        let mut bits: Vec<u64> = Vec::new();
        for r in 0..c.shape().0 {
            if mask[r] {
                let (ci, cv) = c.row(r);
                idx.extend_from_slice(ci);
                bits.extend(cv.iter().map(|v| v.to_bits()));
            }
            indptr.push(idx.len());
        }
        (indptr, idx, bits)
    }

    #[test]
    fn row_masked_small_matches_filtered_full() {
        let a = from_triples(3, 2, &[(0, 0, 1.0), (0, 1, 2.0), (1, 0, 3.0), (2, 1, 4.0)]);
        let b = from_triples(2, 3, &[(0, 0, 1.0), (0, 2, 1.0), (1, 1, 5.0), (1, 2, 2.0)]);
        let mask = [true, false, true];
        let full = spgemm(&a, &b, &PlusTimes).unwrap();
        let (ptr, idx, bits) = drop_rows_arrays(&full, &mask);
        let (got, stats) = spgemm_row_masked_with_stats_par(
            &a,
            &b,
            &PlusTimes,
            Parallelism::serial(),
            &mask,
        )
        .unwrap();
        assert_eq!(got.shape(), full.shape());
        assert_eq!(got.indptr(), &ptr[..]);
        assert_eq!(got.indices(), &idx[..]);
        let gbits: Vec<u64> = got.values().iter().map(|v| v.to_bits()).collect();
        assert_eq!(gbits, bits);
        // Row 1's two flops are gone: row 0 costs 2 + 2, row 2 costs 2.
        assert_eq!(stats.mults, 6);
    }

    #[test]
    fn row_masked_all_false_and_all_true() {
        let a = from_triples(2, 2, &[(0, 0, 1.0), (1, 1, 2.0)]);
        let b = from_triples(2, 2, &[(0, 0, 3.0), (1, 1, 4.0)]);
        let (none, stats) = spgemm_row_masked_with_stats_par(
            &a,
            &b,
            &PlusTimes,
            Parallelism::serial(),
            &[false, false],
        )
        .unwrap();
        assert_eq!(none.nnz(), 0);
        assert_eq!(none.shape(), (2, 2));
        assert_eq!(stats.mults, 0, "excluded rows must cost zero flops");
        let all = spgemm_row_masked(&a, &b, &PlusTimes, &[true, true]).unwrap();
        assert_eq!(all, spgemm(&a, &b, &PlusTimes).unwrap());
    }

    #[test]
    fn prop_row_masked_matches_filtered_all_semirings() {
        check("row-masked spgemm == full-then-drop-rows", 60, |g| {
            let m = 20;
            let k = 12;
            let n = 16;
            let mk_mat = |r: &mut SplitMix64, rows: usize, cols: usize, nnz: usize| {
                let mut t = Vec::new();
                for _ in 0..nnz {
                    t.push((r.below_usize(rows), r.below_usize(cols), r.range_i64(1, 9) as f64));
                }
                from_triples(rows, cols, &t)
            };
            let a = mk_mat(g.rng(), m, k, 80);
            let b = mk_mat(g.rng(), k, n, 60);
            let mask: Vec<bool> = (0..m).map(|_| g.rng().chance(0.3)).collect();
            for s in [&PlusTimes as &dyn Semiring, &MaxPlus, &MinPlus, &MaxMin] {
                let (full, full_stats) =
                    spgemm_with_stats_par(&a, &b, s, Parallelism::serial()).unwrap();
                let (ptr, idx, bits) = drop_rows_arrays(&full, &mask);
                for threads in [1usize, 3, 7] {
                    let (got, stats) = spgemm_row_masked_with_stats_par(
                        &a,
                        &b,
                        s,
                        Parallelism::with_threads(threads),
                        &mask,
                    )
                    .unwrap();
                    assert_eq!(got.indptr(), &ptr[..], "{} t={threads}", s.name());
                    assert_eq!(got.indices(), &idx[..], "{} t={threads}", s.name());
                    let gbits: Vec<u64> = got.values().iter().map(|v| v.to_bits()).collect();
                    assert_eq!(gbits, bits, "{} t={threads}", s.name());
                    assert!(stats.mults <= full_stats.mults, "{} t={threads}", s.name());
                }
            }
        });
    }

    #[test]
    fn masked_small_matches_filtered_full() {
        let a = from_triples(2, 2, &[(0, 0, 1.0), (0, 1, 2.0), (1, 0, 3.0), (1, 1, 4.0)]);
        let b = from_triples(2, 3, &[(0, 0, 1.0), (0, 2, 1.0), (1, 1, 5.0), (1, 2, 2.0)]);
        let mask = [true, false, true];
        let full = spgemm(&a, &b, &PlusTimes).unwrap();
        let (ptr, idx, bits) = drop_cols_arrays(&full, &mask);
        let (got, stats) = spgemm_masked_with_stats_par(
            &a,
            &b,
            &PlusTimes,
            Parallelism::serial(),
            &mask,
        )
        .unwrap();
        assert_eq!(got.shape(), full.shape());
        assert_eq!(got.indptr(), &ptr[..]);
        assert_eq!(got.indices(), &idx[..]);
        let gbits: Vec<u64> = got.values().iter().map(|v| v.to_bits()).collect();
        assert_eq!(gbits, bits);
        // Excluded column 1 contributed zero flops: 2 A-entries × 1
        // surviving B-entry each... row 0 hits B rows 0 and 1 (2 + 1
        // surviving entries), row 1 the same: 6 total vs 8 unmasked.
        assert_eq!(stats.mults, 6);
    }

    #[test]
    fn masked_all_false_and_all_true() {
        let a = from_triples(2, 2, &[(0, 0, 1.0), (1, 1, 2.0)]);
        let b = from_triples(2, 2, &[(0, 0, 3.0), (1, 1, 4.0)]);
        let none = spgemm_masked(&a, &b, &PlusTimes, &[false, false]).unwrap();
        assert_eq!(none.nnz(), 0);
        assert_eq!(none.shape(), (2, 2));
        let all = spgemm_masked(&a, &b, &PlusTimes, &[true, true]).unwrap();
        assert_eq!(all, spgemm(&a, &b, &PlusTimes).unwrap());
    }

    #[test]
    fn prop_masked_matches_filtered_all_semirings() {
        check("masked spgemm == full-then-drop", 60, |g| {
            let m = 20;
            let k = 12;
            let n = 16;
            let mk_mat = |r: &mut SplitMix64, rows: usize, cols: usize, nnz: usize| {
                let mut t = Vec::new();
                for _ in 0..nnz {
                    t.push((r.below_usize(rows), r.below_usize(cols), r.range_i64(1, 9) as f64));
                }
                from_triples(rows, cols, &t)
            };
            let a = mk_mat(g.rng(), m, k, 80);
            let b = mk_mat(g.rng(), k, n, 60);
            let mask: Vec<bool> = (0..n).map(|_| g.rng().chance(0.3)).collect();
            for s in [&PlusTimes as &dyn Semiring, &MaxPlus, &MinPlus, &MaxMin] {
                let full = spgemm(&a, &b, s).unwrap();
                let (ptr, idx, bits) = drop_cols_arrays(&full, &mask);
                for threads in [1usize, 3, 7] {
                    let got =
                        spgemm_masked_par(&a, &b, s, Parallelism::with_threads(threads), &mask)
                            .unwrap();
                    assert_eq!(got.indptr(), &ptr[..], "{} t={threads}", s.name());
                    assert_eq!(got.indices(), &idx[..], "{} t={threads}", s.name());
                    let gbits: Vec<u64> = got.values().iter().map(|v| v.to_bits()).collect();
                    assert_eq!(gbits, bits, "{} t={threads}", s.name());
                }
            }
        });
    }

    #[test]
    fn prop_associativity_on_binary_matrices() {
        // (A@B)@C == A@(B@C) for 0/1 matrices under plus-times (exact in f64).
        check("spgemm associative", 60, |g| {
            let n = 5;
            let mk = |r: &mut SplitMix64| {
                let mut t = Vec::new();
                for i in 0..n {
                    for j in 0..n {
                        if r.chance(0.3) {
                            t.push((i, j, 1.0));
                        }
                    }
                }
                from_triples(n, n, &t)
            };
            let a = mk(g.rng());
            let b = mk(g.rng());
            let c = mk(g.rng());
            let left = spgemm(&spgemm(&a, &b, &PlusTimes).unwrap(), &c, &PlusTimes).unwrap();
            let right = spgemm(&a, &spgemm(&b, &c, &PlusTimes).unwrap(), &PlusTimes).unwrap();
            assert_eq!(left, right);
        });
    }
}
