//! Sparse general matrix-matrix multiply (SpGEMM) over a semiring.
//!
//! Gustavson's row-wise algorithm with a dense accumulator: for each row
//! `i` of `A`, accumulate `⊕_k A[i,k] ⊗ B[k,:]` into a dense scratch row,
//! tracking which columns were touched so the scratch can be reset in
//! O(touched) rather than O(ncols). This is the general path of `A @ B`
//! (paper §II.C.3); the dense-block PJRT kernel in [`crate::runtime`] is
//! the accelerated alternative for dense operands.

use super::{CsrMatrix, SparseError};
use crate::semiring::Semiring;

/// Instrumentation from one SpGEMM call (used by the perf harness).
#[derive(Debug, Clone, Default)]
pub struct SpGemmStats {
    /// Number of `⊗` (multiply) operations performed.
    pub mults: u64,
    /// Stored entries in the output.
    pub out_nnz: usize,
}

/// `C = A ⊗.⊕ B` over semiring `s`. Shapes must contract:
/// `(m × k) @ (k × n) → (m × n)`.
pub fn spgemm(a: &CsrMatrix, b: &CsrMatrix, s: &dyn Semiring) -> Result<CsrMatrix, SparseError> {
    spgemm_with_stats(a, b, s).map(|(c, _)| c)
}

/// [`spgemm`] with operation counts.
pub fn spgemm_with_stats(
    a: &CsrMatrix,
    b: &CsrMatrix,
    s: &dyn Semiring,
) -> Result<(CsrMatrix, SpGemmStats), SparseError> {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    if ka != kb {
        return Err(SparseError::ShapeMismatch { left: a.shape(), right: b.shape(), op: "spgemm" });
    }
    let zero = s.zero();
    let mut stats = SpGemmStats::default();

    // Dense accumulator row + touched-column list. `occupied` marks which
    // accumulator slots are live so nonstandard zeros (e.g. min-plus +inf)
    // need no sentinel trickery.
    let mut acc = vec![zero; n];
    let mut occupied = vec![false; n];
    let mut touched: Vec<u32> = Vec::new();

    let mut indptr = Vec::with_capacity(m + 1);
    indptr.push(0usize);
    // (Measured: pre-reserving the output vectors gives <1% here — the
    // dense-accumulator inner loop dominates — so no size estimate.)
    let mut indices: Vec<u32> = Vec::new();
    let mut data: Vec<f64> = Vec::new();

    for i in 0..m {
        let (acols, avals) = a.row(i);
        for (kk, av) in acols.iter().zip(avals) {
            let (bcols, bvals) = b.row(*kk as usize);
            stats.mults += bcols.len() as u64;
            for (c, bv) in bcols.iter().zip(bvals) {
                let prod = s.mul(*av, *bv);
                let ci = *c as usize;
                if occupied[ci] {
                    acc[ci] = s.add(acc[ci], prod);
                } else {
                    occupied[ci] = true;
                    acc[ci] = prod;
                    touched.push(*c);
                }
            }
        }
        // Emit the row in sorted column order and reset the scratch.
        touched.sort_unstable();
        for &c in &touched {
            let ci = c as usize;
            if acc[ci] != zero {
                indices.push(c);
                data.push(acc[ci]);
            }
            occupied[ci] = false;
            acc[ci] = zero;
        }
        touched.clear();
        indptr.push(indices.len());
    }
    stats.out_nnz = data.len();
    Ok((CsrMatrix::from_parts(m, n, indptr, indices, data), stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::semiring::{MaxMin, MaxPlus, MinPlus, PlusTimes, Semiring};
    use crate::sparse::CooMatrix;
    use crate::util::prop::check;
    use crate::util::SplitMix64;

    fn from_triples(m: usize, n: usize, t: &[(usize, usize, f64)]) -> CsrMatrix {
        let rows: Vec<usize> = t.iter().map(|x| x.0).collect();
        let cols: Vec<usize> = t.iter().map(|x| x.1).collect();
        let vals: Vec<f64> = t.iter().map(|x| x.2).collect();
        CooMatrix::from_triples_aggregate(m, n, &rows, &cols, &vals, 0.0, |a, b| a + b)
            .unwrap()
            .to_csr()
    }

    /// O(m·k·n) reference matmul over a semiring, via dense views.
    fn dense_matmul(a: &CsrMatrix, b: &CsrMatrix, s: &dyn Semiring) -> Vec<f64> {
        let (m, k) = a.shape();
        let (_, n) = b.shape();
        let mut out = vec![s.zero(); m * n];
        for i in 0..m {
            for kk in 0..k {
                let av = match a.get(i, kk) {
                    Some(v) => v,
                    None => continue,
                };
                for j in 0..n {
                    if let Some(bv) = b.get(kk, j) {
                        out[i * n + j] = s.add(out[i * n + j], s.mul(av, bv));
                    }
                }
            }
        }
        out
    }

    #[test]
    fn small_plus_times() {
        // [[1,2],[3,4]] @ [[1,1],[1,1]] = [[3,3],[7,7]]
        let a = from_triples(2, 2, &[(0, 0, 1.0), (0, 1, 2.0), (1, 0, 3.0), (1, 1, 4.0)]);
        let b = from_triples(2, 2, &[(0, 0, 1.0), (0, 1, 1.0), (1, 0, 1.0), (1, 1, 1.0)]);
        let c = spgemm(&a, &b, &PlusTimes).unwrap();
        assert_eq!(c.get(0, 0), Some(3.0));
        assert_eq!(c.get(0, 1), Some(3.0));
        assert_eq!(c.get(1, 0), Some(7.0));
        assert_eq!(c.get(1, 1), Some(7.0));
    }

    #[test]
    fn shape_mismatch_rejected() {
        let a = CsrMatrix::zeros(2, 3);
        let b = CsrMatrix::zeros(2, 2);
        assert!(spgemm(&a, &b, &PlusTimes).is_err());
    }

    #[test]
    fn rectangular_shapes() {
        let a = from_triples(2, 3, &[(0, 2, 2.0), (1, 0, 1.0)]);
        let b = from_triples(3, 4, &[(2, 3, 5.0), (0, 1, 7.0)]);
        let c = spgemm(&a, &b, &PlusTimes).unwrap();
        assert_eq!(c.shape(), (2, 4));
        assert_eq!(c.get(0, 3), Some(10.0));
        assert_eq!(c.get(1, 1), Some(7.0));
        assert_eq!(c.nnz(), 2);
    }

    #[test]
    fn min_plus_shortest_path_step() {
        // Path graph 0 -> 1 -> 2 with weights 2 and 3; A² under min-plus
        // gives the 2-hop distance 0 -> 2 = 5.
        let a = from_triples(3, 3, &[(0, 1, 2.0), (1, 2, 3.0)]);
        let c = spgemm(&a, &a, &MinPlus).unwrap();
        assert_eq!(c.get(0, 2), Some(5.0));
        assert_eq!(c.nnz(), 1);
    }

    #[test]
    fn max_min_bottleneck() {
        let a = from_triples(2, 2, &[(0, 0, 5.0), (0, 1, 2.0)]);
        let b = from_triples(2, 2, &[(0, 1, 3.0), (1, 1, 9.0)]);
        // C[0,1] = max(min(5,3), min(2,9)) = max(3, 2) = 3
        let c = spgemm(&a, &b, &MaxMin).unwrap();
        assert_eq!(c.get(0, 1), Some(3.0));
    }

    #[test]
    fn stats_count_mults() {
        let a = from_triples(2, 2, &[(0, 0, 1.0), (0, 1, 1.0)]);
        let b = from_triples(2, 2, &[(0, 0, 1.0), (0, 1, 1.0), (1, 0, 1.0), (1, 1, 1.0)]);
        let (_, stats) = spgemm_with_stats(&a, &b, &PlusTimes).unwrap();
        assert_eq!(stats.mults, 4); // row 0 of A hits both rows of B (2 nnz each)
    }

    #[test]
    fn empty_operands() {
        let a = CsrMatrix::zeros(3, 4);
        let b = CsrMatrix::zeros(4, 2);
        let c = spgemm(&a, &b, &PlusTimes).unwrap();
        assert_eq!(c.shape(), (3, 2));
        assert_eq!(c.nnz(), 0);
    }

    #[test]
    fn prop_matches_dense_reference_all_semirings() {
        check("spgemm == dense reference", 120, |g| {
            let m = 6;
            let k = 5;
            let n = 7;
            let mk_mat = |r: &mut SplitMix64, rows: usize, cols: usize| {
                let nnz = r.below_usize(rows * cols);
                let mut t = Vec::new();
                for _ in 0..nnz {
                    t.push((r.below_usize(rows), r.below_usize(cols), r.range_i64(1, 9) as f64));
                }
                from_triples(rows, cols, &t)
            };
            let a = mk_mat(g.rng(), m, k);
            let b = mk_mat(g.rng(), k, n);
            let semirings: Vec<Box<dyn Semiring>> = vec![
                Box::new(PlusTimes),
                Box::new(MaxPlus),
                Box::new(MinPlus),
                Box::new(MaxMin),
            ];
            for s in &semirings {
                let c = spgemm(&a, &b, s.as_ref()).unwrap();
                let expect = dense_matmul(&a, &b, s.as_ref());
                for i in 0..m {
                    for j in 0..n {
                        let got = c.get(i, j).unwrap_or(s.zero());
                        assert_eq!(
                            got,
                            expect[i * n + j],
                            "{} at ({i},{j})",
                            s.name()
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn prop_associativity_on_binary_matrices() {
        // (A@B)@C == A@(B@C) for 0/1 matrices under plus-times (exact in f64).
        check("spgemm associative", 60, |g| {
            let n = 5;
            let mk = |r: &mut SplitMix64| {
                let mut t = Vec::new();
                for i in 0..n {
                    for j in 0..n {
                        if r.chance(0.3) {
                            t.push((i, j, 1.0));
                        }
                    }
                }
                from_triples(n, n, &t)
            };
            let a = mk(g.rng());
            let b = mk(g.rng());
            let c = mk(g.rng());
            let left = spgemm(&spgemm(&a, &b, &PlusTimes).unwrap(), &c, &PlusTimes).unwrap();
            let right = spgemm(&a, &spgemm(&b, &c, &PlusTimes).unwrap(), &PlusTimes).unwrap();
            assert_eq!(left, right);
        });
    }
}
