//! Compressed Sparse Column matrix.
//!
//! Mostly a transpose-view companion to [`CsrMatrix`]: `condense` uses
//! its `indptr` for the nonempty-column test (the paper's
//! `csc_cols[:-1] < csc_cols[1:]`), and `transpose` is a free
//! reinterpretation of CSC as CSR. Since PR 2, `CsrMatrix::to_csc`
//! copies out of the CSR's memoized transpose dual rather than
//! re-scattering, so repeated CSC requests are O(nnz) memcpy.

use super::CsrMatrix;

/// Sparse matrix in CSC format. Same invariants as CSR, transposed.
#[derive(Debug, Clone, PartialEq)]
pub struct CscMatrix {
    nrows: usize,
    ncols: usize,
    indptr: Vec<usize>,  // len ncols + 1
    indices: Vec<u32>,   // row indices, column-major
    data: Vec<f64>,
}

impl CscMatrix {
    pub(crate) fn from_parts(
        nrows: usize,
        ncols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        data: Vec<f64>,
    ) -> Self {
        debug_assert_eq!(indptr.len(), ncols + 1);
        debug_assert_eq!(indices.len(), data.len());
        CscMatrix { nrows, ncols, indptr, indices, data }
    }

    /// Shape `(nrows, ncols)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.nrows, self.ncols)
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// Column pointer array (`ncols + 1` entries).
    pub fn indptr(&self) -> &[usize] {
        &self.indptr
    }

    /// The `(row_indices, values)` slice of one column.
    pub fn col(&self, c: usize) -> (&[u32], &[f64]) {
        let (s, e) = (self.indptr[c], self.indptr[c + 1]);
        (&self.indices[s..e], &self.data[s..e])
    }

    /// Boolean mask of columns with at least one stored entry — the
    /// paper's `csc_cols[:-1] < csc_cols[1:]`.
    pub fn nonempty_cols(&self) -> Vec<bool> {
        self.indptr.windows(2).map(|w| w[0] < w[1]).collect()
    }

    /// Reinterpret this CSC as the CSR of the transposed matrix (free).
    pub fn transpose_view(self) -> CsrMatrix {
        CsrMatrix::from_parts(self.ncols, self.nrows, self.indptr, self.indices, self.data)
    }

    /// Convert back to CSR (transpose of the transpose-view). O(nnz).
    pub fn to_csr(&self) -> CsrMatrix {
        let mut indptr = vec![0usize; self.nrows + 1];
        for &r in &self.indices {
            indptr[r as usize + 1] += 1;
        }
        for i in 0..self.nrows {
            indptr[i + 1] += indptr[i];
        }
        let mut next = indptr.clone();
        let mut indices = vec![0u32; self.nnz()];
        let mut data = vec![0f64; self.nnz()];
        for c in 0..self.ncols {
            let (ri, rv) = self.col(c);
            for (r, v) in ri.iter().zip(rv) {
                let q = next[*r as usize];
                next[*r as usize] += 1;
                indices[q] = c as u32;
                data[q] = *v;
            }
        }
        CsrMatrix::from_parts(self.nrows, self.ncols, indptr, indices, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooMatrix;

    fn sample() -> CsrMatrix {
        CooMatrix::from_triples_aggregate(
            3,
            4,
            &[0, 1, 1, 2],
            &[1, 0, 3, 1],
            &[5.0, 2.0, 7.0, 4.0],
            0.0,
            f64::min,
        )
        .unwrap()
        .to_csr()
    }

    #[test]
    fn csc_columns_correct() {
        let csc = sample().to_csc();
        assert_eq!(csc.shape(), (3, 4));
        let (ri, rv) = csc.col(1);
        assert_eq!(ri, &[0, 2]);
        assert_eq!(rv, &[5.0, 4.0]);
        let (ri, _) = csc.col(2);
        assert!(ri.is_empty());
    }

    #[test]
    fn nonempty_cols_mask() {
        let csc = sample().to_csc();
        assert_eq!(csc.nonempty_cols(), vec![true, true, false, true]);
    }

    #[test]
    fn transpose_view_is_transpose() {
        let csr = sample();
        let t = csr.clone().to_csc().transpose_view();
        assert_eq!(t.shape(), (4, 3));
        for r in 0..3 {
            for c in 0..4 {
                assert_eq!(csr.get(r, c), t.get(c, r));
            }
        }
    }

    #[test]
    fn to_csr_roundtrip() {
        let csr = sample();
        assert_eq!(csr.to_csc().to_csr(), csr);
    }
}
