//! Compressed Sparse Row matrix — the compute format.
//!
//! Semiring-parameterized element-wise addition (union merge per row,
//! paper §II.C.1) and multiplication (intersection merge per row,
//! §II.C.2), plus the `indptr`-based nonempty row/column detection that
//! powers `Assoc::condense` — the exact `csr_rows[:-1] < csr_rows[1:]`
//! trick of the paper.
//!
//! **Cached dual.** The transpose (equivalently, the CSC form read as
//! CSR) is computed at most once per matrix and memoized behind a
//! [`OnceLock`]: [`CsrMatrix::transpose`], [`CsrMatrix::to_csc`], and
//! the column gather [`CsrMatrix::gather_cols`] all share it, so
//! transpose-then-multiply patterns (`sqin`, graphulo `table_mult`) and
//! repeated column indexing pay the O(nnz + ncols) conversion once.
//! The cache needs no invalidation: a `CsrMatrix` is immutable after
//! construction (every operation builds a new matrix), and `Clone`
//! starts with an empty cell. Equality and `Debug` ignore the cache.

use super::{CooMatrix, CscMatrix, SparseError};
use crate::semiring::Semiring;
use crate::util::parallel::{parallel_map_ranges, Parallelism};
use std::ops::Range;
use std::sync::OnceLock;

/// Sparse matrix in CSR format.
///
/// Invariants: `indptr.len() == nrows + 1`, `indptr` non-decreasing,
/// column indices strictly increasing within each row, stored values
/// never equal to the semiring zero of the op that produced them.
pub struct CsrMatrix {
    nrows: usize,
    ncols: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    data: Vec<f64>,
    /// Lazily-computed transpose (the CSC dual read row-major). Boxed to
    /// break the recursive type; never compared, printed, or cloned.
    dual: OnceLock<Box<CsrMatrix>>,
}

impl Clone for CsrMatrix {
    /// Structural clone; the dual cache starts empty (cloning it would
    /// double the copy cost for a cache the clone may never use).
    fn clone(&self) -> Self {
        CsrMatrix {
            nrows: self.nrows,
            ncols: self.ncols,
            indptr: self.indptr.clone(),
            indices: self.indices.clone(),
            data: self.data.clone(),
            dual: OnceLock::new(),
        }
    }
}

impl PartialEq for CsrMatrix {
    /// Structural equality only — the dual cache is derived state.
    fn eq(&self, other: &Self) -> bool {
        self.nrows == other.nrows
            && self.ncols == other.ncols
            && self.indptr == other.indptr
            && self.indices == other.indices
            && self.data == other.data
    }
}

impl std::fmt::Debug for CsrMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CsrMatrix")
            .field("nrows", &self.nrows)
            .field("ncols", &self.ncols)
            .field("indptr", &self.indptr)
            .field("indices", &self.indices)
            .field("data", &self.data)
            .finish()
    }
}

impl CsrMatrix {
    /// Assemble from raw parts (trusted; debug-asserted).
    pub(crate) fn from_parts(
        nrows: usize,
        ncols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        data: Vec<f64>,
    ) -> Self {
        debug_assert_eq!(indptr.len(), nrows + 1);
        debug_assert_eq!(indices.len(), data.len());
        debug_assert_eq!(*indptr.last().unwrap_or(&0), indices.len());
        debug_assert!(indptr.windows(2).all(|w| w[0] <= w[1]));
        #[cfg(debug_assertions)]
        for r in 0..nrows {
            let row = &indices[indptr[r]..indptr[r + 1]];
            debug_assert!(row.windows(2).all(|w| w[0] < w[1]), "row {r} not strictly sorted");
        }
        CsrMatrix { nrows, ncols, indptr, indices, data, dual: OnceLock::new() }
    }

    /// Empty matrix of the given shape.
    pub fn zeros(nrows: usize, ncols: usize) -> Self {
        CsrMatrix {
            nrows,
            ncols,
            indptr: vec![0; nrows + 1],
            indices: Vec::new(),
            data: Vec::new(),
            dual: OnceLock::new(),
        }
    }

    /// Shape `(nrows, ncols)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.nrows, self.ncols)
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// Row pointer array (`nrows + 1` entries).
    pub fn indptr(&self) -> &[usize] {
        &self.indptr
    }

    /// Column indices, row-major.
    pub fn indices(&self) -> &[u32] {
        &self.indices
    }

    /// Stored values, row-major.
    pub fn values(&self) -> &[f64] {
        &self.data
    }

    /// The `(indices, values)` slice of one row.
    pub fn row(&self, r: usize) -> (&[u32], &[f64]) {
        let (s, e) = (self.indptr[r], self.indptr[r + 1]);
        (&self.indices[s..e], &self.data[s..e])
    }

    /// Value at `(row, col)` or `None` (binary search within the row).
    pub fn get(&self, row: usize, col: usize) -> Option<f64> {
        if row >= self.nrows {
            return None;
        }
        let (idx, vals) = self.row(row);
        idx.binary_search(&(col as u32)).ok().map(|p| vals[p])
    }

    /// Convert to COO (row-major sorted, same entries).
    pub fn to_coo(&self) -> CooMatrix {
        let mut rows = Vec::with_capacity(self.nnz());
        for r in 0..self.nrows {
            for _ in self.indptr[r]..self.indptr[r + 1] {
                rows.push(r as u32);
            }
        }
        CooMatrix::from_sorted_parts(
            self.nrows,
            self.ncols,
            rows,
            self.indices.clone(),
            self.data.clone(),
        )
    }

    /// The transpose, computed once per matrix and cached (the CSC dual
    /// read row-major). O(nnz + ncols) on first use, O(1) after; safe
    /// for concurrent first use (the `OnceLock` keeps one winner).
    pub fn transpose_cached(&self) -> &CsrMatrix {
        self.dual.get_or_init(|| Box::new(self.compute_dual()))
    }

    /// Whether the transpose/CSC dual has already been materialized
    /// (callers use this to pick between row- and column-major plans).
    pub fn has_cached_dual(&self) -> bool {
        self.dual.get().is_some()
    }

    /// Counting-sort scatter into the transpose. O(nnz + ncols).
    fn compute_dual(&self) -> CsrMatrix {
        let mut indptr = vec![0usize; self.ncols + 1];
        for &c in &self.indices {
            indptr[c as usize + 1] += 1;
        }
        for i in 0..self.ncols {
            indptr[i + 1] += indptr[i];
        }
        let mut next = indptr.clone();
        let mut indices = vec![0u32; self.nnz()];
        let mut data = vec![0f64; self.nnz()];
        for r in 0..self.nrows {
            for p in self.indptr[r]..self.indptr[r + 1] {
                let c = self.indices[p] as usize;
                let q = next[c];
                next[c] += 1;
                indices[q] = r as u32;
                data[q] = self.data[p];
            }
        }
        CsrMatrix::from_parts(self.ncols, self.nrows, indptr, indices, data)
    }

    /// Convert to CSC: a copy of the cached dual's arrays reinterpreted
    /// column-major. First call O(nnz + ncols), repeats O(nnz) memcpy.
    pub fn to_csc(&self) -> CscMatrix {
        let d = self.transpose_cached();
        CscMatrix::from_parts(
            self.nrows,
            self.ncols,
            d.indptr.clone(),
            d.indices.clone(),
            d.data.clone(),
        )
    }

    /// Transpose (an owned copy of the cached dual). Repeated calls on
    /// the same matrix are O(nnz) memcpy instead of a re-scatter; the
    /// returned matrix builds its own dual lazily if ever asked (an
    /// eager back-seed would cost every one-shot caller an extra
    /// retained O(nnz) copy).
    pub fn transpose(&self) -> CsrMatrix {
        self.transpose_cached().clone()
    }

    /// Element-wise addition under `s` (union merge per row, §II.C.1),
    /// at the process-default parallelism.
    pub fn add(&self, other: &CsrMatrix, s: &dyn Semiring) -> Result<CsrMatrix, SparseError> {
        self.add_par(other, s, Parallelism::current())
    }

    /// [`CsrMatrix::add`] with an explicit thread configuration. Rows
    /// are independent under the union merge, so chunks fan out and the
    /// stitched result is bit-identical to the serial path.
    pub fn add_par(
        &self,
        other: &CsrMatrix,
        s: &dyn Semiring,
        par: Parallelism,
    ) -> Result<CsrMatrix, SparseError> {
        if self.shape() != other.shape() {
            return Err(SparseError::ShapeMismatch {
                left: self.shape(),
                right: other.shape(),
                op: "add",
            });
        }
        Ok(self.rowwise_binary_par(other, par, |rows| self.add_rows(other, s, rows)))
    }

    /// Element-wise multiplication under `s` (intersection merge per
    /// row, §II.C.2), at the process-default parallelism.
    pub fn multiply(&self, other: &CsrMatrix, s: &dyn Semiring) -> Result<CsrMatrix, SparseError> {
        self.multiply_par(other, s, Parallelism::current())
    }

    /// [`CsrMatrix::multiply`] with an explicit thread configuration
    /// (bit-identical to serial for every thread count).
    pub fn multiply_par(
        &self,
        other: &CsrMatrix,
        s: &dyn Semiring,
        par: Parallelism,
    ) -> Result<CsrMatrix, SparseError> {
        if self.shape() != other.shape() {
            return Err(SparseError::ShapeMismatch {
                left: self.shape(),
                right: other.shape(),
                op: "multiply",
            });
        }
        Ok(self.rowwise_binary_par(other, par, |rows| self.mul_rows(other, s, rows)))
    }

    /// Shared fan-out/stitch driver for the row-independent binary ops.
    /// `kernel` computes one contiguous row range; chunk boundaries are
    /// balanced by the operands' combined nnz and depend only on the
    /// inputs and `par.threads`, keeping the output deterministic.
    fn rowwise_binary_par(
        &self,
        other: &CsrMatrix,
        par: Parallelism,
        kernel: impl Fn(Range<usize>) -> BinChunk + Sync,
    ) -> CsrMatrix {
        // Below this combined size the fan-out costs more than the merge.
        const PAR_MIN_NNZ: usize = 4096;
        const PAR_MIN_ROWS: usize = 64;
        let serial = par.is_serial()
            || self.nrows < PAR_MIN_ROWS
            || self.nnz() + other.nnz() < PAR_MIN_NNZ;
        let parts: Vec<BinChunk> = if serial {
            vec![kernel(0..self.nrows)]
        } else {
            let cum: Vec<usize> =
                (0..=self.nrows).map(|r| self.indptr[r] + other.indptr[r]).collect();
            parallel_map_ranges(par.chunk_ranges_weighted(&cum), kernel)
        };
        let total: usize = parts.iter().map(|p| p.indices.len()).sum();
        let mut indptr = Vec::with_capacity(self.nrows + 1);
        indptr.push(0usize);
        let mut indices: Vec<u32> = Vec::with_capacity(total);
        let mut data: Vec<f64> = Vec::with_capacity(total);
        for part in parts {
            let base = indices.len();
            indptr.extend(part.rel_indptr.into_iter().map(|e| base + e));
            indices.extend_from_slice(&part.indices);
            data.extend_from_slice(&part.data);
        }
        CsrMatrix::from_parts(self.nrows, self.ncols, indptr, indices, data)
    }

    /// Union-merge kernel over a contiguous row range (the one and only
    /// `add` inner loop; the serial path runs it over `0..nrows`).
    fn add_rows(&self, other: &CsrMatrix, s: &dyn Semiring, rows: Range<usize>) -> BinChunk {
        let zero = s.zero();
        let mut rel_indptr = Vec::with_capacity(rows.len());
        // Union output is at most the chunk's combined nnz.
        let cap = (self.indptr[rows.end] - self.indptr[rows.start])
            + (other.indptr[rows.end] - other.indptr[rows.start]);
        let mut indices = Vec::with_capacity(cap);
        let mut data = Vec::with_capacity(cap);
        for r in rows {
            let (ai, av) = self.row(r);
            let (bi, bv) = other.row(r);
            let (mut m, mut n) = (0usize, 0usize);
            while m < ai.len() && n < bi.len() {
                let (ca, cb) = (ai[m], bi[n]);
                let (c, v) = match ca.cmp(&cb) {
                    std::cmp::Ordering::Less => {
                        let out = (ca, av[m]);
                        m += 1;
                        out
                    }
                    std::cmp::Ordering::Greater => {
                        let out = (cb, bv[n]);
                        n += 1;
                        out
                    }
                    std::cmp::Ordering::Equal => {
                        let out = (ca, s.add(av[m], bv[n]));
                        m += 1;
                        n += 1;
                        out
                    }
                };
                if v != zero {
                    indices.push(c);
                    data.push(v);
                }
            }
            for p in m..ai.len() {
                if av[p] != zero {
                    indices.push(ai[p]);
                    data.push(av[p]);
                }
            }
            for p in n..bi.len() {
                if bv[p] != zero {
                    indices.push(bi[p]);
                    data.push(bv[p]);
                }
            }
            rel_indptr.push(indices.len());
        }
        BinChunk { rel_indptr, indices, data }
    }

    /// Intersection-merge kernel over a contiguous row range (the one
    /// and only `multiply` inner loop).
    fn mul_rows(&self, other: &CsrMatrix, s: &dyn Semiring, rows: Range<usize>) -> BinChunk {
        let zero = s.zero();
        let mut rel_indptr = Vec::with_capacity(rows.len());
        // Intersection output is at most the smaller operand's chunk nnz.
        let cap = (self.indptr[rows.end] - self.indptr[rows.start])
            .min(other.indptr[rows.end] - other.indptr[rows.start]);
        let mut indices = Vec::with_capacity(cap);
        let mut data = Vec::with_capacity(cap);
        for r in rows {
            let (ai, av) = self.row(r);
            let (bi, bv) = other.row(r);
            let (mut m, mut n) = (0usize, 0usize);
            while m < ai.len() && n < bi.len() {
                match ai[m].cmp(&bi[n]) {
                    std::cmp::Ordering::Less => m += 1,
                    std::cmp::Ordering::Greater => n += 1,
                    std::cmp::Ordering::Equal => {
                        let v = s.mul(av[m], bv[n]);
                        if v != zero {
                            indices.push(ai[m]);
                            data.push(v);
                        }
                        m += 1;
                        n += 1;
                    }
                }
            }
            rel_indptr.push(indices.len());
        }
        BinChunk { rel_indptr, indices, data }
    }

    /// Map stored values through `f`, pruning results equal to `zero`.
    /// (`Assoc::logical` replaces all stored values by 1 via this.)
    pub fn map_values(&self, zero: f64, mut f: impl FnMut(f64) -> f64) -> CsrMatrix {
        let mut indptr = Vec::with_capacity(self.nrows + 1);
        indptr.push(0);
        let mut indices = Vec::with_capacity(self.nnz());
        let mut data = Vec::with_capacity(self.nnz());
        for r in 0..self.nrows {
            let (ci, cv) = self.row(r);
            for (c, v) in ci.iter().zip(cv) {
                let w = f(*v);
                if w != zero {
                    indices.push(*c);
                    data.push(w);
                }
            }
            indptr.push(indices.len());
        }
        CsrMatrix::from_parts(self.nrows, self.ncols, indptr, indices, data)
    }

    /// Boolean mask of rows with at least one stored entry —
    /// `csr_rows[:-1] < csr_rows[1:]` from paper §II.C.1.
    pub fn nonempty_rows(&self) -> Vec<bool> {
        self.indptr.windows(2).map(|w| w[0] < w[1]).collect()
    }

    /// Boolean mask of columns with at least one stored entry. Computed
    /// by a direct scan of column indices (equivalent to the paper's
    /// `csc_cols` test without materializing CSC).
    pub fn nonempty_cols(&self) -> Vec<bool> {
        let mut mask = vec![false; self.ncols];
        for &c in &self.indices {
            mask[c as usize] = true;
        }
        mask
    }

    /// Select the sub-matrix of rows/cols whose mask bit is set,
    /// renumbering indices densely — the reshape step of `condense`.
    pub fn select(&self, row_mask: &[bool], col_mask: &[bool]) -> CsrMatrix {
        assert_eq!(row_mask.len(), self.nrows);
        assert_eq!(col_mask.len(), self.ncols);
        // Dense old→new column map; u32::MAX marks dropped columns.
        let mut col_map = vec![u32::MAX; self.ncols];
        let mut ncols = 0u32;
        for (c, &keep) in col_mask.iter().enumerate() {
            if keep {
                col_map[c] = ncols;
                ncols += 1;
            }
        }
        let mut indptr = Vec::with_capacity(self.nrows + 1);
        indptr.push(0usize);
        // Upper bound: the kept rows' stored entries.
        let cap: usize = (0..self.nrows)
            .filter(|&r| row_mask[r])
            .map(|r| self.indptr[r + 1] - self.indptr[r])
            .sum();
        let mut indices = Vec::with_capacity(cap);
        let mut data = Vec::with_capacity(cap);
        for r in 0..self.nrows {
            if !row_mask[r] {
                continue;
            }
            let (ci, cv) = self.row(r);
            for (c, v) in ci.iter().zip(cv) {
                let nc = col_map[*c as usize];
                if nc != u32::MAX {
                    indices.push(nc);
                    data.push(*v);
                }
            }
            indptr.push(indices.len());
        }
        shrink_loose(&mut indices, &mut data);
        let nrows = indptr.len() - 1;
        CsrMatrix::from_parts(nrows, ncols as usize, indptr, indices, data)
    }

    /// Gather the sub-matrix `rows × cols` (index lists, order preserved,
    /// duplicates allowed) — the engine behind `Assoc` sub-array
    /// extraction.
    ///
    /// Fast path: when `cols` is duplicate-free and increasing (the
    /// shape every algebra op produces — identity lists and
    /// sorted-intersection maps), gathering is a single re-map pass
    /// with no per-row sort and no per-column allocation. The general
    /// path (duplicates / arbitrary order, reachable via user
    /// selectors) keeps the old→positions multimap.
    pub fn gather(&self, rows: &[usize], cols: &[usize]) -> CsrMatrix {
        // Upper bound on the gathered nnz: the selected rows' stored
        // entries (exact when every column survives).
        let cap: usize = rows
            .iter()
            .map(|&r| {
                assert!(r < self.nrows);
                self.indptr[r + 1] - self.indptr[r]
            })
            .sum();
        let monotone_unique = cols.windows(2).all(|w| w[0] < w[1]);
        if monotone_unique {
            // Dense old→new map; u32::MAX = dropped.
            let mut col_map = vec![u32::MAX; self.ncols];
            for (new_c, &old_c) in cols.iter().enumerate() {
                assert!(old_c < self.ncols);
                col_map[old_c] = new_c as u32;
            }
            let mut indptr = Vec::with_capacity(rows.len() + 1);
            indptr.push(0usize);
            let mut indices: Vec<u32> = Vec::with_capacity(cap);
            let mut data: Vec<f64> = Vec::with_capacity(cap);
            for &old_r in rows {
                let (ci, cv) = self.row(old_r);
                for (c, v) in ci.iter().zip(cv) {
                    let nc = col_map[*c as usize];
                    if nc != u32::MAX {
                        indices.push(nc);
                        data.push(*v);
                    }
                }
                indptr.push(indices.len());
            }
            shrink_loose(&mut indices, &mut data);
            return CsrMatrix::from_parts(rows.len(), cols.len(), indptr, indices, data);
        }
        // General path: old col -> list of new positions.
        let mut col_positions: Vec<Vec<u32>> = vec![Vec::new(); self.ncols];
        for (new_c, &old_c) in cols.iter().enumerate() {
            assert!(old_c < self.ncols);
            col_positions[old_c].push(new_c as u32);
        }
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        indptr.push(0usize);
        let mut indices: Vec<u32> = Vec::with_capacity(cap);
        let mut data: Vec<f64> = Vec::with_capacity(cap);
        let mut scratch: Vec<(u32, f64)> = Vec::new();
        for &old_r in rows {
            assert!(old_r < self.nrows);
            scratch.clear();
            let (ci, cv) = self.row(old_r);
            for (c, v) in ci.iter().zip(cv) {
                for &nc in &col_positions[*c as usize] {
                    scratch.push((nc, *v));
                }
            }
            scratch.sort_unstable_by_key(|&(c, _)| c);
            for &(c, v) in scratch.iter() {
                indices.push(c);
                data.push(v);
            }
            indptr.push(indices.len());
        }
        shrink_loose(&mut indices, &mut data);
        CsrMatrix::from_parts(rows.len(), cols.len(), indptr, indices, data)
    }

    /// Column gather through the cached dual: bit-identical to
    /// `gather(&[0, 1, …, nrows-1], cols)` but column-driven, so it
    /// costs O(|cols| + nnz(selected) + nrows) instead of scanning every
    /// stored entry — the win for narrow column indexing (`A[:, keys]`)
    /// and for the `A.col ∩ B.row` restriction inside `@` once the dual
    /// exists. `cols` must be strictly increasing (the shape every
    /// selector resolution and sorted-intersection map produces).
    pub fn gather_cols(&self, cols: &[usize]) -> CsrMatrix {
        debug_assert!(cols.windows(2).all(|w| w[0] < w[1]), "gather_cols needs sorted cols");
        let t = self.transpose_cached();
        let mut indptr = vec![0usize; self.nrows + 1];
        for &c in cols {
            assert!(c < self.ncols);
            for &r in t.row(c).0 {
                indptr[r as usize + 1] += 1;
            }
        }
        for i in 0..self.nrows {
            indptr[i + 1] += indptr[i];
        }
        let nnz = indptr[self.nrows];
        let mut indices = vec![0u32; nnz];
        let mut data = vec![0f64; nnz];
        let mut next = indptr.clone();
        // Scattering columns in increasing order keeps each output row's
        // entries in increasing (renumbered) column order.
        for (new_c, &c) in cols.iter().enumerate() {
            let (ri, rv) = t.row(c);
            for (r, v) in ri.iter().zip(rv) {
                let q = next[*r as usize];
                next[*r as usize] += 1;
                indices[q] = new_c as u32;
                data[q] = *v;
            }
        }
        CsrMatrix::from_parts(self.nrows, cols.len(), indptr, indices, data)
    }

    /// Reshape into a larger key space: entry `(r, c)` moves to
    /// `(row_map[r], col_map[c])`, shape becomes `nrows × ncols`.
    /// `row_map` must be strictly increasing (so row order is preserved);
    /// `col_map` must be strictly increasing (column order preserved).
    /// This is the re-indexing step of `+` after sorted union.
    pub fn expand(
        &self,
        nrows: usize,
        ncols: usize,
        row_map: &[usize],
        col_map: &[usize],
    ) -> CsrMatrix {
        assert_eq!(row_map.len(), self.nrows);
        assert_eq!(col_map.len(), self.ncols);
        debug_assert!(row_map.windows(2).all(|w| w[0] < w[1]));
        debug_assert!(col_map.windows(2).all(|w| w[0] < w[1]));
        let mut indptr = vec![0usize; nrows + 1];
        for r in 0..self.nrows {
            indptr[row_map[r] + 1] = self.indptr[r + 1] - self.indptr[r];
        }
        for i in 0..nrows {
            indptr[i + 1] += indptr[i];
        }
        let indices: Vec<u32> =
            self.indices.iter().map(|&c| col_map[c as usize] as u32).collect();
        CsrMatrix::from_parts(nrows, ncols, indptr, indices, self.data.clone())
    }

    /// Row-reduce with `s.add`, producing a column vector of length
    /// `nrows` (dense): `out[r] = ⊕_c A[r, c]` (`Assoc::sum(axis=1)`).
    pub fn reduce_rows(&self, s: &dyn Semiring) -> Vec<f64> {
        let mut out = vec![s.zero(); self.nrows];
        for r in 0..self.nrows {
            let (_, vals) = self.row(r);
            for &v in vals {
                out[r] = s.add(out[r], v);
            }
        }
        out
    }

    /// Column-reduce with `s.add`: `out[c] = ⊕_r A[r, c]` (`sum(axis=0)`).
    pub fn reduce_cols(&self, s: &dyn Semiring) -> Vec<f64> {
        let mut out = vec![s.zero(); self.ncols];
        for (&c, &v) in self.indices.iter().zip(&self.data) {
            let c = c as usize;
            out[c] = s.add(out[c], v);
        }
        out
    }
}

/// Release over-allocation when a conservative reserve turned out loose
/// (> 2× the final size). `gather`/`select` results are long-lived — a
/// loose upper-bound capacity would stay pinned for the matrix's
/// lifetime, unlike the transient per-chunk buffers the kernels stitch
/// and drop.
fn shrink_loose(indices: &mut Vec<u32>, data: &mut Vec<f64>) {
    if indices.capacity() > 2 * indices.len() {
        indices.shrink_to_fit();
        data.shrink_to_fit();
    }
}

/// One row-range's output from a parallel binary-op kernel, stitched in
/// row order by [`CsrMatrix::rowwise_binary_par`]. `rel_indptr` has no
/// leading zero; entries are offsets relative to the chunk start.
struct BinChunk {
    rel_indptr: Vec<usize>,
    indices: Vec<u32>,
    data: Vec<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::semiring::{MaxPlus, MinPlus, PlusTimes};
    use crate::util::prop::check;
    use crate::util::SplitMix64;

    fn from_triples(n: usize, t: &[(usize, usize, f64)]) -> CsrMatrix {
        let rows: Vec<usize> = t.iter().map(|x| x.0).collect();
        let cols: Vec<usize> = t.iter().map(|x| x.1).collect();
        let vals: Vec<f64> = t.iter().map(|x| x.2).collect();
        CooMatrix::from_triples_aggregate(n, n, &rows, &cols, &vals, 0.0, |a, b| a + b)
            .unwrap()
            .to_csr()
    }

    fn random_csr(r: &mut SplitMix64, n: usize, nnz: usize) -> CsrMatrix {
        let mut t = Vec::new();
        for _ in 0..nnz {
            t.push((r.below_usize(n), r.below_usize(n), r.range_i64(1, 9) as f64));
        }
        from_triples(n, &t)
    }

    #[test]
    fn get_and_row() {
        let m = from_triples(3, &[(0, 1, 2.0), (1, 0, 3.0), (1, 2, 4.0)]);
        assert_eq!(m.get(0, 1), Some(2.0));
        assert_eq!(m.get(1, 1), None);
        let (ci, cv) = m.row(1);
        assert_eq!(ci, &[0, 2]);
        assert_eq!(cv, &[3.0, 4.0]);
    }

    #[test]
    fn add_plus_times() {
        let a = from_triples(2, &[(0, 0, 1.0), (0, 1, 2.0)]);
        let b = from_triples(2, &[(0, 1, 3.0), (1, 1, 4.0)]);
        let c = a.add(&b, &PlusTimes).unwrap();
        assert_eq!(c.get(0, 0), Some(1.0));
        assert_eq!(c.get(0, 1), Some(5.0));
        assert_eq!(c.get(1, 1), Some(4.0));
        assert_eq!(c.nnz(), 3);
    }

    #[test]
    fn add_cancellation_prunes() {
        let a = from_triples(2, &[(0, 0, 1.0)]);
        let b = from_triples(2, &[(0, 0, -1.0)]);
        let c = a.add(&b, &PlusTimes).unwrap();
        assert_eq!(c.nnz(), 0);
    }

    #[test]
    fn add_shape_mismatch() {
        let a = CsrMatrix::zeros(2, 2);
        let b = CsrMatrix::zeros(3, 2);
        assert!(a.add(&b, &PlusTimes).is_err());
    }

    #[test]
    fn multiply_intersects() {
        let a = from_triples(2, &[(0, 0, 2.0), (0, 1, 3.0)]);
        let b = from_triples(2, &[(0, 1, 5.0), (1, 0, 7.0)]);
        let c = a.multiply(&b, &PlusTimes).unwrap();
        assert_eq!(c.nnz(), 1);
        assert_eq!(c.get(0, 1), Some(15.0));
    }

    #[test]
    fn multiply_maxplus_is_add() {
        let a = from_triples(2, &[(0, 0, 2.0)]);
        let b = from_triples(2, &[(0, 0, 5.0)]);
        let c = a.multiply(&b, &MaxPlus).unwrap();
        assert_eq!(c.get(0, 0), Some(7.0));
    }

    #[test]
    fn nonempty_masks() {
        let m = from_triples(3, &[(0, 2, 1.0), (2, 2, 1.0)]);
        assert_eq!(m.nonempty_rows(), vec![true, false, true]);
        assert_eq!(m.nonempty_cols(), vec![false, false, true]);
    }

    #[test]
    fn select_condenses() {
        let m = from_triples(3, &[(0, 2, 1.0), (2, 2, 2.0)]);
        let s = m.select(&m.nonempty_rows(), &m.nonempty_cols());
        assert_eq!(s.shape(), (2, 1));
        assert_eq!(s.get(0, 0), Some(1.0));
        assert_eq!(s.get(1, 0), Some(2.0));
    }

    #[test]
    fn gather_with_duplicates_and_order() {
        let m = from_triples(3, &[(0, 0, 1.0), (1, 1, 2.0), (2, 2, 3.0)]);
        let g = m.gather(&[2, 0, 2], &[1, 2, 2]);
        assert_eq!(g.shape(), (3, 3));
        assert_eq!(g.get(0, 1), Some(3.0)); // row 2, col 2 duplicated
        assert_eq!(g.get(0, 2), Some(3.0));
        assert_eq!(g.get(1, 0), None);
        assert_eq!(g.get(2, 1), Some(3.0));
    }

    #[test]
    fn expand_reindexes() {
        let m = from_triples(2, &[(0, 0, 1.0), (1, 1, 2.0)]);
        let e = m.expand(4, 5, &[1, 3], &[0, 4]);
        assert_eq!(e.shape(), (4, 5));
        assert_eq!(e.get(1, 0), Some(1.0));
        assert_eq!(e.get(3, 4), Some(2.0));
        assert_eq!(e.nnz(), 2);
    }

    #[test]
    fn transpose_involutive() {
        let mut r = SplitMix64::new(5);
        let m = random_csr(&mut r, 8, 30);
        assert_eq!(m.transpose().transpose(), m);
        let t = m.transpose();
        for rr in 0..8 {
            for cc in 0..8 {
                assert_eq!(m.get(rr, cc), t.get(cc, rr));
            }
        }
    }

    #[test]
    fn reduce_rows_and_cols() {
        let m = from_triples(3, &[(0, 0, 1.0), (0, 2, 2.0), (2, 0, 5.0)]);
        assert_eq!(m.reduce_rows(&PlusTimes), vec![3.0, 0.0, 5.0]);
        assert_eq!(m.reduce_cols(&PlusTimes), vec![6.0, 0.0, 2.0]);
    }

    #[test]
    fn map_values_prunes_zeros() {
        let m = from_triples(2, &[(0, 0, 1.0), (1, 1, 2.0)]);
        let logical = m.map_values(0.0, |_| 1.0);
        assert_eq!(logical.get(1, 1), Some(1.0));
        let zeroed = m.map_values(0.0, |v| if v > 1.5 { 0.0 } else { v });
        assert_eq!(zeroed.nnz(), 1);
    }

    #[test]
    fn min_plus_add_respects_inf_zero() {
        let a = from_triples(2, &[(0, 0, 3.0)]);
        let b = from_triples(2, &[(0, 0, 5.0)]);
        let c = a.add(&b, &MinPlus).unwrap();
        assert_eq!(c.get(0, 0), Some(3.0));
    }

    #[test]
    fn prop_add_matches_dense_model() {
        check("CSR add == dense add", 150, |g| {
            let n = 8;
            let a = random_csr(g.rng(), n, 24);
            let b = random_csr(g.rng(), n, 24);
            let c = a.add(&b, &PlusTimes).unwrap();
            for r in 0..n {
                for cc in 0..n {
                    let expect = a.get(r, cc).unwrap_or(0.0) + b.get(r, cc).unwrap_or(0.0);
                    let got = c.get(r, cc).unwrap_or(0.0);
                    assert_eq!(got, expect, "at ({r},{cc})");
                }
            }
        });
    }

    #[test]
    fn prop_multiply_matches_dense_model() {
        check("CSR multiply == dense elementwise", 150, |g| {
            let n = 8;
            let a = random_csr(g.rng(), n, 24);
            let b = random_csr(g.rng(), n, 24);
            let c = a.multiply(&b, &PlusTimes).unwrap();
            for r in 0..n {
                for cc in 0..n {
                    let expect = a.get(r, cc).unwrap_or(0.0) * b.get(r, cc).unwrap_or(0.0);
                    assert_eq!(c.get(r, cc).unwrap_or(0.0), expect);
                }
            }
        });
    }

    #[test]
    fn prop_add_commutes() {
        check("CSR add commutative", 100, |g| {
            let a = random_csr(g.rng(), 8, 20);
            let b = random_csr(g.rng(), 8, 20);
            assert_eq!(a.add(&b, &PlusTimes).unwrap(), b.add(&a, &PlusTimes).unwrap());
        });
    }

    #[test]
    fn prop_add_multiply_parallel_match_serial_bitwise() {
        check("CSR add/multiply par == serial", 20, |g| {
            // Big enough to clear the PAR_MIN_* gates.
            let n = 128;
            let a = random_csr(g.rng(), n, 4000);
            let b = random_csr(g.rng(), n, 4000);
            for s in [&PlusTimes as &dyn crate::semiring::Semiring, &MaxPlus, &MinPlus] {
                let add1 = a.add_par(&b, s, Parallelism::serial()).unwrap();
                let mul1 = a.multiply_par(&b, s, Parallelism::serial()).unwrap();
                for threads in [2, 4, 7] {
                    let par = Parallelism::with_threads(threads);
                    assert_eq!(add1, a.add_par(&b, s, par).unwrap(), "add t={threads}");
                    assert_eq!(mul1, a.multiply_par(&b, s, par).unwrap(), "mul t={threads}");
                }
            }
        });
    }

    #[test]
    fn prop_csc_roundtrip() {
        check("CSR -> CSC -> CSR identity", 100, |g| {
            let a = random_csr(g.rng(), 10, 40);
            assert_eq!(a.to_csc().to_csr(), a);
        });
    }

    #[test]
    fn dual_cache_lifecycle() {
        let mut r = SplitMix64::new(11);
        let m = random_csr(&mut r, 8, 24);
        assert!(!m.has_cached_dual());
        let t1 = m.transpose();
        assert!(m.has_cached_dual());
        // The returned transpose builds its own dual lazily.
        assert!(!t1.has_cached_dual());
        assert_eq!(t1.transpose(), m);
        assert!(t1.has_cached_dual());
        // Repeat calls hit the cache and stay equal.
        assert_eq!(m.transpose(), t1);
        // Clones and equality ignore the cache.
        let c = m.clone();
        assert!(!c.has_cached_dual());
        assert_eq!(c, m);
    }

    #[test]
    fn prop_gather_cols_matches_row_gather() {
        check("gather_cols == gather(identity, cols)", 100, |g| {
            let n = 12;
            let a = random_csr(g.rng(), n, 50);
            // A sorted, unique random column subset.
            let mut cols: Vec<usize> =
                (0..n).filter(|_| g.rng().chance(0.5)).collect();
            if cols.is_empty() {
                cols.push(g.rng().below_usize(n));
            }
            let identity: Vec<usize> = (0..n).collect();
            let expect = a.gather(&identity, &cols);
            let got = a.gather_cols(&cols);
            assert_eq!(expect, got);
            let bits = |m: &CsrMatrix| -> Vec<u64> {
                m.values().iter().map(|v| v.to_bits()).collect()
            };
            assert_eq!(bits(&expect), bits(&got));
        });
    }
}
