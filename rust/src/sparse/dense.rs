//! Dense blocks — the scatter/gather boundary between the sparse host
//! representation and the AOT-compiled Pallas kernel.
//!
//! The accelerated `@` path in [`crate::runtime`] works on fixed-size
//! dense `f32` tiles: a [`CsrMatrix`] region is scattered into a
//! [`DenseBlock`], the PJRT executable contracts the tiles, and the
//! result is gathered back into sparse form, pruning semiring zeros.

use super::{CooMatrix, CsrMatrix};

/// A dense row-major `f32` block (the PJRT kernels run in `f32` — the
/// MXU-native matmul dtype; D4M numeric values are small integers and
/// survive the round-trip exactly up to 2^24).
#[derive(Debug, Clone, PartialEq)]
pub struct DenseBlock {
    nrows: usize,
    ncols: usize,
    data: Vec<f32>,
}

impl DenseBlock {
    /// Block filled with `fill` (use the semiring zero).
    pub fn filled(nrows: usize, ncols: usize, fill: f32) -> Self {
        DenseBlock { nrows, ncols, data: vec![fill; nrows * ncols] }
    }

    /// Shape `(nrows, ncols)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.nrows, self.ncols)
    }

    /// Row-major data slice.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable row-major data slice.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Element accessor.
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.ncols + c]
    }

    /// Element setter.
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.ncols + c] = v;
    }

    /// Scatter a CSR matrix into a dense block of shape `(bh, bw)`
    /// (padding with `fill`). The matrix must fit.
    pub fn scatter_from(csr: &CsrMatrix, bh: usize, bw: usize, fill: f32) -> Self {
        let (m, n) = csr.shape();
        assert!(m <= bh && n <= bw, "matrix {m}x{n} does not fit block {bh}x{bw}");
        let mut block = DenseBlock::filled(bh, bw, fill);
        for r in 0..m {
            let (ci, cv) = csr.row(r);
            for (c, v) in ci.iter().zip(cv) {
                block.data[r * bw + *c as usize] = *v as f32;
            }
        }
        block
    }

    /// Gather back to CSR, keeping the leading `m × n` region and
    /// pruning entries equal to `zero`.
    pub fn gather_to_csr(&self, m: usize, n: usize, zero: f64) -> CsrMatrix {
        assert!(m <= self.nrows && n <= self.ncols);
        // Reserve the worst case (fully dense region) so the push loop
        // never reallocates; blocks are small fixed tiles.
        let mut rows = Vec::with_capacity(m * n);
        let mut cols = Vec::with_capacity(m * n);
        let mut vals = Vec::with_capacity(m * n);
        for r in 0..m {
            for c in 0..n {
                let v = self.data[r * self.ncols + c] as f64;
                if v != zero {
                    rows.push(r);
                    cols.push(c);
                    vals.push(v);
                }
            }
        }
        CooMatrix::from_triples_aggregate(m, n, &rows, &cols, &vals, zero, |a, _| a)
            .expect("gather triples are well-formed")
            .into_csr()
    }

    /// Density of the leading `m × n` region of a CSR matrix — the
    /// dispatch heuristic for the accelerated path.
    pub fn density(csr: &CsrMatrix) -> f64 {
        let (m, n) = csr.shape();
        if m == 0 || n == 0 {
            return 0.0;
        }
        csr.nnz() as f64 / (m as f64 * n as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooMatrix;

    fn sample_csr() -> CsrMatrix {
        CooMatrix::from_triples_aggregate(
            2,
            3,
            &[0, 1, 1],
            &[1, 0, 2],
            &[5.0, 2.0, 7.0],
            0.0,
            f64::min,
        )
        .unwrap()
        .to_csr()
    }

    #[test]
    fn scatter_pads_with_fill() {
        let b = DenseBlock::scatter_from(&sample_csr(), 4, 4, 0.0);
        assert_eq!(b.shape(), (4, 4));
        assert_eq!(b.get(0, 1), 5.0);
        assert_eq!(b.get(1, 0), 2.0);
        assert_eq!(b.get(1, 2), 7.0);
        assert_eq!(b.get(3, 3), 0.0);
    }

    #[test]
    fn gather_roundtrip() {
        let csr = sample_csr();
        let b = DenseBlock::scatter_from(&csr, 4, 4, 0.0);
        let back = b.gather_to_csr(2, 3, 0.0);
        assert_eq!(back, csr);
    }

    #[test]
    fn gather_prunes_zero() {
        let mut b = DenseBlock::filled(2, 2, 0.0);
        b.set(0, 0, 3.0);
        let csr = b.gather_to_csr(2, 2, 0.0);
        assert_eq!(csr.nnz(), 1);
    }

    #[test]
    fn min_plus_fill_roundtrip() {
        // Tropical kernels pad with +inf; gather must prune it back out.
        let csr = sample_csr();
        let b = DenseBlock::scatter_from(&csr, 4, 4, f32::INFINITY);
        let back = b.gather_to_csr(2, 3, f64::INFINITY);
        assert_eq!(back, csr);
    }

    #[test]
    #[should_panic(expected = "does not fit")]
    fn scatter_overflow_panics() {
        DenseBlock::scatter_from(&sample_csr(), 1, 1, 0.0);
    }

    #[test]
    fn density_calc() {
        let d = DenseBlock::density(&sample_csr());
        assert!((d - 3.0 / 6.0).abs() < 1e-12);
        assert_eq!(DenseBlock::density(&CsrMatrix::zeros(0, 0)), 0.0);
    }
}
