//! COOrdinate-format sparse matrix (row, col, value triples).
//!
//! This is D4M.py's `A.adj` storage format (`scipy.sparse.coo_matrix`).
//! Construction from unsorted triples with collision aggregation is the
//! hot path of the `Assoc` constructor (paper Figures 3–4), so
//! [`CooMatrix::from_triples_aggregate`] is written as one sort + one
//! linear aggregation pass over index pairs packed into `u64`s.

use super::{CsrMatrix, SparseError};

/// Sparse matrix in COO format. Invariants after construction:
/// entries are sorted row-major (row, then col), unique, and no stored
/// value equals the `zero` it was constructed with.
#[derive(Debug, Clone, PartialEq)]
pub struct CooMatrix {
    nrows: usize,
    ncols: usize,
    rows: Vec<u32>,
    cols: Vec<u32>,
    data: Vec<f64>,
}

impl CooMatrix {
    /// Maximum extent along either axis (indices are stored as `u32`).
    pub const MAX_EXTENT: usize = u32::MAX as usize;

    /// Empty matrix of the given shape.
    pub fn zeros(nrows: usize, ncols: usize) -> Self {
        assert!(nrows <= Self::MAX_EXTENT && ncols <= Self::MAX_EXTENT);
        CooMatrix { nrows, ncols, rows: Vec::new(), cols: Vec::new(), data: Vec::new() }
    }

    /// Build from triples, aggregating duplicate `(row, col)` pairs with
    /// `agg` and dropping entries equal to `zero`.
    ///
    /// `agg` must be associative and commutative (the paper's constructor
    /// contract) — the order in which colliding values are combined is
    /// unspecified. Cost: one `u64` sort + one linear pass.
    pub fn from_triples_aggregate(
        nrows: usize,
        ncols: usize,
        rows: &[usize],
        cols: &[usize],
        vals: &[f64],
        zero: f64,
        mut agg: impl FnMut(f64, f64) -> f64,
    ) -> Result<Self, SparseError> {
        if rows.len() != cols.len() || cols.len() != vals.len() {
            return Err(SparseError::LengthMismatch {
                rows: rows.len(),
                cols: cols.len(),
                vals: vals.len(),
            });
        }
        assert!(nrows <= Self::MAX_EXTENT && ncols <= Self::MAX_EXTENT);
        // Pack (row, col) into one u64 key; sort a permutation of entry
        // ids by key; aggregate runs of equal keys.
        let n = rows.len();
        let mut keyed: Vec<(u64, u32)> = Vec::with_capacity(n);
        for i in 0..n {
            let (r, c) = (rows[i], cols[i]);
            if r >= nrows {
                return Err(SparseError::IndexOutOfBounds { axis: "row", index: r, extent: nrows });
            }
            if c >= ncols {
                return Err(SparseError::IndexOutOfBounds { axis: "col", index: c, extent: ncols });
            }
            keyed.push((((r as u64) << 32) | c as u64, i as u32));
        }
        // Sort by (key, input-position): deterministic, and runs of equal
        // keys preserve input order so First/Last aggregators are
        // meaningful.
        keyed.sort_unstable();

        let mut out_rows = Vec::with_capacity(n);
        let mut out_cols = Vec::with_capacity(n);
        let mut out_data: Vec<f64> = Vec::with_capacity(n);
        let mut i = 0;
        while i < n {
            let key = keyed[i].0;
            let mut acc = vals[keyed[i].1 as usize];
            i += 1;
            while i < n && keyed[i].0 == key {
                acc = agg(acc, vals[keyed[i].1 as usize]);
                i += 1;
            }
            if acc != zero {
                out_rows.push((key >> 32) as u32);
                out_cols.push((key & 0xFFFF_FFFF) as u32);
                out_data.push(acc);
            }
        }
        Ok(CooMatrix { nrows, ncols, rows: out_rows, cols: out_cols, data: out_data })
    }

    /// Build from already-sorted, unique, nonzero triples (no checks
    /// beyond debug assertions). Used by format conversions.
    pub(crate) fn from_sorted_parts(
        nrows: usize,
        ncols: usize,
        rows: Vec<u32>,
        cols: Vec<u32>,
        data: Vec<f64>,
    ) -> Self {
        debug_assert_eq!(rows.len(), cols.len());
        debug_assert_eq!(cols.len(), data.len());
        debug_assert!(rows
            .iter()
            .zip(&cols)
            .zip(rows.iter().skip(1).zip(cols.iter().skip(1)))
            .all(|((r0, c0), (r1, c1))| (r0, c0) < (r1, c1)));
        CooMatrix { nrows, ncols, rows, cols, data }
    }

    /// Shape `(nrows, ncols)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.nrows, self.ncols)
    }

    /// Number of stored (nonzero) entries.
    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// Stored row indices (sorted row-major with [`Self::col_indices`]).
    pub fn row_indices(&self) -> &[u32] {
        &self.rows
    }

    /// Stored column indices.
    pub fn col_indices(&self) -> &[u32] {
        &self.cols
    }

    /// Stored values.
    pub fn values(&self) -> &[f64] {
        &self.data
    }

    /// Iterate stored entries as `(row, col, value)`.
    pub fn iter(&self) -> impl Iterator<Item = (usize, usize, f64)> + '_ {
        self.rows
            .iter()
            .zip(&self.cols)
            .zip(&self.data)
            .map(|((&r, &c), &v)| (r as usize, c as usize, v))
    }

    /// Value at `(row, col)` or `None` if unstored. O(log nnz).
    pub fn get(&self, row: usize, col: usize) -> Option<f64> {
        let key = ((row as u64) << 32) | col as u64;
        // Binary search over the packed row-major key order.
        let mut lo = 0usize;
        let mut hi = self.data.len();
        while lo < hi {
            let mid = (lo + hi) / 2;
            let k = ((self.rows[mid] as u64) << 32) | self.cols[mid] as u64;
            match k.cmp(&key) {
                std::cmp::Ordering::Less => lo = mid + 1,
                std::cmp::Ordering::Greater => hi = mid,
                std::cmp::Ordering::Equal => return Some(self.data[mid]),
            }
        }
        None
    }

    /// The CSR row-pointer array for the (already row-major sorted)
    /// entries: exactly `nrows + 1` slots, built by one counting pass —
    /// no incremental growth.
    fn csr_indptr(&self) -> Vec<usize> {
        let mut indptr = vec![0usize; self.nrows + 1];
        for &r in &self.rows {
            indptr[r as usize + 1] += 1;
        }
        for i in 0..self.nrows {
            indptr[i + 1] += indptr[i];
        }
        indptr
    }

    /// Convert to CSR. O(nnz) — entries are already row-major sorted,
    /// and the column/value arrays are cloned at exactly their final
    /// size.
    pub fn to_csr(&self) -> CsrMatrix {
        let indptr = self.csr_indptr();
        CsrMatrix::from_parts(self.nrows, self.ncols, indptr, self.cols.clone(), self.data.clone())
    }

    /// Convert to CSR, consuming `self`: the column and value arrays
    /// move without any copy (the `Assoc` constructor's path — COO is
    /// only an ingest intermediate there).
    pub fn into_csr(self) -> CsrMatrix {
        let indptr = self.csr_indptr();
        CsrMatrix::from_parts(self.nrows, self.ncols, indptr, self.cols, self.data)
    }

    /// Transpose (swaps shape; re-sorts entries col-major → row-major).
    pub fn transpose(&self) -> CooMatrix {
        let mut entries: Vec<(u32, u32, f64)> = self
            .iter()
            .map(|(r, c, v)| (c as u32, r as u32, v))
            .collect();
        entries.sort_unstable_by_key(|&(r, c, _)| ((r as u64) << 32) | c as u64);
        let mut rows = Vec::with_capacity(entries.len());
        let mut cols = Vec::with_capacity(entries.len());
        let mut data = Vec::with_capacity(entries.len());
        for (r, c, v) in entries {
            rows.push(r);
            cols.push(c);
            data.push(v);
        }
        CooMatrix { nrows: self.ncols, ncols: self.nrows, rows, cols, data }
    }

    /// Densify into row-major `Vec<f64>` with `fill` in unstored slots
    /// (testing / small blocks only).
    pub fn to_dense(&self, fill: f64) -> Vec<f64> {
        let mut out = vec![fill; self.nrows * self.ncols];
        for (r, c, v) in self.iter() {
            out[r * self.ncols + c] = v;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn simple() -> CooMatrix {
        CooMatrix::from_triples_aggregate(
            3,
            4,
            &[0, 2, 1, 0],
            &[1, 3, 0, 1],
            &[5.0, 7.0, 2.0, 3.0],
            0.0,
            |a, b| a + b,
        )
        .unwrap()
    }

    #[test]
    fn aggregates_collisions() {
        let m = simple();
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.get(0, 1), Some(8.0)); // 5 + 3 collided
        assert_eq!(m.get(1, 0), Some(2.0));
        assert_eq!(m.get(2, 3), Some(7.0));
        assert_eq!(m.get(0, 0), None);
    }

    #[test]
    fn sorted_row_major() {
        let m = simple();
        let entries: Vec<_> = m.iter().collect();
        assert_eq!(entries, vec![(0, 1, 8.0), (1, 0, 2.0), (2, 3, 7.0)]);
    }

    #[test]
    fn drops_zeros_after_aggregation() {
        let m = CooMatrix::from_triples_aggregate(
            2,
            2,
            &[0, 0],
            &[0, 0],
            &[3.0, -3.0],
            0.0,
            |a, b| a + b,
        )
        .unwrap();
        assert_eq!(m.nnz(), 0);
    }

    #[test]
    fn drops_explicit_zero_inputs() {
        let m =
            CooMatrix::from_triples_aggregate(2, 2, &[0, 1], &[0, 1], &[0.0, 1.0], 0.0, f64::min)
                .unwrap();
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.get(1, 1), Some(1.0));
    }

    #[test]
    fn respects_nonstandard_zero() {
        // min-plus zero is +inf.
        let m = CooMatrix::from_triples_aggregate(
            2,
            2,
            &[0, 1],
            &[0, 0],
            &[f64::INFINITY, 2.0],
            f64::INFINITY,
            f64::min,
        )
        .unwrap();
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.get(1, 0), Some(2.0));
    }

    #[test]
    fn length_mismatch_rejected() {
        let err =
            CooMatrix::from_triples_aggregate(2, 2, &[0], &[0, 1], &[1.0], 0.0, f64::min)
                .unwrap_err();
        assert!(matches!(err, SparseError::LengthMismatch { .. }));
    }

    #[test]
    fn out_of_bounds_rejected() {
        let err = CooMatrix::from_triples_aggregate(2, 2, &[5], &[0], &[1.0], 0.0, f64::min)
            .unwrap_err();
        assert!(matches!(err, SparseError::IndexOutOfBounds { axis: "row", .. }));
    }

    #[test]
    fn transpose_roundtrip() {
        let m = simple();
        let t = m.transpose();
        assert_eq!(t.shape(), (4, 3));
        assert_eq!(t.get(1, 0), Some(8.0));
        assert_eq!(t.transpose(), m);
    }

    #[test]
    #[allow(clippy::identity_op, clippy::erasing_op)] // spelled-out row*stride+col indexing
    fn to_dense_layout() {
        let m = simple();
        let d = m.to_dense(0.0);
        assert_eq!(d.len(), 12);
        assert_eq!(d[0 * 4 + 1], 8.0);
        assert_eq!(d[1 * 4 + 0], 2.0);
        assert_eq!(d[2 * 4 + 3], 7.0);
    }

    #[test]
    fn into_csr_matches_to_csr() {
        let m = simple();
        let by_ref = m.to_csr();
        assert_eq!(m.into_csr(), by_ref);
    }

    #[test]
    fn empty_matrix() {
        let m = CooMatrix::zeros(5, 7);
        assert_eq!(m.shape(), (5, 7));
        assert_eq!(m.nnz(), 0);
        assert_eq!(m.get(0, 0), None);
        assert_eq!(m.to_csr().nnz(), 0);
    }

    #[test]
    fn prop_matches_hashmap_model() {
        check("COO constructor == HashMap model", 200, |g| {
            let n = 12usize;
            let len = g.rng().below_usize(80);
            let mut rows = Vec::new();
            let mut cols = Vec::new();
            let mut vals = Vec::new();
            for _ in 0..len {
                rows.push(g.rng().below_usize(n));
                cols.push(g.rng().below_usize(n));
                vals.push(g.rng().range_i64(1, 50) as f64);
            }
            let m = CooMatrix::from_triples_aggregate(n, n, &rows, &cols, &vals, 0.0, f64::min)
                .unwrap();
            use std::collections::HashMap;
            let mut model: HashMap<(usize, usize), f64> = HashMap::new();
            for i in 0..len {
                model
                    .entry((rows[i], cols[i]))
                    .and_modify(|v| *v = v.min(vals[i]))
                    .or_insert(vals[i]);
            }
            model.retain(|_, v| *v != 0.0);
            assert_eq!(m.nnz(), model.len());
            for ((r, c), v) in model {
                assert_eq!(m.get(r, c), Some(v), "at ({r},{c})");
            }
        });
    }

    #[test]
    fn prop_csr_roundtrip_preserves_entries() {
        check("COO -> CSR -> COO identity", 200, |g| {
            let n = 10usize;
            let len = g.rng().below_usize(60);
            let mut rows = Vec::new();
            let mut cols = Vec::new();
            let mut vals = Vec::new();
            for _ in 0..len {
                rows.push(g.rng().below_usize(n));
                cols.push(g.rng().below_usize(n));
                vals.push(g.rng().range_i64(1, 9) as f64);
            }
            let m = CooMatrix::from_triples_aggregate(n, n, &rows, &cols, &vals, 0.0, |a, b| {
                a + b
            })
            .unwrap();
            let back = m.to_csr().to_coo();
            assert_eq!(m, back);
        });
    }
}
