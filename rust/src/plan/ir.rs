//! **Build** pass: the logical plan IR kernel entry points construct.
//!
//! A logical plan says *what* a Graphulo kernel reads and combines,
//! never *how*: no scan specs, no range sets, no engine selection.
//! Those are physical concerns the **choose** pass
//! ([`super::choose`]) resolves against per-table statistics. Two node
//! shapes cover every kernel in [`crate::graphulo`]:
//!
//! * [`ScanNode`] — scan + filter + reduce fused into one struct (the
//!   store's scan stack executes them as one pipeline anyway), over a
//!   [`RowSet`]. BFS frontier hops, seeded Jaccard, and degree tables
//!   all lower from this node.
//! * [`MultNode`] — the TableMult contraction with an optional *mask*
//!   node on one output axis ([`MaskAxis`]).
//!
//! The IR's *write* node is implicit: every plan executes into a sink
//! table bound at execution time ([`super::exec`]), mirroring how the
//! kernels have always taken `out: &Arc<Table>`.

use crate::store::{CellFilter, KeyMatch, RowReduce, Table};

/// The row subset a logical scan reads.
#[derive(Debug, Clone)]
pub enum RowSet<'p> {
    /// Every row.
    All,
    /// Exactly these row keys. Order and duplicates do not affect
    /// results (lowering coalesces), but sorted distinct input gives
    /// the sharpest cost estimates.
    Keys(Vec<&'p str>),
}

/// Logical scan: read `table` over `rows`, keep cells passing
/// `filter`, optionally collapse each row through `reduce`.
#[derive(Debug, Clone)]
pub struct ScanNode<'p> {
    /// The table read.
    pub table: &'p Table,
    /// Row subset.
    pub rows: RowSet<'p>,
    /// Optional filter node.
    pub filter: Option<CellFilter>,
    /// Optional per-row reduce node.
    pub reduce: Option<RowReduce>,
}

impl<'p> ScanNode<'p> {
    /// Full-table scan.
    pub fn full(table: &'p Table) -> Self {
        ScanNode { table, rows: RowSet::All, filter: None, reduce: None }
    }

    /// Scan restricted to `keys` rows.
    pub fn over_rows(table: &'p Table, keys: Vec<&'p str>) -> Self {
        ScanNode { rows: RowSet::Keys(keys), ..Self::full(table) }
    }

    /// Attach a filter node.
    pub fn filtered(mut self, f: CellFilter) -> Self {
        self.filter = Some(f);
        self
    }

    /// Attach a reduce node.
    pub fn reduced(mut self, r: RowReduce) -> Self {
        self.reduce = Some(r);
        self
    }
}

/// Which output axis a mask node restricts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MaskAxis {
    /// Keep output rows matching the mask. Output rows of `AᵀB` are
    /// `A`'s column keys, so the mask rides the `A` side.
    Rows,
    /// Keep output columns matching the mask (`B`'s column keys).
    Cols,
}

/// Logical TableMult: `C(c1, c2) ⊕= Σ_r A(r, c1) ⊗ B(r, c2)`,
/// optionally under a mask node on one output axis.
#[derive(Debug, Clone)]
pub struct MultNode<'p> {
    /// Left operand (contracted over rows; its columns become output
    /// rows).
    pub a: &'p Table,
    /// Right operand (contracted over rows; its columns become output
    /// columns).
    pub b: &'p Table,
    /// Optional mask node on one output axis.
    pub mask: Option<(MaskAxis, KeyMatch)>,
}

impl<'p> MultNode<'p> {
    /// Unmasked full product.
    pub fn new(a: &'p Table, b: &'p Table) -> Self {
        MultNode { a, b, mask: None }
    }

    /// Product masked on the output-column axis.
    pub fn col_masked(a: &'p Table, b: &'p Table, keep: KeyMatch) -> Self {
        MultNode { a, b, mask: Some((MaskAxis::Cols, keep)) }
    }

    /// Product masked on the output-row axis.
    pub fn row_masked(a: &'p Table, b: &'p Table, keep: KeyMatch) -> Self {
        MultNode { a, b, mask: Some((MaskAxis::Rows, keep)) }
    }
}
