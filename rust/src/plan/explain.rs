//! Deterministic plan rendering (`EXPLAIN`).
//!
//! One line per decision, in knob order, with provenance — exactly
//! what the choose pass recorded. The output is a pure function of
//! the table *contents* at plan time: statistics lines include cell,
//! run, and dictionary figures but deliberately exclude the mutation
//! version counter, so re-planning an unchanged workload renders the
//! identical string (pinned by the `Explain` stability test).

use super::choose::{MultPlan, ScanPlan};
use super::ir::MaskAxis;
use crate::store::{KeyMatch, TableStats};
use std::fmt::Write as _;

/// Render a mult plan.
pub fn explain_mult(plan: &MultPlan<'_>) -> String {
    let mut s = String::from("TableMult C(c1,c2) (+)= sum_r A(r,c1) (x) B(r,c2)\n");
    match &plan.mask {
        None => s.push_str("  mask: none (full product)\n"),
        Some((axis, keep)) => {
            let ax = match axis {
                MaskAxis::Rows => "rows",
                MaskAxis::Cols => "cols",
            };
            let _ = writeln!(s, "  mask: {ax} {}", render_match(keep));
        }
    }
    let _ = writeln!(s, "  A: {}", render_stats(&plan.ann.a));
    let _ = writeln!(s, "  B: {}", render_stats(&plan.ann.b));
    for d in &plan.decisions {
        let _ = writeln!(s, "  {}: {} [{}]", d.knob, d.pick, d.why);
    }
    s
}

/// Render a scan plan.
pub fn explain_scan(plan: &ScanPlan<'_>) -> String {
    let mut s = String::from("Scan\n");
    let _ = writeln!(s, "  table: {}", render_stats(&plan.stats));
    for d in &plan.decisions {
        let _ = writeln!(s, "  {}: {} [{}]", d.knob, d.pick, d.why);
    }
    s
}

fn render_stats(st: &TableStats) -> String {
    format!(
        "cells={} tablets={} runs={} dict-keys={} sampled-rows={}",
        st.cells, st.tablets, st.runs, st.dict_keys, st.sampled_rows.len()
    )
}

fn render_match(k: &KeyMatch) -> String {
    match k {
        KeyMatch::Equals(v) => format!("equals({v:?})"),
        KeyMatch::Prefix(p) => format!("prefix({p:?})"),
        KeyMatch::Glob(g) => format!("glob({g:?})"),
        KeyMatch::In(set) => format!("in({} keys)", set.len()),
    }
}
