//! Cost-based Graphulo query planner.
//!
//! Accumulo-side Graphulo (paper refs [18], [19]) plans its kernels
//! around pre-computed degree tables; this module is that idea grown
//! into a small query planner for the in-repo store. A kernel call
//! flows through four explicit, individually testable lowering passes:
//!
//! 1. **build** ([`ir`]) — the kernel entry point constructs a logical
//!    plan: scan / filter / reduce / mult / mask nodes, no physical
//!    decisions.
//! 2. **annotate** ([`choose::annotate_scan`] /
//!    [`choose::annotate_mult`]) — per-table statistics
//!    ([`crate::store::TableStats`]: tablet cell counts, run and
//!    dictionary cardinalities, sampled row boundaries) bind to the
//!    nodes, plus range-set cell estimates from
//!    [`crate::store::Table::estimate_cells_in`].
//! 3. **choose** ([`choose`]) — every formerly hard-coded heuristic
//!    becomes a recorded, cost-based decision: masked vs. unmasked
//!    SpGEMM, row-restricted vs. full ingest, filter-as-range-set vs.
//!    filter-as-predicate, combiner at scan vs. at merge, symbolic
//!    output bound. Any knob can be *forced* ([`Choices`]), keeping
//!    the old heuristics callable as frozen physical plans
//!    ([`Choices::frozen`]).
//! 4. **execute** ([`exec`]) — fused scan→filter→SpGEMM→write
//!    pipelines streaming through the snapshot scan path; no
//!    intermediate `Assoc` is materialized.
//!
//! [`explain`] renders any chosen plan as a stable, deterministic
//! multi-line string.
//!
//! **Determinism contract.** Every plan the chooser can emit — for any
//! [`Choices`], any thread count, any physical operator combination —
//! produces bit-identical output tables. The planner moves work, never
//! results; `rust/tests/plan_equivalence.rs` enforces this over the
//! full forced-choice grid.

pub mod choose;
pub mod exec;
pub mod explain;
pub mod ir;

pub use choose::{
    annotate_mult, annotate_scan, choose_mult, choose_scan, plan_mult, plan_scan, Choices,
    CombinerChoice, Decision, EngineChoice, EnginePhys, FilterChoice, IngestChoice, IngestRule,
    MultAnnotations, MultPlan, RowSetChoice, ScanAnnotations, ScanPlan, COMBINER_MIN_DUP,
    SEEK_COST_CELLS, WINDOW_MAX_KEYS,
};
pub use exec::{execute_mult, execute_reduce_write};
pub use explain::{explain_mult, explain_scan};
pub use ir::{MaskAxis, MultNode, RowSet, ScanNode};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assoc::Assoc;
    use crate::store::{
        CellFilter, KeyMatch, RowReduce, ScanRange, SharedStr, Table, TableStore,
    };
    use crate::util::Parallelism;
    use std::collections::BTreeSet;
    use std::sync::Arc;

    /// `rows × cols` grid of unit-weight cells: row keys `r000..`,
    /// column keys `c000..`.
    fn grid_table(store: &TableStore, name: &str, rows: usize, cols: usize) -> Arc<Table> {
        let r: Vec<String> = (0..rows * cols).map(|i| format!("r{:03}", i / cols)).collect();
        let c: Vec<String> = (0..rows * cols).map(|i| format!("c{:03}", i % cols)).collect();
        store.ingest_assoc(name, &Assoc::from_triples(&r, &c, 1.0)).0
    }

    fn pick(decisions: &[Decision], knob: &str) -> String {
        decisions.iter().find(|d| d.knob == knob).unwrap_or_else(|| panic!("{knob}")).pick.clone()
    }

    #[test]
    fn filter_lowering_cost_rules() {
        let store = TableStore::with_defaults();
        let a = grid_table(&store, "a", 6, 4);
        let b = grid_table(&store, "b", 6, 4);
        let planner = Choices::planner();
        // Interval-shaped matchers lower to column windows...
        let p = plan_mult(&MultNode::col_masked(&a, &b, KeyMatch::Prefix("c0".into())), &planner);
        assert_eq!(pick(&p.decisions, "filter"), "windows(1)");
        assert!(p.lead_spec.filters.is_empty());
        let small: BTreeSet<String> = (0..3).map(|i| format!("c{i:03}")).collect();
        let p = plan_mult(&MultNode::col_masked(&a, &b, KeyMatch::In(small)), &planner);
        assert_eq!(pick(&p.decisions, "filter"), "windows(3)");
        // ...globs are not interval-shaped, and an `In` set past the
        // window cap pays more per-cell than the predicate probe.
        let p = plan_mult(&MultNode::col_masked(&a, &b, KeyMatch::Glob("c*1".into())), &planner);
        assert_eq!(pick(&p.decisions, "filter"), "predicate");
        assert_eq!(p.lead_spec.filters.len(), 1);
        let big: BTreeSet<String> =
            (0..WINDOW_MAX_KEYS + 1).map(|i| format!("c{i:03}")).collect();
        let p = plan_mult(&MultNode::col_masked(&a, &b, KeyMatch::In(big)), &planner);
        assert_eq!(pick(&p.decisions, "filter"), "predicate");
    }

    #[test]
    fn forced_filter_choices_clamp() {
        let store = TableStore::with_defaults();
        let a = grid_table(&store, "a", 6, 4);
        let b = grid_table(&store, "b", 6, 4);
        // Windows forced on a non-interval matcher clamps to predicate.
        let mut ch = Choices::planner();
        ch.filter = FilterChoice::Windows;
        let p = plan_mult(&MultNode::col_masked(&a, &b, KeyMatch::Glob("*x".into())), &ch);
        assert_eq!(pick(&p.decisions, "filter"), "predicate");
        // NoPushdown is honored inside a mult plan: the lead scan runs
        // unfiltered and the engine/write-back enforces the mask...
        ch.filter = FilterChoice::NoPushdown;
        let p = plan_mult(&MultNode::col_masked(&a, &b, KeyMatch::Prefix("c0".into())), &ch);
        assert_eq!(pick(&p.decisions, "filter"), "no-pushdown");
        assert!(p.lead_spec.filters.is_empty());
        assert_eq!(p.lead_spec.ranges, vec![ScanRange::all()]);
        // ...but clamps to predicate on a standalone scan, which has no
        // later stage to enforce the dropped filter.
        let node = ScanNode::full(&a).filtered(CellFilter::col(KeyMatch::Prefix("c0".into())));
        let sp = plan_scan(&node, &ch);
        assert_eq!(pick(&sp.decisions, "filter"), "predicate");
        assert_eq!(sp.spec.filters.len(), 1);
    }

    #[test]
    fn rowset_cost_rule() {
        let store = TableStore::with_defaults();
        let t = grid_table(&store, "t", 20, 5); // 100 cells, 5 per row
        let planner = Choices::planner();
        // A selective subset lowers to a coalesced range set.
        let sel = plan_scan(&ScanNode::over_rows(&t, vec!["r000", "r007"]), &planner);
        assert_eq!(pick(&sel.decisions, "rows"), "ranges(2)");
        assert_eq!(sel.spec.ranges.len(), 2);
        // A subset covering the whole table estimates no cheaper than a
        // full scan, so it lowers to an `In` row filter instead.
        let all: Vec<String> = (0..20).map(|i| format!("r{i:03}")).collect();
        let keys: Vec<&str> = all.iter().map(|s| s.as_str()).collect();
        let un = plan_scan(&ScanNode::over_rows(&t, keys), &planner);
        assert_eq!(pick(&un.decisions, "rows"), "in-filter");
        assert_eq!(un.spec.filters.len(), 1);
        // Forcing the other lowering moves work, never results.
        let mut ch = Choices::planner();
        ch.rowset = RowSetChoice::FilterIn;
        let forced = plan_scan(&ScanNode::over_rows(&t, vec!["r000", "r007"]), &ch);
        assert_eq!(pick(&forced.decisions, "rows"), "in-filter");
        assert_eq!(
            t.scan_stream(forced.spec.clone()).collect::<Vec<_>>(),
            t.scan_stream(sel.spec.clone()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn combiner_cost_rule() {
        let store = TableStore::with_defaults();
        // No run statistics yet (dict_keys == 0): combiner stays
        // scan-side, the frozen default.
        let t = grid_table(&store, "mem", 10, 10);
        let node = ScanNode::full(&t).reduced(RowReduce::Count { out_col: "deg".into() });
        let p = plan_scan(&node, &Choices::planner());
        assert_eq!(pick(&p.decisions, "combiner"), "at-scan");
        assert!(p.spec.reduce.is_some() && p.client_reduce.is_none());
        // Compacted with heavy key duplication (100 cells over ~21
        // dictionary keys): still scan-side.
        t.minor_compact().unwrap();
        let p = plan_scan(&node, &Choices::planner());
        assert_eq!(pick(&p.decisions, "combiner"), "at-scan");
        // Compacted all-distinct single-cell rows: scan-side
        // aggregation would shrink nothing, so the reduce moves to the
        // client merge.
        let rows: Vec<String> = (0..50).map(|i| format!("r{i:03}")).collect();
        let cols: Vec<String> = (0..50).map(|i| format!("c{i:03}")).collect();
        let thin = store.ingest_assoc("thin", &Assoc::from_triples(&rows, &cols, 1.0)).0;
        thin.minor_compact().unwrap();
        let node = ScanNode::full(&thin).reduced(RowReduce::Count { out_col: "deg".into() });
        let p = plan_scan(&node, &Choices::planner());
        assert_eq!(pick(&p.decisions, "combiner"), "at-merge");
        assert!(p.spec.reduce.is_none() && p.client_reduce.is_some());
        // Both placements write identical degree tables.
        let merge_out = store.create_table("deg_merge");
        execute_reduce_write(&p, &merge_out, Parallelism::serial());
        let mut forced = Choices::planner();
        forced.combiner = CombinerChoice::AtScan;
        let scan_out = store.create_table("deg_scan");
        execute_reduce_write(&plan_scan(&node, &forced), &scan_out, Parallelism::serial());
        assert_eq!(merge_out.scan(ScanRange::all()), scan_out.scan(ScanRange::all()));
    }

    #[test]
    fn ingest_rule_resolution() {
        let store = TableStore::with_defaults();
        let t = grid_table(&store, "op", 20, 5); // 100 cells, 5 per row
        let few: Vec<SharedStr> = vec!["r000".into(), "r007".into()];
        let many: Vec<SharedStr> = (0..20).map(|i| format!("r{i:03}").into()).collect();
        // Cost rule: a selective survivor set restricts the scan, a
        // covering one falls back to the full pass.
        let rule = IngestRule::Cost { operand_cells: t.stats().cells };
        assert_eq!(rule.spec(&few, &t).ranges.len(), 2);
        assert_eq!(rule.spec(&many, &t).ranges, vec![ScanRange::all()]);
        // Frozen 8x heuristic: 2·8 ≤ 100 restricts, 20·8 > 100 not.
        assert_eq!(IngestRule::Heuristic8x.spec(&few, &t).ranges.len(), 2);
        assert_eq!(IngestRule::Heuristic8x.spec(&many, &t).ranges, vec![ScanRange::all()]);
        // Forced rules ignore the statistics entirely.
        assert_eq!(IngestRule::Ranges.spec(&many, &t).ranges.len(), 20);
        assert_eq!(IngestRule::Full.spec(&few, &t).ranges, vec![ScanRange::all()]);
    }

    #[test]
    fn explain_renders_stably() {
        let store = TableStore::with_defaults();
        let a = grid_table(&store, "a", 6, 4);
        let b = grid_table(&store, "b", 6, 4);
        let node = MultNode::col_masked(&a, &b, KeyMatch::Prefix("c0".into()));
        let first = explain_mult(&plan_mult(&node, &Choices::planner()));
        // Re-planning an unchanged workload renders the identical
        // string (the stability contract EXPLAIN tests pin against).
        assert_eq!(explain_mult(&plan_mult(&node, &Choices::planner())), first);
        assert!(first.starts_with("TableMult"), "{first}");
        assert!(first.contains("mask: cols prefix(\"c0\")"), "{first}");
        assert!(first.contains("A: cells=24 tablets=1 runs=0 dict-keys=0"), "{first}");
        assert!(first.contains("filter: windows(1)"), "{first}");
        assert!(first.contains("engine: masked-spgemm"), "{first}");
        assert!(first.contains("bound: auto"), "{first}");
        let sp = plan_scan(&ScanNode::over_rows(&a, vec!["r001"]), &Choices::planner());
        let scan = explain_scan(&sp);
        assert!(scan.starts_with("Scan\n"), "{scan}");
        assert!(scan.contains("rows: ranges(1)"), "{scan}");
    }
}
