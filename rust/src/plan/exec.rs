//! **Execute** pass: fused physical pipelines.
//!
//! Plans stream: scans feed dictionary-encoded id triples straight
//! into CSR builders ([`ScanSide`], moved here from the pre-planner
//! `graphulo` kernels), the SpGEMM engine runs over the snapshot scan
//! path, and results flow back out through a [`BatchWriter`] — no
//! intermediate `Assoc` (or full `Vec<Triple>`) is ever materialized.
//! The executor is deliberately dumb: every decision was already made
//! by the choose pass and recorded in the plan; the only execution-time
//! resolution is [`IngestRule::spec`], which needs the surviving row
//! set a prior pipeline stage produced.

use super::choose::{EnginePhys, IngestRule, MultPlan, ScanPlan};
use super::ir::MaskAxis;
use crate::semiring::Semiring;
use crate::sparse::{
    spgemm_masked_with_modes_par, spgemm_row_masked_with_modes_par, spgemm_with_modes_par,
    AccumulatorPolicy, CooMatrix, CsrMatrix,
};
use crate::store::{
    format_num, BatchWriter, RowReduce, ScanSpec, SharedStr, Table, Triple, WriterConfig,
    SCAN_BLOCK,
};
use crate::util::intern::StrDict;
use crate::util::Parallelism;
use std::sync::Arc;

/// Execute a lowered mult plan into `out` under semiring `s`,
/// returning the number of result cells written.
///
/// The pipeline is the fused scan→SpGEMM→write path: the lead (mask-
/// carrying) side streams through its lowered spec, the opposite side
/// through the ingest rule resolved against the survivors, the engine
/// enforces the mask at compute or write-back exactly as the plan
/// says. Every engine/lowering combination writes bit-identical cells
/// — a dropped input cell can only feed dropped outputs, and the
/// per-output ⊕ order (ascending contraction row key) never changes.
pub fn execute_mult(
    plan: &MultPlan<'_>,
    out: &Arc<Table>,
    s: &dyn Semiring,
    par: Parallelism,
) -> usize {
    let (sa, sb) = match &plan.mask {
        None => (
            ingest_side(plan.a, ScanSpec::all(), par),
            ingest_side(plan.b, ScanSpec::all(), par),
        ),
        Some((MaskAxis::Rows, _)) => {
            let sa = ingest_side(plan.a, plan.lead_spec.clone(), par);
            let sb = if sa.rows.is_empty() {
                ScanSide::default()
            } else {
                ingest_side(plan.b, plan.ingest.spec(&sa.rows, plan.b), par)
            };
            (sa, sb)
        }
        Some((MaskAxis::Cols, _)) => {
            let sb = ingest_side(plan.b, plan.lead_spec.clone(), par);
            let sa = if sb.rows.is_empty() {
                ScanSide::default()
            } else {
                ingest_side(plan.a, plan.ingest.spec(&sb.rows, plan.a), par)
            };
            (sa, sb)
        }
    };
    if sa.rows.is_empty() && sb.rows.is_empty() {
        return 0;
    }
    // Shared contraction dimension: merged distinct row keys (scans are
    // sorted by row, so this is a linear merge of pointer handles).
    let merged = merge_distinct(&sa.rows, &sb.rows);
    let (ma, cols_a) = sa.into_csr(&merged);
    let (mb, cols_b) = sb.into_csr(&merged);
    // `Aᵀ` row c1 walks the rows containing c1 in ascending key order —
    // the same ⊕ order the streaming row-join produced.
    let at = ma.transpose_cached();
    let policy = AccumulatorPolicy::default();
    let (c, _stats) = match (&plan.mask, plan.engine) {
        (Some((MaskAxis::Cols, keep)), EnginePhys::Masked) => {
            let mask: Vec<bool> = cols_b.iter().map(|c| keep.matches(c)).collect();
            spgemm_masked_with_modes_par(at, &mb, s, par, &mask, policy, plan.bound)
        }
        (Some((MaskAxis::Rows, keep)), EnginePhys::Masked) => {
            let mask: Vec<bool> = cols_a.iter().map(|c| keep.matches(c)).collect();
            spgemm_row_masked_with_modes_par(at, &mb, s, par, &mask, policy, plan.bound)
        }
        (None, _) | (Some(_), EnginePhys::WriteFilter) => {
            spgemm_with_modes_par(at, &mb, s, par, policy, plan.bound)
        }
    }
    .expect("shared row dimension");
    // Under the write-filter engine the compute stage ran unmasked, so
    // the mask drops cells here instead; under the masked engine these
    // predicates are `None` and every computed cell is written.
    let (row_keep, col_keep) = match (&plan.mask, plan.engine) {
        (Some((MaskAxis::Rows, keep)), EnginePhys::WriteFilter) => (Some(keep), None),
        (Some((MaskAxis::Cols, keep)), EnginePhys::WriteFilter) => (None, Some(keep)),
        _ => (None, None),
    };
    let mut w = BatchWriter::new(Arc::clone(out), WriterConfig::default());
    let mut cells = 0usize;
    for (i, c1) in cols_a.iter().enumerate() {
        if row_keep.is_some_and(|k| !k.matches(c1)) {
            continue;
        }
        let (cj, cv) = c.row(i);
        for (j, v) in cj.iter().zip(cv) {
            if *v != s.zero() {
                let c2 = &cols_b[*j as usize];
                if col_keep.is_some_and(|k| !k.matches(c2)) {
                    continue;
                }
                // Output keys are pointer clones of the scanned bytes.
                w.put(Triple::new(c1.clone(), c2.clone(), format_num(*v)));
                cells += 1;
            }
        }
    }
    w.flush().expect("spgemm sink flush");
    cells
}

/// Execute a lowered scan(-reduce) pipeline into `out`, returning the
/// number of triples written. A scan-side reduce rides the spec; a
/// client-side reduce ([`ScanPlan::client_reduce`]) streams raw cells
/// and aggregates here, bit-for-bit like the scan stack's combiner.
pub fn execute_reduce_write(plan: &ScanPlan<'_>, out: &Arc<Table>, par: Parallelism) -> usize {
    let t = plan.table;
    let mut w = BatchWriter::new(Arc::clone(out), WriterConfig::default());
    let written = match (&plan.client_reduce, par.is_serial()) {
        (None, true) => w.put_scan(t.scan_stream(plan.spec.clone().batched(SCAN_BLOCK))),
        (None, false) => {
            let triples = t.scan_spec_par(&plan.spec, par);
            let n = triples.len();
            for tr in triples {
                w.put(tr);
            }
            n
        }
        (Some(r), true) => {
            reduce_write(&mut w, t.scan_stream(plan.spec.clone().batched(SCAN_BLOCK)), r)
        }
        (Some(r), false) => reduce_write(&mut w, t.scan_spec_par(&plan.spec, par).into_iter(), r),
    };
    w.flush().expect("planned scan flush");
    written
}

/// Client-side combiner mirroring the scan stack's `ReduceIter` bit
/// for bit: the first cell starts a row (count 1, accumulator = parsed
/// value, non-numeric parses as 0), later cells fold, a row change
/// emits `(row, out_col, aggregate)`.
fn reduce_write(
    w: &mut BatchWriter,
    triples: impl Iterator<Item = Triple>,
    reduce: &RowReduce,
) -> usize {
    let out_col = match reduce {
        RowReduce::Count { out_col }
        | RowReduce::Sum { out_col }
        | RowReduce::Min { out_col }
        | RowReduce::Max { out_col } => out_col.clone(),
    };
    let emit = |w: &mut BatchWriter, row: SharedStr, count: usize, acc: f64| {
        let val = match reduce {
            RowReduce::Count { .. } => count.to_string(),
            _ => format_num(acc),
        };
        w.put(Triple::new(row, out_col.as_str(), val));
    };
    let mut rows = 0usize;
    let mut cur: Option<SharedStr> = None;
    let mut count = 0usize;
    let mut acc = 0.0f64;
    for t in triples {
        let v: f64 = t.val.parse().unwrap_or(0.0);
        match &cur {
            Some(r) if *r == t.row => {
                count += 1;
                match reduce {
                    RowReduce::Count { .. } => {}
                    RowReduce::Sum { .. } => acc += v,
                    RowReduce::Min { .. } => acc = acc.min(v),
                    RowReduce::Max { .. } => acc = acc.max(v),
                }
            }
            _ => {
                if let Some(prev) = cur.take() {
                    emit(w, prev, count, acc);
                    rows += 1;
                }
                cur = Some(t.row.clone());
                count = 1;
                acc = v;
            }
        }
    }
    if let Some(prev) = cur.take() {
        emit(w, prev, count, acc);
        rows += 1;
    }
    rows
}

/// Stream one operand's stacked scan into a [`ScanSide`] — `spec`
/// carries the plan's pushdown (filters, column windows, and/or a
/// restricting range set); the serial path pulls from the stack
/// triple-by-triple at the full-scan batch size, the parallel path
/// consumes the fanned-out collection without re-allocating it.
fn ingest_side(t: &Table, spec: ScanSpec, par: Parallelism) -> ScanSide {
    let mut side = ScanSide::default();
    if par.is_serial() {
        for tr in t.scan_stream(spec.batched(SCAN_BLOCK)) {
            side.ingest(tr);
        }
    } else {
        for tr in t.scan_spec_par(&spec, par) {
            side.ingest(tr);
        }
    }
    side
}

/// One operand of a mult plan, accumulated directly from a sorted
/// triple stream as dictionary-encoded ids: distinct row keys (shared
/// handles), per-entry local row index, a column [`StrDict`] with
/// per-entry column ids, and parsed values — no `Triple` structs
/// retained, no string bytes copied, no per-cell string compares.
#[derive(Default)]
struct ScanSide {
    rows: Vec<SharedStr>,
    row_of: Vec<u32>,
    cols: StrDict,
    col_of: Vec<u32>,
    vals: Vec<f64>,
}

impl ScanSide {
    /// Fold one streamed triple (stream is (row, col)-sorted). Values
    /// parse like the old streaming join did (`unwrap_or(0.0)`), and
    /// parsed zeros stay stored so non-plus-times semirings see exactly
    /// the cells the table holds.
    fn ingest(&mut self, t: Triple) {
        let Triple { row, col, val } = t;
        if self.rows.last() != Some(&row) {
            self.rows.push(row);
        }
        self.row_of.push((self.rows.len() - 1) as u32);
        self.col_of.push(self.cols.intern(&col));
        self.vals.push(val.parse().unwrap_or(0.0));
    }

    /// Index into a CSR matrix over `merged` (a sorted superset of
    /// `self.rows`). Returns the matrix and its sorted distinct column
    /// keys. String bytes are touched once per distinct column here
    /// (the dictionary sort); per-cell work is two id lookups.
    fn into_csr(self, merged: &[SharedStr]) -> (CsrMatrix, Vec<SharedStr>) {
        let ScanSide { rows, row_of, cols, col_of, vals } = self;
        let (distinct, rank) = cols.into_sorted();
        // Local row index → merged row index (both lists sorted).
        let mut map = vec![0u32; rows.len()];
        let mut p = 0usize;
        for (i, r) in rows.iter().enumerate() {
            while merged[p] != *r {
                p += 1;
            }
            map[i] = p as u32;
        }
        let mut ri: Vec<u32> = Vec::with_capacity(row_of.len());
        let mut ci: Vec<u32> = Vec::with_capacity(col_of.len());
        for (k, &own) in row_of.iter().enumerate() {
            ri.push(map[own as usize]);
            ci.push(rank[col_of[k] as usize]);
        }
        let m = CooMatrix::from_sorted_parts(merged.len(), distinct.len(), ri, ci, vals)
            .into_csr();
        (m, distinct)
    }
}

/// Merge two sorted, distinct key lists into their sorted union
/// (clones are pointer copies).
fn merge_distinct(x: &[SharedStr], y: &[SharedStr]) -> Vec<SharedStr> {
    let mut out = Vec::with_capacity(x.len().max(y.len()));
    let (mut i, mut j) = (0usize, 0usize);
    while i < x.len() || j < y.len() {
        let next = match (x.get(i), y.get(j)) {
            (Some(a), Some(b)) => a.min(b),
            (Some(a), None) => a,
            (None, Some(b)) => b,
            (None, None) => unreachable!(),
        }
        .clone();
        if i < x.len() && x[i] == next {
            i += 1;
        }
        if j < y.len() && y[j] == next {
            j += 1;
        }
        out.push(next);
    }
    out
}
