//! **Annotate** and **choose** passes: bind per-table statistics to a
//! logical plan, then select physical operators by cost.
//!
//! The annotate pass ([`annotate_scan`] / [`annotate_mult`]) attaches
//! [`TableStats`] snapshots (and, for row-restricted scans, a
//! range-set cell estimate from [`Table::estimate_cells_in`]) to the
//! logical nodes. The choose pass ([`choose_scan`] / [`choose_mult`])
//! turns each annotated node into a physical plan, one recorded
//! [`Decision`] per knob:
//!
//! | knob | physical alternatives | cost rule |
//! |---|---|---|
//! | `rows` | multi-range set vs. full scan + `In` row filter | `est + seeks < stored cells` |
//! | `filter` | column windows vs. predicate | interval-shaped and ≤ [`WINDOW_MAX_KEYS`] |
//! | `ingest` | restrict the non-mask side vs. full scan | `est + seeks < stored cells`, at execution |
//! | `engine` | masked SpGEMM vs. unmasked + write-back filter | masked always wins; write-filter is forced-only |
//! | `bound` | symbolic output bound ([`SymbolicBound`]) | `Auto` upgrades inside the SpGEMM |
//! | `combiner` | reduce at scan vs. at the client merge | mean key duplication ≥ [`COMBINER_MIN_DUP`] |
//!
//! Every knob can be *forced* through [`Choices`], which is how the
//! pre-planner heuristics stay callable ([`Choices::frozen`]) and how
//! the equivalence suite pins every physical alternative to the same
//! bits. **Determinism contract:** any plan the chooser can emit —
//! cost-picked or forced — produces bit-identical output; the choices
//! move only work, never results.

use super::ir::{MaskAxis, MultNode, RowSet, ScanNode};
use crate::sparse::SymbolicBound;
use crate::store::{
    CellField, CellFilter, KeyMatch, RowReduce, ScanRange, ScanSpec, SharedStr, Table, TableStats,
};
use std::collections::BTreeSet;

/// Largest `In`-set lowered to per-key column windows. Each examined
/// cell pays one binary hop per live window in its row's range set, so
/// beyond a modest set size the predicate (one hash probe per cell)
/// wins back.
pub const WINDOW_MAX_KEYS: usize = 64;

/// Cost of one range seek in examined-cell equivalents: a range hop
/// re-locates every layer cursor (binary searches plus a possible
/// block fault), worth roughly this many sequential cell copies.
pub const SEEK_COST_CELLS: usize = 4;

/// Minimum mean key-duplication factor (stored cells per dictionary
/// key) at which a combiner runs inside the scan stack: below it, rows
/// mostly hold one cell, so scan-side aggregation shrinks nothing and
/// only adds per-cell iterator work.
pub const COMBINER_MIN_DUP: usize = 2;

/// How the non-mask-side operand of a masked mult is restricted to the
/// mask side's surviving contraction rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum IngestChoice {
    /// Cost-based: restrict when the estimated restricted cells plus
    /// seek overhead undercut the full scan (resolved at execution,
    /// when the surviving rows exist).
    #[default]
    Cost,
    /// The frozen PR 5 heuristic: restrict when `8·rows ≤ len`.
    Heuristic8x,
    /// Always scan the restricted range set.
    Ranges,
    /// Always scan the full operand.
    Full,
}

/// How a sink mask (or column filter) lowers into the carrying scan.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FilterChoice {
    /// Cost-based: column windows when interval-shaped and at most
    /// [`WINDOW_MAX_KEYS`] windows, else a pushed-down predicate.
    #[default]
    Cost,
    /// Always a pushed-down predicate (the frozen PR 5 behavior).
    Predicate,
    /// Always column windows (clamped to predicate when the matcher is
    /// not interval-shaped).
    Windows,
    /// No pushdown at all: scan everything, enforce the mask at the
    /// compute/write stage. Only honored inside a mult plan (a
    /// standalone scan has no later enforcement stage); the naive
    /// baseline leg of the equivalence tests.
    NoPushdown,
}

/// Which engine enforces a mult's mask at the compute stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineChoice {
    /// Cost-based (always resolves to masked SpGEMM: it computes only
    /// kept outputs, strictly less work than compute-then-drop).
    #[default]
    Cost,
    /// Force the masked SpGEMM engine.
    MaskedSpGemm,
    /// Force an unmasked SpGEMM with the mask applied at write-back —
    /// the multiply-then-filter baseline, kept forced-only.
    WriteFilter,
}

/// Where a scan's per-row reduce node runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CombinerChoice {
    /// Cost-based: scan-side when mean key duplication is at least
    /// [`COMBINER_MIN_DUP`] (or no run statistics exist), else at the
    /// client merge.
    #[default]
    Cost,
    /// Always inside the scan stack (the frozen behavior).
    AtScan,
    /// Always at the client merge: the scan streams raw cells and the
    /// executor aggregates, bit-for-bit like the scan stack would.
    AtMerge,
}

/// How a [`RowSet::Keys`] restriction lowers into the scan.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RowSetChoice {
    /// Cost-based: range set when `est + seeks < stored cells`.
    #[default]
    Cost,
    /// Always a coalesced single-row range set (the frozen behavior).
    Ranges,
    /// Always a full scan under an `In` row filter.
    FilterIn,
}

/// One knob per physical decision. `Cost` variants (the default) let
/// the chooser decide from [`TableStats`]; any other value forces that
/// physical operator. Forced plans are how the pre-planner heuristics
/// stay callable ([`Choices::frozen`]) and how the equivalence tests
/// pin every operator combination to identical bits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Choices {
    /// Non-mask-side restriction rule.
    pub ingest: IngestChoice,
    /// Mask/filter lowering rule.
    pub filter: FilterChoice,
    /// Mask enforcement engine.
    pub engine: EngineChoice,
    /// SpGEMM symbolic output bound.
    pub bound: SymbolicBound,
    /// Reduce placement.
    pub combiner: CombinerChoice,
    /// Row-subset lowering rule.
    pub rowset: RowSetChoice,
}

impl Choices {
    /// Every knob cost-based — what the public kernels use.
    pub fn planner() -> Self {
        Choices {
            ingest: IngestChoice::Cost,
            filter: FilterChoice::Cost,
            engine: EngineChoice::Cost,
            bound: SymbolicBound::Auto,
            combiner: CombinerChoice::Cost,
            rowset: RowSetChoice::Cost,
        }
    }

    /// The pre-planner behavior, frozen: `8·rows ≤ len` ingest
    /// heuristic, predicate filter pushdown, masked SpGEMM,
    /// `min(flops, ncols)` bound, scan-side combiner, range-set row
    /// subsets. The benchmark baseline every planner leg is measured
    /// against.
    pub fn frozen() -> Self {
        Choices {
            ingest: IngestChoice::Heuristic8x,
            filter: FilterChoice::Predicate,
            engine: EngineChoice::MaskedSpGemm,
            bound: SymbolicBound::MinFlopsCols,
            combiner: CombinerChoice::AtScan,
            rowset: RowSetChoice::Ranges,
        }
    }
}

impl Default for Choices {
    fn default() -> Self {
        Choices::planner()
    }
}

/// One resolved decision with provenance — the unit `EXPLAIN` renders
/// ([`super::explain`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Decision {
    /// The knob decided: `rows`, `filter`, `ingest`, `engine`,
    /// `bound`, or `combiner`.
    pub knob: &'static str,
    /// The physical pick, e.g. `windows(3)`.
    pub pick: String,
    /// Provenance: `forced`, or the cost inputs that decided it.
    pub why: String,
}

impl Decision {
    fn new(knob: &'static str, pick: impl Into<String>, why: impl Into<String>) -> Self {
        Decision { knob, pick: pick.into(), why: why.into() }
    }
}

/// Output of the annotate pass over one [`ScanNode`].
#[derive(Debug, Clone)]
pub struct ScanAnnotations {
    /// Statistics of the scanned table at annotation time.
    pub stats: TableStats,
    /// Coalesced single-row ranges for a [`RowSet::Keys`] subset.
    pub row_ranges: Option<Vec<ScanRange>>,
    /// Estimated stored cells inside `row_ranges`.
    pub est_row_cells: Option<usize>,
}

/// Annotate a scan node: bind table statistics and, for row-restricted
/// scans, the restricted-cell estimate the chooser weighs against a
/// full scan.
pub fn annotate_scan(node: &ScanNode<'_>) -> ScanAnnotations {
    let stats = node.table.stats();
    let (row_ranges, est_row_cells) = match &node.rows {
        RowSet::All => (None, None),
        RowSet::Keys(keys) => {
            let ranges = ScanSpec::ranges(keys.iter().map(|k| ScanRange::single(*k))).ranges;
            let est = node.table.estimate_cells_in(&ranges);
            (Some(ranges), Some(est))
        }
    };
    ScanAnnotations { stats, row_ranges, est_row_cells }
}

/// Output of the annotate pass over a [`MultNode`]: statistics of both
/// operands.
#[derive(Debug, Clone)]
pub struct MultAnnotations {
    /// `A`-side statistics.
    pub a: TableStats,
    /// `B`-side statistics.
    pub b: TableStats,
}

/// Annotate a mult node.
pub fn annotate_mult(node: &MultNode<'_>) -> MultAnnotations {
    MultAnnotations { a: node.a.stats(), b: node.b.stats() }
}

/// Physical rule restricting the non-mask side of a masked mult. The
/// surviving row set does not exist until the mask side has been
/// scanned, so the choose pass emits a *rule* and the executor binds
/// it to the discovered rows ([`IngestRule::spec`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum IngestRule {
    /// Always one [`ScanRange::single`] per surviving row.
    Ranges,
    /// Always the full operand.
    Full,
    /// Restrict when `8·rows ≤ len` (the frozen PR 5 rule).
    Heuristic8x,
    /// Restrict when `est(ranges) + SEEK_COST_CELLS·|ranges| <
    /// operand_cells`.
    Cost {
        /// The operand's stored-cell count at annotation time.
        operand_cells: usize,
    },
}

impl IngestRule {
    /// Resolve the rule against the surviving contraction rows: the
    /// spec the operand's ingest scan runs with.
    pub fn spec(&self, rows: &[SharedStr], operand: &Table) -> ScanSpec {
        let singles = || ScanSpec::ranges(rows.iter().map(|r| ScanRange::single(r.as_str())));
        match self {
            IngestRule::Ranges => singles(),
            IngestRule::Full => ScanSpec::all(),
            IngestRule::Heuristic8x => {
                if rows.len().saturating_mul(8) <= operand.len() {
                    singles()
                } else {
                    ScanSpec::all()
                }
            }
            IngestRule::Cost { operand_cells } => {
                let spec = singles();
                let est = operand.estimate_cells_in(&spec.ranges);
                let seeks = SEEK_COST_CELLS.saturating_mul(spec.ranges.len());
                if est.saturating_add(seeks) < *operand_cells {
                    spec
                } else {
                    ScanSpec::all()
                }
            }
        }
    }
}

/// Physical mask-enforcement engine of a mult plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EnginePhys {
    /// Masked SpGEMM: the compute stage skips dropped outputs.
    Masked,
    /// Unmasked SpGEMM; the write-back drops masked cells.
    WriteFilter,
}

/// A fully lowered scan pipeline.
#[derive(Debug, Clone)]
pub struct ScanPlan<'p> {
    /// The table read.
    pub table: &'p Table,
    /// The lowered spec handed to the scan stack.
    pub spec: ScanSpec,
    /// A reduce the executor applies client-side (chosen when
    /// scan-side aggregation would not shrink the stream).
    pub client_reduce: Option<RowReduce>,
    /// The statistics the plan was chosen against.
    pub stats: TableStats,
    /// Decision log, in knob order.
    pub decisions: Vec<Decision>,
}

/// A fully lowered mult pipeline.
#[derive(Debug, Clone)]
pub struct MultPlan<'p> {
    /// Left operand.
    pub a: &'p Table,
    /// Right operand.
    pub b: &'p Table,
    /// The mask node, if any.
    pub mask: Option<(MaskAxis, KeyMatch)>,
    /// Lowered spec for the mask-carrying side (`B` under a column
    /// mask, `A` under a row mask; a full scan when unmasked).
    pub lead_spec: ScanSpec,
    /// Restriction rule for the opposite side.
    pub ingest: IngestRule,
    /// Mask enforcement engine.
    pub engine: EnginePhys,
    /// SpGEMM symbolic output bound.
    pub bound: SymbolicBound,
    /// The statistics the plan was chosen against.
    pub ann: MultAnnotations,
    /// Decision log, in knob order.
    pub decisions: Vec<Decision>,
}

/// Resolved filter lowering: the windows to scan, or `None` for a
/// predicate / no-pushdown outcome.
fn resolve_filter(
    keep: &KeyMatch,
    choice: FilterChoice,
    allow_no_pushdown: bool,
    decisions: &mut Vec<Decision>,
) -> Option<Vec<(String, Option<String>)>> {
    let windows = keep.intervals();
    match (choice, windows) {
        (FilterChoice::NoPushdown, _) if allow_no_pushdown => {
            decisions.push(Decision::new(
                "filter",
                "no-pushdown",
                "forced: mask enforced at the compute/write stage only",
            ));
            None
        }
        (FilterChoice::NoPushdown, _) => {
            decisions.push(Decision::new(
                "filter",
                "predicate",
                "forced no-pushdown clamped: a standalone scan has no later enforcement stage",
            ));
            None
        }
        (FilterChoice::Predicate, _) => {
            decisions.push(Decision::new("filter", "predicate", "forced"));
            None
        }
        (FilterChoice::Windows, Some(ivs)) => {
            decisions.push(Decision::new("filter", format!("windows({})", ivs.len()), "forced"));
            Some(ivs)
        }
        (FilterChoice::Windows, None) => {
            decisions.push(Decision::new(
                "filter",
                "predicate",
                "forced windows clamped: matcher is not interval-shaped",
            ));
            None
        }
        (FilterChoice::Cost, Some(ivs)) if ivs.len() <= WINDOW_MAX_KEYS => {
            decisions.push(Decision::new(
                "filter",
                format!("windows({})", ivs.len()),
                format!("cost: interval-shaped, {} <= {WINDOW_MAX_KEYS} windows", ivs.len()),
            ));
            Some(ivs)
        }
        (FilterChoice::Cost, Some(ivs)) => {
            decisions.push(Decision::new(
                "filter",
                "predicate",
                format!("cost: {} windows exceed cap {WINDOW_MAX_KEYS}", ivs.len()),
            ));
            None
        }
        (FilterChoice::Cost, None) => {
            decisions.push(Decision::new(
                "filter",
                "predicate",
                "cost: matcher is not interval-shaped",
            ));
            None
        }
    }
}

/// Column-window intervals as a coalesced range set (unbounded rows,
/// one per-row window per interval).
fn windows_spec(ivs: Vec<(String, Option<String>)>) -> ScanSpec {
    ScanSpec::ranges(ivs.into_iter().map(|(lo, hi)| ScanRange {
        lo: None,
        hi: None,
        col_lo: Some(lo),
        col_hi: hi,
    }))
}

/// Choose pass over an annotated scan node: lower the row subset, the
/// filter, and the reduce placement into a [`ScanPlan`].
///
/// `ann` must come from [`annotate_scan`] over the same node.
pub fn choose_scan<'p>(
    node: &ScanNode<'p>,
    ann: &ScanAnnotations,
    choices: &Choices,
) -> ScanPlan<'p> {
    let mut decisions = Vec::new();
    let mut spec = match (&node.rows, ann.row_ranges.as_ref()) {
        (RowSet::All, _) => ScanSpec::all(),
        (RowSet::Keys(keys), Some(ranges)) => {
            let est = ann.est_row_cells.unwrap_or(0);
            let as_ranges = match choices.rowset {
                RowSetChoice::Ranges => {
                    decisions.push(Decision::new(
                        "rows",
                        format!("ranges({})", ranges.len()),
                        "forced",
                    ));
                    true
                }
                RowSetChoice::FilterIn => {
                    decisions.push(Decision::new("rows", "in-filter", "forced"));
                    false
                }
                RowSetChoice::Cost => {
                    let seeks = SEEK_COST_CELLS.saturating_mul(ranges.len());
                    let selective = est.saturating_add(seeks) < ann.stats.cells;
                    let why = format!(
                        "cost: est {est} cells + {seeks} seek vs {} stored",
                        ann.stats.cells
                    );
                    let pick = if selective {
                        format!("ranges({})", ranges.len())
                    } else {
                        "in-filter".to_string()
                    };
                    decisions.push(Decision::new("rows", pick, why));
                    selective
                }
            };
            if as_ranges {
                ScanSpec::ranges(ranges.iter().cloned())
            } else {
                let set: BTreeSet<String> = keys.iter().map(|k| (*k).to_string()).collect();
                ScanSpec::all().filtered(CellFilter::row(KeyMatch::In(set)))
            }
        }
        (RowSet::Keys(_), None) => {
            unreachable!("ScanAnnotations missing row ranges: annotate the same node")
        }
    };
    if let Some(f) = &node.filter {
        let lowerable = matches!(f.field, CellField::Col) && matches!(node.rows, RowSet::All);
        let windows = if lowerable {
            resolve_filter(&f.matcher, choices.filter, false, &mut decisions)
        } else {
            decisions.push(Decision::new(
                "filter",
                "predicate",
                "only column filters over unrestricted rows lower to windows",
            ));
            None
        };
        spec = match windows {
            Some(ivs) => windows_spec(ivs),
            None => spec.filtered(f.clone()),
        };
    }
    let mut client_reduce = None;
    if let Some(r) = &node.reduce {
        let at_scan = match choices.combiner {
            CombinerChoice::AtScan => {
                decisions.push(Decision::new("combiner", "at-scan", "forced"));
                true
            }
            CombinerChoice::AtMerge => {
                decisions.push(Decision::new("combiner", "at-merge", "forced"));
                false
            }
            CombinerChoice::Cost => {
                let dup = ann.stats.dict_keys == 0
                    || ann.stats.cells >= COMBINER_MIN_DUP.saturating_mul(ann.stats.dict_keys);
                let why = format!(
                    "cost: {} stored cells vs {} dictionary keys (dup >= {COMBINER_MIN_DUP}x \
                     => scan-side)",
                    ann.stats.cells, ann.stats.dict_keys
                );
                decisions.push(Decision::new(
                    "combiner",
                    if dup { "at-scan" } else { "at-merge" },
                    why,
                ));
                dup
            }
        };
        if at_scan {
            spec = spec.reduced(r.clone());
        } else {
            client_reduce = Some(r.clone());
        }
    }
    ScanPlan { table: node.table, spec, client_reduce, stats: ann.stats.clone(), decisions }
}

/// Choose pass over an annotated mult node: lower the mask into the
/// lead scan, pick the opposite side's ingest rule, the enforcement
/// engine, and the symbolic bound into a [`MultPlan`].
///
/// `ann` must come from [`annotate_mult`] over the same node.
pub fn choose_mult<'p>(
    node: &MultNode<'p>,
    ann: &MultAnnotations,
    choices: &Choices,
) -> MultPlan<'p> {
    let mut decisions = Vec::new();
    let (lead_spec, ingest, engine) = match &node.mask {
        None => (ScanSpec::all(), IngestRule::Full, EnginePhys::Masked),
        Some((axis, keep)) => {
            let lead_spec = match resolve_filter(keep, choices.filter, true, &mut decisions) {
                Some(ivs) => windows_spec(ivs),
                None if matches!(choices.filter, FilterChoice::NoPushdown) => ScanSpec::all(),
                None => ScanSpec::all().filtered(CellFilter::col(keep.clone())),
            };
            let operand_cells = match axis {
                MaskAxis::Cols => ann.a.cells,
                MaskAxis::Rows => ann.b.cells,
            };
            let ingest = match choices.ingest {
                IngestChoice::Cost => {
                    decisions.push(Decision::new(
                        "ingest",
                        "cost-rule",
                        format!(
                            "restrict other side when est + {SEEK_COST_CELLS}*ranges < \
                             {operand_cells} stored cells"
                        ),
                    ));
                    IngestRule::Cost { operand_cells }
                }
                IngestChoice::Heuristic8x => {
                    decisions.push(Decision::new("ingest", "heuristic-8x", "forced"));
                    IngestRule::Heuristic8x
                }
                IngestChoice::Ranges => {
                    decisions.push(Decision::new("ingest", "always-ranges", "forced"));
                    IngestRule::Ranges
                }
                IngestChoice::Full => {
                    decisions.push(Decision::new("ingest", "always-full", "forced"));
                    IngestRule::Full
                }
            };
            let engine = match choices.engine {
                EngineChoice::Cost => {
                    decisions.push(Decision::new(
                        "engine",
                        "masked-spgemm",
                        "cost: compute touches only kept outputs",
                    ));
                    EnginePhys::Masked
                }
                EngineChoice::MaskedSpGemm => {
                    decisions.push(Decision::new("engine", "masked-spgemm", "forced"));
                    EnginePhys::Masked
                }
                EngineChoice::WriteFilter => {
                    decisions.push(Decision::new("engine", "write-filter", "forced"));
                    EnginePhys::WriteFilter
                }
            };
            (lead_spec, ingest, engine)
        }
    };
    let (pick, why) = match choices.bound {
        SymbolicBound::MinFlopsCols => ("min-flops-cols", "forced".to_string()),
        SymbolicBound::Exact => ("exact", "forced".to_string()),
        SymbolicBound::Auto => {
            ("auto", "cost: upgrade to exact when bound > 2x input nnz".to_string())
        }
    };
    decisions.push(Decision::new("bound", pick, why));
    MultPlan {
        a: node.a,
        b: node.b,
        mask: node.mask.clone(),
        lead_spec,
        ingest,
        engine,
        bound: choices.bound,
        ann: ann.clone(),
        decisions,
    }
}

/// Annotate + choose over a scan node in one call.
pub fn plan_scan<'p>(node: &ScanNode<'p>, choices: &Choices) -> ScanPlan<'p> {
    choose_scan(node, &annotate_scan(node), choices)
}

/// Annotate + choose over a mult node in one call.
pub fn plan_mult<'p>(node: &MultNode<'p>, choices: &Choices) -> MultPlan<'p> {
    choose_mult(node, &annotate_mult(node), choices)
}
