//! The `A.val` attribute: numeric flag vs. sorted string value pool.
//!
//! Exactly the paper's §II.A storage duality:
//!
//! * **Numeric** arrays: `A.val` is the float `1.0` (a *flag* that values
//!   are numeric) and `A.adj` stores the values directly.
//! * **String** arrays: `A.val` is the sorted vector of unique nonempty
//!   values and `A.adj` stores **1-based** indices into it (`k + 1`,
//!   because 0 is the unstored "empty").
//!
//! The empty array edge case is stored "as if numeric" (paper §II.A) and
//! every consumer that branches on numeric-vs-string treats an empty
//! array as compatible with both.

use std::fmt;

/// The value pool of an associative array.
#[derive(Debug, Clone, PartialEq)]
pub enum Values {
    /// Numeric array: `adj` holds the values themselves (`A.val = 1.0`).
    Numeric,
    /// String array: `adj` holds 1-based indices into this sorted,
    /// unique, nonempty pool.
    Strings(Vec<Box<str>>),
}

impl Values {
    /// Is this the numeric flag?
    pub fn is_numeric(&self) -> bool {
        matches!(self, Values::Numeric)
    }

    /// The string pool, if any.
    pub fn strings(&self) -> Option<&[Box<str>]> {
        match self {
            Values::Numeric => None,
            Values::Strings(v) => Some(v),
        }
    }

    /// Decode a stored `adj` entry into a value view.
    ///
    /// Numeric arrays pass the float through; string arrays treat it as
    /// the 1-based pool index (paper: `A.adj[i,j] = k + 1`).
    pub fn decode(&self, stored: f64) -> Val<'_> {
        match self {
            Values::Numeric => Val::Num(stored),
            Values::Strings(pool) => {
                let k = stored as usize;
                assert!(
                    k >= 1 && k <= pool.len() && stored.fract() == 0.0,
                    "corrupt string-pool index {stored}"
                );
                Val::Str(&pool[k - 1])
            }
        }
    }
}

/// A decoded value: number or string view.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Val<'a> {
    /// Numeric value.
    Num(f64),
    /// String value (borrowed from the pool).
    Str(&'a str),
}

impl Val<'_> {
    /// Numeric content, if numeric.
    pub fn as_num(&self) -> Option<f64> {
        match self {
            Val::Num(v) => Some(*v),
            Val::Str(_) => None,
        }
    }

    /// String content, if a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Val::Str(s) => Some(s),
            Val::Num(_) => None,
        }
    }
}

impl fmt::Display for Val<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Val::Num(v) => {
                // Integers display without a decimal point, matching Key.
                if v.fract() == 0.0 && v.abs() < 1e15 {
                    write!(f, "{}", *v as i64)
                } else {
                    write!(f, "{v}")
                }
            }
            Val::Str(s) => write!(f, "{s}"),
        }
    }
}

/// Constructor value input: a numeric array, a string array, or a scalar
/// broadcast (the paper's `Assoc(rows, cols, 1)` form).
#[derive(Debug, Clone, PartialEq)]
pub enum ValsInput {
    /// One number per triple.
    Num(Vec<f64>),
    /// One string per triple.
    Str(Vec<String>),
    /// A single number broadcast to every triple.
    NumScalar(f64),
    /// A single string broadcast to every triple.
    StrScalar(String),
}

impl ValsInput {
    /// Length, or `None` for scalars (broadcast to any length).
    pub fn len(&self) -> Option<usize> {
        match self {
            ValsInput::Num(v) => Some(v.len()),
            ValsInput::Str(v) => Some(v.len()),
            ValsInput::NumScalar(_) | ValsInput::StrScalar(_) => None,
        }
    }

    /// True when no per-triple values are present.
    pub fn is_empty(&self) -> bool {
        self.len() == Some(0)
    }
}

impl From<Vec<f64>> for ValsInput {
    fn from(v: Vec<f64>) -> Self {
        ValsInput::Num(v)
    }
}

impl From<&[f64]> for ValsInput {
    fn from(v: &[f64]) -> Self {
        ValsInput::Num(v.to_vec())
    }
}

impl From<f64> for ValsInput {
    fn from(v: f64) -> Self {
        ValsInput::NumScalar(v)
    }
}

impl From<i64> for ValsInput {
    fn from(v: i64) -> Self {
        ValsInput::NumScalar(v as f64)
    }
}

impl From<Vec<String>> for ValsInput {
    fn from(v: Vec<String>) -> Self {
        ValsInput::Str(v)
    }
}

impl From<&[&str]> for ValsInput {
    fn from(v: &[&str]) -> Self {
        ValsInput::Str(v.iter().map(|s| s.to_string()).collect())
    }
}

impl From<&str> for ValsInput {
    fn from(v: &str) -> Self {
        ValsInput::StrScalar(v.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numeric_decode_passthrough() {
        let v = Values::Numeric;
        assert_eq!(v.decode(3.5), Val::Num(3.5));
    }

    #[test]
    fn string_decode_one_based() {
        let v = Values::Strings(vec!["alpha".into(), "beta".into()]);
        assert_eq!(v.decode(1.0), Val::Str("alpha"));
        assert_eq!(v.decode(2.0), Val::Str("beta"));
    }

    #[test]
    #[should_panic(expected = "corrupt string-pool index")]
    fn string_decode_zero_is_corrupt() {
        let v = Values::Strings(vec!["alpha".into()]);
        v.decode(0.0); // 0 means "unstored" — must never be decoded
    }

    #[test]
    #[should_panic(expected = "corrupt string-pool index")]
    fn string_decode_out_of_range() {
        let v = Values::Strings(vec!["alpha".into()]);
        v.decode(5.0);
    }

    #[test]
    fn val_display() {
        assert_eq!(Val::Num(4.0).to_string(), "4");
        assert_eq!(Val::Num(4.25).to_string(), "4.25");
        assert_eq!(Val::Str("x").to_string(), "x");
    }

    #[test]
    fn vals_input_conversions() {
        let v: ValsInput = vec![1.0, 2.0].into();
        assert_eq!(v.len(), Some(2));
        let v: ValsInput = 1.0.into();
        assert_eq!(v.len(), None);
        let v: ValsInput = "tag".into();
        assert_eq!(v, ValsInput::StrScalar("tag".to_string()));
        let v: ValsInput = (&["a", "b"][..]).into();
        assert_eq!(v.len(), Some(2));
    }
}
