//! Associative-array keys.
//!
//! D4M key spaces "consist of all strings and numbers" (paper §I.B).
//! [`Key`] is that union, with a *total* order so keys can live in the
//! sorted unique `row`/`col` vectors: numbers order among themselves by
//! value, strings lexicographically, and every number sorts before every
//! string (a fixed, documented convention — D4M.py inherits whatever
//! NumPy's mixed-dtype sort does; any consistent choice preserves the
//! algebra, which only needs *a* total order).
//!
//! `NaN` keys are rejected at construction: a NaN would poison the sort
//! order and can never compare equal to itself on lookup.

use std::cmp::Ordering;
use std::fmt;

/// A row or column key: a finite number or a string.
#[derive(Debug, Clone)]
pub enum Key {
    /// Numeric key (finite `f64`; integers display without a decimal).
    Num(f64),
    /// String key.
    Str(Box<str>),
}

impl Key {
    /// Build a numeric key; panics on NaN (infinite keys are allowed —
    /// they are orderable). `-0.0` is normalized to `0.0`: the two
    /// compare equal as keys, so admitting both representations would
    /// let bit-level digests (see `sorted::keysort`) disagree with
    /// `Key::cmp` about uniqueness.
    pub fn num(v: f64) -> Key {
        assert!(!v.is_nan(), "NaN cannot be an associative-array key");
        Key::Num(if v == 0.0 { 0.0 } else { v })
    }

    /// Build a string key.
    pub fn str(s: impl Into<Box<str>>) -> Key {
        Key::Str(s.into())
    }

    /// The string content, if this is a string key.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Key::Str(s) => Some(s),
            Key::Num(_) => None,
        }
    }

    /// The numeric value, if this is a numeric key.
    pub fn as_num(&self) -> Option<f64> {
        match self {
            Key::Num(v) => Some(*v),
            Key::Str(_) => None,
        }
    }

    /// Total-order comparison against a string key, consistent with
    /// [`Key`]'s `Ord` (every number sorts before every string). This
    /// is the lookup-safe way to search a **mixed** sorted `&[Key]` by
    /// `&str` — `keys.binary_search_by(|k| k.cmp_str(probe))` — and
    /// replaces the former `Borrow<str>` impl, whose empty-string
    /// sentinel let a numeric key alias `""` (a numeric key now simply
    /// orders `Less` than any string, including the empty one).
    pub fn cmp_str(&self, s: &str) -> Ordering {
        match self {
            Key::Num(_) => Ordering::Less,
            Key::Str(me) => me.as_ref().cmp(s),
        }
    }

    /// Equality against a string key: true only for an identical
    /// string key — a numeric key never equals a `&str`, not even `""`.
    pub fn eq_str(&self, s: &str) -> bool {
        self.cmp_str(s) == Ordering::Equal
    }
}

impl PartialEq for Key {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (Key::Num(a), Key::Num(b)) => a == b,
            (Key::Str(a), Key::Str(b)) => a == b,
            _ => false,
        }
    }
}

impl Eq for Key {}

impl PartialOrd for Key {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Key {
    fn cmp(&self, other: &Self) -> Ordering {
        match (self, other) {
            // Finite/non-NaN by construction, so partial_cmp is total here.
            (Key::Num(a), Key::Num(b)) => a.partial_cmp(b).expect("NaN key"),
            (Key::Str(a), Key::Str(b)) => a.cmp(b),
            (Key::Num(_), Key::Str(_)) => Ordering::Less,
            (Key::Str(_), Key::Num(_)) => Ordering::Greater,
        }
    }
}

impl std::hash::Hash for Key {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        match self {
            Key::Num(v) => {
                state.write_u8(0);
                // Normalize -0.0 to 0.0 so equal keys hash equally.
                let v = if *v == 0.0 { 0.0f64 } else { *v };
                state.write_u64(v.to_bits());
            }
            Key::Str(s) => {
                state.write_u8(1);
                s.hash(state);
            }
        }
    }
}

impl fmt::Display for Key {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Key::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 1e15 {
                    write!(f, "{}", *v as i64)
                } else {
                    write!(f, "{v}")
                }
            }
            Key::Str(s) => write!(f, "{s}"),
        }
    }
}

impl From<&str> for Key {
    fn from(s: &str) -> Key {
        Key::str(s)
    }
}

impl From<String> for Key {
    fn from(s: String) -> Key {
        Key::str(s)
    }
}

impl From<&String> for Key {
    fn from(s: &String) -> Key {
        Key::str(s.as_str())
    }
}

impl From<f64> for Key {
    fn from(v: f64) -> Key {
        Key::num(v)
    }
}

impl From<i64> for Key {
    fn from(v: i64) -> Key {
        Key::num(v as f64)
    }
}

impl From<i32> for Key {
    fn from(v: i32) -> Key {
        Key::num(v as f64)
    }
}

impl From<usize> for Key {
    fn from(v: usize) -> Key {
        Key::num(v as f64)
    }
}

impl From<&Key> for Key {
    fn from(k: &Key) -> Key {
        k.clone()
    }
}

/// Convert a slice of key-like things into a `Vec<Key>`.
pub fn keys_from<K: Into<Key> + Clone>(xs: &[K]) -> Vec<Key> {
    xs.iter().cloned().map(Into::into).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_numbers_before_strings() {
        let mut keys = vec![Key::str("a"), Key::num(10.0), Key::str("0"), Key::num(-1.0)];
        keys.sort();
        assert_eq!(
            keys,
            vec![Key::num(-1.0), Key::num(10.0), Key::str("0"), Key::str("a")]
        );
    }

    #[test]
    fn numeric_order_is_by_value_not_lex() {
        assert!(Key::num(2.0) < Key::num(10.0)); // "10" < "2" lexically — numbers aren't strings
    }

    #[test]
    fn string_order_is_lex() {
        assert!(Key::str("10") < Key::str("2")); // the paper's int-cast-to-string keys sort this way
    }

    #[test]
    fn display_forms() {
        assert_eq!(Key::num(3.0).to_string(), "3");
        assert_eq!(Key::num(3.5).to_string(), "3.5");
        assert_eq!(Key::str("abc").to_string(), "abc");
    }

    #[test]
    fn equality_across_variants_is_false() {
        assert_ne!(Key::num(1.0), Key::str("1"));
    }

    #[test]
    #[should_panic(expected = "NaN")]
    fn nan_key_rejected() {
        Key::num(f64::NAN);
    }

    #[test]
    fn hash_consistent_with_eq() {
        use std::collections::HashSet;
        let mut set = HashSet::new();
        set.insert(Key::num(0.0));
        assert!(set.contains(&Key::num(-0.0)));
        set.insert(Key::str("x"));
        assert!(set.contains(&Key::str("x")));
        assert!(!set.contains(&Key::str("y")));
    }

    #[test]
    fn cmp_str_is_lookup_safe_on_mixed_slices() {
        // Regression for the old Borrow<str> sentinel: a numeric key
        // must never alias "" (it sorts before every string instead).
        assert_eq!(Key::num(7.0).cmp_str(""), Ordering::Less);
        assert!(!Key::num(7.0).eq_str(""));
        assert!(Key::str("").eq_str(""));
        assert_eq!(Key::str("m").cmp_str("m"), Ordering::Equal);
        assert_eq!(Key::str("a").cmp_str("m"), Ordering::Less);
        assert_eq!(Key::str("z").cmp_str("m"), Ordering::Greater);
        // Mixed sorted slice: numbers first, then strings (Key::Ord).
        let keys =
            vec![Key::num(-1.0), Key::num(10.0), Key::str(""), Key::str("0"), Key::str("a")];
        // cmp_str agrees with Ord on every (key, probe) pair...
        for probe in ["", "0", "5", "a", "z"] {
            for k in &keys {
                assert_eq!(k.cmp_str(probe), k.cmp(&Key::str(probe)), "{k} vs {probe:?}");
            }
            // ...so binary search by str finds exactly the string key.
            let by_str = keys.binary_search_by(|k| k.cmp_str(probe)).ok();
            let by_key = keys.binary_search(&Key::str(probe)).ok();
            assert_eq!(by_str, by_key, "probe {probe:?}");
        }
        // "" resolves to the empty *string* key, not a numeric key.
        let hit = keys.binary_search_by(|k| k.cmp_str("")).unwrap();
        assert_eq!(keys[hit], Key::str(""));
    }

    #[test]
    fn conversions() {
        let k: Key = "s".into();
        assert_eq!(k, Key::str("s"));
        let k: Key = 7i64.into();
        assert_eq!(k, Key::num(7.0));
        let k: Key = 7usize.into();
        assert_eq!(k, Key::num(7.0));
        let ks = keys_from(&["a", "b"]);
        assert_eq!(ks, vec![Key::str("a"), Key::str("b")]);
    }
}
