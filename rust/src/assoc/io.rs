//! Associative-array I/O: triple TSV files and dense CSV tables.
//!
//! D4M's standard interchange formats:
//!
//! * **TSV triples** (`row \t col \t val` per line) — the write/read
//!   format used for bulk data and the store ingest path.
//! * **CSV tables** — a spreadsheet-shaped file whose first row is the
//!   column keys and first column the row keys; exactly the tabular
//!   rendering of Figure 1.

use super::{Aggregator, Assoc, Key, ValsInput};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Write `a` as TSV triples (`row\tcol\tval`, one nonempty entry per
/// line, row-major order).
pub fn write_tsv(a: &Assoc, path: impl AsRef<Path>) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for (r, c, v) in a.iter() {
        writeln!(w, "{r}\t{c}\t{v}")?;
    }
    w.flush()
}

/// Read TSV triples into an associative array.
///
/// Values are parsed as numbers when *every* value parses as `f64`,
/// otherwise all values are kept as strings (D4M arrays are entirely
/// numeric or entirely string, paper §I.B). Collisions aggregate with
/// `agg`.
pub fn read_tsv(path: impl AsRef<Path>, agg: Aggregator) -> std::io::Result<Assoc> {
    let f = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(f);
    let mut rows: Vec<Key> = Vec::new();
    let mut cols: Vec<Key> = Vec::new();
    let mut vals: Vec<String> = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        if line.is_empty() {
            continue;
        }
        let mut parts = line.splitn(3, '\t');
        let (r, c, v) = match (parts.next(), parts.next(), parts.next()) {
            (Some(r), Some(c), Some(v)) => (r, c, v),
            _ => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("line {}: expected row\\tcol\\tval", lineno + 1),
                ))
            }
        };
        rows.push(Key::str(r));
        cols.push(Key::str(c));
        vals.push(v.to_string());
    }
    let numeric: Option<Vec<f64>> = vals.iter().map(|v| v.parse::<f64>().ok()).collect();
    let vals_input = match numeric {
        Some(nums) => ValsInput::Num(nums),
        None => ValsInput::Str(vals),
    };
    Assoc::try_new(rows, cols, vals_input, agg)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))
}

/// Write `a` as a dense CSV table: header row of column keys, then one
/// line per row key. Cells are quoted when they contain separators.
pub fn write_csv_table(a: &Assoc, path: impl AsRef<Path>) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    write!(w, "")?;
    for c in a.col_keys() {
        write!(w, ",{}", csv_escape(&c.to_string()))?;
    }
    writeln!(w)?;
    for (r, key) in a.row_keys().iter().enumerate() {
        write!(w, "{}", csv_escape(&key.to_string()))?;
        let (ci, cv) = a.adj().row(r);
        let mut cells = vec![String::new(); a.col_keys().len()];
        for (c, v) in ci.iter().zip(cv) {
            cells[*c as usize] = a.values().decode(*v).to_string();
        }
        for cell in cells {
            write!(w, ",{}", csv_escape(&cell))?;
        }
        writeln!(w)?;
    }
    w.flush()
}

/// Read a dense CSV table (first row = column keys, first column = row
/// keys) into an associative array; empty cells are unstored.
pub fn read_csv_table(path: impl AsRef<Path>) -> std::io::Result<Assoc> {
    let content = std::fs::read_to_string(path)?;
    let mut lines = content.lines();
    let header = lines
        .next()
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "empty CSV"))?;
    let col_keys: Vec<String> = split_csv(header).into_iter().skip(1).collect();
    let mut rows: Vec<Key> = Vec::new();
    let mut cols: Vec<Key> = Vec::new();
    let mut vals: Vec<String> = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let fields = split_csv(line);
        let rkey = &fields[0];
        for (j, cell) in fields.iter().skip(1).enumerate() {
            if !cell.is_empty() && j < col_keys.len() {
                rows.push(Key::str(rkey.as_str()));
                cols.push(Key::str(col_keys[j].as_str()));
                vals.push(cell.clone());
            }
        }
    }
    let numeric: Option<Vec<f64>> = vals.iter().map(|v| v.parse::<f64>().ok()).collect();
    let vals_input = match numeric {
        Some(nums) => ValsInput::Num(nums),
        None => ValsInput::Str(vals),
    };
    Assoc::try_new(rows, cols, vals_input, Aggregator::Min)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))
}

fn csv_escape(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Minimal CSV field splitter with quote handling.
fn split_csv(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut field = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(ch) = chars.next() {
        match ch {
            '"' if in_quotes => {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    field.push('"');
                } else {
                    in_quotes = false;
                }
            }
            '"' => in_quotes = true,
            ',' if !in_quotes => {
                out.push(std::mem::take(&mut field));
            }
            c => field.push(c),
        }
    }
    out.push(field);
    out
}

#[cfg(test)]
mod tests {
    use super::super::tests::music;
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("d4m-io-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn tsv_roundtrip_string() {
        let a = music();
        let p = tmp("music.tsv");
        write_tsv(&a, &p).unwrap();
        let b = read_tsv(&p, Aggregator::Min).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn tsv_roundtrip_numeric() {
        let a = Assoc::from_triples(&["r1", "r2"], &["c1", "c2"], vec![1.5, -2.0]);
        let p = tmp("nums.tsv");
        write_tsv(&a, &p).unwrap();
        let b = read_tsv(&p, Aggregator::Min).unwrap();
        assert_eq!(a, b);
        assert!(b.is_numeric());
    }

    #[test]
    fn tsv_bad_line_errors() {
        let p = tmp("bad.tsv");
        std::fs::write(&p, "only_two\tfields\n").unwrap();
        assert!(read_tsv(&p, Aggregator::Min).is_err());
    }

    #[test]
    fn csv_table_roundtrip() {
        let a = music();
        let p = tmp("music.csv");
        write_csv_table(&a, &p).unwrap();
        let b = read_csv_table(&p).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn csv_quoting() {
        let a = Assoc::from_triples(&["r,1"], &["c\"2"], &["va,l\"ue"][..]);
        let p = tmp("quoted.csv");
        write_csv_table(&a, &p).unwrap();
        let b = read_csv_table(&p).unwrap();
        assert_eq!(b.get_str("r,1", "c\"2"), Some("va,l\"ue"));
    }

    #[test]
    fn split_csv_cases() {
        assert_eq!(split_csv("a,b,c"), vec!["a", "b", "c"]);
        assert_eq!(split_csv("\"a,b\",c"), vec!["a,b", "c"]);
        assert_eq!(split_csv("\"x\"\"y\""), vec!["x\"y"]);
        assert_eq!(split_csv(""), vec![""]);
    }
}
