//! Associative arrays — D4M's central data model (paper §I–II).
//!
//! An [`Assoc`] is a finite-support function `A : I × J → V` from pairs
//! of (string or numeric) keys to a semiring's values, stored exactly as
//! the paper's four attributes:
//!
//! * `row` — sorted unique row keys of the nonempty entries,
//! * `col` — sorted unique column keys,
//! * `val` — the numeric flag **or** the sorted unique string pool
//!   ([`Values`]),
//! * `adj` — a sparse matrix of the values (numeric case) or of 1-based
//!   pool indices (string case).
//!
//! One deliberate deviation from D4M.py: `adj` is kept resident in
//! **CSR** rather than COO. D4M.py stores COO and converts to CSR/CSC
//! inside every operation (the paper's own profiling calls out these
//! conversions as a dominant cost of `@`); keeping CSR moves that
//! conversion cost into the constructor once and eliminates it from the
//! operators. COO views remain available via [`Assoc::adj`]`.to_coo()`.
//!
//! Submodules: [`ops`](self) (`+ * @`, transpose, logical, reductions),
//! indexing (sub-array extraction/assignment, D4M string-slice
//! semantics), tabular display, and TSV/CSV I/O.

mod fmt;
mod index;
mod io;
mod key;
mod ops;
mod scalar;
mod schema;
mod values;

pub use index::Selector;
pub use io::{read_csv_table, read_tsv, write_csv_table, write_tsv};
pub use key::{keys_from, Key};
pub use schema::{col2type, val2col};
pub use values::{Val, ValsInput, Values};

use crate::sorted::sort_dedup_with_index;
use crate::sparse::{CooMatrix, CsrMatrix};
use crate::util::Parallelism;

/// Collision-aggregation policy for the constructor (paper §II.A: "an
/// associative, commutative binary operation (default min)").
///
/// `First`/`Last` resolve collisions by input order and are therefore
/// not commutative; they are provided for ingest convenience (matching
/// D4M's practical usage) and documented as order-dependent.
#[derive(Debug, Clone, PartialEq)]
pub enum Aggregator {
    /// Keep the minimum (numeric or lexicographic) — the D4M default.
    Min,
    /// Keep the maximum.
    Max,
    /// Sum values (numeric arrays only).
    Sum,
    /// Multiply values (numeric arrays only).
    Prod,
    /// Keep the first value in input order.
    First,
    /// Keep the last value in input order (assignment semantics).
    Last,
    /// Concatenate strings with a separator (string arrays only).
    Concat(String),
}

/// How the constructor canonicalizes its row/column key spaces. Both
/// encodings produce the **same bytes** for every input and thread
/// count (`tests/dict_equivalence.rs` enforces it); they differ only in
/// cost model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KeyEncoding {
    /// Dictionary encode (PR 4, the default): intern every key to a
    /// dense `u32` id in one O(n) hashing pass, sort only the distinct
    /// keys, resolve ranks through the ids. Strings are compared once
    /// per *distinct* key — the right cost model for the duplicated key
    /// spaces of real workloads (the paper's figures have ≥ 8 cells per
    /// key; scan rebuilds far more).
    #[default]
    Dict,
    /// Digest sort (the PR 1–3 path): sort an order-preserving 64-bit
    /// digest per input *cell*. Kept as the ablation baseline and for
    /// workloads with near-unique keys.
    Sort,
}

/// Errors from associative-array construction.
#[derive(Debug, Clone, PartialEq)]
pub enum AssocError {
    /// Triple inputs cannot be broadcast to one common length.
    LengthMismatch { rows: usize, cols: usize, vals: Option<usize> },
    /// Aggregator incompatible with the value type (e.g. `Sum` on strings).
    BadAggregator { agg: &'static str, value_type: &'static str },
    /// `from_parts` given inconsistent attribute shapes.
    BadParts(String),
}

impl std::fmt::Display for AssocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AssocError::LengthMismatch { rows, cols, vals } => write!(
                f,
                "cannot broadcast triple lengths rows={rows} cols={cols} vals={vals:?}"
            ),
            AssocError::BadAggregator { agg, value_type } => {
                write!(f, "aggregator {agg} is not defined for {value_type} values")
            }
            AssocError::BadParts(msg) => write!(f, "inconsistent Assoc parts: {msg}"),
        }
    }
}

impl std::error::Error for AssocError {}

/// A D4M associative array.
#[derive(Debug, Clone, PartialEq)]
pub struct Assoc {
    pub(crate) row: Vec<Key>,
    pub(crate) col: Vec<Key>,
    pub(crate) val: Values,
    pub(crate) adj: CsrMatrix,
}

impl Assoc {
    // ------------------------------------------------------------------
    // Constructors
    // ------------------------------------------------------------------

    /// The empty associative array (stored as numeric, paper §II.A).
    pub fn empty() -> Assoc {
        Assoc {
            row: Vec::new(),
            col: Vec::new(),
            val: Values::Numeric,
            adj: CsrMatrix::zeros(0, 0),
        }
    }

    /// Full constructor: `Assoc(row, col, val, aggregate=agg)`.
    ///
    /// `rows`/`cols`/`vals` must have one common length after broadcasting
    /// length-1 (or scalar `vals`) inputs. Collisions — duplicate
    /// `(row, col)` pairs — are resolved by `agg`. Entries whose
    /// (aggregated) value is the zero of its algebra (`0.0` for numbers,
    /// `""` for strings) are dropped, and keys that end up with no
    /// nonempty entries do not appear in `row`/`col`.
    pub fn try_new(
        rows: Vec<Key>,
        cols: Vec<Key>,
        vals: ValsInput,
        agg: Aggregator,
    ) -> Result<Assoc, AssocError> {
        Self::try_new_par(rows, cols, vals, agg, Parallelism::current())
    }

    /// [`Assoc::try_new`] with an explicit thread configuration for the
    /// key/value-pool sorts (the constructor hot path, Figures 3–4).
    /// `threads == 1` is the exact serial code path; the result is
    /// byte-identical for every thread count. Uses the default
    /// [`KeyEncoding::Dict`] key canonicalization.
    pub fn try_new_par(
        rows: Vec<Key>,
        cols: Vec<Key>,
        vals: ValsInput,
        agg: Aggregator,
        par: Parallelism,
    ) -> Result<Assoc, AssocError> {
        Self::try_new_with(rows, cols, vals, agg, par, KeyEncoding::default())
    }

    /// [`Assoc::try_new_par`] with an explicit [`KeyEncoding`] — the
    /// full constructor entry point. Both encodings are bit-identical;
    /// the choice only moves cost (the ablation benches time them
    /// against each other).
    pub fn try_new_with(
        rows: Vec<Key>,
        cols: Vec<Key>,
        vals: ValsInput,
        agg: Aggregator,
        par: Parallelism,
        enc: KeyEncoding,
    ) -> Result<Assoc, AssocError> {
        // --- broadcast to a common length -----------------------------
        let n = broadcast_len(rows.len(), cols.len(), vals.len()).ok_or(
            AssocError::LengthMismatch { rows: rows.len(), cols: cols.len(), vals: vals.len() },
        )?;
        if n == 0 {
            return Ok(Assoc::empty());
        }
        let rows = broadcast_keys(rows, n);
        let cols = broadcast_keys(cols, n);

        // --- canonicalize key spaces (with index maps) -----------------
        // Dict: intern to u32 ids, sort distinct keys only (encode
        // once). Sort: specialized digest sort over all cells (see
        // sorted::keysort — itself ~65% of constructor time in the
        // pre-digest profiles). Both shard-parallel when `par` allows.
        let canon = match enc {
            KeyEncoding::Dict => crate::sorted::encode_keys_par,
            KeyEncoding::Sort => crate::sorted::sort_dedup_keys_par,
        };
        let (row_keys, rmap) = canon(&rows, par);
        let (col_keys, cmap) = canon(&cols, par);

        match vals {
            ValsInput::Num(v) => {
                let v = if v.len() == 1 && n > 1 { vec![v[0]; n] } else { v };
                Self::build_numeric(row_keys, col_keys, rmap, cmap, v, agg)
            }
            ValsInput::NumScalar(x) => {
                Self::build_numeric(row_keys, col_keys, rmap, cmap, vec![x; n], agg)
            }
            ValsInput::Str(v) => {
                let v = if v.len() == 1 && n > 1 { vec![v[0].clone(); n] } else { v };
                Self::build_string(row_keys, col_keys, rmap, cmap, v, agg, par)
            }
            ValsInput::StrScalar(s) => {
                Self::build_string(row_keys, col_keys, rmap, cmap, vec![s; n], agg, par)
            }
        }
    }

    /// Pre-encoded constructor: the caller already canonicalized the
    /// key spaces — sorted unique `row_keys`/`col_keys` plus a
    /// per-triple index map into each (`rmap[p]`/`cmap[p]` is triple
    /// `p`'s key position) — so construction skips the key sort
    /// entirely. This is the zero-copy landing pad of the
    /// dictionary-encoded scan path ([`crate::store::stream_to_assoc`]
    /// interns scan cells to ids and hands the dictionary's sorted
    /// output straight in here).
    ///
    /// Scalar `vals` broadcast to the triple count; `Vec` inputs must
    /// match `rmap`'s length exactly (no length-1 broadcast — the
    /// caller encoded per-triple maps, so it knows the length).
    pub fn try_from_encoded(
        row_keys: Vec<Key>,
        col_keys: Vec<Key>,
        rmap: Vec<usize>,
        cmap: Vec<usize>,
        vals: ValsInput,
        agg: Aggregator,
        par: Parallelism,
    ) -> Result<Assoc, AssocError> {
        let n = rmap.len();
        if cmap.len() != n || vals.len().is_some_and(|l| l != n) {
            return Err(AssocError::LengthMismatch {
                rows: n,
                cols: cmap.len(),
                vals: vals.len(),
            });
        }
        if n == 0 {
            return Ok(Assoc::empty());
        }
        if !crate::sorted::is_sorted_unique(&row_keys)
            || !crate::sorted::is_sorted_unique(&col_keys)
        {
            return Err(AssocError::BadParts("encoded keys must be sorted unique".into()));
        }
        if rmap.iter().any(|&i| i >= row_keys.len()) || cmap.iter().any(|&i| i >= col_keys.len()) {
            return Err(AssocError::BadParts("encoded index map out of bounds".into()));
        }
        match vals {
            ValsInput::Num(v) => Self::build_numeric(row_keys, col_keys, rmap, cmap, v, agg),
            ValsInput::NumScalar(x) => {
                Self::build_numeric(row_keys, col_keys, rmap, cmap, vec![x; n], agg)
            }
            ValsInput::Str(v) => Self::build_string(row_keys, col_keys, rmap, cmap, v, agg, par),
            ValsInput::StrScalar(s) => {
                Self::build_string(row_keys, col_keys, rmap, cmap, vec![s; n], agg, par)
            }
        }
    }

    /// Convenience constructor with the D4M default aggregator (`Min`);
    /// panics on length mismatch. Accepts anything key-like and
    /// value-like:
    ///
    /// ```
    /// use d4m::assoc::Assoc;
    /// let a = Assoc::from_triples(&["r1", "r2"], &["c", "c"], &["x", "y"][..]);
    /// assert_eq!(a.nnz(), 2);
    /// let b = Assoc::from_triples(&["r1"], &["c"], 1.0); // scalar broadcast
    /// assert_eq!(b.get_num("r1", "c"), Some(1.0));
    /// ```
    pub fn from_triples<K1, K2, V>(rows: &[K1], cols: &[K2], vals: V) -> Assoc
    where
        K1: Into<Key> + Clone,
        K2: Into<Key> + Clone,
        V: Into<ValsInput>,
    {
        Assoc::try_new(keys_from(rows), keys_from(cols), vals.into(), Aggregator::Min)
            .expect("Assoc::from_triples: bad inputs")
    }

    /// Constructor with an explicit aggregator (still panicking).
    pub fn from_triples_agg<K1, K2, V>(rows: &[K1], cols: &[K2], vals: V, agg: Aggregator) -> Assoc
    where
        K1: Into<Key> + Clone,
        K2: Into<Key> + Clone,
        V: Into<ValsInput>,
    {
        Assoc::try_new(keys_from(rows), keys_from(cols), vals.into(), agg)
            .expect("Assoc::from_triples_agg: bad inputs")
    }

    fn build_numeric(
        row_keys: Vec<Key>,
        col_keys: Vec<Key>,
        rmap: Vec<usize>,
        cmap: Vec<usize>,
        vals: Vec<f64>,
        agg: Aggregator,
    ) -> Result<Assoc, AssocError> {
        if vals.len() != rmap.len() {
            return Err(AssocError::LengthMismatch {
                rows: rmap.len(),
                cols: cmap.len(),
                vals: Some(vals.len()),
            });
        }
        let agg_fn: fn(f64, f64) -> f64 = match agg {
            Aggregator::Min => f64::min,
            Aggregator::Max => f64::max,
            Aggregator::Sum => |a, b| a + b,
            Aggregator::Prod => |a, b| a * b,
            Aggregator::First => |a, _| a,
            Aggregator::Last => |_, b| b,
            Aggregator::Concat(_) => {
                return Err(AssocError::BadAggregator { agg: "Concat", value_type: "numeric" })
            }
        };
        let coo = CooMatrix::from_triples_aggregate(
            row_keys.len(),
            col_keys.len(),
            &rmap,
            &cmap,
            &vals,
            0.0,
            agg_fn,
        )
        .expect("index maps are in bounds by construction");
        let adj = coo.into_csr();
        Ok(Assoc { row: row_keys, col: col_keys, val: Values::Numeric, adj }.condensed())
    }

    fn build_string(
        row_keys: Vec<Key>,
        col_keys: Vec<Key>,
        rmap: Vec<usize>,
        cmap: Vec<usize>,
        vals: Vec<String>,
        agg: Aggregator,
        par: Parallelism,
    ) -> Result<Assoc, AssocError> {
        if vals.len() != rmap.len() {
            return Err(AssocError::LengthMismatch {
                rows: rmap.len(),
                cols: cmap.len(),
                vals: Some(vals.len()),
            });
        }
        match agg {
            Aggregator::Sum => {
                return Err(AssocError::BadAggregator { agg: "Sum", value_type: "string" })
            }
            Aggregator::Prod => {
                return Err(AssocError::BadAggregator { agg: "Prod", value_type: "string" })
            }
            Aggregator::Concat(sep) => {
                // General path: aggregate in string space, then intern.
                return Ok(Self::build_string_concat(row_keys, col_keys, rmap, cmap, vals, &sep));
            }
            _ => {}
        }
        // Fast path (Min/Max/First/Last): intern values first; because
        // the pool is sorted, lexicographic min/max on strings is
        // numeric min/max on (1-based) pool indices.
        let (pool, vmap) = crate::sorted::sort_dedup_strs_par(&vals, par);
        let stored: Vec<f64> = vmap.iter().map(|&k| (k + 1) as f64).collect();
        let agg_fn: fn(f64, f64) -> f64 = match agg {
            Aggregator::Min => f64::min,
            Aggregator::Max => f64::max,
            Aggregator::First => |a, _| a,
            Aggregator::Last => |_, b| b,
            _ => unreachable!(),
        };
        // Note: empty-string values participate in aggregation
        // (min("", "x") == ""); the pool may contain "" at index 1 (it
        // sorts first), stripped after aggregation.
        let coo = CooMatrix::from_triples_aggregate(
            row_keys.len(),
            col_keys.len(),
            &rmap,
            &cmap,
            &stored,
            0.0,
            agg_fn,
        )
        .expect("index maps in bounds");
        let assoc = Assoc {
            row: row_keys,
            col: col_keys,
            val: Values::Strings(pool.into_iter().map(String::into_boxed_str).collect()),
            adj: coo.into_csr(),
        };
        Ok(assoc.strip_empty_string().condense_pool().condensed())
    }

    fn build_string_concat(
        row_keys: Vec<Key>,
        col_keys: Vec<Key>,
        rmap: Vec<usize>,
        cmap: Vec<usize>,
        vals: Vec<String>,
        sep: &str,
    ) -> Assoc {
        // Group triples by (row, col) in row-major order, preserving
        // input order within groups, and concatenate.
        let n = vals.len();
        let mut keyed: Vec<(u64, u32)> = (0..n)
            .map(|i| (((rmap[i] as u64) << 32) | cmap[i] as u64, i as u32))
            .collect();
        keyed.sort_unstable();
        let mut agg_rows = Vec::new();
        let mut agg_cols = Vec::new();
        let mut agg_vals: Vec<String> = Vec::new();
        let mut i = 0;
        while i < n {
            let key = keyed[i].0;
            let mut s = vals[keyed[i].1 as usize].clone();
            i += 1;
            while i < n && keyed[i].0 == key {
                s.push_str(sep);
                s.push_str(&vals[keyed[i].1 as usize]);
                i += 1;
            }
            agg_rows.push((key >> 32) as usize);
            agg_cols.push((key & 0xFFFF_FFFF) as usize);
            agg_vals.push(s);
        }
        let (pool, vmap) = sort_dedup_with_index(&agg_vals);
        let stored: Vec<f64> = vmap.iter().map(|&k| (k + 1) as f64).collect();
        let coo = CooMatrix::from_triples_aggregate(
            row_keys.len(),
            col_keys.len(),
            &agg_rows,
            &agg_cols,
            &stored,
            0.0,
            |a, _| a,
        )
        .expect("aggregated triples are unique");
        let assoc = Assoc {
            row: row_keys,
            col: col_keys,
            val: Values::Strings(pool.into_iter().map(String::into_boxed_str).collect()),
            adj: coo.into_csr(),
        };
        assoc.strip_empty_string().condense_pool().condensed()
    }

    /// The paper's second constructor form: attributes given directly
    /// (`Assoc(row, col, val, adj=sp_mat)`). Validates consistency.
    pub fn from_parts(
        row: Vec<Key>,
        col: Vec<Key>,
        val: Values,
        adj: CsrMatrix,
    ) -> Result<Assoc, AssocError> {
        let (m, n) = adj.shape();
        if row.len() != m || col.len() != n {
            return Err(AssocError::BadParts(format!(
                "adj is {m}x{n} but |row|={} |col|={}",
                row.len(),
                col.len()
            )));
        }
        if !crate::sorted::is_sorted_unique(&row) || !crate::sorted::is_sorted_unique(&col) {
            return Err(AssocError::BadParts("row/col keys must be sorted unique".into()));
        }
        if let Values::Strings(pool) = &val {
            if !pool.windows(2).all(|w| w[0] < w[1]) {
                return Err(AssocError::BadParts("string pool must be sorted unique".into()));
            }
            let k = pool.len() as f64;
            for &v in adj.values() {
                if v.fract() != 0.0 || v < 1.0 || v > k {
                    return Err(AssocError::BadParts(format!(
                        "adj value {v} is not a 1-based pool index (pool size {k})"
                    )));
                }
            }
        }
        Ok(Assoc { row, col, val, adj }.condensed())
    }

    // ------------------------------------------------------------------
    // Attributes (the paper's A.row / A.col / A.val / A.adj)
    // ------------------------------------------------------------------

    /// Sorted unique row keys (`A.row`).
    pub fn row_keys(&self) -> &[Key] {
        &self.row
    }

    /// Sorted unique column keys (`A.col`).
    pub fn col_keys(&self) -> &[Key] {
        &self.col
    }

    /// The value pool / numeric flag (`A.val`).
    pub fn values(&self) -> &Values {
        &self.val
    }

    /// The adjacency sparse matrix (`A.adj`), CSR-resident.
    pub fn adj(&self) -> &CsrMatrix {
        &self.adj
    }

    /// `(number of row keys, number of column keys)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.row.len(), self.col.len())
    }

    /// Number of nonempty entries.
    pub fn nnz(&self) -> usize {
        self.adj.nnz()
    }

    /// True when the array has no entries.
    pub fn is_empty(&self) -> bool {
        self.nnz() == 0
    }

    /// True when values are numeric (the empty array counts as numeric,
    /// paper §II.A).
    pub fn is_numeric(&self) -> bool {
        self.val.is_numeric()
    }

    // ------------------------------------------------------------------
    // Point access
    // ------------------------------------------------------------------

    /// Position of a row key, if present.
    pub fn find_row(&self, key: &Key) -> Option<usize> {
        self.row.binary_search(key).ok()
    }

    /// Position of a column key, if present.
    pub fn find_col(&self, key: &Key) -> Option<usize> {
        self.col.binary_search(key).ok()
    }

    /// Value at `(row, col)`, decoded; `None` when unstored (= the
    /// conventional zero-padding of the full key space, paper §I.A).
    pub fn get(&self, row: impl Into<Key>, col: impl Into<Key>) -> Option<Val<'_>> {
        let (r, c) = (row.into(), col.into());
        let ri = self.find_row(&r)?;
        let ci = self.find_col(&c)?;
        self.adj.get(ri, ci).map(|stored| self.val.decode(stored))
    }

    /// Numeric value at `(row, col)` (`None` if unstored or a string).
    pub fn get_num(&self, row: impl Into<Key>, col: impl Into<Key>) -> Option<f64> {
        self.get(row, col).and_then(|v| v.as_num())
    }

    /// String value at `(row, col)` (`None` if unstored or numeric).
    pub fn get_str(&self, row: impl Into<Key>, col: impl Into<Key>) -> Option<&str> {
        match (self.find_row(&row.into()), self.find_col(&col.into())) {
            (Some(ri), Some(ci)) => match (self.adj.get(ri, ci), &self.val) {
                (Some(stored), Values::Strings(pool)) => Some(&pool[stored as usize - 1]),
                _ => None,
            },
            _ => None,
        }
    }

    /// Iterate all nonempty entries as `(row_key, col_key, value)`.
    pub fn iter(&self) -> impl Iterator<Item = (&Key, &Key, Val<'_>)> + '_ {
        (0..self.row.len()).flat_map(move |r| {
            let (ci, cv) = self.adj.row(r);
            ci.iter()
                .zip(cv)
                .map(move |(c, v)| (&self.row[r], &self.col[*c as usize], self.val.decode(*v)))
        })
    }

    /// Extract the `(rows, cols, vals)` triple lists that reconstruct
    /// this array (the paper's `find`-style extraction used by string
    /// addition). String values are cloned out of the pool.
    pub fn triples(&self) -> (Vec<Key>, Vec<Key>, ValsInput) {
        let mut rows = Vec::with_capacity(self.nnz());
        let mut cols = Vec::with_capacity(self.nnz());
        match &self.val {
            Values::Numeric => {
                let mut vals = Vec::with_capacity(self.nnz());
                for (r, c, v) in self.entries_raw() {
                    rows.push(self.row[r].clone());
                    cols.push(self.col[c].clone());
                    vals.push(v);
                }
                (rows, cols, ValsInput::Num(vals))
            }
            Values::Strings(pool) => {
                let mut vals = Vec::with_capacity(self.nnz());
                for (r, c, v) in self.entries_raw() {
                    rows.push(self.row[r].clone());
                    cols.push(self.col[c].clone());
                    vals.push(pool[v as usize - 1].to_string());
                }
                (rows, cols, ValsInput::Str(vals))
            }
        }
    }

    /// Raw `(row_idx, col_idx, stored_value)` iterator.
    pub(crate) fn entries_raw(&self) -> impl Iterator<Item = (usize, usize, f64)> + '_ {
        (0..self.row.len()).flat_map(move |r| {
            let (ci, cv) = self.adj.row(r);
            ci.iter().zip(cv).map(move |(c, v)| (r, *c as usize, *v))
        })
    }

    // ------------------------------------------------------------------
    // Maintenance (condense & friends — paper §II.C.1)
    // ------------------------------------------------------------------

    /// Remove rows/columns with no nonempty entries, shrinking `row`,
    /// `col` and `adj` consistently — the paper's `.condense()`.
    /// Normalizes a fully-empty result to the canonical empty array.
    pub(crate) fn condensed(self) -> Assoc {
        if self.nnz() == 0 {
            return Assoc::empty();
        }
        let row_mask = self.adj.nonempty_rows();
        let col_mask = self.adj.nonempty_cols();
        if row_mask.iter().all(|&b| b) && col_mask.iter().all(|&b| b) {
            return self; // already condensed — common fast path
        }
        let adj = self.adj.select(&row_mask, &col_mask);
        let row = mask_keys(self.row, &row_mask);
        let col = mask_keys(self.col, &col_mask);
        Assoc { row, col, val: self.val, adj }
    }

    /// Drop string-pool entries no longer referenced by `adj`, and
    /// renumber stored indices. No-op for numeric arrays.
    pub(crate) fn condense_pool(self) -> Assoc {
        let pool = match &self.val {
            Values::Numeric => return self,
            Values::Strings(pool) => pool,
        };
        let mut used = vec![false; pool.len()];
        for &v in self.adj.values() {
            used[v as usize - 1] = true;
        }
        if used.iter().all(|&u| u) {
            return self;
        }
        // old (1-based) -> new (1-based) index map.
        let mut remap = vec![0f64; pool.len() + 1];
        let mut new_pool = Vec::new();
        for (i, keep) in used.iter().enumerate() {
            if *keep {
                new_pool.push(pool[i].clone());
                remap[i + 1] = new_pool.len() as f64;
            }
        }
        let adj = self.adj.map_values(0.0, |v| remap[v as usize]);
        Assoc { row: self.row, col: self.col, val: Values::Strings(new_pool), adj }
    }

    /// Remove entries whose value is the empty string (the string-zero;
    /// "zeros are unstored"). No-op for numeric arrays or pools without
    /// an empty string (it can only be pool index 1, since "" sorts
    /// first).
    pub(crate) fn strip_empty_string(self) -> Assoc {
        let has_empty = match &self.val {
            Values::Strings(pool) => pool.first().is_some_and(|s| s.is_empty()),
            Values::Numeric => false,
        };
        if !has_empty {
            return self;
        }
        // Drop stored index 1 (""), shift the rest down, drop "" from pool.
        let adj = self.adj.map_values(0.0, |v| if v == 1.0 { 0.0 } else { v - 1.0 });
        let pool = match self.val {
            Values::Strings(pool) => pool[1..].to_vec(),
            Values::Numeric => unreachable!(),
        };
        Assoc { row: self.row, col: self.col, val: Values::Strings(pool), adj }
    }
}

/// Compute the common broadcast length of the three constructor inputs.
/// `None` for vals means scalar (matches anything).
fn broadcast_len(r: usize, c: usize, v: Option<usize>) -> Option<usize> {
    let n = r.max(c).max(v.unwrap_or(0));
    let ok = |len: usize| len == n || len == 1;
    if !ok(r) || !ok(c) {
        return None;
    }
    if let Some(v) = v {
        if !ok(v) {
            return None;
        }
    }
    Some(n)
}

fn broadcast_keys(mut keys: Vec<Key>, n: usize) -> Vec<Key> {
    if keys.len() == 1 && n > 1 {
        let k = keys.pop().unwrap();
        vec![k; n]
    } else {
        keys
    }
}

fn mask_keys(keys: Vec<Key>, mask: &[bool]) -> Vec<Key> {
    keys.into_iter()
        .zip(mask)
        .filter_map(|(k, &keep)| keep.then_some(k))
        .collect()
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// The paper's Figure 1/2 example array.
    pub(crate) fn music() -> Assoc {
        Assoc::from_triples(
            &[
                "0294.mp3", "0294.mp3", "0294.mp3", "1829.mp3", "1829.mp3", "1829.mp3",
                "7802.mp3", "7802.mp3", "7802.mp3",
            ],
            &[
                "artist", "duration", "genre", "artist", "duration", "genre", "artist",
                "duration", "genre",
            ],
            &[
                "Pink Floyd", "6:53", "rock", "Samuel Barber", "8:01", "classical",
                "Taylor Swift", "10:12", "pop",
            ][..],
        )
    }

    #[test]
    fn figure2_attributes() {
        let a = music();
        let rows: Vec<String> = a.row_keys().iter().map(|k| k.to_string()).collect();
        assert_eq!(rows, vec!["0294.mp3", "1829.mp3", "7802.mp3"]);
        let cols: Vec<String> = a.col_keys().iter().map(|k| k.to_string()).collect();
        assert_eq!(cols, vec!["artist", "duration", "genre"]);
        // The paper's Fig 2 pool, sorted: "10:12","6:53","8:01","Pink
        // Floyd","Samuel Barber","Taylor Swift","classical","pop","rock"
        let pool: Vec<&str> =
            a.values().strings().unwrap().iter().map(|s| s.as_ref()).collect();
        assert_eq!(
            pool,
            vec![
                "10:12", "6:53", "8:01", "Pink Floyd", "Samuel Barber", "Taylor Swift",
                "classical", "pop", "rock"
            ]
        );
        // Spot-check the 1-based index correspondence of Fig 2's adj.
        assert_eq!(a.get_str("0294.mp3", "artist"), Some("Pink Floyd"));
        assert_eq!(a.get_str("7802.mp3", "duration"), Some("10:12"));
        assert_eq!(a.get_str("1829.mp3", "genre"), Some("classical"));
        assert_eq!(a.get_str("1829.mp3", "nope"), None);
    }

    #[test]
    fn numeric_constructor_and_access() {
        let a = Assoc::from_triples(&["r1", "r2"], &["c1", "c2"], vec![2.0, 3.0]);
        assert_eq!(a.get_num("r1", "c1"), Some(2.0));
        assert_eq!(a.get_num("r2", "c2"), Some(3.0));
        assert_eq!(a.get_num("r1", "c2"), None);
        assert!(a.is_numeric());
        assert_eq!(a.shape(), (2, 2));
    }

    #[test]
    fn scalar_broadcast() {
        let a = Assoc::from_triples(&["a", "b", "c"], &["x", "y", "z"], 1.0);
        assert_eq!(a.nnz(), 3);
        assert!(a.iter().all(|(_, _, v)| v == Val::Num(1.0)));
        // length-1 key broadcast too
        let b = Assoc::from_triples(&["r"], &["x", "y", "z"], 1.0);
        assert_eq!(b.shape(), (1, 3));
        assert_eq!(b.nnz(), 3);
    }

    #[test]
    fn default_min_aggregation_on_collision() {
        let a = Assoc::from_triples(&["r", "r"], &["c", "c"], vec![5.0, 3.0]);
        assert_eq!(a.get_num("r", "c"), Some(3.0));
        let s = Assoc::from_triples(&["r", "r"], &["c", "c"], &["zeta", "alpha"][..]);
        assert_eq!(s.get_str("r", "c"), Some("alpha"));
    }

    #[test]
    fn aggregators_numeric() {
        let mk = |agg| {
            Assoc::from_triples_agg(&["r", "r"], &["c", "c"], vec![5.0, 3.0], agg)
                .get_num("r", "c")
                .unwrap()
        };
        assert_eq!(mk(Aggregator::Min), 3.0);
        assert_eq!(mk(Aggregator::Max), 5.0);
        assert_eq!(mk(Aggregator::Sum), 8.0);
        assert_eq!(mk(Aggregator::Prod), 15.0);
        assert_eq!(mk(Aggregator::First), 5.0);
        assert_eq!(mk(Aggregator::Last), 3.0);
    }

    #[test]
    fn string_first_last_respect_input_order() {
        let mk = |agg| {
            Assoc::from_triples_agg(
                &["r", "r", "r"],
                &["c", "c", "c"],
                &["mid", "zzz", "aaa"][..],
                agg,
            )
        };
        assert_eq!(mk(Aggregator::First).get_str("r", "c"), Some("mid"));
        assert_eq!(mk(Aggregator::Last).get_str("r", "c"), Some("aaa"));
        assert_eq!(mk(Aggregator::Max).get_str("r", "c"), Some("zzz"));
    }

    #[test]
    fn concat_aggregator_on_strings() {
        let a = Assoc::from_triples_agg(
            &["r", "r", "r"],
            &["c", "c", "c"],
            &["x", "y", "z"][..],
            Aggregator::Concat(";".into()),
        );
        assert_eq!(a.get_str("r", "c"), Some("x;y;z"));
    }

    #[test]
    fn bad_aggregators_rejected() {
        let err = Assoc::try_new(
            keys_from(&["r"]),
            keys_from(&["c"]),
            ValsInput::Str(vec!["x".into()]),
            Aggregator::Sum,
        )
        .unwrap_err();
        assert!(matches!(err, AssocError::BadAggregator { .. }));
        let err = Assoc::try_new(
            keys_from(&["r"]),
            keys_from(&["c"]),
            ValsInput::Num(vec![1.0]),
            Aggregator::Concat(",".into()),
        )
        .unwrap_err();
        assert!(matches!(err, AssocError::BadAggregator { .. }));
    }

    #[test]
    fn length_mismatch_rejected() {
        let err = Assoc::try_new(
            keys_from(&["a", "b"]),
            keys_from(&["c", "d", "e"]),
            ValsInput::NumScalar(1.0),
            Aggregator::Min,
        )
        .unwrap_err();
        assert!(matches!(err, AssocError::LengthMismatch { .. }));
    }

    #[test]
    fn zero_values_unstored() {
        let a = Assoc::from_triples(&["r1", "r2"], &["c1", "c2"], vec![0.0, 1.0]);
        assert_eq!(a.nnz(), 1);
        // r1/c1 must not linger in the key space.
        assert_eq!(a.shape(), (1, 1));
        assert!(a.find_row(&Key::str("r1")).is_none());
    }

    #[test]
    fn empty_string_values_unstored() {
        let a = Assoc::from_triples(&["r1", "r2"], &["c1", "c2"], &["", "x"][..]);
        assert_eq!(a.nnz(), 1);
        assert_eq!(a.shape(), (1, 1));
        assert_eq!(a.get_str("r2", "c2"), Some("x"));
        // Pool contains only "x".
        assert_eq!(a.values().strings().unwrap().len(), 1);
    }

    #[test]
    fn aggregation_to_zero_condenses() {
        let a = Assoc::from_triples_agg(
            &["r", "r", "s"],
            &["c", "c", "d"],
            vec![2.0, -2.0, 1.0],
            Aggregator::Sum,
        );
        assert_eq!(a.nnz(), 1);
        assert_eq!(a.shape(), (1, 1));
    }

    #[test]
    fn empty_constructor_inputs() {
        let a = Assoc::from_triples::<&str, &str, _>(&[], &[], ValsInput::Num(vec![]));
        assert!(a.is_empty());
        assert!(a.is_numeric());
        assert_eq!(a, Assoc::empty());
    }

    #[test]
    fn numeric_keys_work() {
        let a = Assoc::from_triples(&[1i64, 2, 10], &[1i64, 1, 1], 1.0);
        let rows: Vec<f64> = a.row_keys().iter().map(|k| k.as_num().unwrap()).collect();
        assert_eq!(rows, vec![1.0, 2.0, 10.0]); // numeric order, not lex
        assert_eq!(a.get_num(10i64, 1i64), Some(1.0));
    }

    #[test]
    fn key_encodings_bit_identical() {
        // Mixed numeric/string keys, string values, collisions.
        let rows = vec![Key::str("r2"), Key::num(3.0), Key::str("r2"), Key::num(-1.0)];
        let cols = vec![Key::num(7.0), Key::str("c"), Key::num(7.0), Key::str("c")];
        let vals = ValsInput::Str(vec!["x".into(), "y".into(), "a".into(), "z".into()]);
        let dict = Assoc::try_new_with(
            rows.clone(),
            cols.clone(),
            vals.clone(),
            Aggregator::Min,
            Parallelism::serial(),
            KeyEncoding::Dict,
        )
        .unwrap();
        let sort = Assoc::try_new_with(
            rows,
            cols,
            vals,
            Aggregator::Min,
            Parallelism::serial(),
            KeyEncoding::Sort,
        )
        .unwrap();
        assert_eq!(dict, sort);
        assert_eq!(dict.get_str("r2", 7.0), Some("a"));
    }

    #[test]
    fn try_from_encoded_matches_try_new() {
        let rows = keys_from(&["b", "a", "b"]);
        let cols = keys_from(&["y", "x", "x"]);
        let vals = ValsInput::Num(vec![1.0, 2.0, 3.0]);
        let expect = Assoc::try_new(rows, cols, vals.clone(), Aggregator::Min).unwrap();
        // Hand-encoded: row keys a,b; col keys x,y.
        let got = Assoc::try_from_encoded(
            keys_from(&["a", "b"]),
            keys_from(&["x", "y"]),
            vec![1, 0, 1],
            vec![1, 0, 0],
            vals,
            Aggregator::Min,
            Parallelism::serial(),
        )
        .unwrap();
        assert_eq!(got, expect);
    }

    #[test]
    fn try_from_encoded_validates() {
        let err = Assoc::try_from_encoded(
            keys_from(&["b", "a"]), // unsorted
            keys_from(&["x"]),
            vec![0],
            vec![0],
            ValsInput::NumScalar(1.0),
            Aggregator::Min,
            Parallelism::serial(),
        )
        .unwrap_err();
        assert!(matches!(err, AssocError::BadParts(_)));
        let err = Assoc::try_from_encoded(
            keys_from(&["a"]),
            keys_from(&["x"]),
            vec![1], // out of bounds
            vec![0],
            ValsInput::NumScalar(1.0),
            Aggregator::Min,
            Parallelism::serial(),
        )
        .unwrap_err();
        assert!(matches!(err, AssocError::BadParts(_)));
        let err = Assoc::try_from_encoded(
            keys_from(&["a"]),
            keys_from(&["x"]),
            vec![0, 0],
            vec![0], // length mismatch
            ValsInput::NumScalar(1.0),
            Aggregator::Min,
            Parallelism::serial(),
        )
        .unwrap_err();
        assert!(matches!(err, AssocError::LengthMismatch { .. }));
    }

    #[test]
    fn from_parts_validation() {
        use crate::sparse::CsrMatrix;
        // Shape mismatch.
        let err = Assoc::from_parts(
            keys_from(&["a"]),
            keys_from(&["b", "c"]),
            Values::Numeric,
            CsrMatrix::zeros(2, 2),
        )
        .unwrap_err();
        assert!(matches!(err, AssocError::BadParts(_)));
        // Unsorted keys.
        let err = Assoc::from_parts(
            vec![Key::str("b"), Key::str("a")],
            keys_from(&["c", "d"]),
            Values::Numeric,
            CsrMatrix::zeros(2, 2),
        )
        .unwrap_err();
        assert!(matches!(err, AssocError::BadParts(_)));
    }

    #[test]
    fn from_parts_roundtrip() {
        let a = music();
        let b = Assoc::from_parts(a.row.clone(), a.col.clone(), a.val.clone(), a.adj.clone())
            .unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn triples_roundtrip_string() {
        let a = music();
        let (r, c, v) = a.triples();
        let b = Assoc::try_new(r, c, v, Aggregator::Min).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn triples_roundtrip_numeric() {
        let a = Assoc::from_triples(&["r1", "r2", "r3"], &["c1", "c1", "c2"], vec![3.0, 1.0, 2.0]);
        let (r, c, v) = a.triples();
        let b = Assoc::try_new(r, c, v, Aggregator::Min).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn iter_yields_sorted_row_major() {
        let a = music();
        let entries: Vec<(String, String)> =
            a.iter().map(|(r, c, _)| (r.to_string(), c.to_string())).collect();
        let mut sorted = entries.clone();
        sorted.sort();
        assert_eq!(entries, sorted);
        assert_eq!(entries.len(), 9);
    }
}
