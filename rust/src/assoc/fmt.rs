//! Tabular display of associative arrays — the Figure 1 rendering.

use super::{Assoc, Val};
use std::fmt;

/// Maximum rows/columns rendered before truncation.
const MAX_DISPLAY_ROWS: usize = 20;
const MAX_DISPLAY_COLS: usize = 12;

impl fmt::Display for Assoc {
    /// Render as the paper's Figure-1 style table: column keys as the
    /// header, row keys on the left, empty cells blank. Large arrays are
    /// truncated with ellipses and a summary line.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_empty() {
            return writeln!(f, "(empty associative array)");
        }
        let (m, n) = self.shape();
        let show_m = m.min(MAX_DISPLAY_ROWS);
        let show_n = n.min(MAX_DISPLAY_COLS);

        // Gather cell strings.
        let col_hdrs: Vec<String> =
            self.col[..show_n].iter().map(|k| k.to_string()).collect();
        let row_hdrs: Vec<String> =
            self.row[..show_m].iter().map(|k| k.to_string()).collect();
        let mut cells: Vec<Vec<String>> = vec![vec![String::new(); show_n]; show_m];
        for r in 0..show_m {
            let (ci, cv) = self.adj.row(r);
            for (c, v) in ci.iter().zip(cv) {
                let c = *c as usize;
                if c < show_n {
                    cells[r][c] = self.val.decode(*v).to_string();
                }
            }
        }

        // Column widths.
        let mut rw = row_hdrs.iter().map(String::len).max().unwrap_or(0);
        rw = rw.max(1);
        let mut widths: Vec<usize> = col_hdrs.iter().map(String::len).collect();
        for row in &cells {
            for (j, cell) in row.iter().enumerate() {
                widths[j] = widths[j].max(cell.len());
            }
        }

        // Header.
        write!(f, "{:rw$} ", "")?;
        for (j, h) in col_hdrs.iter().enumerate() {
            write!(f, " {:>w$}", h, w = widths[j])?;
        }
        if n > show_n {
            write!(f, " …")?;
        }
        writeln!(f)?;
        // Body.
        for r in 0..show_m {
            write!(f, "{:rw$} ", row_hdrs[r])?;
            for (j, cell) in cells[r].iter().enumerate() {
                write!(f, " {:>w$}", cell, w = widths[j])?;
            }
            if n > show_n {
                write!(f, " …")?;
            }
            writeln!(f)?;
        }
        if m > show_m {
            writeln!(f, "… ({m} rows total)")?;
        }
        writeln!(
            f,
            "[{m}x{n} {} associative array, {} nonempty]",
            if self.is_numeric() { "numeric" } else { "string" },
            self.nnz()
        )
    }
}

impl Assoc {
    /// One-line summary (shape, type, nnz).
    pub fn summary(&self) -> String {
        let (m, n) = self.shape();
        format!(
            "{}x{} {} assoc, nnz={}",
            m,
            n,
            if self.is_numeric() { "numeric" } else { "string" },
            self.nnz()
        )
    }

    /// A "spy plot" as text: `#` for nonempty cells (small arrays only).
    pub fn spy(&self) -> String {
        let (m, n) = self.shape();
        let mut out = String::new();
        for r in 0..m.min(40) {
            let (ci, _) = self.adj.row(r);
            let mut line = vec![b'.'; n.min(80)];
            for &c in ci {
                if (c as usize) < line.len() {
                    line[c as usize] = b'#';
                }
            }
            out.push_str(std::str::from_utf8(&line).unwrap());
            out.push('\n');
        }
        out
    }

    /// Decoded value at a raw position (for display/debug helpers).
    pub fn val_at(&self, r: usize, c: usize) -> Option<Val<'_>> {
        self.adj.get(r, c).map(|v| self.val.decode(v))
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::music;
    use super::*;

    #[test]
    fn display_contains_headers_and_values() {
        let s = music().to_string();
        assert!(s.contains("artist"));
        assert!(s.contains("Pink Floyd"));
        assert!(s.contains("0294.mp3"));
        assert!(s.contains("[3x3 string associative array, 9 nonempty]"));
    }

    #[test]
    fn display_empty() {
        assert!(Assoc::empty().to_string().contains("empty"));
    }

    #[test]
    fn display_truncates_large() {
        let rows: Vec<String> = (0..50).map(|i| format!("r{i:03}")).collect();
        let a = Assoc::from_triples(&rows, &["c"], 1.0);
        let s = a.to_string();
        assert!(s.contains("(50 rows total)"));
    }

    #[test]
    fn summary_and_spy() {
        let a = music();
        assert_eq!(a.summary(), "3x3 string assoc, nnz=9");
        let spy = a.spy();
        assert_eq!(spy.lines().count(), 3);
        assert!(spy.lines().all(|l| l == "###"));
    }
}
