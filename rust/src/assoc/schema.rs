//! Exploded-schema helpers — D4M's `val2col` / `col2type`.
//!
//! The standard D4M database pattern stores a dense table
//! `A[row, field] = value` as a *sparse indicator* array
//! `E[row, "field|value"] = 1`, which turns facet queries, joins and
//! correlations into pure sparse algebra (`E.sqin()` is the
//! co-occurrence graph). `val2col` performs that explosion; `col2type`
//! inverts it.

use super::{Aggregator, Assoc, Key, ValsInput};

/// Explode `A[row, field] = value` into `E[row, "field<sep>value"] = 1`.
///
/// Numeric values are rendered with the usual integer-style formatting.
pub fn val2col(a: &Assoc, sep: &str) -> Assoc {
    let (rows, cols, vals) = a.triples();
    let rendered: Vec<String> = match vals {
        ValsInput::Str(vs) => vs,
        ValsInput::Num(vs) => vs
            .into_iter()
            .map(|x| {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    format!("{}", x as i64)
                } else {
                    format!("{x}")
                }
            })
            .collect(),
        _ => unreachable!("triples() never yields scalars"),
    };
    let exploded: Vec<Key> = cols
        .iter()
        .zip(&rendered)
        .map(|(c, v)| Key::str(format!("{c}{sep}{v}")))
        .collect();
    Assoc::try_new(rows, exploded, ValsInput::NumScalar(1.0), Aggregator::Min)
        .expect("val2col triples")
}

/// Invert [`val2col`]: collapse `E[row, "field<sep>value"] = 1` back to
/// `A[row, field] = value`. Columns without the separator are skipped;
/// collisions (two exploded columns for one field) keep the
/// lexicographically smallest value (the D4M default aggregator).
pub fn col2type(e: &Assoc, sep: &str) -> Assoc {
    let mut rows: Vec<Key> = Vec::new();
    let mut cols: Vec<Key> = Vec::new();
    let mut vals: Vec<String> = Vec::new();
    for (r, c, _) in e.iter() {
        let cs = c.to_string();
        if let Some((field, value)) = cs.split_once(sep) {
            rows.push(r.clone());
            cols.push(Key::str(field));
            vals.push(value.to_string());
        }
    }
    Assoc::try_new(rows, cols, ValsInput::Str(vals), Aggregator::Min).expect("col2type triples")
}

#[cfg(test)]
mod tests {
    use super::super::tests::music;
    use super::*;

    #[test]
    fn val2col_explodes_to_indicators() {
        let a = music();
        let e = val2col(&a, "|");
        assert!(e.is_numeric());
        assert_eq!(e.nnz(), a.nnz());
        assert_eq!(e.get_num("0294.mp3", "genre|rock"), Some(1.0));
        assert_eq!(e.get_num("7802.mp3", "artist|Taylor Swift"), Some(1.0));
        // One exploded column per distinct (field, value) pair.
        assert_eq!(e.col_keys().len(), 9);
    }

    #[test]
    fn col2type_inverts_val2col() {
        let a = music();
        let roundtrip = col2type(&val2col(&a, "|"), "|");
        assert_eq!(roundtrip, a);
    }

    #[test]
    fn val2col_numeric_values() {
        let a = Assoc::from_triples(&["r"], &["score"], vec![7.0]);
        let e = val2col(&a, "|");
        assert_eq!(e.get_num("r", "score|7"), Some(1.0));
    }

    #[test]
    fn col2type_skips_plain_columns() {
        let e = Assoc::from_triples(&["r", "r"], &["genre|rock", "plain"], 1.0);
        let back = col2type(&e, "|");
        assert_eq!(back.nnz(), 1);
        assert_eq!(back.get_str("r", "genre"), Some("rock"));
    }

    #[test]
    fn facet_pipeline_on_exploded_schema() {
        // The motivating pattern: explode, correlate, read facets.
        let a = music();
        let e = val2col(&a, "|");
        let ata = e.sqin();
        // "rock" and "Pink Floyd" co-occur on exactly one track.
        assert_eq!(ata.get_num("genre|rock", "artist|Pink Floyd"), Some(1.0));
        assert_eq!(ata.get_num("genre|rock", "genre|classical"), None);
    }
}
