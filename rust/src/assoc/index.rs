//! Sub-array extraction and assignment — `__getitem__`/`__setitem__`
//! (paper §II.B).
//!
//! Two subtleties called out by the paper are implemented faithfully:
//!
//! 1. **String slices are inclusive on the right**: `A["a,:,b,", :]`
//!    selects all keys `k` with `a ≤ k ≤ b` — [`Selector::KeyRange`].
//! 2. **Integers mean positions, not keys**: `A[1, 0:2]` treats the
//!    integers as indices into `A.row`/`A.col` (the keys are usually
//!    strings). [`Selector::Positions`]/[`Selector::PosRange`] are those
//!    forms; to select a *numeric key*, use `Selector::keys([...])`.

use super::{Assoc, Key};
use crate::sorted::range_indices;

/// A row- or column-selector for [`Assoc::select`].
#[derive(Debug, Clone, PartialEq)]
pub enum Selector {
    /// All keys (`:`).
    All,
    /// An explicit set of keys; missing keys are silently ignored
    /// (D4M extraction never errors on absent keys).
    Keys(Vec<Key>),
    /// The *closed* key range `[lo, hi]` — D4M string-slice semantics,
    /// inclusive on the right (paper §II.B item 1).
    KeyRange(Key, Key),
    /// All string keys starting with the given prefix (D4M's
    /// `StartsWith`).
    Prefix(String),
    /// Explicit positions into `A.row`/`A.col` (paper §II.B item 2).
    /// Out-of-range positions are ignored; order and duplicates are
    /// preserved in the extracted key list semantics of D4M (the result
    /// is still a set of keys, so duplicates collapse).
    Positions(Vec<usize>),
    /// The half-open position range `[start, end)` — Python slice
    /// semantics (`A[1, 0:2]`), *exclusive* on the right, in contrast to
    /// key ranges.
    PosRange(usize, usize),
}

impl Selector {
    /// Selector from anything key-like.
    pub fn keys<K: Into<Key> + Clone>(keys: &[K]) -> Selector {
        Selector::Keys(keys.iter().cloned().map(Into::into).collect())
    }

    /// Closed key range (both endpoints included).
    pub fn range(lo: impl Into<Key>, hi: impl Into<Key>) -> Selector {
        Selector::KeyRange(lo.into(), hi.into())
    }

    /// Resolve to sorted, deduplicated positions into `keys`.
    fn resolve(&self, keys: &[Key]) -> Vec<usize> {
        match self {
            Selector::All => (0..keys.len()).collect(),
            Selector::Keys(sel) => {
                let mut pos: Vec<usize> =
                    sel.iter().filter_map(|k| keys.binary_search(k).ok()).collect();
                pos.sort_unstable();
                pos.dedup();
                pos
            }
            Selector::KeyRange(lo, hi) => {
                let (s, e) = range_indices(keys, lo, hi);
                (s..e).collect()
            }
            Selector::Prefix(p) => {
                // Prefix p selects the contiguous key range [p, p + U+10FFFF).
                let lo = Key::str(p.clone());
                let mut hi_s = p.clone();
                hi_s.push(char::MAX);
                let hi = Key::str(hi_s);
                let (s, e) = range_indices(keys, &lo, &hi);
                (s..e).collect()
            }
            Selector::Positions(ps) => {
                let mut pos: Vec<usize> =
                    ps.iter().copied().filter(|&p| p < keys.len()).collect();
                pos.sort_unstable();
                pos.dedup();
                pos
            }
            Selector::PosRange(s, e) => (*s..(*e).min(keys.len())).collect(),
        }
    }
}

impl Assoc {
    /// Extract the sub-array selected by `rows` × `cols`
    /// (`A[rows, cols]`). The result is condensed: only keys with
    /// surviving nonempty entries appear (and string pools are pruned).
    pub fn select(&self, rows: &Selector, cols: &Selector) -> Assoc {
        let rpos = rows.resolve(&self.row);
        let cpos = cols.resolve(&self.col);
        if rpos.is_empty() || cpos.is_empty() {
            return Assoc::empty();
        }
        // Column-only selection (`A[:, keys]`): a full-length resolved
        // row list is sorted, deduplicated and in-bounds, hence the
        // identity — use the column-driven gather through the adj's
        // cached transpose dual instead of scanning every row. Taken
        // when the dual already exists, or when the selection is narrow
        // enough that building it costs no more than one row scan; the
        // dual then stays cached on `self`, so repeated column
        // extractions amortize the build (the deliberate memoization
        // bet: one extra retained copy of the adj arrays buys O(nnz)
        // → O(selected) on every later column access). Either path
        // yields bit-identical output.
        let col_driven = rpos.len() == self.row.len()
            && (self.adj.has_cached_dual() || cpos.len() * 4 <= self.col.len());
        let adj = if col_driven {
            self.adj.gather_cols(&cpos)
        } else {
            self.adj.gather(&rpos, &cpos)
        };
        let row = rpos.iter().map(|&p| self.row[p].clone()).collect();
        let col = cpos.iter().map(|&p| self.col[p].clone()).collect();
        Assoc { row, col, val: self.val.clone(), adj }
            .condense_pool()
            .condensed()
    }

    /// Extract one row as a `1 × n` array (`A[key, :]`).
    pub fn get_row(&self, key: impl Into<Key>) -> Assoc {
        self.select(&Selector::Keys(vec![key.into()]), &Selector::All)
    }

    /// Extract one column as an `m × 1` array (`A[:, key]`).
    pub fn get_col(&self, key: impl Into<Key>) -> Assoc {
        self.select(&Selector::All, &Selector::Keys(vec![key.into()]))
    }

    /// Assign one entry (`A[row, col] = val` — `__setitem__`).
    ///
    /// Implemented as a merge-rebuild (D4M arrays are value types built
    /// for bulk construction; point mutation is O(nnz)). Assigning a
    /// numeric value to a string array (or vice versa) converts the
    /// array via the same string-combination rules as `+`.
    pub fn set(
        &mut self,
        row: impl Into<Key>,
        col: impl Into<Key>,
        val: impl Into<super::ValsInput>,
    ) {
        // Append the raw triple and rebuild with Last semantics (the
        // patch wins on collision; a zero/empty value deletes, since the
        // constructor never stores zeros).
        let (mut r, mut c, v) = self.triples();
        r.push(row.into());
        c.push(col.into());
        let patch: super::ValsInput = val.into();
        match (v, patch) {
            (super::ValsInput::Num(mut v), super::ValsInput::Num(pv)) if pv.len() == 1 => {
                v.push(pv[0]);
                *self = Assoc::try_new(r, c, super::ValsInput::Num(v), super::Aggregator::Last)
                    .expect("merged triples");
            }
            (super::ValsInput::Num(mut v), super::ValsInput::NumScalar(x)) => {
                v.push(x);
                *self = Assoc::try_new(r, c, super::ValsInput::Num(v), super::Aggregator::Last)
                    .expect("merged triples");
            }
            (v, pv) => {
                // Mixed or string: go through string space.
                let mut vs = super::ops::vals_to_strings(v);
                vs.push(match pv {
                    super::ValsInput::StrScalar(s) => s,
                    super::ValsInput::NumScalar(x) => {
                        super::ops::vals_to_strings(super::ValsInput::Num(vec![x])).pop().unwrap()
                    }
                    super::ValsInput::Str(mut xs) if xs.len() == 1 => xs.pop().unwrap(),
                    super::ValsInput::Num(xs) if xs.len() == 1 => {
                        super::ops::vals_to_strings(super::ValsInput::Num(xs)).pop().unwrap()
                    }
                    other => panic!("Assoc::set expects a single value, got {other:?}"),
                });
                *self = Assoc::try_new(r, c, super::ValsInput::Str(vs), super::Aggregator::Last)
                    .expect("merged triples");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assoc::tests::music;

    #[test]
    fn select_all_is_identity() {
        let a = music();
        assert_eq!(a.select(&Selector::All, &Selector::All), a);
    }

    #[test]
    fn select_by_keys() {
        let a = music();
        let b = a.select(&Selector::keys(&["0294.mp3", "7802.mp3"]), &Selector::keys(&["genre"]));
        assert_eq!(b.shape(), (2, 1));
        assert_eq!(b.get_str("0294.mp3", "genre"), Some("rock"));
        assert_eq!(b.get_str("7802.mp3", "genre"), Some("pop"));
    }

    #[test]
    fn select_missing_keys_ignored() {
        let a = music();
        let b = a.select(&Selector::keys(&["0294.mp3", "nope.mp3"]), &Selector::All);
        assert_eq!(b.shape(), (1, 3));
    }

    #[test]
    fn key_range_right_inclusive() {
        let a = music();
        // "0294.mp3" ≤ k ≤ "1829.mp3" — both endpoints included.
        let b = a.select(&Selector::range("0294.mp3", "1829.mp3"), &Selector::All);
        assert_eq!(b.shape(), (2, 3));
        assert!(b.get_str("1829.mp3", "genre").is_some());
    }

    #[test]
    fn prefix_selector() {
        let a = music();
        let b = a.select(&Selector::Prefix("18".into()), &Selector::All);
        assert_eq!(b.shape(), (1, 3));
        assert_eq!(b.get_str("1829.mp3", "artist"), Some("Samuel Barber"));
        // Prefix matching everything.
        let c = a.select(&Selector::Prefix("".into()), &Selector::All);
        assert_eq!(c, a);
    }

    #[test]
    fn positions_are_indices_not_keys() {
        let a = music();
        // Position 1 = second row key "1829.mp3" (paper §II.B item 2).
        let b = a.select(&Selector::Positions(vec![1]), &Selector::PosRange(0, 2));
        assert_eq!(b.shape(), (1, 2));
        assert_eq!(b.get_str("1829.mp3", "artist"), Some("Samuel Barber"));
        assert_eq!(b.get_str("1829.mp3", "duration"), Some("8:01"));
        assert_eq!(b.get_str("1829.mp3", "genre"), None); // pos 2 excluded
    }

    #[test]
    fn pos_range_clamps() {
        let a = music();
        let b = a.select(&Selector::PosRange(0, 99), &Selector::All);
        assert_eq!(b, a);
        let c = a.select(&Selector::PosRange(5, 9), &Selector::All);
        assert!(c.is_empty());
    }

    #[test]
    fn select_result_pool_is_pruned() {
        let a = music();
        let b = a.select(&Selector::keys(&["0294.mp3"]), &Selector::keys(&["artist"]));
        assert_eq!(b.values().strings().unwrap().len(), 1);
    }

    #[test]
    fn get_row_get_col() {
        let a = music();
        let r = a.get_row("0294.mp3");
        assert_eq!(r.shape(), (1, 3));
        let c = a.get_col("artist");
        assert_eq!(c.shape(), (3, 1));
    }

    #[test]
    fn set_inserts_and_overwrites() {
        let mut a = Assoc::from_triples(&["r"], &["c"], vec![1.0]);
        a.set("r", "c2", 5.0);
        assert_eq!(a.get_num("r", "c2"), Some(5.0));
        a.set("r", "c", 9.0); // overwrite
        assert_eq!(a.get_num("r", "c"), Some(9.0));
        assert_eq!(a.nnz(), 2);
    }

    #[test]
    fn set_zero_deletes() {
        let mut a = Assoc::from_triples(&["r", "r2"], &["c", "c"], vec![1.0, 2.0]);
        a.set("r", "c", 0.0);
        assert_eq!(a.nnz(), 1);
        assert_eq!(a.shape(), (1, 1));
    }

    #[test]
    fn set_string_value() {
        let mut a = music();
        a.set("0294.mp3", "genre", "prog-rock");
        assert_eq!(a.get_str("0294.mp3", "genre"), Some("prog-rock"));
        assert_eq!(a.nnz(), 9);
    }

    #[test]
    fn select_on_numeric_array() {
        let a = Assoc::from_triples(&[1i64, 2, 10], &[1i64, 1, 1], 1.0);
        // Numeric keys selected BY KEY:
        let b = a.select(&Selector::keys(&[10i64]), &Selector::All);
        assert_eq!(b.nnz(), 1);
        // vs BY POSITION:
        let c = a.select(&Selector::Positions(vec![0]), &Selector::All);
        assert_eq!(c.get_num(1i64, 1i64), Some(1.0));
    }
}
