//! Associative-array algebra: `+`, `*`, `@`, transpose, logical,
//! reductions — paper §II.C, implemented exactly by its recipes:
//!
//! * **Addition** (numeric): sorted union of key spaces (with index
//!   maps), re-index both `adj`s onto the union, sparse add, condense.
//! * **Addition** (string): extract both triple lists, append, rebuild
//!   with concatenation aggregation (the `combine` method).
//! * **Element-wise multiplication**: sorted intersections, restrict +
//!   re-index, sparse element-wise multiply, condense. Mixed
//!   string×numeric acts as a mask; numeric×string reduces via
//!   `B.logical()`.
//! * **Multiplication** (`@`): sorted intersection `A.col ∩ B.row`,
//!   restrict + re-index, SpGEMM, condense. String operands go through
//!   `.logical()` first.
//!
//! Every operation is also exposed with an explicit [`Semiring`]
//! (`add_with`, `elemmul_with`, `matmul_with`) — the paper's future-work
//! "user-selected semiring operations" — and with an explicit
//! [`Parallelism`] (`add_par`, `elemmul_par`, `matmul_par`, and the
//! `*_with_par` forms). The convenience forms use the process-default
//! parallelism; `threads == 1` always selects the exact serial code
//! path, and every parallel result is byte-identical to it (enforced by
//! `rust/tests/parallel_equivalence.rs`).

use super::{Aggregator, Assoc, Key, ValsInput, Values};
use crate::semiring::{FnSemiring, PlusTimes, Semiring};
use crate::sorted::{sorted_intersect, sorted_union};
use crate::sparse::spgemm_par;
use crate::util::Parallelism;

impl Assoc {
    // ------------------------------------------------------------------
    // logical / transpose
    // ------------------------------------------------------------------

    /// Replace every nonempty entry by numeric `1` (paper §II.C.2: "can
    /// be very easily achieved by replacing `B.val` with 1.0 and
    /// `B.adj.data` with ones").
    pub fn logical(&self) -> Assoc {
        Assoc {
            row: self.row.clone(),
            col: self.col.clone(),
            val: Values::Numeric,
            adj: self.adj.map_values(0.0, |_| 1.0),
        }
    }

    /// Transpose: `Aᵀ[j, i] = A[i, j]`.
    pub fn transpose(&self) -> Assoc {
        Assoc {
            row: self.col.clone(),
            col: self.row.clone(),
            val: self.val.clone(),
            adj: self.adj.transpose(),
        }
    }

    // ------------------------------------------------------------------
    // element-wise addition
    // ------------------------------------------------------------------

    /// Element-wise addition `A + B` with D4M semantics: numeric arrays
    /// add under plus-times; if either operand is a string array, values
    /// combine by concatenation (paper §II.C.1), with numeric values
    /// rendered to strings first.
    pub fn add(&self, other: &Assoc) -> Assoc {
        self.add_par(other, Parallelism::current())
    }

    /// [`Assoc::add`] with an explicit thread configuration.
    pub fn add_par(&self, other: &Assoc, par: Parallelism) -> Assoc {
        if self.is_string() || other.is_string() {
            return self.combine_strings_par(other, Aggregator::Concat(String::new()), par);
        }
        self.add_with_par(other, &PlusTimes, par)
    }

    /// Numeric element-wise addition under an explicit semiring's `⊕`
    /// (string operands are `logical()`-ed first).
    pub fn add_with(&self, other: &Assoc, s: &dyn Semiring) -> Assoc {
        self.add_with_par(other, s, Parallelism::current())
    }

    /// [`Assoc::add_with`] with an explicit thread configuration: the
    /// union re-index is serial, the row-wise sparse add fans out.
    pub fn add_with_par(&self, other: &Assoc, s: &dyn Semiring, par: Parallelism) -> Assoc {
        let a = self.as_numeric();
        let b = other.as_numeric();
        if a.is_empty() {
            return b.into_owned();
        }
        if b.is_empty() {
            return a.into_owned();
        }
        let (a, b) = (a.as_ref(), b.as_ref());
        // Sorted unions with index maps (paper §II.C.1).
        let ru = sorted_union(&a.row, &b.row);
        let cu = sorted_union(&a.col, &b.col);
        let nrows = ru.keys.len();
        let ncols = cu.keys.len();
        // Re-shape and re-index both adjs onto the union key space.
        let ea = a.adj.expand(nrows, ncols, &ru.map_left, &cu.map_left);
        let eb = b.adj.expand(nrows, ncols, &ru.map_right, &cu.map_right);
        let adj = ea.add_par(&eb, s, par).expect("expanded shapes match");
        Assoc { row: ru.keys, col: cu.keys, val: Values::Numeric, adj }.condensed()
    }

    /// The paper's `combine`: element-wise merge over the *union* of key
    /// spaces with a chosen aggregator — concatenation gives string `+`,
    /// `Min`/`Max` give element-wise min/max. Values of both operands
    /// are taken as strings (numeric values are rendered).
    pub fn combine_strings(&self, other: &Assoc, agg: Aggregator) -> Assoc {
        self.combine_strings_par(other, agg, Parallelism::current())
    }

    /// [`Assoc::combine_strings`] with an explicit thread configuration
    /// for the rebuild's constructor sorts.
    pub fn combine_strings_par(&self, other: &Assoc, agg: Aggregator, par: Parallelism) -> Assoc {
        let (mut r1, mut c1, v1) = self.triples();
        let (r2, c2, v2) = other.triples();
        let mut vals = vals_to_strings(v1);
        r1.extend(r2);
        c1.extend(c2);
        vals.extend(vals_to_strings(v2));
        // Collisions occur between at most one value from each operand,
        // at most once per key pair (paper §II.C.1).
        Assoc::try_new_par(r1, c1, ValsInput::Str(vals), agg, par)
            .expect("triples from well-formed operands")
    }

    /// Element-wise min over the union (numeric or string).
    pub fn elemmin(&self, other: &Assoc) -> Assoc {
        if self.is_string() || other.is_string() {
            return self.combine_strings(other, Aggregator::Min);
        }
        // Numeric: min over union. Values may be negative, so "absent"
        // must behave as an identity, not as 0 — combine via triples.
        self.combine_numeric(other, f64::min)
    }

    /// Element-wise max over the union (numeric or string).
    pub fn elemmax(&self, other: &Assoc) -> Assoc {
        if self.is_string() || other.is_string() {
            return self.combine_strings(other, Aggregator::Max);
        }
        self.combine_numeric(other, f64::max)
    }

    fn combine_numeric(&self, other: &Assoc, agg: fn(f64, f64) -> f64) -> Assoc {
        let a = self.as_numeric();
        let b = other.as_numeric();
        let (r, c, v) = collect_union_triples(a.as_ref(), b.as_ref(), agg);
        Assoc::try_new(r, c, ValsInput::Num(v), Aggregator::First).expect("well-formed triples")
    }

    // ------------------------------------------------------------------
    // element-wise multiplication
    // ------------------------------------------------------------------

    /// Element-wise multiplication `A * B` with D4M's type rules
    /// (paper §II.C.2):
    ///
    /// * numeric × numeric — multiply over the intersection;
    /// * string × numeric — the numeric array acts as a **mask** on the
    ///   string array;
    /// * numeric × string — the string array is `logical()`-ed, reducing
    ///   to the numeric case (note the asymmetry with the previous rule);
    /// * string × string — element-wise lexicographic `min` over the
    ///   intersection (the string algebra's ⊗).
    pub fn elemmul(&self, other: &Assoc) -> Assoc {
        self.elemmul_par(other, Parallelism::current())
    }

    /// [`Assoc::elemmul`] with an explicit thread configuration.
    pub fn elemmul_par(&self, other: &Assoc, par: Parallelism) -> Assoc {
        match (self.is_string(), other.is_string()) {
            (false, false) => self.elemmul_with_par(other, &PlusTimes, par),
            (true, false) => self.mask_by(other, par),
            (false, true) => self.elemmul_with_par(&other.logical(), &PlusTimes, par),
            (true, true) => self.string_elemmul(other, par),
        }
    }

    /// Numeric element-wise multiplication under an explicit semiring's
    /// `⊗` (string operands `logical()`-ed first).
    pub fn elemmul_with(&self, other: &Assoc, s: &dyn Semiring) -> Assoc {
        self.elemmul_with_par(other, s, Parallelism::current())
    }

    /// [`Assoc::elemmul_with`] with an explicit thread configuration:
    /// the intersection re-index is serial, the row-wise sparse
    /// multiply fans out.
    pub fn elemmul_with_par(&self, other: &Assoc, s: &dyn Semiring, par: Parallelism) -> Assoc {
        let a = self.as_numeric();
        let b = other.as_numeric();
        let (a, b) = (a.as_ref(), b.as_ref());
        let ri = sorted_intersect(&a.row, &b.row);
        let ci = sorted_intersect(&a.col, &b.col);
        if ri.keys.is_empty() || ci.keys.is_empty() {
            return Assoc::empty();
        }
        let ga = a.adj.gather(&ri.map_left, &ci.map_left);
        let gb = b.adj.gather(&ri.map_right, &ci.map_right);
        let adj = ga.multiply_par(&gb, s, par).expect("gathered shapes match");
        Assoc { row: ri.keys, col: ci.keys, val: Values::Numeric, adj }.condensed()
    }

    /// Keep this (string) array's entries wherever `mask` is nonempty.
    fn mask_by(&self, mask: &Assoc, par: Parallelism) -> Assoc {
        let ri = sorted_intersect(&self.row, &mask.row);
        let ci = sorted_intersect(&self.col, &mask.col);
        if ri.keys.is_empty() || ci.keys.is_empty() {
            return Assoc::empty();
        }
        let ga = self.adj.gather(&ri.map_left, &ci.map_left);
        let gb = mask.logical().adj.gather(&ri.map_right, &ci.map_right);
        // stored-index × 1.0 = stored-index: plus-times multiply keeps
        // the pool pointers intact where the mask is set.
        let adj = ga.multiply_par(&gb, &PlusTimes, par).expect("shapes match");
        Assoc { row: ri.keys, col: ci.keys, val: self.val.clone(), adj }
            .condense_pool()
            .condensed()
    }

    /// String × string element-wise `min` (the string semiring's ⊗).
    fn string_elemmul(&self, other: &Assoc, par: Parallelism) -> Assoc {
        // Merge the two pools so lexicographic order is index order.
        let (pa, pb) = (self.pool(), other.pool());
        let merged = sorted_union(pa, pb);
        let remap_a: Vec<f64> =
            merged.map_left.iter().map(|&i| (i + 1) as f64).collect();
        let remap_b: Vec<f64> =
            merged.map_right.iter().map(|&i| (i + 1) as f64).collect();
        let ri = sorted_intersect(&self.row, &other.row);
        let ci = sorted_intersect(&self.col, &other.col);
        if ri.keys.is_empty() || ci.keys.is_empty() {
            return Assoc::empty();
        }
        let ga = self
            .adj
            .gather(&ri.map_left, &ci.map_left)
            .map_values(0.0, |v| remap_a[v as usize - 1]);
        let gb = other
            .adj
            .gather(&ri.map_right, &ci.map_right)
            .map_values(0.0, |v| remap_b[v as usize - 1]);
        // min on merged-pool indices == lexicographic min on strings.
        fn idx_min(a: f64, b: f64) -> f64 {
            a.min(b)
        }
        fn never(_: f64, _: f64) -> f64 {
            unreachable!("multiply never calls ⊕")
        }
        let s = FnSemiring::new("string_min", 0.0, f64::NAN, never, idx_min);
        let adj = ga.multiply_par(&gb, &s, par).expect("shapes match");
        Assoc {
            row: ri.keys,
            col: ci.keys,
            val: Values::Strings(merged.keys),
            adj,
        }
        .condense_pool()
        .condensed()
    }

    // ------------------------------------------------------------------
    // array multiplication
    // ------------------------------------------------------------------

    /// Associative-array multiplication `A @ B` (plus-times). String
    /// operands are converted via `.logical()` first (paper §II.C.3:
    /// "associative array multiplication is currently defined only for
    /// numerical associative arrays").
    pub fn matmul(&self, other: &Assoc) -> Assoc {
        self.matmul_with(other, &PlusTimes)
    }

    /// [`Assoc::matmul`] with an explicit thread configuration.
    pub fn matmul_par(&self, other: &Assoc, par: Parallelism) -> Assoc {
        self.matmul_with_par(other, &PlusTimes, par)
    }

    /// `A ⊗.⊕ B` under an explicit semiring.
    pub fn matmul_with(&self, other: &Assoc, s: &dyn Semiring) -> Assoc {
        self.matmul_with_par(other, s, Parallelism::current())
    }

    /// [`Assoc::matmul_with`] with an explicit thread configuration:
    /// the contraction re-index is serial, the SpGEMM fans out
    /// row-partitioned over the pool (bit-identical to serial).
    pub fn matmul_with_par(&self, other: &Assoc, s: &dyn Semiring, par: Parallelism) -> Assoc {
        let a = self.as_numeric();
        let b = other.as_numeric();
        let (a, b) = (a.as_ref(), b.as_ref());
        // Contract over A.col ∩ B.row (paper §II.C.3).
        let k = sorted_intersect(&a.col, &b.row);
        if k.keys.is_empty() {
            return Assoc::empty();
        }
        // Restricting A to the contraction columns keeps all rows: when
        // A's transpose dual is already cached (A was transposed or
        // column-indexed earlier), the column-driven gather skips the
        // full row scan. Bit-identical either way.
        let ga = if a.adj.has_cached_dual() {
            a.adj.gather_cols(&k.map_left)
        } else {
            let all_rows: Vec<usize> = (0..a.row.len()).collect();
            a.adj.gather(&all_rows, &k.map_left)
        };
        let all_cols: Vec<usize> = (0..b.col.len()).collect();
        let gb = b.adj.gather(&k.map_right, &all_cols);
        let adj = spgemm_par(&ga, &gb, s, par).expect("contracted shapes match");
        Assoc { row: a.row.clone(), col: b.col.clone(), val: Values::Numeric, adj }.condensed()
    }

    /// D4M's `CatKeyMul`: array multiplication that records *which*
    /// contraction keys produced each output entry instead of the
    /// numeric sum — `C[i,j] = "k₁;k₂;…"` over all `k ∈ A.col ∩ B.row`
    /// with `A[i,k]` and `B[k,j]` both nonempty. The standard D4M
    /// provenance idiom: a graph product that remembers its witnesses.
    pub fn catkeymul(&self, other: &Assoc, sep: &str) -> Assoc {
        let a = self.as_numeric();
        let b = other.as_numeric();
        let (a, b) = (a.as_ref(), b.as_ref());
        let kx = sorted_intersect(&a.col, &b.row);
        if kx.keys.is_empty() {
            return Assoc::empty();
        }
        let all_rows: Vec<usize> = (0..a.row.len()).collect();
        let all_cols: Vec<usize> = (0..b.col.len()).collect();
        let ga = a.adj.gather(&all_rows, &kx.map_left);
        let gb = b.adj.gather(&kx.map_right, &all_cols);
        // Row-wise expansion: for each (i, k, j) contributing pair,
        // append key k's name to C[i, j]'s witness list. Keys arrive in
        // sorted-k order per (i, j) because we scan k within row i in
        // column order and merge per-j lists via a BTreeMap.
        let mut witnesses: std::collections::BTreeMap<(usize, usize), String> =
            std::collections::BTreeMap::new();
        for i in 0..all_rows.len() {
            let (kcols, _) = ga.row(i);
            for &k in kcols {
                let kname = kx.keys[k as usize].to_string();
                let (jcols, _) = gb.row(k as usize);
                for &j in jcols {
                    witnesses
                        .entry((i, j as usize))
                        .and_modify(|s| {
                            s.push_str(sep);
                            s.push_str(&kname);
                        })
                        .or_insert_with(|| kname.clone());
                }
            }
        }
        let mut rows = Vec::with_capacity(witnesses.len());
        let mut cols = Vec::with_capacity(witnesses.len());
        let mut vals = Vec::with_capacity(witnesses.len());
        for ((i, j), s) in witnesses {
            rows.push(a.row[i].clone());
            cols.push(b.col[j].clone());
            vals.push(s);
        }
        Assoc::try_new(rows, cols, ValsInput::Str(vals), Aggregator::First)
            .expect("catkeymul triples")
    }

    /// Correlation `AᵀA` — the canonical D4M facet/graph construction.
    pub fn sqin(&self) -> Assoc {
        self.transpose().matmul(self)
    }

    /// Correlation `AAᵀ`.
    pub fn sqout(&self) -> Assoc {
        self.matmul(&self.transpose())
    }

    // ------------------------------------------------------------------
    // reductions
    // ------------------------------------------------------------------

    /// Sum along an axis (string arrays are `logical()`-ed first, so
    /// this counts nonempty entries). `axis = 0` collapses rows
    /// (result is `1 × ncols`, row key `1`); `axis = 1` collapses
    /// columns (result is `nrows × 1`, column key `1`).
    pub fn sum(&self, axis: usize) -> Assoc {
        self.reduce(axis, &PlusTimes)
    }

    /// Count of nonempty entries along an axis (degree vectors).
    pub fn count(&self, axis: usize) -> Assoc {
        self.logical().reduce(axis, &PlusTimes)
    }

    /// Reduce along an axis with a semiring's `⊕`.
    pub fn reduce(&self, axis: usize, s: &dyn Semiring) -> Assoc {
        let a = self.as_numeric();
        let a = a.as_ref();
        assert!(axis < 2, "axis must be 0 (collapse rows) or 1 (collapse columns)");
        let key1 = vec![Key::num(1.0)];
        if axis == 0 {
            let sums = a.adj.reduce_cols(s);
            let cols = a.col.clone();
            Assoc::try_new(
                key1,
                cols,
                ValsInput::Num(sums),
                Aggregator::First,
            )
            .expect("reduction triples")
        } else {
            let sums = a.adj.reduce_rows(s);
            let rows = a.row.clone();
            Assoc::try_new(
                rows,
                key1,
                ValsInput::Num(sums),
                Aggregator::First,
            )
            .expect("reduction triples")
        }
    }

    /// Total of all nonempty values (string arrays: count of entries).
    pub fn total(&self) -> f64 {
        match &self.val {
            Values::Numeric => self.adj.values().iter().sum(),
            Values::Strings(_) => self.nnz() as f64,
        }
    }

    // ------------------------------------------------------------------
    // helpers
    // ------------------------------------------------------------------

    /// True when values are strings.
    pub fn is_string(&self) -> bool {
        !self.val.is_numeric()
    }

    fn pool(&self) -> &[Box<str>] {
        self.val.strings().expect("string array")
    }

    /// A numeric view: identity for numeric arrays, `logical()` for
    /// string arrays.
    fn as_numeric(&self) -> std::borrow::Cow<'_, Assoc> {
        if self.is_numeric() {
            std::borrow::Cow::Borrowed(self)
        } else {
            std::borrow::Cow::Owned(self.logical())
        }
    }
}

pub(crate) fn vals_to_strings(v: ValsInput) -> Vec<String> {
    match v {
        ValsInput::Str(v) => v,
        ValsInput::Num(v) => v
            .into_iter()
            .map(|x| {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    format!("{}", x as i64)
                } else {
                    format!("{x}")
                }
            })
            .collect(),
        ValsInput::NumScalar(_) | ValsInput::StrScalar(_) => {
            unreachable!("triples() never yields scalars")
        }
    }
}

/// Union-merge the numeric triples of two arrays with `agg` applied on
/// collisions (exactly one collision per common key pair).
fn collect_union_triples(
    a: &Assoc,
    b: &Assoc,
    agg: fn(f64, f64) -> f64,
) -> (Vec<Key>, Vec<Key>, Vec<f64>) {
    use std::collections::BTreeMap;
    let mut m: BTreeMap<(Key, Key), f64> = BTreeMap::new();
    for (r, c, v) in a.iter() {
        m.insert((r.clone(), c.clone()), v.as_num().expect("numeric"));
    }
    for (r, c, v) in b.iter() {
        let v = v.as_num().expect("numeric");
        m.entry((r.clone(), c.clone()))
            .and_modify(|x| *x = agg(*x, v))
            .or_insert(v);
    }
    let mut rows = Vec::with_capacity(m.len());
    let mut cols = Vec::with_capacity(m.len());
    let mut vals = Vec::with_capacity(m.len());
    for ((r, c), v) in m {
        rows.push(r);
        cols.push(c);
        vals.push(v);
    }
    (rows, cols, vals)
}

/// `A + B` (operator form).
impl std::ops::Add<&Assoc> for &Assoc {
    type Output = Assoc;
    fn add(self, rhs: &Assoc) -> Assoc {
        Assoc::add(self, rhs)
    }
}

/// `A * B` — element-wise multiplication (operator form; `@` has no Rust
/// operator, use [`Assoc::matmul`]).
impl std::ops::Mul<&Assoc> for &Assoc {
    type Output = Assoc;
    fn mul(self, rhs: &Assoc) -> Assoc {
        Assoc::elemmul(self, rhs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assoc::tests::music;
    use crate::semiring::{MaxPlus, MinPlus};
    use crate::util::prop::check;

    fn num(rows: &[&str], cols: &[&str], vals: &[f64]) -> Assoc {
        Assoc::from_triples(rows, cols, vals.to_vec())
    }

    #[test]
    fn numeric_add_union_semantics() {
        let a = num(&["r1", "r1"], &["c1", "c2"], &[1.0, 2.0]);
        let b = num(&["r1", "r2"], &["c2", "c3"], &[10.0, 5.0]);
        let c = &a + &b;
        assert_eq!(c.get_num("r1", "c1"), Some(1.0));
        assert_eq!(c.get_num("r1", "c2"), Some(12.0));
        assert_eq!(c.get_num("r2", "c3"), Some(5.0));
        assert_eq!(c.shape(), (2, 3));
    }

    #[test]
    fn add_with_empty_is_identity() {
        let a = num(&["r"], &["c"], &[3.0]);
        assert_eq!(&a + &Assoc::empty(), a);
        assert_eq!(&Assoc::empty() + &a, a);
    }

    #[test]
    fn add_cancellation_condenses() {
        let a = num(&["r1", "r2"], &["c1", "c2"], &[1.0, 1.0]);
        let b = num(&["r1"], &["c1"], &[-1.0]);
        let c = &a + &b;
        assert_eq!(c.shape(), (1, 1));
        assert_eq!(c.get_num("r2", "c2"), Some(1.0));
    }

    #[test]
    fn string_add_concatenates_on_collision() {
        let a = Assoc::from_triples(&["r"], &["c"], &["foo"][..]);
        let b = Assoc::from_triples(&["r", "r2"], &["c", "c"], &["bar", "solo"][..]);
        let c = &a + &b;
        assert_eq!(c.get_str("r", "c"), Some("foobar"));
        assert_eq!(c.get_str("r2", "c"), Some("solo"));
    }

    #[test]
    fn mixed_add_renders_numbers() {
        let a = Assoc::from_triples(&["r"], &["c"], &["v="][..]);
        let b = num(&["r"], &["c"], &[7.0]);
        let c = &a + &b;
        assert_eq!(c.get_str("r", "c"), Some("v=7"));
    }

    #[test]
    fn elemmin_elemmax_union() {
        let a = num(&["r1", "r2"], &["c", "c"], &[5.0, 1.0]);
        let b = num(&["r1"], &["c"], &[3.0]);
        assert_eq!(a.elemmin(&b).get_num("r1", "c"), Some(3.0));
        assert_eq!(a.elemmin(&b).get_num("r2", "c"), Some(1.0)); // union keeps b-absent
        assert_eq!(a.elemmax(&b).get_num("r1", "c"), Some(5.0));
        // String variant.
        let sa = Assoc::from_triples(&["r"], &["c"], &["bb"][..]);
        let sb = Assoc::from_triples(&["r"], &["c"], &["aa"][..]);
        assert_eq!(sa.elemmin(&sb).get_str("r", "c"), Some("aa"));
        assert_eq!(sa.elemmax(&sb).get_str("r", "c"), Some("bb"));
    }

    #[test]
    fn numeric_elemmul_intersection_semantics() {
        let a = num(&["r1", "r1", "r2"], &["c1", "c2", "c1"], &[2.0, 3.0, 4.0]);
        let b = num(&["r1", "r3"], &["c1", "c1"], &[10.0, 9.0]);
        let c = &a * &b;
        assert_eq!(c.get_num("r1", "c1"), Some(20.0));
        assert_eq!(c.nnz(), 1);
        assert_eq!(c.shape(), (1, 1)); // condensed to the surviving keys
    }

    #[test]
    fn elemmul_disjoint_is_empty() {
        let a = num(&["r1"], &["c1"], &[2.0]);
        let b = num(&["r2"], &["c2"], &[3.0]);
        assert!((&a * &b).is_empty());
    }

    #[test]
    fn string_times_numeric_is_mask() {
        let a = music();
        let mask = num(&["0294.mp3", "7802.mp3"], &["genre", "genre"], &[1.0, 1.0]);
        let c = &a * &mask;
        assert_eq!(c.get_str("0294.mp3", "genre"), Some("rock"));
        assert_eq!(c.get_str("7802.mp3", "genre"), Some("pop"));
        assert_eq!(c.nnz(), 2);
        assert!(c.is_string());
        // Pool condensed to just the surviving values.
        assert_eq!(c.values().strings().unwrap().len(), 2);
    }

    #[test]
    fn numeric_times_string_reduces_to_logical() {
        let a = music();
        let m = num(&["0294.mp3"], &["genre"], &[5.0]);
        let c = &m * &a; // numeric × string
        assert!(c.is_numeric());
        assert_eq!(c.get_num("0294.mp3", "genre"), Some(5.0));
    }

    #[test]
    fn string_times_string_is_lex_min() {
        let a = Assoc::from_triples(&["r", "r2"], &["c", "c"], &["zeta", "x"][..]);
        let b = Assoc::from_triples(&["r"], &["c"], &["alpha"][..]);
        let c = &a * &b;
        assert_eq!(c.get_str("r", "c"), Some("alpha"));
        assert_eq!(c.nnz(), 1);
    }

    #[test]
    fn matmul_small_known() {
        // A: r1->k1 (2), r1->k2 (3); B: k1->c1 (10), k2->c1 (100)
        let a = num(&["r1", "r1"], &["k1", "k2"], &[2.0, 3.0]);
        let b = num(&["k1", "k2"], &["c1", "c1"], &[10.0, 100.0]);
        let c = a.matmul(&b);
        assert_eq!(c.get_num("r1", "c1"), Some(320.0));
        assert_eq!(c.shape(), (1, 1));
    }

    #[test]
    fn matmul_contracts_only_common_keys() {
        let a = num(&["r"], &["shared"], &[2.0]);
        let b = num(&["shared", "other"], &["c", "c"], &[5.0, 7.0]);
        let c = a.matmul(&b);
        assert_eq!(c.get_num("r", "c"), Some(10.0));
        // disjoint contraction → empty
        let d = num(&["r"], &["x"], &[1.0]).matmul(&num(&["y"], &["c"], &[1.0]));
        assert!(d.is_empty());
    }

    #[test]
    fn matmul_string_operands_logicalized() {
        let a = music();
        let ata = a.sqin(); // AᵀA: column-key correlation counts
        assert!(ata.is_numeric());
        // Every track has each attribute: diagonal = 3.
        assert_eq!(ata.get_num("artist", "artist"), Some(3.0));
        assert_eq!(ata.get_num("artist", "genre"), Some(3.0));
        assert_eq!(ata.shape(), (3, 3));
    }

    #[test]
    fn matmul_semiring_minplus() {
        // Shortest path through one hop: r -k1-> c (2+10), r -k2-> c (3+1).
        let a = num(&["r", "r"], &["k1", "k2"], &[2.0, 3.0]);
        let b = num(&["k1", "k2"], &["c", "c"], &[10.0, 1.0]);
        let c = a.matmul_with(&b, &MinPlus);
        assert_eq!(c.get_num("r", "c"), Some(4.0));
        let c = a.matmul_with(&b, &MaxPlus);
        assert_eq!(c.get_num("r", "c"), Some(12.0));
    }

    #[test]
    fn catkeymul_records_witnesses() {
        // r -k1-> c and r -k2-> c: witnesses are "k1;k2" (sorted).
        let a = num(&["r", "r"], &["k1", "k2"], &[1.0, 1.0]);
        let b = num(&["k1", "k2"], &["c", "c"], &[1.0, 1.0]);
        let c = a.catkeymul(&b, ";");
        assert!(c.is_string());
        assert_eq!(c.get_str("r", "c"), Some("k1;k2"));
        // Numeric matmul on the same operands counts the witnesses.
        assert_eq!(a.matmul(&b).get_num("r", "c"), Some(2.0));
    }

    #[test]
    fn catkeymul_empty_and_single() {
        let a = num(&["r"], &["x"], &[1.0]);
        let b = num(&["y"], &["c"], &[1.0]);
        assert!(a.catkeymul(&b, ";").is_empty());
        let b2 = num(&["x"], &["c"], &[1.0]);
        assert_eq!(a.catkeymul(&b2, ";").get_str("r", "c"), Some("x"));
    }

    #[test]
    fn prop_catkeymul_support_matches_matmul() {
        check("catkeymul support == matmul support", 60, |g| {
            let (r1, c1, v1) = g.triples(25, 8);
            let (r2, c2, v2) = g.triples(25, 8);
            let a = Assoc::from_triples(&r1, &c1, v1);
            let b = Assoc::from_triples(&r2, &c2, v2);
            let ck = a.catkeymul(&b, ";");
            let mm = a.logical().matmul(&b.logical());
            assert_eq!(ck.nnz(), mm.nnz());
            for (r, c, v) in ck.iter() {
                // Witness count == logical contraction count.
                let count = v.as_str().unwrap().split(';').count() as f64;
                assert_eq!(mm.get_num(r.clone(), c.clone()), Some(count));
            }
        });
    }

    #[test]
    fn transpose_roundtrip_and_values() {
        let a = music();
        let t = a.transpose();
        assert_eq!(t.get_str("genre", "0294.mp3"), Some("rock"));
        assert_eq!(t.transpose(), a);
    }

    #[test]
    fn logical_makes_ones() {
        let a = music();
        let l = a.logical();
        assert!(l.is_numeric());
        assert_eq!(l.nnz(), a.nnz());
        assert!(l.iter().all(|(_, _, v)| v.as_num() == Some(1.0)));
    }

    #[test]
    fn sum_axes() {
        let a = num(&["r1", "r1", "r2"], &["c1", "c2", "c1"], &[1.0, 2.0, 4.0]);
        let rowsum = a.sum(1); // collapse columns
        assert_eq!(rowsum.get_num("r1", 1i64), Some(3.0));
        assert_eq!(rowsum.get_num("r2", 1i64), Some(4.0));
        let colsum = a.sum(0); // collapse rows
        assert_eq!(colsum.get_num(1i64, "c1"), Some(5.0));
        assert_eq!(colsum.get_num(1i64, "c2"), Some(2.0));
    }

    #[test]
    fn count_counts_nonempty() {
        let a = music();
        let degrees = a.count(1);
        assert_eq!(degrees.get_num("0294.mp3", 1i64), Some(3.0));
        assert_eq!(a.total(), 9.0);
    }

    #[test]
    fn prop_add_commutative_numeric() {
        check("A + B == B + A (numeric)", 150, |g| {
            let (r1, c1, v1) = g.triples(40, 12);
            let (r2, c2, v2) = g.triples(40, 12);
            let a = Assoc::from_triples(&r1, &c1, v1);
            let b = Assoc::from_triples(&r2, &c2, v2);
            assert_eq!(&a + &b, &b + &a);
        });
    }

    #[test]
    fn prop_add_associative_numeric() {
        check("(A+B)+C == A+(B+C) (integer values)", 100, |g| {
            let mk = |g: &mut crate::util::prop::Gen| {
                let (r, c, v) = g.triples(25, 8);
                Assoc::from_triples(&r, &c, v)
            };
            let a = mk(g);
            let b = mk(g);
            let c = mk(g);
            assert_eq!(&(&a + &b) + &c, &a + &(&b + &c));
        });
    }

    #[test]
    fn prop_elemmul_matches_pointwise_model() {
        check("(A*B)[i,j] == A[i,j]*B[i,j]", 150, |g| {
            let (r1, c1, v1) = g.triples(30, 10);
            let (r2, c2, v2) = g.triples(30, 10);
            let a = Assoc::from_triples(&r1, &c1, v1);
            let b = Assoc::from_triples(&r2, &c2, v2);
            let c = &a * &b;
            for i in 0..10u64 {
                for j in 0..10u64 {
                    let (ik, jk) = (i.to_string(), j.to_string());
                    let expect = a.get_num(ik.as_str(), jk.as_str()).unwrap_or(0.0)
                        * b.get_num(ik.as_str(), jk.as_str()).unwrap_or(0.0);
                    let got = c.get_num(ik.as_str(), jk.as_str()).unwrap_or(0.0);
                    assert_eq!(got, expect, "at ({ik},{jk})");
                }
            }
        });
    }

    #[test]
    fn prop_matmul_matches_contraction_model() {
        check("(A@B)[i,j] == Σ_k A[i,k]B[k,j]", 80, |g| {
            let (r1, c1, v1) = g.triples(25, 8);
            let (r2, c2, v2) = g.triples(25, 8);
            let a = Assoc::from_triples(&r1, &c1, v1);
            let b = Assoc::from_triples(&r2, &c2, v2);
            let c = a.matmul(&b);
            for i in 0..8u64 {
                for j in 0..8u64 {
                    let (ik, jk) = (i.to_string(), j.to_string());
                    let mut expect = 0.0;
                    for k in 0..8u64 {
                        let kk = k.to_string();
                        expect += a.get_num(ik.as_str(), kk.as_str()).unwrap_or(0.0)
                            * b.get_num(kk.as_str(), jk.as_str()).unwrap_or(0.0);
                    }
                    assert_eq!(
                        c.get_num(ik.as_str(), jk.as_str()).unwrap_or(0.0),
                        expect,
                        "at ({ik},{jk})"
                    );
                }
            }
        });
    }

    #[test]
    fn prop_distributivity_matmul_over_add() {
        check("A@(B+C) == A@B + A@C (integer values)", 60, |g| {
            let mk = |g: &mut crate::util::prop::Gen| {
                let (r, c, v) = g.triples(20, 6);
                Assoc::from_triples(&r, &c, v)
            };
            let a = mk(g);
            let b = mk(g);
            let c = mk(g);
            let left = a.matmul(&(&b + &c));
            let right = &a.matmul(&b) + &a.matmul(&c);
            assert_eq!(left, right);
        });
    }

    #[test]
    fn prop_transpose_antihomomorphism() {
        check("(A@B)ᵀ == Bᵀ@Aᵀ", 80, |g| {
            let (r1, c1, v1) = g.triples(25, 8);
            let (r2, c2, v2) = g.triples(25, 8);
            let a = Assoc::from_triples(&r1, &c1, v1);
            let b = Assoc::from_triples(&r2, &c2, v2);
            assert_eq!(a.matmul(&b).transpose(), b.transpose().matmul(&a.transpose()));
        });
    }
}
