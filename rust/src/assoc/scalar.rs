//! Scalar arithmetic and comparison operations — the rest of D4M's
//! day-to-day API: `A + 3`, `A * 2`, `A > 5`, `A == "rock"`, `abs`,
//! element-wise divide.
//!
//! Comparisons return **indicator arrays** (numeric 1 at every entry
//! satisfying the predicate, unstored elsewhere), D4M's idiom for
//! building masks that feed back into element-wise multiplication.

use super::{Assoc, Values};

impl Assoc {
    /// Map every nonempty numeric value through `f`, dropping results
    /// equal to zero (string arrays are `logical()`-ed first).
    pub fn map_num(&self, f: impl Fn(f64) -> f64 + Copy) -> Assoc {
        let base = if self.is_string() { self.logical() } else { self.clone() };
        Assoc {
            row: base.row,
            col: base.col,
            val: Values::Numeric,
            adj: base.adj.map_values(0.0, f),
        }
        .condensed()
    }

    /// `A + s` on nonempty entries (note: *not* on the implicit zeros —
    /// associative arrays only store and transform nonempty values,
    /// matching D4M).
    pub fn scalar_add(&self, s: f64) -> Assoc {
        self.map_num(move |v| v + s)
    }

    /// `A * s` on nonempty entries.
    pub fn scalar_mul(&self, s: f64) -> Assoc {
        self.map_num(move |v| v * s)
    }

    /// `|A|` element-wise.
    pub fn abs(&self) -> Assoc {
        self.map_num(f64::abs)
    }

    /// Element-wise division `A ./ B` over the intersection of key
    /// spaces. Division by a stored zero cannot occur (zeros are
    /// unstored); any non-finite result is dropped.
    pub fn elemdiv(&self, other: &Assoc) -> Assoc {
        use crate::semiring::FnSemiring;
        fn div(a: f64, b: f64) -> f64 {
            let q = a / b;
            if q.is_finite() {
                q
            } else {
                0.0
            }
        }
        fn never(_: f64, _: f64) -> f64 {
            unreachable!("multiply never calls ⊕")
        }
        let s = FnSemiring::new("divide", 0.0, 1.0, never, div);
        self.elemmul_with(other, &s)
    }

    /// Indicator of entries with numeric value `> s`.
    pub fn gt(&self, s: f64) -> Assoc {
        self.map_num(move |v| if v > s { 1.0 } else { 0.0 })
    }

    /// Indicator of entries with numeric value `>= s`.
    pub fn ge(&self, s: f64) -> Assoc {
        self.map_num(move |v| if v >= s { 1.0 } else { 0.0 })
    }

    /// Indicator of entries with numeric value `< s` (nonempty only).
    pub fn lt(&self, s: f64) -> Assoc {
        self.map_num(move |v| if v < s { 1.0 } else { 0.0 })
    }

    /// Indicator of entries with numeric value `<= s` (nonempty only).
    pub fn le(&self, s: f64) -> Assoc {
        self.map_num(move |v| if v <= s { 1.0 } else { 0.0 })
    }

    /// Indicator of entries equal to the numeric value `s` (for `s = 0`
    /// this is always empty: zeros are unstored).
    pub fn eq_num(&self, s: f64) -> Assoc {
        self.map_num(move |v| if v == s { 1.0 } else { 0.0 })
    }

    /// Indicator of string entries equal to `s` — `A == "rock"`, the
    /// facet-query primitive. Empty for numeric arrays.
    pub fn eq_str(&self, s: &str) -> Assoc {
        let pool = match &self.val {
            Values::Strings(pool) => pool,
            Values::Numeric => return Assoc::empty(),
        };
        // The pool is sorted: the match, if any, is one binary search.
        let target = match pool.binary_search_by(|p| p.as_ref().cmp(s)) {
            Ok(i) => (i + 1) as f64,
            Err(_) => return Assoc::empty(),
        };
        Assoc {
            row: self.row.clone(),
            col: self.col.clone(),
            val: Values::Numeric,
            adj: self.adj.map_values(0.0, |v| if v == target { 1.0 } else { 0.0 }),
        }
        .condensed()
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::music;
    use super::*;
    use crate::assoc::ValsInput;

    fn nums() -> Assoc {
        Assoc::from_triples(
            &["r1", "r1", "r2"],
            &["c1", "c2", "c1"],
            ValsInput::Num(vec![2.0, -3.0, 5.0]),
        )
    }

    #[test]
    fn scalar_arith() {
        let a = nums();
        assert_eq!(a.scalar_add(1.0).get_num("r1", "c2"), Some(-2.0));
        assert_eq!(a.scalar_mul(2.0).get_num("r2", "c1"), Some(10.0));
        assert_eq!(a.abs().get_num("r1", "c2"), Some(3.0));
    }

    #[test]
    fn scalar_add_can_cancel() {
        let a = nums();
        let b = a.scalar_add(3.0); // -3 + 3 = 0 → dropped + condensed
        assert_eq!(b.get_num("r1", "c2"), None);
        assert_eq!(b.nnz(), 2);
        assert_eq!(b.col_keys().len(), 1);
    }

    #[test]
    fn comparisons_are_indicators() {
        let a = nums();
        let big = a.gt(1.0);
        assert_eq!(big.get_num("r1", "c1"), Some(1.0));
        assert_eq!(big.get_num("r2", "c1"), Some(1.0));
        assert_eq!(big.nnz(), 2);
        assert_eq!(a.lt(0.0).nnz(), 1);
        assert_eq!(a.ge(5.0).nnz(), 1);
        assert_eq!(a.le(2.0).nnz(), 2);
        assert_eq!(a.eq_num(5.0).nnz(), 1);
    }

    #[test]
    fn comparison_feeds_mask() {
        // Classic idiom: A * (A > 1) keeps only the large entries.
        let a = nums();
        let masked = a.elemmul(&a.gt(1.0));
        assert_eq!(masked.nnz(), 2);
        assert_eq!(masked.get_num("r1", "c1"), Some(2.0));
        assert_eq!(masked.get_num("r1", "c2"), None);
    }

    #[test]
    fn eq_str_facet_query() {
        let a = music();
        let rock = a.eq_str("rock");
        assert!(rock.is_numeric());
        assert_eq!(rock.nnz(), 1);
        assert_eq!(rock.get_num("0294.mp3", "genre"), Some(1.0));
        assert!(a.eq_str("no-such-value").is_empty());
        // eq_str on numeric arrays is empty.
        assert!(nums().eq_str("2").is_empty());
    }

    #[test]
    fn elemdiv_intersection() {
        let a = nums();
        let b = Assoc::from_triples(&["r1"], &["c1"], ValsInput::Num(vec![4.0]));
        let q = a.elemdiv(&b);
        assert_eq!(q.get_num("r1", "c1"), Some(0.5));
        assert_eq!(q.nnz(), 1);
    }

    #[test]
    fn string_arrays_logicalize_for_scalar_math() {
        let a = music();
        let doubled = a.scalar_mul(2.0);
        assert!(doubled.is_numeric());
        assert!(doubled.iter().all(|(_, _, v)| v.as_num() == Some(2.0)));
    }
}
