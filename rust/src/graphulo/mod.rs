//! Graphulo-style server-side graph kernels over the triple store.
//!
//! Graphulo (paper refs [18], [19]) implements "matrix math primitives
//! and graph algorithm building blocks in the style of GraphBLAS on top
//! of Accumulo, representing database tables as D4M associative arrays".
//! This module is that layer for the in-repo store:
//!
//! * [`table_mult`] — server-side `C += Aᵀ ⊗.⊕ B` computed by streaming
//!   scans (Graphulo's `TableMult`, which contracts over the *row*
//!   dimension of both inputs — the transpose-free formulation that fits
//!   a row-sorted store).
//! * [`degree_table`] — out/in degree tables (Graphulo's pre-computed
//!   degree tables used for query planning).
//! * [`bfs`] — k-hop breadth-first expansion from a seed set using the
//!   adjacency + transpose tables.
//! * [`jaccard`] — neighborhood Jaccard similarity from the adjacency
//!   table (a standard Graphulo demo kernel).
//!
//! All kernels stream through [`ScanRange`]s and write results back via
//! a [`BatchWriter`] — no full-table materialization in the "server".

use crate::assoc::Assoc;
use crate::semiring::Semiring;
use crate::sparse::{spgemm_par, CooMatrix, CsrMatrix};
use crate::store::{BatchWriter, ScanRange, Table, Triple, WriterConfig};
use crate::util::Parallelism;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

/// Server-side table multiplication (Graphulo `TableMult`):
/// `C(c1, c2) ⊕= Σ_r Aᵀ(c1, r) ⊗ B(r, c2) = Σ_r A(r, c1) ⊗ B(r, c2)`.
///
/// Both operands are scanned row-by-row (one sorted pass each — rows
/// align because both tables are row-sorted), partial products are
/// accumulated under `s`, and the result is written into `out`. Values
/// must parse as numbers (Graphulo multiplies numeric weights).
///
/// Returns the number of result cells written.
pub fn table_mult(a: &Table, b: &Table, out: &Arc<Table>, s: &dyn Semiring) -> usize {
    table_mult_par(a, b, out, s, Parallelism::current())
}

/// [`table_mult`] with an explicit thread configuration: the two input
/// scans fan out per tablet, and the contraction itself runs on the
/// adaptive SpGEMM engine — both scans are indexed into hypersparse CSR
/// matrices over the shared (sorted) row dimension, `AᵀB` is one
/// `spgemm_par` call against `A`'s cached transpose dual, and the
/// result streams back out as triples. This replaces the old
/// string-keyed `BTreeMap` outer-product accumulation (one map probe
/// per ⊗) and is numerically identical to it: per output cell, partial
/// products still combine in ascending row-key order.
pub fn table_mult_par(
    a: &Table,
    b: &Table,
    out: &Arc<Table>,
    s: &dyn Semiring,
    par: Parallelism,
) -> usize {
    let ta = a.scan_par(ScanRange::all(), par);
    let tb = b.scan_par(ScanRange::all(), par);
    // Shared contraction dimension: merged distinct row keys (scans are
    // sorted by row, so this is a linear merge).
    let rows = merge_distinct(&distinct_rows(&ta), &distinct_rows(&tb));
    if rows.is_empty() {
        return 0;
    }
    let (ma, cols_a) = scan_to_csr(&ta, &rows);
    let (mb, cols_b) = scan_to_csr(&tb, &rows);
    // `Aᵀ` row c1 walks the rows containing c1 in ascending key order —
    // the same ⊕ order the streaming row-join produced.
    let at = ma.transpose_cached();
    let c = spgemm_par(at, &mb, s, par).expect("shared row dimension");
    let mut w = BatchWriter::new(Arc::clone(out), WriterConfig::default());
    let mut cells = 0usize;
    for (i, &c1) in cols_a.iter().enumerate() {
        let (cj, cv) = c.row(i);
        for (j, v) in cj.iter().zip(cv) {
            if *v != s.zero() {
                w.put(Triple::new(c1, cols_b[*j as usize], format_num(*v)));
                cells += 1;
            }
        }
    }
    w.flush();
    cells
}

/// Distinct row keys of a (row-sorted) scan, in order.
fn distinct_rows(scan: &[Triple]) -> Vec<&str> {
    let mut out: Vec<&str> = Vec::new();
    for t in scan {
        if out.last() != Some(&t.row.as_str()) {
            out.push(t.row.as_str());
        }
    }
    out
}

/// Merge two sorted, distinct key lists into their sorted union.
fn merge_distinct<'a>(x: &[&'a str], y: &[&'a str]) -> Vec<&'a str> {
    let mut out = Vec::with_capacity(x.len().max(y.len()));
    let (mut i, mut j) = (0usize, 0usize);
    while i < x.len() || j < y.len() {
        let next = match (x.get(i), y.get(j)) {
            (Some(&a), Some(&b)) => a.min(b),
            (Some(&a), None) => a,
            (None, Some(&b)) => b,
            (None, None) => unreachable!(),
        };
        if i < x.len() && x[i] == next {
            i += 1;
        }
        if j < y.len() && y[j] == next {
            j += 1;
        }
        out.push(next);
    }
    out
}

/// Index a (row, col)-sorted scan into a CSR matrix over the given
/// sorted row key space (a superset of the scan's rows). Returns the
/// matrix and its sorted distinct column keys. Values parse like the
/// streaming join did (`unwrap_or(0.0)`), and parsed zeros stay stored
/// so non-plus-times semirings see exactly the cells the table holds.
fn scan_to_csr<'a>(scan: &'a [Triple], rows: &[&str]) -> (CsrMatrix, Vec<&'a str>) {
    let mut cols: Vec<&str> = scan.iter().map(|t| t.col.as_str()).collect();
    cols.sort_unstable();
    cols.dedup();
    let mut ri: Vec<u32> = Vec::with_capacity(scan.len());
    let mut ci: Vec<u32> = Vec::with_capacity(scan.len());
    let mut vals: Vec<f64> = Vec::with_capacity(scan.len());
    let mut rp = 0usize;
    for t in scan {
        // Scan rows are sorted and `rows` is a sorted superset, so the
        // cursor only moves forward.
        while rows[rp] != t.row.as_str() {
            rp += 1;
        }
        let c = cols.binary_search(&t.col.as_str()).expect("column collected above");
        ri.push(rp as u32);
        ci.push(c as u32);
        vals.push(t.val.parse().unwrap_or(0.0));
    }
    let m = CooMatrix::from_sorted_parts(rows.len(), cols.len(), ri, ci, vals).into_csr();
    (m, cols)
}

/// Build degree tables from an edge table: `(node, "deg", count)`.
/// `out_degrees` counts cells per row (out-degree in an adjacency
/// table); run it on the transpose table for in-degrees.
pub fn degree_table(edges: &Table, out: &Arc<Table>) -> usize {
    let scan = edges.scan(ScanRange::all());
    let mut w = BatchWriter::new(Arc::clone(out), WriterConfig::default());
    let mut count = 0usize;
    let mut nodes = 0usize;
    let mut current: Option<String> = None;
    let flush_node = |node: &str, count: usize, w: &mut BatchWriter| {
        w.put(Triple::new(node, "deg", count.to_string()));
    };
    for t in &scan {
        match &mut current {
            Some(node) if *node == t.row => count += 1,
            Some(node) => {
                flush_node(node, count, &mut w);
                nodes += 1;
                current = Some(t.row.clone());
                count = 1;
            }
            None => {
                current = Some(t.row.clone());
                count = 1;
            }
        }
    }
    if let Some(node) = current {
        flush_node(&node, count, &mut w);
        nodes += 1;
    }
    w.flush();
    nodes
}

/// k-hop BFS from `seeds` over an adjacency table (`row → col` edges).
/// Returns the set of reached nodes per hop (hop 0 = the seeds that
/// exist in the table ∪ given set).
pub fn bfs(adj: &Table, seeds: &[String], hops: usize) -> Vec<BTreeSet<String>> {
    let mut frontiers: Vec<BTreeSet<String>> = Vec::with_capacity(hops + 1);
    let mut visited: BTreeSet<String> = seeds.iter().cloned().collect();
    frontiers.push(visited.clone());
    let mut frontier: BTreeSet<String> = visited.clone();
    for _ in 0..hops {
        let mut next = BTreeSet::new();
        for node in &frontier {
            for t in adj.scan(ScanRange::single(node.clone())) {
                if !visited.contains(&t.col) {
                    next.insert(t.col.clone());
                }
            }
        }
        visited.extend(next.iter().cloned());
        frontiers.push(next.clone());
        if next.is_empty() {
            break;
        }
        frontier = next;
    }
    frontiers
}

/// Jaccard similarity of the out-neighborhoods of every pair of nodes
/// that share at least one neighbor. Returns an associative array
/// `J(u, v) = |N(u) ∩ N(v)| / |N(u) ∪ N(v)|` for `u < v`.
pub fn jaccard(adj: &Table) -> Assoc {
    let scan = adj.scan(ScanRange::all());
    // Build neighbor sets.
    let mut nbrs: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    for t in &scan {
        nbrs.entry(t.row.clone()).or_default().insert(t.col.clone());
    }
    // Invert: neighbor -> rows touching it, so only co-neighbor pairs
    // are considered (sparse pair enumeration).
    let mut inv: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for (u, ns) in &nbrs {
        for n in ns {
            inv.entry(n.as_str()).or_default().push(u.as_str());
        }
    }
    let mut inter: BTreeMap<(String, String), usize> = BTreeMap::new();
    for (_, us) in inv {
        for (ai, u) in us.iter().enumerate() {
            for v in &us[ai + 1..] {
                inter
                    .entry((u.to_string(), v.to_string()))
                    .and_modify(|c| *c += 1)
                    .or_insert(1);
            }
        }
    }
    let mut rows = Vec::new();
    let mut cols = Vec::new();
    let mut vals = Vec::new();
    for ((u, v), i) in inter {
        let nu = nbrs[&u].len();
        let nv = nbrs[&v].len();
        let union = nu + nv - i;
        rows.push(crate::assoc::Key::str(u));
        cols.push(crate::assoc::Key::str(v));
        vals.push(i as f64 / union as f64);
    }
    Assoc::try_new(rows, cols, crate::assoc::ValsInput::Num(vals), crate::assoc::Aggregator::First)
        .expect("jaccard triples")
}

fn format_num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::semiring::PlusTimes;
    use crate::store::{TableConfig, TableStore};

    /// Small directed graph:  a→b, a→c, b→c, c→d.
    fn graph_store() -> (TableStore, Arc<Table>, Arc<Table>) {
        let store = TableStore::with_defaults();
        let edges = Assoc::from_triples(
            &["a", "a", "b", "c"],
            &["b", "c", "c", "d"],
            1.0,
        );
        let (t, tt) = store.ingest_assoc("edges", &edges);
        (store, t, tt)
    }

    #[test]
    fn table_mult_is_ata() {
        // TableMult(A, A) computes AᵀA: co-occurrence of columns.
        let (store, t, _) = graph_store();
        let out = store.create_table("ata");
        let cells = table_mult(&t, &t, &out, &PlusTimes);
        assert!(cells > 0);
        let ata = store.read_assoc("ata").unwrap();
        // Column c is reached from a and b; col b from a: (AᵀA)[b,c] = 1 (via a).
        assert_eq!(ata.get_num("b", "c"), Some(1.0));
        assert_eq!(ata.get_num("c", "c"), Some(2.0)); // two in-edges
        // Cross-check against the in-core algebra.
        let a = store.read_assoc("edges").unwrap();
        assert_eq!(ata, a.sqin());
    }

    #[test]
    fn degree_tables_both_directions() {
        let (store, t, tt) = graph_store();
        let dout = store.create_table("deg_out");
        let din = store.create_table("deg_in");
        assert_eq!(degree_table(&t, &dout), 3); // a, b, c have out-edges
        assert_eq!(degree_table(&tt, &din), 3); // b, c, d have in-edges
        assert_eq!(dout.get("a", "deg"), Some("2".into()));
        assert_eq!(dout.get("c", "deg"), Some("1".into()));
        assert_eq!(din.get("c", "deg"), Some("2".into()));
        assert_eq!(din.get("a", "deg"), None);
    }

    #[test]
    fn bfs_hops() {
        let (_, t, _) = graph_store();
        let fr = bfs(&t, &["a".to_string()], 3);
        assert_eq!(fr[0], ["a".to_string()].into_iter().collect());
        assert_eq!(fr[1], ["b".to_string(), "c".to_string()].into_iter().collect());
        assert_eq!(fr[2], ["d".to_string()].into_iter().collect());
        // Frontier exhausts; no 4th hop entry beyond the empty one.
        assert!(fr.len() <= 4);
    }

    #[test]
    fn bfs_no_revisit() {
        let store = TableStore::with_defaults();
        // Cycle: x→y, y→x.
        let edges = Assoc::from_triples(&["x", "y"], &["y", "x"], 1.0);
        let (t, _) = store.ingest_assoc("cyc", &edges);
        let fr = bfs(&t, &["x".to_string()], 5);
        assert_eq!(fr[1], ["y".to_string()].into_iter().collect());
        // y's neighbor x is already visited → BFS terminates.
        assert!(fr.len() == 3 && fr[2].is_empty() || fr.len() == 2);
    }

    #[test]
    fn jaccard_shared_neighbors() {
        let (_, t, _) = graph_store();
        let j = jaccard(&t);
        // N(a) = {b, c}, N(b) = {c}: intersection 1, union 2 → 0.5.
        assert_eq!(j.get_num("a", "b"), Some(0.5));
        // a and c share no out-neighbors → no entry.
        assert_eq!(j.get_num("a", "c"), None);
    }

    #[test]
    fn table_mult_on_split_tables() {
        // Force splits, then verify TableMult still agrees with sqin().
        let store = TableStore::new(TableConfig { split_threshold: 128, write_latency_us: 0 });
        let n = 40;
        let rows: Vec<String> = (0..n).map(|i| format!("r{:02}", i % 10)).collect();
        let cols: Vec<String> = (0..n).map(|i| format!("c{:02}", i % 7)).collect();
        let a = Assoc::from_triples(&rows, &cols, 1.0);
        let (t, _) = store.ingest_assoc("m", &a);
        assert!(t.tablet_count() > 1);
        let out = store.create_table("out");
        table_mult(&t, &t, &out, &PlusTimes);
        assert_eq!(store.read_assoc("out").unwrap(), a.sqin());
    }
}

mod algorithms;
pub use algorithms::{pagerank, triangle_count};
