//! Graphulo-style server-side graph kernels over the triple store.
//!
//! Graphulo (paper refs [18], [19]) implements "matrix math primitives
//! and graph algorithm building blocks in the style of GraphBLAS on top
//! of Accumulo, representing database tables as D4M associative arrays".
//! This module is that layer for the in-repo store:
//!
//! * [`table_mult`] — server-side `C += Aᵀ ⊗.⊕ B` computed by streaming
//!   scans (Graphulo's `TableMult`, which contracts over the *row*
//!   dimension of both inputs — the transpose-free formulation that fits
//!   a row-sorted store). [`table_mult_masked`] is the sink-filtered
//!   variant: the output-column mask rides the masked SpGEMM engine, so
//!   a multiply whose sink keeps 10% of columns does ~10% of the work.
//! * [`degree_table`] — out/in degree tables (Graphulo's pre-computed
//!   degree tables used for query planning), produced entirely by a
//!   server-side combiner stage ([`RowReduce::Count`]).
//! * [`bfs`] — k-hop breadth-first expansion from a seed set: each hop
//!   is **one stacked multi-range scan** over the frontier rows (the
//!   Accumulo `BatchScanner` idiom — the servers hop the range set
//!   beneath the block copy), not a seek per node.
//! * [`jaccard`] — neighborhood Jaccard similarity from the adjacency
//!   table (a standard Graphulo demo kernel); [`jaccard_seeded`] is the
//!   node-subset variant riding a multi-range scan.
//!
//! All kernels pull from the server-side iterator stack
//! ([`crate::store::scan`]) and write results back via a
//! [`crate::store::BatchWriter`] — no kernel materializes a full
//! `Vec<Triple>` of its input; scans stream into the compute
//! structures directly, and since PR 4 they stream as
//! *dictionary-encoded id triples*: each side's column keys are
//! interned to dense `u32` ids through a [`crate::util::StrDict`]
//! (cells arrive as shared-bytes handles, so interning is a pointer
//! clone), and the CSR builders consume ids — string bytes are touched
//! once per distinct key instead of once per cell.
//!
//! The kernels are oblivious to the storage tiering underneath (PR 6):
//! an input table whose cells live partly in frozen runs scans
//! byte-identically to an all-in-memory one, so every kernel here works
//! unchanged over compacted tables (pinned by the compacted-input
//! equivalence test below and `tests/scan_stack.rs`).
//!
//! Since PR 10 every kernel routes through the cost-based query
//! planner ([`crate::plan`]): the entry points here *build* logical
//! plans, the planner annotates them with per-table statistics,
//! chooses the physical operators that used to be hard-coded
//! heuristics, and executes the fused pipeline. The `_planned`
//! variants expose the [`Choices`] knobs — [`Choices::frozen`] forces
//! the exact pre-planner behavior (the benchmark baseline), and every
//! choice combination produces bit-identical output
//! (`rust/tests/plan_equivalence.rs`).

use crate::assoc::{Assoc, AssocError};
use crate::plan::{
    execute_mult, execute_reduce_write, plan_mult, plan_scan, Choices, MultNode, ScanNode,
};
use crate::semiring::Semiring;
use crate::store::{KeyMatch, RowReduce, ScanSpec, SharedStr, Table, Triple, SCAN_BLOCK};
use crate::util::Parallelism;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

/// Server-side table multiplication (Graphulo `TableMult`):
/// `C(c1, c2) ⊕= Σ_r Aᵀ(c1, r) ⊗ B(r, c2) = Σ_r A(r, c1) ⊗ B(r, c2)`.
///
/// Both operands are scanned row-by-row (one sorted pass each — rows
/// align because both tables are row-sorted), partial products are
/// accumulated under `s`, and the result is written into `out`. Values
/// must parse as numbers (Graphulo multiplies numeric weights).
///
/// Returns the number of result cells written.
pub fn table_mult(a: &Table, b: &Table, out: &Arc<Table>, s: &dyn Semiring) -> usize {
    table_mult_par(a, b, out, s, Parallelism::current())
}

/// [`table_mult`] with an explicit thread configuration: both scans
/// stream (serial) or fan out per tablet (parallel) into hypersparse
/// CSR matrices over the shared (sorted) row dimension, `AᵀB` is one
/// `spgemm_par` call against `A`'s cached transpose dual, and the
/// result streams back out as triples. Numerically identical to the
/// old streaming row-join: per output cell, partial products combine in
/// ascending row-key order.
pub fn table_mult_par(
    a: &Table,
    b: &Table,
    out: &Arc<Table>,
    s: &dyn Semiring,
    par: Parallelism,
) -> usize {
    table_mult_planned(a, b, out, s, par, &Choices::planner())
}

/// [`table_mult_par`] under explicit planner [`Choices`]: the logical
/// plan is built here, annotated/chosen/executed by [`crate::plan`].
/// Forced choices select physical operators directly (every
/// combination is bit-identical); [`Choices::planner`] is what the
/// plain entry points use.
pub fn table_mult_planned(
    a: &Table,
    b: &Table,
    out: &Arc<Table>,
    s: &dyn Semiring,
    par: Parallelism,
    choices: &Choices,
) -> usize {
    let node = MultNode::new(a, b);
    execute_mult(&plan_mult(&node, choices), out, s, par)
}

/// Sink-filtered [`table_mult`]: compute and write only the output
/// columns whose key matches `keep` — the Graphulo pattern of a
/// multiply feeding a filtered sink table. The filter is pushed all the
/// way into the scans (since PR 5, cost-gated by the planner since
/// PR 10): `B` is scanned with the column filter beneath the tablet
/// block copy, and when the statistics say the surviving row subset is
/// selective `A` is scanned over a multi-range set of `B`'s surviving
/// contraction rows only, so doomed cells are never copied and emptied
/// rows are never visited. The masked SpGEMM engine
/// ([`crate::sparse::spgemm_masked_par`]) still guards the compute
/// stage; the kept cells are bit-identical to running the full
/// multiply and filtering afterwards.
pub fn table_mult_masked(
    a: &Table,
    b: &Table,
    out: &Arc<Table>,
    s: &dyn Semiring,
    keep: &KeyMatch,
) -> usize {
    table_mult_masked_par(a, b, out, s, keep, Parallelism::current())
}

/// [`table_mult_masked`] with an explicit thread configuration.
pub fn table_mult_masked_par(
    a: &Table,
    b: &Table,
    out: &Arc<Table>,
    s: &dyn Semiring,
    keep: &KeyMatch,
    par: Parallelism,
) -> usize {
    table_mult_masked_planned(a, b, out, s, keep, par, &Choices::planner())
}

/// [`table_mult_masked_par`] under explicit planner [`Choices`] (see
/// [`table_mult_planned`]).
pub fn table_mult_masked_planned(
    a: &Table,
    b: &Table,
    out: &Arc<Table>,
    s: &dyn Semiring,
    keep: &KeyMatch,
    par: Parallelism,
    choices: &Choices,
) -> usize {
    let node = MultNode::col_masked(a, b, keep.clone());
    execute_mult(&plan_mult(&node, choices), out, s, par)
}

/// Row-sink-filtered [`table_mult`]: compute and write only the output
/// *rows* whose key matches `keep` — the twin of [`table_mult_masked`]
/// for sinks filtered on the row key space. Output rows of `AᵀB` are
/// `A`'s column keys, so the filter rides `A`'s scan (a pushed-down
/// column filter: doomed cells are rejected beneath the tablet block
/// copy) and, when the planner's statistics say the surviving subset
/// is selective, `B` is scanned over a multi-range set of `A`'s
/// surviving contraction rows only — rows the mask will drop are never
/// scanned (since PR 5, cost-gated since PR 10). The row-masked SpGEMM
/// engine ([`crate::sparse::spgemm_row_masked_par`]) still guards the
/// compute stage, and the kept cells are bit-identical to running the
/// full multiply and filtering afterwards.
pub fn table_mult_row_masked(
    a: &Table,
    b: &Table,
    out: &Arc<Table>,
    s: &dyn Semiring,
    keep: &KeyMatch,
) -> usize {
    table_mult_row_masked_par(a, b, out, s, keep, Parallelism::current())
}

/// [`table_mult_row_masked`] with an explicit thread configuration.
pub fn table_mult_row_masked_par(
    a: &Table,
    b: &Table,
    out: &Arc<Table>,
    s: &dyn Semiring,
    keep: &KeyMatch,
    par: Parallelism,
) -> usize {
    table_mult_row_masked_planned(a, b, out, s, keep, par, &Choices::planner())
}

/// [`table_mult_row_masked_par`] under explicit planner [`Choices`]
/// (see [`table_mult_planned`]).
pub fn table_mult_row_masked_planned(
    a: &Table,
    b: &Table,
    out: &Arc<Table>,
    s: &dyn Semiring,
    keep: &KeyMatch,
    par: Parallelism,
    choices: &Choices,
) -> usize {
    let node = MultNode::row_masked(a, b, keep.clone());
    execute_mult(&plan_mult(&node, choices), out, s, par)
}

/// Build degree tables from an edge table: `(node, "deg", count)`.
/// `out_degrees` counts cells per row (out-degree in an adjacency
/// table); run it on the transpose table for in-degrees.
///
/// The count usually happens *inside* the scan stack — a
/// [`RowReduce::Count`] combiner collapses each row server-side, so
/// exactly one triple per node crosses into the writer. The planner's
/// combiner knob may instead aggregate at the client merge when run
/// statistics say rows mostly hold one cell (scan-side aggregation
/// would shrink nothing); both placements count identically.
pub fn degree_table(edges: &Table, out: &Arc<Table>) -> usize {
    degree_table_planned(edges, out, Parallelism::serial(), &Choices::planner())
}

/// [`degree_table`] with an explicit thread configuration: the counting
/// scan fans out over pinned snapshots as load-balanced range chunks
/// ([`Table::scan_spec_par`] since PR 8 — chunks cut at row
/// boundaries, so the per-node counts are bit-identical to the
/// streamed kernel).
pub fn degree_table_par(edges: &Table, out: &Arc<Table>, par: Parallelism) -> usize {
    degree_table_planned(edges, out, par, &Choices::planner())
}

/// [`degree_table_par`] under explicit planner [`Choices`] (see
/// [`table_mult_planned`]).
pub fn degree_table_planned(
    edges: &Table,
    out: &Arc<Table>,
    par: Parallelism,
    choices: &Choices,
) -> usize {
    let node = ScanNode::full(edges).reduced(RowReduce::Count { out_col: "deg".into() });
    execute_reduce_write(&plan_scan(&node, choices), out, par)
}

/// k-hop BFS from `seeds` over an adjacency table (`row → col` edges).
/// Returns the set of reached nodes per hop. **Hop 0 is the seeds that
/// exist in the table**: the first stacked multi-range scan probes
/// every seed row, and seeds with no adjacency row (absent from the
/// table, or present only as edge *targets* — probing the column space
/// would take the transpose table) are dropped. Dropped seeds never
/// enter the visited set, so a reachable one is still discovered at
/// its true hop distance.
///
/// Every hop is **one stacked scan** over the frontier rows, lowered
/// by the planner's row-set knob: a sorted, coalesced range set (the
/// Accumulo `BatchScanner` idiom — the tablet cursors hop from range
/// to range beneath the block copy, so a 1 000-node frontier costs one
/// scan, not 1 000 seeks) when the statistics say the frontier is
/// selective, or a full scan under an `In` row filter when it is not.
/// The first scan does double duty: the rows it yields *are* the
/// present seeds (hop 0) and their columns are hop 1, so the seed rows
/// are walked once, not twice. A `hops == 0` call probes existence
/// alone, pushing a [`RowReduce::Count`] combiner into the stack so
/// exactly one triple per present seed crosses to the client.
pub fn bfs(adj: &Table, seeds: &[String], hops: usize) -> Vec<BTreeSet<String>> {
    bfs_planned(adj, seeds, hops, Parallelism::serial(), &Choices::planner())
}

/// [`bfs`] with an explicit thread configuration: every hop's frontier
/// scan fans out over pinned snapshots as load-balanced range chunks
/// ([`Table::scan_spec_par`] since PR 8), so a wide frontier's one
/// stacked scan also uses the pool. Chunks cut at row boundaries and
/// stitch in range order, so the hop sets are identical to the
/// streamed kernel's at every thread count.
pub fn bfs_par(
    adj: &Table,
    seeds: &[String],
    hops: usize,
    par: Parallelism,
) -> Vec<BTreeSet<String>> {
    bfs_planned(adj, seeds, hops, par, &Choices::planner())
}

/// [`bfs_par`] under explicit planner [`Choices`] (see
/// [`table_mult_planned`]): the row-set knob decides how each hop's
/// frontier lowers; every choice yields identical hop sets.
pub fn bfs_planned(
    adj: &Table,
    seeds: &[String],
    hops: usize,
    par: Parallelism,
    choices: &Choices,
) -> Vec<BTreeSet<String>> {
    if par.is_serial() {
        bfs_impl(adj, seeds, hops, choices, |spec| adj.scan_stream(spec.batched(SCAN_BLOCK)))
    } else {
        bfs_impl(adj, seeds, hops, choices, |spec| adj.scan_spec_par(&spec, par).into_iter())
    }
}

/// The hop engine shared by the streamed and snapshot-fan-out paths:
/// `scan` runs one stacked scan and yields its row-sorted triples;
/// each hop's spec comes from the planner's row-set lowering.
fn bfs_impl<I, F>(
    adj: &Table,
    seeds: &[String],
    hops: usize,
    choices: &Choices,
    scan: F,
) -> Vec<BTreeSet<String>>
where
    I: Iterator<Item = Triple>,
    F: Fn(ScanSpec) -> I,
{
    let spec_over = |keys: Vec<&str>| plan_scan(&ScanNode::over_rows(adj, keys), choices).spec;
    let seed_spec = || spec_over(seeds.iter().map(|s| s.as_str()).collect());
    let mut frontiers: Vec<BTreeSet<String>> = Vec::with_capacity(hops + 1);
    if hops == 0 {
        // Existence probe only: one triple per present seed row.
        let hop0: BTreeSet<String> = if seeds.is_empty() {
            BTreeSet::new()
        } else {
            scan(seed_spec().reduced(RowReduce::Count { out_col: String::new() }))
                .map(|t| t.row.to_string())
                .collect()
        };
        frontiers.push(hop0);
        return frontiers;
    }
    // One scan yields hop 0 (the seed rows that exist) and hop 1 (their
    // neighbors); the presence set is complete only after the scan, so
    // the visited filter is applied as one set subtraction.
    let mut present: BTreeSet<String> = BTreeSet::new();
    let mut cols: BTreeSet<String> = BTreeSet::new();
    if !seeds.is_empty() {
        let mut last_row: Option<SharedStr> = None;
        for t in scan(seed_spec()) {
            if last_row.as_deref() != Some(t.row.as_str()) {
                present.insert(t.row.to_string());
                last_row = Some(t.row.clone());
            }
            if !cols.contains(t.col.as_str()) {
                cols.insert(t.col.to_string());
            }
        }
    }
    for p in &present {
        cols.remove(p.as_str());
    }
    let next = cols;
    let mut visited = present.clone();
    frontiers.push(present);
    visited.extend(next.iter().cloned());
    frontiers.push(next.clone());
    if next.is_empty() {
        return frontiers;
    }
    let mut frontier = next;
    for _ in 1..hops {
        let mut next = BTreeSet::new();
        let spec = spec_over(frontier.iter().map(|f| f.as_str()).collect());
        for t in scan(spec) {
            if !visited.contains(t.col.as_str()) && !next.contains(t.col.as_str()) {
                next.insert(t.col.to_string());
            }
        }
        visited.extend(next.iter().cloned());
        frontiers.push(next.clone());
        if next.is_empty() {
            break;
        }
        frontier = next;
    }
    frontiers
}

/// Jaccard similarity of the out-neighborhoods of every pair of nodes
/// that share at least one neighbor. Returns an associative array
/// `J(u, v) = |N(u) ∩ N(v)| / |N(u) ∪ N(v)|` for `u < v`, or the
/// constructor error if the collected triples are inconsistent (the
/// kernel no longer panics on them).
pub fn jaccard(adj: &Table) -> Result<Assoc, AssocError> {
    jaccard_over(adj, ScanSpec::all())
}

/// Seeded [`jaccard`]: similarities among `nodes` only. The scan over
/// the node rows is lowered by the planner's row-set knob — a stacked
/// multi-range pass when the subset is selective (rows outside it are
/// never copied out of the tablets), a filtered full scan when it is
/// not — and absent nodes simply contribute nothing. `J(u, v)` depends
/// only on `N(u)` and `N(v)`, so for pairs inside the subset the
/// values are bit-identical to the full kernel's.
pub fn jaccard_seeded(adj: &Table, nodes: &[String]) -> Result<Assoc, AssocError> {
    jaccard_seeded_planned(adj, nodes, Parallelism::serial(), &Choices::planner())
}

/// [`jaccard_seeded`] with an explicit thread configuration: the one
/// stacked scan over the node rows fans out over pinned snapshots as
/// load-balanced range chunks ([`Table::scan_spec_par`] since PR 8).
/// The pair enumeration itself is unchanged, so the similarities are
/// bit-identical to the streamed kernel's at every thread count.
pub fn jaccard_seeded_par(
    adj: &Table,
    nodes: &[String],
    par: Parallelism,
) -> Result<Assoc, AssocError> {
    jaccard_seeded_planned(adj, nodes, par, &Choices::planner())
}

/// [`jaccard_seeded_par`] under explicit planner [`Choices`] (see
/// [`table_mult_planned`]).
pub fn jaccard_seeded_planned(
    adj: &Table,
    nodes: &[String],
    par: Parallelism,
    choices: &Choices,
) -> Result<Assoc, AssocError> {
    let node = ScanNode::over_rows(adj, nodes.iter().map(|n| n.as_str()).collect());
    let spec = plan_scan(&node, choices).spec;
    if par.is_serial() {
        jaccard_triples(adj.scan_stream(spec.batched(SCAN_BLOCK)))
    } else {
        jaccard_triples(adj.scan_spec_par(&spec, par).into_iter())
    }
}

fn jaccard_over(adj: &Table, spec: ScanSpec) -> Result<Assoc, AssocError> {
    jaccard_triples(adj.scan_stream(spec.batched(SCAN_BLOCK)))
}

fn jaccard_triples(triples: impl Iterator<Item = Triple>) -> Result<Assoc, AssocError> {
    // Build neighbor sets straight off the stream (shared handles are
    // moved, not copied, into the map).
    let mut nbrs: BTreeMap<SharedStr, BTreeSet<SharedStr>> = BTreeMap::new();
    for t in triples {
        nbrs.entry(t.row).or_default().insert(t.col);
    }
    // Invert: neighbor -> rows touching it, so only co-neighbor pairs
    // are considered (sparse pair enumeration).
    let mut inv: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for (u, ns) in &nbrs {
        for n in ns {
            inv.entry(n.as_str()).or_default().push(u.as_str());
        }
    }
    // Intersection counts keyed by *borrowed* ids: incrementing a pair
    // allocates nothing (the old map keyed by owned `String` pairs paid
    // two fresh allocations per co-neighbor increment).
    let mut inter: BTreeMap<(&str, &str), usize> = BTreeMap::new();
    for us in inv.values() {
        for (ai, u) in us.iter().enumerate() {
            for v in &us[ai + 1..] {
                *inter.entry((u, v)).or_insert(0) += 1;
            }
        }
    }
    let mut rows = Vec::with_capacity(inter.len());
    let mut cols = Vec::with_capacity(inter.len());
    let mut vals = Vec::with_capacity(inter.len());
    for ((u, v), i) in inter {
        let nu = nbrs[u].len();
        let nv = nbrs[v].len();
        let union = nu + nv - i;
        rows.push(crate::assoc::Key::str(u));
        cols.push(crate::assoc::Key::str(v));
        vals.push(i as f64 / union as f64);
    }
    Assoc::try_new(rows, cols, crate::assoc::ValsInput::Num(vals), crate::assoc::Aggregator::First)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::semiring::{MaxPlus, MinPlus, PlusTimes};
    use crate::store::{ScanRange, TableConfig, TableStore};

    /// Small directed graph:  a→b, a→c, b→c, c→d.
    fn graph_store() -> (TableStore, Arc<Table>, Arc<Table>) {
        let store = TableStore::with_defaults();
        let edges = Assoc::from_triples(
            &["a", "a", "b", "c"],
            &["b", "c", "c", "d"],
            1.0,
        );
        let (t, tt) = store.ingest_assoc("edges", &edges);
        (store, t, tt)
    }

    #[test]
    fn table_mult_is_ata() {
        // TableMult(A, A) computes AᵀA: co-occurrence of columns.
        let (store, t, _) = graph_store();
        let out = store.create_table("ata");
        let cells = table_mult(&t, &t, &out, &PlusTimes);
        assert!(cells > 0);
        let ata = store.read_assoc("ata").unwrap();
        // Column c is reached from a and b; col b from a: (AᵀA)[b,c] = 1 (via a).
        assert_eq!(ata.get_num("b", "c"), Some(1.0));
        assert_eq!(ata.get_num("c", "c"), Some(2.0)); // two in-edges
        // Cross-check against the in-core algebra.
        let a = store.read_assoc("edges").unwrap();
        assert_eq!(ata, a.sqin());
    }

    #[test]
    fn kernels_agree_on_compacted_inputs() {
        // PR 6: inputs may be served from memtable+run stacks; kernel
        // output must not depend on where the cells physically live.
        let (store, t, _) = graph_store();
        let out_mem = store.create_table("ata_mem");
        table_mult(&t, &t, &out_mem, &PlusTimes);
        let expect_bfs = bfs(&t, &["a".to_string()], 3);
        let expect_deg = {
            let d = store.create_table("deg_mem");
            degree_table(&t, &d);
            d.scan(ScanRange::all())
        };
        t.minor_compact().unwrap();
        assert!(t.run_count() >= 1, "input should now be run-backed");
        let out_run = store.create_table("ata_run");
        table_mult(&t, &t, &out_run, &PlusTimes);
        assert_eq!(out_run.scan(ScanRange::all()), out_mem.scan(ScanRange::all()));
        assert_eq!(bfs(&t, &["a".to_string()], 3), expect_bfs);
        let d = store.create_table("deg_run");
        degree_table(&t, &d);
        assert_eq!(d.scan(ScanRange::all()), expect_deg);
    }

    #[test]
    fn degree_tables_both_directions() {
        let (store, t, tt) = graph_store();
        let dout = store.create_table("deg_out");
        let din = store.create_table("deg_in");
        assert_eq!(degree_table(&t, &dout), 3); // a, b, c have out-edges
        assert_eq!(degree_table(&tt, &din), 3); // b, c, d have in-edges
        assert_eq!(dout.get("a", "deg"), Some("2".into()));
        assert_eq!(dout.get("c", "deg"), Some("1".into()));
        assert_eq!(din.get("c", "deg"), Some("2".into()));
        assert_eq!(din.get("a", "deg"), None);
    }

    #[test]
    fn bfs_hops() {
        let (_, t, _) = graph_store();
        let fr = bfs(&t, &["a".to_string()], 3);
        assert_eq!(fr[0], ["a".to_string()].into_iter().collect());
        assert_eq!(fr[1], ["b".to_string(), "c".to_string()].into_iter().collect());
        assert_eq!(fr[2], ["d".to_string()].into_iter().collect());
        // Frontier exhausts; no 4th hop entry beyond the empty one.
        assert!(fr.len() <= 4);
    }

    #[test]
    fn bfs_no_revisit() {
        let store = TableStore::with_defaults();
        // Cycle: x→y, y→x.
        let edges = Assoc::from_triples(&["x", "y"], &["y", "x"], 1.0);
        let (t, _) = store.ingest_assoc("cyc", &edges);
        let fr = bfs(&t, &["x".to_string()], 5);
        assert_eq!(fr[1], ["y".to_string()].into_iter().collect());
        // y's neighbor x is already visited → BFS terminates.
        assert!(fr.len() == 3 && fr[2].is_empty() || fr.len() == 2);
    }

    #[test]
    fn bfs_hop0_probes_the_table() {
        // Regression (PR 5): the documented contract is that hop 0
        // holds only the seeds that exist in the table — the old code
        // pushed every seed into frontiers[0] and visited unprobed.
        let (_, t, _) = graph_store();
        let seeds = ["zz".to_string(), "a".to_string(), "d".to_string()];
        let fr = bfs(&t, &seeds, 3);
        // "zz" appears nowhere; "d" exists only as an edge target (no
        // adjacency row): both are dropped from hop 0.
        assert_eq!(fr[0], ["a".to_string()].into_iter().collect());
        assert_eq!(fr[1], ["b".to_string(), "c".to_string()].into_iter().collect());
        // Because "d" never entered the visited set, it is discovered
        // at its true hop distance from the surviving seed.
        assert_eq!(fr[2], ["d".to_string()].into_iter().collect());
        // All seeds absent → hop 0 empty, expansion stops immediately.
        let none = bfs(&t, &["nope".to_string()], 3);
        assert!(none[0].is_empty());
        assert_eq!(none.len(), 2);
        assert!(none[1].is_empty());
        // No seeds at all behaves identically.
        let empty = bfs(&t, &[], 3);
        assert!(empty[0].is_empty() && empty.len() == 2);
        // hops == 0 is a pure existence probe (Count-reduced scan).
        let zero = bfs(&t, &seeds, 0);
        assert_eq!(zero.len(), 1);
        assert_eq!(zero[0], ["a".to_string()].into_iter().collect());
        assert!(bfs(&t, &[], 0).len() == 1 && bfs(&t, &[], 0)[0].is_empty());
    }

    #[test]
    fn jaccard_shared_neighbors() {
        let (_, t, _) = graph_store();
        let j = jaccard(&t).unwrap();
        // N(a) = {b, c}, N(b) = {c}: intersection 1, union 2 → 0.5.
        assert_eq!(j.get_num("a", "b"), Some(0.5));
        // a and c share no out-neighbors → no entry.
        assert_eq!(j.get_num("a", "c"), None);
    }

    #[test]
    fn jaccard_matches_naive_pairwise_baseline() {
        // Pin the borrowed-key rework bit-identical to the definition:
        // J(u, v) over every pair of rows sharing a neighbor, keys and
        // values exactly as the pre-PR 5 string-keyed path produced.
        let store = TableStore::with_defaults();
        let n = 30;
        let rows: Vec<String> = (0..n).map(|i| format!("u{:02}", i % 9)).collect();
        let cols: Vec<String> = (0..n).map(|i| format!("w{:02}", (i * 5) % 11)).collect();
        let a = Assoc::from_triples(&rows, &cols, 1.0);
        let (t, _) = store.ingest_assoc("g", &a);
        let mut nbrs: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
        for tr in t.scan_stream(ScanSpec::all()) {
            nbrs.entry(tr.row.to_string()).or_default().insert(tr.col.to_string());
        }
        let mut er = Vec::new();
        let mut ec = Vec::new();
        let mut ev = Vec::new();
        let keys: Vec<&String> = nbrs.keys().collect();
        for (i, u) in keys.iter().enumerate() {
            for v in &keys[i + 1..] {
                let inter = nbrs[*u].intersection(&nbrs[*v]).count();
                if inter == 0 {
                    continue;
                }
                let union = nbrs[*u].len() + nbrs[*v].len() - inter;
                er.push(crate::assoc::Key::str(u.as_str()));
                ec.push(crate::assoc::Key::str(v.as_str()));
                ev.push(inter as f64 / union as f64);
            }
        }
        let expect = Assoc::try_new(
            er,
            ec,
            crate::assoc::ValsInput::Num(ev),
            crate::assoc::Aggregator::First,
        )
        .unwrap();
        assert_eq!(jaccard(&t).unwrap(), expect);
    }

    #[test]
    fn jaccard_seeded_matches_full_on_subset_pairs() {
        let store = TableStore::with_defaults();
        let n = 40;
        let rows: Vec<String> = (0..n).map(|i| format!("u{:02}", i % 10)).collect();
        let cols: Vec<String> = (0..n).map(|i| format!("w{:02}", (i * 3) % 13)).collect();
        let a = Assoc::from_triples(&rows, &cols, 1.0);
        let (t, _) = store.ingest_assoc("g", &a);
        let full = jaccard(&t).unwrap();
        // Subset incl. an absent node: seeded == full restricted to
        // pairs with both endpoints inside the subset.
        let subset: Vec<String> =
            ["u01", "u03", "u04", "u07", "absent"].iter().map(|s| s.to_string()).collect();
        let seeded = jaccard_seeded(&t, &subset).unwrap();
        let in_subset = |k: &crate::assoc::Key| {
            subset.iter().any(|s| k.cmp_str(s.as_str()) == std::cmp::Ordering::Equal)
        };
        for (u, v, val) in full.iter() {
            let expect_val = seeded.get_num(u, v);
            if in_subset(u) && in_subset(v) {
                assert_eq!(expect_val, val.as_num(), "pair ({u:?}, {v:?})");
            } else {
                assert_eq!(expect_val, None, "pair ({u:?}, {v:?}) outside subset");
            }
        }
        // Every seeded pair appears in the full result.
        for (u, v, val) in seeded.iter() {
            assert_eq!(full.get_num(u, v), val.as_num());
        }
        // Seeding with every row reproduces the full kernel exactly.
        let all_rows: Vec<String> = (0..10).map(|i| format!("u{i:02}")).collect();
        assert_eq!(jaccard_seeded(&t, &all_rows).unwrap(), full);
        // Empty subset → empty result.
        assert!(jaccard_seeded(&t, &[]).unwrap().is_empty());
    }

    #[test]
    fn table_mult_on_split_tables() {
        // Force splits, then verify TableMult still agrees with sqin().
        let store = TableStore::new(TableConfig { split_threshold: 128, write_latency_us: 0 });
        let n = 40;
        let rows: Vec<String> = (0..n).map(|i| format!("r{:02}", i % 10)).collect();
        let cols: Vec<String> = (0..n).map(|i| format!("c{:02}", i % 7)).collect();
        let a = Assoc::from_triples(&rows, &cols, 1.0);
        let (t, _) = store.ingest_assoc("m", &a);
        assert!(t.tablet_count() > 1);
        let out = store.create_table("out");
        table_mult(&t, &t, &out, &PlusTimes);
        assert_eq!(store.read_assoc("out").unwrap(), a.sqin());
    }

    #[test]
    fn masked_table_mult_equals_filtered_full() {
        // Masked output cells must be byte-identical to unmasked-then-
        // filter, across semirings, thread counts, and split tables.
        let store = TableStore::new(TableConfig { split_threshold: 256, write_latency_us: 0 });
        let n = 60;
        let rows: Vec<String> = (0..n).map(|i| format!("r{:02}", i % 12)).collect();
        let cols: Vec<String> = (0..n).map(|i| format!("c{:02}", (i * 7) % 20)).collect();
        let a = Assoc::from_triples(&rows, &cols, 2.0);
        let (t, _) = store.ingest_assoc("m", &a);
        let keep = KeyMatch::Prefix("c0".into());
        for s in [&PlusTimes as &dyn Semiring, &MaxPlus, &MinPlus] {
            let full = store.create_table(&format!("full_{}", s.name()));
            table_mult(&t, &t, &full, s);
            let expect: Vec<Triple> = full
                .scan(ScanRange::all())
                .into_iter()
                .filter(|tr| keep.matches(&tr.col))
                .collect();
            for threads in [1usize, 2, 4] {
                let out = store.create_table(&format!("masked_{}_{threads}", s.name()));
                let cells = table_mult_masked_par(
                    &t,
                    &t,
                    &out,
                    s,
                    &keep,
                    Parallelism::with_threads(threads),
                );
                let got = out.scan(ScanRange::all());
                assert_eq!(got, expect, "{} t={threads}", s.name());
                assert_eq!(cells, expect.len(), "{} t={threads}", s.name());
            }
        }
    }

    #[test]
    fn row_masked_table_mult_equals_filtered_full() {
        // The row twin: masked output rows must be byte-identical to
        // unmasked-then-filter-rows, across semirings, thread counts,
        // and split tables.
        let store = TableStore::new(TableConfig { split_threshold: 256, write_latency_us: 0 });
        let n = 60;
        let rows: Vec<String> = (0..n).map(|i| format!("r{:02}", i % 12)).collect();
        let cols: Vec<String> = (0..n).map(|i| format!("c{:02}", (i * 7) % 20)).collect();
        let a = Assoc::from_triples(&rows, &cols, 2.0);
        let (t, _) = store.ingest_assoc("m", &a);
        // Output rows of AᵀA are A's column keys: keep the "c0*" band.
        let keep = KeyMatch::Prefix("c0".into());
        for s in [&PlusTimes as &dyn Semiring, &MaxPlus, &MinPlus] {
            let full = store.create_table(&format!("rfull_{}", s.name()));
            table_mult(&t, &t, &full, s);
            let expect: Vec<Triple> = full
                .scan(ScanRange::all())
                .into_iter()
                .filter(|tr| keep.matches(&tr.row))
                .collect();
            for threads in [1usize, 2, 4] {
                let out = store.create_table(&format!("rmasked_{}_{threads}", s.name()));
                let cells = table_mult_row_masked_par(
                    &t,
                    &t,
                    &out,
                    s,
                    &keep,
                    Parallelism::with_threads(threads),
                );
                let got = out.scan(ScanRange::all());
                assert_eq!(got, expect, "{} t={threads}", s.name());
                assert_eq!(cells, expect.len(), "{} t={threads}", s.name());
            }
        }
    }

    #[test]
    fn row_masked_table_mult_degenerate_masks() {
        let (store, t, _) = graph_store();
        let none = store.create_table("rnone");
        let keep_none = KeyMatch::Equals("nope".into());
        assert_eq!(table_mult_row_masked(&t, &t, &none, &PlusTimes, &keep_none), 0);
        assert!(store.read_assoc("rnone").unwrap().is_empty());
        let all = store.create_table("rall");
        let keep_all = KeyMatch::Glob("*".into());
        table_mult_row_masked(&t, &t, &all, &PlusTimes, &keep_all);
        let a = store.read_assoc("edges").unwrap();
        assert_eq!(store.read_assoc("rall").unwrap(), a.sqin());
    }

    #[test]
    fn masked_table_mult_degenerate_masks() {
        let (store, t, _) = graph_store();
        let none = store.create_table("none");
        let keep_none = KeyMatch::Equals("nope".into());
        assert_eq!(table_mult_masked(&t, &t, &none, &PlusTimes, &keep_none), 0);
        assert!(store.read_assoc("none").unwrap().is_empty());
        let all = store.create_table("all");
        let keep_all = KeyMatch::Glob("*".into());
        table_mult_masked(&t, &t, &all, &PlusTimes, &keep_all);
        let a = store.read_assoc("edges").unwrap();
        assert_eq!(store.read_assoc("all").unwrap(), a.sqin());
    }

    #[test]
    fn snapshot_parallel_kernels_match_streamed() {
        // PR 8: the `_par` kernel variants route their scans through
        // pinned-snapshot range-chunk fan-out; every output must be
        // bit-identical to the streamed kernel at every thread count.
        let (store, t, _) = graph_store();
        t.minor_compact().unwrap();
        let seeds = vec!["a".to_string()];
        let expect_bfs = bfs(&t, &seeds, 3);
        let expect_probe = bfs(&t, &seeds, 0);
        let nodes: Vec<String> =
            ["a", "b", "c"].iter().map(|s| s.to_string()).collect();
        let expect_jac = jaccard_seeded(&t, &nodes).unwrap();
        let expect_deg = {
            let d = store.create_table("deg_serial");
            degree_table(&t, &d);
            d.scan(ScanRange::all())
        };
        for threads in [1usize, 2, 4, 7] {
            let par = Parallelism::with_threads(threads);
            assert_eq!(bfs_par(&t, &seeds, 3, par), expect_bfs, "t={threads}");
            assert_eq!(bfs_par(&t, &seeds, 0, par), expect_probe, "t={threads}");
            assert_eq!(
                jaccard_seeded_par(&t, &nodes, par).unwrap(),
                expect_jac,
                "t={threads}"
            );
            let d = store.create_table(&format!("deg_par_{threads}"));
            let n = degree_table_par(&t, &d, par);
            assert_eq!(d.scan(ScanRange::all()), expect_deg, "t={threads}");
            assert_eq!(n, expect_deg.len(), "t={threads}");
        }
    }
}

mod algorithms;
pub use algorithms::{pagerank, triangle_count};
