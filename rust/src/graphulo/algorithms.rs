//! Whole-graph algorithms composed from the associative-array algebra —
//! the PageRank and triangle-centrality style kernels the Graphulo /
//! GraphBLAS papers use as their standard demos (paper refs [19], [24]).

use crate::assoc::{Assoc, Key, ValsInput};
use std::collections::BTreeMap;

/// PageRank over an adjacency array `A[u, v] = weight` (weights are
/// logicalized; dangling nodes distribute uniformly). Returns the rank
/// vector as an `n × 1` associative array (column key `1`), iterated to
/// `iters` rounds of `r ← d·Pᵀr + (1−d)/n`.
pub fn pagerank(adj: &Assoc, damping: f64, iters: usize) -> Assoc {
    // Node set = union of sources and sinks.
    let a = adj.logical();
    let mut nodes: Vec<Key> = a.row_keys().to_vec();
    nodes.extend(a.col_keys().iter().cloned());
    nodes.sort();
    nodes.dedup();
    let n = nodes.len();
    if n == 0 {
        return Assoc::empty();
    }
    let index: BTreeMap<&Key, usize> = nodes.iter().zip(0..).collect();

    // Column-normalized transition structure: out-degree per source.
    let degrees = a.count(1); // per-row out-degree
    let mut outdeg = vec![0f64; n];
    for (r, _, v) in degrees.iter() {
        outdeg[index[r]] = v.as_num().unwrap_or(0.0);
    }
    // Edge list in index space.
    let edges: Vec<(usize, usize)> =
        a.iter().map(|(r, c, _)| (index[r], index[c])).collect();

    let mut rank = vec![1.0 / n as f64; n];
    for _ in 0..iters {
        let mut next = vec![(1.0 - damping) / n as f64; n];
        // Dangling mass distributes uniformly.
        let dangling: f64 = rank
            .iter()
            .zip(&outdeg)
            .filter(|(_, &d)| d == 0.0)
            .map(|(r, _)| r)
            .sum();
        let dangling_share = damping * dangling / n as f64;
        for v in next.iter_mut() {
            *v += dangling_share;
        }
        for &(u, v) in &edges {
            next[v] += damping * rank[u] / outdeg[u];
        }
        rank = next;
    }
    Assoc::try_new(
        nodes,
        vec![Key::num(1.0)],
        ValsInput::Num(rank),
        crate::assoc::Aggregator::First,
    )
    .expect("pagerank vector")
}

/// Count triangles in an undirected graph given as a (possibly
/// directed) adjacency array: symmetrize, then `trace(A³)/6` computed
/// sparsely as `Σ (A² ∘ A) / 6` — the masked-SpGEMM formulation
/// GraphBLAS uses.
pub fn triangle_count(adj: &Assoc) -> u64 {
    let a = adj.logical();
    // Symmetrize without self-loops.
    let sym = &a + &a.transpose();
    let sym = sym.logical();
    let no_diag = remove_diagonal(&sym);
    let squared = no_diag.matmul(&no_diag);
    let masked = squared.elemmul(&no_diag);
    (masked.total() / 6.0).round() as u64
}

fn remove_diagonal(a: &Assoc) -> Assoc {
    let (rows, cols, vals) = a.triples();
    let vals = match vals {
        ValsInput::Num(v) => v,
        _ => unreachable!("logical arrays are numeric"),
    };
    let mut fr = Vec::new();
    let mut fc = Vec::new();
    let mut fv = Vec::new();
    for ((r, c), v) in rows.into_iter().zip(cols).zip(vals) {
        if r != c {
            fr.push(r);
            fc.push(c);
            fv.push(v);
        }
    }
    Assoc::try_new(fr, fc, ValsInput::Num(fv), crate::assoc::Aggregator::First)
        .expect("diagonal-free triples")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pagerank_ring_is_uniform() {
        // Ring a→b→c→a: perfectly symmetric, ranks equal.
        let a = Assoc::from_triples(&["a", "b", "c"], &["b", "c", "a"], 1.0);
        let r = pagerank(&a, 0.85, 50);
        let ra = r.get_num("a", 1i64).unwrap();
        let rb = r.get_num("b", 1i64).unwrap();
        let rc = r.get_num("c", 1i64).unwrap();
        assert!((ra - rb).abs() < 1e-12 && (rb - rc).abs() < 1e-12);
        assert!((ra + rb + rc - 1.0).abs() < 1e-9, "ranks sum to 1");
    }

    #[test]
    fn pagerank_hub_ranks_highest() {
        // Star: everything points at "hub".
        let a = Assoc::from_triples(&["x", "y", "z"], &["hub", "hub", "hub"], 1.0);
        let r = pagerank(&a, 0.85, 50);
        let hub = r.get_num("hub", 1i64).unwrap();
        for leaf in ["x", "y", "z"] {
            assert!(hub > r.get_num(leaf, 1i64).unwrap() * 2.0);
        }
    }

    #[test]
    fn pagerank_handles_dangling() {
        // b is dangling (no out-edges): mass must not vanish.
        let a = Assoc::from_triples(&["a"], &["b"], 1.0);
        let r = pagerank(&a, 0.85, 100);
        let total = r.get_num("a", 1i64).unwrap() + r.get_num("b", 1i64).unwrap();
        assert!((total - 1.0).abs() < 1e-9, "total rank {total}");
    }

    #[test]
    fn triangles_in_known_graphs() {
        // Single triangle.
        let tri = Assoc::from_triples(&["a", "b", "c"], &["b", "c", "a"], 1.0);
        assert_eq!(triangle_count(&tri), 1);
        // K4 has 4 triangles (directed input, gets symmetrized).
        let mut rows = Vec::new();
        let mut cols = Vec::new();
        let nodes = ["a", "b", "c", "d"];
        for i in 0..4 {
            for j in (i + 1)..4 {
                rows.push(nodes[i]);
                cols.push(nodes[j]);
            }
        }
        let k4 = Assoc::from_triples(&rows, &cols, 1.0);
        assert_eq!(triangle_count(&k4), 4);
        // Path graph: none.
        let path = Assoc::from_triples(&["a", "b"], &["b", "c"], 1.0);
        assert_eq!(triangle_count(&path), 0);
    }

    #[test]
    fn triangle_count_ignores_self_loops() {
        let g = Assoc::from_triples(&["a", "a", "b", "c"], &["a", "b", "c", "a"], 1.0);
        assert_eq!(triangle_count(&g), 1);
    }
}
