//! # d4m — Dynamic Distributed Dimensional Data Model, in Rust
//!
//! A from-scratch reproduction of the D4M associative-array data model
//! described in *"Python Implementation of the Dynamic Distributed
//! Dimensional Data Model"* (Jananthan et al., IEEE HPEC 2022), built as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **[`assoc`]** — the associative-array algebra (`A : I × J → V` over a
//!   semiring), the paper's central data model, with the four-attribute
//!   storage layout (`row`, `col`, `val`, `adj`).
//! * **[`sorted`]** — sorted union / sorted intersection with index maps,
//!   the algorithmic core of `+`, `*` and `@` (paper §II.C), plus the
//!   dictionary key encoder (`KeyDict`: intern to dense `u32` ids, sort
//!   distinct keys once — the constructor's default encoding).
//! * **[`semiring`]** — plus-times, max-plus, min-plus, max-min and the
//!   string (concat, min) algebra (paper §I.A).
//! * **[`sparse`]** — a from-scratch sparse linear-algebra substrate
//!   (COO/CSR/CSC, add, elementwise multiply, SpGEMM) standing in for
//!   SciPy.sparse.
//! * **[`store`]** — an Accumulo-like sorted, distributed key/value triple
//!   store (tablets, splits, batch writer) whose scans run on a
//!   server-side iterator stack ([`store::scan`]): seekable streaming
//!   cursors with range, filter, and combiner pushdown. Cells are
//!   shared-bytes handles ([`store::SharedStr`]): a scanned triple is
//!   three pointer clones, filters evaluate beneath the block copy, and
//!   the scan→assoc and Graphulo paths consume dictionary-encoded ids.
//! * **[`graphulo`]** — Graphulo-style server-side kernels (TableMult —
//!   including the sink-masked variant on masked SpGEMM — degree
//!   tables, BFS) over the store's scan stack.
//! * **[`pipeline`]** — the streaming ingest orchestrator: sharding,
//!   rebalancing and bounded-queue backpressure.
//! * **[`plan`]** — the cost-based Graphulo query planner: a logical
//!   plan IR with explicit lowering passes (build → annotate → choose
//!   → execute), per-table statistics ([`store::Table::stats`]), and
//!   fused scan→kernel pipelines; every physical choice is forcible
//!   and produces bit-identical output.
//! * **[`runtime`]** — PJRT (XLA) runtime that loads AOT-compiled Pallas
//!   semiring-matmul kernels and serves the dense-block acceleration path
//!   (gated behind the `accel` feature; the default offline build uses an
//!   API-compatible stub that reports the runtime unavailable).
//! * **[`baselines`]** — alternative engines (hashmap dict-of-dict, btree
//!   triple store) used as the comparison curves for the paper's figures.
//! * **[`bench`]** — the paper's workload generators (§III.A) and the
//!   harness that regenerates Figures 3–7.
//!
//! ## Parallelism
//!
//! The compute hot paths — row-partitioned Gustavson SpGEMM (`@`), the
//! row-wise sparse add/multiply behind `+` and `*`, the constructor's
//! key/value-pool sorts (shard sort + union merge), and per-tablet
//! store scans — fan out over a shared fixed-size thread pool. The one
//! knob is [`util::Parallelism`] (`threads: usize`): every operation
//! has a `*_par` form taking it explicitly, the plain forms use the
//! process default (`Parallelism::current()`, all cores unless
//! overridden via `Parallelism::set_default`), and `threads == 1`
//! selects the exact serial code path. **Determinism guarantee:** the
//! parallel result is byte-identical to the serial result for every
//! thread count and every builtin semiring — work is chunked by a pure
//! function of the input, chunks never share accumulators, and outputs
//! are stitched in chunk order (`rust/tests/parallel_equivalence.rs`
//! enforces this; `cargo bench --bench ablations -- --threads N`
//! sweeps the knob).
//!
//! ## Quickstart
//!
//! ```
//! use d4m::assoc::Assoc;
//! let a = Assoc::from_triples(
//!     &["0294.mp3", "1829.mp3", "7802.mp3"],
//!     &["artist", "artist", "artist"],
//!     &["Pink Floyd", "Samuel Barber", "Taylor Swift"][..],
//! );
//! assert_eq!(a.get_str("0294.mp3", "artist"), Some("Pink Floyd"));
//! ```

pub mod assoc;
pub mod baselines;
pub mod bench;
pub mod graphulo;
pub mod pipeline;
pub mod plan;
// The real PJRT runtime needs the external `xla` + `anyhow` crates,
// unavailable in the offline build image; the default build compiles an
// API-compatible stub whose loader reports "runtime unavailable".
#[cfg(feature = "accel")]
pub mod runtime;
#[cfg(not(feature = "accel"))]
#[path = "runtime/stub.rs"]
pub mod runtime;
pub mod semiring;
pub mod sorted;
pub mod sparse;
pub mod store;
pub mod util;
