//! Major compaction: merging a tablet's layers under a combiner and a
//! version-retention rule.
//!
//! Accumulo applies its iterator stack *at compaction time* as well as
//! at scan time: the versioning iterator keeps the newest `N` versions
//! of each key, deletion markers swallow what they mask, and configured
//! combiners fold a key's versions into one cell as files merge
//! (arXiv:1508.07371 §II). [`CompactionSpec`] is that configuration
//! here, and [`merge_cells`] is the merge itself, shared by
//! [`super::Tablet::compact`].
//!
//! The combiner path re-uses the *scan-time* [`ReduceIter`] verbatim
//! (fed by a slice-backed [`ScanIter`]), so a combiner applied at merge
//! is bit-identical to the same combiner applied at scan — the
//! equivalence `tests/scan_stack.rs` pins for every [`RowReduce`].

use super::run::RunCell;
use super::scan::{ReduceIter, RowReduce, ScanIter};
use super::Triple;

/// What a major compaction applies while merging layers.
#[derive(Debug, Clone)]
pub struct CompactionSpec {
    /// Optional row combiner folded in at merge time. The merged run
    /// then stores the *reduced* rows (one `(row, out_col)` cell per
    /// row), exactly what scanning the uncompacted tablet through
    /// [`crate::store::ScanSpec::reduced`] would emit.
    pub reduce: Option<RowReduce>,
    /// Newest versions of each `(row, col)` retained in the merged run
    /// (Accumulo's versioning iterator; minimum 1). Ignored when
    /// `reduce` folds rows down to single cells anyway.
    pub max_versions: usize,
}

impl Default for CompactionSpec {
    /// Accumulo's default table configuration: no combiner, keep only
    /// the newest version.
    fn default() -> Self {
        CompactionSpec { reduce: None, max_versions: 1 }
    }
}

/// [`ScanIter`] over an in-memory sorted triple list — the adapter that
/// lets compaction drive the scan stack's [`ReduceIter`] over already
/// merged cells.
struct SliceIter {
    data: Vec<Triple>,
    pos: usize,
}

impl ScanIter for SliceIter {
    fn seek(&mut self, row: &str, col: &str) {
        self.pos = self
            .data
            .partition_point(|t| (t.row.as_str(), t.col.as_str()) < (row, col));
    }

    fn next_triple(&mut self) -> Option<Triple> {
        let t = self.data.get(self.pos)?.clone();
        self.pos += 1;
        Some(t)
    }
}

/// Merge collected cell versions under `spec`.
///
/// `cells` must be sorted by `(row, col)` with each key's versions
/// adjacent and **newest first** (the priority order
/// [`super::Tablet::compact`] builds), tombstones included. The merge:
///
/// 1. truncates each key's version list at its first tombstone (the
///    marker masks everything older, then — this being a full-extent
///    compaction — is itself dropped);
/// 2. keeps at most `max_versions` surviving versions per key;
/// 3. if a combiner is configured, folds the newest visible version of
///    each key through the real scan-stack [`ReduceIter`] instead, so
///    the output is the reduced row set.
pub(crate) fn merge_cells(cells: Vec<RunCell>, spec: &CompactionSpec) -> Vec<RunCell> {
    debug_assert!(cells
        .windows(2)
        .all(|w| (w[0].0.as_str(), w[0].1.as_str()) <= (w[1].0.as_str(), w[1].1.as_str())));
    if let Some(reduce) = &spec.reduce {
        // Newest visible version per key — what a scan of the
        // uncompacted tablet would stream into its ReduceIter.
        let mut newest: Vec<Triple> = Vec::new();
        each_group(&cells, |group| {
            if let (r, c, Some(v)) = &group[0] {
                newest.push(Triple { row: r.clone(), col: c.clone(), val: v.clone() });
            }
        });
        let mut folded = ReduceIter::new(SliceIter { data: newest, pos: 0 }, Some(reduce.clone()));
        let mut out: Vec<RunCell> = Vec::new();
        while let Some(t) = folded.next_triple() {
            out.push((t.row, t.col, Some(t.val)));
        }
        return out;
    }
    let keep = spec.max_versions.max(1);
    let mut out: Vec<RunCell> = Vec::new();
    each_group(&cells, |group| {
        for cell in group.iter().take_while(|c| c.2.is_some()).take(keep) {
            out.push(cell.clone());
        }
    });
    out
}

/// Call `f` once per maximal same-key group of `cells` (sorted input).
fn each_group(cells: &[RunCell], mut f: impl FnMut(&[RunCell])) {
    let mut i = 0usize;
    while i < cells.len() {
        let key = (cells[i].0.as_str(), cells[i].1.as_str());
        let mut j = i + 1;
        while j < cells.len() && (cells[j].0.as_str(), cells[j].1.as_str()) == key {
            j += 1;
        }
        f(&cells[i..j]);
        i = j;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SharedStr;

    fn cell(r: &str, c: &str, v: Option<&str>) -> RunCell {
        (r.into(), c.into(), v.map(SharedStr::from))
    }

    #[test]
    fn tombstone_masks_older_versions_then_drops() {
        let cells = vec![
            cell("a", "x", Some("3")), // newest
            cell("a", "x", None),      // delete below it
            cell("a", "x", Some("1")), // masked
            cell("b", "y", None),      // deleted outright
            cell("b", "y", Some("9")),
        ];
        let out = merge_cells(cells, &CompactionSpec { reduce: None, max_versions: 10 });
        assert_eq!(out, vec![cell("a", "x", Some("3"))]);
    }

    #[test]
    fn max_versions_trims_each_group() {
        let cells = vec![
            cell("a", "x", Some("3")),
            cell("a", "x", Some("2")),
            cell("a", "x", Some("1")),
            cell("b", "y", Some("7")),
        ];
        let out = merge_cells(cells, &CompactionSpec { reduce: None, max_versions: 2 });
        assert_eq!(
            out,
            vec![cell("a", "x", Some("3")), cell("a", "x", Some("2")), cell("b", "y", Some("7"))]
        );
        // max_versions is clamped to ≥ 1.
        let cells = vec![cell("a", "x", Some("3")), cell("a", "x", Some("2"))];
        let out = merge_cells(cells, &CompactionSpec { reduce: None, max_versions: 0 });
        assert_eq!(out, vec![cell("a", "x", Some("3"))]);
    }

    #[test]
    fn reduce_folds_newest_visible_versions() {
        let cells = vec![
            cell("a", "x", Some("3")),
            cell("a", "x", Some("1")), // shadowed: must not count
            cell("a", "y", Some("4")),
            cell("a", "z", None), // deleted: must not count
            cell("b", "x", Some("5")),
        ];
        let spec = CompactionSpec {
            reduce: Some(RowReduce::Sum { out_col: "sum".into() }),
            max_versions: 1,
        };
        let out = merge_cells(cells, &spec);
        assert_eq!(out, vec![cell("a", "sum", Some("7")), cell("b", "sum", Some("5"))]);
    }
}
