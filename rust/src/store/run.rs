//! Immutable sorted runs: frozen tablets as dictionary-encoded blocks.
//!
//! Accumulo's minor compaction writes a tablet's in-memory map to an
//! immutable sorted file (an RFile); scans then merge the memory map
//! with the files (arXiv:1508.07371 §II). A [`Run`] is that file's
//! in-process form, and it closes the PR 4 follow-up of spilling the
//! [`StrDict`] into the store layer (the D4M 3.0 server-side dictionary,
//! arXiv:1702.03253): a run stores `u32` id triples over one sorted
//! per-run string pool, so id order *is* string order and the merge
//! walk compares pooled `&str`s without per-cell allocation.
//!
//! A run may hold several versions of a key (newest first) when major
//! compaction retains `max_versions > 1`, and it may hold tombstones
//! ([`TOMBSTONE`] value id) masking older runs — exactly Accumulo's
//! deletion markers.
//!
//! ## File format (`run-<seq>.run`)
//!
//! ```text
//! [8-byte magic "D4MRUN01"]
//! [u64 seq][u64 watermark]
//! [u32 pool_len] pool_len × ([u32 len][bytes])
//! [u32 ntriples] ntriples × ([u32 row][u32 col][u32 val])
//! [u32 crc32(everything after the magic)]
//! ```
//!
//! All integers little-endian; the CRC guards the whole body so a torn
//! or bit-flipped run file fails loudly at [`Run::load`] instead of
//! serving wrong cells.

use super::io::{RealIo, StorageIo};
use super::wal::crc32;
use crate::util::intern::StrDict;
use crate::util::SharedStr;
use std::io;
use std::path::Path;

/// Magic bytes opening every run file (format version 01).
pub const RUN_MAGIC: &[u8; 8] = b"D4MRUN01";

/// Value id marking a deletion tombstone (never a real pool id).
pub const TOMBSTONE: u32 = u32::MAX;

/// Sanity cap on pool and triple counts read from disk.
const MAX_COUNT: u32 = 1 << 28;

/// One cell as frozen: key plus value, `None` value = tombstone.
pub type RunCell = (SharedStr, SharedStr, Option<SharedStr>);

/// An immutable, dictionary-encoded sorted block of cells.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Run {
    seq: u64,
    watermark: u64,
    /// Sorted distinct strings; `u32` id order equals string order.
    pool: Vec<SharedStr>,
    /// `(row, col, val)` pool ids, sorted by `(row, col)`; duplicate
    /// keys are adjacent, newest version first. `val == TOMBSTONE`
    /// marks a deletion.
    triples: Vec<(u32, u32, u32)>,
}

impl Run {
    /// Freeze `cells` into a run. `cells` must be sorted by `(row,
    /// col)` with duplicate keys newest-first — the order every caller
    /// (tablet freeze, major compaction) produces naturally.
    ///
    /// `seq` names the run file; `watermark` is the highest WAL
    /// sequence number whose effects the run captures (recovery skips
    /// WAL records at or below the minimum live watermark).
    pub fn from_cells(seq: u64, watermark: u64, cells: &[RunCell]) -> Run {
        debug_assert!(cells
            .windows(2)
            .all(|w| (w[0].0.as_str(), w[0].1.as_str()) <= (w[1].0.as_str(), w[1].1.as_str())));
        let mut dict = StrDict::new();
        let raw: Vec<(u32, u32, u32)> = cells
            .iter()
            .map(|(r, c, v)| {
                (
                    dict.intern(r),
                    dict.intern(c),
                    v.as_ref().map_or(TOMBSTONE, |v| dict.intern(v)),
                )
            })
            .collect();
        // `into_sorted` yields the pool in string order plus the
        // monotone old-id → rank map; remapping ids through it keeps
        // the (row, col) sort *and* the stable newest-first order of
        // duplicate keys (no re-sort happens).
        let (pool, rank) = dict.into_sorted();
        let triples = raw
            .into_iter()
            .map(|(r, c, v)| {
                let v = if v == TOMBSTONE { TOMBSTONE } else { rank[v as usize] };
                (rank[r as usize], rank[c as usize], v)
            })
            .collect();
        Run { seq, watermark, pool, triples }
    }

    /// The run's file sequence number (unique per table, increasing).
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Highest WAL sequence number this run's contents cover.
    pub fn watermark(&self) -> u64 {
        self.watermark
    }

    /// Number of stored cells (tombstones included).
    pub fn len(&self) -> usize {
        self.triples.len()
    }

    /// Whether the run stores no cells at all.
    pub fn is_empty(&self) -> bool {
        self.triples.is_empty()
    }

    /// Key of cell `i` as pooled strings.
    #[inline]
    pub fn key(&self, i: usize) -> (&SharedStr, &SharedStr) {
        let (r, c, _) = self.triples[i];
        (&self.pool[r as usize], &self.pool[c as usize])
    }

    /// Value of cell `i`; `None` for a tombstone.
    #[inline]
    pub fn val(&self, i: usize) -> Option<&SharedStr> {
        let (_, _, v) = self.triples[i];
        if v == TOMBSTONE {
            None
        } else {
            Some(&self.pool[v as usize])
        }
    }

    #[inline]
    fn key_str(&self, i: usize) -> (&str, &str) {
        let (r, c) = self.key(i);
        (r.as_str(), c.as_str())
    }

    /// Index of the first cell at or after `(row, col)` (`inclusive`)
    /// or strictly after the *whole version group* of `(row, col)`
    /// (`!inclusive`). Pool ids sort like strings, so this is a plain
    /// binary search over pooled `&str`s.
    pub fn lower_bound(&self, row: &str, col: &str, inclusive: bool) -> usize {
        if inclusive {
            self.partition(|k| k < (row, col))
        } else {
            self.partition(|k| k <= (row, col))
        }
    }

    #[inline]
    fn partition(&self, pred: impl Fn((&str, &str)) -> bool) -> usize {
        let mut lo = 0usize;
        let mut hi = self.triples.len();
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if pred(self.key_str(mid)) {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo
    }

    /// Half-open index range of cells whose row lies in `[lo, hi)`
    /// (either bound `None` = unbounded) — the clamp that keeps a
    /// cloned run from leaking cells outside a split tablet's extent.
    pub fn extent_range(&self, lo: Option<&str>, hi: Option<&str>) -> (usize, usize) {
        let start = match lo {
            Some(lo) => self.partition(|(r, _)| r < lo),
            None => 0,
        };
        let end = match hi {
            Some(hi) => self.partition(|(r, _)| r < hi),
            None => self.triples.len(),
        };
        (start, end.max(start))
    }

    /// Newest version of `(row, col)` in this run: `None` if the run
    /// has no cell for the key, `Some(None)` if the newest version is
    /// a tombstone, `Some(Some(val))` otherwise.
    pub fn get(&self, row: &str, col: &str) -> Option<Option<&SharedStr>> {
        let i = self.lower_bound(row, col, true);
        if i < self.triples.len() && self.key_str(i) == (row, col) {
            Some(self.val(i))
        } else {
            None
        }
    }

    /// Number of stored versions of `(row, col)` (tombstones counted).
    pub fn versions(&self, row: &str, col: &str) -> usize {
        self.lower_bound(row, col, false) - self.lower_bound(row, col, true)
    }

    /// Serialize to `path` (see the module docs for the format).
    pub fn save(&self, path: &Path) -> io::Result<()> {
        self.save_with(&RealIo, path)
    }

    /// [`Run::save`] through an explicit [`StorageIo`]. The whole file
    /// (magic + body + CRC) is built in memory and installed with
    /// [`StorageIo::write_atomic`] — a crash or failure mid-save leaves
    /// either the old file or nothing, never a torn run.
    pub fn save_with(&self, io: &dyn StorageIo, path: &Path) -> io::Result<()> {
        let mut bytes = Vec::with_capacity(48 + self.pool.len() * 12 + self.triples.len() * 12);
        bytes.extend_from_slice(RUN_MAGIC);
        bytes.extend_from_slice(&self.seq.to_le_bytes());
        bytes.extend_from_slice(&self.watermark.to_le_bytes());
        bytes.extend_from_slice(&(self.pool.len() as u32).to_le_bytes());
        for s in &self.pool {
            bytes.extend_from_slice(&(s.len() as u32).to_le_bytes());
            bytes.extend_from_slice(s.as_bytes());
        }
        bytes.extend_from_slice(&(self.triples.len() as u32).to_le_bytes());
        for &(r, c, v) in &self.triples {
            bytes.extend_from_slice(&r.to_le_bytes());
            bytes.extend_from_slice(&c.to_le_bytes());
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let crc = crc32(&bytes[RUN_MAGIC.len()..]);
        bytes.extend_from_slice(&crc.to_le_bytes());
        io.write_atomic(path, &bytes)
    }

    /// Load a run from `path`, validating magic, CRC, and id bounds.
    /// Unlike the WAL, a damaged run file is a hard
    /// [`io::ErrorKind::InvalidData`] error: runs are written atomically
    /// after an fsync, so torn runs are not an expected crash state —
    /// recovery quarantines such files instead of serving wrong cells.
    pub fn load(path: &Path) -> io::Result<Run> {
        Self::load_with(&RealIo, path)
    }

    /// [`Run::load`] through an explicit [`StorageIo`].
    pub fn load_with(io: &dyn StorageIo, path: &Path) -> io::Result<Run> {
        let bad = |msg: &str| {
            io::Error::new(io::ErrorKind::InvalidData, format!("{}: {msg}", path.display()))
        };
        let bytes = io.read(path)?;
        if bytes.len() < RUN_MAGIC.len() + 4 || &bytes[..RUN_MAGIC.len()] != RUN_MAGIC {
            return Err(bad("not a d4m run file (bad magic or too short)"));
        }
        let body = &bytes[RUN_MAGIC.len()..bytes.len() - 4];
        let stored_crc =
            u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().expect("4 bytes"));
        if crc32(body) != stored_crc {
            return Err(bad("run body failed its checksum"));
        }
        struct Reader<'a> {
            buf: &'a [u8],
            pos: usize,
        }
        impl<'a> Reader<'a> {
            fn take(&mut self, n: usize) -> Option<&'a [u8]> {
                let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len())?;
                let s = &self.buf[self.pos..end];
                self.pos = end;
                Some(s)
            }
            fn u32(&mut self) -> Option<u32> {
                self.take(4).map(|b| u32::from_le_bytes(b.try_into().expect("4 bytes")))
            }
            fn u64(&mut self) -> Option<u64> {
                self.take(8).map(|b| u64::from_le_bytes(b.try_into().expect("8 bytes")))
            }
        }
        let mut rd = Reader { buf: body, pos: 0 };
        let parse = |rd: &mut Reader<'_>| -> Option<Result<Run, &'static str>> {
            let seq = rd.u64()?;
            let watermark = rd.u64()?;
            let pool_len = rd.u32()?;
            if pool_len > MAX_COUNT {
                return Some(Err("run pool count out of range"));
            }
            let mut pool = Vec::with_capacity(pool_len as usize);
            for _ in 0..pool_len {
                let len = rd.u32()? as usize;
                match std::str::from_utf8(rd.take(len)?) {
                    Ok(s) => pool.push(SharedStr::from(s)),
                    Err(_) => return Some(Err("run pool entry is not UTF-8")),
                }
            }
            let ntriples = rd.u32()?;
            if ntriples > MAX_COUNT {
                return Some(Err("run triple count out of range"));
            }
            let mut triples = Vec::with_capacity(ntriples as usize);
            for _ in 0..ntriples {
                let (r, c, v) = (rd.u32()?, rd.u32()?, rd.u32()?);
                let in_pool = |id: u32| (id as usize) < pool.len();
                if !in_pool(r) || !in_pool(c) || (v != TOMBSTONE && !in_pool(v)) {
                    return Some(Err("run triple id out of pool range"));
                }
                triples.push((r, c, v));
            }
            Some(Ok(Run { seq, watermark, pool, triples }))
        };
        let run = match parse(&mut rd) {
            None => return Err(bad("run body truncated")),
            Some(Err(msg)) => return Err(bad(msg)),
            Some(Ok(run)) => run,
        };
        if rd.pos != body.len() {
            return Err(bad("trailing bytes after run body"));
        }
        Ok(run)
    }
}

/// Forward cursor over a run's cells within an extent-clamped index
/// window. Borrowed views live as long as the run (`'r`), independent
/// of the cursor borrow — the merge walk peeks several cursors at once.
#[derive(Debug)]
pub struct RunCursor<'r> {
    run: &'r Run,
    pos: usize,
    end: usize,
}

impl<'r> RunCursor<'r> {
    /// Cursor over `run` positioned at `pos`, bounded by `end`.
    pub fn new(run: &'r Run, pos: usize, end: usize) -> RunCursor<'r> {
        RunCursor { run, pos: pos.min(end), end }
    }

    /// Current cell, or `None` past the window. The value is `None`
    /// for a tombstone.
    #[inline]
    pub fn peek(&self) -> Option<(&'r SharedStr, &'r SharedStr, Option<&'r SharedStr>)> {
        if self.pos >= self.end {
            return None;
        }
        let (r, c) = self.run.key(self.pos);
        Some((r, c, self.run.val(self.pos)))
    }

    /// Step past the *entire version group* of the current key, so the
    /// cursor only ever exposes each key's newest version.
    pub fn advance_key(&mut self) {
        if self.pos >= self.end {
            return;
        }
        // `key_str` borrows from `self.run: &'r Run`, not from the
        // cursor, so the key stays valid while `pos` moves. Version
        // groups are tiny (≤ max_versions); linear step.
        let key = self.run.key_str(self.pos);
        while self.pos < self.end && self.run.key_str(self.pos) == key {
            self.pos += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(r: &str, c: &str, v: Option<&str>) -> RunCell {
        (r.into(), c.into(), v.map(SharedStr::from))
    }

    fn sample() -> Run {
        Run::from_cells(
            7,
            42,
            &[
                cell("a", "x", Some("1")),
                cell("a", "y", None), // tombstone
                cell("b", "x", Some("3")),
                cell("b", "x", Some("2")), // older version, newest first
                cell("d", "z", Some("4")),
            ],
        )
    }

    #[test]
    fn from_cells_preserves_order_and_versions() {
        let run = sample();
        assert_eq!((run.seq(), run.watermark(), run.len()), (7, 42, 5));
        let keys: Vec<(String, String)> = (0..run.len())
            .map(|i| {
                let (r, c) = run.key(i);
                (r.to_string(), c.to_string())
            })
            .collect();
        assert_eq!(
            keys,
            vec![
                ("a".into(), "x".into()),
                ("a".into(), "y".into()),
                ("b".into(), "x".into()),
                ("b".into(), "x".into()),
                ("d".into(), "z".into()),
            ]
        );
        // Newest-first duplicate order survived the dictionary remap.
        assert_eq!(run.val(2).map(|v| v.as_str()), Some("3"));
        assert_eq!(run.val(3).map(|v| v.as_str()), Some("2"));
        assert_eq!(run.val(1), None);
    }

    #[test]
    fn lookup_and_bounds() {
        let run = sample();
        assert_eq!(run.get("a", "x").unwrap().unwrap().as_str(), "1");
        assert_eq!(run.get("a", "y"), Some(None)); // tombstone visible
        assert_eq!(run.get("b", "x").unwrap().unwrap().as_str(), "3"); // newest
        assert_eq!(run.get("c", "q"), None);
        assert_eq!(run.versions("b", "x"), 2);
        assert_eq!(run.versions("a", "x"), 1);
        assert_eq!(run.lower_bound("b", "x", true), 2);
        assert_eq!(run.lower_bound("b", "x", false), 4); // past the group
        assert_eq!(run.extent_range(Some("b"), Some("d")), (2, 4));
        assert_eq!(run.extent_range(None, None), (0, 5));
        assert_eq!(run.extent_range(Some("e"), None), (5, 5));
    }

    #[test]
    fn cursor_exposes_newest_per_key() {
        let run = sample();
        let (start, end) = run.extent_range(None, None);
        let mut cur = RunCursor::new(&run, start, end);
        let mut seen = Vec::new();
        while let Some((r, c, v)) = cur.peek() {
            seen.push((r.to_string(), c.to_string(), v.map(|v| v.to_string())));
            cur.advance_key();
        }
        assert_eq!(
            seen,
            vec![
                ("a".into(), "x".into(), Some("1".into())),
                ("a".into(), "y".into(), None),
                ("b".into(), "x".into(), Some("3".into())), // newest of the pair
                ("d".into(), "z".into(), Some("4".into())),
            ]
        );
    }

    #[test]
    fn save_load_roundtrip_and_corruption() {
        let dir = std::env::temp_dir().join("d4m-run-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.run");
        let run = sample();
        run.save(&path).unwrap();
        assert_eq!(Run::load(&path).unwrap(), run);
        // Flip a byte in the body: load must fail the checksum.
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        assert_eq!(Run::load(&path).unwrap_err().kind(), io::ErrorKind::InvalidData);
        // Not a run file at all.
        std::fs::write(&path, b"garbage").unwrap();
        assert_eq!(Run::load(&path).unwrap_err().kind(), io::ErrorKind::InvalidData);
    }
}
