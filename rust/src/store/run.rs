//! Immutable sorted runs: frozen tablets as dictionary-encoded blocks.
//!
//! Accumulo's minor compaction writes a tablet's in-memory map to an
//! immutable sorted file (an RFile); scans then merge the memory map
//! with the files (arXiv:1508.07371 §II). A [`Run`] is that file's
//! in-process form, and it closes the PR 4 follow-up of spilling the
//! [`StrDict`] into the store layer (the D4M 3.0 server-side dictionary,
//! arXiv:1702.03253): a run stores `u32` id triples over one sorted
//! per-run string pool, so id order *is* string order and the merge
//! walk compares pooled `&str`s without per-cell allocation.
//!
//! A run may hold several versions of a key (newest first) when major
//! compaction retains `max_versions > 1`, and it may hold tombstones
//! ([`TOMBSTONE`] value id) masking older runs — exactly Accumulo's
//! deletion markers.
//!
//! ## File format v2 (`run-<seq>.run`, magic `D4MRUN02`)
//!
//! The v2 layout is Accumulo's RFile shape: data blocks first, then a
//! footer holding the string pool and a block index, located by a
//! fixed-size trailer at the end of the file — so a paged open
//! ([`Run::open_with`]) reads *only* the trailer and footer, and data
//! blocks fault lazily through the shared
//! [`BlockCache`](super::cache::BlockCache).
//!
//! ```text
//! [8-byte magic "D4MRUN02"]
//! blocks × (count × [u32 row][u32 col][u32 val])      // raw id triples
//! footer:
//!   [u64 seq][u64 watermark]
//!   [u32 pool_len] pool_len × ([u32 len][bytes])
//!   [u32 nblocks] nblocks × ([u32 first_row][u32 first_col]
//!                            [u32 count][u64 offset][u32 len][u32 crc])
//!   [u32 ntriples]                                    // redundant sum
//! trailer: [u64 footer_off][u32 footer_len][u32 crc32(footer)]
//! ```
//!
//! All integers little-endian. Each index entry carries the CRC of its
//! raw block bytes, so a bit flip is caught at block-load time; the
//! trailer CRC guards the footer. A fully-resident load
//! ([`Run::load`]) still validates every block up front, preserving the
//! PR 7 contract that a damaged run file fails loudly at attach time.
//!
//! The v1 format (magic `D4MRUN01`: one body + one trailing CRC) is
//! still read — old manifests recover unchanged — but always resident;
//! [`Run::save`] writes v2 only.

use super::cache::{Block, BlockCache};
use super::io::{RealIo, StorageIo};
use super::wal::crc32;
use crate::util::intern::StrDict;
use crate::util::retry::RetryPolicy;
use crate::util::SharedStr;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Magic bytes of the legacy single-body format (read-only support).
pub const RUN_MAGIC_V1: &[u8; 8] = b"D4MRUN01";

/// Magic bytes of the paged block format every new run file uses.
pub const RUN_MAGIC_V2: &[u8; 8] = b"D4MRUN02";

/// Value id marking a deletion tombstone (never a real pool id).
pub const TOMBSTONE: u32 = u32::MAX;

/// Sanity cap on pool, block, and triple counts read from disk.
const MAX_COUNT: u32 = 1 << 28;

/// Default number of triples per data block (12 bytes per triple, so
/// ~12 KiB blocks — the same order as Accumulo's default data block
/// target). Configurable per save for tests and tuning.
pub const DEFAULT_BLOCK_TRIPLES: usize = 1024;

/// Encoded size of one triple.
const TRIPLE_BYTES: usize = 12;

/// Size of the fixed trailer locating the footer.
const TRAILER_BYTES: usize = 16;

/// One cell as frozen: key plus value, `None` value = tombstone.
pub type RunCell = (SharedStr, SharedStr, Option<SharedStr>);

/// Index entry for one data block.
#[derive(Debug, Clone, PartialEq, Eq)]
struct BlockMeta {
    /// Pool ids of the block's first key (pool id order == string
    /// order, so the index is searchable without touching any block).
    first_row: u32,
    first_col: u32,
    /// Global index of the block's first triple (cumulative).
    start: usize,
    /// Number of triples in the block.
    count: usize,
    /// Absolute file offset of the raw block bytes.
    offset: u64,
    /// Raw length in bytes (`count * 12`).
    len: u32,
    /// CRC-32 of the raw block bytes.
    crc: u32,
}

/// Lazily-paged triple storage behind a [`Run`].
struct Paged {
    io: Arc<dyn StorageIo>,
    path: PathBuf,
    cache: Arc<BlockCache>,
    retry: RetryPolicy,
    /// Process-unique cache-key namespace for this open.
    uid: u64,
    index: Vec<BlockMeta>,
    total: usize,
    /// Set on an unrecoverable block fault (failed read after retries,
    /// CRC mismatch, id out of pool range). A poisoned run reads as
    /// empty to in-flight cursors and is skipped — then quarantined —
    /// exactly like a run whose whole file failed validation (PR 7).
    poisoned: AtomicBool,
}

impl std::fmt::Debug for Paged {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Paged")
            .field("path", &self.path)
            .field("blocks", &self.index.len())
            .field("total", &self.total)
            .field("poisoned", &self.poisoned.load(Ordering::Relaxed))
            .finish()
    }
}

/// Triple storage: fully resident (v1 loads, freshly frozen runs, the
/// default durable mode) or paged through the block cache.
#[derive(Debug)]
enum Triples {
    Resident(Vec<(u32, u32, u32)>),
    Paged(Paged),
}

/// An immutable, dictionary-encoded sorted block of cells.
#[derive(Debug)]
pub struct Run {
    seq: u64,
    watermark: u64,
    /// Sorted distinct strings; `u32` id order equals string order.
    /// Always resident, even for paged runs — the pool is the part the
    /// merge walk borrows from (`&'r SharedStr`), so cursor lifetimes
    /// are independent of which data block happens to be pinned.
    pool: Vec<SharedStr>,
    triples: Triples,
}

impl Clone for Run {
    fn clone(&self) -> Run {
        let triples = match &self.triples {
            Triples::Resident(t) => Triples::Resident(t.clone()),
            Triples::Paged(p) => Triples::Paged(Paged {
                io: Arc::clone(&p.io),
                path: p.path.clone(),
                cache: Arc::clone(&p.cache),
                retry: p.retry.clone(),
                uid: p.uid,
                index: p.index.clone(),
                total: p.total,
                poisoned: AtomicBool::new(p.poisoned.load(Ordering::Relaxed)),
            }),
        };
        Run { seq: self.seq, watermark: self.watermark, pool: self.pool.clone(), triples }
    }
}

impl PartialEq for Run {
    fn eq(&self, other: &Run) -> bool {
        self.seq == other.seq
            && self.watermark == other.watermark
            && self.pool == other.pool
            && match (&self.triples, &other.triples) {
                (Triples::Resident(a), Triples::Resident(b)) => a == b,
                (Triples::Paged(a), Triples::Paged(b)) => {
                    a.path == b.path && a.total == b.total && a.index == b.index
                }
                _ => false,
            }
    }
}

impl Eq for Run {}

impl Run {
    /// Freeze `cells` into a run. `cells` must be sorted by `(row,
    /// col)` with duplicate keys newest-first — the order every caller
    /// (tablet freeze, major compaction) produces naturally.
    ///
    /// `seq` names the run file; `watermark` is the highest WAL
    /// sequence number whose effects the run captures (recovery skips
    /// WAL records at or below the minimum live watermark).
    pub fn from_cells(seq: u64, watermark: u64, cells: &[RunCell]) -> Run {
        debug_assert!(cells
            .windows(2)
            .all(|w| (w[0].0.as_str(), w[0].1.as_str()) <= (w[1].0.as_str(), w[1].1.as_str())));
        let mut dict = StrDict::new();
        let raw: Vec<(u32, u32, u32)> = cells
            .iter()
            .map(|(r, c, v)| {
                (
                    dict.intern(r),
                    dict.intern(c),
                    v.as_ref().map_or(TOMBSTONE, |v| dict.intern(v)),
                )
            })
            .collect();
        // `into_sorted` yields the pool in string order plus the
        // monotone old-id → rank map; remapping ids through it keeps
        // the (row, col) sort *and* the stable newest-first order of
        // duplicate keys (no re-sort happens).
        let (pool, rank) = dict.into_sorted();
        let triples = raw
            .into_iter()
            .map(|(r, c, v)| {
                let v = if v == TOMBSTONE { TOMBSTONE } else { rank[v as usize] };
                (rank[r as usize], rank[c as usize], v)
            })
            .collect();
        Run { seq, watermark, pool, triples: Triples::Resident(triples) }
    }

    /// The run's file sequence number (unique per table, increasing).
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Highest WAL sequence number this run's contents cover.
    pub fn watermark(&self) -> u64 {
        self.watermark
    }

    /// Number of stored cells (tombstones included).
    pub fn len(&self) -> usize {
        match &self.triples {
            Triples::Resident(t) => t.len(),
            Triples::Paged(p) => p.total,
        }
    }

    /// Whether the run stores no cells at all.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of distinct strings in the run's dictionary pool (row,
    /// column, and value keys share one pool). The pool is always
    /// resident — even for paged runs — so this never faults a block.
    pub fn dict_len(&self) -> usize {
        self.pool.len()
    }

    /// Whether the run is paged through the block cache (vs. fully
    /// resident in memory).
    pub fn is_paged(&self) -> bool {
        matches!(self.triples, Triples::Paged(_))
    }

    /// Whether an unrecoverable block fault has been observed. Poisoned
    /// runs read as empty to new cursors; `Table::sync` and the
    /// compaction entry points quarantine them (PR 7 semantics at block
    /// granularity). Resident runs never poison — their bytes were
    /// fully validated at load.
    pub fn is_poisoned(&self) -> bool {
        match &self.triples {
            Triples::Resident(_) => false,
            Triples::Paged(p) => p.poisoned.load(Ordering::Acquire),
        }
    }

    /// Triple ids of cell `i`, faulting its block in if needed. `None`
    /// only on a paged run whose block cannot be read or fails its CRC
    /// — which also poisons the run.
    #[inline]
    fn ids(&self, i: usize) -> Option<(u32, u32, u32)> {
        match &self.triples {
            Triples::Resident(t) => Some(t[i]),
            Triples::Paged(p) => {
                let b = p.block_of(i);
                let blk = p.load_block(b, self.pool.len())?;
                Some(blk.triples()[i - p.index[b].start])
            }
        }
    }

    /// Key of cell `i` as pooled strings. On a paged run this faults
    /// the containing block (point-lookup path; the merge walk goes
    /// through [`RunCursor`], which pins one block at a time). After a
    /// block fault the run is poisoned and this degrades to the first
    /// pool entry — callers observe the mismatch and treat the run as
    /// absent, matching the quarantine semantics.
    #[inline]
    pub fn key(&self, i: usize) -> (&SharedStr, &SharedStr) {
        let (r, c, _) = self.ids(i).unwrap_or((0, 0, TOMBSTONE));
        (&self.pool[r as usize], &self.pool[c as usize])
    }

    /// Value of cell `i`; `None` for a tombstone (or a faulted block —
    /// see [`Run::key`]).
    #[inline]
    pub fn val(&self, i: usize) -> Option<&SharedStr> {
        let (_, _, v) = self.ids(i).unwrap_or((0, 0, TOMBSTONE));
        if v == TOMBSTONE {
            None
        } else {
            Some(&self.pool[v as usize])
        }
    }

    #[inline]
    fn key_str(&self, i: usize) -> (&str, &str) {
        let (r, c) = self.key(i);
        (r.as_str(), c.as_str())
    }

    /// Index of the first cell at or after `(row, col)` (`inclusive`)
    /// or strictly after the *whole version group* of `(row, col)`
    /// (`!inclusive`). Pool ids sort like strings, so this is a plain
    /// binary search over pooled `&str`s; on a paged run the block
    /// index narrows the search to one block first, so a seek faults at
    /// most one block and never touches the gaps.
    pub fn lower_bound(&self, row: &str, col: &str, inclusive: bool) -> usize {
        if inclusive {
            self.partition(|k| k < (row, col))
        } else {
            self.partition(|k| k <= (row, col))
        }
    }

    /// Global partition point of a monotone key predicate (`true` on a
    /// prefix of the sorted cells).
    fn partition(&self, pred: impl Fn((&str, &str)) -> bool) -> usize {
        match &self.triples {
            Triples::Resident(t) => {
                partition_slice(t.len(), |i| pred(self.key_str_resident(t, i)))
            }
            Triples::Paged(p) => {
                // Count index entries whose first key satisfies `pred`;
                // the partition point lives in the last such block (or
                // is 0 when even the first key fails the predicate).
                let pool = &self.pool;
                let nb = partition_slice(p.index.len(), |b| {
                    let m = &p.index[b];
                    pred((pool[m.first_row as usize].as_str(), pool[m.first_col as usize].as_str()))
                });
                if nb == 0 {
                    return 0;
                }
                let b = nb - 1;
                let meta = &p.index[b];
                let Some(blk) = p.load_block(b, pool.len()) else {
                    // Faulted (now poisoned): any in-range position is
                    // fine, the cursor built from it will read empty.
                    return meta.start;
                };
                let triples = blk.triples();
                meta.start
                    + partition_slice(triples.len(), |i| {
                        let (r, c, _) = triples[i];
                        pred((pool[r as usize].as_str(), pool[c as usize].as_str()))
                    })
            }
        }
    }

    #[inline]
    fn key_str_resident<'a>(&'a self, t: &[(u32, u32, u32)], i: usize) -> (&'a str, &'a str) {
        let (r, c, _) = t[i];
        (self.pool[r as usize].as_str(), self.pool[c as usize].as_str())
    }

    /// Half-open index range of cells whose row lies in `[lo, hi)`
    /// (either bound `None` = unbounded) — the clamp that keeps a
    /// cloned run from leaking cells outside a split tablet's extent.
    pub fn extent_range(&self, lo: Option<&str>, hi: Option<&str>) -> (usize, usize) {
        let start = match lo {
            Some(lo) => self.partition(|(r, _)| r < lo),
            None => 0,
        };
        let end = match hi {
            Some(hi) => self.partition(|(r, _)| r < hi),
            None => self.len(),
        };
        (start, end.max(start))
    }

    /// A row usable as a chunking cut point near cell `i`. Resident
    /// runs answer exactly; paged runs answer with the first row of the
    /// containing block straight from the index — zero block faults, at
    /// the cost of a slightly coarser (still valid) cut.
    pub(crate) fn sample_row(&self, i: usize) -> &SharedStr {
        match &self.triples {
            Triples::Resident(_) => self.key(i).0,
            Triples::Paged(p) => {
                let m = &p.index[p.block_of(i)];
                &self.pool[m.first_row as usize]
            }
        }
    }

    /// Newest version of `(row, col)` in this run: `None` if the run
    /// has no cell for the key, `Some(None)` if the newest version is
    /// a tombstone, `Some(Some(val))` otherwise.
    pub fn get(&self, row: &str, col: &str) -> Option<Option<&SharedStr>> {
        let i = self.lower_bound(row, col, true);
        if i < self.len() && self.key_str(i) == (row, col) {
            Some(self.val(i))
        } else {
            None
        }
    }

    /// Number of stored versions of `(row, col)` (tombstones counted).
    pub fn versions(&self, row: &str, col: &str) -> usize {
        self.lower_bound(row, col, false) - self.lower_bound(row, col, true)
    }

    /// Serialize to `path` in the v2 paged format (see module docs).
    pub fn save(&self, path: &Path) -> io::Result<()> {
        self.save_with(&RealIo, path)
    }

    /// [`Run::save`] through an explicit [`StorageIo`]. The whole file
    /// is built in memory and installed with
    /// [`StorageIo::write_atomic`] — a crash or failure mid-save leaves
    /// either the old file or nothing, never a torn run. (Streaming
    /// compaction writes block-by-block through [`RunWriter`] instead.)
    pub fn save_with(&self, io: &dyn StorageIo, path: &Path) -> io::Result<()> {
        self.save_with_blocks(io, path, DEFAULT_BLOCK_TRIPLES)
    }

    /// [`Run::save_with`] with an explicit data-block size in triples.
    pub fn save_with_blocks(
        &self,
        io: &dyn StorageIo,
        path: &Path,
        block_triples: usize,
    ) -> io::Result<()> {
        let Triples::Resident(triples) = &self.triples else {
            // Paged runs are already on disk; re-saving one would mean
            // faulting every block back in, which no caller needs.
            return Err(io::Error::other("cannot re-save a paged run"));
        };
        let block_triples = block_triples.max(1);
        let mut bytes =
            Vec::with_capacity(64 + self.pool.len() * 12 + triples.len() * TRIPLE_BYTES);
        bytes.extend_from_slice(RUN_MAGIC_V2);
        let mut index: Vec<BlockMeta> = Vec::new();
        for chunk in triples.chunks(block_triples) {
            let offset = bytes.len() as u64;
            let start = index.last().map_or(0, |m: &BlockMeta| m.start + m.count);
            for &(r, c, v) in chunk {
                bytes.extend_from_slice(&r.to_le_bytes());
                bytes.extend_from_slice(&c.to_le_bytes());
                bytes.extend_from_slice(&v.to_le_bytes());
            }
            let raw = &bytes[offset as usize..];
            let (first_row, first_col, _) = chunk[0];
            index.push(BlockMeta {
                first_row,
                first_col,
                start,
                count: chunk.len(),
                offset,
                len: raw.len() as u32,
                crc: crc32(raw),
            });
        }
        let footer_off = bytes.len() as u64;
        let footer = encode_footer(self.seq, self.watermark, &self.pool, &index, triples.len());
        bytes.extend_from_slice(&footer);
        bytes.extend_from_slice(&footer_off.to_le_bytes());
        bytes.extend_from_slice(&(footer.len() as u32).to_le_bytes());
        bytes.extend_from_slice(&crc32(&footer).to_le_bytes());
        io.write_atomic(path, &bytes)
    }

    /// Serialize in the **legacy v1** single-body format. Kept so the
    /// cross-version regression tests can manufacture old-format files;
    /// production code always writes v2.
    pub fn save_v1_with(&self, io: &dyn StorageIo, path: &Path) -> io::Result<()> {
        let Triples::Resident(triples) = &self.triples else {
            return Err(io::Error::other("cannot re-save a paged run"));
        };
        let mut bytes = Vec::with_capacity(48 + self.pool.len() * 12 + triples.len() * 12);
        bytes.extend_from_slice(RUN_MAGIC_V1);
        bytes.extend_from_slice(&self.seq.to_le_bytes());
        bytes.extend_from_slice(&self.watermark.to_le_bytes());
        bytes.extend_from_slice(&(self.pool.len() as u32).to_le_bytes());
        for s in &self.pool {
            bytes.extend_from_slice(&(s.len() as u32).to_le_bytes());
            bytes.extend_from_slice(s.as_bytes());
        }
        bytes.extend_from_slice(&(triples.len() as u32).to_le_bytes());
        for &(r, c, v) in triples {
            bytes.extend_from_slice(&r.to_le_bytes());
            bytes.extend_from_slice(&c.to_le_bytes());
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let crc = crc32(&bytes[RUN_MAGIC_V1.len()..]);
        bytes.extend_from_slice(&crc.to_le_bytes());
        io.write_atomic(path, &bytes)
    }

    /// Load a run from `path` fully resident, validating magic, CRCs,
    /// and id bounds — both format versions. Unlike the WAL, a damaged
    /// run file is a hard [`io::ErrorKind::InvalidData`] error: runs
    /// are written atomically after an fsync, so torn runs are not an
    /// expected crash state — recovery quarantines such files instead
    /// of serving wrong cells.
    pub fn load(path: &Path) -> io::Result<Run> {
        Self::load_with(&RealIo, path)
    }

    /// [`Run::load`] through an explicit [`StorageIo`].
    pub fn load_with(io: &dyn StorageIo, path: &Path) -> io::Result<Run> {
        let bytes = io.read(path)?;
        if bytes.len() >= 8 && &bytes[..8] == RUN_MAGIC_V1 {
            return Self::load_v1(&bytes, path);
        }
        if bytes.len() >= 8 && &bytes[..8] == RUN_MAGIC_V2 {
            return Self::load_v2(&bytes, path);
        }
        Err(bad(path, "not a d4m run file (bad magic or too short)"))
    }

    /// Open a run **paged**: read only the trailer and footer through
    /// `io`, leave the data blocks on disk to be faulted lazily through
    /// `cache`. A v1 file (no block structure) falls back to a fully
    /// resident load. `retry` governs each later block read.
    pub fn open_with(
        io: Arc<dyn StorageIo>,
        path: &Path,
        cache: Arc<BlockCache>,
        retry: RetryPolicy,
    ) -> io::Result<Run> {
        let magic = io.read_range(path, 0, 8)?;
        if magic.as_slice() == RUN_MAGIC_V1 {
            return Self::load_with(&*io, path);
        }
        if magic.as_slice() != RUN_MAGIC_V2 {
            return Err(bad(path, "not a d4m run file (bad magic or too short)"));
        }
        let size = io.file_size(path)?;
        if size < (8 + TRAILER_BYTES) as u64 {
            return Err(bad(path, "run file too short for its trailer"));
        }
        let trailer = io.read_range(path, size - TRAILER_BYTES as u64, TRAILER_BYTES)?;
        let footer_off = u64::from_le_bytes(trailer[0..8].try_into().expect("8 bytes"));
        let footer_len = u32::from_le_bytes(trailer[8..12].try_into().expect("4 bytes")) as usize;
        let footer_crc = u32::from_le_bytes(trailer[12..16].try_into().expect("4 bytes"));
        if footer_off < 8
            || footer_len as u64 > size
            || footer_off + footer_len as u64 + TRAILER_BYTES as u64 != size
        {
            return Err(bad(path, "run trailer geometry out of bounds"));
        }
        let footer = io.read_range(path, footer_off, footer_len)?;
        if crc32(&footer) != footer_crc {
            return Err(bad(path, "run footer failed its checksum"));
        }
        let (seq, watermark, pool, index, total) =
            decode_footer(&footer, footer_off, path)?;
        Ok(Run {
            seq,
            watermark,
            pool,
            triples: Triples::Paged(Paged {
                io,
                path: path.to_path_buf(),
                cache,
                retry,
                uid: BlockCache::next_run_uid(),
                index,
                total,
                poisoned: AtomicBool::new(false),
            }),
        })
    }

    fn load_v1(bytes: &[u8], path: &Path) -> io::Result<Run> {
        if bytes.len() < RUN_MAGIC_V1.len() + 4 {
            return Err(bad(path, "not a d4m run file (bad magic or too short)"));
        }
        let body = &bytes[RUN_MAGIC_V1.len()..bytes.len() - 4];
        let stored_crc =
            u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().expect("4 bytes"));
        if crc32(body) != stored_crc {
            return Err(bad(path, "run body failed its checksum"));
        }
        let mut rd = Reader { buf: body, pos: 0 };
        let parse = |rd: &mut Reader<'_>| -> Option<Result<Run, &'static str>> {
            let seq = rd.u64()?;
            let watermark = rd.u64()?;
            let pool = match read_pool(rd)? {
                Ok(pool) => pool,
                Err(msg) => return Some(Err(msg)),
            };
            let ntriples = rd.u32()?;
            if ntriples > MAX_COUNT {
                return Some(Err("run triple count out of range"));
            }
            let mut triples = Vec::with_capacity(ntriples as usize);
            for _ in 0..ntriples {
                let (r, c, v) = (rd.u32()?, rd.u32()?, rd.u32()?);
                if !ids_in_pool(r, c, v, pool.len()) {
                    return Some(Err("run triple id out of pool range"));
                }
                triples.push((r, c, v));
            }
            Some(Ok(Run { seq, watermark, pool, triples: Triples::Resident(triples) }))
        };
        let run = match parse(&mut rd) {
            None => return Err(bad(path, "run body truncated")),
            Some(Err(msg)) => return Err(bad(path, msg)),
            Some(Ok(run)) => run,
        };
        if rd.pos != body.len() {
            return Err(bad(path, "trailing bytes after run body"));
        }
        Ok(run)
    }

    fn load_v2(bytes: &[u8], path: &Path) -> io::Result<Run> {
        if bytes.len() < 8 + TRAILER_BYTES {
            return Err(bad(path, "run file too short for its trailer"));
        }
        let t = &bytes[bytes.len() - TRAILER_BYTES..];
        let footer_off = u64::from_le_bytes(t[0..8].try_into().expect("8 bytes")) as usize;
        let footer_len = u32::from_le_bytes(t[8..12].try_into().expect("4 bytes")) as usize;
        let footer_crc = u32::from_le_bytes(t[12..16].try_into().expect("4 bytes"));
        if footer_off < 8 || footer_off + footer_len + TRAILER_BYTES != bytes.len() {
            return Err(bad(path, "run trailer geometry out of bounds"));
        }
        let footer = &bytes[footer_off..footer_off + footer_len];
        if crc32(footer) != footer_crc {
            return Err(bad(path, "run footer failed its checksum"));
        }
        let (seq, watermark, pool, index, total) =
            decode_footer(footer, footer_off as u64, path)?;
        let mut triples = Vec::with_capacity(total);
        for m in &index {
            let (off, len) = (m.offset as usize, m.len as usize);
            let raw = &bytes[off..off + len];
            if crc32(raw) != m.crc {
                return Err(bad(path, "run block failed its checksum"));
            }
            for t in raw.chunks_exact(TRIPLE_BYTES) {
                let r = u32::from_le_bytes(t[0..4].try_into().expect("4 bytes"));
                let c = u32::from_le_bytes(t[4..8].try_into().expect("4 bytes"));
                let v = u32::from_le_bytes(t[8..12].try_into().expect("4 bytes"));
                if !ids_in_pool(r, c, v, pool.len()) {
                    return Err(bad(path, "run triple id out of pool range"));
                }
                triples.push((r, c, v));
            }
        }
        Ok(Run { seq, watermark, pool, triples: Triples::Resident(triples) })
    }
}

impl Paged {
    /// Index of the block containing global triple `i`.
    fn block_of(&self, i: usize) -> usize {
        debug_assert!(i < self.total);
        self.index.partition_point(|m| m.start + m.count <= i)
    }

    /// Fault block `b` in through the cache, verifying its CRC and id
    /// bounds. `None` poisons the run (read failure after retries or
    /// corruption) — callers treat the block as empty; the next sweep
    /// quarantines the file.
    fn load_block(&self, b: usize, pool_len: usize) -> Option<Arc<Block>> {
        if self.poisoned.load(Ordering::Acquire) {
            return None;
        }
        let meta = &self.index[b];
        let loaded = self.cache.get_or_load((self.uid, b as u32), || {
            let raw = self.retry.run("block read", || {
                self.io.read_range(&self.path, meta.offset, meta.len as usize)
            })?;
            if crc32(&raw) != meta.crc {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("{}: run block failed its checksum", self.path.display()),
                ));
            }
            let mut triples = Vec::with_capacity(meta.count);
            for t in raw.chunks_exact(TRIPLE_BYTES) {
                let r = u32::from_le_bytes(t[0..4].try_into().expect("4 bytes"));
                let c = u32::from_le_bytes(t[4..8].try_into().expect("4 bytes"));
                let v = u32::from_le_bytes(t[8..12].try_into().expect("4 bytes"));
                if !ids_in_pool(r, c, v, pool_len) {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("{}: run triple id out of pool range", self.path.display()),
                    ));
                }
                triples.push((r, c, v));
            }
            Ok(self.cache.make_block(triples))
        });
        match loaded {
            Ok(blk) => Some(blk),
            Err(_) => {
                self.poisoned.store(true, Ordering::Release);
                None
            }
        }
    }
}

fn bad(path: &Path, msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("{}: {msg}", path.display()))
}

/// `partition_point` over `0..n` for a predicate true on a prefix.
fn partition_slice(n: usize, pred: impl Fn(usize) -> bool) -> usize {
    let mut lo = 0usize;
    let mut hi = n;
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if pred(mid) {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

#[inline]
fn ids_in_pool(r: u32, c: u32, v: u32, pool_len: usize) -> bool {
    let in_pool = |id: u32| (id as usize) < pool_len;
    in_pool(r) && in_pool(c) && (v == TOMBSTONE || in_pool(v))
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len())?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Some(s)
    }

    fn u32(&mut self) -> Option<u32> {
        self.take(4).map(|b| u32::from_le_bytes(b.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Option<u64> {
        self.take(8).map(|b| u64::from_le_bytes(b.try_into().expect("8 bytes")))
    }
}

fn read_pool(rd: &mut Reader<'_>) -> Option<Result<Vec<SharedStr>, &'static str>> {
    let pool_len = rd.u32()?;
    if pool_len > MAX_COUNT {
        return Some(Err("run pool count out of range"));
    }
    let mut pool = Vec::with_capacity(pool_len as usize);
    for _ in 0..pool_len {
        let len = rd.u32()? as usize;
        match std::str::from_utf8(rd.take(len)?) {
            Ok(s) => pool.push(SharedStr::from(s)),
            Err(_) => return Some(Err("run pool entry is not UTF-8")),
        }
    }
    Some(Ok(pool))
}

fn encode_footer(
    seq: u64,
    watermark: u64,
    pool: &[SharedStr],
    index: &[BlockMeta],
    total: usize,
) -> Vec<u8> {
    let mut f = Vec::with_capacity(32 + pool.len() * 12 + index.len() * 28);
    f.extend_from_slice(&seq.to_le_bytes());
    f.extend_from_slice(&watermark.to_le_bytes());
    f.extend_from_slice(&(pool.len() as u32).to_le_bytes());
    for s in pool {
        f.extend_from_slice(&(s.len() as u32).to_le_bytes());
        f.extend_from_slice(s.as_bytes());
    }
    f.extend_from_slice(&(index.len() as u32).to_le_bytes());
    for m in index {
        f.extend_from_slice(&m.first_row.to_le_bytes());
        f.extend_from_slice(&m.first_col.to_le_bytes());
        f.extend_from_slice(&(m.count as u32).to_le_bytes());
        f.extend_from_slice(&m.offset.to_le_bytes());
        f.extend_from_slice(&m.len.to_le_bytes());
        f.extend_from_slice(&m.crc.to_le_bytes());
    }
    f.extend_from_slice(&(total as u32).to_le_bytes());
    f
}

/// Parse and validate a v2 footer. `footer_off` bounds the block
/// geometry (every block must end before the footer starts).
#[allow(clippy::type_complexity)]
fn decode_footer(
    footer: &[u8],
    footer_off: u64,
    path: &Path,
) -> io::Result<(u64, u64, Vec<SharedStr>, Vec<BlockMeta>, usize)> {
    let mut rd = Reader { buf: footer, pos: 0 };
    let parse = |rd: &mut Reader<'_>| -> Option<Result<_, &'static str>> {
        let seq = rd.u64()?;
        let watermark = rd.u64()?;
        let pool = match read_pool(rd)? {
            Ok(pool) => pool,
            Err(msg) => return Some(Err(msg)),
        };
        let nblocks = rd.u32()?;
        if nblocks > MAX_COUNT {
            return Some(Err("run block count out of range"));
        }
        let mut index = Vec::with_capacity(nblocks as usize);
        let mut start = 0usize;
        let mut prev_end = 8u64;
        for _ in 0..nblocks {
            let first_row = rd.u32()?;
            let first_col = rd.u32()?;
            let count = rd.u32()? as usize;
            let offset = rd.u64()?;
            let len = rd.u32()?;
            let crc = rd.u32()?;
            let in_pool = |id: u32| (id as usize) < pool.len();
            if !in_pool(first_row) || !in_pool(first_col) {
                return Some(Err("run block first key out of pool range"));
            }
            if count == 0
                || count as u32 > MAX_COUNT
                || len as usize != count * TRIPLE_BYTES
                || offset < prev_end
                || offset + len as u64 > footer_off
            {
                return Some(Err("run block geometry out of bounds"));
            }
            prev_end = offset + len as u64;
            index.push(BlockMeta { first_row, first_col, start, count, offset, len, crc });
            start += count;
        }
        let total = rd.u32()? as usize;
        if total != start {
            return Some(Err("run triple count disagrees with block index"));
        }
        Some(Ok((seq, watermark, pool, index, total)))
    };
    let parsed = match parse(&mut rd) {
        None => return Err(bad(path, "run footer truncated")),
        Some(Err(msg)) => return Err(bad(path, msg)),
        Some(Ok(p)) => p,
    };
    if rd.pos != footer.len() {
        return Err(bad(path, "trailing bytes after run footer"));
    }
    Ok(parsed)
}

// ------------------------------------------------------------ RunWriter

/// Streaming v2 run writer: blocks go to storage as they fill, so the
/// writer's memory is one block plus the (resident-by-design) pool and
/// index — the bounded-memory half of streaming major compaction.
///
/// The pool must be complete and sorted *before* the first triple is
/// pushed (ids are final in the file); the streaming compactor gets it
/// from its intern pass. Writes go to `<path>.tmp`; [`RunWriter::finish`]
/// appends the footer + trailer, fsyncs, and renames over `path` — the
/// same atomic-install contract as [`Run::save_with`]. Dropping an
/// unfinished writer leaves only a `.tmp` file for the orphan GC.
pub(crate) struct RunWriter {
    file: Box<dyn super::io::StorageFile>,
    seq: u64,
    watermark: u64,
    pool: Vec<SharedStr>,
    block_triples: usize,
    /// Serialized bytes of the currently filling block.
    buf: Vec<u8>,
    /// First key of the currently filling block.
    first: Option<(u32, u32)>,
    index: Vec<BlockMeta>,
    written: u64,
    total: usize,
}

impl RunWriter {
    /// Open `<path>.tmp` through `io` and write the magic. `pool` must
    /// be sorted ascending with no duplicates.
    pub(crate) fn create(
        io: &dyn StorageIo,
        path: &Path,
        seq: u64,
        watermark: u64,
        pool: Vec<SharedStr>,
        block_triples: usize,
    ) -> io::Result<RunWriter> {
        debug_assert!(pool.windows(2).all(|w| w[0].as_str() < w[1].as_str()));
        let tmp = tmp_of(path);
        let mut file = io.create(&tmp)?;
        file.write_all(RUN_MAGIC_V2)?;
        Ok(RunWriter {
            file,
            seq,
            watermark,
            pool,
            block_triples: block_triples.max(1),
            buf: Vec::new(),
            first: None,
            index: Vec::new(),
            written: 8,
            total: 0,
        })
    }

    /// Pool id of `s`, or `None` when the string was never interned —
    /// a divergence between the interning pass and the streaming pass,
    /// only reachable when a source block faulted between them. Callers
    /// treat `None` as a fault, never a panic.
    pub(crate) fn id_of(&self, s: &str) -> Option<u32> {
        self.pool.binary_search_by(|p| p.as_str().cmp(s)).ok().map(|i| i as u32)
    }

    /// Append one triple (ids from [`RunWriter::id_of`]; `TOMBSTONE`
    /// for a deleted value). Must arrive in `(row, col)` order,
    /// duplicates newest-first — the merge order.
    pub(crate) fn push(&mut self, r: u32, c: u32, v: u32) -> io::Result<()> {
        if self.first.is_none() {
            self.first = Some((r, c));
        }
        self.buf.extend_from_slice(&r.to_le_bytes());
        self.buf.extend_from_slice(&c.to_le_bytes());
        self.buf.extend_from_slice(&v.to_le_bytes());
        self.total += 1;
        if self.buf.len() >= self.block_triples * TRIPLE_BYTES {
            self.flush_block()?;
        }
        Ok(())
    }

    fn flush_block(&mut self) -> io::Result<()> {
        if self.buf.is_empty() {
            return Ok(());
        }
        let (first_row, first_col) = self.first.take().expect("non-empty block has a first key");
        let count = self.buf.len() / TRIPLE_BYTES;
        let start = self.index.last().map_or(0, |m| m.start + m.count);
        self.index.push(BlockMeta {
            first_row,
            first_col,
            start,
            count,
            offset: self.written,
            len: self.buf.len() as u32,
            crc: crc32(&self.buf),
        });
        self.file.write_all(&self.buf)?;
        self.written += self.buf.len() as u64;
        self.buf.clear();
        Ok(())
    }

    /// Flush the last block, write footer + trailer, fsync, and rename
    /// the tmp file over `path`. Returns the number of cells written.
    pub(crate) fn finish(mut self, io: &dyn StorageIo, path: &Path) -> io::Result<usize> {
        self.flush_block()?;
        let footer_off = self.written;
        let footer =
            encode_footer(self.seq, self.watermark, &self.pool, &self.index, self.total);
        self.file.write_all(&footer)?;
        let mut trailer = Vec::with_capacity(TRAILER_BYTES);
        trailer.extend_from_slice(&footer_off.to_le_bytes());
        trailer.extend_from_slice(&(footer.len() as u32).to_le_bytes());
        trailer.extend_from_slice(&crc32(&footer).to_le_bytes());
        self.file.write_all(&trailer)?;
        self.file.sync_data()?;
        drop(self.file);
        io.rename(&tmp_of(path), path)?;
        Ok(self.total)
    }
}

/// `<path>.tmp`, matching [`StorageIo::write_atomic`]'s convention so
/// abandoned streaming writes are swept by the same stale-tmp GC.
fn tmp_of(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_owned();
    os.push(".tmp");
    PathBuf::from(os)
}

// ------------------------------------------------------------ RunCursor

/// Forward cursor over a run's cells within an extent-clamped index
/// window. Borrowed views live as long as the run (`'r`), independent
/// of the cursor borrow — the merge walk peeks several cursors at once.
/// (The strings come from the always-resident pool; only the id triples
/// page, so the lifetimes hold in both modes.)
///
/// On a paged run the cursor pins exactly one block at a time (an
/// `Arc<Block>` that stays valid even if the cache evicts it) — this is
/// the "+ one block per active cursor" term of the scan memory bound. A
/// block fault failure poisons the run and exhausts the cursor; newer
/// cursors skip poisoned runs entirely.
#[derive(Debug)]
pub struct RunCursor<'r> {
    run: &'r Run,
    pos: usize,
    end: usize,
    /// Pinned `(block index, block)` for paged runs.
    pin: std::cell::RefCell<Option<(usize, Arc<Block>)>>,
}

impl<'r> RunCursor<'r> {
    /// Cursor over `run` positioned at `pos`, bounded by `end`.
    pub fn new(run: &'r Run, pos: usize, end: usize) -> RunCursor<'r> {
        RunCursor { run, pos: pos.min(end), end, pin: std::cell::RefCell::new(None) }
    }

    /// Triple ids at global position `i`, through the pin for paged
    /// runs. `None` exhausts the cursor (block fault on a paged run).
    #[inline]
    fn ids_at(&self, i: usize) -> Option<(u32, u32, u32)> {
        match &self.run.triples {
            Triples::Resident(t) => Some(t[i]),
            Triples::Paged(p) => {
                let b = p.block_of(i);
                let mut pin = self.pin.borrow_mut();
                if pin.as_ref().map(|(bi, _)| *bi) != Some(b) {
                    *pin = Some((b, p.load_block(b, self.run.pool.len())?));
                }
                let (_, blk) = pin.as_ref().expect("just pinned");
                Some(blk.triples()[i - p.index[b].start])
            }
        }
    }

    /// Current cell, or `None` past the window. The value is `None`
    /// for a tombstone.
    #[inline]
    pub fn peek(&self) -> Option<(&'r SharedStr, &'r SharedStr, Option<&'r SharedStr>)> {
        if self.pos >= self.end {
            return None;
        }
        let (r, c, v) = self.ids_at(self.pos)?;
        // Borrow through the copied `&'r Run`, not through `&self`, so
        // the returned views outlive the cursor borrow.
        let run: &'r Run = self.run;
        let pool = &run.pool;
        let val = if v == TOMBSTONE { None } else { Some(&pool[v as usize]) };
        Some((&pool[r as usize], &pool[c as usize], val))
    }

    /// Step past the *entire version group* of the current key, so the
    /// cursor only ever exposes each key's newest version.
    pub fn advance_key(&mut self) {
        if self.pos >= self.end {
            return;
        }
        let Some((kr, kc, _)) = self.ids_at(self.pos) else {
            self.pos = self.end;
            return;
        };
        // Ids are stable across blocks (one pool per run), so the
        // version-group compare needs no string lookups. Version groups
        // are tiny (≤ max_versions); linear step.
        loop {
            self.pos += 1;
            if self.pos >= self.end {
                return;
            }
            match self.ids_at(self.pos) {
                Some((r, c, _)) if (r, c) == (kr, kc) => continue,
                Some(_) => return,
                None => {
                    self.pos = self.end;
                    return;
                }
            }
        }
    }

    /// Step exactly one stored version forward (the compaction merge
    /// needs every version, not just each key's newest).
    pub(crate) fn advance_one(&mut self) {
        if self.pos < self.end {
            self.pos += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(r: &str, c: &str, v: Option<&str>) -> RunCell {
        (r.into(), c.into(), v.map(SharedStr::from))
    }

    fn sample() -> Run {
        Run::from_cells(
            7,
            42,
            &[
                cell("a", "x", Some("1")),
                cell("a", "y", None), // tombstone
                cell("b", "x", Some("3")),
                cell("b", "x", Some("2")), // older version, newest first
                cell("d", "z", Some("4")),
            ],
        )
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("d4m-run-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn from_cells_preserves_order_and_versions() {
        let run = sample();
        assert_eq!((run.seq(), run.watermark(), run.len()), (7, 42, 5));
        let keys: Vec<(String, String)> = (0..run.len())
            .map(|i| {
                let (r, c) = run.key(i);
                (r.to_string(), c.to_string())
            })
            .collect();
        assert_eq!(
            keys,
            vec![
                ("a".into(), "x".into()),
                ("a".into(), "y".into()),
                ("b".into(), "x".into()),
                ("b".into(), "x".into()),
                ("d".into(), "z".into()),
            ]
        );
        // Newest-first duplicate order survived the dictionary remap.
        assert_eq!(run.val(2).map(|v| v.as_str()), Some("3"));
        assert_eq!(run.val(3).map(|v| v.as_str()), Some("2"));
        assert_eq!(run.val(1), None);
    }

    #[test]
    fn lookup_and_bounds() {
        let run = sample();
        assert_eq!(run.get("a", "x").unwrap().unwrap().as_str(), "1");
        assert_eq!(run.get("a", "y"), Some(None)); // tombstone visible
        assert_eq!(run.get("b", "x").unwrap().unwrap().as_str(), "3"); // newest
        assert_eq!(run.get("c", "q"), None);
        assert_eq!(run.versions("b", "x"), 2);
        assert_eq!(run.versions("a", "x"), 1);
        assert_eq!(run.lower_bound("b", "x", true), 2);
        assert_eq!(run.lower_bound("b", "x", false), 4); // past the group
        assert_eq!(run.extent_range(Some("b"), Some("d")), (2, 4));
        assert_eq!(run.extent_range(None, None), (0, 5));
        assert_eq!(run.extent_range(Some("e"), None), (5, 5));
    }

    #[test]
    fn cursor_exposes_newest_per_key() {
        let run = sample();
        let (start, end) = run.extent_range(None, None);
        let mut cur = RunCursor::new(&run, start, end);
        let mut seen = Vec::new();
        while let Some((r, c, v)) = cur.peek() {
            seen.push((r.to_string(), c.to_string(), v.map(|v| v.to_string())));
            cur.advance_key();
        }
        assert_eq!(
            seen,
            vec![
                ("a".into(), "x".into(), Some("1".into())),
                ("a".into(), "y".into(), None),
                ("b".into(), "x".into(), Some("3".into())), // newest of the pair
                ("d".into(), "z".into(), Some("4".into())),
            ]
        );
    }

    #[test]
    fn save_load_roundtrip_and_corruption() {
        let dir = tmp_dir("roundtrip");
        let path = dir.join("roundtrip.run");
        let run = sample();
        run.save(&path).unwrap();
        assert_eq!(Run::load(&path).unwrap(), run);
        // Flip a byte in the body: load must fail a checksum.
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        assert_eq!(Run::load(&path).unwrap_err().kind(), io::ErrorKind::InvalidData);
        // Not a run file at all.
        std::fs::write(&path, b"garbage").unwrap();
        assert_eq!(Run::load(&path).unwrap_err().kind(), io::ErrorKind::InvalidData);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn v1_files_still_load_and_match_v2() {
        let dir = tmp_dir("v1compat");
        let run = sample();
        let v1 = dir.join("v1.run");
        let v2 = dir.join("v2.run");
        run.save_v1_with(&RealIo, &v1).unwrap();
        run.save(&v2).unwrap();
        // Distinct formats on disk, identical runs in memory.
        assert_eq!(&std::fs::read(&v1).unwrap()[..8], RUN_MAGIC_V1);
        assert_eq!(&std::fs::read(&v2).unwrap()[..8], RUN_MAGIC_V2);
        assert_eq!(Run::load(&v1).unwrap(), run);
        assert_eq!(Run::load(&v1).unwrap(), Run::load(&v2).unwrap());
        // A corrupted v1 file still fails loudly.
        let mut bytes = std::fs::read(&v1).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&v1, &bytes).unwrap();
        assert_eq!(Run::load(&v1).unwrap_err().kind(), io::ErrorKind::InvalidData);
        // Paged open of a v1 file falls back to a resident load.
        run.save_v1_with(&RealIo, &v1).unwrap();
        let cache = BlockCache::new(1 << 16);
        let opened = Run::open_with(
            Arc::new(RealIo),
            &v1,
            Arc::clone(&cache),
            RetryPolicy::none(),
        )
        .unwrap();
        assert!(!opened.is_paged());
        assert_eq!(opened, run);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A multi-block run (3 cells per block) used by the paged tests.
    fn big_run() -> Run {
        let mut cells = Vec::new();
        for i in 0..40 {
            let row = format!("r{i:03}");
            cells.push(cell(&row, "c", Some(&format!("{i}"))));
            if i % 5 == 0 {
                cells.push(cell(&row, "d", None));
            }
        }
        Run::from_cells(9, 100, &cells)
    }

    #[test]
    fn paged_open_matches_resident_load() {
        let dir = tmp_dir("paged");
        let path = dir.join("paged.run");
        let run = big_run();
        run.save_with_blocks(&RealIo, &path, 3).unwrap();
        let resident = Run::load(&path).unwrap();
        assert_eq!(resident, run);

        let cache = BlockCache::new(1 << 16);
        let paged = Run::open_with(
            Arc::new(RealIo),
            &path,
            Arc::clone(&cache),
            RetryPolicy::none(),
        )
        .unwrap();
        assert!(paged.is_paged());
        assert_eq!((paged.seq(), paged.watermark(), paged.len()), (9, 100, run.len()));
        // Point lookups and bounds agree cell-for-cell.
        for i in 0..run.len() {
            assert_eq!(paged.key(i), resident.key(i));
            assert_eq!(paged.val(i), resident.val(i));
        }
        assert_eq!(paged.get("r007", "c"), resident.get("r007", "c"));
        assert_eq!(paged.get("r005", "d"), Some(None));
        assert_eq!(paged.get("zzz", "c"), None);
        assert_eq!(
            paged.extent_range(Some("r010"), Some("r020")),
            resident.extent_range(Some("r010"), Some("r020"))
        );
        assert_eq!(paged.lower_bound("r013", "c", true), resident.lower_bound("r013", "c", true));
        // Cursor walk is bit-identical, and the stats show real faults.
        let (s, e) = paged.extent_range(None, None);
        let mut cur = RunCursor::new(&paged, s, e);
        let mut cur_r = RunCursor::new(&resident, s, e);
        loop {
            let (a, b) = (cur.peek(), cur_r.peek());
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
            cur.advance_key();
            cur_r.advance_key();
        }
        let stats = cache.stats();
        assert!(stats.misses > 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn paged_block_corruption_poisons_not_panics() {
        let dir = tmp_dir("poison");
        let path = dir.join("bad.run");
        let run = big_run();
        run.save_with_blocks(&RealIo, &path, 4).unwrap();
        // Flip a byte inside the first data block (offset 8 is the
        // first triple byte; the footer is far away).
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[10] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        // Resident load fails loudly (PR 7 quarantine path)...
        assert_eq!(Run::load(&path).unwrap_err().kind(), io::ErrorKind::InvalidData);
        // ...while a paged open succeeds (footer is intact) and the
        // fault surfaces at block-read time as poison + empty reads.
        let cache = BlockCache::new(1 << 16);
        let paged = Run::open_with(
            Arc::new(RealIo),
            &path,
            Arc::clone(&cache),
            RetryPolicy::none(),
        )
        .unwrap();
        assert!(!paged.is_poisoned());
        let (s, e) = paged.extent_range(None, None);
        let cur = RunCursor::new(&paged, s, e);
        assert_eq!(cur.peek(), None); // first block is the bad one
        assert!(paged.is_poisoned());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn run_writer_streams_identical_files() {
        let dir = tmp_dir("writer");
        let via_save = dir.join("save.run");
        let via_writer = dir.join("writer.run");
        let run = big_run();
        run.save_with_blocks(&RealIo, &via_save, 7).unwrap();

        // Stream the same cells through RunWriter.
        let Triples::Resident(triples) = &run.triples else { unreachable!() };
        let mut w = RunWriter::create(
            &RealIo,
            &via_writer,
            run.seq(),
            run.watermark(),
            run.pool.clone(),
            7,
        )
        .unwrap();
        for &(r, c, v) in triples {
            w.push(r, c, v).unwrap();
        }
        assert_eq!(w.finish(&RealIo, &via_writer).unwrap(), run.len());
        assert_eq!(std::fs::read(&via_save).unwrap(), std::fs::read(&via_writer).unwrap());
        assert_eq!(Run::load(&via_writer).unwrap(), run);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
