//! A tablet: one sorted key range of a table (the Accumulo unit of
//! distribution and recovery).

use super::Triple;
use std::collections::BTreeMap;
use std::ops::Bound;

/// Sorted `(row, col) → val` map covering the half-open row range
/// `[lo, hi)` (`None` = unbounded on that side).
#[derive(Debug, Default)]
pub struct Tablet {
    /// Inclusive lower row bound (`None` = -∞).
    pub lo: Option<String>,
    /// Exclusive upper row bound (`None` = +∞).
    pub hi: Option<String>,
    entries: BTreeMap<(Box<str>, Box<str>), Box<str>>,
    weight: usize,
    /// Failure-injection flag: an offline tablet rejects reads/writes.
    pub offline: bool,
}

impl Tablet {
    /// New tablet covering `[lo, hi)`.
    pub fn new(lo: Option<String>, hi: Option<String>) -> Self {
        Tablet { lo, hi, ..Default::default() }
    }

    /// Whether `row` falls inside this tablet's extent.
    pub fn contains(&self, row: &str) -> bool {
        let above_lo = self.lo.as_deref().is_none_or(|lo| row >= lo);
        let below_hi = self.hi.as_deref().is_none_or(|hi| row < hi);
        above_lo && below_hi
    }

    /// Insert (overwriting any existing value). Returns the previous
    /// value if the cell existed.
    pub fn put(&mut self, t: Triple) -> Option<Box<str>> {
        debug_assert!(self.contains(&t.row), "triple routed to wrong tablet");
        let val_len = t.val.len();
        let full_weight = t.weight();
        let prev = self
            .entries
            .insert((t.row.into_boxed_str(), t.col.into_boxed_str()), t.val.into_boxed_str());
        match &prev {
            // Replacement: keys already counted, only the value delta.
            Some(old) => self.weight = self.weight - old.len() + val_len,
            None => self.weight += full_weight,
        }
        prev
    }

    /// Point lookup.
    pub fn get(&self, row: &str, col: &str) -> Option<&str> {
        self.entries.get(&(row.into(), col.into())).map(|v| v.as_ref())
    }

    /// Delete a cell; returns whether it existed.
    pub fn delete(&mut self, row: &str, col: &str) -> bool {
        if let Some(v) = self.entries.remove(&(row.into(), col.into())) {
            self.weight -= row.len() + col.len() + v.len();
            true
        } else {
            false
        }
    }

    /// Scan rows in `[lo, hi)` (clamped to the tablet extent), in sorted
    /// order, appending to `out`.
    pub fn scan_into(&self, lo: Option<&str>, hi: Option<&str>, out: &mut Vec<Triple>) {
        let start: Bound<(Box<str>, Box<str>)> = match lo {
            Some(lo) => Bound::Included((lo.into(), "".into())),
            None => Bound::Unbounded,
        };
        for ((r, c), v) in self.entries.range((start, Bound::Unbounded)) {
            if let Some(hi) = hi {
                if r.as_ref() >= hi {
                    break;
                }
            }
            out.push(Triple::new(r.as_ref(), c.as_ref(), v.as_ref()));
        }
    }

    /// Number of stored cells.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the tablet holds no cells.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Approximate stored bytes (the split trigger).
    pub fn weight(&self) -> usize {
        self.weight
    }

    /// The median row key — the split point used when this tablet grows
    /// past the size threshold. `None` for tablets with < 2 distinct rows.
    pub fn median_row(&self) -> Option<String> {
        if self.entries.len() < 2 {
            return None;
        }
        let mid = self.entries.len() / 2;
        let (row, _) = self.entries.keys().nth(mid)?.clone();
        // Splitting at the first row would create an empty left tablet.
        let first = self.entries.keys().next().map(|(r, _)| r.clone())?;
        if row == first {
            return None;
        }
        Some(row.into())
    }

    /// Split at `row`: self keeps `[lo, row)`, the returned tablet holds
    /// `[row, hi)`.
    pub fn split_at(&mut self, row: &str) -> Tablet {
        let right_entries: BTreeMap<(Box<str>, Box<str>), Box<str>> =
            self.entries.split_off(&(row.into(), "".into()));
        let right_weight: usize =
            right_entries.iter().map(|((r, c), v)| r.len() + c.len() + v.len()).sum();
        self.weight -= right_weight;
        let right = Tablet {
            lo: Some(row.to_string()),
            hi: self.hi.take(),
            entries: right_entries,
            weight: right_weight,
            offline: false,
        };
        self.hi = Some(row.to_string());
        right
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(r: &str, c: &str, v: &str) -> Triple {
        Triple::new(r, c, v)
    }

    #[test]
    fn put_get_delete() {
        let mut tab = Tablet::new(None, None);
        assert!(tab.put(t("r1", "c1", "v1")).is_none());
        assert_eq!(tab.get("r1", "c1"), Some("v1"));
        // Overwrite returns previous.
        assert_eq!(tab.put(t("r1", "c1", "v2")).as_deref(), Some("v1"));
        assert_eq!(tab.get("r1", "c1"), Some("v2"));
        assert!(tab.delete("r1", "c1"));
        assert!(!tab.delete("r1", "c1"));
        assert!(tab.is_empty());
        assert_eq!(tab.weight(), 0);
    }

    #[test]
    fn contains_respects_bounds() {
        let tab = Tablet::new(Some("m".into()), Some("t".into()));
        assert!(tab.contains("m"));
        assert!(tab.contains("s"));
        assert!(!tab.contains("t")); // exclusive hi
        assert!(!tab.contains("a"));
        let unbounded = Tablet::new(None, None);
        assert!(unbounded.contains(""));
        assert!(unbounded.contains("zzz"));
    }

    #[test]
    fn scan_sorted_and_ranged() {
        let mut tab = Tablet::new(None, None);
        for (r, c) in [("b", "1"), ("a", "2"), ("c", "1"), ("a", "1")] {
            tab.put(t(r, c, "v"));
        }
        let mut all = Vec::new();
        tab.scan_into(None, None, &mut all);
        let keys: Vec<(String, String)> =
            all.iter().map(|t| (t.row.clone(), t.col.clone())).collect();
        assert_eq!(
            keys,
            vec![
                ("a".into(), "1".into()),
                ("a".into(), "2".into()),
                ("b".into(), "1".into()),
                ("c".into(), "1".into())
            ]
        );
        let mut ranged = Vec::new();
        tab.scan_into(Some("b"), Some("c"), &mut ranged);
        assert_eq!(ranged.len(), 1);
        assert_eq!(ranged[0].row, "b");
    }

    #[test]
    fn split_partitions_entries() {
        let mut tab = Tablet::new(None, None);
        for r in ["a", "b", "c", "d"] {
            tab.put(t(r, "c", "v"));
        }
        let median = tab.median_row().unwrap();
        assert_eq!(median, "c");
        let right = tab.split_at(&median);
        assert_eq!(tab.len(), 2);
        assert_eq!(right.len(), 2);
        assert_eq!(tab.hi.as_deref(), Some("c"));
        assert_eq!(right.lo.as_deref(), Some("c"));
        assert!(tab.contains("b") && !tab.contains("c"));
        assert!(right.contains("c") && right.contains("zzz"));
        // Weights are consistent with contents.
        let mut sum = 0;
        let mut out = Vec::new();
        tab.scan_into(None, None, &mut out);
        right.scan_into(None, None, &mut out);
        for tr in &out {
            sum += tr.weight();
        }
        assert_eq!(sum, tab.weight() + right.weight());
    }

    #[test]
    fn median_row_degenerate() {
        let mut tab = Tablet::new(None, None);
        assert!(tab.median_row().is_none());
        tab.put(t("a", "1", "v"));
        assert!(tab.median_row().is_none());
        // All cells in one row → no valid split point.
        tab.put(t("a", "2", "v"));
        tab.put(t("a", "3", "v"));
        assert!(tab.median_row().is_none());
    }
}
