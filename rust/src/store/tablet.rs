//! A tablet: one sorted key range of a table (the Accumulo unit of
//! distribution and recovery).
//!
//! Since PR 6 a tablet is an LSM level stack, not just a map: the
//! `BTreeMap` is the *memtable*, and beneath it sit zero or more
//! immutable sorted [`Run`]s produced by minor compaction
//! ([`Tablet::freeze`]). Reads and scans merge the layers newest-first
//! (memtable over newest run over older runs), with a tombstone set
//! masking run cells that were deleted after their run froze — the
//! Accumulo memory-map-plus-RFiles read path.

use super::cache::BlockCache;
use super::compact::{self, CompactionSpec};
use super::io::StorageIo;
use super::run::{Run, RunCell, RunCursor, RunWriter, TOMBSTONE};
use super::scan::{self, CellFilter, ScanRange};
use super::{SharedStr, Triple};
use crate::util::intern::StrDict;
use crate::util::retry::RetryPolicy;
use std::collections::{btree_map, btree_set, BTreeMap, BTreeSet};
use std::io;
use std::iter::Peekable;
use std::ops::Bound;
use std::path::Path;
use std::sync::Arc;

/// Sorted `(row, col) → val` map covering the half-open row range
/// `[lo, hi)` (`None` = unbounded on that side), stacked over the
/// tablet's frozen [`Run`]s. Cells are stored as shared-bytes
/// [`SharedStr`]s, so scanning one out is a pointer clone.
#[derive(Debug, Default)]
pub struct Tablet {
    /// Inclusive lower row bound (`None` = -∞).
    pub lo: Option<String>,
    /// Exclusive upper row bound (`None` = +∞).
    pub hi: Option<String>,
    entries: BTreeMap<(SharedStr, SharedStr), SharedStr>,
    /// Tombstones masking cells that live in `runs`: a delete that hits
    /// a run-resident cell cannot remove it (runs are immutable), so it
    /// records a marker here instead. Invariant: disjoint from
    /// `entries` (a put clears the key's tombstone), and empty while
    /// `runs` is empty (nothing to mask).
    deletes: BTreeSet<(SharedStr, SharedStr)>,
    /// Frozen immutable runs, oldest first / **newest last**. Shared
    /// (`Arc`) because a split clones the stack into both children and
    /// open scans pin a snapshot. Reads clamp each run to the tablet's
    /// extent so post-split children never double-serve cells.
    runs: Vec<Arc<Run>>,
    /// Cached frozen image of the memtable + tombstones (the sorted
    /// cell list [`Tablet::freeze_cells`] builds), shared into
    /// [`TabletSnapshot`]s so pinning a quiescent tablet is a handful
    /// of `Arc` clones. Point mutations (put/delete) don't discard it:
    /// they record the touched key in `frozen_stale`, and the next pin
    /// splices only those keys into the cached image — O(dirty · log)
    /// lookups plus one pointer-clone copy, instead of rebuilding from
    /// the `BTreeMap`. Structural changes (split, run attach/clear —
    /// run presence decides tombstone retention in the image) still
    /// invalidate fully.
    frozen_mem: Option<Arc<Vec<RunCell>>>,
    /// Keys written or deleted since `frozen_mem` was built — the dirty
    /// portion the next pin re-derives. Meaningless (and empty) while
    /// `frozen_mem` is `None`.
    frozen_stale: BTreeSet<(SharedStr, SharedStr)>,
    weight: usize,
    /// Failure-injection flag: an offline tablet rejects *writes*
    /// (`Table::write_batch` errors). Reads, scans, and compactions are
    /// still served — the scan stack treats offline as a write-side
    /// failure, and `tests/scan_stack.rs` pins that contract.
    pub offline: bool,
}

impl Tablet {
    /// New tablet covering `[lo, hi)`.
    pub fn new(lo: Option<String>, hi: Option<String>) -> Self {
        Tablet { lo, hi, ..Default::default() }
    }

    /// Whether `row` falls inside this tablet's extent.
    pub fn contains(&self, row: &str) -> bool {
        let above_lo = self.lo.as_deref().is_none_or(|lo| row >= lo);
        let below_hi = self.hi.as_deref().is_none_or(|hi| row < hi);
        above_lo && below_hi
    }

    /// Insert (overwriting any existing value). Returns the previous
    /// *memtable* value if the cell existed there (run-resident values
    /// are shadowed, not read back).
    pub fn put(&mut self, t: Triple) -> Option<SharedStr> {
        debug_assert!(self.contains(&t.row), "triple routed to wrong tablet");
        if self.frozen_mem.is_some() {
            self.frozen_stale.insert((t.row.clone(), t.col.clone()));
        }
        if !self.deletes.is_empty() {
            // A new write un-deletes the key (pointer-clone probe).
            self.deletes.remove(&(t.row.clone(), t.col.clone()));
        }
        let val_len = t.val.len();
        let full_weight = t.weight();
        let prev = self.entries.insert((t.row, t.col), t.val);
        match &prev {
            // Replacement: keys already counted, only the value delta.
            Some(old) => self.weight = self.weight - old.len() + val_len,
            None => self.weight += full_weight,
        }
        prev
    }

    /// Newest run-resident decision for `(row, col)`: `None` if no run
    /// stores the key, `Some(None)` if the newest storing run holds a
    /// tombstone, `Some(Some(val))` otherwise. Point ops skip extent
    /// clamping — routing guarantees the key is in-extent.
    fn run_lookup(&self, row: &str, col: &str) -> Option<Option<&SharedStr>> {
        self.runs.iter().rev().filter(|run| !run.is_poisoned()).find_map(|run| run.get(row, col))
    }

    /// Point lookup, merging memtable over tombstones over runs.
    pub fn get(&self, row: &str, col: &str) -> Option<&str> {
        if let Some(v) = self.entries.get(&(row.into(), col.into())) {
            return Some(v.as_str());
        }
        if self.runs.is_empty() || self.deletes.contains(&(row.into(), col.into())) {
            return None;
        }
        match self.run_lookup(row, col) {
            Some(Some(v)) => Some(v.as_str()),
            _ => None,
        }
    }

    /// Delete a cell; returns whether it was *visible* before (in the
    /// memtable, or live in a run and not already tombstoned). Removing
    /// only the memtable entry would resurrect any run-resident value
    /// beneath it, so when runs hold the key a tombstone is recorded.
    pub fn delete(&mut self, row: &str, col: &str) -> bool {
        if self.frozen_mem.is_some() {
            self.frozen_stale.insert((row.into(), col.into()));
        }
        let had_mem = if let Some(v) = self.entries.remove(&(row.into(), col.into())) {
            self.weight -= row.len() + col.len() + v.len();
            true
        } else {
            false
        };
        if self.runs.is_empty() {
            return had_mem;
        }
        let live_in_runs = matches!(self.run_lookup(row, col), Some(Some(_)));
        let newly_masked = live_in_runs && self.deletes.insert((row.into(), col.into()));
        had_mem || newly_masked
    }

    /// Scan rows in `[lo, hi)` (clamped to the tablet extent), in sorted
    /// order, appending to `out`.
    pub fn scan_into(&self, lo: Option<&str>, hi: Option<&str>, out: &mut Vec<Triple>) {
        let range = ScanRange {
            lo: lo.map(String::from),
            hi: hi.map(String::from),
            ..ScanRange::default()
        };
        let more = self.scan_block(None, std::slice::from_ref(&range), &[], usize::MAX, out);
        debug_assert!(more.is_none(), "an unbounded unfiltered scan_block must exhaust");
    }

    /// Whether this tablet's extent overlaps the row range of `range`.
    pub fn overlaps(&self, range: &ScanRange) -> bool {
        range.overlaps_extent(self.lo.as_deref(), self.hi.as_deref())
    }

    /// Copy up to `limit` in-range, filter-passing cells into `out`,
    /// resuming from `from = (row, col, inclusive)` (or the range-set
    /// start when `None`) — the primitive under the scan stack's block
    /// cursors. `ranges` is a sorted, coalesced range set
    /// ([`crate::store::scan::coalesce_ranges`]); the walk yields the
    /// sorted, deduplicated union of the per-range cells in one pass,
    /// hopping closed ranges *beneath* the block copy: when the walk
    /// leaves the last open range's row span it re-seeks the `BTreeMap`
    /// straight to the next range's start, so cells in the gaps between
    /// ranges cost one examined key each (the multi-range analogue of
    /// the column-window seek). Per containing range the column window
    /// `[col_lo, col_hi)` applies (a row whose windows are exhausted
    /// seeks directly to the next row), and `filters` are evaluated
    /// against `&str` borrows of the stored bytes *before* a `Triple`
    /// is built, so a rejected cell allocates nothing and never leaves
    /// the tablet. An emitted cell is three pointer clones of the
    /// stored [`SharedStr`]s.
    ///
    /// Returns `None` when no in-range cells remain past the copied
    /// block (the tablet is exhausted for this range set), or the
    /// resume key — the caller continues *exclusively after* it — when
    /// the block filled: either `limit` cells were emitted, or
    /// `max(limit, SCAN_BLOCK)` cells were examined. The examined cap
    /// keeps one call's lock hold bounded even when a selective filter
    /// rejects everything it walks (the cursors re-acquire locks
    /// between calls, so writers and splits interleave with filtered
    /// scans exactly as with plain ones).
    pub fn scan_block(
        &self,
        from: Option<(&str, &str, bool)>,
        ranges: &[ScanRange],
        filters: &[CellFilter],
        limit: usize,
        out: &mut Vec<Triple>,
    ) -> Option<(SharedStr, SharedStr)> {
        walk_block(|start| Merged::new(self, start), from, ranges, filters, limit, out)
    }

    /// Number of *visible* cells. With no runs this is the memtable
    /// length (O(1)); with runs it walks the merged view (O(cells)) so
    /// shadowed versions and tombstoned cells are not double-counted.
    pub fn len(&self) -> usize {
        if self.runs.is_empty() {
            return self.entries.len();
        }
        let mut merged = Merged::new(self, Bound::Unbounded);
        let mut n = 0usize;
        while merged.next().is_some() {
            n += 1;
        }
        n
    }

    /// True when the tablet serves no visible cells.
    pub fn is_empty(&self) -> bool {
        if self.runs.is_empty() {
            return self.entries.is_empty();
        }
        Merged::new(self, Bound::Unbounded).next().is_none()
    }

    /// Approximate stored bytes of the **memtable only** (the split and
    /// minor-compaction trigger). Frozen runs don't count: they are
    /// immutable, and the thresholds exist to bound mutable state.
    pub fn weight(&self) -> usize {
        self.weight
    }

    /// The median **memtable** row key — the split point used when this
    /// tablet grows past the size threshold. `None` for tablets with
    /// < 2 distinct memtable rows. Run-resident rows don't vote: splits
    /// exist to bound mutable state, and both children keep serving the
    /// shared runs clamped to their extents.
    pub fn median_row(&self) -> Option<String> {
        if self.entries.len() < 2 {
            return None;
        }
        let mid = self.entries.len() / 2;
        let (row, _) = self.entries.keys().nth(mid)?.clone();
        // Splitting at the first row would create an empty left tablet.
        let first = self.entries.keys().next().map(|(r, _)| r.clone())?;
        if row == first {
            return None;
        }
        Some(row.to_string())
    }

    /// Split at `row`: self keeps `[lo, row)`, the returned tablet holds
    /// `[row, hi)`. Both children share the run stack (`Arc` clones);
    /// extent clamping keeps each child serving only its half of every
    /// run.
    pub fn split_at(&mut self, row: &str) -> Tablet {
        self.invalidate_frozen();
        let right_entries: BTreeMap<(SharedStr, SharedStr), SharedStr> =
            self.entries.split_off(&(row.into(), "".into()));
        let right_deletes = self.deletes.split_off(&(row.into(), "".into()));
        let right_weight: usize =
            right_entries.iter().map(|((r, c), v)| r.len() + c.len() + v.len()).sum();
        self.weight -= right_weight;
        let right = Tablet {
            lo: Some(row.to_string()),
            hi: self.hi.take(),
            entries: right_entries,
            deletes: right_deletes,
            runs: self.runs.clone(),
            frozen_mem: None,
            frozen_stale: BTreeSet::new(),
            weight: right_weight,
            offline: false,
        };
        self.hi = Some(row.to_string());
        right
    }

    /// The tablet's frozen runs, oldest first (shared snapshots).
    pub(crate) fn runs(&self) -> &[Arc<Run>] {
        &self.runs
    }

    /// Attach an already-built run as the newest layer below the
    /// memtable — the recovery path ([`super::Table::recover`] loads
    /// run files oldest-to-newest and stacks them here).
    pub(crate) fn attach_run(&mut self, run: Arc<Run>) {
        // Run presence decides whether the frozen image keeps
        // tombstones, so the layer change invalidates the cache too.
        self.invalidate_frozen();
        self.runs.push(run);
    }

    /// Detach every poisoned run (one whose block-granular reads hit a
    /// CRC or I/O failure) from the serving stack, returning them for
    /// the caller to quarantine on disk. New scans already skip
    /// poisoned runs; this makes the pruning durable. Invalidates the
    /// frozen image only when something was actually dropped (run
    /// presence decides tombstone retention).
    pub(crate) fn drop_poisoned(&mut self) -> Vec<Arc<Run>> {
        if !self.runs.iter().any(|run| run.is_poisoned()) {
            return Vec::new();
        }
        self.invalidate_frozen();
        let (bad, good): (Vec<_>, Vec<_>) =
            self.runs.drain(..).partition(|run| run.is_poisoned());
        self.runs = good;
        bad
    }

    /// Drop the cached frozen image and its dirty-key overlay. Called
    /// by every *structural* change; point writes go through
    /// `frozen_stale` instead.
    fn invalidate_frozen(&mut self) {
        self.frozen_mem = None;
        self.frozen_stale.clear();
    }

    /// Merge the memtable and tombstones into a sorted cell list
    /// (values `None` for tombstones) **without mutating the tablet** —
    /// the build half of the build/persist/commit compaction protocol.
    /// Tombstones are kept only when `keep_tombstones` (they mask older
    /// runs; with no older layer they mask nothing). Cells are pointer
    /// clones of the stored [`SharedStr`]s.
    fn memtable_cells(&self, keep_tombstones: bool) -> Vec<RunCell> {
        let mut cells: Vec<RunCell> =
            Vec::with_capacity(self.entries.len() + self.deletes.len());
        let mut ents = self.entries.iter().peekable();
        let mut dels = self.deletes.iter().peekable();
        loop {
            // Disjoint sorted sequences (the put/delete invariant), so
            // a plain two-pointer merge keeps (row, col) order.
            let take_entry = match (ents.peek(), dels.peek()) {
                (Some((ek, _)), Some(dk)) => *ek < *dk,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (None, None) => break,
            };
            if take_entry {
                let ((r, c), v) = ents.next().expect("peeked");
                cells.push((r.clone(), c.clone(), Some(v.clone())));
            } else {
                let (r, c) = dels.next().expect("peeked");
                if keep_tombstones {
                    cells.push((r.clone(), c.clone(), None));
                }
            }
        }
        cells
    }

    /// Drop the memtable state (entries, tombstones, weight). The
    /// commit half of a freeze — call only after the frozen run has
    /// been durably persisted (or when provably empty).
    fn clear_memtable(&mut self) {
        self.invalidate_frozen();
        self.entries.clear();
        self.deletes.clear();
        self.weight = 0;
    }

    /// Build the cell list a minor compaction would freeze, without
    /// touching tablet state. Returns an empty list when there is
    /// nothing worth freezing (dangling tombstones with no runs beneath
    /// them mask nothing and are not freezable content).
    pub(crate) fn freeze_cells(&self) -> Vec<RunCell> {
        self.memtable_cells(!self.runs.is_empty())
    }

    /// Commit a successful freeze: clear the memtable and stack `run`
    /// as the newest layer. The caller guarantees `run` was built from
    /// [`Tablet::freeze_cells`] on this exact state and has been
    /// persisted (when durability is in play) — a failed persist must
    /// *not* call this, leaving the tablet untouched and re-runnable.
    pub(crate) fn complete_freeze(&mut self, run: Arc<Run>) {
        self.clear_memtable();
        self.runs.push(run);
    }

    /// Build the fully-merged cell list a major compaction would write,
    /// applying `spec`'s combiner and max-versions rule, without
    /// touching tablet state — the build half of
    /// [`Tablet::install_compacted`].
    pub(crate) fn compact_cells(&self, spec: &CompactionSpec) -> Vec<RunCell> {
        // Collect every stored version, newest layer first: memtable
        // (with its tombstones), then runs newest → oldest, each
        // clamped to the extent. A stable key-only sort then groups
        // versions while preserving that priority order.
        let mut cells = self.memtable_cells(true);
        for run in self.runs.iter().rev().filter(|run| !run.is_poisoned()) {
            let (start, end) = run.extent_range(self.lo.as_deref(), self.hi.as_deref());
            for i in start..end {
                let (r, c) = run.key(i);
                cells.push((r.clone(), c.clone(), run.val(i).cloned()));
            }
        }
        cells.sort_by(|a, b| (a.0.as_str(), a.1.as_str()).cmp(&(b.0.as_str(), b.1.as_str())));
        compact::merge_cells(cells, spec)
    }

    /// Commit a successful major compaction: drop the memtable and the
    /// whole run stack, installing `run` (built from
    /// [`Tablet::compact_cells`] on this exact state) as the only
    /// layer — or nothing when the merge came out empty. As with
    /// [`Tablet::complete_freeze`], a failed persist skips this call
    /// and the tablet keeps serving its old layers.
    pub(crate) fn install_compacted(&mut self, run: Option<Arc<Run>>) {
        self.clear_memtable();
        self.runs.clear();
        if let Some(run) = run {
            self.runs.push(run);
        }
    }

    /// Minor compaction: freeze the memtable (and tombstone set) into a
    /// new immutable run stacked as the newest layer. Returns the run
    /// (for the caller to persist), or `None` when there was nothing to
    /// freeze. `seq` names the run; `watermark` is the WAL sequence
    /// number its contents cover. In-memory path: build and commit in
    /// one step (durable tables persist between the two halves via
    /// `Tablet::freeze_cells` / `Tablet::complete_freeze`).
    pub fn freeze(&mut self, seq: u64, watermark: u64) -> Option<Arc<Run>> {
        let cells = self.freeze_cells();
        if cells.is_empty() {
            // Nothing freezable; dangling tombstones (if any) mask
            // nothing and are dropped with the memtable.
            self.clear_memtable();
            return None;
        }
        let run = Arc::new(Run::from_cells(seq, watermark, &cells));
        self.complete_freeze(Arc::clone(&run));
        Some(run)
    }

    /// Major compaction: merge the memtable and **all** runs into one
    /// fresh run, applying `spec`'s combiner and max-versions rule at
    /// merge time (Accumulo's versioning iterator). This is a *full*
    /// compaction over the tablet's whole extent, so surviving
    /// tombstones are dropped — nothing older exists for them to mask.
    /// Returns the merged run (`None` if the tablet ends up empty; its
    /// run stack is cleared either way).
    pub fn compact(&mut self, spec: &CompactionSpec, seq: u64, watermark: u64) -> Option<Arc<Run>> {
        let merged = self.compact_cells(spec);
        if merged.is_empty() {
            self.install_compacted(None);
            return None;
        }
        let run = Arc::new(Run::from_cells(seq, watermark, &merged));
        self.install_compacted(Some(Arc::clone(&run)));
        Some(run)
    }

    /// Number of *stored* versions of `(row, col)` across the memtable
    /// and every run (tombstones included, shadowing ignored) — the
    /// retention witness for the max-versions compaction rule.
    pub fn cell_versions(&self, row: &str, col: &str) -> usize {
        let mem = usize::from(self.entries.contains_key(&(row.into(), col.into())))
            + usize::from(self.deletes.contains(&(row.into(), col.into())));
        mem + self
            .runs
            .iter()
            .filter(|run| !run.is_poisoned())
            .map(|run| run.versions(row, col))
            .sum::<usize>()
    }

    /// Pin the tablet's current state as an immutable
    /// [`TabletSnapshot`]: the run stack is `Arc`-cloned, and the
    /// memtable (entries + tombstones) is frozen into a shared sorted
    /// cell list. The frozen image is cached on the tablet, so pinning
    /// a tablet that hasn't been written since the last pin is a
    /// handful of `Arc` clones — the common case for scan-heavy
    /// workloads. Mutations invalidate the cache; they never touch an
    /// already-pinned snapshot.
    pub(crate) fn snapshot(&mut self) -> TabletSnapshot {
        let mem = if self.entries.is_empty() && self.deletes.is_empty() {
            // Deletes may have drained the memtable key-by-key while an
            // image was cached; the image is stale and worthless now.
            self.invalidate_frozen();
            None
        } else {
            let image = match (&self.frozen_mem, self.frozen_stale.is_empty()) {
                // Quiet re-pin: pure Arc clone, no rebuild at all.
                (Some(img), true) => Arc::clone(img),
                // Dirty re-pin: splice only the touched keys into the
                // cached image — O(dirty) map probes, one linear copy.
                (Some(img), false) => Arc::new(self.splice_frozen(img)),
                // Cold pin: full rebuild from the BTreeMap.
                (None, _) => Arc::new(self.freeze_cells()),
            };
            self.frozen_mem = Some(Arc::clone(&image));
            self.frozen_stale.clear();
            Some(image)
        };
        TabletSnapshot {
            lo: self.lo.clone(),
            hi: self.hi.clone(),
            runs: self.runs.clone(),
            mem,
        }
    }

    /// Rebuild only the dirty portion of a cached frozen image: walk
    /// `base` and the sorted stale-key set with two pointers, replacing
    /// each stale key's cell with its current memtable state (value,
    /// tombstone, or absent). Clean stretches are copied as pointer
    /// clones. Equivalent to [`Tablet::freeze_cells`] by construction:
    /// every key not in `frozen_stale` is unchanged since `base` was
    /// built, and run presence (which decides tombstone retention)
    /// can't have changed — structural ops fully invalidate.
    fn splice_frozen(&self, base: &[RunCell]) -> Vec<RunCell> {
        let keep_tombstones = !self.runs.is_empty();
        let mut out: Vec<RunCell> = Vec::with_capacity(base.len() + self.frozen_stale.len());
        let mut bi = 0usize;
        for key in &self.frozen_stale {
            let k = (key.0.as_str(), key.1.as_str());
            // Copy the clean cells strictly before the stale key, then
            // drop the superseded image cell for the key itself.
            let upto = bi + base[bi..].partition_point(|(r, c, _)| (r.as_str(), c.as_str()) < k);
            out.extend_from_slice(&base[bi..upto]);
            bi = upto;
            if bi < base.len() && (base[bi].0.as_str(), base[bi].1.as_str()) == k {
                bi += 1;
            }
            if let Some(v) = self.entries.get(key) {
                out.push((key.0.clone(), key.1.clone(), Some(v.clone())));
            } else if keep_tombstones && self.deletes.contains(key) {
                out.push((key.0.clone(), key.1.clone(), None));
            }
        }
        out.extend_from_slice(&base[bi..]);
        out
    }

    /// Streaming major compaction for paged (block-cached) tablets:
    /// produce exactly the triples [`Tablet::compact_cells`] +
    /// [`Run::from_cells`] would, but never materialise more than one
    /// key-group of input cells, one output block, and the output
    /// string pool — peak memory is O(blocks in flight), not O(table).
    ///
    /// Two passes over the same immutable state: pass 1 merges and
    /// interns every *output* string into a [`StrDict`] (ids must be
    /// assigned in sorted order before any block is written); pass 2
    /// re-merges and streams encoded blocks through a [`RunWriter`].
    /// Each source run's cursor pins at most one cache block at a
    /// time.
    ///
    /// If any source run is poisoned by a block fault mid-merge the
    /// compaction aborts with an error *before* commit — the tmp file
    /// is left for orphan GC and the tablet keeps serving its old
    /// layers, exactly like a failed persist. Returns the reopened
    /// (paged) output run, or `None` when the merge came out empty.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn compact_streamed(
        &self,
        spec: &CompactionSpec,
        seq: u64,
        watermark: u64,
        io: &Arc<dyn StorageIo>,
        path: &Path,
        cache: &Arc<BlockCache>,
        retry: &RetryPolicy,
        block_triples: usize,
    ) -> io::Result<Option<Arc<Run>>> {
        let fault = || {
            io::Error::new(
                io::ErrorKind::InvalidData,
                "block fault while streaming compaction; source run poisoned",
            )
        };
        let mem = self.memtable_cells(true);

        // Pass 1: intern the strings the merged output will reference.
        let mut dict = StrDict::default();
        let mut total = 0usize;
        self.for_each_compacted_row(&mem, spec, |row| {
            for (r, c, v) in &row {
                dict.intern_str(r.as_str());
                dict.intern_str(c.as_str());
                if let Some(v) = v {
                    dict.intern_str(v.as_str());
                }
            }
            total += row.len();
        });
        if self.runs.iter().any(|run| run.is_poisoned()) {
            return Err(fault());
        }
        if total == 0 {
            return Ok(None);
        }
        let (pool, _ids) = dict.into_sorted();

        // Pass 2: re-merge (same immutable inputs, same output) and
        // stream blocks through the writer.
        let mut writer = retry.run("run create", || {
            RunWriter::create(&**io, path, seq, watermark, pool.clone(), block_triples)
        })?;
        let mut stream_err: Option<io::Error> = None;
        self.for_each_compacted_row(&mem, spec, |row| {
            if stream_err.is_some() {
                return;
            }
            for (r, c, v) in &row {
                // A block fault in pass 2 only can shrink a combined
                // row to a value pass 1 never interned — map the
                // missing id to a fault instead of panicking.
                let ids = (|| {
                    let ri = writer.id_of(r.as_str())?;
                    let ci = writer.id_of(c.as_str())?;
                    let vi = match v {
                        Some(v) => writer.id_of(v.as_str())?,
                        None => TOMBSTONE,
                    };
                    Some((ri, ci, vi))
                })();
                let Some((ri, ci, vi)) = ids else {
                    stream_err = Some(fault());
                    return;
                };
                if let Err(e) = writer.push(ri, ci, vi) {
                    stream_err = Some(e);
                    return;
                }
            }
        });
        if let Some(e) = stream_err {
            return Err(e);
        }
        if self.runs.iter().any(|run| run.is_poisoned()) {
            return Err(fault());
        }
        let written = writer.finish(&**io, path)?;
        debug_assert_eq!(written, total, "pass 1 / pass 2 merge divergence");
        let run = retry.run("run open", || {
            Run::open_with(Arc::clone(io), path, Arc::clone(cache), retry.clone())
        })?;
        Ok(Some(Arc::new(run)))
    }

    /// Shared merge engine for [`Tablet::compact_streamed`]: visit each
    /// fully-compacted row (post `spec` combiner/versioning) in key
    /// order, materialising only one key-group at a time. Version
    /// priority matches [`Tablet::compact_cells`] exactly — memtable
    /// first, then runs newest → oldest, each clamped to the extent —
    /// so per-row [`compact::merge_cells`] (key groups are independent
    /// and row reduction is row-local) equals the whole-table call.
    fn for_each_compacted_row(
        &self,
        mem: &[RunCell],
        spec: &CompactionSpec,
        mut sink: impl FnMut(Vec<RunCell>),
    ) {
        let mut curs: Vec<RunCursor<'_>> = self
            .runs
            .iter()
            .rev()
            .filter(|run| !run.is_poisoned())
            .map(|run| {
                let (start, end) = run.extent_range(self.lo.as_deref(), self.hi.as_deref());
                RunCursor::new(run, start, end)
            })
            .collect();
        let mut mi = 0usize;
        let mut cur_row: Option<SharedStr> = None;
        let mut row_cells: Vec<RunCell> = Vec::new();
        loop {
            // Smallest (row, col) still pending across the memtable
            // image and every cursor. Cursor peeks borrow from the runs
            // (not the cursors), so the key survives advancing below.
            let mut min: Option<(&str, &str)> = None;
            if let Some((r, c, _)) = mem.get(mi) {
                min = Some((r.as_str(), c.as_str()));
            }
            for cur in &curs {
                if let Some((r, c, _)) = cur.peek() {
                    let k = (r.as_str(), c.as_str());
                    if min.is_none_or(|m| k < m) {
                        min = Some(k);
                    }
                }
            }
            let Some(min) = min else { break };
            if cur_row.as_ref().map(|r| r.as_str()) != Some(min.0) {
                if !row_cells.is_empty() {
                    sink(compact::merge_cells(std::mem::take(&mut row_cells), spec));
                }
                cur_row = None; // set from the first cell pushed below
            }
            // Gather every version of the min key, newest layer first.
            if let Some((r, c, v)) = mem.get(mi) {
                if (r.as_str(), c.as_str()) == min {
                    cur_row.get_or_insert_with(|| r.clone());
                    row_cells.push((r.clone(), c.clone(), v.clone()));
                    mi += 1;
                }
            }
            for cur in &mut curs {
                while let Some((r, c, v)) = cur.peek() {
                    if (r.as_str(), c.as_str()) != min {
                        break;
                    }
                    cur_row.get_or_insert_with(|| r.clone());
                    row_cells.push((r.clone(), c.clone(), v.cloned()));
                    cur.advance_one();
                }
            }
        }
        if !row_cells.is_empty() {
            sink(compact::merge_cells(row_cells, spec));
        }
    }
}

/// An immutable, cheaply-clonable image of one tablet's state at pin
/// time: the `Arc`-shared run stack plus a frozen sorted image of the
/// memtable and its tombstones. Scans over a snapshot
/// (`TabletSnapshot::scan_block`) serve exactly what the tablet
/// served at pin time and acquire **no locks** — writers, splits, and
/// compactions mutate the live tablet without disturbing pinned
/// snapshots (the Accumulo scan-time isolation contract).
#[derive(Debug, Clone)]
pub struct TabletSnapshot {
    /// Inclusive lower row bound at pin time (`None` = -∞).
    pub lo: Option<String>,
    /// Exclusive upper row bound at pin time (`None` = +∞).
    pub hi: Option<String>,
    runs: Vec<Arc<Run>>,
    /// Frozen memtable image (sorted, tombstones as `None` values), or
    /// `None` when the memtable was empty at pin time.
    mem: Option<Arc<Vec<RunCell>>>,
}

impl TabletSnapshot {
    /// Lock-free equivalent of [`Tablet::scan_block`] over the pinned
    /// state — same contract (resume keys, range hops, column windows,
    /// filter pushdown, examined-cells yield discipline), same shared
    /// walk engine, zero lock acquisitions.
    pub(crate) fn scan_block(
        &self,
        from: Option<(&str, &str, bool)>,
        ranges: &[ScanRange],
        filters: &[CellFilter],
        limit: usize,
        out: &mut Vec<Triple>,
    ) -> Option<(SharedStr, SharedStr)> {
        walk_block(|start| LayerMerge::new(self, start), from, ranges, filters, limit, out)
    }

    /// Estimated number of stored cells with row `< row` (`None` = all
    /// cells) — the load-balancing weight for per-range-chunk fan-out.
    /// Counts stored (not visible) cells: shadowed versions and
    /// tombstones inflate the estimate slightly, which only skews chunk
    /// weights, never results.
    pub(crate) fn cells_upto(&self, row: Option<&str>) -> usize {
        let mut n = 0;
        for run in &self.runs {
            let (start, end) = run.extent_range(self.lo.as_deref(), self.hi.as_deref());
            let cut = match row {
                Some(rw) => run.extent_range(self.lo.as_deref(), Some(rw)).1.clamp(start, end),
                None => end,
            };
            n += cut - start;
        }
        if let Some(mem) = &self.mem {
            n += match row {
                Some(rw) => mem.partition_point(|(r, _, _)| r.as_str() < rw),
                None => mem.len(),
            };
        }
        n
    }

    /// One `(seq, len, dict_len)` summary per pinned run — the raw
    /// material for [`crate::store::TableStats`]. Post-split siblings
    /// share runs by `Arc`, so callers dedup by `seq` before summing
    /// run-level figures table-wide.
    pub(crate) fn run_summaries(&self) -> impl Iterator<Item = (u64, usize, usize)> + '_ {
        self.runs.iter().map(|r| (r.seq(), r.len(), r.dict_len()))
    }

    /// Append up to `per_run - 1` evenly-strided row keys from each
    /// layer to `out` — candidate cut points for range chunking.
    /// Samples fall strictly inside the layer's extent, so every
    /// returned row is a valid half-open boundary.
    pub(crate) fn sample_rows(&self, per_run: usize, out: &mut Vec<String>) {
        if per_run < 2 {
            return;
        }
        for run in &self.runs {
            let (start, end) = run.extent_range(self.lo.as_deref(), self.hi.as_deref());
            let n = end - start;
            for j in 1..per_run {
                let idx = start + n * j / per_run;
                if idx > start && idx < end {
                    // Index-resolution sampling: on a paged run this
                    // answers from the block index's first keys and
                    // never faults a block in.
                    out.push(run.sample_row(idx).as_str().to_string());
                }
            }
        }
        if let Some(mem) = &self.mem {
            let n = mem.len();
            for j in 1..per_run {
                let idx = n * j / per_run;
                if idx > 0 && idx < n {
                    out.push(mem[idx].0.as_str().to_string());
                }
            }
        }
    }
}

/// A merged forward walk over some layered cell source, yielding
/// visible cells in `(row, col)` order with lifetime `'t` borrows into
/// the underlying storage. The two implementors are [`Merged`] (live
/// tablet: `BTreeMap` memtable + tombstone set + runs) and
/// [`LayerMerge`] (pinned [`TabletSnapshot`]: frozen memtable image +
/// runs). [`walk_block`] is generic over this trait, so the live
/// locked path and the lock-free snapshot path share one block-walk
/// engine — every range-hop/window/filter/cap behavior is identical by
/// construction.
trait MergeWalk<'t> {
    /// Next visible cell, or `None` when every layer is exhausted.
    fn next(&mut self) -> Option<(&'t SharedStr, &'t SharedStr, &'t SharedStr)>;
}

/// The block-walk engine shared by [`Tablet::scan_block`] and
/// [`TabletSnapshot::scan_block`]: copy up to `limit` in-range,
/// filter-passing cells into `out`, resuming from `from` (or the
/// range-set start). `make` builds a fresh merged walk from a start
/// bound — called once up front and again after each internal re-seek
/// (column-window hop or inter-range gap hop). See
/// [`Tablet::scan_block`] for the full contract; this function *is*
/// that contract, for both walk sources.
fn walk_block<'t, M: MergeWalk<'t>>(
    make: impl Fn(Bound<(SharedStr, SharedStr)>) -> M,
    from: Option<(&str, &str, bool)>,
    ranges: &[ScanRange],
    filters: &[CellFilter],
    limit: usize,
    out: &mut Vec<Triple>,
) -> Option<(SharedStr, SharedStr)> {
    debug_assert!(limit > 0, "scan_block needs room to make progress");
    // The walk's monotonic range advance and gap hops assume the
    // set is sorted by row lower bound — hand-built `ScanSpec`s
    // that bypass `ScanSpec::ranges()` would otherwise silently
    // drop cells.
    debug_assert!(
        ranges.windows(2).all(|w| w[0].lo <= w[1].lo),
        "scan_block needs a lo-sorted range set (build specs via ScanSpec::ranges)"
    );
    if ranges.is_empty() {
        return None;
    }
    let examine_cap = limit.max(scan::SCAN_BLOCK);
    let mut start: Bound<(SharedStr, SharedStr)> = match from {
        Some((r, c, true)) => Bound::Included((r.into(), c.into())),
        Some((r, c, false)) => Bound::Excluded((r.into(), c.into())),
        None => match ranges[0].lo.as_deref() {
            Some(lo) => Bound::Included((lo.into(), scan::start_col(ranges, lo).into())),
            None => Bound::Unbounded,
        },
    };
    // First range whose row span may still lie ahead; rows only
    // move forward, so this never rewinds.
    let mut ri = 0usize;
    let mut emitted = 0usize;
    let mut examined = 0usize;
    loop {
        // Re-seeks happen when a row's column windows close or the
        // walk falls in a gap between ranges (cells the reseek
        // jumps over are never examined). The walk itself runs over
        // the merged view: memtable over tombstones over runs
        // (newest run wins), so a block is the same sorted stream a
        // pure-memtable tablet would serve.
        let mut reseek: Option<(SharedStr, SharedStr)> = None;
        let mut merged = make(start);
        while let Some((r, c, v)) = merged.next() {
            while ri < ranges.len()
                && ranges[ri].hi.as_deref().is_some_and(|hi| r.as_str() >= hi)
            {
                ri += 1;
            }
            if ri == ranges.len() {
                // Past every range: exhausted.
                return None;
            }
            examined += 1;
            if let Some(lo) = ranges[ri].lo.as_deref() {
                if r.as_str() < lo {
                    // In the gap before the next range: hop to its
                    // start beneath the copy.
                    if examined >= examine_cap {
                        return Some((r.clone(), c.clone()));
                    }
                    reseek = Some((lo.into(), scan::start_col(&ranges[ri..], lo).into()));
                    break;
                }
            }
            // The row is inside at least one range. Column
            // decision over every range containing it: in any
            // window → candidate; below every open window → hop to
            // the nearest window start; past them all → next row.
            let mut in_window = false;
            let mut next_col: Option<&str> = None;
            for rg in &ranges[ri..] {
                if rg.lo.as_deref().is_some_and(|lo| r.as_str() < lo) {
                    break;
                }
                if rg.hi.as_deref().is_some_and(|hi| r.as_str() >= hi) {
                    continue;
                }
                let below = rg.col_lo.as_deref().is_some_and(|cl| c.as_str() < cl);
                let above = rg.col_hi.as_deref().is_some_and(|ch| c.as_str() >= ch);
                if !below && !above {
                    in_window = true;
                    break;
                }
                if below {
                    let cl = rg.col_lo.as_deref().expect("below implies a lower bound");
                    if next_col.is_none_or(|n| cl < n) {
                        next_col = Some(cl);
                    }
                }
            }
            if !in_window {
                if examined >= examine_cap {
                    // The cap bounds window-skip and gap walks too:
                    // a reseek-per-row stride must not extend this
                    // lock hold (on the snapshot path it is only a
                    // yield point, but the discipline is shared).
                    return Some((r.clone(), c.clone()));
                }
                match next_col {
                    // A window opens later in this row.
                    Some(nc) => reseek = Some((r.clone(), nc.into())),
                    // Every window on this row is done: jump to the
                    // next row's window start.
                    None => {
                        let mut next_row = r.to_string();
                        next_row.push('\0');
                        let col = scan::start_col(&ranges[ri..], &next_row);
                        reseek = Some((next_row.into(), col.into()));
                    }
                }
                break;
            }
            // Rejected beneath the copy: no allocation.
            if filters.iter().all(|f| f.matches_parts(r, c, v)) {
                out.push(Triple { row: r.clone(), col: c.clone(), val: v.clone() });
                emitted += 1;
            }
            if emitted == limit || examined >= examine_cap {
                // Caller resumes after the last examined key.
                return Some((r.clone(), c.clone()));
            }
        }
        match reseek {
            Some(key) => start = Bound::Included(key),
            None => return None,
        }
    }
}

/// Merged forward walk over a tablet's layers from a start bound:
/// memtable over tombstones over runs (newest run wins), yielding only
/// *visible* cells in `(row, col)` order. Borrowed views live as long
/// as the tablet borrow (`'t`), so the caller can hold a yielded cell
/// while the walk advances.
struct Merged<'t> {
    mem: Peekable<btree_map::Range<'t, (SharedStr, SharedStr), SharedStr>>,
    del: Peekable<btree_set::Range<'t, (SharedStr, SharedStr)>>,
    runs: Vec<RunCursor<'t>>,
    /// No runs → the walk is exactly the memtable range (fast path: no
    /// per-cell key comparisons).
    simple: bool,
}

impl<'t> Merged<'t> {
    fn new(tablet: &'t Tablet, start: Bound<(SharedStr, SharedStr)>) -> Merged<'t> {
        let simple = tablet.runs.is_empty();
        // The run cursors need the bound as (row, col, inclusive); an
        // exclusive resume skips the key's whole version group (every
        // version is superseded once the key was served).
        let probe: Option<(SharedStr, SharedStr, bool)> = match &start {
            Bound::Included((r, c)) => Some((r.clone(), c.clone(), true)),
            Bound::Excluded((r, c)) => Some((r.clone(), c.clone(), false)),
            Bound::Unbounded => None,
        };
        let mut runs = Vec::with_capacity(tablet.runs.len());
        if !simple {
            // A poisoned run (block-level CRC/I/O failure) is served as
            // table-minus-run until it is swept — same contract as the
            // whole-run corruption path.
            for run in tablet.runs.iter().filter(|run| !run.is_poisoned()) {
                let (ext_start, ext_end) =
                    run.extent_range(tablet.lo.as_deref(), tablet.hi.as_deref());
                let pos = match &probe {
                    Some((r, c, inclusive)) => {
                        run.lower_bound(r, c, *inclusive).max(ext_start)
                    }
                    None => ext_start,
                };
                runs.push(RunCursor::new(run, pos, ext_end));
            }
        }
        Merged {
            mem: tablet.entries.range((start.clone(), Bound::Unbounded)).peekable(),
            del: tablet.deletes.range((start, Bound::Unbounded)).peekable(),
            runs,
            simple,
        }
    }
}

impl<'t> MergeWalk<'t> for Merged<'t> {
    fn next(&mut self) -> Option<(&'t SharedStr, &'t SharedStr, &'t SharedStr)> {
        if self.simple {
            return self.mem.next().map(|((r, c), v)| (r, c, v));
        }
        loop {
            // Peeked items are tuples of `Copy` references with
            // lifetime `'t`, so `.copied()` escapes the peek borrow.
            let mem_peek = self.mem.peek().copied();
            let del_peek = self.del.peek().copied();
            let mut min: Option<(&'t str, &'t str)> = None;
            let mut consider = |key: (&'t str, &'t str), min: &mut Option<(&'t str, &'t str)>| {
                if min.is_none_or(|m| key < m) {
                    *min = Some(key);
                }
            };
            if let Some(((r, c), _)) = mem_peek {
                consider((r.as_str(), c.as_str()), &mut min);
            }
            if let Some((r, c)) = del_peek {
                consider((r.as_str(), c.as_str()), &mut min);
            }
            for cur in &self.runs {
                if let Some((r, c, _)) = cur.peek() {
                    consider((r.as_str(), c.as_str()), &mut min);
                }
            }
            let min = min?;
            // Advance every run cursor sitting on the min key (each
            // skips its whole version group) so no layer serves a
            // shadowed version later. The peeked refs point into the
            // runs' pools ('t), not into the cursors, so they survive
            // the advance.
            let mut run_winner: Option<(&'t SharedStr, &'t SharedStr, Option<&'t SharedStr>)> =
                None;
            for cur in &mut self.runs {
                if let Some((r, c, v)) = cur.peek() {
                    if (r.as_str(), c.as_str()) == min {
                        // Iterating oldest → newest: the last hit is the
                        // newest run's decision.
                        run_winner = Some((r, c, v));
                        cur.advance_key();
                    }
                }
            }
            if let Some(((r, c), v)) = mem_peek {
                if (r.as_str(), c.as_str()) == min {
                    self.mem.next();
                    return Some((r, c, v));
                }
            }
            if let Some((r, c)) = del_peek {
                if (r.as_str(), c.as_str()) == min {
                    // Tombstone: the key is deleted; skip it.
                    self.del.next();
                    continue;
                }
            }
            match run_winner {
                Some((r, c, Some(v))) => return Some((r, c, v)),
                // Newest run version is a tombstone: skip the key.
                // (`None` is unreachable — the min key came from some
                // layer — but skipping is the safe decode.)
                _ => continue,
            }
        }
    }
}

/// Merged forward walk over a [`TabletSnapshot`]'s layers: the frozen
/// memtable image (entries and tombstones already interleaved in one
/// sorted list) over the runs, newest run winning. The lock-free
/// counterpart of [`Merged`]; borrows live as long as the snapshot
/// borrow (`'s`).
struct LayerMerge<'s> {
    /// Frozen memtable image; tombstones are `None` values. Keys are
    /// unique (the put/delete invariant keeps entries and tombstones
    /// disjoint), so one cursor position suffices.
    mem: &'s [RunCell],
    mem_pos: usize,
    runs: Vec<RunCursor<'s>>,
}

impl<'s> LayerMerge<'s> {
    fn new(snap: &'s TabletSnapshot, start: Bound<(SharedStr, SharedStr)>) -> LayerMerge<'s> {
        // The run cursors need the bound as (row, col, inclusive); an
        // exclusive resume skips the key's whole version group (every
        // version is superseded once the key was served).
        let probe: Option<(&SharedStr, &SharedStr, bool)> = match &start {
            Bound::Included((r, c)) => Some((r, c, true)),
            Bound::Excluded((r, c)) => Some((r, c, false)),
            Bound::Unbounded => None,
        };
        let mut runs = Vec::with_capacity(snap.runs.len());
        // New merges skip runs already poisoned; a fault *during* this
        // merge instead exhausts that run's cursor (and poisons the run
        // for later merges) — reads never panic or block.
        for run in snap.runs.iter().filter(|run| !run.is_poisoned()) {
            let (ext_start, ext_end) =
                run.extent_range(snap.lo.as_deref(), snap.hi.as_deref());
            let pos = match probe {
                Some((r, c, inclusive)) => run.lower_bound(r, c, inclusive).max(ext_start),
                None => ext_start,
            };
            runs.push(RunCursor::new(run, pos, ext_end));
        }
        let mem: &'s [RunCell] = snap.mem.as_deref().map_or(&[], Vec::as_slice);
        let mem_pos = match probe {
            Some((r, c, true)) => mem.partition_point(|(mr, mc, _)| {
                (mr.as_str(), mc.as_str()) < (r.as_str(), c.as_str())
            }),
            Some((r, c, false)) => mem.partition_point(|(mr, mc, _)| {
                (mr.as_str(), mc.as_str()) <= (r.as_str(), c.as_str())
            }),
            None => 0,
        };
        LayerMerge { mem, mem_pos, runs }
    }
}

impl<'s> MergeWalk<'s> for LayerMerge<'s> {
    fn next(&mut self) -> Option<(&'s SharedStr, &'s SharedStr, &'s SharedStr)> {
        loop {
            let mem_peek: Option<&'s RunCell> = self.mem.get(self.mem_pos);
            let mut min: Option<(&'s str, &'s str)> = None;
            let mut consider = |key: (&'s str, &'s str), min: &mut Option<(&'s str, &'s str)>| {
                if min.is_none_or(|m| key < m) {
                    *min = Some(key);
                }
            };
            if let Some((r, c, _)) = mem_peek {
                consider((r.as_str(), c.as_str()), &mut min);
            }
            for cur in &self.runs {
                if let Some((r, c, _)) = cur.peek() {
                    consider((r.as_str(), c.as_str()), &mut min);
                }
            }
            let min = min?;
            // Advance every run cursor sitting on the min key (each
            // skips its whole version group) so no layer serves a
            // shadowed version later; iterating oldest → newest makes
            // the last hit the newest run's decision.
            let mut run_winner: Option<(&'s SharedStr, &'s SharedStr, Option<&'s SharedStr>)> =
                None;
            for cur in &mut self.runs {
                if let Some((r, c, v)) = cur.peek() {
                    if (r.as_str(), c.as_str()) == min {
                        run_winner = Some((r, c, v));
                        cur.advance_key();
                    }
                }
            }
            if let Some((r, c, v)) = mem_peek {
                if (r.as_str(), c.as_str()) == min {
                    self.mem_pos += 1;
                    match v {
                        Some(v) => return Some((r, c, v)),
                        // Frozen tombstone: masks every run version.
                        None => continue,
                    }
                }
            }
            match run_winner {
                Some((r, c, Some(v))) => return Some((r, c, v)),
                // Newest run version is a tombstone: skip the key.
                _ => continue,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(r: &str, c: &str, v: &str) -> Triple {
        Triple::new(r, c, v)
    }

    #[test]
    fn frozen_image_reuse_and_splice() {
        let mut tab = Tablet::new(None, None);
        for i in 0..20 {
            tab.put(t(&format!("r{i:02}"), "c", &format!("v{i}")));
        }
        // Quiet re-pin: the cached image is shared, not rebuilt.
        let a = tab.snapshot().mem.expect("non-empty memtable");
        let b = tab.snapshot().mem.expect("non-empty memtable");
        assert!(Arc::ptr_eq(&a, &b));
        // Point writes mark keys stale; the next pin splices only those
        // keys and must equal a from-scratch freeze.
        tab.put(t("r05", "c", "v5-new"));
        tab.put(t("r20", "c", "appended"));
        tab.delete("r07", "c");
        tab.delete("never", "present");
        let spliced = tab.snapshot().mem.expect("non-empty memtable");
        assert!(!Arc::ptr_eq(&a, &spliced));
        assert_eq!(*spliced, tab.freeze_cells());
        // With no runs beneath, tombstones are dropped from the image.
        assert!(spliced.iter().all(|(_, _, v)| v.is_some()));
        assert_eq!(spliced.len(), 20); // 20 base - r07 + r20
        // With a run attached (structural: full invalidation) the same
        // dirty-splice path must keep tombstones.
        tab.freeze(1, 0);
        tab.put(t("r01", "c", "over"));
        let warm = tab.snapshot().mem;
        assert!(warm.is_none() || !warm.as_ref().unwrap().is_empty());
        tab.delete("r02", "c");
        tab.put(t("r30", "c", "tail"));
        let dirty = tab.snapshot().mem.expect("non-empty memtable");
        assert_eq!(*dirty, tab.freeze_cells());
        assert!(dirty.iter().any(|(r, _, v)| r.as_str() == "r02" && v.is_none()));
        // Deleting every live key drains the memtable; the pin reports
        // an empty image (tombstones only) or none, matching a rebuild.
        tab.delete("r01", "c");
        tab.delete("r30", "c");
        let drained = tab.snapshot().mem;
        match &drained {
            Some(img) => assert_eq!(**img, tab.freeze_cells()),
            None => assert!(tab.is_empty()),
        }
    }

    #[test]
    fn put_get_delete() {
        let mut tab = Tablet::new(None, None);
        assert!(tab.put(t("r1", "c1", "v1")).is_none());
        assert_eq!(tab.get("r1", "c1"), Some("v1"));
        // Overwrite returns previous.
        assert_eq!(tab.put(t("r1", "c1", "v2")).as_deref(), Some("v1"));
        assert_eq!(tab.get("r1", "c1"), Some("v2"));
        assert!(tab.delete("r1", "c1"));
        assert!(!tab.delete("r1", "c1"));
        assert!(tab.is_empty());
        assert_eq!(tab.weight(), 0);
    }

    #[test]
    fn contains_respects_bounds() {
        let tab = Tablet::new(Some("m".into()), Some("t".into()));
        assert!(tab.contains("m"));
        assert!(tab.contains("s"));
        assert!(!tab.contains("t")); // exclusive hi
        assert!(!tab.contains("a"));
        let unbounded = Tablet::new(None, None);
        assert!(unbounded.contains(""));
        assert!(unbounded.contains("zzz"));
    }

    #[test]
    fn scan_sorted_and_ranged() {
        let mut tab = Tablet::new(None, None);
        for (r, c) in [("b", "1"), ("a", "2"), ("c", "1"), ("a", "1")] {
            tab.put(t(r, c, "v"));
        }
        let mut all = Vec::new();
        tab.scan_into(None, None, &mut all);
        let keys: Vec<(SharedStr, SharedStr)> =
            all.iter().map(|t| (t.row.clone(), t.col.clone())).collect();
        assert_eq!(
            keys,
            vec![
                ("a".into(), "1".into()),
                ("a".into(), "2".into()),
                ("b".into(), "1".into()),
                ("c".into(), "1".into())
            ]
        );
        let mut ranged = Vec::new();
        tab.scan_into(Some("b"), Some("c"), &mut ranged);
        assert_eq!(ranged.len(), 1);
        assert_eq!(ranged[0].row, "b");
    }

    #[test]
    fn split_partitions_entries() {
        let mut tab = Tablet::new(None, None);
        for r in ["a", "b", "c", "d"] {
            tab.put(t(r, "c", "v"));
        }
        let median = tab.median_row().unwrap();
        assert_eq!(median, "c");
        let right = tab.split_at(&median);
        assert_eq!(tab.len(), 2);
        assert_eq!(right.len(), 2);
        assert_eq!(tab.hi.as_deref(), Some("c"));
        assert_eq!(right.lo.as_deref(), Some("c"));
        assert!(tab.contains("b") && !tab.contains("c"));
        assert!(right.contains("c") && right.contains("zzz"));
        // Weights are consistent with contents.
        let mut sum = 0;
        let mut out = Vec::new();
        tab.scan_into(None, None, &mut out);
        right.scan_into(None, None, &mut out);
        for tr in &out {
            sum += tr.weight();
        }
        assert_eq!(sum, tab.weight() + right.weight());
    }

    #[test]
    fn scan_block_resumes_and_windows() {
        let mut tab = Tablet::new(None, None);
        for r in ["a", "b", "c"] {
            for c in ["c1", "c2", "c3"] {
                tab.put(t(r, c, "v"));
            }
        }
        // Block-resume walk (limit 2) covers everything exactly once,
        // continuing from each block's returned resume key.
        let range = ScanRange::all();
        let mut got = Vec::new();
        let mut from: Option<(SharedStr, SharedStr)> = None;
        loop {
            let mut block = Vec::new();
            let f = from.as_ref().map(|(r, c)| (r.as_str(), c.as_str(), false));
            let more = tab.scan_block(f, std::slice::from_ref(&range), &[], 2, &mut block);
            got.extend(block);
            match more {
                Some(key) => from = Some(key),
                None => break,
            }
        }
        assert_eq!(got.len(), 9);
        assert!(got.windows(2).all(|w| w[0] < w[1]));

        // Column window restricts per row and skips ahead.
        let range = ScanRange::all().with_cols("c2", "c3");
        let mut win = Vec::new();
        assert!(tab
            .scan_block(None, std::slice::from_ref(&range), &[], usize::MAX, &mut win)
            .is_none());
        let keys: Vec<(SharedStr, SharedStr)> = win.into_iter().map(|t| (t.row, t.col)).collect();
        assert_eq!(
            keys,
            vec![
                ("a".into(), "c2".into()),
                ("b".into(), "c2".into()),
                ("c".into(), "c2".into())
            ]
        );

        // Row range + column window + inclusive resume compose.
        let range = ScanRange::rows("b", "c\0").with_cols("c1", "c3");
        let mut out = Vec::new();
        assert!(tab
            .scan_block(
                Some(("b", "c2", true)),
                std::slice::from_ref(&range),
                &[],
                usize::MAX,
                &mut out,
            )
            .is_none());
        let keys: Vec<(SharedStr, SharedStr)> = out.into_iter().map(|t| (t.row, t.col)).collect();
        assert_eq!(
            keys,
            vec![
                ("b".into(), "c2".into()),
                ("c".into(), "c1".into()),
                ("c".into(), "c2".into())
            ]
        );
    }

    #[test]
    fn scan_block_pushes_filters_beneath_the_copy() {
        use crate::store::scan::KeyMatch;
        let mut tab = Tablet::new(None, None);
        for r in ["a", "b", "c"] {
            for c in ["c1", "c2", "c3"] {
                tab.put(t(r, c, &format!("{r}{c}")));
            }
        }
        // Filtered block scan emits only matches; limit counts emitted
        // cells, and the returned resume key continues the walk.
        let filters = vec![CellFilter::col(KeyMatch::Equals("c2".into()))];
        let range = ScanRange::all();
        let mut block = Vec::new();
        let more = tab.scan_block(None, std::slice::from_ref(&range), &filters, 2, &mut block);
        let (rr, rc) = more.expect("a third match remains");
        assert_eq!(block.len(), 2);
        assert!(block.iter().all(|t| t.col == "c2"));
        let mut rest = Vec::new();
        let more = tab.scan_block(
            Some((rr.as_str(), rc.as_str(), false)),
            std::slice::from_ref(&range),
            &filters,
            usize::MAX,
            &mut rest,
        );
        assert!(more.is_none());
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0], t("c", "c2", "cc2"));
        // Emitted cells share bytes with the store (pointer clones).
        let again = tab.get("c", "c2").map(str::to_string);
        assert_eq!(again.as_deref(), Some("cc2"));
        // Value filters see the stored value beneath the copy too.
        let vf = vec![CellFilter::val(KeyMatch::Glob("b*".into()))];
        let mut vals = Vec::new();
        assert!(tab
            .scan_block(None, std::slice::from_ref(&range), &vf, usize::MAX, &mut vals)
            .is_none());
        assert_eq!(vals.len(), 3);
        assert!(vals.iter().all(|t| t.row == "b"));
    }

    #[test]
    fn scan_block_caps_examined_cells_per_lock_hold() {
        use crate::store::scan::{KeyMatch, SCAN_BLOCK};
        let mut tab = Tablet::new(None, None);
        for i in 0..(SCAN_BLOCK + 500) {
            tab.put(t(&format!("r{i:05}"), "c", "v"));
        }
        // A filter that rejects everything must still yield after
        // examining max(limit, SCAN_BLOCK) cells — one lock hold never
        // walks the whole tablet.
        let reject_all = vec![CellFilter::col(KeyMatch::Equals("nope".into()))];
        let range = ScanRange::all();
        let mut out = Vec::new();
        let more = tab.scan_block(None, std::slice::from_ref(&range), &reject_all, 64, &mut out);
        let (rr, rc) = more.expect("cap must fire before exhaustion");
        assert!(out.is_empty(), "every examined cell was rejected");
        assert_eq!(rr.as_str(), format!("r{:05}", SCAN_BLOCK - 1));
        assert_eq!(rc.as_str(), "c");
        // Resuming from the returned key walks the tail and exhausts.
        let more = tab.scan_block(
            Some((rr.as_str(), rc.as_str(), false)),
            std::slice::from_ref(&range),
            &reject_all,
            64,
            &mut out,
        );
        assert!(more.is_none());
        assert!(out.is_empty());
        // The cap also bounds window-reseek walks: every row's window
        // closes immediately here (all columns sort above it), so the
        // call strides row to row — and must still yield at the cap.
        let window = ScanRange::all().with_cols("a", "b");
        let mut out2 = Vec::new();
        let more = tab.scan_block(None, std::slice::from_ref(&window), &[], 64, &mut out2);
        let (wr, _) = more.expect("cap must fire during a reseek walk");
        assert!(out2.is_empty());
        assert_eq!(wr.as_str(), format!("r{:05}", SCAN_BLOCK - 1));
    }

    #[test]
    fn scan_block_hops_ranges_beneath_the_copy() {
        use crate::store::scan::coalesce_ranges;
        let mut tab = Tablet::new(None, None);
        for r in ["a", "b", "c", "d", "e", "f"] {
            for c in ["c1", "c2"] {
                tab.put(t(r, c, "v"));
            }
        }
        // Two disjoint row ranges: the walk unions them in one pass.
        let ranges =
            coalesce_ranges(vec![ScanRange::rows("e", "g"), ScanRange::rows("b", "c")]);
        let mut got = Vec::new();
        assert!(tab.scan_block(None, &ranges, &[], usize::MAX, &mut got).is_none());
        let rows: Vec<&str> = got.iter().map(|t| t.row.as_str()).collect();
        assert_eq!(rows, vec!["b", "b", "e", "e", "f", "f"]);
        // Block-resume walk (limit 2) crosses the gap and covers each
        // cell exactly once.
        let mut stepped = Vec::new();
        let mut from: Option<(SharedStr, SharedStr)> = None;
        loop {
            let mut block = Vec::new();
            let f = from.as_ref().map(|(r, c)| (r.as_str(), c.as_str(), false));
            let more = tab.scan_block(f, &ranges, &[], 2, &mut block);
            stepped.extend(block);
            match more {
                Some(key) => from = Some(key),
                None => break,
            }
        }
        assert_eq!(stepped, got);
        // Single-row ranges (the BFS frontier shape).
        let singles = coalesce_ranges(vec![ScanRange::single("f"), ScanRange::single("a")]);
        let mut probe = Vec::new();
        assert!(tab.scan_block(None, &singles, &[], usize::MAX, &mut probe).is_none());
        let keys: Vec<&str> = probe.iter().map(|t| t.row.as_str()).collect();
        assert_eq!(keys, vec!["a", "a", "f", "f"]);
        // An empty range set scans nothing.
        let mut none = Vec::new();
        assert!(tab.scan_block(None, &[], &[], usize::MAX, &mut none).is_none());
        assert!(none.is_empty());
    }

    #[test]
    fn scan_block_unions_overlapping_column_windows() {
        use crate::store::scan::coalesce_ranges;
        let mut tab = Tablet::new(None, None);
        for r in ["a", "b"] {
            for c in ["c1", "c2", "c3", "c4", "c5"] {
                tab.put(t(r, c, "v"));
            }
        }
        // Two windows over the same (full) row span: per row, the walk
        // hops from window to window (a multi-column-window scan).
        let ranges = coalesce_ranges(vec![
            ScanRange::all().with_cols("c4", "c5"),
            ScanRange::all().with_cols("c1", "c2"),
        ]);
        assert_eq!(ranges.len(), 2);
        let mut got = Vec::new();
        assert!(tab.scan_block(None, &ranges, &[], usize::MAX, &mut got).is_none());
        let keys: Vec<(&str, &str)> =
            got.iter().map(|t| (t.row.as_str(), t.col.as_str())).collect();
        assert_eq!(
            keys,
            vec![("a", "c1"), ("a", "c4"), ("b", "c1"), ("b", "c4")]
        );
        // Overlapping windows emit each cell once (dedup by walk).
        let ranges = coalesce_ranges(vec![
            ScanRange::all().with_cols("c1", "c3"),
            ScanRange::all().with_cols("c2", "c4"),
        ]);
        let mut got = Vec::new();
        assert!(tab.scan_block(None, &ranges, &[], usize::MAX, &mut got).is_none());
        assert_eq!(got.iter().filter(|t| t.row == "a").count(), 3); // c1, c2, c3
    }

    #[test]
    fn overlaps_matches_range_pruning() {
        let tab = Tablet::new(Some("m".into()), Some("t".into()));
        assert!(tab.overlaps(&ScanRange::all()));
        assert!(tab.overlaps(&ScanRange::rows("a", "n")));
        assert!(!tab.overlaps(&ScanRange::rows("a", "m")));
        assert!(!tab.overlaps(&ScanRange::rows("t", "z")));
        assert!(tab.overlaps(&ScanRange::single("s")));
    }

    #[test]
    fn snapshot_scan_matches_live_and_survives_mutation() {
        let mut tab = Tablet::new(None, None);
        for i in 0..40 {
            tab.put(t(&format!("r{i:02}"), "c", &format!("v{i}")));
        }
        tab.freeze(1, 0);
        // Post-freeze state mixes run cells, tombstones, and fresh
        // memtable writes — all three snapshot layers are exercised.
        for i in (0..40).step_by(3) {
            tab.delete(&format!("r{i:02}"), "c");
        }
        for i in 40..50 {
            tab.put(t(&format!("r{i:02}"), "c", "new"));
        }
        let snap = tab.snapshot();
        let mut live = Vec::new();
        tab.scan_into(None, None, &mut live);
        let range = ScanRange::all();
        // Block-resume walk over the snapshot matches the live scan.
        let mut got = Vec::new();
        let mut from: Option<(SharedStr, SharedStr)> = None;
        loop {
            let mut block = Vec::new();
            let f = from.as_ref().map(|(r, c)| (r.as_str(), c.as_str(), false));
            let more = snap.scan_block(f, std::slice::from_ref(&range), &[], 7, &mut block);
            got.extend(block);
            match more {
                Some(key) => from = Some(key),
                None => break,
            }
        }
        assert_eq!(got, live);
        // Mutating the live tablet never disturbs a pinned snapshot.
        tab.put(t("r01", "c", "after-pin"));
        tab.delete("r41", "c");
        let mut again = Vec::new();
        assert!(snap
            .scan_block(None, std::slice::from_ref(&range), &[], usize::MAX, &mut again)
            .is_none());
        assert_eq!(again, got);
        // Chunk-weight helpers: cells_upto is monotone and totals out;
        // sampled rows are usable cut points.
        assert_eq!(snap.cells_upto(None), snap.cells_upto(Some("zzz")));
        assert!(snap.cells_upto(Some("r20")) <= snap.cells_upto(Some("r40")));
        let mut samples = Vec::new();
        snap.sample_rows(4, &mut samples);
        assert!(!samples.is_empty());
        assert!(samples.iter().all(|s| snap.cells_upto(Some(s)) > 0));
    }

    #[test]
    fn median_row_degenerate() {
        let mut tab = Tablet::new(None, None);
        assert!(tab.median_row().is_none());
        tab.put(t("a", "1", "v"));
        assert!(tab.median_row().is_none());
        // All cells in one row → no valid split point.
        tab.put(t("a", "2", "v"));
        tab.put(t("a", "3", "v"));
        assert!(tab.median_row().is_none());
    }
}
