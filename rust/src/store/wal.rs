//! Write-ahead log: the durability backbone of the tiered store.
//!
//! Accumulo logs every mutation to a write-ahead log before applying it
//! to the in-memory map, so a crash loses nothing that was acknowledged
//! (arXiv:1508.07371 §II). This module is that log for the d4m store:
//! [`WalWriter`] appends length-prefixed, CRC-checksummed records;
//! [`replay`] reads them back, stopping cleanly at the first torn or
//! corrupt record (the tail a crash can leave behind is *expected*, not
//! an error).
//!
//! ## File format
//!
//! ```text
//! [8-byte magic "D4MWAL01"]
//! repeated records:
//!   [u32 len][u32 crc32(payload)][payload; len bytes]
//! payload:
//!   [u64 seq][u8 op][u32 count][strings...]
//!   op 1 = put batch: count triples, each row/col/val as [u32 len][bytes]
//!   op 2 = delete:    count == 1, row + col as [u32 len][bytes]
//! ```
//!
//! All integers are little-endian. `seq` is strictly increasing within a
//! log; run watermarks (see [`super::run`]) reference these sequence
//! numbers so recovery knows which log suffix is not yet frozen into
//! runs.

use super::io::{RealIo, StorageFile, StorageIo};
use super::{SharedStr, Triple};
use std::io;
use std::path::Path;

/// Magic bytes opening every WAL file (format version 01).
pub const WAL_MAGIC: &[u8; 8] = b"D4MWAL01";

/// Largest accepted record payload (64 MiB) — a sanity cap so a corrupt
/// length prefix cannot trigger a huge allocation during replay.
const MAX_RECORD_LEN: u32 = 64 << 20;

/// CRC-32 (IEEE 802.3, reflected polynomial `0xEDB88320`) lookup table,
/// built at compile time so the store stays dependency-free.
const CRC32_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC-32 (IEEE) of `bytes` — the checksum guarding each WAL record and
/// each run file footer.
pub(crate) fn crc32(bytes: &[u8]) -> u32 {
    let mut c = !0u32;
    for &b in bytes {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// When the WAL forces data to disk. Accumulo exposes the same knob as
/// its `sync`/`flush` durability levels: group-committing callers trade
/// a bounded window of acknowledged-but-unsynced mutations for
/// throughput.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FsyncPolicy {
    /// Never fsync explicitly; the OS flushes on its own schedule.
    /// Fastest; a *machine* crash may lose the OS-buffered tail (every
    /// record is handed to the OS at append time, so a process crash
    /// loses nothing).
    #[default]
    Never,
    /// Fsync after every appended record. Slowest, strongest.
    Always,
    /// Fsync after every `n` appended records.
    EveryN(usize),
}

/// Appender over one table's WAL file.
///
/// Not internally synchronized: the owning [`super::Table`] wraps it in
/// a mutex and holds that lock across append **and** memtable apply, so
/// log order equals apply order (the invariant recovery relies on).
///
/// Appends are retry-safe: each record is written with a single
/// `write_all` and the writer tracks `durable_len`, the byte offset of
/// the last fully-appended record. A failed (possibly short) write marks
/// the tail dirty, and the next append first truncates back to
/// `durable_len` — so a retried append can never land a good record
/// after torn bytes, which replay would silently discard.
#[derive(Debug)]
pub struct WalWriter {
    file: Box<dyn StorageFile>,
    policy: FsyncPolicy,
    /// Records appended since the last fsync (for `EveryN`).
    pending: usize,
    last_seq: u64,
    /// File length through the last fully-written record.
    durable_len: u64,
    /// A failed append may have left torn bytes past `durable_len`;
    /// repair (truncate) before the next append.
    tail_dirty: bool,
}

impl WalWriter {
    /// Create a fresh WAL at `path` (truncating any existing file) and
    /// write the header.
    pub fn create(io: &dyn StorageIo, path: &Path, policy: FsyncPolicy) -> io::Result<WalWriter> {
        let mut file = io.create(path)?;
        file.write_all(WAL_MAGIC)?;
        Ok(WalWriter {
            file,
            policy,
            pending: 0,
            last_seq: 0,
            durable_len: WAL_MAGIC.len() as u64,
            tail_dirty: false,
        })
    }

    /// Reopen `path` for appending after recovery. `last_seq` is the
    /// highest sequence number already durable (from replay and run
    /// watermarks); new records continue from there. The current file
    /// length is adopted as the durable tail — callers reopen only logs
    /// whose tail they have verified via [`replay`].
    pub fn open_append(
        io: &dyn StorageIo,
        path: &Path,
        policy: FsyncPolicy,
        last_seq: u64,
    ) -> io::Result<WalWriter> {
        let file = io.open_append(path)?;
        let durable_len = file.size()?;
        Ok(WalWriter { file, policy, pending: 0, last_seq, durable_len, tail_dirty: false })
    }

    /// Re-acquire a handle on the same log after a permanent-looking
    /// failure — the health re-probe behind auto-recovery from
    /// read-only degradation. Opens `path` for appending (never
    /// truncating the whole file the way [`WalWriter::create`] would)
    /// and cuts the file back to `durable_len`, dropping any torn
    /// never-acknowledged bytes the failed handle left; acknowledged
    /// records and the writer's sequence numbering are untouched. On
    /// error the caller stays degraded and a later probe simply
    /// retries the whole reopen.
    pub fn reopen(&mut self, io: &dyn StorageIo, path: &Path) -> io::Result<()> {
        let file = io.open_append(path)?;
        self.file = file;
        self.file.truncate(self.durable_len)?;
        self.tail_dirty = false;
        self.pending = 0;
        Ok(())
    }

    /// Highest sequence number appended (or adopted at open).
    pub fn last_seq(&self) -> u64 {
        self.last_seq
    }

    /// Adopt `seq` as the highest already-durable sequence number (the
    /// recovery path starts a fresh log but must keep numbering past
    /// the run watermarks it restored).
    pub(crate) fn set_last_seq(&mut self, seq: u64) {
        self.last_seq = self.last_seq.max(seq);
    }

    /// Truncate any torn bytes a failed append left past the last
    /// complete record. Idempotent; called automatically before the
    /// next append after a failure.
    pub fn repair(&mut self) -> io::Result<()> {
        if self.tail_dirty {
            self.file.truncate(self.durable_len)?;
            self.tail_dirty = false;
        }
        Ok(())
    }

    fn write_record(&mut self, payload: &[u8]) -> io::Result<()> {
        debug_assert!(payload.len() as u64 <= MAX_RECORD_LEN as u64);
        self.repair()?;
        let mut buf = Vec::with_capacity(8 + payload.len());
        buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(&crc32(payload).to_le_bytes());
        buf.extend_from_slice(payload);
        if let Err(e) = self.file.write_all(&buf) {
            self.tail_dirty = true;
            return Err(e);
        }
        self.durable_len += buf.len() as u64;
        self.pending += 1;
        match self.policy {
            FsyncPolicy::Never => Ok(()),
            FsyncPolicy::Always => self.sync(),
            FsyncPolicy::EveryN(n) => {
                if self.pending >= n.max(1) {
                    self.sync()
                } else {
                    Ok(())
                }
            }
        }
    }

    /// Append one put batch; returns the record's sequence number.
    pub fn append_put(&mut self, batch: &[Triple]) -> io::Result<u64> {
        self.last_seq += 1;
        let mut payload = Vec::with_capacity(16 + batch.iter().map(Triple::weight).sum::<usize>());
        payload.extend_from_slice(&self.last_seq.to_le_bytes());
        payload.push(1u8);
        payload.extend_from_slice(&(batch.len() as u32).to_le_bytes());
        for t in batch {
            for s in [t.row.as_str(), t.col.as_str(), t.val.as_str()] {
                payload.extend_from_slice(&(s.len() as u32).to_le_bytes());
                payload.extend_from_slice(s.as_bytes());
            }
        }
        self.write_record(&payload)?;
        Ok(self.last_seq)
    }

    /// Append one delete record; returns its sequence number.
    pub fn append_delete(&mut self, row: &str, col: &str) -> io::Result<u64> {
        self.last_seq += 1;
        let mut payload = Vec::with_capacity(32 + row.len() + col.len());
        payload.extend_from_slice(&self.last_seq.to_le_bytes());
        payload.push(2u8);
        payload.extend_from_slice(&1u32.to_le_bytes());
        for s in [row, col] {
            payload.extend_from_slice(&(s.len() as u32).to_le_bytes());
            payload.extend_from_slice(s.as_bytes());
        }
        self.write_record(&payload)?;
        Ok(self.last_seq)
    }

    /// Fsync file data to disk. Every appended record has already been
    /// handed to the OS (no user-space buffer), so this only forces the
    /// kernel cache down.
    ///
    /// A failed sync leaves the log *structurally* intact — the record
    /// bytes are fully written — so callers may simply retry the append
    /// or the sync; re-appended batches replay idempotently.
    pub fn sync(&mut self) -> io::Result<()> {
        self.file.sync_data()?;
        self.pending = 0;
        Ok(())
    }
}

/// One mutation read back from the log.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WalOp {
    /// A batch of puts, in original order.
    Put(Vec<Triple>),
    /// A single-cell delete.
    Delete {
        /// Row key of the deleted cell.
        row: SharedStr,
        /// Column key of the deleted cell.
        col: SharedStr,
    },
}

/// One replayed record: its sequence number and operation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WalRecord {
    /// Sequence number assigned at append time.
    pub seq: u64,
    /// The logged mutation.
    pub op: WalOp,
}

/// Result of reading a WAL back: every record up to the first damaged
/// one, plus whether damage was found.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WalReplay {
    /// Intact records, in log order.
    pub records: Vec<WalRecord>,
    /// `true` if the file ended mid-record or a record failed its
    /// checksum — the surviving prefix in `records` is still valid.
    pub truncated: bool,
}

/// Reader cursor over a byte buffer; `None` means "ran off the end",
/// which replay treats as a torn tail.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.pos.checked_add(n)?;
        if end > self.buf.len() {
            return None;
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Some(s)
    }

    fn u32(&mut self) -> Option<u32> {
        self.take(4).map(|b| u32::from_le_bytes(b.try_into().unwrap()))
    }

    fn u64(&mut self) -> Option<u64> {
        self.take(8).map(|b| u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn u8(&mut self) -> Option<u8> {
        self.take(1).map(|b| b[0])
    }

    fn string(&mut self) -> Option<SharedStr> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        std::str::from_utf8(bytes).ok().map(SharedStr::from)
    }
}

/// Decode one record payload. `None` = malformed (treated as a torn
/// record by `replay`).
fn decode_payload(payload: &[u8]) -> Option<WalRecord> {
    let mut c = Cursor { buf: payload, pos: 0 };
    let seq = c.u64()?;
    let op = c.u8()?;
    let count = c.u32()? as usize;
    let op = match op {
        1 => {
            let mut batch = Vec::with_capacity(count.min(1 << 20));
            for _ in 0..count {
                let row = c.string()?;
                let col = c.string()?;
                let val = c.string()?;
                batch.push(Triple { row, col, val });
            }
            WalOp::Put(batch)
        }
        2 => {
            if count != 1 {
                return None;
            }
            WalOp::Delete { row: c.string()?, col: c.string()? }
        }
        _ => return None,
    };
    if c.pos != payload.len() {
        return None; // trailing garbage inside a "valid" record
    }
    Some(WalRecord { seq, op })
}

/// Read every intact record from the WAL at `path`.
///
/// Stops cleanly (returning `truncated = true`) at the first short,
/// over-long, checksum-failing or undecodable record — the state a
/// crash mid-append legitimately leaves. A file too short to hold the
/// header replays as empty-and-truncated. A full-size header with the
/// wrong magic is a real error ([`io::ErrorKind::InvalidData`]): that
/// file is not a WAL at all.
pub fn replay(path: &Path) -> io::Result<WalReplay> {
    replay_with(&RealIo, path)
}

/// [`replay`] through an explicit [`StorageIo`] (the recovery path,
/// which must observe injected faults).
pub fn replay_with(io: &dyn StorageIo, path: &Path) -> io::Result<WalReplay> {
    let bytes = io.read(path)?;
    if bytes.len() < WAL_MAGIC.len() {
        return Ok(WalReplay { records: Vec::new(), truncated: true });
    }
    if &bytes[..WAL_MAGIC.len()] != WAL_MAGIC {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("{}: not a d4m WAL (bad magic)", path.display()),
        ));
    }
    let mut c = Cursor { buf: &bytes, pos: WAL_MAGIC.len() };
    let mut records = Vec::new();
    let mut last_seq = 0u64;
    loop {
        if c.pos == bytes.len() {
            return Ok(WalReplay { records, truncated: false });
        }
        let header = (|c: &mut Cursor| Some((c.u32()?, c.u32()?)))(&mut c);
        let (len, crc) = match header {
            Some(h) => h,
            None => return Ok(WalReplay { records, truncated: true }),
        };
        if len > MAX_RECORD_LEN {
            return Ok(WalReplay { records, truncated: true });
        }
        let payload = match c.take(len as usize) {
            Some(p) => p,
            None => return Ok(WalReplay { records, truncated: true }),
        };
        if crc32(payload) != crc {
            return Ok(WalReplay { records, truncated: true });
        }
        match decode_payload(payload) {
            Some(rec) if rec.seq > last_seq => {
                last_seq = rec.seq;
                records.push(rec);
            }
            // Non-increasing seq or undecodable payload: corrupt tail.
            _ => return Ok(WalReplay { records, truncated: true }),
        }
    }
}

/// Byte spans `(offset, len)` of each intact record in the WAL at
/// `path`, header excluded (the first offset is the magic length).
/// The crash-injection harness uses these to truncate at exact record
/// boundaries and to flip bytes inside specific records.
pub fn record_spans(path: &Path) -> io::Result<Vec<(u64, u64)>> {
    let bytes = std::fs::read(path)?;
    let mut spans = Vec::new();
    if bytes.len() < WAL_MAGIC.len() || &bytes[..WAL_MAGIC.len()] != WAL_MAGIC {
        return Ok(spans);
    }
    let mut c = Cursor { buf: &bytes, pos: WAL_MAGIC.len() };
    loop {
        let start = c.pos as u64;
        let header = (|c: &mut Cursor| Some((c.u32()?, c.u32()?)))(&mut c);
        let (len, crc) = match header {
            Some(h) if h.0 <= MAX_RECORD_LEN => h,
            _ => return Ok(spans),
        };
        let payload = match c.take(len as usize) {
            Some(p) => p,
            None => return Ok(spans),
        };
        if crc32(payload) != crc {
            return Ok(spans);
        }
        spans.push((start, c.pos as u64 - start));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_wal(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("d4m-wal-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn t(r: &str, c: &str, v: &str) -> Triple {
        Triple::new(r, c, v)
    }

    #[test]
    fn crc32_known_vectors() {
        // IEEE CRC-32 check value for "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn append_replay_roundtrip() {
        let path = temp_wal("roundtrip.log");
        let mut w = WalWriter::create(&RealIo, &path, FsyncPolicy::Never).unwrap();
        let s1 = w.append_put(&[t("a", "x", "1"), t("b", "y", "2")]).unwrap();
        let s2 = w.append_delete("a", "x").unwrap();
        let s3 = w.append_put(&[t("c", "z", "3")]).unwrap();
        assert_eq!((s1, s2, s3), (1, 2, 3));
        w.sync().unwrap();
        let rp = replay(&path).unwrap();
        assert!(!rp.truncated);
        assert_eq!(rp.records.len(), 3);
        assert_eq!(rp.records[0].seq, 1);
        assert_eq!(rp.records[0].op, WalOp::Put(vec![t("a", "x", "1"), t("b", "y", "2")]));
        assert_eq!(rp.records[1].op, WalOp::Delete { row: "a".into(), col: "x".into() });
        assert_eq!(rp.records[2].op, WalOp::Put(vec![t("c", "z", "3")]));
    }

    #[test]
    fn reopen_append_continues_sequence() {
        let path = temp_wal("reopen.log");
        let mut w = WalWriter::create(&RealIo, &path, FsyncPolicy::Never).unwrap();
        w.append_put(&[t("a", "x", "1")]).unwrap();
        drop(w);
        let mut w = WalWriter::open_append(&RealIo, &path, FsyncPolicy::Always, 1).unwrap();
        assert_eq!(w.append_put(&[t("b", "y", "2")]).unwrap(), 2);
        drop(w);
        let rp = replay(&path).unwrap();
        assert!(!rp.truncated);
        assert_eq!(rp.records.iter().map(|r| r.seq).collect::<Vec<_>>(), vec![1, 2]);
    }

    #[test]
    fn truncation_mid_record_keeps_prefix() {
        let path = temp_wal("trunc.log");
        let mut w = WalWriter::create(&RealIo, &path, FsyncPolicy::Never).unwrap();
        w.append_put(&[t("a", "x", "1")]).unwrap();
        w.append_put(&[t("b", "y", "2")]).unwrap();
        drop(w);
        let spans = record_spans(&path).unwrap();
        assert_eq!(spans.len(), 2);
        // Cut into the middle of the second record.
        let cut = spans[1].0 + spans[1].1 / 2;
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..cut as usize]).unwrap();
        let rp = replay(&path).unwrap();
        assert!(rp.truncated);
        assert_eq!(rp.records.len(), 1);
        assert_eq!(rp.records[0].op, WalOp::Put(vec![t("a", "x", "1")]));
    }

    #[test]
    fn corruption_stops_replay_at_bad_record() {
        let path = temp_wal("corrupt.log");
        let mut w = WalWriter::create(&RealIo, &path, FsyncPolicy::EveryN(2)).unwrap();
        w.append_put(&[t("a", "x", "1")]).unwrap();
        w.append_put(&[t("b", "y", "2")]).unwrap();
        w.append_put(&[t("c", "z", "3")]).unwrap();
        drop(w);
        let spans = record_spans(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip one payload byte inside the second record.
        let idx = (spans[1].0 + 10) as usize;
        bytes[idx] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let rp = replay(&path).unwrap();
        assert!(rp.truncated);
        assert_eq!(rp.records.len(), 1);
    }

    #[test]
    fn empty_and_foreign_files() {
        let path = temp_wal("short.log");
        std::fs::write(&path, b"D4M").unwrap();
        let rp = replay(&path).unwrap();
        assert!(rp.truncated && rp.records.is_empty());
        let path = temp_wal("foreign.log");
        std::fs::write(&path, b"NOTAWAL!more bytes here").unwrap();
        assert_eq!(replay(&path).unwrap_err().kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn torn_append_repairs_before_retry() {
        use crate::store::io::{FaultKind, FaultPlan, FaultyIo};
        let path = temp_wal("torn-retry.log");
        // Op 0 = create, op 1 = magic write, op 2 = first record write.
        let io = FaultyIo::new(FaultPlan::new().fail_at(3, FaultKind::ShortWrite));
        let mut w = WalWriter::create(&*io, &path, FsyncPolicy::Never).unwrap();
        w.append_put(&[t("a", "x", "1")]).unwrap();
        // Second append tears mid-record...
        let err = w.append_put(&[t("b", "y", "2")]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::Interrupted);
        // ...and the retry truncates the torn tail before re-appending,
        // so replay sees both records intact (and no duplicates).
        assert_eq!(w.append_put(&[t("b", "y", "2")]).unwrap(), 3);
        drop(w);
        let rp = replay(&path).unwrap();
        assert!(!rp.truncated);
        assert_eq!(rp.records.len(), 2);
        assert_eq!(rp.records[1].op, WalOp::Put(vec![t("b", "y", "2")]));
    }
}
