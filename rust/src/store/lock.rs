//! Lock wrappers with a per-thread acquisition counter — the test shim
//! behind the "snapshot scans take zero locks after open" guarantee.
//!
//! The table's tablet-list `RwLock` and per-tablet `Mutex`es are wrapped
//! in [`TrackedRwLock`] / [`TrackedMutex`], which expose the same API
//! subset as their `std::sync` counterparts but bump a thread-local
//! counter on every acquisition. [`lock_acquisitions`] reads the
//! counter, so a test can diff it around a scan and assert the
//! lock-free snapshot path acquired nothing — turning the central PR 8
//! performance claim into a checked invariant instead of a comment.
//!
//! The counter is thread-local on purpose: it needs no synchronization
//! of its own (a shared atomic would serialize the very paths being
//! measured), and a serial scan's count is exact regardless of what
//! other threads do concurrently.

use std::cell::Cell;
use std::sync::{LockResult, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

thread_local! {
    /// Tracked lock acquisitions made by this thread (mutex locks plus
    /// rwlock reads and writes).
    static ACQUISITIONS: Cell<u64> = const { Cell::new(0) };
}

/// Number of tracked lock acquisitions this thread has performed so
/// far. Monotone per thread — diff it around an operation to count the
/// locks that operation took on this thread.
pub fn lock_acquisitions() -> u64 {
    ACQUISITIONS.with(Cell::get)
}

#[inline]
fn count_one() {
    ACQUISITIONS.with(|c| c.set(c.get() + 1));
}

/// [`std::sync::Mutex`] with acquisition counting (same API subset, so
/// call sites are unchanged).
#[derive(Debug, Default)]
pub struct TrackedMutex<T>(Mutex<T>);

impl<T> TrackedMutex<T> {
    /// Wrap `value` in a tracked mutex.
    pub fn new(value: T) -> Self {
        TrackedMutex(Mutex::new(value))
    }

    /// Acquire the lock, counting the acquisition.
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        count_one();
        self.0.lock()
    }
}

/// [`std::sync::RwLock`] with acquisition counting (same API subset, so
/// call sites are unchanged).
#[derive(Debug, Default)]
pub struct TrackedRwLock<T>(RwLock<T>);

impl<T> TrackedRwLock<T> {
    /// Wrap `value` in a tracked rwlock.
    pub fn new(value: T) -> Self {
        TrackedRwLock(RwLock::new(value))
    }

    /// Acquire a shared read guard, counting the acquisition.
    pub fn read(&self) -> LockResult<RwLockReadGuard<'_, T>> {
        count_one();
        self.0.read()
    }

    /// Acquire the exclusive write guard, counting the acquisition.
    pub fn write(&self) -> LockResult<RwLockWriteGuard<'_, T>> {
        count_one();
        self.0.write()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_every_acquisition_kind() {
        let m = TrackedMutex::new(1u32);
        let rw = TrackedRwLock::new(2u32);
        let before = lock_acquisitions();
        *m.lock().unwrap() += 1;
        assert_eq!(*rw.read().unwrap(), 2);
        *rw.write().unwrap() += 1;
        assert_eq!(lock_acquisitions() - before, 3);
        assert_eq!(*m.lock().unwrap(), 2);
        assert_eq!(*rw.read().unwrap(), 3);
        assert_eq!(lock_acquisitions() - before, 5);
    }

    #[test]
    fn counter_is_per_thread() {
        let before = lock_acquisitions();
        std::thread::spawn(|| {
            let m = TrackedMutex::new(());
            for _ in 0..10 {
                drop(m.lock().unwrap());
            }
        })
        .join()
        .unwrap();
        assert_eq!(lock_acquisitions(), before, "other threads' locks don't count here");
    }
}
