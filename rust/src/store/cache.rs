//! Shared LRU block cache for paged runs (PR 9).
//!
//! Accumulo tablet servers never map an RFile whole: scans fault
//! index-addressed data blocks on demand through a shared block cache,
//! which is what lets associative-array queries run over tables far
//! larger than RAM (arXiv:1508.07371 §II; the D4M 3.0 server-side
//! architecture, arXiv:1702.03253). [`BlockCache`] is that component
//! for the durable tier: a process-wide, sharded, byte-capacity LRU
//! keyed by `(run uid, block index)` that hands out [`Arc<Block>`]s.
//!
//! Two properties matter for the PR 8 lock-free scan contract:
//!
//! - **Pins survive eviction.** A cursor holds an `Arc<Block>`; eviction
//!   only drops the cache's own reference, so an in-flight merge keeps
//!   reading its pinned block while the cache reuses the budget for
//!   other blocks. [`CacheStats::peak_live_bytes`] tracks cache
//!   residency *plus* pins, which is how the bench asserts the
//!   "capacity + one block per active cursor" memory bound.
//! - **No tracked locks.** Shards use plain [`std::sync::Mutex`], not
//!   [`super::lock::TrackedMutex`]: the PR 8 zero-lock-after-open shim
//!   counts *table* lock acquisitions, and a cache-faulting scan must
//!   still report zero of those (`tests/scan_stack.rs` asserts it).
//!   Shard critical sections are a hash probe and a list splice — no
//!   I/O ever happens under a shard lock.
//!
//! Capacity `0` is a degenerate but supported mode: every load is a
//! miss, nothing is retained, and scans still complete correctly off
//! pinned blocks alone — the eviction-torture configuration of the
//! cache test matrix.

use std::collections::{HashMap, VecDeque};
use std::io;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Number of independently locked shards. A small power of two: enough
/// to keep scan workers from serializing on one mutex, small enough
/// that per-shard capacity stays meaningful for tiny test capacities.
const SHARDS: usize = 8;

/// One decoded run block: the `(row, col, val)` pool-id triples of a
/// contiguous slice of a run file, plus its accounting handle. Dropping
/// the last `Arc<Block>` (cache copy and all pins gone) releases its
/// bytes from [`CacheStats::live_bytes`].
#[derive(Debug)]
pub struct Block {
    triples: Vec<(u32, u32, u32)>,
    bytes: usize,
    stats: Arc<StatsInner>,
}

impl Block {
    /// The decoded triples; indices are block-relative.
    #[inline]
    pub fn triples(&self) -> &[(u32, u32, u32)] {
        &self.triples
    }

    /// Encoded size of the block on disk (12 bytes per triple) — the
    /// unit of cache accounting.
    #[inline]
    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

impl Drop for Block {
    fn drop(&mut self) {
        self.stats.live_bytes.fetch_sub(self.bytes as u64, Ordering::Relaxed);
    }
}

/// Monotonic counters shared by every block the cache has handed out.
#[derive(Debug, Default)]
struct StatsInner {
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    live_bytes: AtomicU64,
    peak_live_bytes: AtomicU64,
}

impl StatsInner {
    fn on_block_created(&self, bytes: usize) {
        let live = self.live_bytes.fetch_add(bytes as u64, Ordering::Relaxed) + bytes as u64;
        self.peak_live_bytes.fetch_max(live, Ordering::Relaxed);
    }
}

/// A point-in-time snapshot of the cache counters, surfaced through
/// `Table::health()` and the bench JSON.
///
/// `misses` is the total number of block faults; diffing it around a
/// scan gives that scan's faulted-block count. `resident_bytes` is what
/// the cache itself holds; `live_bytes` additionally counts blocks kept
/// alive only by cursor pins, and `peak_live_bytes` is the high-water
/// mark of that sum — the quantity bounded by
/// `capacity + one block per active cursor`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Block lookups served from the cache.
    pub hits: u64,
    /// Block lookups that had to read and decode from storage.
    pub misses: u64,
    /// Blocks dropped from the cache to stay under capacity.
    pub evictions: u64,
    /// Bytes currently held by the cache itself.
    pub resident_bytes: u64,
    /// Bytes of all live blocks: cache residents plus cursor pins.
    pub live_bytes: u64,
    /// High-water mark of `live_bytes` since creation (or the last
    /// [`BlockCache::reset_peak`]).
    pub peak_live_bytes: u64,
}

/// Key of one cached block: the owning run's process-unique id plus the
/// block's index within that run. Run uids (not file paths) keep a
/// reopened or renamed file from aliasing stale cache entries.
type BlockKey = (u64, u32);

struct Slot {
    block: Arc<Block>,
    /// Shard-local LRU stamp; queue entries with a stale stamp are
    /// skipped (lazy deletion — no doubly linked list needed).
    stamp: u64,
}

#[derive(Default)]
struct Shard {
    map: HashMap<BlockKey, Slot>,
    /// Recency queue, oldest first, with lazy deletion via stamps.
    queue: VecDeque<(BlockKey, u64)>,
    bytes: usize,
    next_stamp: u64,
}

impl Shard {
    fn touch(&mut self, key: BlockKey) {
        self.next_stamp += 1;
        let stamp = self.next_stamp;
        if let Some(slot) = self.map.get_mut(&key) {
            slot.stamp = stamp;
            self.queue.push_back((key, stamp));
        }
        // Bound the lazy queue: compact once stale entries dominate.
        if self.queue.len() > 4 * self.map.len() + 16 {
            let map = &self.map;
            self.queue.retain(|(k, s)| map.get(k).is_some_and(|slot| slot.stamp == *s));
        }
    }

    fn evict_to(&mut self, capacity: usize, stats: &StatsInner) {
        while self.bytes > capacity {
            let Some((key, stamp)) = self.queue.pop_front() else { break };
            let live = self.map.get(&key).is_some_and(|slot| slot.stamp == stamp);
            if live {
                let slot = self.map.remove(&key).expect("checked above");
                self.bytes -= slot.block.bytes;
                stats.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// Process-unique id source for paged runs (cache key namespace).
static NEXT_RUN_UID: AtomicU64 = AtomicU64::new(1);

/// The shared LRU block cache. Create one with [`BlockCache::new`] and
/// hand the same `Arc` to every `DurableOptions` that should share the
/// byte budget (a `TableStore` does this automatically).
pub struct BlockCache {
    capacity: usize,
    shards: Vec<Mutex<Shard>>,
    stats: Arc<StatsInner>,
}

impl std::fmt::Debug for BlockCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BlockCache").field("capacity", &self.capacity).finish()
    }
}

impl BlockCache {
    /// A cache with a total byte `capacity` split evenly across shards.
    /// Capacity `0` disables retention entirely (every load is a miss).
    pub fn new(capacity: usize) -> Arc<BlockCache> {
        Arc::new(BlockCache {
            capacity,
            shards: (0..SHARDS).map(|_| Mutex::new(Shard::default())).collect(),
            stats: Arc::new(StatsInner::default()),
        })
    }

    /// Total byte capacity this cache was created with.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Allocate a process-unique run uid (one per paged `Run::open`).
    pub(crate) fn next_run_uid() -> u64 {
        NEXT_RUN_UID.fetch_add(1, Ordering::Relaxed)
    }

    /// Snapshot the counters.
    pub fn stats(&self) -> CacheStats {
        let s = &self.stats;
        let resident: usize = self
            .shards
            .iter()
            .map(|sh| sh.lock().expect("cache shard poisoned").bytes)
            .sum();
        CacheStats {
            hits: s.hits.load(Ordering::Relaxed),
            misses: s.misses.load(Ordering::Relaxed),
            evictions: s.evictions.load(Ordering::Relaxed),
            resident_bytes: resident as u64,
            live_bytes: s.live_bytes.load(Ordering::Relaxed),
            peak_live_bytes: s.peak_live_bytes.load(Ordering::Relaxed),
        }
    }

    /// Reset the `peak_live_bytes` high-water mark to the current live
    /// bytes — used by benches to bound one phase at a time.
    pub fn reset_peak(&self) {
        let live = self.stats.live_bytes.load(Ordering::Relaxed);
        self.stats.peak_live_bytes.store(live, Ordering::Relaxed);
    }

    /// Fetch block `key`, loading (and decoding) it with `load` on a
    /// miss. `load` runs *outside* any shard lock; if two threads race
    /// on the same missing block, both load it and the first insert
    /// wins (the loser's copy serves its caller and then drops).
    pub fn get_or_load(
        &self,
        key: BlockKey,
        load: impl FnOnce() -> io::Result<Block>,
    ) -> io::Result<Arc<Block>> {
        let shard_idx = self.shard_of(key);
        {
            let mut shard = self.shards[shard_idx].lock().expect("cache shard poisoned");
            if let Some(slot) = shard.map.get(&key) {
                let block = Arc::clone(&slot.block);
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                shard.touch(key);
                return Ok(block);
            }
        }
        self.stats.misses.fetch_add(1, Ordering::Relaxed);
        let block = Arc::new(load()?);
        if self.capacity == 0 {
            return Ok(block);
        }
        let per_shard = (self.capacity / SHARDS).max(1);
        let mut shard = self.shards[shard_idx].lock().expect("cache shard poisoned");
        if let Some(slot) = shard.map.get(&key) {
            // Lost the race; keep the resident copy so accounting stays
            // single-entry per key.
            return Ok(Arc::clone(&slot.block));
        }
        shard.bytes += block.bytes;
        shard.map.insert(key, Slot { block: Arc::clone(&block), stamp: 0 });
        shard.touch(key);
        shard.evict_to(per_shard, &self.stats);
        Ok(block)
    }

    /// Build a [`Block`] wired to this cache's accounting. The block
    /// immediately counts toward `live_bytes` (it is live the moment a
    /// loader holds it, cached or not).
    pub(crate) fn make_block(&self, triples: Vec<(u32, u32, u32)>) -> Block {
        let bytes = triples.len() * 12;
        self.stats.on_block_created(bytes);
        Block { triples, bytes, stats: Arc::clone(&self.stats) }
    }

    fn shard_of(&self, key: BlockKey) -> usize {
        // Cheap integer mix; uids are sequential, so fold both halves.
        let h = key.0.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ u64::from(key.1);
        ((h >> 32) as usize ^ h as usize) % SHARDS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn block_of(cache: &BlockCache, n: usize) -> Block {
        cache.make_block(vec![(0, 0, 0); n])
    }

    #[test]
    fn hit_miss_and_eviction_accounting() {
        let cache = BlockCache::new(SHARDS * 24); // 2 triples per shard
        let uid = BlockCache::next_run_uid();
        let b0 = cache.get_or_load((uid, 0), || Ok(block_of(&cache, 1))).unwrap();
        assert_eq!(b0.triples().len(), 1);
        let again = cache.get_or_load((uid, 0), || panic!("must hit")).unwrap();
        assert!(Arc::ptr_eq(&b0, &again));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 1, 0));
        assert_eq!(s.resident_bytes, 12);

        // Same shard keys: uid fixed, spray block indices until one
        // lands on block 0's shard and overflows it.
        let shard0 = cache.shard_of((uid, 0));
        let mut sibling = 1u32;
        while cache.shard_of((uid, sibling)) != shard0 {
            sibling += 1;
        }
        // Two 1-triple blocks fit (24 bytes); a third evicts the LRU.
        let _b1 = cache.get_or_load((uid, sibling), || Ok(block_of(&cache, 1))).unwrap();
        let mut next = sibling + 1;
        while cache.shard_of((uid, next)) != shard0 {
            next += 1;
        }
        let _b2 = cache.get_or_load((uid, next), || Ok(block_of(&cache, 1))).unwrap();
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        // The evicted block (key 0) is still alive through our pin.
        assert_eq!(s.live_bytes, 36);
        assert!(s.peak_live_bytes >= 36);
        // Refetching the evicted key is a miss again.
        let b0b = cache.get_or_load((uid, 0), || Ok(block_of(&cache, 1))).unwrap();
        assert!(!Arc::ptr_eq(&b0, &b0b));
    }

    #[test]
    fn capacity_zero_never_retains() {
        let cache = BlockCache::new(0);
        let uid = BlockCache::next_run_uid();
        for _ in 0..3 {
            let b = cache.get_or_load((uid, 7), || Ok(block_of(&cache, 2))).unwrap();
            assert_eq!(b.triples().len(), 2);
        }
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (0, 3));
        assert_eq!(s.resident_bytes, 0);
        // All handed-out blocks dropped: live bytes fully released.
        assert_eq!(cache.stats().live_bytes, 0);
        assert!(cache.stats().peak_live_bytes >= 24);
    }

    #[test]
    fn reset_peak_tracks_current_live() {
        let cache = BlockCache::new(1 << 20);
        let uid = BlockCache::next_run_uid();
        let pin = cache.get_or_load((uid, 0), || Ok(block_of(&cache, 4))).unwrap();
        assert!(cache.stats().peak_live_bytes >= 48);
        cache.reset_peak();
        assert_eq!(cache.stats().peak_live_bytes, cache.stats().live_bytes);
        drop(pin);
    }

    #[test]
    fn load_errors_propagate_and_count_as_misses() {
        let cache = BlockCache::new(1 << 20);
        let uid = BlockCache::next_run_uid();
        let err = cache
            .get_or_load((uid, 0), || Err(io::Error::other("boom")))
            .unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::Other);
        assert_eq!(cache.stats().misses, 1);
        assert_eq!(cache.stats().resident_bytes, 0);
    }
}
