//! The server-side scan stack — Accumulo's iterator model for this store.
//!
//! Accumulo's defining performance trick (and the one the D4M database
//! papers lean on — "D4M: Bringing Associative Arrays to Database
//! Engines", "D4M 3.0") is that scans are *iterator stacks executed
//! inside the tablet servers*: a seekable sorted-key iterator per
//! tablet, wrapped by range restriction, filters, and combiners, so the
//! client receives only the cells (or aggregates) it asked for. This
//! module is that stack for the in-repo store:
//!
//! | Accumulo | here |
//! |----------|------|
//! | `SortedKeyValueIterator` (seek + next) | [`ScanIter`] |
//! | `Range` (row + column qualifier bounds) | [`ScanRange`] |
//! | `BatchScanner` (a *set* of ranges per scan) | [`ScanSpec::ranges()`] (sorted, coalesced multi-range spec) |
//! | `ColumnQualifierFilter` / `RegExFilter` | [`CellFilter`] + [`KeyMatch`] |
//! | `Combiner` (per-key aggregation) | [`RowReduce`] |
//! | `ScannerOptions` (the configured stack) | [`ScanSpec`] |
//! | Scan-time isolation (a scan serves one consistent view) | `TabletSnapshot` (pinned per scan) |
//! | `BatchScanner` worker threads (per-range server fan-out) | `SnapshotScan::collect` (weighted range-chunk fan-out) |
//! | RFile index blocks + shared block cache (beyond-RAM tables) | Paged [`super::Run`] + [`super::BlockCache`] |
//!
//! In paged mode the base cursors fault data blocks through the shared
//! [`super::BlockCache`] on demand: each run cursor pins at most one
//! block (`Arc`-held, so eviction never invalidates it), multi-range
//! specs seek via the per-run block index and never fault the blocks
//! between ranges, and the whole stack stays lock-free after the pin —
//! eviction and refault happen under the cache's own shards, not the
//! table's locks.
//!
//! The base of the stack is a *block cursor* over the tablet layers
//! ([`SliceCursor`] over a live tablet list, [`SnapCursor`] over pinned
//! lock-free `TabletSnapshot`s, `TableCursor` in `table.rs` for the
//! re-locating streaming scanner): it resumes by key between blocks
//! and therefore composes with concurrent writers and tablet splits —
//! the live cursor by re-locking per block, the snapshot cursor by
//! never needing a lock at all after the pin. Filter stages are pushed
//! *beneath the block copy*: the cursors hand the spec's [`CellFilter`]
//! list to [`Tablet::scan_block`], which evaluates the matchers against
//! `&str` borrows of the stored bytes, so a rejected cell is never
//! copied out of the tablet and allocates nothing (an accepted cell is
//! three pointer clones of the stored shared bytes). Range hopping
//! happens down there too: a spec carries a sorted, coalesced *set* of
//! ranges, and when the tablet walk leaves one range's row span it
//! re-seeks the B-tree straight to the next range's start — one resume
//! key serves the whole set, so a thousand-row BFS frontier is one
//! stacked scan, not a thousand seeks. The combiner stage
//! wraps generically ([`ReduceIter`]; [`FilterIter`] remains for
//! client-side composition over non-tablet bases); nothing in the stack
//! ever materializes the full triple set — consumers pull one triple at
//! a time.
//!
//! **Determinism.** Every stage is a pure, order-preserving function of
//! the sorted triple stream, and the parallel collector in
//! `Table::scan_spec_par` cuts work at *row* boundaries (load-balanced
//! range chunks over pinned snapshots, independent of tablet layout) —
//! so a stacked scan is byte-identical to "naive scan, then filter,
//! then reduce" at every thread count and chunk granularity
//! (`rust/tests/scan_stack.rs` enforces this).

use super::lock::TrackedMutex;
use super::tablet::{Tablet, TabletSnapshot};
use super::{SharedStr, Triple};
use std::collections::BTreeSet;

/// A scan range: rows in `[lo, hi)` and, within each row, columns in
/// `[col_lo, col_hi)` — all unbounded when `None`. The column window is
/// applied *inside* the tablet cursor, which skips to the next row as
/// soon as a row's window is exhausted (Accumulo's column-qualifier
/// range seek), so out-of-window cells are never even copied out of the
/// tablet.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ScanRange {
    /// Inclusive lower row bound.
    pub lo: Option<String>,
    /// Exclusive upper row bound.
    pub hi: Option<String>,
    /// Inclusive lower column bound (per row).
    pub col_lo: Option<String>,
    /// Exclusive upper column bound (per row).
    pub col_hi: Option<String>,
}

impl ScanRange {
    /// The full-table range.
    pub fn all() -> Self {
        ScanRange::default()
    }

    /// Rows in `[lo, hi)`.
    pub fn rows(lo: impl Into<String>, hi: impl Into<String>) -> Self {
        ScanRange { lo: Some(lo.into()), hi: Some(hi.into()), ..ScanRange::default() }
    }

    /// Exactly one row.
    pub fn single(row: impl Into<String>) -> Self {
        let row = row.into();
        let mut hi = row.clone();
        hi.push('\0');
        ScanRange { lo: Some(row), hi: Some(hi), ..ScanRange::default() }
    }

    /// Restrict this range to columns in `[lo, hi)` within each row.
    pub fn with_cols(mut self, lo: impl Into<String>, hi: impl Into<String>) -> Self {
        self.col_lo = Some(lo.into());
        self.col_hi = Some(hi.into());
        self
    }

    /// Whether a tablet extent `[tab_lo, tab_hi)` overlaps the row
    /// range (the pruning test shared by every scan path).
    pub fn overlaps_extent(&self, tab_lo: Option<&str>, tab_hi: Option<&str>) -> bool {
        let past = matches!((self.hi.as_deref(), tab_lo), (Some(hi), Some(tlo)) if tlo >= hi);
        let before = matches!((self.lo.as_deref(), tab_hi), (Some(lo), Some(thi)) if thi <= lo);
        !(past || before)
    }

    /// Whether both ranges carry the same per-row column window (the
    /// precondition for merging their row spans).
    fn same_window(&self, other: &ScanRange) -> bool {
        self.col_lo == other.col_lo && self.col_hi == other.col_hi
    }
}

/// Order two exclusive upper bounds where `None` = +∞.
fn hi_cmp(a: Option<&str>, b: Option<&str>) -> std::cmp::Ordering {
    match (a, b) {
        (None, None) => std::cmp::Ordering::Equal,
        (None, Some(_)) => std::cmp::Ordering::Greater,
        (Some(_), None) => std::cmp::Ordering::Less,
        (Some(x), Some(y)) => x.cmp(y),
    }
}

/// Normalize a range set for a multi-range scan: sort, and merge
/// overlapping or adjacent row spans that carry the same column window
/// (`[a, b) ∪ [b, c) = [a, c)`; Accumulo's `Range.mergeOverlapping`).
/// Ranges with *different* column windows are never merged — the scan
/// walk unions them cell-by-cell instead. The result is sorted by row
/// lower bound (`None` first), the order [`Tablet::scan_block`]'s
/// range-hopping walk requires.
pub fn coalesce_ranges(mut ranges: Vec<ScanRange>) -> Vec<ScanRange> {
    // Window-major sort puts every mergeable pair adjacent; row-minor
    // keeps each window class in walk order for the merge pass.
    ranges.sort_by(|a, b| {
        (a.col_lo.as_deref(), a.col_hi.as_deref(), a.lo.as_deref())
            .cmp(&(b.col_lo.as_deref(), b.col_hi.as_deref(), b.lo.as_deref()))
            .then_with(|| hi_cmp(a.hi.as_deref(), b.hi.as_deref()))
    });
    let mut out: Vec<ScanRange> = Vec::with_capacity(ranges.len());
    for r in ranges {
        if let Some(last) = out.last_mut() {
            // Same window and the row spans touch (last.hi = None covers
            // everything after it; r.lo = None implies last.lo = None).
            let touches = last.same_window(&r)
                && match last.hi.as_deref() {
                    None => true,
                    Some(h) => r.lo.as_deref().is_none_or(|lo| lo <= h),
                };
            if touches {
                if hi_cmp(r.hi.as_deref(), last.hi.as_deref()).is_gt() {
                    last.hi = r.hi;
                }
                continue;
            }
        }
        out.push(r);
    }
    // Global walk order: row lower bound, ties broken deterministically.
    out.sort_by(|a, b| {
        a.lo.as_deref()
            .cmp(&b.lo.as_deref())
            .then_with(|| hi_cmp(a.hi.as_deref(), b.hi.as_deref()))
            .then_with(|| {
                (a.col_lo.as_deref(), a.col_hi.as_deref())
                    .cmp(&(b.col_lo.as_deref(), b.col_hi.as_deref()))
            })
    });
    out
}

/// Ensure a range set satisfies the walk's lo-sorted invariant,
/// normalizing hand-built specs that bypassed [`ScanSpec::ranges()`]
/// (`ScanSpec.ranges` is a public field): well-formed sets pay one
/// ordering check; misordered ones are coalesced — without this, the
/// tablet walk's monotonic range advance would silently drop cells.
pub(crate) fn ensure_walk_order(ranges: Vec<ScanRange>) -> Vec<ScanRange> {
    if ranges.windows(2).all(|w| w[0].lo <= w[1].lo) {
        ranges
    } else {
        coalesce_ranges(ranges)
    }
}

/// The overall exclusive row upper bound of a sorted range set
/// (`None` = unbounded). Callers must pass a non-empty set.
pub(crate) fn ranges_row_hi(ranges: &[ScanRange]) -> Option<&str> {
    let mut hi = ranges[0].hi.as_deref();
    for r in &ranges[1..] {
        if hi_cmp(r.hi.as_deref(), hi).is_gt() {
            hi = r.hi.as_deref();
        }
    }
    hi
}

/// Snap `row` forward onto a sorted range set: `Some(row)` when some
/// range's row span contains it, the next range's lower bound when it
/// sits in a gap, `None` when it lies past every range.
pub(crate) fn snap_row<'a>(ranges: &'a [ScanRange], row: &'a str) -> Option<&'a str> {
    for r in ranges {
        if r.hi.as_deref().is_some_and(|hi| row >= hi) {
            continue;
        }
        return match r.lo.as_deref() {
            Some(lo) if row < lo => Some(lo),
            _ => Some(row),
        };
    }
    None
}

/// Clamp a sorted, coalesced range set to the row span `[lo, hi)`
/// (`None` = unbounded): ranges outside the span are dropped, ranges
/// straddling a boundary are cut at it, column windows pass through
/// untouched. Sortedness is preserved (raising every `lo` to the same
/// floor keeps relative order), so the result feeds straight into the
/// block walk — this is how the per-range-chunk fan-out hands each
/// worker its row slice of the full spec.
pub(crate) fn clamp_ranges(
    ranges: &[ScanRange],
    lo: Option<&str>,
    hi: Option<&str>,
) -> Vec<ScanRange> {
    let mut out = Vec::new();
    for r in ranges {
        if !r.overlaps_extent(lo, hi) {
            continue;
        }
        let mut c = r.clone();
        if let Some(lo) = lo {
            if c.lo.as_deref().is_none_or(|rl| rl < lo) {
                c.lo = Some(lo.to_string());
            }
        }
        if let Some(hi) = hi {
            if c.hi.as_deref().is_none_or(|rh| rh > hi) {
                c.hi = Some(hi.to_string());
            }
        }
        out.push(c);
    }
    out
}

/// The column position a fresh walk of `row` starts at: the smallest
/// column-window start among the ranges whose row span contains `row`
/// (`""` when any containing window is unbounded below, or when no
/// range contains the row — the walk's own range hop corrects that).
pub(crate) fn start_col<'a>(ranges: &'a [ScanRange], row: &str) -> &'a str {
    let mut best: Option<&str> = None;
    for r in ranges {
        if r.lo.as_deref().is_some_and(|lo| row < lo) {
            break;
        }
        if r.hi.as_deref().is_some_and(|hi| row >= hi) {
            continue;
        }
        let cl = r.col_lo.as_deref().unwrap_or("");
        if best.is_none_or(|b| cl < b) {
            best = Some(cl);
        }
    }
    best.unwrap_or("")
}

/// A streaming iterator over sorted triples — the store's analogue of
/// Accumulo's `SortedKeyValueIterator`. Implementors yield triples in
/// strictly increasing `(row, col)` order.
pub trait ScanIter {
    /// Reposition so the next triple returned is the first one with key
    /// `>= (row, col)` (clamped to the scan's range). Seeks are
    /// absolute: they may move forward or backward. Seeking into the
    /// middle of a row under a [`RowReduce`] stage restarts that row's
    /// aggregate, so reduced scans should seek to row starts
    /// (`col = ""`).
    fn seek(&mut self, row: &str, col: &str);

    /// The next triple, or `None` when the scan is exhausted.
    fn next_triple(&mut self) -> Option<Triple>;
}

/// String matcher for filter stages (Accumulo's filter iterators reach
/// for Java regex; this store keeps an offline-friendly subset).
#[derive(Debug, Clone)]
pub enum KeyMatch {
    /// Exact equality.
    Equals(String),
    /// Prefix match.
    Prefix(String),
    /// Glob match: `*` = any sequence, `?` = any single char.
    Glob(String),
    /// Membership in an explicit key set.
    In(BTreeSet<String>),
}

impl KeyMatch {
    /// Whether `s` matches.
    pub fn matches(&self, s: &str) -> bool {
        match self {
            KeyMatch::Equals(k) => s == k,
            KeyMatch::Prefix(p) => s.starts_with(p.as_str()),
            KeyMatch::Glob(p) => glob_match(p, s),
            KeyMatch::In(set) => set.contains(s),
        }
    }

    /// The half-open key interval `[lo, hi)` (`hi` `None` = +∞) that
    /// contains exactly the accepted keys, when the matcher is
    /// interval-shaped: `Equals` and `Prefix` are; `In` decomposes into
    /// several intervals ([`KeyMatch::intervals`]); `Glob` is not.
    pub fn interval(&self) -> Option<(String, Option<String>)> {
        match self {
            KeyMatch::Equals(k) => Some((k.clone(), Some(format!("{k}\0")))),
            KeyMatch::Prefix(p) => Some((p.clone(), prefix_upper_bound(p))),
            KeyMatch::Glob(_) | KeyMatch::In(_) => None,
        }
    }

    /// Sorted, pairwise-disjoint half-open key intervals exactly
    /// covering the accepted keys, or `None` when the matcher is not
    /// interval-shaped (`Glob`). This is the raw material for the
    /// planner's filter-lowering rule: each interval becomes a per-row
    /// column window on a [`ScanRange`], so the block walk *seeks* past
    /// doomed cells instead of evaluating a predicate on each.
    pub fn intervals(&self) -> Option<Vec<(String, Option<String>)>> {
        match self {
            // `BTreeSet` iterates in sorted order; `[k, k\0)` intervals
            // of distinct keys never overlap.
            KeyMatch::In(set) => {
                Some(set.iter().map(|k| (k.clone(), Some(format!("{k}\0")))).collect())
            }
            KeyMatch::Glob(_) => None,
            _ => self.interval().map(|iv| vec![iv]),
        }
    }
}

/// Least string greater than every string carrying prefix `p` under
/// the store's byte-lexicographic order, or `None` when no finite
/// bound exists (`p` empty or entirely `char::MAX`). Strips trailing
/// `char::MAX` chars, then replaces the final char with its code-point
/// successor (hopping the surrogate gap). UTF-8 byte order equals
/// code-point order, so the replacement bounds every extension of the
/// prefix (Accumulo's `Range.prefix` followingKey construction).
fn prefix_upper_bound(p: &str) -> Option<String> {
    let mut s: String = p.trim_end_matches(char::MAX).to_string();
    let last = s.pop()?;
    let mut code = last as u32 + 1;
    if (0xD800..=0xDFFF).contains(&code) {
        code = 0xE000;
    }
    s.push(char::from_u32(code)?);
    Some(s)
}

/// Iterative glob matcher (`*` any sequence, `?` any one char) with the
/// classic single-star backtrack — linear in `s.len()` per star, and
/// allocation-free (it runs once per cell in the filter hot path).
/// Operates on bytes; literal multi-byte chars compare bytewise, `?`
/// consumes one full UTF-8 char, and the backtrack mark only ever
/// advances from char boundary to char boundary.
fn glob_match(pat: &str, s: &str) -> bool {
    let (p, t) = (pat.as_bytes(), s.as_bytes());
    // UTF-8 sequence length from a leading byte (only ever called on
    // char boundaries).
    let char_len = |b: u8| -> usize {
        match b {
            x if x < 0x80 => 1,
            x if x >= 0xF0 => 4,
            x if x >= 0xE0 => 3,
            _ => 2,
        }
    };
    let (mut pi, mut ti) = (0usize, 0usize);
    let mut star: Option<(usize, usize)> = None;
    while ti < t.len() {
        if pi < p.len() && p[pi] == b'?' {
            pi += 1;
            ti += char_len(t[ti]);
        } else if pi < p.len() && p[pi] != b'*' && p[pi] == t[ti] {
            pi += 1;
            ti += 1;
        } else if pi < p.len() && p[pi] == b'*' {
            star = Some((pi, ti));
            pi += 1;
        } else if let Some((sp, mark)) = star {
            let next = mark + char_len(t[mark]);
            star = Some((sp, next));
            pi = sp + 1;
            ti = next;
        } else {
            return false;
        }
    }
    p[pi..].iter().all(|&c| c == b'*')
}

/// Which part of a cell a [`CellFilter`] inspects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellField {
    /// The row key.
    Row,
    /// The column key.
    Col,
    /// The stored value.
    Val,
}

/// One predicate of a filter stage: match `field` against `matcher`.
#[derive(Debug, Clone)]
pub struct CellFilter {
    /// The cell part under test.
    pub field: CellField,
    /// The matcher applied to it.
    pub matcher: KeyMatch,
}

impl CellFilter {
    /// Filter on an arbitrary field.
    pub fn new(field: CellField, matcher: KeyMatch) -> Self {
        CellFilter { field, matcher }
    }

    /// Filter on the row key.
    pub fn row(matcher: KeyMatch) -> Self {
        Self::new(CellField::Row, matcher)
    }

    /// Filter on the column key.
    pub fn col(matcher: KeyMatch) -> Self {
        Self::new(CellField::Col, matcher)
    }

    /// Filter on the value.
    pub fn val(matcher: KeyMatch) -> Self {
        Self::new(CellField::Val, matcher)
    }

    /// Whether `t` passes this filter.
    pub fn matches(&self, t: &Triple) -> bool {
        self.matches_parts(&t.row, &t.col, &t.val)
    }

    /// [`CellFilter::matches`] against borrowed cell parts — the form
    /// the tablet cursor evaluates *beneath* the block copy, so cells
    /// can be rejected before any `Triple` (or any allocation) exists.
    pub fn matches_parts(&self, row: &str, col: &str, val: &str) -> bool {
        let s = match self.field {
            CellField::Row => row,
            CellField::Col => col,
            CellField::Val => val,
        };
        self.matcher.matches(s)
    }
}

/// Per-row combiner: collapse each row's (post-filter) cells into one
/// output triple `(row, out_col, aggregate)` — Accumulo's `Combiner`
/// specialized to the row axis (the degree-table reduction of the D4M
/// papers). Values parse as numbers; non-numeric values count as `0`.
#[derive(Debug, Clone)]
pub enum RowReduce {
    /// Cell count per row.
    Count {
        /// Output column key.
        out_col: String,
    },
    /// Numeric sum of the row's values.
    Sum {
        /// Output column key.
        out_col: String,
    },
    /// Numeric minimum of the row's values.
    Min {
        /// Output column key.
        out_col: String,
    },
    /// Numeric maximum of the row's values.
    Max {
        /// Output column key.
        out_col: String,
    },
}

impl RowReduce {
    fn out_col(&self) -> &str {
        match self {
            RowReduce::Count { out_col }
            | RowReduce::Sum { out_col }
            | RowReduce::Min { out_col }
            | RowReduce::Max { out_col } => out_col,
        }
    }
}

/// A configured scan stack: a *range set* at the bottom, then filters,
/// then an optional per-row combiner. Built fluently and handed to
/// `Table::scan_stream` / `Table::scan_spec_par`.
#[derive(Debug, Clone)]
pub struct ScanSpec {
    /// The range set at the base of the stack — sorted and coalesced
    /// ([`coalesce_ranges`]); the scan yields the sorted, deduplicated
    /// union of the per-range cells in one pass (Accumulo's
    /// `BatchScanner` handing the servers a set of `Range`s at once).
    /// One full range scans everything; an **empty set scans nothing**
    /// (the union of zero ranges). Build through [`ScanSpec::over`] /
    /// [`ScanSpec::ranges()`] to keep the invariant.
    pub ranges: Vec<ScanRange>,
    /// Filter stages, applied in order (all must pass) — pushed beneath
    /// the tablet block copy by the base cursors.
    pub filters: Vec<CellFilter>,
    /// Optional combiner stage at the top of the stack.
    pub reduce: Option<RowReduce>,
    /// Per-stream batch-size hint: the tablet block size a streaming
    /// scan starts at after open/seek (clamped to `1..=`[`SCAN_BLOCK`],
    /// still doubling up to [`SCAN_BLOCK`] as the stream runs). `None`
    /// uses the default ramp. Small hints fit point-lookup-heavy
    /// workloads (a row probe reads a handful of cells per seek —
    /// copying a 64-cell block to use 3 is pure waste); [`SCAN_BLOCK`]
    /// fits full-table and bulk multi-range scans, which skip the ramp
    /// entirely.
    pub batch: Option<usize>,
}

impl Default for ScanSpec {
    /// Scan everything (one unbounded range).
    fn default() -> Self {
        ScanSpec {
            ranges: vec![ScanRange::all()],
            filters: Vec::new(),
            reduce: None,
            batch: None,
        }
    }
}

impl ScanSpec {
    /// Scan everything.
    pub fn all() -> Self {
        ScanSpec::default()
    }

    /// Scan over a single `range`.
    pub fn over(range: ScanRange) -> Self {
        ScanSpec { ranges: vec![range], ..ScanSpec::default() }
    }

    /// Scan over the union of `ranges` in one stacked pass — the
    /// `BatchScanner` multi-range spec. The set is sorted and
    /// overlapping/adjacent same-window ranges are merged
    /// ([`coalesce_ranges`]), so results are the sorted, deduplicated
    /// union of the per-range scans; an empty iterator scans nothing.
    pub fn ranges(ranges: impl IntoIterator<Item = ScanRange>) -> Self {
        ScanSpec {
            ranges: coalesce_ranges(ranges.into_iter().collect()),
            ..ScanSpec::default()
        }
    }

    /// Add a filter stage.
    pub fn filtered(mut self, f: CellFilter) -> Self {
        self.filters.push(f);
        self
    }

    /// Set the combiner stage.
    pub fn reduced(mut self, r: RowReduce) -> Self {
        self.reduce = Some(r);
        self
    }

    /// Set the per-stream batch-size hint (see [`ScanSpec::batch`]).
    pub fn batched(mut self, hint: usize) -> Self {
        self.batch = Some(hint);
        self
    }
}

/// Render a numeric value the way the store writes it (integers without
/// a trailing `.0`) — shared by the combiner stage and graphulo's
/// result writers so reduced scans round-trip through tables.
pub fn format_num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

// ---------------------------------------------------------------------
// Stages
// ---------------------------------------------------------------------

/// Filter stage: passes through triples matching every [`CellFilter`].
/// An empty filter list is a free passthrough.
///
/// The tablet block cursors evaluate spec filters *beneath* the block
/// copy ([`Tablet::scan_block`]), so table scans no longer stack this
/// iterator; it remains for client-side composition over arbitrary
/// [`ScanIter`] bases (and as the reference the pushdown is tested
/// against).
pub struct FilterIter<I> {
    inner: I,
    filters: Vec<CellFilter>,
}

impl<I: ScanIter> FilterIter<I> {
    /// Wrap `inner` with `filters`.
    pub fn new(inner: I, filters: Vec<CellFilter>) -> Self {
        FilterIter { inner, filters }
    }
}

impl<I: ScanIter> ScanIter for FilterIter<I> {
    fn seek(&mut self, row: &str, col: &str) {
        self.inner.seek(row, col);
    }

    fn next_triple(&mut self) -> Option<Triple> {
        loop {
            let t = self.inner.next_triple()?;
            if self.filters.iter().all(|f| f.matches(&t)) {
                return Some(t);
            }
        }
    }
}

/// Combiner stage: folds each row's cells into one triple as the stream
/// passes through (constant state — one row in flight). `None` reduce
/// is a free passthrough.
pub struct ReduceIter<I> {
    inner: I,
    reduce: Option<RowReduce>,
    row: Option<SharedStr>,
    count: usize,
    acc: f64,
    exhausted: bool,
}

impl<I: ScanIter> ReduceIter<I> {
    /// Wrap `inner` with an optional combiner.
    pub fn new(inner: I, reduce: Option<RowReduce>) -> Self {
        ReduceIter { inner, reduce, row: None, count: 0, acc: 0.0, exhausted: false }
    }

    /// Emit the in-flight row's aggregate, if any.
    fn emit(&mut self) -> Option<Triple> {
        let row = self.row.take()?;
        let r = self.reduce.as_ref().expect("emit only under a reduce");
        let val = match r {
            RowReduce::Count { .. } => self.count.to_string(),
            _ => format_num(self.acc),
        };
        Some(Triple::new(row, r.out_col(), val))
    }

    /// Start a fresh row aggregate from its first cell.
    fn start(&mut self, t: &Triple) {
        self.row = Some(t.row.clone());
        self.count = 1;
        self.acc = t.val.parse().unwrap_or(0.0);
    }

    /// Fold one more cell of the current row.
    fn fold(&mut self, t: &Triple) {
        self.count += 1;
        let v: f64 = t.val.parse().unwrap_or(0.0);
        match self.reduce.as_ref().expect("fold only under a reduce") {
            RowReduce::Count { .. } => {}
            RowReduce::Sum { .. } => self.acc += v,
            RowReduce::Min { .. } => self.acc = self.acc.min(v),
            RowReduce::Max { .. } => self.acc = self.acc.max(v),
        }
    }
}

impl<I: ScanIter> ScanIter for ReduceIter<I> {
    fn seek(&mut self, row: &str, col: &str) {
        self.inner.seek(row, col);
        self.row = None;
        self.count = 0;
        self.acc = 0.0;
        self.exhausted = false;
    }

    fn next_triple(&mut self) -> Option<Triple> {
        if self.reduce.is_none() {
            return self.inner.next_triple();
        }
        if self.exhausted {
            return None;
        }
        loop {
            match self.inner.next_triple() {
                None => {
                    self.exhausted = true;
                    return self.emit();
                }
                Some(t) => {
                    if self.row.as_deref() == Some(t.row.as_str()) {
                        self.fold(&t);
                    } else {
                        let out = self.emit();
                        self.start(&t);
                        if out.is_some() {
                            return out;
                        }
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Base cursor over a pinned tablet list
// ---------------------------------------------------------------------

/// Triples copied out of a tablet per lock acquisition. Blocks bound
/// lock hold time (writers interleave between blocks) and amortize the
/// `BTreeMap` re-seek. Doubles as the *examined*-cells floor of
/// [`Tablet::scan_block`]'s per-call cap — a selective pushed-down
/// filter yields the lock after examining this many cells even when it
/// emitted none — and as the ceiling of the per-stream batch-size ramp
/// ([`ScanSpec::batch`]).
pub const SCAN_BLOCK: usize = 2048;

/// Block cursor over an explicit, pinned tablet list — the base
/// iterator used by `Table::scan_spec_par`, which resolves the in-range
/// tablets under the table's read lock and hands each parallel worker a
/// contiguous sub-list. Holds no tablet lock between blocks; resumes by
/// key; evaluates the spec's filters beneath the tablet block copy.
pub struct SliceCursor<'t> {
    tablets: &'t [TrackedMutex<Tablet>],
    live: Vec<usize>,
    ranges: Vec<ScanRange>,
    filters: Vec<CellFilter>,
    /// Position in `live`.
    ti: usize,
    /// Resume key: `(row, col, inclusive)`; `None` = range start.
    resume: Option<(SharedStr, SharedStr, bool)>,
    /// Current block, reversed so consuming is a pop (a move, not a
    /// clone — the cell stays a pointer handle end to end).
    buf: Vec<Triple>,
    done: bool,
}

impl<'t> SliceCursor<'t> {
    /// Cursor over `live` (indices into `tablets`, in row order),
    /// restricted to the sorted, coalesced range set `ranges`, with
    /// `filters` pushed into the tablet block scan.
    pub fn new(
        tablets: &'t [TrackedMutex<Tablet>],
        live: Vec<usize>,
        ranges: Vec<ScanRange>,
        filters: Vec<CellFilter>,
    ) -> Self {
        let done = ranges.is_empty();
        SliceCursor {
            tablets,
            live,
            ranges,
            filters,
            ti: 0,
            resume: None,
            buf: Vec::new(),
            done,
        }
    }

    fn refill(&mut self) {
        self.buf.clear();
        while self.ti < self.live.len() {
            let tab = self.tablets[self.live[self.ti]].lock().unwrap();
            let from = self.resume.as_ref().map(|(r, c, inc)| (r.as_str(), c.as_str(), *inc));
            let more =
                tab.scan_block(from, &self.ranges, &self.filters, SCAN_BLOCK, &mut self.buf);
            drop(tab);
            match more {
                None => {
                    // Done with this tablet — advance now so a partial
                    // final block doesn't cost an extra lock + re-seek
                    // round trip.
                    self.ti += 1;
                    self.resume = None;
                    if !self.buf.is_empty() {
                        self.buf.reverse();
                        return;
                    }
                }
                Some((row, col)) => {
                    self.resume = Some((row, col, false));
                    if !self.buf.is_empty() {
                        self.buf.reverse();
                        return;
                    }
                    // The examined cap fired on an all-rejected block:
                    // loop — the lock was released above, so writers
                    // interleave here.
                }
            }
        }
        self.done = true;
    }
}

impl ScanIter for SliceCursor<'_> {
    fn seek(&mut self, row: &str, col: &str) {
        self.buf.clear();
        if self.ranges.is_empty() {
            self.done = true;
            return;
        }
        self.done = false;
        // Clamp the target to the range-set start (targets inside a gap
        // are hopped forward by the tablet walk itself).
        let (row, col) = match self.ranges[0].lo.as_deref() {
            Some(lo) if row < lo => (lo, ""),
            _ => (row, col),
        };
        self.resume = Some((row.into(), col.into(), true));
        // First tablet whose extent may still hold keys >= row.
        self.ti = 0;
        while self.ti < self.live.len() {
            let tab = self.tablets[self.live[self.ti]].lock().unwrap();
            let past = tab.hi.as_deref().is_some_and(|hi| hi <= row);
            drop(tab);
            if !past {
                break;
            }
            self.ti += 1;
        }
    }

    fn next_triple(&mut self) -> Option<Triple> {
        loop {
            if let Some(t) = self.buf.pop() {
                return Some(t);
            }
            if self.done {
                return None;
            }
            self.refill();
        }
    }
}

/// Block cursor over a pinned [`TabletSnapshot`] list — the lock-free
/// base iterator under snapshot scans (`Table::scan_snapshot`). Same
/// walk, same resume discipline, and same block-at-a-time yield points
/// as [`SliceCursor`], but every block comes from immutable pinned
/// state: after construction **no lock is ever acquired** (the
/// examined-cells cap is only a yield point here, kept so snapshot
/// refresh/cancellation hooks have somewhere to run). Results are
/// bit-identical to a locked scan of the same state by construction —
/// both cursors drive the one shared `walk_block` engine.
pub struct SnapCursor<'s> {
    snaps: &'s [TabletSnapshot],
    ranges: Vec<ScanRange>,
    filters: Vec<CellFilter>,
    /// Position in `snaps`.
    ti: usize,
    /// Resume key: `(row, col, inclusive)`; `None` = range start.
    resume: Option<(SharedStr, SharedStr, bool)>,
    /// Current block, reversed so consuming is a pop.
    buf: Vec<Triple>,
    done: bool,
}

impl<'s> SnapCursor<'s> {
    /// Cursor over `snaps` (pinned snapshots in row order), restricted
    /// to the sorted, coalesced range set `ranges`, with `filters`
    /// pushed into the snapshot block scan. Out-of-range snapshots are
    /// skipped inline (no pre-pruned index list — pruning a pinned
    /// snapshot costs one extent comparison, not a lock).
    pub fn new(
        snaps: &'s [TabletSnapshot],
        ranges: Vec<ScanRange>,
        filters: Vec<CellFilter>,
    ) -> Self {
        let done = ranges.is_empty();
        SnapCursor { snaps, ranges, filters, ti: 0, resume: None, buf: Vec::new(), done }
    }

    fn refill(&mut self) {
        self.buf.clear();
        while self.ti < self.snaps.len() {
            let snap = &self.snaps[self.ti];
            if !self
                .ranges
                .iter()
                .any(|r| r.overlaps_extent(snap.lo.as_deref(), snap.hi.as_deref()))
            {
                self.ti += 1;
                self.resume = None;
                continue;
            }
            let from = self.resume.as_ref().map(|(r, c, inc)| (r.as_str(), c.as_str(), *inc));
            let more =
                snap.scan_block(from, &self.ranges, &self.filters, SCAN_BLOCK, &mut self.buf);
            match more {
                None => {
                    self.ti += 1;
                    self.resume = None;
                    if !self.buf.is_empty() {
                        self.buf.reverse();
                        return;
                    }
                }
                Some((row, col)) => {
                    self.resume = Some((row, col, false));
                    if !self.buf.is_empty() {
                        self.buf.reverse();
                        return;
                    }
                    // Examined cap fired on an all-rejected block —
                    // just a yield point on the lock-free path; loop.
                }
            }
        }
        self.done = true;
    }
}

impl ScanIter for SnapCursor<'_> {
    fn seek(&mut self, row: &str, col: &str) {
        self.buf.clear();
        if self.ranges.is_empty() {
            self.done = true;
            return;
        }
        self.done = false;
        // Clamp the target to the range-set start (targets inside a gap
        // are hopped forward by the walk itself).
        let (row, col) = match self.ranges[0].lo.as_deref() {
            Some(lo) if row < lo => (lo, ""),
            _ => (row, col),
        };
        self.resume = Some((row.into(), col.into(), true));
        // First snapshot whose extent may still hold keys >= row — an
        // extent comparison per snapshot, no locks.
        self.ti = 0;
        while self.ti < self.snaps.len() {
            let past = self.snaps[self.ti].hi.as_deref().is_some_and(|hi| hi <= row);
            if !past {
                break;
            }
            self.ti += 1;
        }
    }

    fn next_triple(&mut self) -> Option<Triple> {
        loop {
            if let Some(t) = self.buf.pop() {
                return Some(t);
            }
            if self.done {
                return None;
            }
            self.refill();
        }
    }
}

/// Run the stack over a base iterator that already applies the spec's
/// filters (both block cursors do) and collect the result — the shared
/// consumer behind `Table::scan_spec_par`'s serial path and each
/// parallel worker.
pub(crate) fn stack_collect<I: ScanIter>(base: I, spec: &ScanSpec) -> Vec<Triple> {
    let mut it = ReduceIter::new(base, spec.reduce.clone());
    let mut out = Vec::new();
    while let Some(t) = it.next_triple() {
        out.push(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glob_matching() {
        assert!(glob_match("", ""));
        assert!(glob_match("*", "anything"));
        assert!(glob_match("a*c", "abc"));
        assert!(glob_match("a*c", "ac"));
        assert!(glob_match("a*c", "axxxc"));
        assert!(!glob_match("a*c", "abd"));
        assert!(glob_match("a?c", "abc"));
        assert!(!glob_match("a?c", "ac"));
        assert!(glob_match("*page0?", "/page01"));
        assert!(glob_match("c*1*", "c011x"));
        assert!(!glob_match("c*1*", "c000"));
        assert!(glob_match("**a", "a"));
        assert!(!glob_match("b*", "ab"));
    }

    #[test]
    fn key_match_variants() {
        assert!(KeyMatch::Equals("x".into()).matches("x"));
        assert!(!KeyMatch::Equals("x".into()).matches("xy"));
        assert!(KeyMatch::Prefix("ro".into()).matches("row1"));
        assert!(!KeyMatch::Prefix("ro".into()).matches("r1"));
        let set: BTreeSet<String> = ["a", "b"].iter().map(|s| s.to_string()).collect();
        assert!(KeyMatch::In(set.clone()).matches("a"));
        assert!(!KeyMatch::In(set).matches("c"));
    }

    #[test]
    fn prefix_upper_bounds() {
        assert_eq!(prefix_upper_bound("abc"), Some("abd".to_string()));
        assert_eq!(prefix_upper_bound("c0"), Some("c1".to_string()));
        assert_eq!(prefix_upper_bound("a\u{D7FF}"), Some("a\u{E000}".to_string()));
        // Trailing MAX chars fall back to bumping the preceding char.
        assert_eq!(prefix_upper_bound("a\u{10FFFF}"), Some("b".to_string()));
        assert_eq!(prefix_upper_bound("\u{10FFFF}"), None);
        assert_eq!(prefix_upper_bound(""), None);
    }

    /// Interval membership `[lo, hi)` under plain string order.
    fn in_iv(iv: &(String, Option<String>), s: &str) -> bool {
        s >= iv.0.as_str() && iv.1.as_deref().is_none_or(|hi| s < hi)
    }

    #[test]
    fn key_match_intervals_cover_exactly_the_matches() {
        let samples =
            ["", "a", "ab", "abc", "abcd", "ab\u{0}", "abd", "b", "c0", "c00", "c1", "z"];
        let set: BTreeSet<String> = ["ab", "c0"].iter().map(|s| s.to_string()).collect();
        let cases = [
            KeyMatch::Equals("ab".into()),
            KeyMatch::Prefix("ab".into()),
            KeyMatch::Prefix("".into()),
            KeyMatch::In(set),
        ];
        for m in &cases {
            let ivs = m.intervals().expect("interval-shaped matcher");
            for s in samples {
                let covered = ivs.iter().any(|iv| in_iv(iv, s));
                assert_eq!(covered, m.matches(s), "matcher {m:?} key {s:?}");
            }
            // Sorted and disjoint: each interval's hi <= the next lo.
            for w in ivs.windows(2) {
                let hi = w[0].1.as_deref().expect("non-final interval is bounded");
                assert!(hi <= w[1].0.as_str(), "overlapping intervals {w:?}");
            }
        }
        assert!(KeyMatch::Glob("c*".into()).intervals().is_none());
    }

    #[test]
    fn coalesce_merges_sorts_and_keeps_windows_apart() {
        // Overlapping + adjacent same-window ranges merge.
        let got = coalesce_ranges(vec![
            ScanRange::rows("m", "p"),
            ScanRange::rows("a", "c"),
            ScanRange::rows("b", "d"),
            ScanRange::rows("d", "f"),
        ]);
        assert_eq!(got.len(), 2);
        assert_eq!((got[0].lo.as_deref(), got[0].hi.as_deref()), (Some("a"), Some("f")));
        assert_eq!((got[1].lo.as_deref(), got[1].hi.as_deref()), (Some("m"), Some("p")));
        // Duplicate singles collapse.
        let got = coalesce_ranges(vec![ScanRange::single("r"), ScanRange::single("r")]);
        assert_eq!(got.len(), 1);
        // Unbounded-above swallows everything after it.
        let got = coalesce_ranges(vec![
            ScanRange { lo: Some("c".into()), hi: None, ..ScanRange::default() },
            ScanRange::rows("d", "f"),
        ]);
        assert_eq!(got.len(), 1);
        assert_eq!((got[0].lo.as_deref(), got[0].hi.as_deref()), (Some("c"), None));
        // A contained range disappears into its container.
        let got = coalesce_ranges(vec![ScanRange::rows("a", "z"), ScanRange::rows("b", "c")]);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].hi.as_deref(), Some("z"));
        // Different column windows never merge, even on touching rows.
        let got = coalesce_ranges(vec![
            ScanRange::rows("a", "c").with_cols("x", "y"),
            ScanRange::rows("c", "e"),
        ]);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].col_lo.as_deref(), Some("x"));
        assert!(got[1].col_lo.is_none());
        // Empty in, empty out.
        assert!(coalesce_ranges(Vec::new()).is_empty());
    }

    #[test]
    fn range_set_helpers() {
        let rs = coalesce_ranges(vec![ScanRange::rows("a", "c"), ScanRange::rows("f", "h")]);
        assert_eq!(ranges_row_hi(&rs), Some("h"));
        assert_eq!(
            ranges_row_hi(&[ScanRange::rows("a", "c"), ScanRange::all()]),
            None
        );
        // snap_row: inside, gap, before, past.
        assert_eq!(snap_row(&rs, "b"), Some("b"));
        assert_eq!(snap_row(&rs, "d"), Some("f"));
        assert_eq!(snap_row(&rs, ""), Some("a"));
        assert_eq!(snap_row(&rs, "x"), None);
        // start_col picks the smallest containing window start.
        let ws = coalesce_ranges(vec![
            ScanRange::rows("a", "m").with_cols("q", "r"),
            ScanRange::rows("b", "m").with_cols("c", "d"),
        ]);
        assert_eq!(start_col(&ws, "a"), "q");
        assert_eq!(start_col(&ws, "b"), "c");
        assert_eq!(start_col(&ws, "z"), "");
    }

    #[test]
    fn clamp_ranges_cuts_at_row_bounds() {
        let rs = coalesce_ranges(vec![
            ScanRange::rows("a", "f").with_cols("x", "y"),
            ScanRange::rows("m", "p"),
        ]);
        let got = clamp_ranges(&rs, Some("c"), Some("n"));
        assert_eq!(got.len(), 2);
        assert_eq!((got[0].lo.as_deref(), got[0].hi.as_deref()), (Some("c"), Some("f")));
        assert_eq!(got[0].col_lo.as_deref(), Some("x"));
        assert_eq!((got[1].lo.as_deref(), got[1].hi.as_deref()), (Some("m"), Some("n")));
        // Fully-outside ranges drop; unbounded chunk sides pass through.
        assert!(clamp_ranges(&rs, Some("q"), None).is_empty());
        let all = clamp_ranges(&rs, None, None);
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].lo.as_deref(), Some("a"));
    }

    #[test]
    fn spec_ranges_builder_and_empty_set() {
        let spec = ScanSpec::ranges([
            ScanRange::single("b"),
            ScanRange::single("a"),
            ScanRange::single("b"),
        ]);
        assert_eq!(spec.ranges.len(), 2);
        assert_eq!(spec.ranges[0].lo.as_deref(), Some("a"));
        // Default spec scans everything; an explicit empty set, nothing.
        assert_eq!(ScanSpec::all().ranges.len(), 1);
        assert!(ScanSpec::ranges(Vec::new()).ranges.is_empty());
    }

    #[test]
    fn range_overlap_pruning() {
        let r = ScanRange::rows("f", "m");
        assert!(r.overlaps_extent(None, None));
        assert!(r.overlaps_extent(None, Some("g")));
        assert!(!r.overlaps_extent(None, Some("f"))); // tablet ends at range start
        assert!(r.overlaps_extent(Some("l"), None));
        assert!(!r.overlaps_extent(Some("m"), None)); // tablet starts at range end
        assert!(ScanRange::all().overlaps_extent(Some("a"), Some("b")));
    }

    /// Vec-backed ScanIter for stage unit tests.
    struct VecIter {
        data: Vec<Triple>,
        pos: usize,
    }

    impl ScanIter for VecIter {
        fn seek(&mut self, row: &str, col: &str) {
            self.pos =
                self.data.partition_point(|t| (t.row.as_str(), t.col.as_str()) < (row, col));
        }

        fn next_triple(&mut self) -> Option<Triple> {
            let t = self.data.get(self.pos).cloned();
            self.pos += 1;
            t
        }
    }

    fn cells() -> Vec<Triple> {
        vec![
            Triple::new("a", "c1", "1"),
            Triple::new("a", "c2", "5"),
            Triple::new("b", "c1", "2"),
            Triple::new("c", "c3", "4"),
            Triple::new("c", "c4", "x"),
        ]
    }

    #[test]
    fn filter_stage_keeps_matches() {
        let mut it = FilterIter::new(
            VecIter { data: cells(), pos: 0 },
            vec![CellFilter::col(KeyMatch::Equals("c1".into()))],
        );
        let mut got = Vec::new();
        while let Some(t) = it.next_triple() {
            got.push((t.row, t.val));
        }
        assert_eq!(got, vec![("a".into(), "1".into()), ("b".into(), "2".into())]);
    }

    #[test]
    fn reduce_stage_counts_and_sums() {
        let count = RowReduce::Count { out_col: "n".into() };
        let mut it = ReduceIter::new(VecIter { data: cells(), pos: 0 }, Some(count));
        let mut got = Vec::new();
        while let Some(t) = it.next_triple() {
            got.push(format!("{}:{}={}", t.row, t.col, t.val));
        }
        assert_eq!(got, vec!["a:n=2", "b:n=1", "c:n=2"]);

        let sum = RowReduce::Sum { out_col: "s".into() };
        let mut it = ReduceIter::new(VecIter { data: cells(), pos: 0 }, Some(sum));
        let mut got = Vec::new();
        while let Some(t) = it.next_triple() {
            got.push(format!("{}={}", t.row, t.val));
        }
        // "x" parses as 0.
        assert_eq!(got, vec!["a=6", "b=2", "c=4"]);
    }

    #[test]
    fn reduce_min_max_and_format() {
        let min = RowReduce::Min { out_col: "m".into() };
        let mut it = ReduceIter::new(VecIter { data: cells(), pos: 0 }, Some(min));
        let mut got = Vec::new();
        while let Some(t) = it.next_triple() {
            got.push(format!("{}={}", t.row, t.val));
        }
        assert_eq!(got, vec!["a=1", "b=2", "c=0"]);
        assert_eq!(format_num(2.0), "2");
        assert_eq!(format_num(2.5), "2.5");
    }

    #[test]
    fn passthrough_stages_are_identity() {
        let base = cells();
        let mut it =
            ReduceIter::new(FilterIter::new(VecIter { data: cells(), pos: 0 }, Vec::new()), None);
        let mut got = Vec::new();
        while let Some(t) = it.next_triple() {
            got.push(t);
        }
        assert_eq!(got, base);
    }

    #[test]
    fn stage_seek_forwards_and_resets() {
        let count = RowReduce::Count { out_col: "n".into() };
        let mut it = ReduceIter::new(
            FilterIter::new(VecIter { data: cells(), pos: 0 }, Vec::new()),
            Some(count),
        );
        // Consume one reduced row, seek back to the start: full replay.
        assert_eq!(it.next_triple().unwrap().row, "a");
        it.seek("", "");
        let mut rows = Vec::new();
        while let Some(t) = it.next_triple() {
            rows.push(t.row);
        }
        assert_eq!(rows, vec!["a", "b", "c"]);
    }
}
