//! Buffered batch writer — the Accumulo `BatchWriter` pattern.
//!
//! Mutations accumulate in a local buffer and flush to the table when
//! the buffer reaches [`WriterConfig::batch_bytes`] (or on `flush`/drop).
//! Batching amortizes per-write locking and is the single biggest
//! ingest-throughput lever (the `store_ingest` bench sweeps it).

use super::{StoreError, Table, Triple};
use std::sync::Arc;

/// Batch-writer tuning.
#[derive(Debug, Clone)]
pub struct WriterConfig {
    /// Flush when buffered triples reach this many bytes.
    pub batch_bytes: usize,
    /// Retries for transient (offline-tablet) failures.
    pub max_retries: usize,
    /// Backoff between retries.
    pub retry_backoff: std::time::Duration,
}

impl Default for WriterConfig {
    fn default() -> Self {
        WriterConfig {
            batch_bytes: 1 << 20,
            max_retries: 3,
            retry_backoff: std::time::Duration::from_millis(1),
        }
    }
}

/// Buffered writer bound to one table.
pub struct BatchWriter {
    table: Arc<Table>,
    config: WriterConfig,
    buffer: Vec<Triple>,
    buffered_bytes: usize,
    /// Total triples successfully written.
    pub written: usize,
    /// Flushes performed.
    pub flushes: usize,
    /// Transient failures retried.
    pub retries: usize,
}

impl BatchWriter {
    /// New writer for `table`.
    pub fn new(table: Arc<Table>, config: WriterConfig) -> Self {
        BatchWriter {
            table,
            config,
            buffer: Vec::new(),
            buffered_bytes: 0,
            written: 0,
            flushes: 0,
            retries: 0,
        }
    }

    /// Buffer one triple, flushing if the buffer is full. A failed
    /// threshold flush keeps the data buffered (see [`BatchWriter::flush`]);
    /// the error resurfaces on the next explicit `flush`/`sync`.
    pub fn put(&mut self, t: Triple) {
        self.buffered_bytes += t.weight();
        self.buffer.push(t);
        if self.buffered_bytes >= self.config.batch_bytes {
            let _ = self.flush();
        }
    }

    /// Buffer many triples.
    pub fn put_all(&mut self, ts: impl IntoIterator<Item = Triple>) {
        for t in ts {
            self.put(t);
        }
    }

    /// Drain a server-side scan stack into this writer (the
    /// scan-transform-write shape of every Graphulo kernel), returning
    /// the number of triples buffered. The stream stays block-buffered
    /// end to end — nothing is materialized beyond the write buffer.
    pub fn put_scan(&mut self, mut scan: impl super::ScanIter) -> usize {
        let mut n = 0usize;
        while let Some(t) = scan.next_triple() {
            self.put(t);
            n += 1;
        }
        n
    }

    /// Flush the buffer, retrying transient failures (offline tablets,
    /// retryable storage I/O) up to `max_retries` with `retry_backoff`
    /// between attempts. Returns the number of triples written.
    ///
    /// On failure the buffered mutations are **retained**: the error is
    /// returned, nothing is lost, and a later `flush` (after the tablet
    /// comes back or the storage heals) retries the same data. This is
    /// the writer-side half of graceful degradation — Accumulo's
    /// `MutationsRejectedException` without the data loss.
    pub fn flush(&mut self) -> Result<usize, StoreError> {
        if self.buffer.is_empty() {
            return Ok(0);
        }
        let mut attempt = 0;
        loop {
            // `write_batch` consumes its argument, so the buffer is
            // cloned per attempt and only cleared on success.
            match self.table.write_batch(self.buffer.clone()) {
                Ok(n) => {
                    self.buffer.clear();
                    self.buffered_bytes = 0;
                    self.written += n;
                    self.flushes += 1;
                    return Ok(n);
                }
                Err(e) if e.is_transient() && attempt < self.config.max_retries => {
                    attempt += 1;
                    self.retries += 1;
                    std::thread::sleep(self.config.retry_backoff);
                    continue;
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Triples currently buffered (retained across failed flushes).
    pub fn buffered(&self) -> usize {
        self.buffer.len()
    }

    /// Flush, then force the table's write-ahead log to stable storage
    /// (no-op for in-memory tables) — the writer-side durability
    /// barrier: when this returns, every `put` so far survives a crash.
    pub fn sync(&mut self) -> std::io::Result<()> {
        self.flush().map_err(std::io::Error::other)?;
        self.table.sync()
    }
}

impl Drop for BatchWriter {
    fn drop(&mut self) {
        // Best-effort final flush (ignore failures during unwind).
        if !std::thread::panicking() {
            let _ = self.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::{ScanRange, TableConfig};

    fn table() -> Arc<Table> {
        Arc::new(Table::new("t", TableConfig::default()))
    }

    #[test]
    fn buffers_and_flushes_on_threshold() {
        let t = table();
        let mut w = BatchWriter::new(
            Arc::clone(&t),
            WriterConfig { batch_bytes: 30, ..Default::default() },
        );
        // Each triple is 11 bytes => flush on the 3rd put.
        for i in 0..3 {
            w.put(Triple::new(format!("row{i}"), "col", "val"));
        }
        assert_eq!(w.flushes, 1);
        assert_eq!(w.written, 3);
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn explicit_flush_and_drop() {
        let t = table();
        {
            let mut w = BatchWriter::new(Arc::clone(&t), WriterConfig::default());
            w.put(Triple::new("a", "b", "c"));
            w.flush().unwrap();
            assert_eq!(t.len(), 1);
            w.put(Triple::new("d", "e", "f"));
        } // drop flushes the second triple
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn put_all_bulk() {
        let t = table();
        let mut w = BatchWriter::new(Arc::clone(&t), WriterConfig::default());
        w.put_all((0..100).map(|i| Triple::new(format!("r{i}"), "c", "v")));
        w.flush().unwrap();
        assert_eq!(t.len(), 100);
        assert_eq!(t.scan(ScanRange::all()).len(), 100);
    }

    #[test]
    fn empty_flush_is_noop() {
        let t = table();
        let mut w = BatchWriter::new(Arc::clone(&t), WriterConfig::default());
        assert_eq!(w.flush().unwrap(), 0);
        assert_eq!(w.flushes, 0);
    }

    #[test]
    fn failed_flush_retains_buffer_for_retry() {
        // Regression: a flush that exhausts its retries must keep the
        // buffered mutations so a later flush (after the failure heals)
        // writes them — not silently drop or panic.
        let t = table();
        t.set_tablet_offline(0, true);
        let mut w = BatchWriter::new(
            Arc::clone(&t),
            WriterConfig {
                max_retries: 1,
                retry_backoff: std::time::Duration::from_millis(0),
                ..Default::default()
            },
        );
        w.put(Triple::new("a", "b", "c"));
        let err = w.flush().unwrap_err();
        assert!(err.is_transient(), "offline tablet is retryable: {err}");
        assert_eq!(w.buffered(), 1, "buffer retained after failed flush");
        assert_eq!(w.written, 0);
        assert_eq!(t.len(), 0);
        // The failure heals; the same writer delivers the same data.
        t.set_tablet_offline(0, false);
        assert_eq!(w.flush().unwrap(), 1);
        assert_eq!(w.buffered(), 0);
        assert_eq!(t.len(), 1);
        assert_eq!(t.get("a", "b"), Some("c".into()));
    }

    #[test]
    fn sync_is_a_durability_barrier() {
        use crate::store::FsyncPolicy;
        let dir = std::env::temp_dir().join("d4m-writer-sync-test");
        let _ = std::fs::remove_dir_all(&dir);
        let t = Arc::new(
            Table::durable("t", TableConfig::default(), &dir, FsyncPolicy::Never).unwrap(),
        );
        let mut w = BatchWriter::new(Arc::clone(&t), WriterConfig::default());
        w.put(Triple::new("a", "b", "c"));
        w.sync().unwrap();
        assert_eq!(t.len(), 1);
        drop(w);
        drop(t);
        let r = Table::recover("t", TableConfig::default(), &dir, FsyncPolicy::Never).unwrap();
        assert_eq!(r.get("a", "b"), Some("c".into()));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
