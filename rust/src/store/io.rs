//! The storage I/O boundary: everything in `store/` that touches the
//! filesystem goes through [`StorageIo`].
//!
//! Two implementations exist: [`RealIo`] (plain `std::fs`) and
//! [`FaultyIo`], a deterministic fault injector that counts every I/O
//! operation and fails chosen operation indices according to a
//! [`FaultPlan`] — transient errors, permanent errors, short writes,
//! fsync failures, ENOSPC, and silent payload corruption. Because the
//! operation counter is the schedule key, a `(workload, plan)` pair
//! reproduces the exact same failure on every run; `tests/fault_injection.rs`
//! sweeps a fault over *every* operation index of a workload and asserts
//! the store stays prefix-consistent.
//!
//! [`StorageIo::write_atomic`] is the tmp + fsync + rename idiom used for
//! run files and the manifest: readers observe either the old bytes or
//! the complete new bytes, never a torn file.

use std::collections::BTreeMap;
use std::fmt::Debug;
use std::fs::{File, OpenOptions};
use std::io::{self, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// An open, writable storage file (WAL segment or similar append
/// stream). Writes are unbuffered from the caller's point of view: when
/// `write_all` returns `Ok`, the bytes have been handed to the OS.
pub trait StorageFile: Send + Debug {
    /// Write all of `buf` at the current position.
    fn write_all(&mut self, buf: &[u8]) -> io::Result<()>;
    /// Flush file *data* to stable storage (`fdatasync`).
    fn sync_data(&mut self) -> io::Result<()>;
    /// Truncate the file to `len` bytes and reposition the write cursor
    /// at the new end — used to repair a torn tail before re-appending.
    fn truncate(&mut self, len: u64) -> io::Result<()>;
    /// Current size of the file in bytes (metadata read, not counted as
    /// a faultable operation).
    fn size(&self) -> io::Result<u64>;
}

/// The filesystem surface the durable tier is allowed to use.
pub trait StorageIo: Send + Sync + Debug {
    /// Create (truncate) `path` for writing.
    fn create(&self, path: &Path) -> io::Result<Box<dyn StorageFile>>;
    /// Open `path` for appending (created if absent).
    fn open_append(&self, path: &Path) -> io::Result<Box<dyn StorageFile>>;
    /// Read the entire file.
    fn read(&self, path: &Path) -> io::Result<Vec<u8>>;
    /// Read exactly `len` bytes starting at `offset`. A file shorter
    /// than `offset + len` is an `UnexpectedEof` error — run blocks and
    /// footers are always read with an exact length from the index, so
    /// a short read means truncation, never a partial tail. This is the
    /// paged-run fault path: counted (and corruptible) by [`FaultyIo`]
    /// per call, so a fault can land on one block read without touching
    /// its neighbors.
    fn read_range(&self, path: &Path, offset: u64, len: usize) -> io::Result<Vec<u8>>;
    /// Atomically replace `path` with `bytes`: write `<path>.tmp`, fsync
    /// it, rename over `path`. Readers never see a partial file.
    fn write_atomic(&self, path: &Path, bytes: &[u8]) -> io::Result<()>;
    /// Delete a file.
    fn remove_file(&self, path: &Path) -> io::Result<()>;
    /// Rename a file (same directory; used to quarantine corrupt files).
    fn rename(&self, from: &Path, to: &Path) -> io::Result<()>;
    /// List a directory as `(file_name, is_dir)` pairs. Non-UTF-8 names
    /// are an `InvalidData` error (nothing in the store writes them).
    fn read_dir(&self, dir: &Path) -> io::Result<Vec<(String, bool)>>;
    /// Whether `path` exists (metadata probe, not counted as faultable).
    fn exists(&self, path: &Path) -> bool;
    /// Size of `path` in bytes (metadata probe, not counted as
    /// faultable — the paged-run trailer locator, like [`Self::exists`]).
    fn file_size(&self, path: &Path) -> io::Result<u64>;
    /// Create `path` and any missing parents.
    fn create_dir_all(&self, path: &Path) -> io::Result<()>;
}

/// Append `.tmp` to the file name (keeping the original extension, so
/// `run-00000001.run` becomes `run-00000001.run.tmp` — invisible to the
/// `run-*.run` orphan-GC pattern).
fn tmp_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_owned();
    os.push(".tmp");
    PathBuf::from(os)
}

// ---------------------------------------------------------------- RealIo

/// The production [`StorageIo`]: plain `std::fs`.
#[derive(Clone, Copy, Debug, Default)]
pub struct RealIo;

#[derive(Debug)]
struct RealFile(File);

impl StorageFile for RealFile {
    fn write_all(&mut self, buf: &[u8]) -> io::Result<()> {
        self.0.write_all(buf)
    }

    fn sync_data(&mut self) -> io::Result<()> {
        self.0.sync_data()
    }

    fn truncate(&mut self, len: u64) -> io::Result<()> {
        self.0.set_len(len)?;
        // Reposition explicitly: on non-append handles `set_len` leaves
        // the cursor where it was, which could be past the new end.
        self.0.seek(SeekFrom::Start(len)).map(|_| ())
    }

    fn size(&self) -> io::Result<u64> {
        self.0.metadata().map(|m| m.len())
    }
}

impl StorageIo for RealIo {
    fn create(&self, path: &Path) -> io::Result<Box<dyn StorageFile>> {
        Ok(Box::new(RealFile(File::create(path)?)))
    }

    fn open_append(&self, path: &Path) -> io::Result<Box<dyn StorageFile>> {
        let f = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(Box::new(RealFile(f)))
    }

    fn read(&self, path: &Path) -> io::Result<Vec<u8>> {
        let mut buf = Vec::new();
        File::open(path)?.read_to_end(&mut buf)?;
        Ok(buf)
    }

    fn read_range(&self, path: &Path, offset: u64, len: usize) -> io::Result<Vec<u8>> {
        let mut f = File::open(path)?;
        f.seek(SeekFrom::Start(offset))?;
        let mut buf = vec![0u8; len];
        f.read_exact(&mut buf)?;
        Ok(buf)
    }

    fn write_atomic(&self, path: &Path, bytes: &[u8]) -> io::Result<()> {
        let tmp = tmp_path(path);
        {
            let mut f = File::create(&tmp)?;
            f.write_all(bytes)?;
            f.sync_data()?;
        }
        std::fs::rename(&tmp, path)
    }

    fn remove_file(&self, path: &Path) -> io::Result<()> {
        std::fs::remove_file(path)
    }

    fn rename(&self, from: &Path, to: &Path) -> io::Result<()> {
        std::fs::rename(from, to)
    }

    fn read_dir(&self, dir: &Path) -> io::Result<Vec<(String, bool)>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(dir)? {
            let entry = entry?;
            let name = entry.file_name().into_string().map_err(|_| {
                io::Error::new(io::ErrorKind::InvalidData, "non-UTF-8 file name in store directory")
            })?;
            out.push((name, entry.file_type()?.is_dir()));
        }
        Ok(out)
    }

    fn exists(&self, path: &Path) -> bool {
        path.exists()
    }

    fn file_size(&self, path: &Path) -> io::Result<u64> {
        std::fs::metadata(path).map(|m| m.len())
    }

    fn create_dir_all(&self, path: &Path) -> io::Result<()> {
        std::fs::create_dir_all(path)
    }
}

// --------------------------------------------------------------- FaultyIo

/// What an injected fault does to the operation it lands on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Fail with a retryable error (`ErrorKind::Interrupted`) before any
    /// side effect.
    Transient,
    /// Fail with a non-retryable error (`ErrorKind::Other`) before any
    /// side effect — models a dead device.
    Permanent,
    /// For writes: persist only the first half of the payload, then fail
    /// with a retryable error (a torn append). Other operations degrade
    /// to [`FaultKind::Transient`].
    ShortWrite,
    /// For `sync_data`: the flush fails retryably (data may or may not
    /// have reached the platter). Other operations degrade to
    /// [`FaultKind::Transient`].
    FsyncFail,
    /// Fail with `ErrorKind::StorageFull` (ENOSPC) before any side
    /// effect — permanent under the retry taxonomy.
    Enospc,
    /// Silent payload corruption: the operation *succeeds* but one byte
    /// of the written (or read) payload is flipped. Non-payload
    /// operations are unaffected.
    Corrupt,
}

/// A deterministic fault schedule keyed by global operation index (the
/// [`FaultyIo`] counter value at the moment the operation runs).
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    at: BTreeMap<u64, FaultKind>,
    sticky_from: Option<(u64, FaultKind)>,
    every: Option<(u64, FaultKind)>,
}

impl FaultPlan {
    /// An empty plan (no faults; useful for counting operations).
    pub fn new() -> Self {
        FaultPlan::default()
    }

    /// Fault exactly operation `op`.
    pub fn fail_at(mut self, op: u64, kind: FaultKind) -> Self {
        self.at.insert(op, kind);
        self
    }

    /// Fault operation `op` and every operation after it (a device that
    /// dies and stays dead).
    pub fn fail_from(mut self, op: u64, kind: FaultKind) -> Self {
        self.sticky_from = Some((op, kind));
        self
    }

    /// Fault every `period`-th operation (indices `period-1`,
    /// `2*period-1`, ...).
    pub fn fail_every(mut self, period: u64, kind: FaultKind) -> Self {
        self.every = Some((period.max(1), kind));
        self
    }

    fn fault_for(&self, op: u64) -> Option<FaultKind> {
        if let Some((from, kind)) = self.sticky_from {
            if op >= from {
                return Some(kind);
            }
        }
        if let Some(kind) = self.at.get(&op) {
            return Some(*kind);
        }
        if let Some((period, kind)) = self.every {
            if (op + 1) % period == 0 {
                return Some(kind);
            }
        }
        None
    }
}

#[derive(Debug)]
struct FaultState {
    plan: Mutex<FaultPlan>,
    counter: AtomicU64,
    injected: AtomicU64,
}

impl FaultState {
    /// Count this operation and return the fault scheduled for it, if
    /// any.
    fn next_fault(&self) -> Option<FaultKind> {
        let op = self.counter.fetch_add(1, Ordering::SeqCst);
        let fault = self.plan.lock().unwrap().fault_for(op);
        if fault.is_some() {
            self.injected.fetch_add(1, Ordering::SeqCst);
        }
        fault
    }

    fn error(kind: FaultKind) -> io::Error {
        match kind {
            FaultKind::Transient | FaultKind::ShortWrite | FaultKind::FsyncFail => {
                io::Error::new(io::ErrorKind::Interrupted, "injected transient fault")
            }
            FaultKind::Permanent => io::Error::other("injected permanent fault"),
            FaultKind::Enospc => {
                io::Error::new(io::ErrorKind::StorageFull, "injected ENOSPC")
            }
            FaultKind::Corrupt => unreachable!("corruption succeeds silently"),
        }
    }
}

/// Flip one byte in the middle of `bytes` (no-op on empty payloads).
fn corrupt(bytes: &mut [u8]) {
    if !bytes.is_empty() {
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
    }
}

/// A [`StorageIo`] wrapping [`RealIo`] that injects scheduled faults.
///
/// Every faultable operation — file creates/opens, reads, writes,
/// fsyncs, truncates, renames, removals, directory scans — increments a
/// global counter; the [`FaultPlan`] decides per index whether (and how)
/// the operation fails. Metadata probes (`exists`, `size`) are not
/// counted so schedules stay stable across incidental checks.
#[derive(Debug)]
pub struct FaultyIo {
    inner: RealIo,
    state: Arc<FaultState>,
}

impl FaultyIo {
    /// Build an injector around the given plan. Returned as `Arc` so the
    /// caller can keep a handle for counters/rescheduling while the
    /// store owns it as an `Arc<dyn StorageIo>`.
    pub fn new(plan: FaultPlan) -> Arc<Self> {
        Arc::new(FaultyIo {
            inner: RealIo,
            state: Arc::new(FaultState {
                plan: Mutex::new(plan),
                counter: AtomicU64::new(0),
                injected: AtomicU64::new(0),
            }),
        })
    }

    /// Operations counted so far.
    pub fn ops(&self) -> u64 {
        self.state.counter.load(Ordering::SeqCst)
    }

    /// Faults injected so far.
    pub fn injected(&self) -> u64 {
        self.state.injected.load(Ordering::SeqCst)
    }

    /// Schedule an additional one-shot fault at absolute index `op`.
    pub fn schedule(&self, op: u64, kind: FaultKind) {
        self.state.plan.lock().unwrap().at.insert(op, kind);
    }

    /// Fault every operation from now on (sticky device death).
    pub fn fail_from_now(&self, kind: FaultKind) {
        let now = self.ops();
        self.state.plan.lock().unwrap().sticky_from = Some((now, kind));
    }

    /// Drop all scheduled faults (the device "recovers").
    pub fn clear(&self) {
        *self.state.plan.lock().unwrap() = FaultPlan::new();
    }
}

#[derive(Debug)]
struct FaultyFile {
    inner: Box<dyn StorageFile>,
    state: Arc<FaultState>,
}

impl StorageFile for FaultyFile {
    fn write_all(&mut self, buf: &[u8]) -> io::Result<()> {
        match self.state.next_fault() {
            None => self.inner.write_all(buf),
            Some(FaultKind::Corrupt) => {
                let mut copy = buf.to_vec();
                corrupt(&mut copy);
                self.inner.write_all(&copy)
            }
            Some(FaultKind::ShortWrite) => {
                // Persist a torn prefix, then fail retryably: the caller
                // must repair the tail before re-appending.
                let half = buf.len() / 2;
                self.inner.write_all(&buf[..half])?;
                Err(FaultState::error(FaultKind::ShortWrite))
            }
            Some(kind) => Err(FaultState::error(kind)),
        }
    }

    fn sync_data(&mut self) -> io::Result<()> {
        match self.state.next_fault() {
            None | Some(FaultKind::Corrupt) => self.inner.sync_data(),
            Some(kind) => Err(FaultState::error(kind)),
        }
    }

    fn truncate(&mut self, len: u64) -> io::Result<()> {
        match self.state.next_fault() {
            None | Some(FaultKind::Corrupt) => self.inner.truncate(len),
            Some(kind) => Err(FaultState::error(kind)),
        }
    }

    fn size(&self) -> io::Result<u64> {
        self.inner.size()
    }
}

impl StorageIo for FaultyIo {
    fn create(&self, path: &Path) -> io::Result<Box<dyn StorageFile>> {
        match self.state.next_fault() {
            None | Some(FaultKind::Corrupt) => {
                let inner = self.inner.create(path)?;
                Ok(Box::new(FaultyFile { inner, state: Arc::clone(&self.state) }))
            }
            Some(kind) => Err(FaultState::error(kind)),
        }
    }

    fn open_append(&self, path: &Path) -> io::Result<Box<dyn StorageFile>> {
        match self.state.next_fault() {
            None | Some(FaultKind::Corrupt) => {
                let inner = self.inner.open_append(path)?;
                Ok(Box::new(FaultyFile { inner, state: Arc::clone(&self.state) }))
            }
            Some(kind) => Err(FaultState::error(kind)),
        }
    }

    fn read(&self, path: &Path) -> io::Result<Vec<u8>> {
        match self.state.next_fault() {
            None => self.inner.read(path),
            Some(FaultKind::Corrupt) => {
                let mut bytes = self.inner.read(path)?;
                corrupt(&mut bytes);
                Ok(bytes)
            }
            Some(kind) => Err(FaultState::error(kind)),
        }
    }

    fn read_range(&self, path: &Path, offset: u64, len: usize) -> io::Result<Vec<u8>> {
        match self.state.next_fault() {
            None => self.inner.read_range(path, offset, len),
            Some(FaultKind::Corrupt) => {
                let mut bytes = self.inner.read_range(path, offset, len)?;
                corrupt(&mut bytes);
                Ok(bytes)
            }
            Some(kind) => Err(FaultState::error(kind)),
        }
    }

    fn write_atomic(&self, path: &Path, bytes: &[u8]) -> io::Result<()> {
        match self.state.next_fault() {
            None => self.inner.write_atomic(path, bytes),
            Some(FaultKind::Corrupt) => {
                let mut copy = bytes.to_vec();
                corrupt(&mut copy);
                self.inner.write_atomic(path, &copy)
            }
            // Short writes cannot tear an atomic replace — the rename
            // never happens — so every failing kind leaves the old file.
            Some(kind) => Err(FaultState::error(kind)),
        }
    }

    fn remove_file(&self, path: &Path) -> io::Result<()> {
        match self.state.next_fault() {
            None | Some(FaultKind::Corrupt) => self.inner.remove_file(path),
            Some(kind) => Err(FaultState::error(kind)),
        }
    }

    fn rename(&self, from: &Path, to: &Path) -> io::Result<()> {
        match self.state.next_fault() {
            None | Some(FaultKind::Corrupt) => self.inner.rename(from, to),
            Some(kind) => Err(FaultState::error(kind)),
        }
    }

    fn read_dir(&self, dir: &Path) -> io::Result<Vec<(String, bool)>> {
        match self.state.next_fault() {
            None | Some(FaultKind::Corrupt) => self.inner.read_dir(dir),
            Some(kind) => Err(FaultState::error(kind)),
        }
    }

    fn exists(&self, path: &Path) -> bool {
        self.inner.exists(path)
    }

    fn file_size(&self, path: &Path) -> io::Result<u64> {
        self.inner.file_size(path)
    }

    fn create_dir_all(&self, path: &Path) -> io::Result<()> {
        match self.state.next_fault() {
            None | Some(FaultKind::Corrupt) => self.inner.create_dir_all(path),
            Some(kind) => Err(FaultState::error(kind)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("d4m-io-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn write_atomic_replaces_whole_file() {
        let d = tmp_dir("atomic");
        let p = d.join("m");
        RealIo.write_atomic(&p, b"one").unwrap();
        assert_eq!(RealIo.read(&p).unwrap(), b"one");
        RealIo.write_atomic(&p, b"two-longer").unwrap();
        assert_eq!(RealIo.read(&p).unwrap(), b"two-longer");
        assert!(!RealIo.exists(&tmp_path(&p)));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn truncate_repositions_cursor() {
        let d = tmp_dir("trunc");
        let p = d.join("f");
        let mut f = RealIo.create(&p).unwrap();
        f.write_all(b"hello world").unwrap();
        f.truncate(5).unwrap();
        f.write_all(b"!").unwrap();
        drop(f);
        assert_eq!(RealIo.read(&p).unwrap(), b"hello!");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn read_range_is_exact_and_faultable() {
        let d = tmp_dir("range");
        let p = d.join("f");
        RealIo.write_atomic(&p, b"0123456789").unwrap();
        assert_eq!(RealIo.read_range(&p, 2, 5).unwrap(), b"23456");
        assert_eq!(RealIo.read_range(&p, 0, 10).unwrap(), b"0123456789");
        // Past the end: exact reads fail instead of returning a prefix.
        assert_eq!(
            RealIo.read_range(&p, 8, 5).unwrap_err().kind(),
            io::ErrorKind::UnexpectedEof
        );
        assert_eq!(RealIo.file_size(&p).unwrap(), 10);

        let io = FaultyIo::new(
            FaultPlan::new()
                .fail_at(0, FaultKind::Corrupt)
                .fail_at(1, FaultKind::Permanent),
        );
        let got = io.read_range(&p, 2, 5).unwrap(); // op 0: corrupted
        assert_ne!(got, b"23456");
        assert_eq!(got.len(), 5);
        assert!(io.read_range(&p, 2, 5).is_err()); // op 1: fails
        assert_eq!(io.file_size(&p).unwrap(), 10); // metadata: uncounted
        assert_eq!(io.ops(), 2);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn fault_plan_schedules_deterministically() {
        let plan = FaultPlan::new()
            .fail_at(3, FaultKind::Transient)
            .fail_every(10, FaultKind::Enospc)
            .fail_from(25, FaultKind::Permanent);
        assert_eq!(plan.fault_for(3), Some(FaultKind::Transient));
        assert_eq!(plan.fault_for(9), Some(FaultKind::Enospc));
        assert_eq!(plan.fault_for(19), Some(FaultKind::Enospc));
        assert_eq!(plan.fault_for(4), None);
        assert_eq!(plan.fault_for(25), Some(FaultKind::Permanent));
        assert_eq!(plan.fault_for(400), Some(FaultKind::Permanent));
    }

    #[test]
    fn short_write_tears_then_fails() {
        let d = tmp_dir("short");
        let p = d.join("f");
        let io = FaultyIo::new(FaultPlan::new().fail_at(1, FaultKind::ShortWrite));
        let mut f = io.create(&p).unwrap(); // op 0
        let err = f.write_all(b"abcdefgh").unwrap_err(); // op 1: torn
        assert_eq!(err.kind(), io::ErrorKind::Interrupted);
        f.truncate(0).unwrap(); // repair
        f.write_all(b"abcdefgh").unwrap();
        drop(f);
        assert_eq!(RealIo.read(&p).unwrap(), b"abcdefgh");
        assert_eq!(io.injected(), 1);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn corrupt_flips_one_byte_silently() {
        let d = tmp_dir("corrupt");
        let p = d.join("f");
        let io = FaultyIo::new(FaultPlan::new().fail_at(0, FaultKind::Corrupt));
        io.write_atomic(&p, b"abcd").unwrap(); // succeeds, payload damaged
        let got = RealIo.read(&p).unwrap();
        assert_ne!(got, b"abcd");
        assert_eq!(got.len(), 4);
        assert_eq!(got.iter().zip(b"abcd").filter(|(a, b)| a != b).count(), 1);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn runtime_scheduling_and_recovery() {
        let d = tmp_dir("sched");
        let io = FaultyIo::new(FaultPlan::new());
        io.write_atomic(&d.join("a"), b"x").unwrap();
        io.fail_from_now(FaultKind::Permanent);
        assert!(io.write_atomic(&d.join("b"), b"y").is_err());
        assert!(io.read(&d.join("a")).is_err());
        io.clear();
        assert_eq!(io.read(&d.join("a")).unwrap(), b"x");
        let _ = std::fs::remove_dir_all(&d);
    }
}
