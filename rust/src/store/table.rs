//! A table: an ordered collection of tablets with automatic splitting.
//!
//! Mirrors Accumulo's model: a table starts as one tablet spanning the
//! whole row space; when a tablet's stored bytes exceed
//! [`TableConfig::split_threshold`], it splits at its median row. Each
//! tablet has its own lock, so concurrent writers to different key
//! ranges do not contend — the property the ingest pipeline's sharding
//! exploits.
//!
//! Scans run on the server-side iterator stack (see
//! [`crate::store::scan`]): [`Table::scan_stream`] returns a streaming,
//! seekable [`TableStream`]; [`Table::scan_spec_par`] pins a
//! [`TabletSnapshot`] per tablet and fans load-balanced *range chunks*
//! out across the pool (Accumulo's BatchScanner, minus the lock
//! contention — workers touch no lock after the pin); and the classic
//! [`Table::scan`] / [`Table::scan_par`] entry points are thin
//! consumers of the same stack.

use super::cache::{BlockCache, CacheStats};
use super::compact::CompactionSpec;
use super::io::{RealIo, StorageIo};
use super::run::{Run, DEFAULT_BLOCK_TRIPLES};
use super::lock::{TrackedMutex, TrackedRwLock};
use super::scan::{
    self, stack_collect, CellFilter, ReduceIter, ScanIter, ScanRange, ScanSpec, SliceCursor,
    SnapCursor, SCAN_BLOCK,
};
use super::tablet::{Tablet, TabletSnapshot};
use super::wal::{self, FsyncPolicy, WalOp, WalWriter};
use super::{SharedStr, StoreError, Triple};
use crate::assoc::Assoc;
use crate::util::parallel::parallel_map_ranges;
use crate::util::retry::{classify, ErrorClass, RetryPolicy};
use crate::util::Parallelism;
use std::collections::BTreeSet;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// WAL file name inside a durable table's directory.
const WAL_FILE: &str = "wal.log";
/// Manifest file name: one live run file name per line, rewritten
/// atomically (tmp + fsync + rename) after every compaction. A
/// superseded run drops out of the manifest and its file is deleted by
/// the orphan GC pass that follows each successful rewrite.
const MANIFEST_FILE: &str = "MANIFEST";
/// Manifest line prefix recording one tablet split point, so
/// [`Table::recover`] restores the tablet layout instead of restarting
/// as a single tablet that must re-grow its splits from memtable
/// weight. Split lines precede run lines in the file.
const SPLIT_PREFIX: &str = "split:";

/// Degradation ladder of a durable table. The table only ever moves
/// *down* the ladder at runtime (recovery starts a fresh table at
/// [`TableHealth::Healthy`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TableHealth {
    /// The write-ahead log is accepting appends; full durability.
    #[default]
    Healthy,
    /// The WAL failed permanently and
    /// [`DurableOptions::fallback_to_memory`] is off: reads, scans and
    /// compaction queries keep serving, writes are rejected with
    /// [`StoreError::Degraded`].
    DegradedReadOnly,
    /// The WAL failed permanently and the table fell back to in-memory
    /// operation: reads *and* writes keep working, but new writes are
    /// not logged ([`HealthReport::non_durable_writes`] counts them)
    /// and [`Table::sync`] reports the condition.
    InMemoryOnly,
}

impl std::fmt::Display for TableHealth {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            TableHealth::Healthy => "healthy",
            TableHealth::DegradedReadOnly => "degraded-read-only",
            TableHealth::InMemoryOnly => "in-memory-only",
        })
    }
}

/// Snapshot of a durable table's fault-tolerance state (see
/// [`Table::health`]).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct HealthReport {
    /// Current rung on the degradation ladder.
    pub state: TableHealth,
    /// File names quarantined during recovery (runs failing their
    /// checksum, a foreign WAL, a non-UTF-8 manifest), moved aside as
    /// `<name>.quarantined` and excluded from the recovered table.
    pub quarantined: Vec<String>,
    /// Most recent storage error, rendered with context.
    pub last_error: Option<String>,
    /// Mutations applied without logging while
    /// [`TableHealth::InMemoryOnly`].
    pub non_durable_writes: u64,
    /// Orphan run files deleted by GC passes on this handle.
    pub orphans_removed: u64,
    /// Successful WAL reopen probes: times the table climbed back from
    /// [`TableHealth::DegradedReadOnly`] to [`TableHealth::Healthy`]
    /// after the storage medium healed.
    pub wal_reopens: u64,
    /// Block-cache counters when the table runs paged
    /// ([`DurableOptions::cache_capacity`]); `None` in the default
    /// fully-resident mode.
    pub cache: Option<CacheStats>,
    /// Per-table planner statistics ([`Table::stats`]), attached on
    /// every [`Table::health`] call for observability.
    pub stats: Option<TableStats>,
}

/// Cheap per-table statistics: the **annotate** input of the query
/// planner ([`crate::plan`]) and an observability surface
/// ([`Table::health`]). Computed from pinned [`TabletSnapshot`]s in
/// O(tablets × runs) — cell counts come from run extents and frozen
/// memtable lengths, never from walking cells — cached per content
/// version, and refreshed eagerly by compactions.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TableStats {
    /// Content version (the table's mutation counter) the statistics
    /// were computed at. The counter bumps after every visible-content
    /// mutation, so `version < current` means the numbers are stale —
    /// the staleness signal for planner caches.
    pub version: u64,
    /// Tablet count at computation time.
    pub tablets: usize,
    /// Total stored cells across tablets (shadowed versions and
    /// tombstones included — an upper bound on visible cells).
    pub cells: usize,
    /// Stored cells per tablet, in row order.
    pub per_tablet_cells: Vec<usize>,
    /// Distinct run files attached across tablets (post-split siblings
    /// sharing a run count it once).
    pub runs: usize,
    /// Dictionary-pool entries summed over distinct runs: distinct
    /// row/col/value strings per run. An upper bound on the table's
    /// distinct keys; `cells / dict_keys` approximates the mean
    /// duplication factor the planner uses for combiner placement.
    pub dict_keys: usize,
    /// Evenly-strided sampled row boundaries (sorted, deduplicated) —
    /// the same candidate cut points range chunking uses.
    pub sampled_rows: Vec<String>,
}

/// How a durable table talks to storage: the backend, the retry
/// schedule, and what to do when the log dies.
#[derive(Debug, Clone)]
pub struct DurableOptions {
    /// Storage backend — [`RealIo`] in production, a
    /// [`super::io::FaultyIo`] under fault injection.
    pub io: Arc<dyn StorageIo>,
    /// Retry schedule for WAL appends/syncs, run saves, manifest
    /// rewrites, and recovery reads. [`RetryPolicy::none`] reproduces
    /// the raw single-attempt behavior.
    pub retry: RetryPolicy,
    /// On a permanent WAL failure: `true` drops to
    /// [`TableHealth::InMemoryOnly`] (writes keep working, non-durably);
    /// `false` (default) drops to [`TableHealth::DegradedReadOnly`].
    pub fallback_to_memory: bool,
    /// Shared block cache: `Some` switches the table to **paged** run
    /// I/O — run files are opened footer-only and data blocks are
    /// faulted through this LRU cache on demand, so tables larger than
    /// RAM scan within the cache's byte budget. `None` (default) keeps
    /// every run fully resident, byte-for-byte the pre-cache behavior.
    /// Share one cache across tables to share the budget process-wide.
    pub cache: Option<Arc<BlockCache>>,
    /// Target data-block size, in triples, for newly written run files
    /// (12 bytes per triple on disk). Smaller blocks = finer cache
    /// granularity, larger index.
    pub block_triples: usize,
}

impl Default for DurableOptions {
    fn default() -> Self {
        DurableOptions {
            io: Arc::new(RealIo),
            retry: RetryPolicy::default(),
            fallback_to_memory: false,
            cache: None,
            block_triples: DEFAULT_BLOCK_TRIPLES,
        }
    }
}

impl DurableOptions {
    /// Enable paged run I/O through a fresh block cache holding at most
    /// `bytes` of data blocks (0 = pin-only: blocks live exactly as
    /// long as a cursor holds them). Scans and compactions then run in
    /// bounded memory; see [`BlockCache`] for the eviction contract.
    pub fn cache_capacity(mut self, bytes: usize) -> Self {
        self.cache = Some(BlockCache::new(bytes));
        self
    }
}

/// Durability attachment of a [`Table`]: its directory, storage
/// backend, write-ahead log, and health. The WAL mutex is the
/// *group-commit serialization point* — it is held across append
/// **and** memtable apply, so log order equals apply order, and across
/// a whole minor compaction, so run watermarks are exact. Lock order:
/// `wal` before `health`.
struct DurableState {
    dir: PathBuf,
    io: Arc<dyn StorageIo>,
    retry: RetryPolicy,
    fallback_to_memory: bool,
    /// Paged-mode block cache (see [`DurableOptions::cache`]).
    cache: Option<Arc<BlockCache>>,
    block_triples: usize,
    wal: Mutex<WalWriter>,
    health: Mutex<HealthReport>,
}

/// The durable half of a checkpoint pass: where runs and the manifest
/// are saved, under which retry schedule, and (in paged mode) through
/// which block cache.
struct CheckpointCtx<'a> {
    io: &'a Arc<dyn StorageIo>,
    retry: &'a RetryPolicy,
    dir: &'a Path,
    cache: Option<&'a Arc<BlockCache>>,
    block_triples: usize,
}

/// Table tuning knobs.
#[derive(Debug, Clone)]
pub struct TableConfig {
    /// Tablet size (bytes) that triggers a split.
    pub split_threshold: usize,
    /// Artificial per-batch write latency in microseconds (failure /
    /// slow-server injection for tests and backpressure demos).
    pub write_latency_us: u64,
}

impl Default for TableConfig {
    fn default() -> Self {
        TableConfig { split_threshold: 4 << 20, write_latency_us: 0 }
    }
}

/// A named table of sorted tablets.
pub struct Table {
    name: String,
    config: TableConfig,
    /// Tablets in row order. The `RwLock` guards the tablet *list*
    /// (splits); each tablet has its own `Mutex` for cell data. Both
    /// are tracked wrappers so tests can assert the snapshot scan path
    /// acquires zero locks after open.
    tablets: TrackedRwLock<Vec<TrackedMutex<Tablet>>>,
    /// WAL + directory when the table is durable ([`Table::durable`] /
    /// [`Table::recover`]); `None` for the classic in-memory table.
    durable: Option<DurableState>,
    /// Monotone run-file sequence allocator (also orders runs by age).
    run_seq: AtomicU64,
    /// Monotone content-version counter, bumped *after* every mutation
    /// that changes visible cell content (write batches, deletes,
    /// compactions with a combiner — splits are content-neutral). Open
    /// streams compare it against the version they pinned at and
    /// re-pin their snapshots when it moved, which keeps the
    /// streams-see-concurrent-writes contract without any locking on
    /// the quiescent path.
    mutations: AtomicU64,
    /// Cached [`TableStats`], valid while its `version` matches
    /// `mutations`. Leaf lock: never held across any other lock.
    stats_cache: Mutex<Option<TableStats>>,
}

impl Table {
    /// New in-memory table with a single unbounded tablet. Writes are
    /// not logged; see [`Table::durable`] for the WAL-backed variant.
    pub fn new(name: &str, config: TableConfig) -> Self {
        Table {
            name: name.to_string(),
            config,
            tablets: TrackedRwLock::new(vec![TrackedMutex::new(Tablet::new(None, None))]),
            durable: None,
            run_seq: AtomicU64::new(0),
            mutations: AtomicU64::new(0),
            stats_cache: Mutex::new(None),
        }
    }

    /// New durable table rooted at `dir`: a fresh write-ahead log is
    /// created there (truncating any previous one) and every
    /// [`Table::write_batch`] / [`Table::delete`] is appended to it
    /// before touching the memtables. Use [`Table::recover`] to reopen
    /// an existing directory instead.
    pub fn durable(
        name: &str,
        config: TableConfig,
        dir: &Path,
        policy: FsyncPolicy,
    ) -> io::Result<Table> {
        Self::durable_with(name, config, dir, policy, DurableOptions::default())
    }

    /// [`Table::durable`] with explicit [`DurableOptions`]: the storage
    /// backend, retry schedule, and degradation mode.
    pub fn durable_with(
        name: &str,
        config: TableConfig,
        dir: &Path,
        policy: FsyncPolicy,
        opts: DurableOptions,
    ) -> io::Result<Table> {
        opts.retry.run("create table dir", || opts.io.create_dir_all(dir))?;
        let wal = opts
            .retry
            .run("wal create", || WalWriter::create(&*opts.io, &dir.join(WAL_FILE), policy))?;
        let mut table = Table::new(name, config);
        table.durable = Some(DurableState {
            dir: dir.to_path_buf(),
            io: Arc::clone(&opts.io),
            retry: opts.retry,
            fallback_to_memory: opts.fallback_to_memory,
            cache: opts.cache,
            block_triples: opts.block_triples.max(1),
            wal: Mutex::new(wal),
            health: Mutex::new(HealthReport::default()),
        });
        Ok(table)
    }

    /// Reopen a durable table from `dir`: load the manifest's runs,
    /// replay the WAL suffix past the oldest run watermark, then
    /// checkpoint the replayed state and start a fresh log.
    ///
    /// Replay starts at `min` run watermark (not `max`): after a major
    /// compaction the single merged run carries the newest watermark,
    /// but re-applying *older* already-frozen records is safe — replay
    /// is in log order, so puts are idempotent and deletes converge —
    /// while skipping records a lagging tablet never froze would lose
    /// data. Crash-safety ordering inside recovery itself: the replayed
    /// memtable is frozen to runs and the manifest rewritten *before*
    /// the old WAL is truncated, so a crash mid-recovery only ever
    /// re-replays (converging), never loses acknowledged records.
    pub fn recover(
        name: &str,
        config: TableConfig,
        dir: &Path,
        policy: FsyncPolicy,
    ) -> io::Result<Table> {
        Self::recover_with(name, config, dir, policy, DurableOptions::default())
    }

    /// [`Table::recover`] with explicit [`DurableOptions`].
    ///
    /// **Corruption quarantine**: a run file that fails its checksum
    /// (`InvalidData`) or vanished under a listed name (`NotFound`,
    /// e.g. a crash landed between a previous quarantine rename and the
    /// manifest rewrite) is moved aside as `<name>.quarantined` and
    /// excluded; the table degrades to WAL + memtable + surviving runs
    /// and the quarantined names are reported via [`Table::health`]. A
    /// structurally invalid WAL or a non-UTF-8 manifest is quarantined
    /// the same way, so recovery never panics on damaged files. When
    /// anything was quarantined the replay lower bound drops to zero:
    /// every record the log still holds is re-applied (idempotently),
    /// restoring content the quarantined run also covered whenever the
    /// log still has it.
    ///
    /// Crash-safety ordering inside recovery itself: the replayed
    /// memtable is frozen to runs and the manifest rewritten *before*
    /// the old WAL is truncated (the fresh log is created last), so a
    /// crash mid-recovery — even a second one — only ever re-replays
    /// (converging), never loses acknowledged records.
    pub fn recover_with(
        name: &str,
        config: TableConfig,
        dir: &Path,
        policy: FsyncPolicy,
        opts: DurableOptions,
    ) -> io::Result<Table> {
        let io: &dyn StorageIo = &*opts.io;
        let retry = &opts.retry;
        let mut report = HealthReport::default();
        retry.run("create table dir", || io.create_dir_all(dir))?;

        // Manifest → split points + run list, quarantining structural
        // damage.
        let manifest_path = dir.join(MANIFEST_FILE);
        let mut run_names: Vec<String> = Vec::new();
        let mut split_rows: Vec<String> = Vec::new();
        if io.exists(&manifest_path) {
            let bytes = retry.run("manifest read", || io.read(&manifest_path))?;
            match String::from_utf8(bytes) {
                Ok(body) => {
                    for line in body.lines().map(str::trim).filter(|l| !l.is_empty()) {
                        match line.strip_prefix(SPLIT_PREFIX) {
                            Some(row) => split_rows.push(row.to_string()),
                            None => run_names.push(line.to_string()),
                        }
                    }
                }
                Err(_) => quarantine_file(io, dir, MANIFEST_FILE, &mut report, "not UTF-8"),
            }
        }
        // A hand-damaged manifest could hold unsorted or duplicate
        // split lines; normalize so the tablet layout is well-formed.
        split_rows.sort();
        split_rows.dedup();

        // Load every listed run, quarantining damaged or missing files.
        // Paged mode opens footer-only (blocks fault lazily through the
        // cache); resident mode loads and fully validates each file.
        let mut runs: Vec<Run> = Vec::new();
        for rn in &run_names {
            let path = dir.join(rn);
            let load = || match &opts.cache {
                Some(cache) => Run::open_with(
                    Arc::clone(&opts.io),
                    &path,
                    Arc::clone(cache),
                    retry.clone(),
                ),
                None => Run::load_with(io, &path),
            };
            match retry.run("run load", load) {
                Ok(run) => runs.push(run),
                Err(e)
                    if matches!(
                        e.kind(),
                        io::ErrorKind::InvalidData | io::ErrorKind::NotFound
                    ) =>
                {
                    quarantine_file(io, dir, rn, &mut report, &e.to_string());
                }
                Err(e) => return Err(e),
            }
        }

        // Replay the WAL. A torn tail is the normal crash state (the
        // intact prefix is used as-is); a file that is not a WAL at all
        // is quarantined.
        let wal_path = dir.join(WAL_FILE);
        let replay = if io.exists(&wal_path) {
            match retry.run("wal replay", || wal::replay_with(io, &wal_path)) {
                Ok(rp) => rp,
                Err(e) if e.kind() == io::ErrorKind::InvalidData => {
                    quarantine_file(io, dir, WAL_FILE, &mut report, &e.to_string());
                    wal::WalReplay { records: Vec::new(), truncated: true }
                }
                Err(e) => return Err(e),
            }
        } else {
            wal::WalReplay { records: Vec::new(), truncated: false }
        };

        runs.sort_by_key(Run::seq);
        // Replay lower bound: normally the *min* surviving watermark
        // (see `recover`'s original rationale); zero when anything was
        // quarantined, so the log backfills what the lost run covered.
        let wmin = if report.quarantined.is_empty() {
            runs.iter().map(Run::watermark).min().unwrap_or(0)
        } else {
            0
        };
        let wmax = runs.iter().map(Run::watermark).max().unwrap_or(0);
        let max_run_seq = runs.iter().map(Run::seq).max().unwrap_or(0);
        let table = Table::new(name, config);
        table.run_seq.store(max_run_seq, Ordering::SeqCst);
        {
            // Restore the persisted tablet layout, then attach each
            // run to every tablet whose extent it overlaps (post-split
            // tablets share runs — extents do the pruning at scan
            // time, exactly as `split_at` leaves them).
            let mut tablets = table.tablets.write().unwrap();
            tablets.clear();
            let mut lo: Option<String> = None;
            for row in &split_rows {
                tablets.push(TrackedMutex::new(Tablet::new(lo.take(), Some(row.clone()))));
                lo = Some(row.clone());
            }
            tablets.push(TrackedMutex::new(Tablet::new(lo, None)));
            for run in runs {
                let run = Arc::new(run);
                for t in tablets.iter() {
                    let mut tab = t.lock().unwrap();
                    let (start, end) = run.extent_range(tab.lo.as_deref(), tab.hi.as_deref());
                    if start < end {
                        tab.attach_run(Arc::clone(&run));
                    }
                }
            }
        }
        let mut last_seq = wmax;
        for rec in &replay.records {
            if rec.seq <= wmin {
                continue; // Already durable in every surviving run.
            }
            last_seq = last_seq.max(rec.seq);
            match &rec.op {
                WalOp::Put(batch) => {
                    table
                        .apply_batch(batch.clone())
                        .expect("recovery writes hit no offline tablet");
                }
                WalOp::Delete { row, col } => {
                    table.apply_delete(row, col);
                }
            }
        }
        // Checkpoint replayed state BEFORE truncating the log. The
        // manifest is rewritten whenever it must change: new frozen
        // runs, quarantined names to drop from the list, or a tablet
        // layout that grew past the persisted split points during
        // replay.
        let ctx = CheckpointCtx {
            io: &opts.io,
            retry,
            dir,
            cache: opts.cache.as_ref(),
            block_triples: opts.block_triples.max(1),
        };
        let frozen = table.checkpoint_tablets(Some(&ctx), None, last_seq)?;
        if frozen > 0 || !report.quarantined.is_empty() || table.split_points() != split_rows {
            table.write_manifest(&ctx)?;
        }
        // Collect orphans left by crashes, quarantine, or compaction
        // (best-effort: a missed orphan costs disk, never correctness).
        if let Ok(removed) = table.gc_orphan_runs(&ctx) {
            report.orphans_removed += removed as u64;
        }
        let mut wal = retry.run("wal create", || WalWriter::create(io, &wal_path, policy))?;
        wal.set_last_seq(last_seq);
        Ok(Table {
            durable: Some(DurableState {
                dir: dir.to_path_buf(),
                io: Arc::clone(&opts.io),
                retry: retry.clone(),
                fallback_to_memory: opts.fallback_to_memory,
                cache: opts.cache,
                block_triples: opts.block_triples.max(1),
                wal: Mutex::new(wal),
                health: Mutex::new(report),
            }),
            ..table
        })
    }

    /// Table name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Number of tablets (grows as the table splits).
    pub fn tablet_count(&self) -> usize {
        self.tablets.read().unwrap().len()
    }

    /// Index of the tablet whose extent contains `row`.
    fn locate(tablets: &[TrackedMutex<Tablet>], row: &str) -> usize {
        // Binary search on lower bounds: find the last tablet whose
        // lo <= row. Tablets are in row order; the first has lo = None.
        let mut lo = 0usize;
        let mut hi = tablets.len();
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            let t = tablets[mid].lock().unwrap();
            match t.lo.as_deref() {
                Some(bound) if row < bound => hi = mid,
                _ => lo = mid,
            }
        }
        lo
    }

    /// Indices of the tablets overlapping any range of the (sorted,
    /// coalesced) range set, in row order — the one range-pruning pass
    /// shared by every scan path. Tablet extents are sorted, so the
    /// walk stops at the first tablet past the set's overall upper
    /// bound; tablets sitting in the gaps between ranges are pruned.
    fn live_tablets(tablets: &[TrackedMutex<Tablet>], ranges: &[ScanRange]) -> Vec<usize> {
        if ranges.is_empty() {
            return Vec::new();
        }
        let set_hi = scan::ranges_row_hi(ranges);
        let mut live = Vec::new();
        // Ranges are lo-sorted and tablet extents ascend, so a range
        // ending at or before this tablet's lo is dead for every later
        // tablet too — the dead prefix is skipped once, and the
        // per-tablet walk stops at the first range past the tablet's
        // hi, keeping the pass ~O(tablets + ranges) for the disjoint
        // sets the coalescer produces.
        let mut first = 0usize;
        for (i, t) in tablets.iter().enumerate() {
            let tab = t.lock().unwrap();
            if let (Some(hi), Some(tlo)) = (set_hi, tab.lo.as_deref()) {
                if tlo >= hi {
                    break;
                }
            }
            if let Some(tlo) = tab.lo.as_deref() {
                while first < ranges.len()
                    && ranges[first].hi.as_deref().is_some_and(|hi| hi <= tlo)
                {
                    first += 1;
                }
            }
            let mut overlap = false;
            for r in &ranges[first..] {
                if let (Some(thi), Some(rlo)) = (tab.hi.as_deref(), r.lo.as_deref()) {
                    if rlo >= thi {
                        break;
                    }
                }
                if tab.overlaps(r) {
                    overlap = true;
                    break;
                }
            }
            if overlap {
                live.push(i);
            }
        }
        live
    }

    /// Write a batch of triples (grouped internally by tablet). Returns
    /// the number written. Triples for offline tablets produce an error.
    ///
    /// On a durable table the batch is appended to the write-ahead log
    /// *first*, and the WAL lock is held across the memtable apply so
    /// log order equals apply order (group commit). A log I/O failure
    /// surfaces as [`StoreError::Io`] before any memtable mutates. A
    /// batch that then fails on an offline tablet has already been
    /// logged: recovery replays it in full — offline is transient
    /// write-side backpressure, not a durable rejection.
    pub fn write_batch(&self, batch: Vec<Triple>) -> Result<usize, StoreError> {
        let Some(d) = &self.durable else {
            return self.apply_batch(batch);
        };
        let mut wal = d.wal.lock().unwrap();
        // Copy the rung out before matching: holding the health guard
        // through the arms would deadlock `note_wal_failure` below.
        let state = d.health.lock().unwrap().state;
        match state {
            TableHealth::Healthy => {}
            TableHealth::InMemoryOnly => {
                d.health.lock().unwrap().non_durable_writes += 1;
                return self.apply_batch(batch);
            }
            TableHealth::DegradedReadOnly => {
                // Re-probe: the medium may have healed since the table
                // degraded. A successful WAL reopen climbs back to
                // Healthy and the write proceeds normally; a failed
                // probe rejects the write as before.
                if self.try_reopen_wal(d, &mut wal).is_err() {
                    return Err(StoreError::Degraded { table: self.name.clone(), state });
                }
            }
        }
        if !batch.is_empty() {
            if let Err(e) = d.retry.run("wal append", || wal.append_put(&batch)) {
                self.note_wal_failure(d, "wal append", e)?;
                // Fallback accepted the failure: apply non-durably.
                d.health.lock().unwrap().non_durable_writes += 1;
                return self.apply_batch(batch);
            }
        }
        self.apply_batch(batch)
    }

    /// Record a post-retry WAL failure and decide the table's fate.
    /// Transient failures (retry budget exhausted on a retryable error)
    /// keep the table [`TableHealth::Healthy`] — the *next* write may
    /// succeed — and surface as a retryable [`StoreError::Io`].
    /// Permanent failures move the table down the degradation ladder:
    /// `Ok(())` means the caller should proceed non-durably
    /// ([`DurableOptions::fallback_to_memory`]), `Err` means the write
    /// is rejected. Caller holds the WAL lock; `health` is taken here
    /// (lock order: wal before health).
    fn note_wal_failure(
        &self,
        d: &DurableState,
        what: &str,
        e: io::Error,
    ) -> Result<(), StoreError> {
        let transient = classify(&e) == ErrorClass::Transient;
        let context = format!("{what} for table '{}': {e}", self.name);
        let mut health = d.health.lock().unwrap();
        health.last_error = Some(context.clone());
        if transient {
            return Err(StoreError::Io { context, transient: true });
        }
        if d.fallback_to_memory {
            health.state = TableHealth::InMemoryOnly;
            Ok(())
        } else {
            health.state = TableHealth::DegradedReadOnly;
            Err(StoreError::Io { context, transient: false })
        }
    }

    /// Health re-probe from [`TableHealth::DegradedReadOnly`]: try to
    /// reopen the WAL on its existing path. On success the torn
    /// never-acknowledged tail is truncated, the table climbs back to
    /// [`TableHealth::Healthy`], and the caller proceeds with a normal
    /// append (whose own failure re-degrades via `note_wal_failure`).
    /// Caller holds the WAL lock; `health` is taken here (lock order:
    /// wal before health).
    fn try_reopen_wal(&self, d: &DurableState, wal: &mut WalWriter) -> io::Result<()> {
        let path = d.dir.join(WAL_FILE);
        d.retry.run("wal reopen", || wal.reopen(&*d.io, &path))?;
        let mut health = d.health.lock().unwrap();
        health.state = TableHealth::Healthy;
        health.wal_reopens += 1;
        health.last_error = None;
        Ok(())
    }

    /// The memtable half of [`Table::write_batch`] (no logging).
    fn apply_batch(&self, batch: Vec<Triple>) -> Result<usize, StoreError> {
        if self.config.write_latency_us > 0 {
            std::thread::sleep(std::time::Duration::from_micros(self.config.write_latency_us));
        }
        let mut written = 0;
        {
            let tablets = self.tablets.read().unwrap();
            // Group by destination tablet to take each lock once.
            let mut grouped: Vec<Vec<Triple>> = (0..tablets.len()).map(|_| Vec::new()).collect();
            for t in batch {
                let idx = Self::locate(&tablets, &t.row);
                grouped[idx].push(t);
            }
            for (idx, group) in grouped.into_iter().enumerate() {
                if group.is_empty() {
                    continue;
                }
                let mut tab = tablets[idx].lock().unwrap();
                if tab.offline {
                    return Err(StoreError::TabletOffline {
                        table: self.name.clone(),
                        tablet: idx,
                    });
                }
                for t in group {
                    tab.put(t);
                    written += 1;
                }
            }
        }
        if written > 0 {
            self.mutations.fetch_add(1, Ordering::Release);
        }
        self.maybe_split();
        Ok(written)
    }

    /// Split any tablet exceeding the size threshold (one pass; called
    /// after each batch, so growth beyond 2× the threshold is bounded).
    fn maybe_split(&self) {
        let needs_split = {
            let tablets = self.tablets.read().unwrap();
            tablets.iter().enumerate().find_map(|(i, t)| {
                let t = t.lock().unwrap();
                (t.weight() > self.config.split_threshold).then(|| i)
            })
        };
        let mut did_split = false;
        if let Some(idx) = needs_split {
            let mut tablets = self.tablets.write().unwrap();
            // Re-check under the write lock.
            let split = {
                let mut tab = tablets[idx].lock().unwrap();
                if tab.weight() <= self.config.split_threshold {
                    None
                } else {
                    tab.median_row().map(|m| tab.split_at(&m))
                }
            };
            if let Some(right) = split {
                tablets.insert(idx + 1, TrackedMutex::new(right));
                did_split = true;
            }
        }
        // Persist the new layout (best-effort, after the write guard
        // drops — `write_manifest` retakes the read lock). A missed
        // rewrite only costs re-growing this split at recovery, never
        // data: runs and the WAL carry all cell content.
        if did_split {
            if let Some(d) = &self.durable {
                let _ = self.write_manifest(&Self::ctx_of(d));
            }
        }
    }

    /// Scan a row range, returning sorted triples, at the
    /// process-default parallelism.
    pub fn scan(&self, range: ScanRange) -> Vec<Triple> {
        self.scan_par(range, Parallelism::current())
    }

    /// [`Table::scan`] with an explicit thread configuration — a thin
    /// consumer of the iterator stack with no filter or combiner
    /// stages.
    pub fn scan_par(&self, range: ScanRange, par: Parallelism) -> Vec<Triple> {
        self.scan_spec_par(&ScanSpec::over(range), par)
    }

    /// Collect a stacked scan (range + filters + combiner) at the
    /// process-default parallelism.
    pub fn scan_spec(&self, spec: &ScanSpec) -> Vec<Triple> {
        self.scan_spec_par(spec, Parallelism::current())
    }

    /// Collect a stacked scan with an explicit thread configuration:
    /// pin a [`TabletSnapshot`] per tablet (the only locking the scan
    /// ever does), cut the pinned key space into load-balanced *range
    /// chunks* weighted by per-chunk cell-count estimates, and fan the
    /// chunks across the pool independent of tablet boundaries —
    /// Accumulo's BatchScanner fan-out, minus the lock contention.
    /// Chunks cut at row boundaries and every stage is per-row, so
    /// stitching them in order is byte-identical to the serial stack —
    /// and to naive scan-then-filter-then-reduce
    /// (`tests/scan_stack.rs`), at every thread count and chunk
    /// granularity.
    pub fn scan_spec_par(&self, spec: &ScanSpec, par: Parallelism) -> Vec<Triple> {
        self.scan_snapshot(spec).collect(par)
    }

    /// The pre-snapshot collection path, retained as the bench baseline
    /// (the `ablations` bench's `--chunk-scale` section) and as a
    /// reference implementation: resolve the in-range tablets once, split them
    /// into at most `par.threads` contiguous *tablet groups*, and run
    /// the full stack over each group through [`SliceCursor`] — which
    /// re-takes the tablet lock for every block. Byte-identical to
    /// [`Table::scan_spec_par`] on a quiescent table.
    pub fn scan_spec_locked_par(&self, spec: &ScanSpec, par: Parallelism) -> Vec<Triple> {
        // Hand-built specs may bypass the builder's sorted invariant;
        // normalize once before pruning (which assumes the order too).
        let ranges = scan::ensure_walk_order(spec.ranges.clone());
        let tablets = self.tablets.read().unwrap();
        let live = Self::live_tablets(&tablets, &ranges);
        if par.is_serial() || live.len() <= 1 {
            let base = SliceCursor::new(&tablets, live, ranges, spec.filters.clone());
            return stack_collect(base, spec);
        }
        let parts: Vec<Vec<Triple>> = parallel_map_ranges(par.chunk_ranges(live.len()), |group| {
            let base = SliceCursor::new(
                &tablets,
                live[group].to_vec(),
                ranges.clone(),
                spec.filters.clone(),
            );
            stack_collect(base, spec)
        });
        let mut out = Vec::with_capacity(parts.iter().map(Vec::len).sum());
        for part in parts {
            out.extend(part);
        }
        out
    }

    /// Pin one [`TabletSnapshot`] per tablet, in row order — the
    /// "scan open" moment. These are the *last* lock acquisitions a
    /// snapshot scan makes; everything after walks `Arc`-shared
    /// immutable state.
    fn pin_all(&self) -> Vec<TabletSnapshot> {
        let tablets = self.tablets.read().unwrap();
        tablets.iter().map(|t| t.lock().unwrap().snapshot()).collect()
    }

    /// Open a pinned snapshot scan: the tablet states are frozen here,
    /// and [`SnapshotScan::collect`] / [`SnapshotScan::stream`] serve
    /// exactly the table content at this moment regardless of
    /// concurrent writes, deletes, compactions, or splits.
    pub fn scan_snapshot(&self, spec: &ScanSpec) -> SnapshotScan {
        // Hand-built specs may bypass the builder's sorted invariant;
        // normalize once (the chunker and cursors assume the order).
        let ranges = scan::ensure_walk_order(spec.ranges.clone());
        let snaps = self.pin_all();
        SnapshotScan { snaps, spec: ScanSpec { ranges, ..spec.clone() } }
    }

    /// Per-table statistics for cost-based planning (the planner's
    /// **annotate** input) and observability. Cached per content
    /// version: a hit costs one mutex lock + clone, a miss pins the
    /// tablet snapshots and recounts in O(tablets × runs) without
    /// touching cell data. Compactions refresh the cache eagerly, so
    /// post-compaction calls are hits.
    pub fn stats(&self) -> TableStats {
        let version = self.mutations.load(Ordering::Acquire);
        if let Some(cached) = self.stats_cache.lock().unwrap().as_ref() {
            if cached.version == version {
                return cached.clone();
            }
        }
        let stats = Self::compute_stats(&self.pin_all(), version);
        *self.stats_cache.lock().unwrap() = Some(stats.clone());
        stats
    }

    /// Recompute the stats cache at the current content version —
    /// called by the compaction entry points so the post-compaction
    /// layout (fewer runs, merged dictionaries) is visible to planners
    /// without a recount on their next [`Table::stats`] call.
    fn refresh_stats(&self) {
        let version = self.mutations.load(Ordering::Acquire);
        let stats = Self::compute_stats(&self.pin_all(), version);
        *self.stats_cache.lock().unwrap() = Some(stats);
    }

    /// Count cells/runs/dictionaries over pinned snapshots. Run-level
    /// figures dedup by run sequence number because post-split sibling
    /// tablets share their runs by `Arc`.
    fn compute_stats(snaps: &[TabletSnapshot], version: u64) -> TableStats {
        let mut per_tablet_cells = Vec::with_capacity(snaps.len());
        let mut seen = BTreeSet::new();
        let mut runs = 0usize;
        let mut dict_keys = 0usize;
        let mut sampled_rows = Vec::new();
        for snap in snaps {
            per_tablet_cells.push(snap.cells_upto(None));
            for (seq, _len, dict) in snap.run_summaries() {
                if seen.insert(seq) {
                    runs += 1;
                    dict_keys += dict;
                }
            }
            snap.sample_rows(SnapshotScan::CHUNK_SAMPLES, &mut sampled_rows);
        }
        sampled_rows.sort_unstable();
        sampled_rows.dedup();
        TableStats {
            version,
            tablets: snaps.len(),
            cells: per_tablet_cells.iter().sum(),
            per_tablet_cells,
            runs,
            dict_keys,
            sampled_rows,
        }
    }

    /// Estimated stored cells whose row falls inside any of `ranges`
    /// (column windows are ignored — this is a row-extent estimate).
    /// Costs O(ranges × tablets × runs) binary searches over pinned
    /// snapshots; never walks cells. Overlapping ranges double-count,
    /// so pass a coalesced set ([`ScanSpec`] builders coalesce).
    pub fn estimate_cells_in(&self, ranges: &[ScanRange]) -> usize {
        let snaps = self.pin_all();
        let mut n = 0usize;
        for r in ranges {
            for snap in &snaps {
                // Out-of-extent bounds clamp inside `cells_upto`, so a
                // range disjoint from this tablet contributes ~0.
                let hi_n = snap.cells_upto(r.hi.as_deref());
                let lo_n = match r.lo.as_deref() {
                    Some(lo) => snap.cells_upto(Some(lo)),
                    None => 0,
                };
                n += hi_n.saturating_sub(lo_n);
            }
        }
        n
    }

    /// Open a streaming, seekable scan over this table — the stack as
    /// an iterator. The cursor walks pinned snapshots and re-pins only
    /// when the table's content version moved (holding no lock between
    /// blocks on a quiescent table), so the stream stays valid across
    /// concurrent writes and tablet splits, sees their effects at
    /// block granularity, and allows backward seeks.
    pub fn scan_stream(&self, spec: ScanSpec) -> TableStream<'_> {
        TableStream::new(self, spec)
    }

    /// Point lookup.
    pub fn get(&self, row: &str, col: &str) -> Option<String> {
        let tablets = self.tablets.read().unwrap();
        let idx = Self::locate(&tablets, row);
        let tab = tablets[idx].lock().unwrap();
        tab.get(row, col).map(str::to_string)
    }

    /// Delete a cell; returns whether it was visible before.
    ///
    /// On a durable table the delete is logged first (under the same
    /// group-commit lock as [`Table::write_batch`]) and a post-retry
    /// WAL failure follows the same degradation ladder: transient
    /// errors surface as retryable [`StoreError::Io`], permanent ones
    /// flip the table to in-memory operation or reject the delete.
    pub fn delete(&self, row: &str, col: &str) -> Result<bool, StoreError> {
        let Some(d) = &self.durable else {
            return Ok(self.apply_delete(row, col));
        };
        let mut wal = d.wal.lock().unwrap();
        let state = d.health.lock().unwrap().state;
        match state {
            TableHealth::Healthy => {}
            TableHealth::InMemoryOnly => {
                d.health.lock().unwrap().non_durable_writes += 1;
                return Ok(self.apply_delete(row, col));
            }
            TableHealth::DegradedReadOnly => {
                // Same re-probe as `write_batch`: reopen-or-reject.
                if self.try_reopen_wal(d, &mut wal).is_err() {
                    return Err(StoreError::Degraded { table: self.name.clone(), state });
                }
            }
        }
        if let Err(e) = d.retry.run("wal append", || wal.append_delete(row, col)) {
            self.note_wal_failure(d, "wal append (delete)", e)?;
            d.health.lock().unwrap().non_durable_writes += 1;
        }
        Ok(self.apply_delete(row, col))
    }

    /// The memtable half of [`Table::delete`] (no logging).
    fn apply_delete(&self, row: &str, col: &str) -> bool {
        let hit = {
            let tablets = self.tablets.read().unwrap();
            let idx = Self::locate(&tablets, row);
            let mut tab = tablets[idx].lock().unwrap();
            tab.delete(row, col)
        };
        if hit {
            self.mutations.fetch_add(1, Ordering::Release);
        }
        hit
    }

    /// Total stored cells across tablets.
    pub fn len(&self) -> usize {
        let tablets = self.tablets.read().unwrap();
        tablets.iter().map(|t| t.lock().unwrap().len()).sum()
    }

    /// True when no cells are stored.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The current split points (for pipeline range-sharding).
    pub fn split_points(&self) -> Vec<String> {
        let tablets = self.tablets.read().unwrap();
        tablets
            .iter()
            .filter_map(|t| t.lock().unwrap().lo.clone())
            .collect()
    }

    /// Scan into an associative array.
    pub fn scan_to_assoc(&self, range: ScanRange) -> Assoc {
        self.scan_spec_to_assoc(&ScanSpec::over(range), Parallelism::current())
    }

    /// [`Table::scan_to_assoc`] with an explicit thread configuration
    /// for both the fan-out scan and the constructor rebuild.
    pub fn scan_to_assoc_par(&self, range: ScanRange, par: Parallelism) -> Assoc {
        self.scan_spec_to_assoc(&ScanSpec::over(range), par)
    }

    /// Run a stacked scan straight into an associative array. The
    /// serial path streams — triples flow from the stack directly into
    /// the dictionary encoder, never materializing a `Vec<Triple>`
    /// (full-scan batch hint applied unless the spec sets its own); the
    /// parallel path fans the collection out per tablet group first.
    pub fn scan_spec_to_assoc(&self, spec: &ScanSpec, par: Parallelism) -> Assoc {
        if par.is_serial() {
            let mut spec = spec.clone();
            spec.batch.get_or_insert(SCAN_BLOCK);
            super::stream_to_assoc(self.scan_stream(spec), par)
        } else {
            super::stream_to_assoc(self.scan_spec_par(spec, par).into_iter(), par)
        }
    }

    /// Failure injection: mark a tablet offline/online. Offline blocks
    /// *writes* only; reads, scans, and compactions still serve.
    pub fn set_tablet_offline(&self, idx: usize, offline: bool) {
        let tablets = self.tablets.read().unwrap();
        if let Some(t) = tablets.get(idx) {
            t.lock().unwrap().offline = offline;
        }
    }

    /// Minor compaction: freeze every tablet's memtable into an
    /// immutable sorted run (Accumulo's memtable flush). Returns the
    /// number of runs written.
    ///
    /// On a durable table the WAL lock is held throughout, the log is
    /// synced first, and the new runs carry `last_seq` as their
    /// watermark — every record at or below it is now in a run, so
    /// recovery may skip that log prefix. The manifest is rewritten
    /// after the run files land. On an in-memory table this just
    /// freezes (watermark 0, nothing persisted) so scan tests can stack
    /// memtable-over-run states without a filesystem.
    /// **Failure isolation**: a failed save (post-retry) aborts the
    /// pass with `Err`, leaving the failing tablet's memtable *and* the
    /// manifest untouched — runs are built from a non-destructive
    /// snapshot and installed only after their file is durably on disk.
    /// Earlier tablets may have frozen, but the WAL still covers their
    /// records (it is only truncated at recovery), so the compaction is
    /// safely re-runnable and a crash loses nothing.
    pub fn minor_compact(&self) -> io::Result<usize> {
        let Some(d) = &self.durable else {
            let written = self.checkpoint_tablets(None, None, 0)?;
            self.refresh_stats();
            return Ok(written);
        };
        let mut wal = d.wal.lock().unwrap();
        self.sync_locked(d, &mut wal)?;
        self.sweep_poisoned(d);
        let watermark = wal.last_seq();
        let ctx = Self::ctx_of(d);
        let written = self.checkpoint_tablets(Some(&ctx), None, watermark)?;
        if written > 0 {
            self.write_manifest(&ctx)?;
            self.collect_orphans(d, &ctx);
        }
        self.refresh_stats();
        Ok(written)
    }

    /// Major compaction: merge each tablet's full layer stack (memtable
    /// + tombstones + all runs) into one run per tablet, applying
    /// `spec`'s combiner and version-retention rule at merge time.
    /// Tombstones and the cells they mask are gone afterwards. Returns
    /// the number of merged runs produced (empty tablets produce none).
    /// Shares [`Table::minor_compact`]'s failure isolation: a failed
    /// save leaves the tablet's layers and the manifest untouched, and
    /// the pass is safely re-runnable.
    pub fn major_compact(&self, spec: &CompactionSpec) -> io::Result<usize> {
        let Some(d) = &self.durable else {
            let written = self.checkpoint_tablets(None, Some(spec), 0)?;
            self.refresh_stats();
            return Ok(written);
        };
        let mut wal = d.wal.lock().unwrap();
        self.sync_locked(d, &mut wal)?;
        self.sweep_poisoned(d);
        let watermark = wal.last_seq();
        let ctx = Self::ctx_of(d);
        let written = self.checkpoint_tablets(Some(&ctx), Some(spec), watermark)?;
        // Rewrite unconditionally: compaction may have *removed* every
        // run (all cells deleted), and the manifest must drop them.
        self.write_manifest(&ctx)?;
        self.collect_orphans(d, &ctx);
        self.refresh_stats();
        Ok(written)
    }

    /// One checkpoint pass over every tablet — the engine behind minor
    /// (freeze, `spec` = `None`) and major (merge, `spec` = `Some`)
    /// compaction, durable (`ctx` = `Some`) or in-memory. Per tablet:
    /// build the run cells from a non-destructive snapshot, save the
    /// run file under the retry schedule, and only then commit the
    /// mutation (clear memtable / swap run list). The save failing
    /// leaves that tablet byte-identical; the error propagates
    /// immediately with later tablets untouched too. Caller holds the
    /// WAL lock on durable paths. Returns the number of runs produced.
    fn checkpoint_tablets(
        &self,
        ctx: Option<&CheckpointCtx<'_>>,
        spec: Option<&CompactionSpec>,
        watermark: u64,
    ) -> io::Result<usize> {
        let tablets = self.tablets.read().unwrap();
        let mut written = 0usize;
        for t in tablets.iter() {
            let mut tab = t.lock().unwrap();
            let Some(ctx) = ctx else {
                // In-memory: no file to fail, mutate directly.
                let seq = self.run_seq.fetch_add(1, Ordering::SeqCst) + 1;
                let produced = match spec {
                    None => tab.freeze(seq, watermark).is_some(),
                    Some(spec) => tab.compact(spec, seq, watermark).is_some(),
                };
                if produced {
                    written += 1;
                }
                continue;
            };
            if let (Some(spec), Some(cache)) = (spec, ctx.cache) {
                // Paged major compaction: stream block-by-block so peak
                // memory is O(blocks in flight), never O(table). The
                // tmp file of an aborted pass is swept by orphan GC;
                // the tablet commits only after the rename.
                let seq = self.run_seq.fetch_add(1, Ordering::SeqCst) + 1;
                let path = ctx.dir.join(run_file_name(seq));
                let run = tab.compact_streamed(
                    spec,
                    seq,
                    watermark,
                    ctx.io,
                    &path,
                    cache,
                    ctx.retry,
                    ctx.block_triples,
                )?;
                if run.is_some() {
                    written += 1;
                }
                tab.install_compacted(run);
                continue;
            }
            let cells = match spec {
                None => tab.freeze_cells(),
                Some(spec) => tab.compact_cells(spec),
            };
            if cells.is_empty() {
                if spec.is_some() {
                    // Merged-empty: visible state is already empty
                    // (tombstones consumed everything), so dropping the
                    // old layers commits nothing new — and on a crash
                    // before the manifest rewrite, WAL + old runs
                    // reconverge to the same emptiness.
                    tab.install_compacted(None);
                }
                continue;
            }
            let seq = self.run_seq.fetch_add(1, Ordering::SeqCst) + 1;
            let mut run = Arc::new(Run::from_cells(seq, watermark, &cells));
            let path = ctx.dir.join(run_file_name(seq));
            ctx.retry
                .run("run save", || run.save_with_blocks(&**ctx.io, &path, ctx.block_triples))?;
            if let Some(cache) = ctx.cache {
                // Paged mode: drop the resident copy and serve the run
                // we just wrote through the cache, so a freshly frozen
                // memtable doesn't stay pinned in RAM.
                run = Arc::new(ctx.retry.run("run open", || {
                    Run::open_with(
                        Arc::clone(ctx.io),
                        &path,
                        Arc::clone(cache),
                        ctx.retry.clone(),
                    )
                })?);
            }
            match spec {
                None => tab.complete_freeze(Arc::clone(&run)),
                Some(_) => tab.install_compacted(Some(Arc::clone(&run))),
            }
            written += 1;
        }
        // A compaction can change visible content (a combiner folds
        // versions, retention drops them, tombstones are consumed), so
        // open streams must re-pin their snapshots.
        self.mutations.fetch_add(1, Ordering::Release);
        Ok(written)
    }

    /// Rewrite the manifest: the tablet split points (`split:` lines,
    /// so recovery restores the layout) followed by the set of
    /// currently attached run files (post-split tablets share runs;
    /// the `BTreeSet` dedups). Written atomically (temp + fsync +
    /// rename), so readers see old-or-new, never a torn list.
    fn write_manifest(&self, ctx: &CheckpointCtx<'_>) -> io::Result<()> {
        let mut names: BTreeSet<u64> = BTreeSet::new();
        let mut splits: Vec<String> = Vec::new();
        {
            let tablets = self.tablets.read().unwrap();
            for t in tablets.iter() {
                let tab = t.lock().unwrap();
                if let Some(lo) = &tab.lo {
                    splits.push(lo.clone());
                }
                for run in tab.runs() {
                    names.insert(run.seq());
                }
            }
        }
        let mut body = String::new();
        for row in splits {
            if row.contains('\n') {
                // Not line-representable; recovery re-grows this split
                // from memtable weight instead.
                continue;
            }
            body.push_str(SPLIT_PREFIX);
            body.push_str(&row);
            body.push('\n');
        }
        for seq in names {
            body.push_str(&run_file_name(seq));
            body.push('\n');
        }
        let path = ctx.dir.join(MANIFEST_FILE);
        ctx.retry.run("manifest write", || ctx.io.write_atomic(&path, body.as_bytes()))
    }

    /// Delete run files in the table directory that no live reference
    /// knows: not listed in the on-disk manifest *and* not attached to
    /// any tablet (the union guards against a garbled manifest read
    /// deleting live data). Also sweeps stale `run-*.run.tmp` saves.
    /// Quarantined files (`*.quarantined`) are preserved for forensics.
    /// Best-effort: per-file errors are swallowed — a missed orphan
    /// costs disk, never correctness. Returns the number removed.
    fn gc_orphan_runs(&self, ctx: &CheckpointCtx<'_>) -> io::Result<usize> {
        let mut live: BTreeSet<String> = BTreeSet::new();
        if let Ok(bytes) = ctx.io.read(&ctx.dir.join(MANIFEST_FILE)) {
            if let Ok(body) = String::from_utf8(bytes) {
                let names = body
                    .lines()
                    .map(str::trim)
                    .filter(|l| !l.is_empty() && !l.starts_with(SPLIT_PREFIX));
                live.extend(names.map(String::from));
            }
        }
        {
            let tablets = self.tablets.read().unwrap();
            for t in tablets.iter() {
                let tab = t.lock().unwrap();
                for run in tab.runs() {
                    live.insert(run_file_name(run.seq()));
                }
            }
        }
        let mut removed = 0usize;
        for (name, is_dir) in ctx.io.read_dir(ctx.dir)? {
            if is_dir {
                continue;
            }
            let orphan_run = is_run_file_name(&name) && !live.contains(&name);
            let stale_tmp = name.strip_suffix(".tmp").is_some_and(is_run_file_name);
            if (orphan_run || stale_tmp) && ctx.io.remove_file(&ctx.dir.join(&name)).is_ok() {
                removed += 1;
            }
        }
        Ok(removed)
    }

    /// Run the orphan GC pass and fold the count into the health
    /// report. Best-effort (see [`Table::gc_orphan_runs`]).
    fn collect_orphans(&self, d: &DurableState, ctx: &CheckpointCtx<'_>) {
        if let Ok(removed) = self.gc_orphan_runs(ctx) {
            if removed > 0 {
                d.health.lock().unwrap().orphans_removed += removed as u64;
            }
        }
    }

    /// A [`CheckpointCtx`] borrowing `d`'s storage configuration.
    fn ctx_of(d: &DurableState) -> CheckpointCtx<'_> {
        CheckpointCtx {
            io: &d.io,
            retry: &d.retry,
            dir: &d.dir,
            cache: d.cache.as_ref(),
            block_triples: d.block_triples,
        }
    }

    /// Detach every run poisoned by a block-granular fault (a CRC
    /// mismatch or failed read during a paged scan) and quarantine its
    /// file — the block-level twin of recovery's whole-run quarantine.
    /// Scans already serve table-minus-run the moment a run poisons;
    /// this pass makes the pruning durable (manifest rewrite, file
    /// renamed to `<name>.quarantined`) and visible through
    /// [`HealthReport::quarantined`]. Runs at the head of sync and
    /// compaction passes; a no-op in resident mode, where runs are
    /// fully validated at load and never poison.
    fn sweep_poisoned(&self, d: &DurableState) {
        if d.cache.is_none() {
            return;
        }
        let mut dropped: Vec<Arc<Run>> = Vec::new();
        {
            let tablets = self.tablets.read().unwrap();
            for t in tablets.iter() {
                dropped.extend(t.lock().unwrap().drop_poisoned());
            }
        }
        if dropped.is_empty() {
            return;
        }
        // Post-split tablets share runs: dedup by sequence number.
        let seqs: BTreeSet<u64> = dropped.iter().map(|run| run.seq()).collect();
        {
            let mut health = d.health.lock().unwrap();
            for seq in seqs {
                quarantine_file(
                    &*d.io,
                    &d.dir,
                    &run_file_name(seq),
                    &mut health,
                    "block read failed its crc or i/o while paged",
                );
            }
        }
        let _ = self.write_manifest(&Self::ctx_of(d));
        // Visible content shrank when the run poisoned; open streams
        // must re-pin their snapshots.
        self.mutations.fetch_add(1, Ordering::Release);
    }

    /// Number of distinct runs attached across tablets.
    pub fn run_count(&self) -> usize {
        let tablets = self.tablets.read().unwrap();
        let mut seqs: BTreeSet<u64> = BTreeSet::new();
        for t in tablets.iter() {
            let tab = t.lock().unwrap();
            for run in tab.runs() {
                seqs.insert(run.seq());
            }
        }
        seqs.len()
    }

    /// Stored versions of one cell across the tablet's layer stack
    /// (tombstones count) — observability for the versioning-iterator
    /// retention tests.
    pub fn cell_versions(&self, row: &str, col: &str) -> usize {
        let tablets = self.tablets.read().unwrap();
        let idx = Self::locate(&tablets, row);
        let tab = tablets[idx].lock().unwrap();
        tab.cell_versions(row, col)
    }

    /// Force the WAL to stable storage regardless of the configured
    /// [`FsyncPolicy`]. No-op on in-memory tables. On a degraded table
    /// this reports the condition as an error — callers relying on
    /// `sync()` for a durability guarantee are told it no longer holds.
    pub fn sync(&self) -> io::Result<()> {
        let Some(d) = &self.durable else {
            return Ok(());
        };
        let mut wal = d.wal.lock().unwrap();
        {
            let health = d.health.lock().unwrap();
            if health.state != TableHealth::Healthy {
                return Err(io::Error::other(format!(
                    "table '{}' is {}: {}",
                    self.name,
                    health.state,
                    health.last_error.as_deref().unwrap_or("no error recorded")
                )));
            }
        }
        self.sweep_poisoned(d);
        self.sync_locked(d, &mut wal)
    }

    /// The locked half of [`Table::sync`]: sync under retry, and on a
    /// *permanent* post-retry failure move the table down the
    /// degradation ladder (fsync lying about durability is not
    /// recoverable by writing more). Caller holds the WAL lock.
    fn sync_locked(&self, d: &DurableState, wal: &mut WalWriter) -> io::Result<()> {
        match d.retry.run("wal sync", || wal.sync()) {
            Ok(()) => Ok(()),
            Err(e) => {
                let mut health = d.health.lock().unwrap();
                health.last_error = Some(format!("wal sync for table '{}': {e}", self.name));
                if classify(&e) == ErrorClass::Permanent {
                    health.state = if d.fallback_to_memory {
                        TableHealth::InMemoryOnly
                    } else {
                        TableHealth::DegradedReadOnly
                    };
                }
                Err(e)
            }
        }
    }

    /// Snapshot this table's fault-tolerance state: the degradation
    /// rung, quarantined files, last storage error, and the
    /// non-durable-write / orphan-GC counters, plus the current
    /// [`TableStats`]. In-memory tables report a default (healthy,
    /// empty) fault state with the stats attached.
    pub fn health(&self) -> HealthReport {
        let mut report = match &self.durable {
            Some(d) => {
                let mut report = d.health.lock().unwrap().clone();
                report.cache = d.cache.as_ref().map(|cache| cache.stats());
                report
            }
            None => HealthReport::default(),
        };
        report.stats = Some(self.stats());
        report
    }
}

/// A pinned, lock-free scan over a [`Table`]: one [`TabletSnapshot`]
/// per tablet, captured at [`Table::scan_snapshot`] time. Collection
/// and streaming walk the pinned `Arc`-shared state only — zero lock
/// acquisitions after open — so the scan serves exactly the table
/// content at open regardless of concurrent writes, deletes,
/// compactions, or splits (Accumulo's scan-time isolation).
pub struct SnapshotScan {
    snaps: Vec<TabletSnapshot>,
    spec: ScanSpec,
}

impl SnapshotScan {
    /// Cut-row candidates sampled per run / frozen memtable when
    /// building load-balanced range chunks.
    const CHUNK_SAMPLES: usize = 8;

    /// Collect the pinned scan. Serial configurations run the plain
    /// stack; parallel ones cut the pinned key space into
    /// weight-balanced range chunks (cell-count estimates from the
    /// snapshots) and fan them across the pool, independent of tablet
    /// boundaries — then stitch in range order. Chunks cut at row
    /// boundaries and every stack stage is per-row, so the result is
    /// byte-identical at every thread count and chunk granularity.
    pub fn collect(&self, par: Parallelism) -> Vec<Triple> {
        if par.is_serial() {
            return self.collect_serial();
        }
        let spans = self.chunk_spans(&par);
        if spans.len() <= 1 {
            return self.collect_serial();
        }
        let parts: Vec<Vec<Triple>> =
            parallel_map_ranges((0..spans.len()).map(|i| i..i + 1).collect(), |r| {
                let (lo, hi) = &spans[r.start];
                let ranges = scan::clamp_ranges(&self.spec.ranges, lo.as_deref(), hi.as_deref());
                let base = SnapCursor::new(&self.snaps, ranges, self.spec.filters.clone());
                stack_collect(base, &self.spec)
            });
        let mut out = Vec::with_capacity(parts.iter().map(Vec::len).sum());
        for part in parts {
            out.extend(part);
        }
        out
    }

    fn collect_serial(&self) -> Vec<Triple> {
        let base =
            SnapCursor::new(&self.snaps, self.spec.ranges.clone(), self.spec.filters.clone());
        stack_collect(base, &self.spec)
    }

    /// Stream the pinned scan through the full stack (filters →
    /// combiner), one triple at a time, with zero lock acquisitions —
    /// unlike [`Table::scan_stream`] this never observes concurrent
    /// mutations, by design.
    pub fn stream(&self) -> SnapshotStream<'_> {
        let base =
            SnapCursor::new(&self.snaps, self.spec.ranges.clone(), self.spec.filters.clone());
        SnapshotStream { inner: ReduceIter::new(base, self.spec.reduce.clone()) }
    }

    /// Build the chunk spans `[lo, hi)` (row bounds, `None` = open):
    /// candidate cut rows come from tablet boundaries, range lower
    /// bounds, and evenly-strided sample rows out of each snapshot;
    /// each inter-cut interval is weighted by its estimated cell count
    /// and the weighted chunker balances them across `par.threads`.
    fn chunk_spans(&self, par: &Parallelism) -> Vec<(Option<String>, Option<String>)> {
        let mut cands: Vec<String> = Vec::new();
        for snap in &self.snaps {
            if let Some(lo) = &snap.lo {
                cands.push(lo.clone());
            }
            snap.sample_rows(Self::CHUNK_SAMPLES, &mut cands);
        }
        for r in &self.spec.ranges {
            if let Some(lo) = &r.lo {
                cands.push(lo.clone());
            }
        }
        // Snap each candidate onto the range set (a cut row in a gap
        // between ranges would only mint an empty chunk) and dedup.
        let mut cuts: BTreeSet<String> = BTreeSet::new();
        for c in cands {
            if let Some(s) = scan::snap_row(&self.spec.ranges, &c) {
                cuts.insert(s.to_string());
            }
        }
        let mut bounds: Vec<(Option<String>, Option<String>)> = Vec::new();
        let mut lo: Option<String> = None;
        for c in cuts {
            bounds.push((lo.take(), Some(c.clone())));
            lo = Some(c);
        }
        bounds.push((lo, None));
        // Cell-count estimates ignore range/filter selectivity — they
        // only balance load, never affect results.
        let mut cum: Vec<usize> = vec![0];
        for (blo, bhi) in &bounds {
            let mut w = 0usize;
            for snap in &self.snaps {
                let upto = snap.cells_upto(bhi.as_deref());
                let below = blo.as_deref().map_or(0, |b| snap.cells_upto(Some(b)));
                w += upto.saturating_sub(below);
            }
            cum.push(cum.last().unwrap() + w);
        }
        par.chunk_ranges_weighted(&cum)
            .into_iter()
            .map(|r| (bounds[r.start].0.clone(), bounds[r.end - 1].1.clone()))
            .collect()
    }
}

/// A streaming stacked scan over a [`SnapshotScan`]'s pinned state:
/// the full iterator stack pulled one triple at a time, acquiring no
/// lock at any point. Implements both [`ScanIter`] (seek + next) and
/// [`Iterator`].
pub struct SnapshotStream<'s> {
    inner: ReduceIter<SnapCursor<'s>>,
}

impl ScanIter for SnapshotStream<'_> {
    fn seek(&mut self, row: &str, col: &str) {
        self.inner.seek(row, col);
    }

    fn next_triple(&mut self) -> Option<Triple> {
        self.inner.next_triple()
    }
}

impl Iterator for SnapshotStream<'_> {
    type Item = Triple;

    fn next(&mut self) -> Option<Triple> {
        self.inner.next_triple()
    }
}

/// Index of the snapshot whose extent contains `row` — the lock-free
/// mirror of [`Table::locate`] (same binary search on lower bounds).
fn locate_snap(snaps: &[TabletSnapshot], row: &str) -> usize {
    let mut lo = 0usize;
    let mut hi = snaps.len();
    while hi - lo > 1 {
        let mid = (lo + hi) / 2;
        match snaps[mid].lo.as_deref() {
            Some(bound) if row < bound => hi = mid,
            _ => lo = mid,
        }
    }
    lo
}

/// Run file name for a run sequence number (zero-padded so manifests
/// and directory listings sort by age).
fn run_file_name(seq: u64) -> String {
    format!("run-{seq:08}.run")
}

/// True for names minted by [`run_file_name`] (`run-NNNNNNNN.run`,
/// zero-padded to at least 8 digits) — the orphan GC's whitelist, so it
/// never touches foreign files that happen to live in the directory.
fn is_run_file_name(name: &str) -> bool {
    name.strip_prefix("run-")
        .and_then(|s| s.strip_suffix(".run"))
        .is_some_and(|digits| digits.len() >= 8 && digits.bytes().all(|b| b.is_ascii_digit()))
}

/// Move `dir/name` aside as `dir/name.quarantined` (best-effort — the
/// file may already be gone) and record it in the health report.
fn quarantine_file(
    io: &dyn StorageIo,
    dir: &Path,
    name: &str,
    report: &mut HealthReport,
    why: &str,
) {
    let from = dir.join(name);
    if io.exists(&from) {
        let _ = io.rename(&from, &dir.join(format!("{name}.quarantined")));
    }
    report.quarantined.push(name.to_string());
    report.last_error = Some(format!("{name} quarantined: {why}"));
}

/// Tablet blocks fetched after a seek start small and double up to
/// [`SCAN_BLOCK`] — point-ish reads (BFS row probes) stay cheap while
/// long scans amortize locking, the classic scanner batch ramp. A
/// [`ScanSpec::batch`] hint overrides this starting size per stream.
const STREAM_BLOCK_MIN: usize = 64;

/// The base cursor of a [`TableStream`]: a block cursor over pinned
/// [`TabletSnapshot`]s that re-locates its snapshot *by key* on every
/// refill, so it takes zero locks between blocks on a quiescent table
/// and survives concurrent splits (Accumulo scanners re-resolve tablet
/// locations the same way). A content-version check at each refill
/// re-pins the snapshots when the table mutated, so concurrent
/// writes, deletes and compactions still become visible at block
/// granularity — the interleaving contract the old lock-per-block
/// cursor gave, now paid for only when something actually changed.
/// Spec filters are evaluated beneath the snapshot block copy.
struct TableCursor<'a> {
    table: &'a Table,
    /// Pinned per-tablet snapshots (refreshed when `version` lags the
    /// table's mutation counter).
    snaps: Vec<TabletSnapshot>,
    /// The table's mutation-counter value the pins were taken at.
    version: u64,
    /// Sorted, coalesced range set (empty = scan nothing).
    ranges: Vec<ScanRange>,
    /// The set's overall exclusive row upper bound (`None` = +∞).
    set_hi: Option<String>,
    filters: Vec<CellFilter>,
    /// Resume key `(row, col, inclusive)`; `None` = range start.
    resume: Option<(SharedStr, SharedStr, bool)>,
    /// Current block, reversed so consuming is a move-out pop.
    buf: Vec<Triple>,
    done: bool,
    block: usize,
    /// Block size installed after open/seek (the batch ramp start).
    block_min: usize,
}

impl<'a> TableCursor<'a> {
    fn new(
        table: &'a Table,
        ranges: Vec<ScanRange>,
        filters: Vec<CellFilter>,
        batch: Option<usize>,
    ) -> Self {
        let block_min = batch.unwrap_or(STREAM_BLOCK_MIN).clamp(1, SCAN_BLOCK);
        let ranges = scan::ensure_walk_order(ranges);
        let done = ranges.is_empty();
        let set_hi = if done { None } else { scan::ranges_row_hi(&ranges).map(String::from) };
        let mut cur = TableCursor {
            table,
            snaps: Vec::new(),
            version: 0,
            ranges,
            set_hi,
            filters,
            resume: None,
            buf: Vec::new(),
            done,
            block: block_min,
            block_min,
        };
        cur.pin();
        cur
    }

    /// (Re-)pin the per-tablet snapshots. The version is read *before*
    /// the pins: a write landing mid-pin leaves the stored version
    /// stale, forcing one extra (harmless) re-pin at the next refill —
    /// never a missed refresh.
    fn pin(&mut self) {
        let version = self.table.mutations.load(Ordering::Acquire);
        self.snaps = self.table.pin_all();
        self.version = version;
    }

    fn refill(&mut self) {
        self.buf.clear();
        // The walk touches only pinned snapshots; the version check is
        // a single atomic load, so a quiescent table is streamed with
        // zero lock acquisitions after open.
        loop {
            if self.table.mutations.load(Ordering::Acquire) != self.version {
                self.pin();
            }
            // Snap the position onto the range set first, so a resume
            // key sitting in a gap between ranges locates the next
            // range's snapshot directly instead of walking every
            // snapshot under the gap.
            let snapped: Option<Option<(SharedStr, SharedStr)>> = {
                let pos_row = match &self.resume {
                    Some((r, _, _)) => r.as_str(),
                    None => self.ranges[0].lo.as_deref().unwrap_or(""),
                };
                match scan::snap_row(&self.ranges, pos_row) {
                    None => None,
                    Some(s) if s != pos_row => {
                        Some(Some((s.into(), scan::start_col(&self.ranges, s).into())))
                    }
                    Some(_) => Some(None),
                }
            };
            match snapped {
                // Past every range: exhausted.
                None => {
                    self.done = true;
                    return;
                }
                Some(Some((row, col))) => self.resume = Some((row, col, true)),
                Some(None) => {}
            }
            let pos_row = match &self.resume {
                Some((r, _, _)) => r.as_str(),
                None => self.ranges[0].lo.as_deref().unwrap_or(""),
            };
            let idx = locate_snap(&self.snaps, pos_row);
            let snap = &self.snaps[idx];
            // The located snapshot starts at or past the set's end:
            // done.
            if let (Some(hi), Some(tlo)) = (self.set_hi.as_deref(), snap.lo.as_deref()) {
                if tlo >= hi {
                    self.done = true;
                    return;
                }
            }
            let from = self.resume.as_ref().map(|(r, c, inc)| (r.as_str(), c.as_str(), *inc));
            let more =
                snap.scan_block(from, &self.ranges, &self.filters, self.block, &mut self.buf);
            if let Some((row, col)) = more {
                self.resume = Some((row, col, false));
                if !self.buf.is_empty() {
                    self.block = (self.block * 2).min(SCAN_BLOCK);
                    self.buf.reverse();
                    return;
                }
                // Examined cap fired on an all-rejected block: yield
                // (version check, snapshot refresh point) and keep
                // scanning from the resume key.
                continue;
            }
            // This snapshot is done for the range set — move to the
            // next one immediately (no extra refill round trip for a
            // partial final block) or finish the stream.
            match snap.hi.clone() {
                None => self.done = true,
                Some(hi) => {
                    if self.set_hi.as_deref().is_some_and(|rhi| hi.as_str() >= rhi) {
                        self.done = true;
                    } else {
                        // Continue at the next snapshot's first key.
                        self.resume = Some((hi.into(), "".into(), true));
                    }
                }
            }
            if self.done || !self.buf.is_empty() {
                self.buf.reverse();
                return;
            }
        }
    }
}

impl ScanIter for TableCursor<'_> {
    fn seek(&mut self, row: &str, col: &str) {
        self.buf.clear();
        if self.ranges.is_empty() {
            self.done = true;
            return;
        }
        self.done = false;
        self.block = self.block_min;
        let (row, col) = match self.ranges[0].lo.as_deref() {
            Some(lo) if row < lo => (lo, ""),
            _ => (row, col),
        };
        self.resume = Some((row.into(), col.into(), true));
    }

    fn next_triple(&mut self) -> Option<Triple> {
        loop {
            if let Some(t) = self.buf.pop() {
                return Some(t);
            }
            if self.done {
                return None;
            }
            self.refill();
        }
    }
}

/// A streaming stacked scan over a [`Table`]: the full iterator stack
/// (range cursor with pushed-down filters → combiner) pulled one triple
/// at a time. Implements both [`ScanIter`] (seek + next) and
/// [`Iterator`].
pub struct TableStream<'a> {
    inner: ReduceIter<TableCursor<'a>>,
}

impl<'a> TableStream<'a> {
    fn new(table: &'a Table, spec: ScanSpec) -> Self {
        let base = TableCursor::new(table, spec.ranges, spec.filters, spec.batch);
        TableStream { inner: ReduceIter::new(base, spec.reduce) }
    }
}

impl ScanIter for TableStream<'_> {
    fn seek(&mut self, row: &str, col: &str) {
        self.inner.seek(row, col);
    }

    fn next_triple(&mut self) -> Option<Triple> {
        self.inner.next_triple()
    }
}

impl Iterator for TableStream<'_> {
    type Item = Triple;

    fn next(&mut self) -> Option<Triple> {
        self.inner.next_triple()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::scan::{CellFilter, KeyMatch, RowReduce};

    fn small_table() -> Table {
        // Tiny split threshold so splits actually happen in tests.
        Table::new("t", TableConfig { split_threshold: 64, write_latency_us: 0 })
    }

    fn batch(n: usize) -> Vec<Triple> {
        (0..n).map(|i| Triple::new(format!("row{i:04}"), "c", "value")).collect()
    }

    #[test]
    fn write_and_point_get() {
        let t = small_table();
        t.write_batch(vec![Triple::new("r", "c", "v")]).unwrap();
        assert_eq!(t.get("r", "c"), Some("v".into()));
        assert_eq!(t.get("r", "x"), None);
        assert!(t.delete("r", "c").unwrap());
        assert!(t.is_empty());
    }

    #[test]
    fn splits_on_growth_and_stays_scannable() {
        let t = small_table();
        t.write_batch(batch(100)).unwrap();
        assert!(t.tablet_count() > 1, "expected splits, got 1 tablet");
        assert_eq!(t.len(), 100);
        // Scan returns everything, sorted.
        let all = t.scan(ScanRange::all());
        assert_eq!(all.len(), 100);
        assert!(all.windows(2).all(|w| w[0] <= w[1]));
        // Point gets route across split tablets.
        assert_eq!(t.get("row0000", "c"), Some("value".into()));
        assert_eq!(t.get("row0099", "c"), Some("value".into()));
    }

    #[test]
    fn ranged_scans() {
        let t = small_table();
        t.write_batch(batch(50)).unwrap();
        let r = t.scan(ScanRange::rows("row0010", "row0020"));
        assert_eq!(r.len(), 10);
        assert_eq!(r[0].row, "row0010");
        assert_eq!(r[9].row, "row0019");
        let single = t.scan(ScanRange::single("row0033"));
        assert_eq!(single.len(), 1);
    }

    #[test]
    fn column_windowed_scans() {
        let t = small_table();
        let mut b = Vec::new();
        for i in 0..20 {
            for c in ["a", "b", "c"] {
                b.push(Triple::new(format!("row{i:04}"), c, "v"));
            }
        }
        t.write_batch(b).unwrap();
        let win = t.scan(ScanRange::all().with_cols("b", "c"));
        assert_eq!(win.len(), 20);
        assert!(win.iter().all(|t| t.col == "b"));
        let both = t.scan(ScanRange::rows("row0005", "row0010").with_cols("a", "c"));
        assert_eq!(both.len(), 10);
    }

    #[test]
    fn overwrite_keeps_single_cell() {
        let t = small_table();
        t.write_batch(vec![Triple::new("r", "c", "1")]).unwrap();
        t.write_batch(vec![Triple::new("r", "c", "2")]).unwrap();
        assert_eq!(t.len(), 1);
        assert_eq!(t.get("r", "c"), Some("2".into()));
    }

    #[test]
    fn offline_tablet_rejects_writes() {
        let t = small_table();
        t.write_batch(batch(10)).unwrap();
        t.set_tablet_offline(0, true);
        let err = t.write_batch(vec![Triple::new("row0000", "c", "v")]).unwrap_err();
        assert!(matches!(err, StoreError::TabletOffline { .. }));
        t.set_tablet_offline(0, false);
        assert!(t.write_batch(vec![Triple::new("row0000", "c", "v")]).is_ok());
    }

    #[test]
    fn split_points_reflect_tablets() {
        let t = small_table();
        t.write_batch(batch(100)).unwrap();
        let sp = t.split_points();
        assert_eq!(sp.len(), t.tablet_count() - 1);
        assert!(sp.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn stream_matches_collect_and_seeks() {
        let t = small_table();
        t.write_batch(batch(80)).unwrap();
        assert!(t.tablet_count() > 1);
        let collected = t.scan(ScanRange::all());
        let streamed: Vec<Triple> = t.scan_stream(ScanSpec::all()).collect();
        assert_eq!(collected, streamed);
        // Absolute seeks, forward then backward.
        let mut s = t.scan_stream(ScanSpec::all());
        s.seek("row0040", "");
        assert_eq!(s.next_triple().unwrap().row, "row0040");
        s.seek("row0007", "");
        assert_eq!(s.next_triple().unwrap().row, "row0007");
    }

    #[test]
    fn stacked_scan_filters_and_reduces() {
        let t = small_table();
        let mut b = Vec::new();
        for i in 0..30 {
            b.push(Triple::new(format!("r{:02}", i % 10), format!("c{i:02}"), "2"));
        }
        t.write_batch(b).unwrap();
        let spec = ScanSpec::all()
            .filtered(CellFilter::col(KeyMatch::Glob("c*0".into())))
            .reduced(RowReduce::Sum { out_col: "sum".into() });
        let got = t.scan_spec(&spec);
        // Columns c00, c10, c20 → rows r00 and r01... only rows whose
        // cells include a matching column appear.
        assert!(got.iter().all(|t| t.col == "sum"));
        // Cross-check against the naive client-side pipeline.
        let mut expect: Vec<Triple> = Vec::new();
        let mut cur: Option<(SharedStr, f64)> = None;
        for tr in t.scan(ScanRange::all()) {
            if !KeyMatch::Glob("c*0".into()).matches(&tr.col) {
                continue;
            }
            let v: f64 = tr.val.parse().unwrap_or(0.0);
            match &mut cur {
                Some((row, acc)) if *row == tr.row => *acc += v,
                _ => {
                    if let Some((row, acc)) = cur.take() {
                        expect.push(Triple::new(row, "sum", crate::store::format_num(acc)));
                    }
                    cur = Some((tr.row.clone(), v));
                }
            }
        }
        if let Some((row, acc)) = cur {
            expect.push(Triple::new(row, "sum", crate::store::format_num(acc)));
        }
        assert_eq!(got, expect);
        assert!(!got.is_empty());
    }

    #[test]
    fn batch_hints_do_not_change_results() {
        let t = small_table();
        t.write_batch(batch(80)).unwrap();
        let expect: Vec<Triple> = t.scan_stream(ScanSpec::all()).collect();
        // Any hint (clamped to 1..=SCAN_BLOCK) yields identical bytes;
        // the hint only moves lock/copy granularity.
        for hint in [1usize, 2, 7, 64, 100_000] {
            let got: Vec<Triple> = t.scan_stream(ScanSpec::all().batched(hint)).collect();
            assert_eq!(got, expect, "hint={hint}");
            let mut s = t.scan_stream(ScanSpec::all().batched(hint));
            s.seek("row0040", "");
            assert_eq!(s.next_triple().unwrap().row, "row0040", "hint={hint}");
        }
    }

    #[test]
    fn multi_range_scans_across_split_tablets() {
        let t = small_table();
        t.write_batch(batch(100)).unwrap();
        assert!(t.tablet_count() > 1);
        let spec = ScanSpec::ranges([
            ScanRange::rows("row0070", "row0080"),
            ScanRange::single("row0042"),
            ScanRange::rows("row0000", "row0010"),
        ]);
        // Collected, parallel, and streamed walks all agree and equal
        // the sorted union of the per-range scans.
        let mut expect = t.scan(ScanRange::rows("row0000", "row0010"));
        expect.extend(t.scan(ScanRange::single("row0042")));
        expect.extend(t.scan(ScanRange::rows("row0070", "row0080")));
        let got = t.scan_spec(&spec);
        assert_eq!(got, expect);
        assert_eq!(got.len(), 21);
        let streamed: Vec<Triple> = t.scan_stream(spec.clone()).collect();
        assert_eq!(streamed, expect);
        for threads in [2usize, 4] {
            assert_eq!(t.scan_spec_par(&spec, Parallelism::with_threads(threads)), expect);
        }
        // Seeking into a gap lands on the next range's first cell.
        let mut s = t.scan_stream(spec);
        s.seek("row0050", "");
        assert_eq!(s.next_triple().unwrap().row, "row0070");
        // An empty range set scans nothing, streamed or collected.
        assert!(t.scan_spec(&ScanSpec::ranges(Vec::new())).is_empty());
        assert!(t.scan_stream(ScanSpec::ranges(Vec::new())).next().is_none());
        // A hand-built spec that bypassed the builder's sort is
        // normalized at the scan entry points, not silently mis-walked.
        let hand = ScanSpec {
            ranges: vec![
                ScanRange::rows("row0070", "row0080"),
                ScanRange::rows("row0000", "row0010"),
            ],
            ..ScanSpec::default()
        };
        let mut expect2 = t.scan(ScanRange::rows("row0000", "row0010"));
        expect2.extend(t.scan(ScanRange::rows("row0070", "row0080")));
        assert_eq!(t.scan_spec(&hand), expect2);
        let hand_streamed: Vec<Triple> = t.scan_stream(hand).collect();
        assert_eq!(hand_streamed, expect2);
    }

    #[test]
    fn multi_range_stacks_with_filters_and_combiners() {
        let t = small_table();
        let mut b = Vec::new();
        for i in 0..40 {
            for c in ["c1", "c2", "c3"] {
                b.push(Triple::new(format!("r{i:02}"), c, "2"));
            }
        }
        t.write_batch(b).unwrap();
        let spec = ScanSpec::ranges([
            ScanRange::rows("r00", "r05"),
            ScanRange::rows("r30", "r33"),
        ])
        .filtered(CellFilter::col(KeyMatch::In(
            ["c1", "c3"].iter().map(|s| s.to_string()).collect(),
        )))
        .reduced(RowReduce::Sum { out_col: "s".into() });
        let got = t.scan_spec(&spec);
        // 5 + 3 rows, each summing two kept cells of value 2.
        assert_eq!(got.len(), 8);
        assert!(got.iter().all(|t| t.col == "s" && t.val == "4"));
        assert_eq!(got[0].row, "r00");
        assert_eq!(got[7].row, "r32");
    }

    #[test]
    fn concurrent_writers() {
        use std::sync::Arc;
        let t = Arc::new(small_table());
        let mut handles = Vec::new();
        for w in 0..4 {
            let t = Arc::clone(&t);
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    t.write_batch(vec![Triple::new(
                        format!("w{w}-row{i:03}"),
                        "c",
                        "v",
                    )])
                    .unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(t.len(), 200);
        let all = t.scan(ScanRange::all());
        assert!(all.windows(2).all(|w| w[0] <= w[1]));
    }

    fn temp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join("d4m-table-tests")
            .join(format!("{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn durable_roundtrip_recovers_everything() {
        let dir = temp_dir("roundtrip");
        {
            let t =
                Table::durable("t", TableConfig::default(), &dir, FsyncPolicy::Never).unwrap();
            t.write_batch(batch(30)).unwrap();
            assert!(t.delete("row0003", "c").unwrap());
            t.sync().unwrap();
        }
        let r = Table::recover("t", TableConfig::default(), &dir, FsyncPolicy::Never).unwrap();
        assert_eq!(r.len(), 29);
        assert_eq!(r.get("row0000", "c"), Some("value".into()));
        assert_eq!(r.get("row0003", "c"), None);
        // Recovery checkpointed into runs + a fresh (empty) log; a
        // second recovery replays nothing and still agrees.
        let expect = r.scan(ScanRange::all());
        drop(r);
        let r2 = Table::recover("t", TableConfig::default(), &dir, FsyncPolicy::Never).unwrap();
        assert_eq!(r2.scan(ScanRange::all()), expect);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn minor_compact_preserves_scans_and_survives_recovery() {
        let dir = temp_dir("minor");
        let cfg = TableConfig { split_threshold: 64, write_latency_us: 0 };
        let t = Table::durable("t", cfg.clone(), &dir, FsyncPolicy::Never).unwrap();
        t.write_batch(batch(40)).unwrap();
        assert!(t.tablet_count() > 1);
        let before = t.scan(ScanRange::all());
        assert!(t.minor_compact().unwrap() >= 1);
        assert!(t.run_count() >= 1);
        // Run-backed scans are byte-identical to the memtable scan.
        assert_eq!(t.scan(ScanRange::all()), before);
        // Layer new writes over the runs: overwrite shadows, delete
        // tombstones a run-resident cell.
        t.write_batch(vec![Triple::new("row0005", "c", "v2")]).unwrap();
        assert_eq!(t.get("row0005", "c"), Some("v2".into()));
        assert!(t.delete("row0006", "c").unwrap());
        assert_eq!(t.get("row0006", "c"), None);
        assert_eq!(t.len(), 39);
        let expect = t.scan(ScanRange::all());
        drop(t);
        let r = Table::recover("t", cfg, &dir, FsyncPolicy::Never).unwrap();
        assert_eq!(r.scan(ScanRange::all()), expect);
        assert_eq!(r.get("row0006", "c"), None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn major_compact_purges_tombstones_and_applies_retention() {
        let dir = temp_dir("major");
        let t = Table::durable("t", TableConfig::default(), &dir, FsyncPolicy::Never).unwrap();
        t.write_batch(vec![Triple::new("a", "x", "1")]).unwrap();
        t.minor_compact().unwrap();
        t.write_batch(vec![Triple::new("a", "x", "2")]).unwrap();
        t.minor_compact().unwrap();
        t.write_batch(vec![Triple::new("a", "x", "3"), Triple::new("b", "y", "9")]).unwrap();
        assert_eq!(t.cell_versions("a", "x"), 3);
        assert!(t.delete("b", "y").unwrap());
        t.major_compact(&CompactionSpec { reduce: None, max_versions: 2 }).unwrap();
        assert_eq!(t.run_count(), 1);
        assert_eq!(t.cell_versions("a", "x"), 2);
        assert_eq!(t.get("a", "x"), Some("3".into()));
        assert_eq!(t.get("b", "y"), None);
        assert_eq!(t.len(), 1);
        drop(t);
        let r = Table::recover("t", TableConfig::default(), &dir, FsyncPolicy::Never).unwrap();
        assert_eq!(r.get("a", "x"), Some("3".into()));
        assert_eq!(r.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn in_memory_compaction_needs_no_directory() {
        let t = small_table();
        t.write_batch(batch(50)).unwrap();
        let before = t.scan(ScanRange::all());
        assert!(t.minor_compact().unwrap() >= 1);
        assert_eq!(t.scan(ScanRange::all()), before);
        // Overwrites land in the memtable above the frozen runs.
        t.write_batch(batch(50)).unwrap();
        assert_eq!(t.scan(ScanRange::all()), before);
        t.major_compact(&CompactionSpec::default()).unwrap();
        assert_eq!(t.scan(ScanRange::all()), before);
        assert_eq!(t.len(), 50);
    }

    #[test]
    fn stream_survives_mid_scan_split() {
        let t = small_table();
        t.write_batch(batch(20)).unwrap();
        let mut s = t.scan_stream(ScanSpec::all());
        let mut got = Vec::new();
        for _ in 0..5 {
            got.push(s.next_triple().unwrap());
        }
        // Grow the table past more split points while the stream is
        // open; the cursor re-locates by key and keeps going.
        t.write_batch((0..40).map(|i| Triple::new(format!("zz{i:03}"), "c", "v")).collect())
            .unwrap();
        for tr in s {
            got.push(tr);
        }
        assert!(got.windows(2).all(|w| w[0] < w[1]), "stream stays sorted");
        assert_eq!(got.iter().filter(|t| t.row.starts_with("zz")).count(), 40);
        assert_eq!(got.len(), 60);
    }

    #[test]
    fn recovery_restores_split_layout() {
        let dir = temp_dir("splits");
        let cfg = TableConfig { split_threshold: 64, write_latency_us: 0 };
        let (expect, splits) = {
            let t = Table::durable("t", cfg.clone(), &dir, FsyncPolicy::Never).unwrap();
            t.write_batch(batch(80)).unwrap();
            assert!(t.tablet_count() > 1);
            t.minor_compact().unwrap();
            (t.scan(ScanRange::all()), t.split_points())
        };
        let r = Table::recover("t", cfg, &dir, FsyncPolicy::Never).unwrap();
        assert_eq!(r.split_points(), splits, "tablet layout restored from manifest");
        assert_eq!(r.tablet_count(), splits.len() + 1);
        assert_eq!(r.scan(ScanRange::all()), expect);
        // Post-recovery writes route into the restored layout.
        r.write_batch(vec![Triple::new("row0500", "c", "v")]).unwrap();
        assert_eq!(r.get("row0500", "c"), Some("v".into()));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_scan_matches_locked_and_is_isolated() {
        let t = small_table();
        t.write_batch(batch(100)).unwrap();
        assert!(t.tablet_count() > 1);
        let spec = ScanSpec::all();
        let pinned = t.scan_snapshot(&spec);
        let expect = t.scan_spec_locked_par(&spec, Parallelism::serial());
        assert_eq!(expect.len(), 100);
        // Bit-identical at every thread count / chunk granularity.
        for threads in [1usize, 2, 4, 7] {
            assert_eq!(
                pinned.collect(Parallelism::with_threads(threads)),
                expect,
                "threads={threads}"
            );
        }
        let streamed: Vec<Triple> = pinned.stream().collect();
        assert_eq!(streamed, expect);
        // Mutations after the pin are invisible to the snapshot...
        t.write_batch(vec![Triple::new("zzz", "c", "v")]).unwrap();
        assert!(t.delete("row0000", "c").unwrap());
        assert_eq!(pinned.collect(Parallelism::with_threads(4)), expect);
        assert_eq!(pinned.stream().collect::<Vec<Triple>>(), expect);
        // ...but a fresh scan sees them.
        assert_eq!(t.scan_spec_par(&spec, Parallelism::with_threads(4)).len(), 100);
    }
}
