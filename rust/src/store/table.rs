//! A table: an ordered collection of tablets with automatic splitting.
//!
//! Mirrors Accumulo's model: a table starts as one tablet spanning the
//! whole row space; when a tablet's stored bytes exceed
//! [`TableConfig::split_threshold`], it splits at its median row. Each
//! tablet has its own lock, so concurrent writers to different key
//! ranges do not contend — the property the ingest pipeline's sharding
//! exploits.

use super::tablet::Tablet;
use super::{StoreError, Triple};
use crate::assoc::Assoc;
use crate::util::parallel::parallel_map_ranges;
use crate::util::Parallelism;
use std::sync::{Mutex, RwLock};

/// Table tuning knobs.
#[derive(Debug, Clone)]
pub struct TableConfig {
    /// Tablet size (bytes) that triggers a split.
    pub split_threshold: usize,
    /// Artificial per-batch write latency in microseconds (failure /
    /// slow-server injection for tests and backpressure demos).
    pub write_latency_us: u64,
}

impl Default for TableConfig {
    fn default() -> Self {
        TableConfig { split_threshold: 4 << 20, write_latency_us: 0 }
    }
}

/// A scan range over rows: `[lo, hi)`, unbounded when `None`.
#[derive(Debug, Clone, Default)]
pub struct ScanRange {
    /// Inclusive lower row bound.
    pub lo: Option<String>,
    /// Exclusive upper row bound.
    pub hi: Option<String>,
}

impl ScanRange {
    /// The full-table range.
    pub fn all() -> Self {
        ScanRange::default()
    }

    /// Rows in `[lo, hi)`.
    pub fn rows(lo: impl Into<String>, hi: impl Into<String>) -> Self {
        ScanRange { lo: Some(lo.into()), hi: Some(hi.into()) }
    }

    /// Exactly one row.
    pub fn single(row: impl Into<String>) -> Self {
        let row = row.into();
        let mut hi = row.clone();
        hi.push('\0');
        ScanRange { lo: Some(row), hi: Some(hi) }
    }
}

/// A named table of sorted tablets.
pub struct Table {
    name: String,
    config: TableConfig,
    /// Tablets in row order. The `RwLock` guards the tablet *list*
    /// (splits); each tablet has its own `Mutex` for cell data.
    tablets: RwLock<Vec<Mutex<Tablet>>>,
}

impl Table {
    /// New table with a single unbounded tablet.
    pub fn new(name: &str, config: TableConfig) -> Self {
        Table {
            name: name.to_string(),
            config,
            tablets: RwLock::new(vec![Mutex::new(Tablet::new(None, None))]),
        }
    }

    /// Table name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Number of tablets (grows as the table splits).
    pub fn tablet_count(&self) -> usize {
        self.tablets.read().unwrap().len()
    }

    /// Index of the tablet whose extent contains `row`.
    fn locate(tablets: &[Mutex<Tablet>], row: &str) -> usize {
        // Binary search on lower bounds: find the last tablet whose
        // lo <= row. Tablets are in row order; the first has lo = None.
        let mut lo = 0usize;
        let mut hi = tablets.len();
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            let t = tablets[mid].lock().unwrap();
            match t.lo.as_deref() {
                Some(bound) if row < bound => hi = mid,
                _ => lo = mid,
            }
        }
        lo
    }

    /// Write a batch of triples (grouped internally by tablet). Returns
    /// the number written. Triples for offline tablets produce an error.
    pub fn write_batch(&self, batch: Vec<Triple>) -> Result<usize, StoreError> {
        if self.config.write_latency_us > 0 {
            std::thread::sleep(std::time::Duration::from_micros(self.config.write_latency_us));
        }
        let mut written = 0;
        {
            let tablets = self.tablets.read().unwrap();
            // Group by destination tablet to take each lock once.
            let mut grouped: Vec<Vec<Triple>> = (0..tablets.len()).map(|_| Vec::new()).collect();
            for t in batch {
                let idx = Self::locate(&tablets, &t.row);
                grouped[idx].push(t);
            }
            for (idx, group) in grouped.into_iter().enumerate() {
                if group.is_empty() {
                    continue;
                }
                let mut tab = tablets[idx].lock().unwrap();
                if tab.offline {
                    return Err(StoreError::TabletOffline {
                        table: self.name.clone(),
                        tablet: idx,
                    });
                }
                for t in group {
                    tab.put(t);
                    written += 1;
                }
            }
        }
        self.maybe_split();
        Ok(written)
    }

    /// Split any tablet exceeding the size threshold (one pass; called
    /// after each batch, so growth beyond 2× the threshold is bounded).
    fn maybe_split(&self) {
        let needs_split = {
            let tablets = self.tablets.read().unwrap();
            tablets.iter().enumerate().find_map(|(i, t)| {
                let t = t.lock().unwrap();
                (t.weight() > self.config.split_threshold).then(|| i)
            })
        };
        if let Some(idx) = needs_split {
            let mut tablets = self.tablets.write().unwrap();
            // Re-check under the write lock.
            let split = {
                let mut tab = tablets[idx].lock().unwrap();
                if tab.weight() <= self.config.split_threshold {
                    None
                } else {
                    tab.median_row().map(|m| tab.split_at(&m))
                }
            };
            if let Some(right) = split {
                tablets.insert(idx + 1, Mutex::new(right));
            }
        }
    }

    /// Scan a row range, returning sorted triples, at the
    /// process-default parallelism.
    pub fn scan(&self, range: ScanRange) -> Vec<Triple> {
        self.scan_par(range, Parallelism::current())
    }

    /// [`Table::scan`] with an explicit thread configuration: one job
    /// per in-range tablet, stitched back in tablet (= row) order so
    /// the output is byte-identical to the serial scan. Tablets each
    /// carry their own lock, so workers never contend with each other
    /// (only with writers to the same tablet).
    pub fn scan_par(&self, range: ScanRange, par: Parallelism) -> Vec<Triple> {
        let tablets = self.tablets.read().unwrap();
        if par.is_serial() {
            // Exact serial code path: check bounds and scan each tablet
            // under a single lock acquisition.
            let mut out = Vec::new();
            for t in tablets.iter() {
                let tab = t.lock().unwrap();
                // Skip tablets entirely outside the range.
                if let (Some(hi), Some(tlo)) = (&range.hi, &tab.lo) {
                    if tlo.as_str() >= hi.as_str() {
                        break;
                    }
                }
                if let (Some(lo), Some(thi)) = (&range.lo, &tab.hi) {
                    if thi.as_str() <= lo.as_str() {
                        continue;
                    }
                }
                tab.scan_into(range.lo.as_deref(), range.hi.as_deref(), &mut out);
            }
            return out;
        }
        // In-range tablet indices, in row order (tablet extents are
        // sorted, so the first tablet past `hi` ends the walk). The
        // bounds read here cannot go stale before the fan-out below:
        // tablet extents only change on split, and splits take the
        // tablets *write* lock, excluded while we hold the read lock.
        let mut live: Vec<usize> = Vec::new();
        for (i, t) in tablets.iter().enumerate() {
            let tab = t.lock().unwrap();
            if let (Some(hi), Some(tlo)) = (&range.hi, &tab.lo) {
                if tlo.as_str() >= hi.as_str() {
                    break;
                }
            }
            if let (Some(lo), Some(thi)) = (&range.lo, &tab.hi) {
                if thi.as_str() <= lo.as_str() {
                    continue;
                }
            }
            live.push(i);
        }
        if live.len() <= 1 {
            let mut out = Vec::new();
            for &i in &live {
                let tab = tablets[i].lock().unwrap();
                tab.scan_into(range.lo.as_deref(), range.hi.as_deref(), &mut out);
            }
            return out;
        }
        // One job per contiguous *group* of tablets, at most
        // `par.threads` groups — the knob bounds the fan-out, and
        // stitching groups in order preserves row order.
        let parts: Vec<Vec<Triple>> =
            parallel_map_ranges(par.chunk_ranges(live.len()), |group| {
                let mut part = Vec::new();
                for j in group {
                    let tab = tablets[live[j]].lock().unwrap();
                    tab.scan_into(range.lo.as_deref(), range.hi.as_deref(), &mut part);
                }
                part
            });
        let mut out = Vec::with_capacity(parts.iter().map(Vec::len).sum());
        for part in parts {
            out.extend(part);
        }
        out
    }

    /// Point lookup.
    pub fn get(&self, row: &str, col: &str) -> Option<String> {
        let tablets = self.tablets.read().unwrap();
        let idx = Self::locate(&tablets, row);
        let tab = tablets[idx].lock().unwrap();
        tab.get(row, col).map(str::to_string)
    }

    /// Delete a cell; returns whether it existed.
    pub fn delete(&self, row: &str, col: &str) -> bool {
        let tablets = self.tablets.read().unwrap();
        let idx = Self::locate(&tablets, row);
        let mut tab = tablets[idx].lock().unwrap();
        tab.delete(row, col)
    }

    /// Total stored cells across tablets.
    pub fn len(&self) -> usize {
        let tablets = self.tablets.read().unwrap();
        tablets.iter().map(|t| t.lock().unwrap().len()).sum()
    }

    /// True when no cells are stored.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The current split points (for pipeline range-sharding).
    pub fn split_points(&self) -> Vec<String> {
        let tablets = self.tablets.read().unwrap();
        tablets
            .iter()
            .filter_map(|t| t.lock().unwrap().lo.clone())
            .collect()
    }

    /// Scan into an associative array.
    pub fn scan_to_assoc(&self, range: ScanRange) -> Assoc {
        super::triples_to_assoc(&self.scan(range))
    }

    /// [`Table::scan_to_assoc`] with an explicit thread configuration
    /// for both the fan-out scan and the constructor rebuild.
    pub fn scan_to_assoc_par(&self, range: ScanRange, par: Parallelism) -> Assoc {
        super::triples_to_assoc_par(&self.scan_par(range, par), par)
    }

    /// Failure injection: mark a tablet offline/online.
    pub fn set_tablet_offline(&self, idx: usize, offline: bool) {
        let tablets = self.tablets.read().unwrap();
        if let Some(t) = tablets.get(idx) {
            t.lock().unwrap().offline = offline;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_table() -> Table {
        // Tiny split threshold so splits actually happen in tests.
        Table::new("t", TableConfig { split_threshold: 64, write_latency_us: 0 })
    }

    fn batch(n: usize) -> Vec<Triple> {
        (0..n).map(|i| Triple::new(format!("row{i:04}"), "c", "value")).collect()
    }

    #[test]
    fn write_and_point_get() {
        let t = small_table();
        t.write_batch(vec![Triple::new("r", "c", "v")]).unwrap();
        assert_eq!(t.get("r", "c"), Some("v".into()));
        assert_eq!(t.get("r", "x"), None);
        assert!(t.delete("r", "c"));
        assert!(t.is_empty());
    }

    #[test]
    fn splits_on_growth_and_stays_scannable() {
        let t = small_table();
        t.write_batch(batch(100)).unwrap();
        assert!(t.tablet_count() > 1, "expected splits, got 1 tablet");
        assert_eq!(t.len(), 100);
        // Scan returns everything, sorted.
        let all = t.scan(ScanRange::all());
        assert_eq!(all.len(), 100);
        assert!(all.windows(2).all(|w| w[0] <= w[1]));
        // Point gets route across split tablets.
        assert_eq!(t.get("row0000", "c"), Some("value".into()));
        assert_eq!(t.get("row0099", "c"), Some("value".into()));
    }

    #[test]
    fn ranged_scans() {
        let t = small_table();
        t.write_batch(batch(50)).unwrap();
        let r = t.scan(ScanRange::rows("row0010", "row0020"));
        assert_eq!(r.len(), 10);
        assert_eq!(r[0].row, "row0010");
        assert_eq!(r[9].row, "row0019");
        let single = t.scan(ScanRange::single("row0033"));
        assert_eq!(single.len(), 1);
    }

    #[test]
    fn overwrite_keeps_single_cell() {
        let t = small_table();
        t.write_batch(vec![Triple::new("r", "c", "1")]).unwrap();
        t.write_batch(vec![Triple::new("r", "c", "2")]).unwrap();
        assert_eq!(t.len(), 1);
        assert_eq!(t.get("r", "c"), Some("2".into()));
    }

    #[test]
    fn offline_tablet_rejects_writes() {
        let t = small_table();
        t.write_batch(batch(10)).unwrap();
        t.set_tablet_offline(0, true);
        let err = t.write_batch(vec![Triple::new("row0000", "c", "v")]).unwrap_err();
        assert!(matches!(err, StoreError::TabletOffline { .. }));
        t.set_tablet_offline(0, false);
        assert!(t.write_batch(vec![Triple::new("row0000", "c", "v")]).is_ok());
    }

    #[test]
    fn split_points_reflect_tablets() {
        let t = small_table();
        t.write_batch(batch(100)).unwrap();
        let sp = t.split_points();
        assert_eq!(sp.len(), t.tablet_count() - 1);
        assert!(sp.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn concurrent_writers() {
        use std::sync::Arc;
        let t = Arc::new(small_table());
        let mut handles = Vec::new();
        for w in 0..4 {
            let t = Arc::clone(&t);
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    t.write_batch(vec![Triple::new(
                        format!("w{w}-row{i:03}"),
                        "c",
                        "v",
                    )])
                    .unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(t.len(), 200);
        let all = t.scan(ScanRange::all());
        assert!(all.windows(2).all(|w| w[0] <= w[1]));
    }
}
