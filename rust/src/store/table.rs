//! A table: an ordered collection of tablets with automatic splitting.
//!
//! Mirrors Accumulo's model: a table starts as one tablet spanning the
//! whole row space; when a tablet's stored bytes exceed
//! [`TableConfig::split_threshold`], it splits at its median row. Each
//! tablet has its own lock, so concurrent writers to different key
//! ranges do not contend — the property the ingest pipeline's sharding
//! exploits.
//!
//! Scans run on the server-side iterator stack (see
//! [`crate::store::scan`]): [`Table::scan_stream`] returns a streaming,
//! seekable [`TableStream`]; [`Table::scan_spec_par`] collects a
//! stacked scan with per-tablet parallel fan-out; and the classic
//! [`Table::scan`] / [`Table::scan_par`] entry points are thin
//! consumers of the same stack.

use super::compact::CompactionSpec;
use super::run::Run;
use super::scan::{
    self, stack_collect, CellFilter, ReduceIter, ScanIter, ScanRange, ScanSpec, SliceCursor,
    SCAN_BLOCK,
};
use super::tablet::Tablet;
use super::wal::{self, FsyncPolicy, WalOp, WalWriter};
use super::{SharedStr, StoreError, Triple};
use crate::assoc::Assoc;
use crate::util::parallel::parallel_map_ranges;
use crate::util::Parallelism;
use std::collections::BTreeSet;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// WAL file name inside a durable table's directory.
const WAL_FILE: &str = "wal.log";
/// Manifest file name: one live run file name per line, rewritten
/// atomically (tmp + rename) after every compaction. Run files are
/// never deleted — a superseded run simply drops out of the manifest
/// (orphan cleanup is future work; see ROADMAP).
const MANIFEST_FILE: &str = "MANIFEST";

/// Durability attachment of a [`Table`]: its directory and write-ahead
/// log. The WAL mutex is the *group-commit serialization point* — it is
/// held across append **and** memtable apply, so log order equals apply
/// order, and across a whole minor compaction, so run watermarks are
/// exact.
struct DurableState {
    dir: PathBuf,
    wal: Mutex<WalWriter>,
}

/// Table tuning knobs.
#[derive(Debug, Clone)]
pub struct TableConfig {
    /// Tablet size (bytes) that triggers a split.
    pub split_threshold: usize,
    /// Artificial per-batch write latency in microseconds (failure /
    /// slow-server injection for tests and backpressure demos).
    pub write_latency_us: u64,
}

impl Default for TableConfig {
    fn default() -> Self {
        TableConfig { split_threshold: 4 << 20, write_latency_us: 0 }
    }
}

/// A named table of sorted tablets.
pub struct Table {
    name: String,
    config: TableConfig,
    /// Tablets in row order. The `RwLock` guards the tablet *list*
    /// (splits); each tablet has its own `Mutex` for cell data.
    tablets: RwLock<Vec<Mutex<Tablet>>>,
    /// WAL + directory when the table is durable ([`Table::durable`] /
    /// [`Table::recover`]); `None` for the classic in-memory table.
    durable: Option<DurableState>,
    /// Monotone run-file sequence allocator (also orders runs by age).
    run_seq: AtomicU64,
}

impl Table {
    /// New in-memory table with a single unbounded tablet. Writes are
    /// not logged; see [`Table::durable`] for the WAL-backed variant.
    pub fn new(name: &str, config: TableConfig) -> Self {
        Table {
            name: name.to_string(),
            config,
            tablets: RwLock::new(vec![Mutex::new(Tablet::new(None, None))]),
            durable: None,
            run_seq: AtomicU64::new(0),
        }
    }

    /// New durable table rooted at `dir`: a fresh write-ahead log is
    /// created there (truncating any previous one) and every
    /// [`Table::write_batch`] / [`Table::delete`] is appended to it
    /// before touching the memtables. Use [`Table::recover`] to reopen
    /// an existing directory instead.
    pub fn durable(
        name: &str,
        config: TableConfig,
        dir: &Path,
        policy: FsyncPolicy,
    ) -> io::Result<Table> {
        std::fs::create_dir_all(dir)?;
        let wal = WalWriter::create(&dir.join(WAL_FILE), policy)?;
        let mut table = Table::new(name, config);
        table.durable = Some(DurableState { dir: dir.to_path_buf(), wal: Mutex::new(wal) });
        Ok(table)
    }

    /// Reopen a durable table from `dir`: load the manifest's runs,
    /// replay the WAL suffix past the oldest run watermark, then
    /// checkpoint the replayed state and start a fresh log.
    ///
    /// Replay starts at `min` run watermark (not `max`): after a major
    /// compaction the single merged run carries the newest watermark,
    /// but re-applying *older* already-frozen records is safe — replay
    /// is in log order, so puts are idempotent and deletes converge —
    /// while skipping records a lagging tablet never froze would lose
    /// data. Crash-safety ordering inside recovery itself: the replayed
    /// memtable is frozen to runs and the manifest rewritten *before*
    /// the old WAL is truncated, so a crash mid-recovery only ever
    /// re-replays (converging), never loses acknowledged records.
    pub fn recover(
        name: &str,
        config: TableConfig,
        dir: &Path,
        policy: FsyncPolicy,
    ) -> io::Result<Table> {
        let wal_path = dir.join(WAL_FILE);
        let replay = if wal_path.exists() {
            wal::replay(&wal_path)?
        } else {
            wal::WalReplay { records: Vec::new(), truncated: false }
        };
        let mut runs: Vec<Run> = Vec::new();
        let manifest = dir.join(MANIFEST_FILE);
        if manifest.exists() {
            for line in std::fs::read_to_string(&manifest)?.lines() {
                let line = line.trim();
                if line.is_empty() {
                    continue;
                }
                runs.push(Run::load(&dir.join(line))?);
            }
        }
        runs.sort_by_key(Run::seq);
        let wmin = runs.iter().map(Run::watermark).min().unwrap_or(0);
        let wmax = runs.iter().map(Run::watermark).max().unwrap_or(0);
        let max_run_seq = runs.iter().map(Run::seq).max().unwrap_or(0);
        let table = Table::new(name, config);
        table.run_seq.store(max_run_seq, Ordering::SeqCst);
        {
            // Freshly built table: exactly one unbounded tablet.
            let tablets = table.tablets.read().unwrap();
            let mut tab = tablets[0].lock().unwrap();
            for run in runs {
                tab.attach_run(Arc::new(run));
            }
        }
        let mut last_seq = wmax;
        for rec in &replay.records {
            if rec.seq <= wmin {
                continue; // Already durable in every run.
            }
            last_seq = last_seq.max(rec.seq);
            match &rec.op {
                WalOp::Put(batch) => {
                    table
                        .apply_batch(batch.clone())
                        .expect("recovery writes hit no offline tablet");
                }
                WalOp::Delete { row, col } => {
                    table.apply_delete(row, col);
                }
            }
        }
        // Checkpoint replayed state BEFORE truncating the log.
        let frozen = table.freeze_all(last_seq, Some(dir))?;
        if frozen > 0 {
            table.write_manifest(dir)?;
        }
        let mut wal = WalWriter::create(&wal_path, policy)?;
        wal.set_last_seq(last_seq);
        Ok(Table {
            durable: Some(DurableState { dir: dir.to_path_buf(), wal: Mutex::new(wal) }),
            ..table
        })
    }

    /// Table name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Number of tablets (grows as the table splits).
    pub fn tablet_count(&self) -> usize {
        self.tablets.read().unwrap().len()
    }

    /// Index of the tablet whose extent contains `row`.
    fn locate(tablets: &[Mutex<Tablet>], row: &str) -> usize {
        // Binary search on lower bounds: find the last tablet whose
        // lo <= row. Tablets are in row order; the first has lo = None.
        let mut lo = 0usize;
        let mut hi = tablets.len();
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            let t = tablets[mid].lock().unwrap();
            match t.lo.as_deref() {
                Some(bound) if row < bound => hi = mid,
                _ => lo = mid,
            }
        }
        lo
    }

    /// Indices of the tablets overlapping any range of the (sorted,
    /// coalesced) range set, in row order — the one range-pruning pass
    /// shared by every scan path. Tablet extents are sorted, so the
    /// walk stops at the first tablet past the set's overall upper
    /// bound; tablets sitting in the gaps between ranges are pruned.
    fn live_tablets(tablets: &[Mutex<Tablet>], ranges: &[ScanRange]) -> Vec<usize> {
        if ranges.is_empty() {
            return Vec::new();
        }
        let set_hi = scan::ranges_row_hi(ranges);
        let mut live = Vec::new();
        // Ranges are lo-sorted and tablet extents ascend, so a range
        // ending at or before this tablet's lo is dead for every later
        // tablet too — the dead prefix is skipped once, and the
        // per-tablet walk stops at the first range past the tablet's
        // hi, keeping the pass ~O(tablets + ranges) for the disjoint
        // sets the coalescer produces.
        let mut first = 0usize;
        for (i, t) in tablets.iter().enumerate() {
            let tab = t.lock().unwrap();
            if let (Some(hi), Some(tlo)) = (set_hi, tab.lo.as_deref()) {
                if tlo >= hi {
                    break;
                }
            }
            if let Some(tlo) = tab.lo.as_deref() {
                while first < ranges.len()
                    && ranges[first].hi.as_deref().is_some_and(|hi| hi <= tlo)
                {
                    first += 1;
                }
            }
            let mut overlap = false;
            for r in &ranges[first..] {
                if let (Some(thi), Some(rlo)) = (tab.hi.as_deref(), r.lo.as_deref()) {
                    if rlo >= thi {
                        break;
                    }
                }
                if tab.overlaps(r) {
                    overlap = true;
                    break;
                }
            }
            if overlap {
                live.push(i);
            }
        }
        live
    }

    /// Write a batch of triples (grouped internally by tablet). Returns
    /// the number written. Triples for offline tablets produce an error.
    ///
    /// On a durable table the batch is appended to the write-ahead log
    /// *first*, and the WAL lock is held across the memtable apply so
    /// log order equals apply order (group commit). A log I/O failure
    /// surfaces as [`StoreError::Io`] before any memtable mutates. A
    /// batch that then fails on an offline tablet has already been
    /// logged: recovery replays it in full — offline is transient
    /// write-side backpressure, not a durable rejection.
    pub fn write_batch(&self, batch: Vec<Triple>) -> Result<usize, StoreError> {
        let Some(d) = &self.durable else {
            return self.apply_batch(batch);
        };
        let mut wal = d.wal.lock().unwrap();
        if !batch.is_empty() {
            wal.append_put(&batch).map_err(|e| StoreError::Io {
                context: format!("wal append for table '{}': {e}", self.name),
            })?;
        }
        self.apply_batch(batch)
    }

    /// The memtable half of [`Table::write_batch`] (no logging).
    fn apply_batch(&self, batch: Vec<Triple>) -> Result<usize, StoreError> {
        if self.config.write_latency_us > 0 {
            std::thread::sleep(std::time::Duration::from_micros(self.config.write_latency_us));
        }
        let mut written = 0;
        {
            let tablets = self.tablets.read().unwrap();
            // Group by destination tablet to take each lock once.
            let mut grouped: Vec<Vec<Triple>> = (0..tablets.len()).map(|_| Vec::new()).collect();
            for t in batch {
                let idx = Self::locate(&tablets, &t.row);
                grouped[idx].push(t);
            }
            for (idx, group) in grouped.into_iter().enumerate() {
                if group.is_empty() {
                    continue;
                }
                let mut tab = tablets[idx].lock().unwrap();
                if tab.offline {
                    return Err(StoreError::TabletOffline {
                        table: self.name.clone(),
                        tablet: idx,
                    });
                }
                for t in group {
                    tab.put(t);
                    written += 1;
                }
            }
        }
        self.maybe_split();
        Ok(written)
    }

    /// Split any tablet exceeding the size threshold (one pass; called
    /// after each batch, so growth beyond 2× the threshold is bounded).
    fn maybe_split(&self) {
        let needs_split = {
            let tablets = self.tablets.read().unwrap();
            tablets.iter().enumerate().find_map(|(i, t)| {
                let t = t.lock().unwrap();
                (t.weight() > self.config.split_threshold).then(|| i)
            })
        };
        if let Some(idx) = needs_split {
            let mut tablets = self.tablets.write().unwrap();
            // Re-check under the write lock.
            let split = {
                let mut tab = tablets[idx].lock().unwrap();
                if tab.weight() <= self.config.split_threshold {
                    None
                } else {
                    tab.median_row().map(|m| tab.split_at(&m))
                }
            };
            if let Some(right) = split {
                tablets.insert(idx + 1, Mutex::new(right));
            }
        }
    }

    /// Scan a row range, returning sorted triples, at the
    /// process-default parallelism.
    pub fn scan(&self, range: ScanRange) -> Vec<Triple> {
        self.scan_par(range, Parallelism::current())
    }

    /// [`Table::scan`] with an explicit thread configuration — a thin
    /// consumer of the iterator stack with no filter or combiner
    /// stages.
    pub fn scan_par(&self, range: ScanRange, par: Parallelism) -> Vec<Triple> {
        self.scan_spec_par(&ScanSpec::over(range), par)
    }

    /// Collect a stacked scan (range + filters + combiner) at the
    /// process-default parallelism.
    pub fn scan_spec(&self, spec: &ScanSpec) -> Vec<Triple> {
        self.scan_spec_par(spec, Parallelism::current())
    }

    /// Collect a stacked scan with an explicit thread configuration:
    /// the in-range tablets are resolved once (under the tablet-list
    /// read lock), split into at most `par.threads` contiguous groups,
    /// and each worker runs the full stack over its group. Tablets
    /// split at row boundaries and every stage is per-row, so stitching
    /// the groups in order is byte-identical to the serial stack — and
    /// to naive scan-then-filter-then-reduce (`tests/scan_stack.rs`).
    pub fn scan_spec_par(&self, spec: &ScanSpec, par: Parallelism) -> Vec<Triple> {
        // Hand-built specs may bypass the builder's sorted invariant;
        // normalize once before pruning (which assumes the order too).
        let ranges = scan::ensure_walk_order(spec.ranges.clone());
        let tablets = self.tablets.read().unwrap();
        let live = Self::live_tablets(&tablets, &ranges);
        if par.is_serial() || live.len() <= 1 {
            let base = SliceCursor::new(&tablets, live, ranges, spec.filters.clone());
            return stack_collect(base, spec);
        }
        let parts: Vec<Vec<Triple>> = parallel_map_ranges(par.chunk_ranges(live.len()), |group| {
            let base = SliceCursor::new(
                &tablets,
                live[group].to_vec(),
                ranges.clone(),
                spec.filters.clone(),
            );
            stack_collect(base, spec)
        });
        let mut out = Vec::with_capacity(parts.iter().map(Vec::len).sum());
        for part in parts {
            out.extend(part);
        }
        out
    }

    /// Open a streaming, seekable scan over this table — the stack as
    /// an iterator. Holds no lock between blocks (the cursor re-locates
    /// its tablet by key on every refill), so the stream stays valid
    /// across concurrent writes and tablet splits, and backward seeks
    /// are allowed.
    pub fn scan_stream(&self, spec: ScanSpec) -> TableStream<'_> {
        TableStream::new(self, spec)
    }

    /// Point lookup.
    pub fn get(&self, row: &str, col: &str) -> Option<String> {
        let tablets = self.tablets.read().unwrap();
        let idx = Self::locate(&tablets, row);
        let tab = tablets[idx].lock().unwrap();
        tab.get(row, col).map(str::to_string)
    }

    /// Delete a cell; returns whether it was visible before.
    ///
    /// On a durable table the delete is logged first (under the same
    /// group-commit lock as [`Table::write_batch`]). The `bool` return
    /// leaves no error channel, so a WAL I/O failure here panics with
    /// context rather than silently dropping the log record.
    pub fn delete(&self, row: &str, col: &str) -> bool {
        let Some(d) = &self.durable else {
            return self.apply_delete(row, col);
        };
        let mut wal = d.wal.lock().unwrap();
        wal.append_delete(row, col)
            .unwrap_or_else(|e| panic!("wal append (delete) for table '{}': {e}", self.name));
        self.apply_delete(row, col)
    }

    /// The memtable half of [`Table::delete`] (no logging).
    fn apply_delete(&self, row: &str, col: &str) -> bool {
        let tablets = self.tablets.read().unwrap();
        let idx = Self::locate(&tablets, row);
        let mut tab = tablets[idx].lock().unwrap();
        tab.delete(row, col)
    }

    /// Total stored cells across tablets.
    pub fn len(&self) -> usize {
        let tablets = self.tablets.read().unwrap();
        tablets.iter().map(|t| t.lock().unwrap().len()).sum()
    }

    /// True when no cells are stored.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The current split points (for pipeline range-sharding).
    pub fn split_points(&self) -> Vec<String> {
        let tablets = self.tablets.read().unwrap();
        tablets
            .iter()
            .filter_map(|t| t.lock().unwrap().lo.clone())
            .collect()
    }

    /// Scan into an associative array.
    pub fn scan_to_assoc(&self, range: ScanRange) -> Assoc {
        self.scan_spec_to_assoc(&ScanSpec::over(range), Parallelism::current())
    }

    /// [`Table::scan_to_assoc`] with an explicit thread configuration
    /// for both the fan-out scan and the constructor rebuild.
    pub fn scan_to_assoc_par(&self, range: ScanRange, par: Parallelism) -> Assoc {
        self.scan_spec_to_assoc(&ScanSpec::over(range), par)
    }

    /// Run a stacked scan straight into an associative array. The
    /// serial path streams — triples flow from the stack directly into
    /// the dictionary encoder, never materializing a `Vec<Triple>`
    /// (full-scan batch hint applied unless the spec sets its own); the
    /// parallel path fans the collection out per tablet group first.
    pub fn scan_spec_to_assoc(&self, spec: &ScanSpec, par: Parallelism) -> Assoc {
        if par.is_serial() {
            let mut spec = spec.clone();
            spec.batch.get_or_insert(SCAN_BLOCK);
            super::stream_to_assoc(self.scan_stream(spec), par)
        } else {
            super::stream_to_assoc(self.scan_spec_par(spec, par).into_iter(), par)
        }
    }

    /// Failure injection: mark a tablet offline/online. Offline blocks
    /// *writes* only; reads, scans, and compactions still serve.
    pub fn set_tablet_offline(&self, idx: usize, offline: bool) {
        let tablets = self.tablets.read().unwrap();
        if let Some(t) = tablets.get(idx) {
            t.lock().unwrap().offline = offline;
        }
    }

    /// Minor compaction: freeze every tablet's memtable into an
    /// immutable sorted run (Accumulo's memtable flush). Returns the
    /// number of runs written.
    ///
    /// On a durable table the WAL lock is held throughout, the log is
    /// synced first, and the new runs carry `last_seq` as their
    /// watermark — every record at or below it is now in a run, so
    /// recovery may skip that log prefix. The manifest is rewritten
    /// after the run files land. On an in-memory table this just
    /// freezes (watermark 0, nothing persisted) so scan tests can stack
    /// memtable-over-run states without a filesystem.
    pub fn minor_compact(&self) -> io::Result<usize> {
        let Some(d) = &self.durable else {
            return self.freeze_all(0, None);
        };
        let mut wal = d.wal.lock().unwrap();
        wal.sync()?;
        let watermark = wal.last_seq();
        let written = self.freeze_all(watermark, Some(&d.dir))?;
        if written > 0 {
            self.write_manifest(&d.dir)?;
        }
        Ok(written)
    }

    /// Major compaction: merge each tablet's full layer stack (memtable
    /// + tombstones + all runs) into one run per tablet, applying
    /// `spec`'s combiner and version-retention rule at merge time.
    /// Tombstones and the cells they mask are gone afterwards. Returns
    /// the number of merged runs produced (empty tablets produce none).
    pub fn major_compact(&self, spec: &CompactionSpec) -> io::Result<usize> {
        let Some(d) = &self.durable else {
            return self.compact_all(spec, 0, None);
        };
        let mut wal = d.wal.lock().unwrap();
        wal.sync()?;
        let watermark = wal.last_seq();
        let written = self.compact_all(spec, watermark, Some(&d.dir))?;
        // Rewrite unconditionally: compaction may have *removed* every
        // run (all cells deleted), and the manifest must drop them.
        self.write_manifest(&d.dir)?;
        Ok(written)
    }

    /// Freeze every non-empty tablet memtable into a run, saving each
    /// to `dir` when given. Caller holds the WAL lock on durable paths.
    fn freeze_all(&self, watermark: u64, dir: Option<&Path>) -> io::Result<usize> {
        let tablets = self.tablets.read().unwrap();
        let mut written = 0usize;
        for t in tablets.iter() {
            let mut tab = t.lock().unwrap();
            let seq = self.run_seq.fetch_add(1, Ordering::SeqCst) + 1;
            if let Some(run) = tab.freeze(seq, watermark) {
                if let Some(dir) = dir {
                    run.save(&dir.join(run_file_name(run.seq())))?;
                }
                written += 1;
            }
        }
        Ok(written)
    }

    /// Merge every tablet's layers down to (at most) one run each.
    fn compact_all(
        &self,
        spec: &CompactionSpec,
        watermark: u64,
        dir: Option<&Path>,
    ) -> io::Result<usize> {
        let tablets = self.tablets.read().unwrap();
        let mut written = 0usize;
        for t in tablets.iter() {
            let mut tab = t.lock().unwrap();
            let seq = self.run_seq.fetch_add(1, Ordering::SeqCst) + 1;
            if let Some(run) = tab.compact(spec, seq, watermark) {
                if let Some(dir) = dir {
                    run.save(&dir.join(run_file_name(run.seq())))?;
                }
                written += 1;
            }
        }
        Ok(written)
    }

    /// Rewrite the manifest to the set of currently attached run files
    /// (post-split tablets share runs; the `BTreeSet` dedups). Written
    /// to a temp file then renamed, so readers see old-or-new, never a
    /// torn list.
    fn write_manifest(&self, dir: &Path) -> io::Result<()> {
        let mut names: BTreeSet<u64> = BTreeSet::new();
        {
            let tablets = self.tablets.read().unwrap();
            for t in tablets.iter() {
                let tab = t.lock().unwrap();
                for run in tab.runs() {
                    names.insert(run.seq());
                }
            }
        }
        let mut body = String::new();
        for seq in names {
            body.push_str(&run_file_name(seq));
            body.push('\n');
        }
        let tmp = dir.join(format!("{MANIFEST_FILE}.tmp"));
        std::fs::write(&tmp, body)?;
        std::fs::rename(&tmp, dir.join(MANIFEST_FILE))?;
        Ok(())
    }

    /// Number of distinct runs attached across tablets.
    pub fn run_count(&self) -> usize {
        let tablets = self.tablets.read().unwrap();
        let mut seqs: BTreeSet<u64> = BTreeSet::new();
        for t in tablets.iter() {
            let tab = t.lock().unwrap();
            for run in tab.runs() {
                seqs.insert(run.seq());
            }
        }
        seqs.len()
    }

    /// Stored versions of one cell across the tablet's layer stack
    /// (tombstones count) — observability for the versioning-iterator
    /// retention tests.
    pub fn cell_versions(&self, row: &str, col: &str) -> usize {
        let tablets = self.tablets.read().unwrap();
        let idx = Self::locate(&tablets, row);
        let tab = tablets[idx].lock().unwrap();
        tab.cell_versions(row, col)
    }

    /// Force the WAL to stable storage regardless of the configured
    /// [`FsyncPolicy`]. No-op on in-memory tables.
    pub fn sync(&self) -> io::Result<()> {
        if let Some(d) = &self.durable {
            d.wal.lock().unwrap().sync()?;
        }
        Ok(())
    }
}

/// Run file name for a run sequence number (zero-padded so manifests
/// and directory listings sort by age).
fn run_file_name(seq: u64) -> String {
    format!("run-{seq:08}.run")
}

/// Tablet blocks fetched after a seek start small and double up to
/// [`SCAN_BLOCK`] — point-ish reads (BFS row probes) stay cheap while
/// long scans amortize locking, the classic scanner batch ramp. A
/// [`ScanSpec::batch`] hint overrides this starting size per stream.
const STREAM_BLOCK_MIN: usize = 64;

/// The base cursor of a [`TableStream`]: a block cursor that re-locates
/// its tablet *by key* on every refill instead of pinning the tablet
/// list, so it holds no table lock between blocks and survives
/// concurrent splits (Accumulo scanners re-resolve tablet locations the
/// same way). Spec filters are evaluated beneath the tablet block copy.
struct TableCursor<'a> {
    table: &'a Table,
    /// Sorted, coalesced range set (empty = scan nothing).
    ranges: Vec<ScanRange>,
    /// The set's overall exclusive row upper bound (`None` = +∞).
    set_hi: Option<String>,
    filters: Vec<CellFilter>,
    /// Resume key `(row, col, inclusive)`; `None` = range start.
    resume: Option<(SharedStr, SharedStr, bool)>,
    /// Current block, reversed so consuming is a move-out pop.
    buf: Vec<Triple>,
    done: bool,
    block: usize,
    /// Block size installed after open/seek (the batch ramp start).
    block_min: usize,
}

impl<'a> TableCursor<'a> {
    fn new(
        table: &'a Table,
        ranges: Vec<ScanRange>,
        filters: Vec<CellFilter>,
        batch: Option<usize>,
    ) -> Self {
        let block_min = batch.unwrap_or(STREAM_BLOCK_MIN).clamp(1, SCAN_BLOCK);
        let ranges = scan::ensure_walk_order(ranges);
        let done = ranges.is_empty();
        let set_hi = if done { None } else { scan::ranges_row_hi(&ranges).map(String::from) };
        TableCursor {
            table,
            ranges,
            set_hi,
            filters,
            resume: None,
            buf: Vec::new(),
            done,
            block: block_min,
            block_min,
        }
    }

    fn refill(&mut self) {
        self.buf.clear();
        // Both locks (tablet-list read lock, tablet mutex) are taken
        // and released per iteration, so writers and splits interleave
        // even when a selective filter needs several all-rejected
        // blocks to find the next match.
        loop {
            // Snap the position onto the range set first, so a resume
            // key sitting in a gap between ranges locates the next
            // range's tablet directly instead of walking every tablet
            // under the gap.
            let snapped: Option<Option<(SharedStr, SharedStr)>> = {
                let pos_row = match &self.resume {
                    Some((r, _, _)) => r.as_str(),
                    None => self.ranges[0].lo.as_deref().unwrap_or(""),
                };
                match scan::snap_row(&self.ranges, pos_row) {
                    None => None,
                    Some(s) if s != pos_row => {
                        Some(Some((s.into(), scan::start_col(&self.ranges, s).into())))
                    }
                    Some(_) => Some(None),
                }
            };
            match snapped {
                // Past every range: exhausted.
                None => {
                    self.done = true;
                    return;
                }
                Some(Some((row, col))) => self.resume = Some((row, col, true)),
                Some(None) => {}
            }
            let tablets = self.table.tablets.read().unwrap();
            let pos_row = match &self.resume {
                Some((r, _, _)) => r.as_str(),
                None => self.ranges[0].lo.as_deref().unwrap_or(""),
            };
            let idx = Table::locate(&tablets, pos_row);
            let tab = tablets[idx].lock().unwrap();
            // The located tablet starts at or past the set's end: done.
            if let (Some(hi), Some(tlo)) = (self.set_hi.as_deref(), tab.lo.as_deref()) {
                if tlo >= hi {
                    self.done = true;
                    return;
                }
            }
            let from = self.resume.as_ref().map(|(r, c, inc)| (r.as_str(), c.as_str(), *inc));
            let more =
                tab.scan_block(from, &self.ranges, &self.filters, self.block, &mut self.buf);
            if let Some((row, col)) = more {
                self.resume = Some((row, col, false));
                if !self.buf.is_empty() {
                    self.block = (self.block * 2).min(SCAN_BLOCK);
                    self.buf.reverse();
                    return;
                }
                // Examined cap fired on an all-rejected block: release
                // the locks and keep scanning from the resume key.
                continue;
            }
            // This tablet is done for the range set — move to the next
            // one immediately (no extra lock round trip for a partial
            // final block) or finish the stream.
            match tab.hi.clone() {
                None => self.done = true,
                Some(hi) => {
                    if self.set_hi.as_deref().is_some_and(|rhi| hi.as_str() >= rhi) {
                        self.done = true;
                    } else {
                        // Continue at the next tablet's first key.
                        self.resume = Some((hi.into(), "".into(), true));
                    }
                }
            }
            if self.done || !self.buf.is_empty() {
                self.buf.reverse();
                return;
            }
        }
    }
}

impl ScanIter for TableCursor<'_> {
    fn seek(&mut self, row: &str, col: &str) {
        self.buf.clear();
        if self.ranges.is_empty() {
            self.done = true;
            return;
        }
        self.done = false;
        self.block = self.block_min;
        let (row, col) = match self.ranges[0].lo.as_deref() {
            Some(lo) if row < lo => (lo, ""),
            _ => (row, col),
        };
        self.resume = Some((row.into(), col.into(), true));
    }

    fn next_triple(&mut self) -> Option<Triple> {
        loop {
            if let Some(t) = self.buf.pop() {
                return Some(t);
            }
            if self.done {
                return None;
            }
            self.refill();
        }
    }
}

/// A streaming stacked scan over a [`Table`]: the full iterator stack
/// (range cursor with pushed-down filters → combiner) pulled one triple
/// at a time. Implements both [`ScanIter`] (seek + next) and
/// [`Iterator`].
pub struct TableStream<'a> {
    inner: ReduceIter<TableCursor<'a>>,
}

impl<'a> TableStream<'a> {
    fn new(table: &'a Table, spec: ScanSpec) -> Self {
        let base = TableCursor::new(table, spec.ranges, spec.filters, spec.batch);
        TableStream { inner: ReduceIter::new(base, spec.reduce) }
    }
}

impl ScanIter for TableStream<'_> {
    fn seek(&mut self, row: &str, col: &str) {
        self.inner.seek(row, col);
    }

    fn next_triple(&mut self) -> Option<Triple> {
        self.inner.next_triple()
    }
}

impl Iterator for TableStream<'_> {
    type Item = Triple;

    fn next(&mut self) -> Option<Triple> {
        self.inner.next_triple()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::scan::{CellFilter, KeyMatch, RowReduce};

    fn small_table() -> Table {
        // Tiny split threshold so splits actually happen in tests.
        Table::new("t", TableConfig { split_threshold: 64, write_latency_us: 0 })
    }

    fn batch(n: usize) -> Vec<Triple> {
        (0..n).map(|i| Triple::new(format!("row{i:04}"), "c", "value")).collect()
    }

    #[test]
    fn write_and_point_get() {
        let t = small_table();
        t.write_batch(vec![Triple::new("r", "c", "v")]).unwrap();
        assert_eq!(t.get("r", "c"), Some("v".into()));
        assert_eq!(t.get("r", "x"), None);
        assert!(t.delete("r", "c"));
        assert!(t.is_empty());
    }

    #[test]
    fn splits_on_growth_and_stays_scannable() {
        let t = small_table();
        t.write_batch(batch(100)).unwrap();
        assert!(t.tablet_count() > 1, "expected splits, got 1 tablet");
        assert_eq!(t.len(), 100);
        // Scan returns everything, sorted.
        let all = t.scan(ScanRange::all());
        assert_eq!(all.len(), 100);
        assert!(all.windows(2).all(|w| w[0] <= w[1]));
        // Point gets route across split tablets.
        assert_eq!(t.get("row0000", "c"), Some("value".into()));
        assert_eq!(t.get("row0099", "c"), Some("value".into()));
    }

    #[test]
    fn ranged_scans() {
        let t = small_table();
        t.write_batch(batch(50)).unwrap();
        let r = t.scan(ScanRange::rows("row0010", "row0020"));
        assert_eq!(r.len(), 10);
        assert_eq!(r[0].row, "row0010");
        assert_eq!(r[9].row, "row0019");
        let single = t.scan(ScanRange::single("row0033"));
        assert_eq!(single.len(), 1);
    }

    #[test]
    fn column_windowed_scans() {
        let t = small_table();
        let mut b = Vec::new();
        for i in 0..20 {
            for c in ["a", "b", "c"] {
                b.push(Triple::new(format!("row{i:04}"), c, "v"));
            }
        }
        t.write_batch(b).unwrap();
        let win = t.scan(ScanRange::all().with_cols("b", "c"));
        assert_eq!(win.len(), 20);
        assert!(win.iter().all(|t| t.col == "b"));
        let both = t.scan(ScanRange::rows("row0005", "row0010").with_cols("a", "c"));
        assert_eq!(both.len(), 10);
    }

    #[test]
    fn overwrite_keeps_single_cell() {
        let t = small_table();
        t.write_batch(vec![Triple::new("r", "c", "1")]).unwrap();
        t.write_batch(vec![Triple::new("r", "c", "2")]).unwrap();
        assert_eq!(t.len(), 1);
        assert_eq!(t.get("r", "c"), Some("2".into()));
    }

    #[test]
    fn offline_tablet_rejects_writes() {
        let t = small_table();
        t.write_batch(batch(10)).unwrap();
        t.set_tablet_offline(0, true);
        let err = t.write_batch(vec![Triple::new("row0000", "c", "v")]).unwrap_err();
        assert!(matches!(err, StoreError::TabletOffline { .. }));
        t.set_tablet_offline(0, false);
        assert!(t.write_batch(vec![Triple::new("row0000", "c", "v")]).is_ok());
    }

    #[test]
    fn split_points_reflect_tablets() {
        let t = small_table();
        t.write_batch(batch(100)).unwrap();
        let sp = t.split_points();
        assert_eq!(sp.len(), t.tablet_count() - 1);
        assert!(sp.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn stream_matches_collect_and_seeks() {
        let t = small_table();
        t.write_batch(batch(80)).unwrap();
        assert!(t.tablet_count() > 1);
        let collected = t.scan(ScanRange::all());
        let streamed: Vec<Triple> = t.scan_stream(ScanSpec::all()).collect();
        assert_eq!(collected, streamed);
        // Absolute seeks, forward then backward.
        let mut s = t.scan_stream(ScanSpec::all());
        s.seek("row0040", "");
        assert_eq!(s.next_triple().unwrap().row, "row0040");
        s.seek("row0007", "");
        assert_eq!(s.next_triple().unwrap().row, "row0007");
    }

    #[test]
    fn stacked_scan_filters_and_reduces() {
        let t = small_table();
        let mut b = Vec::new();
        for i in 0..30 {
            b.push(Triple::new(format!("r{:02}", i % 10), format!("c{i:02}"), "2"));
        }
        t.write_batch(b).unwrap();
        let spec = ScanSpec::all()
            .filtered(CellFilter::col(KeyMatch::Glob("c*0".into())))
            .reduced(RowReduce::Sum { out_col: "sum".into() });
        let got = t.scan_spec(&spec);
        // Columns c00, c10, c20 → rows r00 and r01... only rows whose
        // cells include a matching column appear.
        assert!(got.iter().all(|t| t.col == "sum"));
        // Cross-check against the naive client-side pipeline.
        let mut expect: Vec<Triple> = Vec::new();
        let mut cur: Option<(SharedStr, f64)> = None;
        for tr in t.scan(ScanRange::all()) {
            if !KeyMatch::Glob("c*0".into()).matches(&tr.col) {
                continue;
            }
            let v: f64 = tr.val.parse().unwrap_or(0.0);
            match &mut cur {
                Some((row, acc)) if *row == tr.row => *acc += v,
                _ => {
                    if let Some((row, acc)) = cur.take() {
                        expect.push(Triple::new(row, "sum", crate::store::format_num(acc)));
                    }
                    cur = Some((tr.row.clone(), v));
                }
            }
        }
        if let Some((row, acc)) = cur {
            expect.push(Triple::new(row, "sum", crate::store::format_num(acc)));
        }
        assert_eq!(got, expect);
        assert!(!got.is_empty());
    }

    #[test]
    fn batch_hints_do_not_change_results() {
        let t = small_table();
        t.write_batch(batch(80)).unwrap();
        let expect: Vec<Triple> = t.scan_stream(ScanSpec::all()).collect();
        // Any hint (clamped to 1..=SCAN_BLOCK) yields identical bytes;
        // the hint only moves lock/copy granularity.
        for hint in [1usize, 2, 7, 64, 100_000] {
            let got: Vec<Triple> = t.scan_stream(ScanSpec::all().batched(hint)).collect();
            assert_eq!(got, expect, "hint={hint}");
            let mut s = t.scan_stream(ScanSpec::all().batched(hint));
            s.seek("row0040", "");
            assert_eq!(s.next_triple().unwrap().row, "row0040", "hint={hint}");
        }
    }

    #[test]
    fn multi_range_scans_across_split_tablets() {
        let t = small_table();
        t.write_batch(batch(100)).unwrap();
        assert!(t.tablet_count() > 1);
        let spec = ScanSpec::ranges([
            ScanRange::rows("row0070", "row0080"),
            ScanRange::single("row0042"),
            ScanRange::rows("row0000", "row0010"),
        ]);
        // Collected, parallel, and streamed walks all agree and equal
        // the sorted union of the per-range scans.
        let mut expect = t.scan(ScanRange::rows("row0000", "row0010"));
        expect.extend(t.scan(ScanRange::single("row0042")));
        expect.extend(t.scan(ScanRange::rows("row0070", "row0080")));
        let got = t.scan_spec(&spec);
        assert_eq!(got, expect);
        assert_eq!(got.len(), 21);
        let streamed: Vec<Triple> = t.scan_stream(spec.clone()).collect();
        assert_eq!(streamed, expect);
        for threads in [2usize, 4] {
            assert_eq!(t.scan_spec_par(&spec, Parallelism::with_threads(threads)), expect);
        }
        // Seeking into a gap lands on the next range's first cell.
        let mut s = t.scan_stream(spec);
        s.seek("row0050", "");
        assert_eq!(s.next_triple().unwrap().row, "row0070");
        // An empty range set scans nothing, streamed or collected.
        assert!(t.scan_spec(&ScanSpec::ranges(Vec::new())).is_empty());
        assert!(t.scan_stream(ScanSpec::ranges(Vec::new())).next().is_none());
        // A hand-built spec that bypassed the builder's sort is
        // normalized at the scan entry points, not silently mis-walked.
        let hand = ScanSpec {
            ranges: vec![
                ScanRange::rows("row0070", "row0080"),
                ScanRange::rows("row0000", "row0010"),
            ],
            ..ScanSpec::default()
        };
        let mut expect2 = t.scan(ScanRange::rows("row0000", "row0010"));
        expect2.extend(t.scan(ScanRange::rows("row0070", "row0080")));
        assert_eq!(t.scan_spec(&hand), expect2);
        let hand_streamed: Vec<Triple> = t.scan_stream(hand).collect();
        assert_eq!(hand_streamed, expect2);
    }

    #[test]
    fn multi_range_stacks_with_filters_and_combiners() {
        let t = small_table();
        let mut b = Vec::new();
        for i in 0..40 {
            for c in ["c1", "c2", "c3"] {
                b.push(Triple::new(format!("r{i:02}"), c, "2"));
            }
        }
        t.write_batch(b).unwrap();
        let spec = ScanSpec::ranges([
            ScanRange::rows("r00", "r05"),
            ScanRange::rows("r30", "r33"),
        ])
        .filtered(CellFilter::col(KeyMatch::In(
            ["c1", "c3"].iter().map(|s| s.to_string()).collect(),
        )))
        .reduced(RowReduce::Sum { out_col: "s".into() });
        let got = t.scan_spec(&spec);
        // 5 + 3 rows, each summing two kept cells of value 2.
        assert_eq!(got.len(), 8);
        assert!(got.iter().all(|t| t.col == "s" && t.val == "4"));
        assert_eq!(got[0].row, "r00");
        assert_eq!(got[7].row, "r32");
    }

    #[test]
    fn concurrent_writers() {
        use std::sync::Arc;
        let t = Arc::new(small_table());
        let mut handles = Vec::new();
        for w in 0..4 {
            let t = Arc::clone(&t);
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    t.write_batch(vec![Triple::new(
                        format!("w{w}-row{i:03}"),
                        "c",
                        "v",
                    )])
                    .unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(t.len(), 200);
        let all = t.scan(ScanRange::all());
        assert!(all.windows(2).all(|w| w[0] <= w[1]));
    }

    fn temp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join("d4m-table-tests")
            .join(format!("{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn durable_roundtrip_recovers_everything() {
        let dir = temp_dir("roundtrip");
        {
            let t =
                Table::durable("t", TableConfig::default(), &dir, FsyncPolicy::Never).unwrap();
            t.write_batch(batch(30)).unwrap();
            assert!(t.delete("row0003", "c"));
            t.sync().unwrap();
        }
        let r = Table::recover("t", TableConfig::default(), &dir, FsyncPolicy::Never).unwrap();
        assert_eq!(r.len(), 29);
        assert_eq!(r.get("row0000", "c"), Some("value".into()));
        assert_eq!(r.get("row0003", "c"), None);
        // Recovery checkpointed into runs + a fresh (empty) log; a
        // second recovery replays nothing and still agrees.
        let expect = r.scan(ScanRange::all());
        drop(r);
        let r2 = Table::recover("t", TableConfig::default(), &dir, FsyncPolicy::Never).unwrap();
        assert_eq!(r2.scan(ScanRange::all()), expect);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn minor_compact_preserves_scans_and_survives_recovery() {
        let dir = temp_dir("minor");
        let cfg = TableConfig { split_threshold: 64, write_latency_us: 0 };
        let t = Table::durable("t", cfg.clone(), &dir, FsyncPolicy::Never).unwrap();
        t.write_batch(batch(40)).unwrap();
        assert!(t.tablet_count() > 1);
        let before = t.scan(ScanRange::all());
        assert!(t.minor_compact().unwrap() >= 1);
        assert!(t.run_count() >= 1);
        // Run-backed scans are byte-identical to the memtable scan.
        assert_eq!(t.scan(ScanRange::all()), before);
        // Layer new writes over the runs: overwrite shadows, delete
        // tombstones a run-resident cell.
        t.write_batch(vec![Triple::new("row0005", "c", "v2")]).unwrap();
        assert_eq!(t.get("row0005", "c"), Some("v2".into()));
        assert!(t.delete("row0006", "c"));
        assert_eq!(t.get("row0006", "c"), None);
        assert_eq!(t.len(), 39);
        let expect = t.scan(ScanRange::all());
        drop(t);
        let r = Table::recover("t", cfg, &dir, FsyncPolicy::Never).unwrap();
        assert_eq!(r.scan(ScanRange::all()), expect);
        assert_eq!(r.get("row0006", "c"), None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn major_compact_purges_tombstones_and_applies_retention() {
        let dir = temp_dir("major");
        let t = Table::durable("t", TableConfig::default(), &dir, FsyncPolicy::Never).unwrap();
        t.write_batch(vec![Triple::new("a", "x", "1")]).unwrap();
        t.minor_compact().unwrap();
        t.write_batch(vec![Triple::new("a", "x", "2")]).unwrap();
        t.minor_compact().unwrap();
        t.write_batch(vec![Triple::new("a", "x", "3"), Triple::new("b", "y", "9")]).unwrap();
        assert_eq!(t.cell_versions("a", "x"), 3);
        assert!(t.delete("b", "y"));
        t.major_compact(&CompactionSpec { reduce: None, max_versions: 2 }).unwrap();
        assert_eq!(t.run_count(), 1);
        assert_eq!(t.cell_versions("a", "x"), 2);
        assert_eq!(t.get("a", "x"), Some("3".into()));
        assert_eq!(t.get("b", "y"), None);
        assert_eq!(t.len(), 1);
        drop(t);
        let r = Table::recover("t", TableConfig::default(), &dir, FsyncPolicy::Never).unwrap();
        assert_eq!(r.get("a", "x"), Some("3".into()));
        assert_eq!(r.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn in_memory_compaction_needs_no_directory() {
        let t = small_table();
        t.write_batch(batch(50)).unwrap();
        let before = t.scan(ScanRange::all());
        assert!(t.minor_compact().unwrap() >= 1);
        assert_eq!(t.scan(ScanRange::all()), before);
        // Overwrites land in the memtable above the frozen runs.
        t.write_batch(batch(50)).unwrap();
        assert_eq!(t.scan(ScanRange::all()), before);
        t.major_compact(&CompactionSpec::default()).unwrap();
        assert_eq!(t.scan(ScanRange::all()), before);
        assert_eq!(t.len(), 50);
    }

    #[test]
    fn stream_survives_mid_scan_split() {
        let t = small_table();
        t.write_batch(batch(20)).unwrap();
        let mut s = t.scan_stream(ScanSpec::all());
        let mut got = Vec::new();
        for _ in 0..5 {
            got.push(s.next_triple().unwrap());
        }
        // Grow the table past more split points while the stream is
        // open; the cursor re-locates by key and keeps going.
        t.write_batch((0..40).map(|i| Triple::new(format!("zz{i:03}"), "c", "v")).collect())
            .unwrap();
        for tr in s {
            got.push(tr);
        }
        assert!(got.windows(2).all(|w| w[0] < w[1]), "stream stays sorted");
        assert_eq!(got.iter().filter(|t| t.row.starts_with("zz")).count(), 40);
        assert_eq!(got.len(), 60);
    }
}
