//! An Accumulo-like sorted, distributed key/value triple store.
//!
//! D4M's "distributed" dimension historically fronts Apache Accumulo: a
//! sorted, distributed key/value store holding associative-array triples,
//! with Graphulo providing server-side linear algebra (paper §I). The
//! JVM stack is unavailable here, so this module *is* the substitute
//! substrate (see DESIGN.md §3), reproducing the interface contract the
//! paper's ecosystem relies on:
//!
//! * **[`Tablet`]** — a sorted in-memory key range (Accumulo tablet):
//!   `(row, col) → val` in a `BTreeMap`, with extent bounds and size
//!   accounting.
//! * **[`Table`]** — a named table: ordered tablets with split points,
//!   automatic splitting when a tablet exceeds its size threshold,
//!   range scans, and multi-threaded-friendly (`Mutex` per tablet).
//! * **[`BatchWriter`]** — buffered, tablet-grouped ingest (the
//!   Accumulo `BatchWriter` that made the 100M-inserts/s result of the
//!   D4M lineage possible, scaled down).
//! * **[`TableStore`]** — the "instance": a named collection of tables,
//!   including D4M's standard *adjacency + transpose-adjacency* pair so
//!   both row and column access are sorted scans.
//! * **[`scan`]** — the server-side iterator stack (Accumulo's
//!   seek/next iterator model): composable range-set, filter, and
//!   combiner stages executed against the tablets, streamed to the
//!   consumer ([`Table::scan_stream`]) or collected over pinned
//!   snapshots with per-range-chunk parallel fan-out
//!   ([`Table::scan_spec_par`]). A spec carries a sorted, coalesced
//!   *set* of ranges ([`ScanSpec::ranges()`], the Accumulo
//!   `BatchScanner` idiom), served in one stacked pass.
//!
//! Triples here are strings (Accumulo keys are bytes), stored and
//! handed out as shared-bytes [`SharedStr`] handles: a cell scanned out
//! of a tablet is a *pointer* clone of the stored bytes, and stays one
//! through every scan stage, the constructor, and the Graphulo kernels
//! (PR 4's zero-copy cell path). Conversion to/from
//! [`crate::assoc::Assoc`] happens at the boundary
//! ([`Table::scan_to_assoc`], [`TableStore::ingest_assoc`]), where the
//! dictionary encoder touches each distinct key once.
//!
//! **Durability** (PR 6) gives the store Accumulo's tiered write path:
//! a [`wal`] write-ahead log in front of the memtables, minor
//! compactions freezing memtables into immutable dictionary-encoded
//! sorted runs, and major compactions merging runs under a combiner
//! and version-retention rule ([`CompactionSpec`]). Open durable tables
//! with [`TableStore::durable`]; reopen a directory after a crash with
//! [`TableStore::recover`].
//!
//! **Fault tolerance** (PR 7) puts a pluggable [`StorageIo`] backend
//! beneath the durable tier. Storage calls run under a deterministic
//! seeded [`crate::util::retry::RetryPolicy`]; recovery *quarantines*
//! corrupt files (moved aside, reported via [`Table::health`]) instead
//! of failing; a failed compaction leaves memtables and the manifest
//! untouched and is safely re-runnable; and a permanent WAL failure
//! moves the table down a degradation ladder ([`TableHealth`]) rather
//! than panicking. [`FaultyIo`] injects scheduled faults
//! deterministically for the `tests/fault_injection.rs` suite.
//!
//! **Snapshot scans** (PR 8) make the read path lock-free: every scan
//! pins one [`TabletSnapshot`] per tablet (`Arc`-shared runs plus a
//! frozen memtable image) and walks the pinned state with *zero lock
//! acquisitions after open* — asserted in tests through the
//! [`lock_acquisitions`] counting shim wrapped around the table's
//! locks. [`Table::scan_spec_par`] fans load-balanced *range chunks*
//! over the snapshots independent of tablet boundaries (Accumulo's
//! BatchScanner worker model), and [`Table::scan_snapshot`] exposes
//! the pinned scan ([`SnapshotScan`]) directly.
//!
//! **Block-granular run I/O** (PR 9) removes the last total-run-bytes
//! memory bound: run files are laid out as index-addressed data blocks
//! (the Accumulo RFile shape) behind a shared byte-capacity LRU
//! [`BlockCache`], so a table opened with
//! [`DurableOptions::cache_capacity`] pages blocks in on demand — scans
//! hold only the blocks they are merging, multi-range scans seek via
//! the block index without faulting gap blocks, and `major_compact`
//! streams block-by-block instead of materializing every input run.
//! The default (no cache configured) stays fully resident, preserving
//! the PR 6–8 behavior bit-for-bit.

mod cache;
mod compact;
pub mod io;
mod lock;
mod run;
pub mod scan;
mod table;
mod tablet;
pub mod wal;
mod writer;

pub use cache::{Block, BlockCache, CacheStats};
pub use compact::CompactionSpec;
pub use io::{FaultKind, FaultPlan, FaultyIo, RealIo, StorageFile, StorageIo};
pub use lock::{lock_acquisitions, TrackedMutex, TrackedRwLock};
pub use run::{Run, RunCursor};
pub use scan::{
    coalesce_ranges, format_num, CellField, CellFilter, KeyMatch, RowReduce, ScanIter, ScanRange,
    ScanSpec, SCAN_BLOCK,
};
pub use table::{
    DurableOptions, HealthReport, SnapshotScan, SnapshotStream, Table, TableConfig, TableHealth,
    TableStats, TableStream,
};
pub use tablet::{Tablet, TabletSnapshot};
pub use wal::FsyncPolicy;
pub use writer::{BatchWriter, WriterConfig};

use crate::assoc::{Aggregator, Assoc, Key, ValsInput};
use crate::util::intern::StrDict;
pub use crate::util::SharedStr;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// A stored triple: `(row, column, value)`, all shared-bytes strings —
/// cloning a `Triple` is three pointer copies, never a byte copy.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Triple {
    /// Row key.
    pub row: SharedStr,
    /// Column key.
    pub col: SharedStr,
    /// Value (string; numeric values are rendered).
    pub val: SharedStr,
}

impl Triple {
    /// Construct a triple.
    pub fn new(
        row: impl Into<SharedStr>,
        col: impl Into<SharedStr>,
        val: impl Into<SharedStr>,
    ) -> Self {
        Triple { row: row.into(), col: col.into(), val: val.into() }
    }

    /// Approximate in-store size in bytes (key + value lengths).
    pub fn weight(&self) -> usize {
        self.row.len() + self.col.len() + self.val.len()
    }
}

/// Errors from store operations.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StoreError {
    /// The named table does not exist.
    NoSuchTable(String),
    /// A tablet server was marked offline (failure injection).
    TabletOffline { table: String, tablet: usize },
    /// A durable-storage I/O failure (WAL append, run write) that
    /// survived the retry schedule, with the failing operation's
    /// context. `transient` carries the
    /// [`crate::util::retry::ErrorClass`]: `true` means the retry
    /// budget ran out on a retryable condition and the *next* attempt
    /// may succeed ([`BatchWriter`] re-flushes these); `false` means
    /// the storage said no definitively. Carried as a rendered string
    /// so the error stays `Clone + PartialEq` like the rest of the
    /// enum.
    Io { context: String, transient: bool },
    /// The table moved down the degradation ladder (permanent WAL
    /// failure without in-memory fallback) and rejects writes; reads
    /// and scans still serve.
    Degraded { table: String, state: TableHealth },
}

impl StoreError {
    /// Whether retrying the failed operation may succeed: offline
    /// tablets come back ([`Table::set_tablet_offline`]) and
    /// transient I/O heals; degraded tables and permanent I/O do not.
    pub fn is_transient(&self) -> bool {
        match self {
            StoreError::TabletOffline { .. } => true,
            StoreError::Io { transient, .. } => *transient,
            StoreError::NoSuchTable(_) | StoreError::Degraded { .. } => false,
        }
    }
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::NoSuchTable(t) => write!(f, "no such table: {t}"),
            StoreError::TabletOffline { table, tablet } => {
                write!(f, "tablet {tablet} of table {table} is offline")
            }
            StoreError::Io { context, transient } => {
                let class = if *transient { "transient" } else { "permanent" };
                write!(f, "storage i/o error ({class}): {context}")
            }
            StoreError::Degraded { table, state } => {
                write!(f, "table {table} is {state} and rejects writes")
            }
        }
    }
}

impl std::error::Error for StoreError {}

/// Durable root settings shared by every table a [`TableStore`]
/// creates: the root directory, the fsync policy, and the storage
/// backend / retry / degradation options.
struct DurableRoot {
    dir: std::path::PathBuf,
    policy: FsyncPolicy,
    opts: DurableOptions,
}

/// A store instance: named tables plus the D4M adjacency/transpose pair
/// convention (`name` and `name_T`).
pub struct TableStore {
    tables: Mutex<BTreeMap<String, Arc<Table>>>,
    config: TableConfig,
    /// Durable root: when set, every table lives in its own
    /// `<root>/<name>/` directory with a WAL and run files.
    durable: Option<DurableRoot>,
}

impl TableStore {
    /// New store whose tables use `config`.
    pub fn new(config: TableConfig) -> Self {
        TableStore { tables: Mutex::new(BTreeMap::new()), config, durable: None }
    }

    /// New store with default table configuration.
    pub fn with_defaults() -> Self {
        Self::new(TableConfig::default())
    }

    /// New durable store rooted at `dir`: each created table gets its
    /// own subdirectory (`<dir>/<name>/`) holding a write-ahead log and
    /// its compacted runs. Table names are used as directory names.
    /// Reopen an existing root with [`TableStore::recover`].
    pub fn durable(
        dir: impl AsRef<std::path::Path>,
        config: TableConfig,
        policy: FsyncPolicy,
    ) -> std::io::Result<Self> {
        Self::durable_with(dir, config, policy, DurableOptions::default())
    }

    /// [`TableStore::durable`] with explicit [`DurableOptions`] (storage
    /// backend, retry schedule, degradation mode) applied to every table
    /// this store creates or recovers.
    pub fn durable_with(
        dir: impl AsRef<std::path::Path>,
        config: TableConfig,
        policy: FsyncPolicy,
        opts: DurableOptions,
    ) -> std::io::Result<Self> {
        let dir = dir.as_ref();
        opts.retry.run("create store root", || opts.io.create_dir_all(dir))?;
        let mut store = Self::new(config);
        store.durable = Some(DurableRoot { dir: dir.to_path_buf(), policy, opts });
        Ok(store)
    }

    /// Reopen a durable store root with default configuration and
    /// [`FsyncPolicy::Never`]: every subdirectory of `dir` is recovered
    /// as a table (runs loaded, WAL suffix replayed).
    pub fn recover(dir: impl AsRef<std::path::Path>) -> std::io::Result<Self> {
        Self::recover_with(dir, TableConfig::default(), FsyncPolicy::Never)
    }

    /// [`TableStore::recover`] with explicit table configuration and
    /// fsync policy. Non-directory entries under the root are skipped;
    /// a non-UTF-8 directory name is an `InvalidData` error (it cannot
    /// name a table).
    pub fn recover_with(
        dir: impl AsRef<std::path::Path>,
        config: TableConfig,
        policy: FsyncPolicy,
    ) -> std::io::Result<Self> {
        Self::recover_with_opts(dir, config, policy, DurableOptions::default())
    }

    /// [`TableStore::recover_with`] with explicit [`DurableOptions`]:
    /// every table directory is recovered through the given storage
    /// backend and retry schedule (per-table quarantine reports are
    /// available via each table's [`Table::health`]).
    pub fn recover_with_opts(
        dir: impl AsRef<std::path::Path>,
        config: TableConfig,
        policy: FsyncPolicy,
        opts: DurableOptions,
    ) -> std::io::Result<Self> {
        let dir = dir.as_ref();
        let store = Self::durable_with(dir, config, policy, opts.clone())?;
        for (name, is_dir) in opts.io.read_dir(dir)? {
            if !is_dir {
                continue;
            }
            let table = Table::recover_with(
                &name,
                store.config.clone(),
                &dir.join(&name),
                policy,
                opts.clone(),
            )?;
            store.tables.lock().unwrap().insert(name, Arc::new(table));
        }
        Ok(store)
    }

    /// Create (or get) a table. On a durable store this creates the
    /// table's directory and write-ahead log; an I/O failure there
    /// panics with context (use [`TableStore::try_create_table`] for
    /// the fallible variant, and [`TableStore::recover`] to reopen
    /// existing tables instead of re-creating them).
    pub fn create_table(&self, name: &str) -> Arc<Table> {
        self.try_create_table(name)
            .unwrap_or_else(|e| panic!("creating durable table '{name}': {e}"))
    }

    /// Create (or get) a table, surfacing durable-setup I/O failures
    /// (directory or WAL creation after retries) instead of panicking.
    pub fn try_create_table(&self, name: &str) -> std::io::Result<Arc<Table>> {
        let mut tables = self.tables.lock().unwrap();
        if let Some(t) = tables.get(name) {
            return Ok(t.clone());
        }
        let table = match &self.durable {
            Some(root) => Table::durable_with(
                name,
                self.config.clone(),
                &root.dir.join(name),
                root.policy,
                root.opts.clone(),
            )?,
            None => Table::new(name, self.config.clone()),
        };
        let table = Arc::new(table);
        tables.insert(name.to_string(), table.clone());
        Ok(table)
    }

    /// Look up an existing table.
    pub fn table(&self, name: &str) -> Result<Arc<Table>, StoreError> {
        self.tables
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| StoreError::NoSuchTable(name.to_string()))
    }

    /// Delete a table; returns whether it existed.
    pub fn drop_table(&self, name: &str) -> bool {
        self.tables.lock().unwrap().remove(name).is_some()
    }

    /// Names of all tables.
    pub fn table_names(&self) -> Vec<String> {
        self.tables.lock().unwrap().keys().cloned().collect()
    }

    /// Create the D4M adjacency pair `name` / `name_T` and ingest an
    /// associative array into both orientations (the standard D4M
    /// database layout: transpose table makes column access a sorted
    /// row scan).
    pub fn ingest_assoc(&self, name: &str, a: &Assoc) -> (Arc<Table>, Arc<Table>) {
        let t = self.create_table(name);
        let tt = self.create_table(&format!("{name}_T"));
        let mut w = BatchWriter::new(Arc::clone(&t), WriterConfig::default());
        let mut wt = BatchWriter::new(Arc::clone(&tt), WriterConfig::default());
        for (r, c, v) in a.iter() {
            // One allocation per key/value; both orientations share it.
            let rs = SharedStr::from(r.to_string());
            let cs = SharedStr::from(c.to_string());
            let vs = SharedStr::from(v.to_string());
            w.put(Triple::new(rs.clone(), cs.clone(), vs.clone()));
            wt.put(Triple::new(cs, rs, vs));
        }
        w.flush().expect("ingest flush");
        wt.flush().expect("ingest flush (transpose)");
        (t, tt)
    }

    /// Read a whole table back as an associative array (values parsed
    /// numerically when all parse; collisions keep the latest write).
    pub fn read_assoc(&self, name: &str) -> Result<Assoc, StoreError> {
        let t = self.table(name)?;
        Ok(t.scan_to_assoc(ScanRange::all()))
    }
}

impl TableStore {
    /// Persist every table as TSV triples under `dir` (one
    /// `<table>.tsv` per table) — the snapshot/backup path. Returns the
    /// number of tables written.
    pub fn snapshot(&self, dir: impl AsRef<std::path::Path>) -> std::io::Result<usize> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let tables: Vec<Arc<Table>> =
            self.tables.lock().unwrap().values().cloned().collect();
        for t in &tables {
            use std::io::Write;
            let path = dir.join(format!("{}.tsv", t.name()));
            let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
            for tr in t.scan(ScanRange::all()) {
                writeln!(w, "{}\t{}\t{}", tr.row, tr.col, tr.val)?;
            }
            w.flush()?;
        }
        Ok(tables.len())
    }

    /// Restore tables from a [`TableStore::snapshot`] directory
    /// (creates one table per `*.tsv` file). Returns the table names
    /// restored. Directories and files without a `.tsv` extension are
    /// skipped; a `.tsv` file whose stem is not UTF-8 is an
    /// `InvalidData` error (it cannot name a table) rather than a
    /// silently mangled lossy name.
    pub fn restore(&self, dir: impl AsRef<std::path::Path>) -> std::io::Result<Vec<String>> {
        let mut names = Vec::new();
        for entry in std::fs::read_dir(dir)? {
            let entry = entry?;
            if !entry.file_type()?.is_file() {
                continue;
            }
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some("tsv") {
                continue;
            }
            let name = match path.file_stem().and_then(|s| s.to_str()) {
                Some(stem) => stem.to_string(),
                None => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        format!("non-UTF-8 snapshot file name: {}", path.display()),
                    ))
                }
            };
            let table = self.create_table(&name);
            let mut w = BatchWriter::new(Arc::clone(&table), WriterConfig::default());
            for (lineno, line) in std::fs::read_to_string(&path)?.lines().enumerate() {
                if line.is_empty() {
                    continue;
                }
                let mut parts = line.splitn(3, '\t');
                match (parts.next(), parts.next(), parts.next()) {
                    (Some(r), Some(c), Some(v)) => w.put(Triple::new(r, c, v)),
                    _ => {
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::InvalidData,
                            format!("{}:{}: bad triple", path.display(), lineno + 1),
                        ))
                    }
                }
            }
            w.flush().map_err(std::io::Error::other)?;
            names.push(name);
        }
        names.sort();
        Ok(names)
    }
}

/// Convert scanned triples into an [`Assoc`] (numeric when every value
/// parses as a number; `Last` aggregation — later writes win, matching
/// store overwrite semantics).
pub fn triples_to_assoc(triples: &[Triple]) -> Assoc {
    triples_to_assoc_par(triples, crate::util::Parallelism::current())
}

/// [`triples_to_assoc`] with an explicit thread configuration for the
/// constructor rebuild. Triples are pointer clones, so this is the same
/// dictionary-encoded path as [`stream_to_assoc`].
pub fn triples_to_assoc_par(triples: &[Triple], par: crate::util::Parallelism) -> Assoc {
    stream_to_assoc(triples.iter().cloned(), par)
}

/// Build an [`Assoc`] from a triple stream (a [`TableStream`] or any
/// other [`ScanIter`] consumer) without materializing a `Vec<Triple>` —
/// and without touching key bytes per cell: every row/column key is
/// interned to a dense `u32` id through a [`StrDict`] (a pointer clone
/// of the shared cell bytes on first sight, a hash probe after), the
/// *distinct* keys are sorted once at the end, and the encoded maps
/// land in [`Assoc::try_from_encoded`]. Scan streams arrive row-sorted,
/// so the row dictionary usually finalizes without sorting at all.
/// Same semantics as [`triples_to_assoc`].
pub fn stream_to_assoc(
    triples: impl Iterator<Item = Triple>,
    par: crate::util::Parallelism,
) -> Assoc {
    let mut rd = StrDict::new();
    let mut cd = StrDict::new();
    let mut rid: Vec<u32> = Vec::new();
    let mut cid: Vec<u32> = Vec::new();
    let mut raw: Vec<SharedStr> = Vec::new();
    for t in triples {
        rid.push(rd.intern(&t.row));
        cid.push(cd.intern(&t.col));
        raw.push(t.val);
    }
    let (row_keys, rrank) = rd.into_sorted();
    let (col_keys, crank) = cd.into_sorted();
    // Key bytes are copied exactly once per distinct key, here.
    let row_keys: Vec<Key> = row_keys.iter().map(|s| Key::str(s.as_str())).collect();
    let col_keys: Vec<Key> = col_keys.iter().map(|s| Key::str(s.as_str())).collect();
    let rmap: Vec<usize> = rid.iter().map(|&id| rrank[id as usize] as usize).collect();
    let cmap: Vec<usize> = cid.iter().map(|&id| crank[id as usize] as usize).collect();
    let numeric: Option<Vec<f64>> = raw.iter().map(|v| v.parse::<f64>().ok()).collect();
    let vals = match numeric {
        Some(nums) => ValsInput::Num(nums),
        None => ValsInput::Str(raw.iter().map(|v| v.to_string()).collect()),
    };
    Assoc::try_from_encoded(row_keys, col_keys, rmap, cmap, vals, Aggregator::Last, par)
        .expect("scan triples are consistent")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assoc::Assoc;

    #[test]
    fn create_and_lookup_tables() {
        let store = TableStore::with_defaults();
        store.create_table("edges");
        assert!(store.table("edges").is_ok());
        assert!(matches!(store.table("nope"), Err(StoreError::NoSuchTable(_))));
        assert_eq!(store.table_names(), vec!["edges".to_string()]);
        assert!(store.drop_table("edges"));
        assert!(!store.drop_table("edges"));
    }

    #[test]
    fn ingest_and_read_roundtrip() {
        let store = TableStore::with_defaults();
        let a = Assoc::from_triples(
            &["r1", "r1", "r2"],
            &["c1", "c2", "c1"],
            &["x", "y", "z"][..],
        );
        store.ingest_assoc("t", &a);
        let back = store.read_assoc("t").unwrap();
        assert_eq!(back, a);
        // Transpose table holds the transposed array.
        let back_t = store.read_assoc("t_T").unwrap();
        assert_eq!(back_t, a.transpose());
    }

    #[test]
    fn numeric_roundtrip() {
        let store = TableStore::with_defaults();
        let a = Assoc::from_triples(&["r1", "r2"], &["c", "c"], vec![1.5, 2.0]);
        store.ingest_assoc("n", &a);
        let back = store.read_assoc("n").unwrap();
        assert!(back.is_numeric());
        assert_eq!(back, a);
    }

    #[test]
    fn triples_to_assoc_last_wins() {
        let ts = vec![
            Triple::new("r", "c", "1"),
            Triple::new("r", "c", "2"), // overwrite
        ];
        let a = triples_to_assoc(&ts);
        assert_eq!(a.get_num("r", "c"), Some(2.0));
    }

    #[test]
    fn triple_weight() {
        assert_eq!(Triple::new("ab", "c", "defg").weight(), 7);
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let store = TableStore::with_defaults();
        let a = Assoc::from_triples(&["r1", "r2"], &["c1", "c2"], &["x", "y"][..]);
        store.ingest_assoc("edges", &a);
        let dir = std::env::temp_dir().join("d4m-snapshot-test");
        let _ = std::fs::remove_dir_all(&dir);
        assert_eq!(store.snapshot(&dir).unwrap(), 2); // edges + edges_T

        let fresh = TableStore::with_defaults();
        let names = fresh.restore(&dir).unwrap();
        assert_eq!(names, vec!["edges".to_string(), "edges_T".to_string()]);
        assert_eq!(fresh.read_assoc("edges").unwrap(), a);
        assert_eq!(fresh.read_assoc("edges_T").unwrap(), a.transpose());
    }

    #[test]
    fn restore_skips_stray_entries() {
        // Regression: restore used to panic (file_stem().unwrap()) on
        // odd directory entries and lossy-coerce non-UTF-8 names.
        let store = TableStore::with_defaults();
        let a = Assoc::from_triples(&["r"], &["c"], &["v"][..]);
        store.ingest_assoc("edges", &a);
        let dir = std::env::temp_dir().join("d4m-restore-stray-test");
        let _ = std::fs::remove_dir_all(&dir);
        store.snapshot(&dir).unwrap();
        // Stray non-snapshot entries that must be skipped, not tripped
        // over: a lockfile, a dotfile, and a subdirectory named like a
        // snapshot.
        std::fs::write(dir.join("LOCK"), b"pid 1234").unwrap();
        std::fs::write(dir.join(".hidden"), b"").unwrap();
        std::fs::create_dir(dir.join("not-a-table.tsv")).unwrap();
        let fresh = TableStore::with_defaults();
        let names = fresh.restore(&dir).unwrap();
        assert_eq!(names, vec!["edges".to_string(), "edges_T".to_string()]);
        assert_eq!(fresh.read_assoc("edges").unwrap(), a);
        // A non-UTF-8 *.tsv name is a typed error, not a mangled table.
        #[cfg(unix)]
        {
            use std::os::unix::ffi::OsStrExt;
            let bad = dir.join(std::ffi::OsStr::from_bytes(b"bad\xff.tsv"));
            std::fs::write(&bad, b"r\tc\tv\n").unwrap();
            let err = TableStore::with_defaults().restore(&dir).unwrap_err();
            assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn durable_store_recovers_tables() {
        let dir = std::env::temp_dir().join("d4m-durable-store-test");
        let _ = std::fs::remove_dir_all(&dir);
        let a = Assoc::from_triples(&["r1", "r2"], &["c1", "c2"], vec![1.0, 2.0]);
        {
            let store =
                TableStore::durable(&dir, TableConfig::default(), FsyncPolicy::Never).unwrap();
            store.ingest_assoc("edges", &a);
            // One table checkpointed to runs, the other left WAL-only:
            // recovery must handle both layouts.
            store.table("edges").unwrap().minor_compact().unwrap();
            store.table("edges").unwrap().sync().unwrap();
            store.table("edges_T").unwrap().sync().unwrap();
        }
        let back = TableStore::recover(&dir).unwrap();
        let mut names = back.table_names();
        names.sort();
        assert_eq!(names, vec!["edges".to_string(), "edges_T".to_string()]);
        assert_eq!(back.read_assoc("edges").unwrap(), a);
        assert_eq!(back.read_assoc("edges_T").unwrap(), a.transpose());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
