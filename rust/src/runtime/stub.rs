//! Offline stand-in for the PJRT runtime (compiled when the `accel`
//! feature is off).
//!
//! The real runtime (`src/runtime/mod.rs` + `tile.rs`) drives
//! AOT-compiled Pallas kernels through the external `xla` crate, which
//! is unavailable in the offline build image. This stub keeps the
//! public API surface — [`Runtime`], [`Artifact`], [`AccelStats`],
//! [`accel_matmul`], [`should_accelerate`] — compiling with zero
//! dependencies: loading always fails with a clear message, and every
//! caller in the tree (CLI `info`, the accel example/bench, the
//! integration test) already treats "runtime unavailable" as a skip.
//! Build with `--features accel` (after vendoring `xla` and `anyhow`)
//! to get the real implementation.

use crate::assoc::Assoc;
use crate::semiring::Semiring;
use crate::sparse::DenseBlock;
use std::fmt;
use std::path::Path;

/// Error type standing in for `anyhow::Error` in the stub build.
#[derive(Debug, Clone)]
pub struct RuntimeUnavailable(String);

impl fmt::Display for RuntimeUnavailable {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for RuntimeUnavailable {}

/// Stub result alias (the real module uses `anyhow::Result`).
pub type Result<T> = std::result::Result<T, RuntimeUnavailable>;

/// One AOT artifact as described by `manifest.tsv` (mirror of the real
/// type; never instantiated by the stub).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Artifact {
    /// Variant name, e.g. `matmul_plus_times_128`.
    pub name: String,
    /// `matmul` (2 inputs) or `accum` (3 inputs, fused ⊕ C).
    pub kind: String,
    /// Semiring name (matches [`crate::semiring::Semiring::name`]).
    pub semiring: String,
    /// Square tile extent S (operands are S×S).
    pub size: usize,
    /// Pallas block parameter used at lowering (perf metadata).
    pub block: usize,
    /// Number of kernel inputs.
    pub num_inputs: usize,
    /// HLO text file (relative to the artifact dir).
    pub file: String,
}

/// Instrumentation from one accelerated matmul (mirror of the real
/// type so callers compile; the stub never produces one).
#[derive(Debug, Clone, Default)]
pub struct AccelStats {
    /// Tile size used.
    pub tile: usize,
    /// PJRT kernel invocations.
    pub kernel_calls: usize,
    /// Tile steps skipped because an operand tile was all-zero.
    pub skipped_tiles: usize,
}

/// Density heuristic shared with the real runtime: the dense path wins
/// when operands are dense enough that `O(S³)` regular dense work beats
/// sparse SpGEMM's irregular access.
pub fn should_accelerate(a: &Assoc, b: &Assoc, threshold: f64) -> bool {
    DenseBlock::density(a.adj()) >= threshold && DenseBlock::density(b.adj()) >= threshold
}

/// Stub runtime: construction always fails.
pub struct Runtime {
    never: std::convert::Infallible,
}

impl Runtime {
    fn unavailable() -> RuntimeUnavailable {
        RuntimeUnavailable(
            "PJRT runtime not compiled in: this build has no `xla` dependency; \
             rebuild with `--features accel` after vendoring the accel crates"
                .to_string(),
        )
    }

    /// Always fails in the stub build (see module docs).
    pub fn load(_dir: impl AsRef<Path>) -> Result<Runtime> {
        Err(Self::unavailable())
    }

    /// Always fails in the stub build (see module docs).
    pub fn load_default() -> Result<Runtime> {
        Err(Self::unavailable())
    }

    /// All artifacts (empty iterator; the stub cannot be constructed).
    pub fn artifacts(&self) -> std::iter::Empty<&Artifact> {
        std::iter::empty()
    }

    /// Artifact lookup by name.
    pub fn artifact(&self, _name: &str) -> Option<&Artifact> {
        match self.never {}
    }

    /// Best matmul artifact for a semiring.
    pub fn best_matmul(&self, _semiring: &str, _max_size: usize) -> Option<&Artifact> {
        match self.never {}
    }

    /// Run a 2-input tile kernel.
    pub fn run_matmul(&self, _name: &str, _a: &[f32], _b: &[f32]) -> Result<Vec<f32>> {
        match self.never {}
    }

    /// Run a 3-input fused-accumulate tile kernel.
    pub fn run_accum(&self, _name: &str, _a: &[f32], _b: &[f32], _c: &[f32]) -> Result<Vec<f32>> {
        match self.never {}
    }
}

/// Stub accelerated matmul — unreachable, since no [`Runtime`] can
/// exist in the stub build; the signature keeps callers compiling.
pub fn accel_matmul(
    rt: &Runtime,
    _a: &Assoc,
    _b: &Assoc,
    _s: &dyn Semiring,
) -> Result<(Assoc, AccelStats)> {
    match rt.never {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_load_fails_with_message() {
        let err = Runtime::load_default().unwrap_err();
        assert!(err.to_string().contains("accel"));
        assert!(Runtime::load("anywhere").is_err());
    }

    #[test]
    fn density_heuristic_still_works() {
        let dense = Assoc::from_triples(&["a", "a", "b", "b"], &["x", "y", "x", "y"], 1.0);
        assert!(should_accelerate(&dense, &dense, 0.5));
        assert!(!should_accelerate(&dense, &dense, 1.5));
    }
}
