//! PJRT runtime: load and execute the AOT-compiled Pallas kernels.
//!
//! `make artifacts` (build-time Python) lowers the L1/L2 semiring
//! matmul variants to HLO text in `artifacts/`; this module is the
//! request-path side: a [`Runtime`] wraps a PJRT CPU client
//! (`xla` crate), discovers artifacts from `manifest.tsv`, compiles
//! each on first use, and serves dense-block execution to the
//! accelerated `@` path ([`accel_matmul`]). Python never runs here.
//!
//! The accelerated path mirrors `Assoc::matmul_with` exactly — contract
//! over `A.col ∩ B.row` — but routes the contraction through fixed-size
//! dense tiles: scatter CSR blocks into `S×S` f32 tiles (padded with
//! the semiring zero, which the kernel's ⊕-accumulation ignores), run
//! the compiled kernel per `(i, j, k)` tile step, ⊕-combine partial
//! tiles on the host, and gather the result back to sparse. Dispatch is
//! by operand density ([`should_accelerate`]).

mod tile;

pub use tile::{accel_matmul, should_accelerate, AccelStats};

use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// One AOT artifact as described by `manifest.tsv`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Artifact {
    /// Variant name, e.g. `matmul_plus_times_128`.
    pub name: String,
    /// `matmul` (2 inputs) or `accum` (3 inputs, fused ⊕ C).
    pub kind: String,
    /// Semiring name (matches [`crate::semiring::Semiring::name`]).
    pub semiring: String,
    /// Square tile extent S (operands are S×S).
    pub size: usize,
    /// Pallas block parameter used at lowering (perf metadata).
    pub block: usize,
    /// Number of kernel inputs.
    pub num_inputs: usize,
    /// HLO text file (relative to the artifact dir).
    pub file: String,
}

/// A loaded PJRT runtime with lazily-compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    artifacts: BTreeMap<String, Artifact>,
    compiled: Mutex<BTreeMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Load the manifest from an artifact directory and start a PJRT
    /// CPU client. Fails if the directory or manifest is missing (run
    /// `make artifacts`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {} (run `make artifacts`)", manifest.display()))?;
        let mut artifacts = BTreeMap::new();
        for (i, line) in text.lines().enumerate() {
            if line.is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split('\t').collect();
            if f.len() != 7 {
                return Err(anyhow!("manifest.tsv line {}: expected 7 fields", i + 1));
            }
            let a = Artifact {
                name: f[0].to_string(),
                kind: f[1].to_string(),
                semiring: f[2].to_string(),
                size: f[3].parse().context("size")?,
                block: f[4].parse().context("block")?,
                num_inputs: f[5].parse().context("num_inputs")?,
                file: f[6].to_string(),
            };
            artifacts.insert(a.name.clone(), a);
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime { client, dir, artifacts, compiled: Mutex::new(BTreeMap::new()) })
    }

    /// Load from the conventional `artifacts/` directory next to the
    /// working directory.
    pub fn load_default() -> Result<Runtime> {
        Self::load("artifacts")
    }

    /// The manifest.
    pub fn artifacts(&self) -> impl Iterator<Item = &Artifact> {
        self.artifacts.values()
    }

    /// Look up an artifact by name.
    pub fn artifact(&self, name: &str) -> Option<&Artifact> {
        self.artifacts.get(name)
    }

    /// Find the matmul artifact for a semiring with the largest tile
    /// size ≤ `max_size` (the tile planner's query).
    pub fn best_matmul(&self, semiring: &str, max_size: usize) -> Option<&Artifact> {
        self.artifacts
            .values()
            .filter(|a| a.kind == "matmul" && a.semiring == semiring && a.size <= max_size)
            .max_by_key(|a| a.size)
    }

    /// Compile (or fetch the cached) executable for an artifact.
    fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.compiled.lock().unwrap().get(name) {
            return Ok(std::sync::Arc::clone(exe));
        }
        let art = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("no artifact named {name} in manifest"))?;
        let path = self.dir.join(&art.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let exe = std::sync::Arc::new(exe);
        self.compiled.lock().unwrap().insert(name.to_string(), std::sync::Arc::clone(&exe));
        Ok(exe)
    }

    /// Execute a 2-input S×S matmul artifact on raw row-major f32 tiles.
    pub fn run_matmul(&self, name: &str, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let art =
            self.artifact(name).ok_or_else(|| anyhow!("no artifact {name}"))?.clone();
        anyhow::ensure!(art.num_inputs == 2, "{name} is not a 2-input matmul artifact");
        let s = art.size;
        anyhow::ensure!(a.len() == s * s && b.len() == s * s, "tile size mismatch");
        let exe = self.executable(name)?;
        let la = literal_2d(a, s)?;
        let lb = literal_2d(b, s)?;
        execute_tuple1(&exe, &[la, lb], s)
    }

    /// Execute a 3-input fused accum artifact: `(A ⊗.⊕ B) ⊕ C`.
    pub fn run_accum(&self, name: &str, a: &[f32], b: &[f32], c: &[f32]) -> Result<Vec<f32>> {
        let art =
            self.artifact(name).ok_or_else(|| anyhow!("no artifact {name}"))?.clone();
        anyhow::ensure!(art.num_inputs == 3, "{name} is not a 3-input accum artifact");
        let s = art.size;
        anyhow::ensure!(
            a.len() == s * s && b.len() == s * s && c.len() == s * s,
            "tile size mismatch"
        );
        let exe = self.executable(name)?;
        execute_tuple1(&exe, &[literal_2d(a, s)?, literal_2d(b, s)?, literal_2d(c, s)?], s)
    }
}

fn literal_2d(data: &[f32], s: usize) -> Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(&[s as i64, s as i64])
        .map_err(|e| anyhow!("reshape literal: {e:?}"))
}

fn execute_tuple1(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[xla::Literal],
    s: usize,
) -> Result<Vec<f32>> {
    let result = exe
        .execute::<xla::Literal>(inputs)
        .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("to_literal: {e:?}"))?;
    // Lowered with return_tuple=True: unwrap the 1-tuple.
    let out = result.to_tuple1().map_err(|e| anyhow!("tuple1: {e:?}"))?;
    let v = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
    anyhow::ensure!(v.len() == s * s, "unexpected output size {}", v.len());
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tests are skipped (not failed) when artifacts are absent, so
    /// `cargo test` works before `make artifacts`; the Makefile's test
    /// target always builds artifacts first.
    fn runtime() -> Option<Runtime> {
        if !Path::new("artifacts/manifest.tsv").exists() {
            eprintln!("skipping runtime test: artifacts/ missing (run `make artifacts`)");
            return None;
        }
        Some(Runtime::load("artifacts").expect("load runtime"))
    }

    #[test]
    fn manifest_loads_expected_variants() {
        let Some(rt) = runtime() else { return };
        let names: Vec<&str> = rt.artifacts().map(|a| a.name.as_str()).collect();
        assert!(names.contains(&"matmul_plus_times_128"));
        assert!(names.contains(&"matmul_min_plus_128"));
        let art = rt.artifact("matmul_plus_times_128").unwrap();
        assert_eq!((art.size, art.num_inputs), (128, 2));
    }

    #[test]
    fn best_matmul_selection() {
        let Some(rt) = runtime() else { return };
        assert_eq!(rt.best_matmul("plus_times", 512).unwrap().size, 256);
        assert_eq!(rt.best_matmul("plus_times", 128).unwrap().size, 128);
        assert!(rt.best_matmul("plus_times", 64).is_none());
        assert!(rt.best_matmul("nope", 512).is_none());
    }

    #[test]
    fn plus_times_tile_matches_host() {
        let Some(rt) = runtime() else { return };
        let s = 128usize;
        // Identity x J: result is J.
        let mut ident = vec![0f32; s * s];
        for i in 0..s {
            ident[i * s + i] = 1.0;
        }
        let j: Vec<f32> = (0..s * s).map(|i| (i % 7) as f32).collect();
        let out = rt.run_matmul("matmul_plus_times_128", &ident, &j).unwrap();
        assert_eq!(out, j);
    }

    #[test]
    fn min_plus_tile_known_values() {
        let Some(rt) = runtime() else { return };
        let s = 128usize;
        let inf = f32::INFINITY;
        // a[0,0]=2, a[0,1]=5; b[0,0]=10, b[1,0]=1 → c[0,0]=min(12, 6)=6.
        let mut a = vec![inf; s * s];
        let mut b = vec![inf; s * s];
        a[0] = 2.0;
        a[1] = 5.0;
        b[0] = 10.0;
        b[s] = 1.0;
        let out = rt.run_matmul("matmul_min_plus_128", &a, &b).unwrap();
        assert_eq!(out[0], 6.0);
        assert_eq!(out[1], inf); // untouched cells stay at the zero
    }

    #[test]
    fn accum_fuses_host_combine() {
        let Some(rt) = runtime() else { return };
        let s = 128usize;
        let a = vec![0f32; s * s]; // zero operand ⇒ A@B = 0
        let b = vec![0f32; s * s];
        let c: Vec<f32> = (0..s * s).map(|i| (i % 13) as f32).collect();
        let out = rt.run_accum("accum_plus_times_128", &a, &b, &c).unwrap();
        assert_eq!(out, c); // 0 + C = C
    }

    #[test]
    fn wrong_tile_size_rejected() {
        let Some(rt) = runtime() else { return };
        let bad = vec![0f32; 4];
        assert!(rt.run_matmul("matmul_plus_times_128", &bad, &bad).is_err());
        assert!(rt.run_matmul("no_such_artifact", &bad, &bad).is_err());
    }
}
