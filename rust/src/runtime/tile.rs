//! The tiled dense-block acceleration path for `A @ B`.
//!
//! Mirrors `Assoc::matmul_with` (contract over `A.col ∩ B.row`,
//! condense after), but runs the numeric contraction on the PJRT tile
//! kernels instead of host SpGEMM: scatter sparse blocks into `S×S`
//! dense f32 tiles padded with the semiring zero, contract tiles on
//! the compiled kernel, ⊕-combine partial tiles on the host, gather
//! the nonzero results back to sparse.

use super::Runtime;
use crate::assoc::{Assoc, Values};
use crate::semiring::Semiring;
use crate::sorted::sorted_intersect;
use crate::sparse::{CooMatrix, CsrMatrix, DenseBlock};
use anyhow::Result;

/// Instrumentation from one accelerated matmul.
#[derive(Debug, Clone, Default)]
pub struct AccelStats {
    /// Tile size used.
    pub tile: usize,
    /// PJRT kernel invocations.
    pub kernel_calls: usize,
    /// Tile steps skipped because an operand tile was all-zero.
    pub skipped_tiles: usize,
}

/// Density heuristic: the dense path wins when operands are dense
/// enough that `O(S³)` regular dense work beats sparse SpGEMM's
/// irregular access. The crossover (measured by the `fig6b_accel`
/// bench) sits at a few percent density.
pub fn should_accelerate(a: &Assoc, b: &Assoc, threshold: f64) -> bool {
    DenseBlock::density(a.adj()) >= threshold && DenseBlock::density(b.adj()) >= threshold
}

/// `A ⊗.⊕ B` on the PJRT tile kernels. Semantically identical to
/// [`Assoc::matmul_with`] (string operands are `logical()`-ed first,
/// result condensed); returns the result plus execution stats.
///
/// Padding tiles with the semiring zero is inert: zero annihilates ⊗
/// and is the identity of ⊕, so padded lanes never contribute.
pub fn accel_matmul(
    rt: &Runtime,
    a: &Assoc,
    b: &Assoc,
    s: &dyn Semiring,
) -> Result<(Assoc, AccelStats)> {
    let art = rt
        .best_matmul(s.name(), 256)
        .ok_or_else(|| anyhow::anyhow!("no matmul artifact for semiring {}", s.name()))?;
    let tile = art.size;
    let name = art.name.clone();
    let zero = s.zero();
    let zero32 = zero as f32;

    let a_log;
    let a = if a.is_string() {
        a_log = a.logical();
        &a_log
    } else {
        a
    };
    let b_log;
    let b = if b.is_string() {
        b_log = b.logical();
        &b_log
    } else {
        b
    };

    // Contract over A.col ∩ B.row (paper §II.C.3), as the sparse path.
    let kx = sorted_intersect(a.col_keys(), b.row_keys());
    let mut stats = AccelStats { tile, ..Default::default() };
    if kx.keys.is_empty() {
        return Ok((Assoc::empty(), stats));
    }
    let (m, _) = a.shape();
    let n = b.shape().1;
    let kk = kx.keys.len();
    let all_rows: Vec<usize> = (0..m).collect();
    let all_cols: Vec<usize> = (0..n).collect();
    let ga = a.adj().gather(&all_rows, &kx.map_left); // m × kk
    let gb = b.adj().gather(&kx.map_right, &all_cols); // kk × n

    let tiles = |extent: usize| extent.div_ceil(tile);
    let (mt, kt, nt) = (tiles(m), tiles(kk), tiles(n));

    // Pre-extract operand tiles as CSR blocks (so all-zero steps are
    // skippable without scattering).
    let block_rows = |lo: usize, extent: usize| -> Vec<usize> {
        (lo..(lo + tile).min(extent)).collect()
    };
    let mut a_tiles: Vec<Vec<CsrMatrix>> = Vec::with_capacity(mt);
    for bi in 0..mt {
        let rows = block_rows(bi * tile, m);
        let mut strip = Vec::with_capacity(kt);
        for bk in 0..kt {
            let cols = block_rows(bk * tile, kk);
            strip.push(ga.gather(&rows, &cols));
        }
        a_tiles.push(strip);
    }
    let mut b_tiles: Vec<Vec<CsrMatrix>> = Vec::with_capacity(kt);
    for bk in 0..kt {
        let rows = block_rows(bk * tile, kk);
        let mut strip = Vec::with_capacity(nt);
        for bj in 0..nt {
            let cols = block_rows(bj * tile, n);
            strip.push(gb.gather(&rows, &cols));
        }
        b_tiles.push(strip);
    }

    // Contract tile-by-tile; accumulate result triples globally.
    let mut rows_out: Vec<usize> = Vec::new();
    let mut cols_out: Vec<usize> = Vec::new();
    let mut vals_out: Vec<f64> = Vec::new();
    for bi in 0..mt {
        for bj in 0..nt {
            let mut acc: Option<Vec<f32>> = None;
            for bk in 0..kt {
                let at = &a_tiles[bi][bk];
                let bt = &b_tiles[bk][bj];
                if at.nnz() == 0 || bt.nnz() == 0 {
                    stats.skipped_tiles += 1;
                    continue;
                }
                let da = DenseBlock::scatter_from(at, tile, tile, zero32);
                let db = DenseBlock::scatter_from(bt, tile, tile, zero32);
                let partial = rt.run_matmul(&name, da.data(), db.data())?;
                stats.kernel_calls += 1;
                match &mut acc {
                    None => acc = Some(partial),
                    Some(acc) => {
                        for (x, p) in acc.iter_mut().zip(&partial) {
                            *x = s.add(*x as f64, *p as f64) as f32;
                        }
                    }
                }
            }
            if let Some(acc) = acc {
                // Gather nonzeros of the valid region into global triples.
                let bh = (m - bi * tile).min(tile);
                let bw = (n - bj * tile).min(tile);
                for r in 0..bh {
                    for c in 0..bw {
                        let v = acc[r * tile + c] as f64;
                        if v != zero {
                            rows_out.push(bi * tile + r);
                            cols_out.push(bj * tile + c);
                            vals_out.push(v);
                        }
                    }
                }
            }
        }
    }

    let adj =
        CooMatrix::from_triples_aggregate(m, n, &rows_out, &cols_out, &vals_out, zero, |x, _| x)
            .expect("tile triples are unique and in bounds")
            .to_csr();
    let out = Assoc {
        row: a.row_keys().to_vec(),
        col: b.col_keys().to_vec(),
        val: Values::Numeric,
        adj,
    }
    .condensed();
    Ok((out, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::semiring::{MinPlus, PlusTimes};
    use crate::util::SplitMix64;
    use std::path::Path;

    fn runtime() -> Option<Runtime> {
        if !Path::new("artifacts/manifest.tsv").exists() {
            eprintln!("skipping accel test: artifacts/ missing (run `make artifacts`)");
            return None;
        }
        Some(Runtime::load("artifacts").expect("load runtime"))
    }

    fn random_assoc(seed: u64, keys: u64, triples: usize) -> Assoc {
        let mut r = SplitMix64::new(seed);
        let rows: Vec<String> = (0..triples).map(|_| format!("k{:04}", r.below(keys))).collect();
        let cols: Vec<String> = (0..triples).map(|_| format!("k{:04}", r.below(keys))).collect();
        let vals: Vec<f64> = (0..triples).map(|_| r.range_i64(1, 9) as f64).collect();
        Assoc::from_triples(&rows, &cols, crate::assoc::ValsInput::Num(vals))
    }

    #[test]
    fn accel_matches_sparse_plus_times() {
        let Some(rt) = runtime() else { return };
        // ~200 keys → spans two 128-tiles in every dimension.
        let a = random_assoc(1, 200, 3000);
        let b = random_assoc(2, 200, 3000);
        let want = a.matmul_with(&b, &PlusTimes);
        let (got, stats) = accel_matmul(&rt, &a, &b, &PlusTimes).unwrap();
        assert_eq!(got, want);
        assert!(stats.kernel_calls > 0);
        assert_eq!(stats.tile, 256); // largest plus-times artifact
    }

    #[test]
    fn accel_matches_sparse_min_plus() {
        let Some(rt) = runtime() else { return };
        let a = random_assoc(3, 100, 800);
        let b = random_assoc(4, 100, 800);
        let want = a.matmul_with(&b, &MinPlus);
        let (got, stats) = accel_matmul(&rt, &a, &b, &MinPlus).unwrap();
        assert_eq!(got, want);
        assert_eq!(stats.tile, 128);
    }

    #[test]
    fn accel_disjoint_contraction_is_empty() {
        let Some(rt) = runtime() else { return };
        let a = Assoc::from_triples(&["r"], &["x"], 1.0);
        let b = Assoc::from_triples(&["y"], &["c"], 1.0);
        let (got, stats) = accel_matmul(&rt, &a, &b, &PlusTimes).unwrap();
        assert!(got.is_empty());
        assert_eq!(stats.kernel_calls, 0);
    }

    #[test]
    fn accel_string_operands_logicalized() {
        let Some(rt) = runtime() else { return };
        let a = crate::assoc::tests::music();
        let want = a.sqin();
        let at = a.transpose();
        let (got, _) = accel_matmul(&rt, &at, &a, &PlusTimes).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn density_dispatch() {
        let dense = Assoc::from_triples(&["a", "a", "b", "b"], &["x", "y", "x", "y"], 1.0);
        let sparse = random_assoc(9, 1000, 50);
        assert!(should_accelerate(&dense, &dense, 0.5));
        assert!(!should_accelerate(&sparse, &sparse, 0.5));
    }
}
