//! Ordered-map triple-store baseline engine.
//!
//! A `BTreeMap<(row, col), value>` — the design a sorted key/value
//! store (or a naive Accumulo-style client) implies: ordered iteration
//! is free, so union/intersection ops are sorted merges like D4M's, but
//! without the dense-index sparse kernels — every step pays tree-node
//! and per-key string-comparison costs. This is the "ordered but not
//! array-packed" comparison curve.

use super::Engine;
use std::collections::BTreeMap;

/// Array representation: a sorted triple map.
#[derive(Debug, Clone, Default)]
pub struct BTreeArray {
    /// Numeric cells in row-major key order.
    pub cells: BTreeMap<(String, String), f64>,
    /// String cells (string constructor bench only).
    pub str_cells: BTreeMap<(String, String), String>,
}

/// The ordered-map engine.
#[derive(Debug, Clone, Copy, Default)]
pub struct BTreeEngine;

impl Engine for BTreeEngine {
    type Array = BTreeArray;

    fn name(&self) -> &'static str {
        "btree"
    }

    fn construct_numeric(&self, rows: &[String], cols: &[String], vals: &[f64]) -> BTreeArray {
        let mut cells: BTreeMap<(String, String), f64> = BTreeMap::new();
        for i in 0..rows.len() {
            cells
                .entry((rows[i].clone(), cols[i].clone()))
                .and_modify(|v| *v = v.min(vals[i]))
                .or_insert(vals[i]);
        }
        cells.retain(|_, v| *v != 0.0);
        BTreeArray { cells, str_cells: BTreeMap::new() }
    }

    fn construct_string(&self, rows: &[String], cols: &[String], vals: &[String]) -> BTreeArray {
        let mut str_cells: BTreeMap<(String, String), String> = BTreeMap::new();
        for i in 0..rows.len() {
            let key = (rows[i].clone(), cols[i].clone());
            match str_cells.get_mut(&key) {
                Some(v) => {
                    if vals[i] < *v {
                        *v = vals[i].clone();
                    }
                }
                None => {
                    str_cells.insert(key, vals[i].clone());
                }
            }
        }
        str_cells.retain(|_, v| !v.is_empty());
        BTreeArray { cells: BTreeMap::new(), str_cells }
    }

    fn add(&self, a: &BTreeArray, b: &BTreeArray) -> BTreeArray {
        // Sorted merge of the two ordered maps.
        let mut cells = BTreeMap::new();
        let mut ia = a.cells.iter().peekable();
        let mut ib = b.cells.iter().peekable();
        loop {
            match (ia.peek(), ib.peek()) {
                (Some((ka, va)), Some((kb, vb))) => match ka.cmp(kb) {
                    std::cmp::Ordering::Less => {
                        cells.insert((*ka).clone(), **va);
                        ia.next();
                    }
                    std::cmp::Ordering::Greater => {
                        cells.insert((*kb).clone(), **vb);
                        ib.next();
                    }
                    std::cmp::Ordering::Equal => {
                        let s = **va + **vb;
                        if s != 0.0 {
                            cells.insert((*ka).clone(), s);
                        }
                        ia.next();
                        ib.next();
                    }
                },
                (Some((ka, va)), None) => {
                    cells.insert((*ka).clone(), **va);
                    ia.next();
                }
                (None, Some((kb, vb))) => {
                    cells.insert((*kb).clone(), **vb);
                    ib.next();
                }
                (None, None) => break,
            }
        }
        BTreeArray { cells, str_cells: BTreeMap::new() }
    }

    fn matmul(&self, a: &BTreeArray, b: &BTreeArray) -> BTreeArray {
        // Group B by row via ordered iteration (runs are contiguous),
        // then contract in A's row-major order.
        let mut b_by_row: BTreeMap<&str, Vec<(&str, f64)>> = BTreeMap::new();
        for ((r, c), v) in &b.cells {
            b_by_row.entry(r.as_str()).or_default().push((c.as_str(), *v));
        }
        let mut cells: BTreeMap<(String, String), f64> = BTreeMap::new();
        for ((r, k), av) in &a.cells {
            if let Some(brow) = b_by_row.get(k.as_str()) {
                for (c2, bv) in brow {
                    *cells.entry((r.clone(), c2.to_string())).or_insert(0.0) += av * bv;
                }
            }
        }
        cells.retain(|_, v| *v != 0.0);
        BTreeArray { cells, str_cells: BTreeMap::new() }
    }

    fn elemmul(&self, a: &BTreeArray, b: &BTreeArray) -> BTreeArray {
        // Sorted-merge intersection.
        let mut cells = BTreeMap::new();
        let mut ia = a.cells.iter().peekable();
        let mut ib = b.cells.iter().peekable();
        while let (Some((ka, va)), Some((kb, vb))) = (ia.peek(), ib.peek()) {
            match ka.cmp(kb) {
                std::cmp::Ordering::Less => {
                    ia.next();
                }
                std::cmp::Ordering::Greater => {
                    ib.next();
                }
                std::cmp::Ordering::Equal => {
                    let p = **va * **vb;
                    if p != 0.0 {
                        cells.insert((*ka).clone(), p);
                    }
                    ia.next();
                    ib.next();
                }
            }
        }
        BTreeArray { cells, str_cells: BTreeMap::new() }
    }

    fn nnz(&self, a: &BTreeArray) -> usize {
        a.cells.len() + a.str_cells.len()
    }

    fn checksum(&self, a: &BTreeArray) -> f64 {
        a.cells.values().sum::<f64>() + a.str_cells.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn sorted_merge_add() {
        let e = BTreeEngine;
        let a = e.construct_numeric(&s(&["a", "c"]), &s(&["1", "1"]), &[1.0, 2.0]);
        let b = e.construct_numeric(&s(&["b", "c"]), &s(&["1", "1"]), &[5.0, -2.0]);
        let sum = e.add(&a, &b);
        assert_eq!(sum.cells.len(), 2); // c/1 cancelled to 0 and dropped
        assert_eq!(sum.cells[&("a".into(), "1".into())], 1.0);
        assert_eq!(sum.cells[&("b".into(), "1".into())], 5.0);
    }

    #[test]
    fn intersection_elemmul() {
        let e = BTreeEngine;
        let a = e.construct_numeric(&s(&["a", "b"]), &s(&["1", "1"]), &[2.0, 3.0]);
        let b = e.construct_numeric(&s(&["b", "z"]), &s(&["1", "9"]), &[4.0, 1.0]);
        let p = e.elemmul(&a, &b);
        assert_eq!(p.cells.len(), 1);
        assert_eq!(p.cells[&("b".into(), "1".into())], 12.0);
    }

    #[test]
    fn matmul_matches_hand_result() {
        let e = BTreeEngine;
        let a = e.construct_numeric(&s(&["r", "r"]), &s(&["k1", "k2"]), &[2.0, 3.0]);
        let b = e.construct_numeric(&s(&["k1", "k2"]), &s(&["c", "c"]), &[10.0, 100.0]);
        let c = e.matmul(&a, &b);
        assert_eq!(c.cells[&("r".into(), "c".into())], 320.0);
    }

    #[test]
    fn string_construct() {
        let e = BTreeEngine;
        let a = e.construct_string(&s(&["r", "r", "q"]), &s(&["c", "c", "c"]), &s(&["b", "a", ""]));
        assert_eq!(a.str_cells.len(), 1);
        assert_eq!(a.str_cells[&("r".into(), "c".into())], "a");
    }
}
