//! Baseline associative-array engines — the comparison curves.
//!
//! The paper's Figures 3–7 compare three implementations of the same
//! API (D4M.py / D4M-MATLAB / D4M.jl). MATLAB and Julia cannot run
//! here, so the reproduction compares three *implementation strategies*
//! with identical semantics instead (DESIGN.md §3):
//!
//! * the sorted-array + sparse-matrix engine (`d4m-rs`, the paper's
//!   design — [`crate::assoc::Assoc`]),
//! * [`hashmap::HashMapEngine`] — a dict-of-triples engine (what a
//!   straightforward Python/Julia dictionary implementation does),
//! * [`btree::BTreeEngine`] — an ordered-map triple store (what a
//!   naive sorted key/value design does).
//!
//! [`Engine`] is the common interface the figure benches drive; the
//! cross-engine agreement tests in `rust/tests/` pin all three to the
//! same semantics so the benchmarks compare equal work.

pub mod btree;
pub mod hashmap;

use crate::assoc::{Assoc, ValsInput};

/// The five benched operations, implementable by every engine.
/// Construction takes pre-generated key/value lists (the paper's
/// workload files); `add`/`elemmul`/`matmul` operate on numeric arrays.
pub trait Engine {
    /// The engine's associative-array representation.
    type Array;

    /// Engine name for bench output.
    fn name(&self) -> &'static str;

    /// Figure 3: numeric-value constructor (default `min` aggregation).
    fn construct_numeric(&self, rows: &[String], cols: &[String], vals: &[f64]) -> Self::Array;

    /// Figure 4: string-value constructor (default `min` aggregation).
    fn construct_string(&self, rows: &[String], cols: &[String], vals: &[String]) -> Self::Array;

    /// Figure 5: element-wise addition (plus-times ⊕ over the union).
    fn add(&self, a: &Self::Array, b: &Self::Array) -> Self::Array;

    /// Figure 6: array multiplication (plus-times contraction).
    fn matmul(&self, a: &Self::Array, b: &Self::Array) -> Self::Array;

    /// Figure 7: element-wise multiplication (intersection).
    fn elemmul(&self, a: &Self::Array, b: &Self::Array) -> Self::Array;

    /// Nonempty-entry count (result verification across engines).
    fn nnz(&self, a: &Self::Array) -> usize;

    /// Checksum of numeric content: Σ value (cross-engine agreement).
    fn checksum(&self, a: &Self::Array) -> f64;
}

/// The primary engine: [`Assoc`] (sorted arrays + CSR sparse matrices).
#[derive(Debug, Clone, Copy, Default)]
pub struct D4mEngine;

impl Engine for D4mEngine {
    type Array = Assoc;

    fn name(&self) -> &'static str {
        "d4m-rs"
    }

    fn construct_numeric(&self, rows: &[String], cols: &[String], vals: &[f64]) -> Assoc {
        Assoc::from_triples(rows, cols, ValsInput::Num(vals.to_vec()))
    }

    fn construct_string(&self, rows: &[String], cols: &[String], vals: &[String]) -> Assoc {
        Assoc::from_triples(rows, cols, ValsInput::Str(vals.to_vec()))
    }

    fn add(&self, a: &Assoc, b: &Assoc) -> Assoc {
        a.add(b)
    }

    fn matmul(&self, a: &Assoc, b: &Assoc) -> Assoc {
        a.matmul(b)
    }

    fn elemmul(&self, a: &Assoc, b: &Assoc) -> Assoc {
        a.elemmul(b)
    }

    fn nnz(&self, a: &Assoc) -> usize {
        a.nnz()
    }

    fn checksum(&self, a: &Assoc) -> f64 {
        a.total()
    }
}

#[cfg(test)]
mod tests {
    use super::btree::BTreeEngine;
    use super::hashmap::HashMapEngine;
    use super::*;
    use crate::util::prop::check;

    /// Run the same random workload through all three engines and insist
    /// on identical nnz + checksums for every benched operation.
    #[test]
    fn prop_engines_agree_on_all_figure_ops() {
        let d4m = D4mEngine;
        let hash = HashMapEngine;
        let btree = BTreeEngine;
        check("3 engines agree (construct/add/matmul/elemmul)", 60, |g| {
            let (r1, c1, v1) = g.triples(50, 14);
            let (r2, c2, _) = g.triples(50, 14);
            let ones1 = vec![1.0; r1.len()];
            let ones2 = vec![1.0; r2.len()];

            let (da, ha, ba) = (
                d4m.construct_numeric(&r1, &c1, &ones1),
                hash.construct_numeric(&r1, &c1, &ones1),
                btree.construct_numeric(&r1, &c1, &ones1),
            );
            let (db, hb, bb) = (
                d4m.construct_numeric(&r2, &c2, &ones2),
                hash.construct_numeric(&r2, &c2, &ones2),
                btree.construct_numeric(&r2, &c2, &ones2),
            );
            // Constructor with values (min aggregation).
            let (dv, hv, bv) = (
                d4m.construct_numeric(&r1, &c1, &v1),
                hash.construct_numeric(&r1, &c1, &v1),
                btree.construct_numeric(&r1, &c1, &v1),
            );
            assert_eq!(d4m.nnz(&dv), hash.nnz(&hv));
            assert_eq!(d4m.nnz(&dv), btree.nnz(&bv));
            assert_eq!(d4m.checksum(&dv), hash.checksum(&hv));
            assert_eq!(d4m.checksum(&dv), btree.checksum(&bv));

            for (op_name, d, h, b) in [
                ("add", d4m.add(&da, &db), hash.add(&ha, &hb), btree.add(&ba, &bb)),
                ("matmul", d4m.matmul(&da, &db), hash.matmul(&ha, &hb), btree.matmul(&ba, &bb)),
                (
                    "elemmul",
                    d4m.elemmul(&da, &db),
                    hash.elemmul(&ha, &hb),
                    btree.elemmul(&ba, &bb),
                ),
            ] {
                assert_eq!(d4m.nnz(&d), hash.nnz(&h), "{op_name} nnz d4m vs hash");
                assert_eq!(d4m.nnz(&d), btree.nnz(&b), "{op_name} nnz d4m vs btree");
                assert_eq!(d4m.checksum(&d), hash.checksum(&h), "{op_name} checksum hash");
                assert_eq!(d4m.checksum(&d), btree.checksum(&b), "{op_name} checksum btree");
            }
        });
    }

    #[test]
    fn string_constructors_agree() {
        let d4m = D4mEngine;
        let hash = HashMapEngine;
        let btree = BTreeEngine;
        check("string constructor agreement", 60, |g| {
            let (r, c, _) = g.triples(40, 10);
            let vals: Vec<String> = (0..r.len()).map(|_| g.rng().ascii_lower(8)).collect();
            let d = d4m.construct_string(&r, &c, &vals);
            let h = hash.construct_string(&r, &c, &vals);
            let b = btree.construct_string(&r, &c, &vals);
            assert_eq!(d4m.nnz(&d), hash.nnz(&h));
            assert_eq!(d4m.nnz(&d), btree.nnz(&b));
        });
    }
}
