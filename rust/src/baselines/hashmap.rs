//! Dict-of-triples baseline engine.
//!
//! The "obvious" implementation: a `HashMap<(row, col), value>`. O(1)
//! point access and cheap construction, but no sorted structure — so
//! union/intersection ops probe per-entry and `@` must build a row
//! index on the fly. This plays the role of a naive scripting-language
//! implementation curve in the figure reproductions.

use super::Engine;
use std::collections::HashMap;

/// Array representation: a flat hash map (numeric) plus the D4M zero
/// rules (no zero values stored).
#[derive(Debug, Clone, Default)]
pub struct HashArray {
    /// Numeric cells.
    pub cells: HashMap<(String, String), f64>,
    /// String cells (used only by the string constructor bench).
    pub str_cells: HashMap<(String, String), String>,
}

/// The dict-of-dict engine.
#[derive(Debug, Clone, Copy, Default)]
pub struct HashMapEngine;

impl Engine for HashMapEngine {
    type Array = HashArray;

    fn name(&self) -> &'static str {
        "hashmap"
    }

    fn construct_numeric(&self, rows: &[String], cols: &[String], vals: &[f64]) -> HashArray {
        let mut cells: HashMap<(String, String), f64> = HashMap::with_capacity(rows.len());
        for i in 0..rows.len() {
            cells
                .entry((rows[i].clone(), cols[i].clone()))
                .and_modify(|v| *v = v.min(vals[i]))
                .or_insert(vals[i]);
        }
        cells.retain(|_, v| *v != 0.0);
        HashArray { cells, str_cells: HashMap::new() }
    }

    fn construct_string(&self, rows: &[String], cols: &[String], vals: &[String]) -> HashArray {
        let mut str_cells: HashMap<(String, String), String> =
            HashMap::with_capacity(rows.len());
        for i in 0..rows.len() {
            let key = (rows[i].clone(), cols[i].clone());
            match str_cells.get_mut(&key) {
                Some(v) => {
                    if vals[i] < *v {
                        *v = vals[i].clone();
                    }
                }
                None => {
                    str_cells.insert(key, vals[i].clone());
                }
            }
        }
        str_cells.retain(|_, v| !v.is_empty());
        HashArray { cells: HashMap::new(), str_cells }
    }

    fn add(&self, a: &HashArray, b: &HashArray) -> HashArray {
        let mut cells = a.cells.clone();
        for (k, v) in &b.cells {
            *cells.entry(k.clone()).or_insert(0.0) += v;
        }
        cells.retain(|_, v| *v != 0.0);
        HashArray { cells, str_cells: HashMap::new() }
    }

    fn matmul(&self, a: &HashArray, b: &HashArray) -> HashArray {
        // Index B by row, then contract: C[r, c2] += A[r, k] * B[k, c2].
        let mut b_by_row: HashMap<&str, Vec<(&str, f64)>> = HashMap::new();
        for ((r, c), v) in &b.cells {
            b_by_row.entry(r.as_str()).or_default().push((c.as_str(), *v));
        }
        let mut cells: HashMap<(String, String), f64> = HashMap::new();
        for ((r, k), av) in &a.cells {
            if let Some(brow) = b_by_row.get(k.as_str()) {
                for (c2, bv) in brow {
                    *cells.entry((r.clone(), c2.to_string())).or_insert(0.0) += av * bv;
                }
            }
        }
        cells.retain(|_, v| *v != 0.0);
        HashArray { cells, str_cells: HashMap::new() }
    }

    fn elemmul(&self, a: &HashArray, b: &HashArray) -> HashArray {
        // Probe the smaller operand against the larger.
        let (small, large) = if a.cells.len() <= b.cells.len() {
            (&a.cells, &b.cells)
        } else {
            (&b.cells, &a.cells)
        };
        let mut cells = HashMap::with_capacity(small.len());
        for (k, v) in small {
            if let Some(w) = large.get(k) {
                let p = v * w;
                if p != 0.0 {
                    cells.insert(k.clone(), p);
                }
            }
        }
        HashArray { cells, str_cells: HashMap::new() }
    }

    fn nnz(&self, a: &HashArray) -> usize {
        a.cells.len() + a.str_cells.len()
    }

    fn checksum(&self, a: &HashArray) -> f64 {
        a.cells.values().sum::<f64>() + a.str_cells.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn construct_min_aggregates_and_drops_zero() {
        let e = HashMapEngine;
        let a = e.construct_numeric(
            &s(&["r", "r", "q"]),
            &s(&["c", "c", "d"]),
            &[5.0, 3.0, 0.0],
        );
        assert_eq!(a.cells[&("r".into(), "c".into())], 3.0);
        assert_eq!(e.nnz(&a), 1);
    }

    #[test]
    fn add_and_elemmul() {
        let e = HashMapEngine;
        let a = e.construct_numeric(&s(&["r"]), &s(&["c"]), &[2.0]);
        let b = e.construct_numeric(&s(&["r", "x"]), &s(&["c", "y"]), &[3.0, 1.0]);
        let sum = e.add(&a, &b);
        assert_eq!(sum.cells[&("r".into(), "c".into())], 5.0);
        assert_eq!(e.nnz(&sum), 2);
        let prod = e.elemmul(&a, &b);
        assert_eq!(prod.cells[&("r".into(), "c".into())], 6.0);
        assert_eq!(e.nnz(&prod), 1);
    }

    #[test]
    fn matmul_contracts() {
        let e = HashMapEngine;
        let a = e.construct_numeric(&s(&["r", "r"]), &s(&["k1", "k2"]), &[2.0, 3.0]);
        let b = e.construct_numeric(&s(&["k1", "k2"]), &s(&["c", "c"]), &[10.0, 100.0]);
        let c = e.matmul(&a, &b);
        assert_eq!(c.cells[&("r".into(), "c".into())], 320.0);
    }

    #[test]
    fn string_construct_lex_min() {
        let e = HashMapEngine;
        let a = e.construct_string(&s(&["r", "r"]), &s(&["c", "c"]), &s(&["zz", "aa"]));
        assert_eq!(a.str_cells[&("r".into(), "c".into())], "aa");
    }
}
